//! One-vs-one multiclass training and voting (paper §5: MNIST8M uses
//! pairwise coupling as LibSVM does; times are the accumulated per-pair
//! training times).
//!
//! [`OvoModel::train`] runs the pairs sequentially (the seed behavior);
//! [`OvoModel::train_parallel`] dispatches them over the pool so a
//! multicore box trains many pairs at once — pair trainers typically
//! share one [`crate::kernel::cache::SharedRowCache`] so the concurrent
//! subproblems stay within a single kernel-cache byte budget.
//! [`OvoModel::train_with`] packages both behind the unified
//! [`Trainer`] API: one configured trainer fans out per pair.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::cache::SharedRowCache;
use crate::metrics::Stopwatch;
use crate::model::{next_line, SvmModel};
use crate::pool;
use crate::solvers::api::Trainer;
use crate::solvers::common::cache_shards;

/// LibSVM's vote argmax: most votes wins, ties broken toward the smaller
/// class id. One definition shared by [`OvoModel::predict`],
/// [`OvoModel::vote_one`] and the serve registry's packed OvO scorer, so
/// all three agree exactly.
pub fn vote_argmax(votes: &[u32]) -> usize {
    votes
        .iter()
        .enumerate()
        .max_by(|(ia, va), (ib, vb)| va.cmp(vb).then(ib.cmp(ia)))
        .map(|(i, _)| i)
        .unwrap()
}

/// A one-vs-one ensemble: models for every unordered class pair (a < b),
/// where a positive margin votes for class `a`.
#[derive(Debug, Clone)]
pub struct OvoModel {
    pub classes: usize,
    pub pairs: Vec<(usize, usize)>,
    pub models: Vec<SvmModel>,
    /// Accumulated per-pair training seconds (the Table-1 convention).
    pub train_secs: f64,
}

impl OvoModel {
    /// Train one binary model per class pair with the provided closure.
    pub fn train<F>(ds: &Dataset, mut train_pair: F) -> Result<OvoModel>
    where
        F: FnMut(&Dataset, usize, usize) -> Result<SvmModel>,
    {
        assert!(ds.is_multiclass(), "dataset has no class ids");
        let k = ds.num_classes();
        assert!(k >= 2);
        let mut pairs = Vec::new();
        let mut models = Vec::new();
        let sw = Stopwatch::new();
        for a in 0..k {
            for b in (a + 1)..k {
                let view = ds.ovo_view(a, b);
                if view.n == 0 {
                    continue;
                }
                models.push(train_pair(&view, a, b)?);
                pairs.push((a, b));
            }
        }
        Ok(OvoModel {
            classes: k,
            pairs,
            models,
            train_secs: sw.total().as_secs_f64(),
        })
    }

    /// Train the pair models concurrently over `workers` pool threads.
    /// `train_pair` must be thread-safe (`Fn + Sync`); the resulting pair
    /// order is identical to [`OvoModel::train`]'s, and `train_secs` stays
    /// the *accumulated* per-pair time (the Table-1 convention), not the
    /// smaller wall-clock of the concurrent run.
    pub fn train_parallel<F>(ds: &Dataset, workers: usize, train_pair: F) -> Result<OvoModel>
    where
        F: Fn(&Dataset, usize, usize) -> Result<SvmModel> + Sync,
    {
        assert!(ds.is_multiclass(), "dataset has no class ids");
        let k = ds.num_classes();
        assert!(k >= 2);
        let mut pair_ids = Vec::with_capacity(k * (k - 1) / 2);
        for a in 0..k {
            for b in (a + 1)..k {
                pair_ids.push((a, b));
            }
        }
        let results: Vec<Result<Option<(usize, usize, SvmModel, f64)>>> =
            pool::parallel_map(workers.max(1), pair_ids.len(), |p| {
                let (a, b) = pair_ids[p];
                let view = ds.ovo_view(a, b);
                if view.n == 0 {
                    return Ok(None);
                }
                let _sp = crate::trace::span("ovo/pair");
                let t0 = std::time::Instant::now();
                let model = train_pair(&view, a, b)?;
                Ok(Some((a, b, model, t0.elapsed().as_secs_f64())))
            });
        let mut pairs = Vec::new();
        let mut models = Vec::new();
        let mut train_secs = 0.0f64;
        for r in results {
            if let Some((a, b, m, secs)) = r? {
                pairs.push((a, b));
                models.push(m);
                train_secs += secs;
            }
        }
        Ok(OvoModel { classes: k, pairs, models, train_secs })
    }

    /// Train every pair through one configured [`Trainer`]. On a
    /// multithreaded engine the pairs run concurrently: pair-level
    /// workers split the trainer's thread budget, and every pair
    /// subproblem draws kernel rows from one shared cache of `cache_mb`
    /// megabytes (group id = pair), so the combined footprint stays
    /// within a single byte budget. Pair order and `train_secs`
    /// semantics match [`OvoModel::train`].
    pub fn train_with(ds: &Dataset, trainer: &Trainer, cache_mb: usize) -> Result<OvoModel> {
        let threads = trainer.threads();
        let k = ds.num_classes();
        let n_pairs = k * (k - 1) / 2;
        if threads > 1 && n_pairs > 1 {
            let workers = threads.min(n_pairs);
            // pair-level workers share the thread budget with each pair's
            // own scan parallelism; the pool bounds total concurrency
            let inner = Engine::cpu_par((threads / workers).max(1));
            let cache = Arc::new(SharedRowCache::new(
                cache_mb * 1024 * 1024,
                cache_shards(threads),
            ));
            let classes = k as u64;
            OvoModel::train_parallel(ds, workers, |view, a, b| {
                let group = a as u64 * classes + b as u64;
                Ok(trainer
                    .clone()
                    .engine(inner.clone())
                    .shared_cache(cache.clone(), group)
                    .train(view)?
                    .model)
            })
        } else {
            OvoModel::train(ds, |view, _, _| Ok(trainer.train(view)?.model))
        }
    }

    /// Predict a class id for each row by pairwise voting (ties broken
    /// toward the smaller class id, LibSVM-style).
    pub fn predict(&self, ds: &Dataset, threads: usize) -> Vec<usize> {
        let mut votes = vec![vec![0u32; self.classes]; ds.n];
        for (m, &(a, b)) in self.models.iter().zip(&self.pairs) {
            let margins = m.decision_batch(ds, threads);
            for (i, &f) in margins.iter().enumerate() {
                if f > 0.0 {
                    votes[i][a] += 1;
                } else {
                    votes[i][b] += 1;
                }
            }
        }
        votes.into_iter().map(|v| vote_argmax(&v)).collect()
    }

    /// Class id (and its vote count) for a single input by pairwise
    /// voting — the scalar path the serve registry falls back to. Matches
    /// [`OvoModel::predict`] row for row.
    pub fn vote_one(&self, x: &[f32]) -> (usize, u32) {
        let mut votes = vec![0u32; self.classes];
        for (m, &(a, b)) in self.models.iter().zip(&self.pairs) {
            if m.decision(x) > 0.0 {
                votes[a] += 1;
            } else {
                votes[b] += 1;
            }
        }
        let c = vote_argmax(&votes);
        (c, votes[c])
    }

    /// Total expansion vectors across all pair models.
    pub fn total_vectors(&self) -> usize {
        self.models.iter().map(|m| m.num_vectors()).sum()
    }

    /// Save the ensemble in a self-describing text container: a v1 header
    /// (class count, accumulated train seconds, pair count) followed by
    /// each pair's label-map line and its embedded [`SvmModel`] v1 block.
    /// Pair models keep their own kernels — mixed per-pair kernels
    /// round-trip.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "wu-svm-ovo v1")?;
        writeln!(w, "classes {}", self.classes)?;
        writeln!(w, "train_secs {}", self.train_secs)?;
        writeln!(w, "pairs {}", self.pairs.len())?;
        for (m, &(a, b)) in self.models.iter().zip(&self.pairs) {
            writeln!(w, "pair {a} {b}")?;
            m.write_to(&mut w)?;
        }
        Ok(())
    }

    /// Load an ensemble saved by [`OvoModel::save`].
    pub fn load(path: &Path) -> Result<OvoModel> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut lines = std::io::BufReader::new(f).lines();
        if next_line(&mut lines)?.trim() != "wu-svm-ovo v1" {
            bail!("not a wu-svm ovo model file");
        }
        let classes: usize = next_line(&mut lines)?
            .strip_prefix("classes ")
            .context("classes line")?
            .parse()?;
        let train_secs: f64 = next_line(&mut lines)?
            .strip_prefix("train_secs ")
            .context("train_secs line")?
            .parse()?;
        let n_pairs: usize = next_line(&mut lines)?
            .strip_prefix("pairs ")
            .context("pairs line")?
            .parse()?;
        let mut pairs = Vec::with_capacity(n_pairs);
        let mut models = Vec::with_capacity(n_pairs);
        for _ in 0..n_pairs {
            let pline = next_line(&mut lines)?;
            let ptok: Vec<&str> = pline.split_ascii_whitespace().collect();
            let (a, b) = match ptok.as_slice() {
                ["pair", a, b] => (a.parse::<usize>()?, b.parse::<usize>()?),
                _ => bail!("bad pair line '{pline}'"),
            };
            if a >= b || b >= classes {
                bail!("pair ({a},{b}) out of range for {classes} classes");
            }
            pairs.push((a, b));
            let model = SvmModel::read_from(&mut lines)?;
            // every pair must score the same feature dimension — a
            // mismatch would panic at serve time instead of load time
            if let Some(first) = models.first() {
                if model.d != first.d {
                    bail!("pair ({a},{b}) has dim {}, expected {}", model.d, first.d);
                }
            }
            models.push(model);
        }
        Ok(OvoModel { classes, pairs, models, train_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::engine::Engine;
    use crate::kernel::KernelKind;
    use crate::metrics::multiclass_error;
    use crate::solvers::smo::{self, SmoParams};

    fn three_class(n: usize, seed: u64) -> Dataset {
        let spec = SynthSpec { classes: 3, clusters: 2, sigma: 0.05, d: 4, ..Default::default() };
        generate(&spec, n, seed, "mc3")
    }

    #[test]
    fn trains_all_pairs() {
        let ds = three_class(300, 1);
        let ovo = OvoModel::train(&ds, |view, _, _| {
            Ok(smo::train(view, KernelKind::Rbf { gamma: 2.0 },
                          &SmoParams { c: 10.0, ..Default::default() },
                          &Engine::cpu_seq())?.model)
        })
        .unwrap();
        assert_eq!(ovo.pairs, vec![(0, 1), (0, 2), (1, 2)]);
        assert_eq!(ovo.models.len(), 3);
        assert!(ovo.total_vectors() > 0);
    }

    #[test]
    fn classifies_well_separated_classes() {
        let tr = three_class(600, 2);
        // same centers (same seed), new draw? same seed -> same data; subsample
        let te = three_class(300, 2);
        let te = te.subsample(200, 9);
        let ovo = OvoModel::train(&tr, |view, _, _| {
            Ok(smo::train(view, KernelKind::Rbf { gamma: 2.0 },
                          &SmoParams { c: 10.0, ..Default::default() },
                          &Engine::cpu_seq())?.model)
        })
        .unwrap();
        let pred = ovo.predict(&te, 2);
        let err = multiclass_error(&pred, &te.class_ids);
        assert!(err < 0.05, "error {err}");
    }

    #[test]
    fn parallel_training_matches_sequential() {
        let ds = three_class(300, 4);
        let train_pair = |view: &Dataset, _a: usize, _b: usize| {
            Ok(smo::train(
                view,
                KernelKind::Rbf { gamma: 2.0 },
                &SmoParams { c: 10.0, ..Default::default() },
                &Engine::cpu_seq(),
            )?
            .model)
        };
        let seq = OvoModel::train(&ds, train_pair).unwrap();
        let par = OvoModel::train_parallel(&ds, 4, train_pair).unwrap();
        assert_eq!(par.pairs, seq.pairs);
        assert_eq!(par.models.len(), seq.models.len());
        for (a, b) in par.models.iter().zip(&seq.models) {
            assert_eq!(a.coef.len(), b.coef.len());
            assert!((a.bias - b.bias).abs() < 1e-6);
        }
        let te = ds.subsample(100, 5);
        assert_eq!(par.predict(&te, 2), seq.predict(&te, 2));
    }

    #[test]
    fn save_load_round_trips_per_pair_kernels_and_label_maps() {
        // deliberately mixed per-pair kernels and a sparse pair list (class
        // 1 vs 3 missing): everything must survive the text round trip
        let mk = |kernel: KernelKind, bias: f32, solver: &str| SvmModel {
            kernel,
            vectors: vec![0.1, 0.2, 0.9, 0.4],
            d: 2,
            coef: vec![0.75, -1.25],
            bias,
            solver: solver.into(),
        };
        let ovo = OvoModel {
            classes: 4,
            pairs: vec![(0, 1), (0, 3), (2, 3)],
            models: vec![
                mk(KernelKind::Rbf { gamma: 0.5 }, 0.1, "smo"),
                mk(KernelKind::Linear, -0.2, "wss"),
                mk(KernelKind::Poly { degree: 3, gamma: 0.7, coef0: 1.5 }, 0.3, "spsvm"),
            ],
            train_secs: 12.25,
        };
        let dir = std::env::temp_dir().join("wu_svm_ovo_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ovo.model");
        ovo.save(&path).unwrap();
        let back = OvoModel::load(&path).unwrap();
        assert_eq!(back.classes, 4);
        assert_eq!(back.pairs, ovo.pairs);
        assert_eq!(back.train_secs, 12.25);
        assert_eq!(back.models.len(), 3);
        for (a, b) in back.models.iter().zip(&ovo.models) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.vectors, b.vectors);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.solver, b.solver);
        }
        // behavioral equality, not just field equality
        let ds = Dataset::new_multiclass("t", 2, vec![0.3, 0.6, 0.8, 0.1], vec![0, 2]);
        assert_eq!(back.predict(&ds, 1), ovo.predict(&ds, 1));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage_and_bad_pairs() {
        let dir = std::env::temp_dir().join("wu_svm_ovo_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.ovo");
        std::fs::write(&bad, "not an ovo model\n").unwrap();
        assert!(OvoModel::load(&bad).is_err());
        std::fs::write(
            &bad,
            "wu-svm-ovo v1\nclasses 2\ntrain_secs 0\npairs 1\npair 1 1\n",
        )
        .unwrap();
        assert!(OvoModel::load(&bad).is_err());
        // mismatched per-pair dims must fail at load, not panic at serve
        let mk = |d: usize| SvmModel {
            kernel: KernelKind::Linear,
            vectors: vec![0.5; d],
            d,
            coef: vec![1.0],
            bias: 0.0,
            solver: "t".into(),
        };
        let mismatched = OvoModel {
            classes: 3,
            pairs: vec![(0, 1), (1, 2)],
            models: vec![mk(2), mk(3)],
            train_secs: 0.0,
        };
        mismatched.save(&bad).unwrap();
        assert!(OvoModel::load(&bad).is_err());
        std::fs::remove_file(bad).ok();
    }

    #[test]
    fn vote_one_matches_batch_predict() {
        let ds = three_class(240, 7);
        let ovo = OvoModel::train(&ds, |view, _, _| {
            Ok(smo::train(view, KernelKind::Rbf { gamma: 2.0 },
                          &SmoParams { c: 10.0, ..Default::default() },
                          &Engine::cpu_seq())?.model)
        })
        .unwrap();
        let te = ds.subsample(40, 3);
        let batch = ovo.predict(&te, 2);
        for i in 0..te.n {
            let (c, votes) = ovo.vote_one(te.row(i));
            assert_eq!(c, batch[i], "row {i}");
            assert!(votes >= 1 && votes <= ovo.pairs.len() as u32);
        }
    }

    #[test]
    fn vote_tie_break_prefers_smaller_class() {
        // hand-build two constant models voting for different classes
        let m_pos = SvmModel {
            kernel: KernelKind::Linear,
            vectors: vec![0.0],
            d: 1,
            coef: vec![0.0],
            bias: 1.0,
            solver: "t".into(),
        };
        let mut m_neg = m_pos.clone();
        m_neg.bias = -1.0;
        let ovo = OvoModel {
            classes: 3,
            pairs: vec![(0, 1), (0, 2), (1, 2)],
            // (0,1): vote 0; (0,2): vote 2; (1,2): vote 1 -> three-way tie
            models: vec![m_pos.clone(), m_neg.clone(), m_pos.clone()],
            train_secs: 0.0,
        };
        let ds = Dataset::new_multiclass("t", 1, vec![0.5], vec![0]);
        let pred = ovo.predict(&ds, 1);
        assert_eq!(pred[0], 0);
    }
}
