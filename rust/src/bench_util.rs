//! Measurement harness (the offline registry has no criterion).
//!
//! `cargo bench` targets use `harness = false` and this module: warmup,
//! repeated samples, median/mean/min/stddev, and aligned table output.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub runs: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

/// Run `f` `runs` times after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, runs: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, &mut times)
}

/// Time a single run (for long end-to-end cases).
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> Sample {
    let t0 = Instant::now();
    f();
    let mut times = vec![t0.elapsed()];
    summarize(name, &mut times)
}

fn summarize(name: &str, times: &mut [Duration]) -> Sample {
    times.sort();
    let runs = times.len();
    let total: Duration = times.iter().sum();
    let mean = total / runs as u32;
    let median = times[runs / 2];
    let min = times[0];
    let mean_s = mean.as_secs_f64();
    let var = times
        .iter()
        .map(|t| {
            let d = t.as_secs_f64() - mean_s;
            d * d
        })
        .sum::<f64>()
        / runs as f64;
    Sample {
        name: name.to_string(),
        runs,
        mean,
        median,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

impl Sample {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>9} x{}",
            self.name,
            fmt(self.median),
            fmt(self.mean),
            fmt(self.min),
            fmt(self.stddev),
            self.runs
        )
    }
}

fn fmt(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Print a bench table header.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>9} runs",
        "case", "median", "mean", "min", "stddev"
    );
}

/// Whether this run is the CI smoke pass (`BENCH_SMOKE=1`): bench
/// targets shrink to seconds-sized workloads so their *code paths*
/// execute in CI, and they skip overwriting the checked-in BENCH_*.json
/// records (smoke numbers are not measurements).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `full` normally, `tiny` under `BENCH_SMOKE=1`.
pub fn smoke_or<T>(tiny: T, full: T) -> T {
    if smoke() {
        tiny
    } else {
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let s = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.runs, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn bench_once_single_run() {
        let s = bench_once("one", || {});
        assert_eq!(s.runs, 1);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt(Duration::from_nanos(500)).ends_with("us"));
        assert!(fmt(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn row_contains_name() {
        let s = bench("named", 0, 2, || {});
        assert!(s.row().contains("named"));
    }
}
