//! Cholesky factorization and SPD solves.
//!
//! Used by the full-kernel baselines (primal Newton on small n) and as a
//! cross-check for the CG solver. Plain right-looking factorization with
//! f64 accumulation; the systems here are at most a few thousand on a side.

use super::Matrix;

/// Errors from the direct solvers.
#[derive(Debug)]
pub enum CholError {
    NotPd(usize, f64),
    Dim(String),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotPd(pivot, value) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {value})")
            }
            CholError::Dim(dims) => write!(f, "dimension mismatch: {dims}"),
        }
    }
}

impl std::error::Error for CholError {}

/// Lower-triangular Cholesky factor L with A = L L^T.
pub fn factor(a: &Matrix) -> Result<Matrix, CholError> {
    if a.rows != a.cols {
        return Err(CholError::Dim(format!("{}x{}", a.rows, a.cols)));
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let mut diag = a.at(j, j) as f64;
        for k in 0..j {
            let v = l.at(j, k) as f64;
            diag -= v * v;
        }
        if diag <= 0.0 {
            return Err(CholError::NotPd(j, diag));
        }
        let dj = diag.sqrt();
        l.set(j, j, dj as f32);
        for i in (j + 1)..n {
            let mut v = a.at(i, j) as f64;
            for k in 0..j {
                v -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            l.set(i, j, (v / dj) as f32);
        }
    }
    Ok(l)
}

/// Solve A x = b given the factor L (forward then backward substitution).
pub fn solve_with_factor(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut v = b[i] as f64;
        for k in 0..i {
            v -= l.at(i, k) as f64 * y[k];
        }
        y[i] = v / l.at(i, i) as f64;
    }
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut v = y[i];
        for k in (i + 1)..n {
            v -= l.at(k, i) as f64 * x[k];
        }
        x[i] = v / l.at(i, i) as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

/// Factor A + reg I with escalating jitter: on a `NotPd` failure the
/// ridge is multiplied by 10 (floored at 1e-6) and the factorization is
/// retried, up to `tries` attempts. Returns the factor together with
/// the ridge that succeeded. This is the one retry policy shared by
/// [`solve_ridge`], the Nyström landmark factorization
/// ([`super::lowrank`]) and the LS-SVM regularizer, so they cannot
/// drift apart.
pub fn factor_ridge(a: &Matrix, reg: f32, tries: usize) -> Result<(Matrix, f32), CholError> {
    let mut reg = reg;
    let mut last = CholError::NotPd(0, 0.0);
    for _ in 0..tries.max(1) {
        let mut aa = a.clone();
        for i in 0..aa.rows {
            let v = aa.at(i, i) + reg;
            aa.set(i, i, v);
        }
        match factor(&aa) {
            Ok(l) => return Ok((l, reg)),
            Err(e) => {
                last = e;
                reg = (reg * 10.0).max(1e-6);
            }
        }
    }
    Err(last)
}

/// One-shot SPD solve with ridge fallback: tries A + reg I with
/// increasing reg ([`factor_ridge`]) until the factorization succeeds,
/// then falls back to a bare attempt so the original error surfaces.
pub fn solve_ridge(a: &Matrix, b: &[f32], reg: f32) -> Result<Vec<f32>, CholError> {
    match factor_ridge(a, reg, 8) {
        Ok((l, _)) => Ok(solve_with_factor(&l, b)),
        Err(_) => factor(a).map(|l| solve_with_factor(&l, b)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, gemm_nt};
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let a = Matrix::from_vec(n, n, (0..n * n).map(|_| rng.gaussian_f32()).collect());
        let mut c = Matrix::zeros(n, n);
        gemm_nt(1, &a, &a, &mut c);
        for i in 0..n {
            c.set(i, i, c.at(i, i) + n as f32);
        }
        c
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(7);
        let a = spd(&mut rng, 20);
        let l = factor(&a).unwrap();
        // A == L L^T
        for i in 0..20 {
            for j in 0..20 {
                let e: f32 = dot(&l.row(i)[..=j.min(i)], &l.row(j)[..=j.min(i)]);
                assert!((a.at(i, j) - e).abs() < 1e-2 * a.at(i, i).abs().max(1.0));
            }
        }
    }

    #[test]
    fn solve_recovers_solution() {
        let mut rng = Rng::new(8);
        let a = spd(&mut rng, 30);
        let x_true: Vec<f32> = (0..30).map(|_| rng.gaussian_f32()).collect();
        let mut b = vec![0.0; 30];
        crate::linalg::gemv(1, &a, &x_true, &mut b);
        let l = factor(&a).unwrap();
        let x = solve_with_factor(&l, &b);
        for (xa, xb) in x.iter().zip(&x_true) {
            assert!((xa - xb).abs() < 1e-2, "{xa} vs {xb}");
        }
    }

    #[test]
    fn not_pd_detected() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(matches!(factor(&a), Err(CholError::NotPd(_, _))));
    }

    #[test]
    fn ridge_fallback_solves_singular() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]); // rank 1
        let x = solve_ridge(&a, &[2.0, 2.0], 1e-4).unwrap();
        // residual small under the ridge
        assert!((x[0] + x[1] - 2.0).abs() < 0.1);
    }

    #[test]
    fn factor_ridge_escalates_and_reports_reg() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]); // rank 1
        let (_, reg) = factor_ridge(&a, 0.0, 8).unwrap();
        assert!(reg >= 1e-6, "escalated ridge, got {reg}");
        // an SPD input succeeds on the first try with the ridge unchanged
        let mut rng = Rng::new(9);
        let s = spd(&mut rng, 10);
        let (_, reg0) = factor_ridge(&s, 0.0, 8).unwrap();
        assert_eq!(reg0, 0.0);
    }

    #[test]
    fn identity_solve() {
        let a = Matrix::eye(5);
        let l = factor(&a).unwrap();
        let b = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(solve_with_factor(&l, &b), b.to_vec());
    }
}
