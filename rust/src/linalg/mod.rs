//! Dense linear-algebra substrate (row-major f32).
//!
//! This is the hand-written counterpart to the optimized library the
//! implicit approach leans on: blocked, thread-parallel GEMM/GEMV plus the
//! small direct solvers the baselines need. The explicit engines and the
//! full-kernel solvers (multiplicative update, primal Newton) run on this;
//! the implicit engine runs on XLA artifacts instead.

pub mod chol;
pub mod cg;

use crate::pool;
use crate::pool::SendPtr;

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Frobenius-norm distance to another matrix (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product with f64 accumulation (keeps SMO's gradient stable).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        acc += (*a as f64) * (*b as f64);
    }
    acc as f32
}

/// Squared euclidean distance.
#[inline]
pub fn dist2(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        acc += d * d;
    }
    acc as f32
}

/// out = M v  (threaded over rows).
pub fn gemv(threads: usize, m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.cols, v.len());
    assert_eq!(m.rows, out.len());
    let rows_per = ((m.rows + 63) / 64).max(1);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    pool::parallel_for(threads, m.rows, rows_per, |r| {
        let val = dot(m.row(r), v);
        // SAFETY: each index r is visited exactly once (parallel_for
        // guarantee), so writes are disjoint.
        unsafe { *out_ptr.get().add(r) = val }
    });
}

/// out = M^T v (threaded over column blocks).
pub fn gemv_t(threads: usize, m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.rows, v.len());
    assert_eq!(m.cols, out.len());
    out.iter_mut().for_each(|o| *o = 0.0);
    let nblk = (m.cols + 255) / 256;
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    pool::parallel_for(threads, nblk, 1, |b| {
        let c0 = b * 256;
        let c1 = (c0 + 256).min(m.cols);
        // SAFETY: column blocks are disjoint across iterations.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(c0), c1 - c0) };
        for r in 0..m.rows {
            let row = &m.row(r)[c0..c1];
            let vr = v[r];
            if vr != 0.0 {
                axpy(vr, row, o);
            }
        }
    });
}

/// C = A * B^T (threaded, blocked). A: [m,k], B: [n,k] -> C: [m,n].
/// B^T layout means both operands stream row-major — the natural layout for
/// kernel blocks (rows = points).
pub fn gemm_nt(threads: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let n = b.rows;
    let c_ptr = SendPtr::new(c.data.as_mut_ptr());
    pool::parallel_for(threads, a.rows, 8, |i| {
        let ai = a.row(i);
        // SAFETY: row i of C written by exactly one task.
        let ci = unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n) };
        for j in 0..n {
            ci[j] = dot(ai, b.row(j));
        }
    });
}

/// C = A^T * A over rows where mask != 0 (Gauss-Newton Gram block).
/// A: [t, b] -> C: [b, b].
pub fn syrk_masked(threads: usize, a: &Matrix, mask: &[f32], c: &mut Matrix) {
    assert_eq!(a.rows, mask.len());
    assert_eq!((c.rows, c.cols), (a.cols, a.cols));
    let bdim = a.cols;
    let nthread = threads.max(1);
    // Per-thread partial accumulators, reduced at the end.
    let ranges = pool::split_ranges(a.rows, nthread);
    let partials: Vec<Matrix> = {
        let outs: Vec<std::sync::Mutex<Matrix>> = (0..ranges.len())
            .map(|_| std::sync::Mutex::new(Matrix::zeros(bdim, bdim)))
            .collect();
        let ranges_ref = &ranges;
        pool::parallel_for(nthread, ranges.len(), 1, |t| {
            let mut acc = outs[t].lock().unwrap();
            for r in ranges_ref[t].clone() {
                let w = mask[r];
                if w == 0.0 {
                    continue;
                }
                let row = a.row(r);
                for i in 0..bdim {
                    let ri = row[i] * w;
                    if ri == 0.0 {
                        continue;
                    }
                    axpy(ri, row, &mut acc.row_mut(i)[..]);
                }
            }
        });
        outs.into_iter().map(|m| m.into_inner().unwrap()).collect()
    };
    c.data.iter_mut().for_each(|v| *v = 0.0);
    for p in partials {
        for (cv, pv) in c.data.iter_mut().zip(p.data) {
            *cv += pv;
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.gaussian_f32()).collect())
    }

    fn gemv_naive(m: &Matrix, v: &[f32]) -> Vec<f32> {
        (0..m.rows).map(|r| dot(m.row(r), v)).collect()
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(1);
        let m = randmat(&mut rng, 123, 45);
        let v: Vec<f32> = (0..45).map(|_| rng.gaussian_f32()).collect();
        let mut out = vec![0.0; 123];
        gemv(4, &m, &v, &mut out);
        let expect = gemv_naive(&m, &v);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let mut rng = Rng::new(2);
        let m = randmat(&mut rng, 67, 301);
        let v: Vec<f32> = (0..67).map(|_| rng.gaussian_f32()).collect();
        let mut out = vec![0.0; 301];
        gemv_t(4, &m, &v, &mut out);
        let expect = gemv_naive(&m.transpose(), &v);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let mut rng = Rng::new(3);
        let a = randmat(&mut rng, 31, 17);
        let b = randmat(&mut rng, 23, 17);
        let mut c = Matrix::zeros(31, 23);
        gemm_nt(4, &a, &b, &mut c);
        for i in 0..31 {
            for j in 0..23 {
                let e = dot(a.row(i), b.row(j));
                assert!((c.at(i, j) - e).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn syrk_masked_matches_naive() {
        let mut rng = Rng::new(4);
        let a = randmat(&mut rng, 100, 13);
        let mask: Vec<f32> = (0..100)
            .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
            .collect();
        let mut c = Matrix::zeros(13, 13);
        syrk_masked(4, &a, &mask, &mut c);
        for i in 0..13 {
            for j in 0..13 {
                let mut e = 0.0f64;
                for r in 0..100 {
                    e += (mask[r] * a.at(r, i) * a.at(r, j)) as f64;
                }
                assert!((c.at(i, j) - e as f32).abs() < 1e-3,
                        "({i},{j}): {} vs {e}", c.at(i, j));
            }
        }
        // symmetric
        for i in 0..13 {
            for j in 0..13 {
                assert!((c.at(i, j) - c.at(j, i)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(5);
        let m = randmat(&mut rng, 8, 5);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn dist2_zero_on_self() {
        let x = [1.0f32, -2.0, 3.5];
        assert_eq!(dist2(&x, &x), 0.0);
        assert!((dist2(&[0.0, 0.0], &[3.0, 4.0]) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_matches_single_thread() {
        let mut rng = Rng::new(6);
        let a = randmat(&mut rng, 200, 64);
        let b = randmat(&mut rng, 50, 64);
        let mut c1 = Matrix::zeros(200, 50);
        let mut c8 = Matrix::zeros(200, 50);
        gemm_nt(1, &a, &b, &mut c1);
        gemm_nt(8, &a, &b, &mut c8);
        assert!(c1.max_abs_diff(&c8) < 1e-6);
    }
}
