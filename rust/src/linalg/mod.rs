//! Dense linear-algebra substrate (row-major f32).
//!
//! This is the hand-written counterpart to the optimized library the
//! implicit approach leans on. The heavy lifting lives in [`gemm`]: a
//! cache-blocked, panel-packing, register-tiled GEMM with deterministic
//! (thread-count independent) accumulation — see `rust/DESIGN.md` §GEMM.
//! The entry points here (`gemm_nt`, `syrk_masked`, `gemv`, `gemv_t`)
//! are thin drivers over that substrate plus the small direct solvers
//! the baselines need. The explicit engines and the full-kernel solvers
//! (multiplicative update, primal Newton) run on this; the implicit
//! engine runs on XLA artifacts instead.

pub mod chol;
pub mod cg;
pub mod gemm;
pub mod lowrank;
pub mod simd;
pub mod spmm;

use crate::pool;
use crate::pool::SendPtr;

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Maximum absolute elementwise difference to another matrix
    /// (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product with f64 accumulation (keeps SMO's gradient stable).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        acc += (*a as f64) * (*b as f64);
    }
    acc as f32
}

/// Squared euclidean distance.
#[inline]
pub fn dist2(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        acc += d * d;
    }
    acc as f32
}

/// out = M v — driver over the lane-accumulated row kernel in [`gemm`].
pub fn gemv(threads: usize, m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.cols, v.len());
    assert_eq!(m.rows, out.len());
    gemm::gemv_blocked(threads, m.rows, m.cols, &m.data, m.cols, v, out);
}

/// out = M^T v — driver over the panel-streaming kernel in [`gemm`].
pub fn gemv_t(threads: usize, m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.rows, v.len());
    assert_eq!(m.cols, out.len());
    gemm::gemv_t_blocked(threads, m.rows, m.cols, &m.data, m.cols, v, out);
}

/// C = A * B^T (cache-blocked, panel-packed, register-tiled — see
/// [`gemm`]). A: [m,k], B: [n,k] -> C: [m,n]. B^T layout means both
/// operands stream row-major — the natural layout for kernel blocks
/// (rows = points). Output is bit-identical for every thread count.
pub fn gemm_nt(threads: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    gemm::gemm_nt_strided(
        threads, a.rows, b.rows, a.cols, &a.data, a.cols, 1, &b.data, b.cols, 1, None,
        &mut c.data, b.rows,
    );
}

/// The seed's dot-loop GEMM (`m·n` independent f64-accumulated scalar
/// dots), kept as the reference the property tests and the
/// `BENCH_gemm.json` micro-benchmark compare the blocked path against.
pub fn gemm_nt_naive(threads: usize, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let n = b.rows;
    let c_ptr = SendPtr::new(c.data.as_mut_ptr());
    pool::parallel_for(threads, a.rows, 8, |i| {
        let ai = a.row(i);
        // SAFETY: row i of C written by exactly one task.
        let ci = unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n) };
        for j in 0..n {
            ci[j] = dot(ai, b.row(j));
        }
    });
}

/// C = A^T * diag(mask) * A (Gauss-Newton Gram block). A: [t, b] ->
/// C: [b, b]. A driver over the packed GEMM: both operands are the
/// transposed tile expressed through strides (packing transposes for
/// free) and the mask rides along as the B-side depth scale, so there is
/// no materialized Aᵀ and no per-thread partial matrices.
pub fn syrk_masked(threads: usize, a: &Matrix, mask: &[f32], c: &mut Matrix) {
    assert_eq!(a.rows, mask.len());
    assert_eq!((c.rows, c.cols), (a.cols, a.cols));
    let bdim = a.cols;
    gemm::gemm_nt_strided(
        threads, bdim, bdim, a.rows, &a.data, 1, bdim, &a.data, 1, bdim, Some(mask),
        &mut c.data, bdim,
    );
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.gaussian_f32()).collect())
    }

    fn gemv_naive(m: &Matrix, v: &[f32]) -> Vec<f32> {
        (0..m.rows).map(|r| dot(m.row(r), v)).collect()
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(1);
        let m = randmat(&mut rng, 123, 45);
        let v: Vec<f32> = (0..45).map(|_| rng.gaussian_f32()).collect();
        let mut out = vec![0.0; 123];
        gemv(4, &m, &v, &mut out);
        let expect = gemv_naive(&m, &v);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_t_matches_transpose_gemv() {
        let mut rng = Rng::new(2);
        let m = randmat(&mut rng, 67, 301);
        let v: Vec<f32> = (0..67).map(|_| rng.gaussian_f32()).collect();
        let mut out = vec![0.0; 301];
        gemv_t(4, &m, &v, &mut out);
        let expect = gemv_naive(&m.transpose(), &v);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let mut rng = Rng::new(3);
        let a = randmat(&mut rng, 31, 17);
        let b = randmat(&mut rng, 23, 17);
        let mut c = Matrix::zeros(31, 23);
        gemm_nt(4, &a, &b, &mut c);
        for i in 0..31 {
            for j in 0..23 {
                let e = dot(a.row(i), b.row(j));
                assert!((c.at(i, j) - e).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn syrk_masked_matches_naive() {
        let mut rng = Rng::new(4);
        let a = randmat(&mut rng, 100, 13);
        let mask: Vec<f32> = (0..100)
            .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
            .collect();
        let mut c = Matrix::zeros(13, 13);
        syrk_masked(4, &a, &mask, &mut c);
        for i in 0..13 {
            for j in 0..13 {
                let mut e = 0.0f64;
                for r in 0..100 {
                    e += (mask[r] * a.at(r, i) * a.at(r, j)) as f64;
                }
                assert!((c.at(i, j) - e as f32).abs() < 1e-3,
                        "({i},{j}): {} vs {e}", c.at(i, j));
            }
        }
        // symmetric
        for i in 0..13 {
            for j in 0..13 {
                assert!((c.at(i, j) - c.at(j, i)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(5);
        let m = randmat(&mut rng, 8, 5);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn dist2_zero_on_self() {
        let x = [1.0f32, -2.0, 3.5];
        assert_eq!(dist2(&x, &x), 0.0);
        assert!((dist2(&[0.0, 0.0], &[3.0, 4.0]) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_matches_single_thread() {
        // stronger than the seed's 1e-6: the blocked substrate is
        // bit-identical for every thread count (DESIGN.md §GEMM)
        let mut rng = Rng::new(6);
        let a = randmat(&mut rng, 200, 64);
        let b = randmat(&mut rng, 50, 64);
        let mut c1 = Matrix::zeros(200, 50);
        gemm_nt(1, &a, &b, &mut c1);
        for threads in [2usize, 8] {
            let mut ck = Matrix::zeros(200, 50);
            gemm_nt(threads, &a, &b, &mut ck);
            assert_eq!(c1.data, ck.data, "threads {threads}");
        }
    }

    #[test]
    fn blocked_gemm_matches_seed_dot_loop() {
        let mut rng = Rng::new(7);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (31, 29, 17), (100, 40, 300)] {
            let a = randmat(&mut rng, m, k);
            let b = randmat(&mut rng, n, k);
            let mut c = Matrix::zeros(m, n);
            let mut e = Matrix::zeros(m, n);
            gemm_nt(4, &a, &b, &mut c);
            gemm_nt_naive(4, &a, &b, &mut e);
            let dmax = c.max_abs_diff(&e);
            assert!(dmax < 1e-3, "({m},{n},{k}): diff {dmax}");
        }
    }
}
