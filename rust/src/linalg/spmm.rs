//! Row-blocked CSR x dense-transpose SpMM — the sparse counterpart of
//! the packed GEMM in [`super::gemm`] (DESIGN.md §SPARSE).
//!
//! The shape every sparse kernel block needs is `C[t x b] = A · Bᵀ`
//! where A is t CSR rows of the design matrix (the tile / working set /
//! whole training set) and B is a small dense `b x d` block (basis
//! vectors, candidates, query batch). B is repacked once per call into
//! its transpose `Bᵀ[d x b]`, so the inner loop is a pure axpy: for each
//! stored `(col, v)` of a CSR row, `acc[0..b] += v * Bᵀ[col][0..b]` —
//! contiguous, dispatched to the active SIMD backend
//! ([`crate::linalg::simd`]), and O(nnz · b) instead of O(t · d · b).
//!
//! **Determinism.** Parallelism is over row blocks: every output row is
//! owned by exactly one task and accumulated sequentially in stored
//! (ascending-column) order, so the result is bit-identical for every
//! thread count — the same contract as the dense substrate.
//!
//! **Exact diagonals.** Accumulation is chunked at `KC` column
//! boundaries exactly like [`gemm::sum_sq`] (a partial per chunk, chunks
//! added in order; all-zero chunks are identity adds). Therefore the
//! cross product of a row with its own densified copy reproduces
//! `CsrMatrix::sum_sq` bit for bit, `‖x‖² + ‖x‖² - 2·x·x` cancels to an
//! exact 0, and RBF diagonals come out exactly 1.0 — the same contract
//! the dense `rbf_blocked` documents.

use crate::data::sparse::CsrMatrix;
use crate::linalg::gemm::{self, KC};
use crate::linalg::simd::{self, Backend};
use crate::pool;

/// Rows of C owned by one parallel task.
const RB: usize = 8;

/// Repack a row-major `b x d` block into its transpose `d x b` so the
/// SpMM inner loop streams contiguous length-b panels. Each output row
/// is written by exactly one task (deterministic trivially).
fn pack_bt(threads: usize, bm: &[f32], b: usize, d: usize) -> Vec<f32> {
    assert_eq!(bm.len(), b * d);
    let mut bt = vec![0.0f32; d * b];
    pool::parallel_chunks_mut(threads, &mut bt, b, |p, row| {
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = bm[j * d + p];
        }
    });
    bt
}

/// `C[t x b] = A[row0..row0+t] · Bᵀ` with A in CSR and B dense row-major
/// `b x d` (`d = a.cols`). Rows at or past `a.rows` are treated as empty
/// (all-zero tile padding). The axpy inner loop runs on the active SIMD
/// backend; bit-identical for every `threads` value within a backend.
pub fn csr_gemm_nt(
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    bm: &[f32],
    b: usize,
    out: &mut [f32],
) {
    csr_gemm_nt_with(simd::active(), threads, a, row0, t, bm, b, out);
}

/// [`csr_gemm_nt`] pinned to an explicit backend — how the property
/// tests and the scalar-vs-SIMD bench column compare flavors.
#[allow(clippy::too_many_arguments)]
pub fn csr_gemm_nt_with(
    backend: Backend,
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    bm: &[f32],
    b: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), t * b);
    if t == 0 || b == 0 {
        return;
    }
    assert_eq!(bm.len(), b * a.cols);
    let bt = pack_bt(threads, bm, b, a.cols);
    csr_gemm_nt_packed_with(backend, threads, a, row0, t, &bt, b, out);
}

/// [`csr_gemm_nt`] over an already-transposed `d x b` B block (callers
/// that reuse one B across several A tiles pack it once).
pub fn csr_gemm_nt_packed(
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    bt: &[f32],
    b: usize,
    out: &mut [f32],
) {
    csr_gemm_nt_packed_with(simd::active(), threads, a, row0, t, bt, b, out);
}

/// [`csr_gemm_nt_packed`] pinned to an explicit backend.
#[allow(clippy::too_many_arguments)]
pub fn csr_gemm_nt_packed_with(
    backend: Backend,
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    bt: &[f32],
    b: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), t * b);
    if t == 0 || b == 0 {
        return;
    }
    assert_eq!(bt.len(), a.cols * b);
    let nnz_range = (a.row_ptr[(row0 + t).min(a.rows)] - a.row_ptr[row0.min(a.rows)]) as u64;
    crate::trace::count(crate::trace::Counter::SpmmFlops, 2 * (b as u64) * nnz_range);
    crate::trace::count(
        crate::trace::Counter::SpmmBytes,
        4 * (2 * nnz_range + (a.cols as u64) * (b as u64) + (t as u64) * (b as u64)),
    );
    pool::parallel_chunks_mut(threads, out, RB * b, |blk, slice| {
        let mut partial = vec![0.0f32; b];
        let rows_here = slice.len() / b;
        for local in 0..rows_here {
            let r = row0 + blk * RB + local;
            let total = &mut slice[local * b..(local + 1) * b];
            total.iter_mut().for_each(|v| *v = 0.0);
            if r >= a.rows {
                continue;
            }
            let (cols, vals) = a.row(r);
            let mut boundary = KC as u32;
            let mut dirty = false;
            for (&c, &v) in cols.iter().zip(vals) {
                if c >= boundary {
                    if dirty {
                        for (tv, pv) in total.iter_mut().zip(partial.iter_mut()) {
                            *tv += *pv;
                            *pv = 0.0;
                        }
                        dirty = false;
                    }
                    boundary = (c / KC as u32 + 1) * KC as u32;
                }
                let panel = &bt[c as usize * b..(c as usize + 1) * b];
                backend.axpy(v, panel, &mut partial);
                dirty = true;
            }
            if dirty {
                for (tv, pv) in total.iter_mut().zip(partial.iter_mut()) {
                    *tv += *pv;
                    *pv = 0.0;
                }
            }
        }
    });
}

/// Sparse-A RBF block: `K[t x b] = exp(-gamma · max(0, ‖aᵢ‖² + ‖bⱼ‖² -
/// 2·aᵢ·bⱼ))` for CSR rows `[row0, row0 + t)` against a dense `b x d`
/// block. The a-side norms are the CSR's precomputed [`CsrMatrix::sum_sq`]
/// (padding rows past `a.rows` count as zero norms, matching the dense
/// zero-row tiles); the b-side norms use [`gemm::sum_sq`] like the dense
/// path. Deterministic for every thread count; symmetric-block diagonals
/// are exactly 1.0 (module docs).
pub fn rbf_csr_blocked(
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    xb: &[f32],
    b: usize,
    gamma: f32,
    out: &mut [f32],
) {
    let d = a.cols;
    let bsq: Vec<f32> = (0..b).map(|j| gemm::sum_sq(&xb[j * d..(j + 1) * d])).collect();
    rbf_csr_blocked_pre(threads, a, row0, t, xb, b, gamma, &bsq, out);
}

/// [`rbf_csr_blocked`] with the b-side squared norms supplied by the
/// caller (they must be in `gemm::sum_sq` order for the exact-diagonal
/// contract to survive).
#[allow(clippy::too_many_arguments)]
pub fn rbf_csr_blocked_pre(
    threads: usize,
    a: &CsrMatrix,
    row0: usize,
    t: usize,
    xb: &[f32],
    b: usize,
    gamma: f32,
    bsq: &[f32],
    out: &mut [f32],
) {
    assert_eq!(out.len(), t * b);
    assert_eq!(bsq.len(), b);
    if t == 0 || b == 0 {
        return;
    }
    csr_gemm_nt(threads, a, row0, t, xb, b, out);
    pool::parallel_chunks_mut(threads, out, b, |i, row| {
        let r = row0 + i;
        let asq = if r < a.rows { a.sum_sq[r] } else { 0.0 };
        for (j, slot) in row.iter_mut().enumerate() {
            let d2 = (asq + bsq[j] - 2.0 * *slot).max(0.0);
            *slot = (-gamma * d2).exp();
        }
    });
}

/// Dense-queries x sparse-vectors RBF block — the serve-time shape:
/// `K[t x b] = exp(-gamma·d²(xᵢ, svⱼ))` for a dense query batch
/// `x[t x d]` against a CSR matrix of b support vectors, with the SV
/// norms precomputed at registration (`CsrMatrix::sum_sq` order). The
/// cross products run through the same SpMM with the operands swapped
/// (`Kᵀ = SV · Xᵀ`); the fused exp pass transposes back, so `out` is the
/// usual row-major `t x b`. Deterministic for every thread count.
pub fn rbf_dense_csr_pre(
    threads: usize,
    x: &[f32],
    t: usize,
    sv: &CsrMatrix,
    gamma: f32,
    out: &mut [f32],
) {
    let b = sv.rows;
    assert_eq!(x.len(), t * sv.cols);
    assert_eq!(out.len(), t * b);
    if t == 0 || b == 0 {
        return;
    }
    let mut kt = vec![0.0f32; b * t];
    csr_gemm_nt(threads, sv, 0, b, x, t, &mut kt);
    let d = sv.cols;
    pool::parallel_chunks_mut(threads, out, b, |i, row| {
        let xsq = gemm::sum_sq(&x[i * d..(i + 1) * d]);
        for (j, slot) in row.iter_mut().enumerate() {
            let d2 = (xsq + sv.sum_sq[j] - 2.0 * kt[j * t + i]).max(0.0);
            *slot = (-gamma * d2).exp();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm_nt_naive, Matrix};
    use crate::rng::Rng;

    fn rand_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> (Vec<f32>, CsrMatrix) {
        let x: Vec<f32> = (0..rows * cols)
            .map(|_| if rng.bernoulli(density) { rng.gaussian_f32() } else { 0.0 })
            .collect();
        let csr = CsrMatrix::from_dense(rows, cols, &x);
        (x, csr)
    }

    #[test]
    fn spmm_matches_naive_reference() {
        let mut rng = Rng::new(1);
        for &(t, b, d) in &[(1usize, 1usize, 1usize), (13, 7, 300), (40, 9, 257), (33, 16, 64)] {
            let (xa, csr) = rand_sparse(&mut rng, t, d, 0.2);
            let bm: Vec<f32> = (0..b * d).map(|_| rng.gaussian_f32()).collect();
            let mut out = vec![0.0f32; t * b];
            csr_gemm_nt(4, &csr, 0, t, &bm, b, &mut out);
            let a = Matrix::from_vec(t, d, xa);
            let bmat = Matrix::from_vec(b, d, bm);
            let mut e = Matrix::zeros(t, b);
            gemm_nt_naive(1, &a, &bmat, &mut e);
            for (g, w) in out.iter().zip(&e.data) {
                assert!((g - w).abs() < 1e-3 * (d as f32).sqrt(), "({t},{b},{d}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmm_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(2);
        let (_, csr) = rand_sparse(&mut rng, 300, 520, 0.1);
        let bm: Vec<f32> = (0..24 * 520).map(|_| rng.gaussian_f32()).collect();
        let mut base = vec![0.0f32; 300 * 24];
        csr_gemm_nt(1, &csr, 0, 300, &bm, 24, &mut base);
        for &threads in &[2usize, 8] {
            let mut got = vec![0.0f32; 300 * 24];
            csr_gemm_nt(threads, &csr, 0, 300, &bm, 24, &mut got);
            for (g, w) in got.iter().zip(&base) {
                assert_eq!(g.to_bits(), w.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn padded_rows_are_zero_and_offsets_work() {
        let mut rng = Rng::new(3);
        let (x, csr) = rand_sparse(&mut rng, 10, 40, 0.3);
        let bm: Vec<f32> = (0..5 * 40).map(|_| rng.gaussian_f32()).collect();
        // rows [6, 14): 4 real rows then 4 past-the-end rows
        let mut out = vec![7.0f32; 8 * 5];
        csr_gemm_nt(2, &csr, 6, 8, &bm, 5, &mut out);
        let a = Matrix::from_vec(10, 40, x);
        let bmat = Matrix::from_vec(5, 40, bm);
        let mut e = Matrix::zeros(10, 5);
        gemm_nt_naive(1, &a, &bmat, &mut e);
        for r in 0..4 {
            for j in 0..5 {
                assert!((out[r * 5 + j] - e.at(6 + r, j)).abs() < 1e-3);
            }
        }
        assert!(out[4 * 5..].iter().all(|&v| v == 0.0), "padding rows must zero");
    }

    #[test]
    fn rbf_diag_exactly_one_and_matches_dense_path() {
        let mut rng = Rng::new(4);
        for &(n, d) in &[(20usize, 300usize), (33, 64), (9, 700)] {
            let (x, csr) = rand_sparse(&mut rng, n, d, 0.15);
            let mut sp = vec![0.0f32; n * n];
            rbf_csr_blocked(3, &csr, 0, n, &x, n, 0.7, &mut sp);
            for i in 0..n {
                assert_eq!(sp[i * n + i], 1.0, "({n},{d}) diag {i}");
            }
            let mut dn = vec![0.0f32; n * n];
            gemm::rbf_blocked(3, &x, n, &x, n, d, 0.7, &mut dn);
            for (a, b) in sp.iter().zip(&dn) {
                assert!((a - b).abs() < 1e-6, "({n},{d}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn dense_csr_serve_path_matches_sparse_a_path() {
        let mut rng = Rng::new(5);
        let (sv_dense, sv) = rand_sparse(&mut rng, 17, 90, 0.2);
        let x: Vec<f32> = (0..11 * 90).map(|_| rng.uniform_f32()).collect();
        let mut serve = vec![0.0f32; 11 * 17];
        rbf_dense_csr_pre(4, &x, 11, &sv, 0.5, &mut serve);
        // reference: dense queries vs densified SVs through the dense path
        let mut want = vec![0.0f32; 11 * 17];
        gemm::rbf_blocked(1, &x, 11, &sv_dense, 17, 90, 0.5, &mut want);
        for (a, b) in serve.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // thread-count determinism
        let mut one = vec![0.0f32; 11 * 17];
        rbf_dense_csr_pre(1, &x, 11, &sv, 0.5, &mut one);
        assert_eq!(serve, one);
    }

    #[test]
    fn empty_shapes_are_fine() {
        let csr = CsrMatrix::empty(0, 5);
        let mut out = vec![];
        csr_gemm_nt(4, &csr, 0, 0, &[1.0; 15], 3, &mut out);
        let mut out2 = vec![];
        rbf_csr_blocked(4, &csr, 0, 0, &[], 0, 1.0, &mut out2);
    }
}
