//! Cache-blocked, panel-packing GEMM — the CPU substrate that makes the
//! implicit engines behave like the paper's optimized BLAS.
//!
//! The paper's implicit methods win because their work collapses into a
//! few large dense ops executed by MKL/CUBLAS. The seed's CPU fallback
//! computed those ops as `m·n` independent f64-converted scalar dot
//! products, which demonstrates the *algorithms* without the
//! *performance mechanism*. This module supplies the mechanism:
//!
//! * **Packing** — operand slabs are repacked into contiguous
//!   depth-major micro-panels (`MR`/`NR` rows wide), so the inner kernel
//!   streams both operands with unit stride regardless of the caller's
//!   layout. Strided packing doubles as free transposition: the masked
//!   SYRK packs `Aᵀ` directly out of the row-major tile.
//! * **Register tiling** — an `MR x NR` micro-kernel accumulates a full
//!   C tile in vector registers. The kernel is dispatched through
//!   [`super::simd`]: explicit AVX2+FMA / NEON flavors on supporting
//!   CPUs (NR=8 is one f32x8 FMA lane per accumulator row), with the
//!   original fixed-shape auto-vectorized scalar code as the portable
//!   fallback (`WU_SVM_FORCE_SCALAR=1` pins it).
//! * **Cache blocking** — the shared `k` dimension is processed in `KC`
//!   slabs (packed panels stay L2-resident), and the C plane is tiled
//!   into `MC x NC` macro-tiles for the 2-D parallel decomposition.
//!
//! **Determinism.** Every C element is owned by exactly one macro-tile
//! task per `k`-slab, slabs run in a fixed sequential order, and the
//! micro-kernel accumulates in a fixed depth order — so the result is
//! bit-identical for every thread count (including 1). That is what
//! lets `cpu-par(k)` engines reproduce `cpu-seq` exactly, the same
//! contract `pool::parallel_reduce` gives the SMO scans.

use super::simd::{self, Backend};
use crate::pool::{self, SendPtr};

/// Micro-tile rows (A-side panel width).
pub const MR: usize = 8;
/// Micro-tile columns (B-side panel width).
pub const NR: usize = 8;
/// Depth of one packed k-slab.
pub const KC: usize = 256;
/// Rows of one parallel macro-tile (multiple of `MR`).
pub const MC: usize = 64;
/// Columns of one parallel macro-tile (multiple of `NR`).
pub const NC: usize = 128;

/// Lane width of the unrolled vector-friendly reductions below
/// (power of two — the lane combine folds pairwise).
pub const LANES: usize = 8;
const _: () = assert!(LANES.is_power_of_two());

/// f32 dot product accumulated in `LANES` independent lanes combined in
/// a fixed tree order — dispatched to the active SIMD backend
/// ([`simd::active`]), deterministic per backend. The f64 scalar
/// [`crate::linalg::dot`] remains for accuracy-critical callers.
#[inline]
pub fn dot_lanes(x: &[f32], y: &[f32]) -> f32 {
    simd::active().dot(x, y)
}

/// Squared euclidean distance with the same lane scheme as
/// [`dot_lanes`]. Exact 0 on identical inputs (no cancellation) in
/// every backend flavor.
#[inline]
pub fn dist2_lanes(x: &[f32], y: &[f32]) -> f32 {
    simd::active().dist2(x, y)
}

/// Σ xᵢ² accumulated sequentially in `KC` slabs — the exact order the
/// packed GEMM uses for a diagonal element `cᵢᵢ = Σ xₚ·xₚ` under the
/// active backend. RBF callers rely on this: `‖x‖² + ‖x‖² - 2·(x·x)`
/// cancels bit-exactly, so kernel diagonals come out as exactly 1.0.
#[inline]
pub fn sum_sq(x: &[f32]) -> f32 {
    simd::active().sum_sq(x)
}

/// Pack one `pr`-row micro-panel of a strided operand slab into `dst`
/// (depth-major: `dst[p*pr + i]`). Logical element `(r, p)` of the
/// `dim x k` operand lives at `src[r*rs + p*cs]`; rows past `dim` are
/// zero-filled so the micro-kernel never needs edge branches. With
/// `kscale`, depth `p` is scaled by `kscale[k0 + p]` (the masked-SYRK
/// row weight applied on one side).
#[allow(clippy::too_many_arguments)]
fn pack_panel(
    dst: &mut [f32],
    pr: usize,
    src: &[f32],
    rs: usize,
    cs: usize,
    dim: usize,
    q: usize,
    k0: usize,
    kc: usize,
    kscale: Option<&[f32]>,
) {
    debug_assert!(dst.len() >= pr * kc);
    let r0 = q * pr;
    debug_assert!(r0 < dim);
    let rows = pr.min(dim - r0);
    for p in 0..kc {
        let col = &mut dst[p * pr..(p + 1) * pr];
        let kidx = k0 + p;
        let w = kscale.map_or(1.0, |s| s[kidx]);
        if w == 1.0 {
            for (i, slot) in col.iter_mut().take(rows).enumerate() {
                *slot = src[(r0 + i) * rs + kidx * cs];
            }
        } else {
            for (i, slot) in col.iter_mut().take(rows).enumerate() {
                *slot = w * src[(r0 + i) * rs + kidx * cs];
            }
        }
        for slot in col.iter_mut().skip(rows) {
            *slot = 0.0;
        }
    }
}

/// `C = A · Bᵀ` over strided operand views (the general driver under
/// [`crate::linalg::gemm_nt`] and [`crate::linalg::syrk_masked`]).
///
/// `A` is an `m x k` view with element `(i, p)` at `a[i*a_rs + p*a_cs]`;
/// `B` is an `n x k` view with element `(j, p)` at `b[j*b_rs + p*b_cs]`
/// (strides express transposition for free). `C` is row-major `m x n`
/// with leading dimension `ldc` and is overwritten. With `b_kscale`,
/// depth `p` of B is scaled by `b_kscale[p]`, which turns the call into
/// the weighted Gram product `C = A·diag(w)·Bᵀ`.
///
/// The micro-kernel runs on the active SIMD backend
/// ([`simd::active`]); output is bit-identical for every `threads`
/// value within a backend — see module docs.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_strided(
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    b: &[f32],
    b_rs: usize,
    b_cs: usize,
    b_kscale: Option<&[f32]>,
    c: &mut [f32],
    ldc: usize,
) {
    gemm_nt_strided_with(
        simd::active(),
        threads,
        m,
        n,
        k,
        a,
        a_rs,
        a_cs,
        b,
        b_rs,
        b_cs,
        b_kscale,
        c,
        ldc,
    );
}

/// [`gemm_nt_strided`] pinned to an explicit backend — how the
/// property tests and the scalar-vs-SIMD bench columns compare flavors
/// inside one process.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_strided_with(
    backend: Backend,
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    b: &[f32],
    b_rs: usize,
    b_cs: usize,
    b_kscale: Option<&[f32]>,
    c: &mut [f32],
    ldc: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(ldc >= n, "ldc {ldc} < n {n}");
    assert!(c.len() >= (m - 1) * ldc + n, "C buffer too small");
    if k == 0 {
        for r in 0..m {
            for v in &mut c[r * ldc..r * ldc + n] {
                *v = 0.0;
            }
        }
        return;
    }
    if let Some(s) = b_kscale {
        assert!(s.len() >= k, "kscale shorter than k");
    }
    crate::trace::count(
        crate::trace::Counter::GemmFlops,
        2 * (m as u64) * (n as u64) * (k as u64),
    );
    crate::trace::count(
        crate::trace::Counter::GemmBytes,
        4 * ((m as u64) * (k as u64) + (n as u64) * (k as u64) + (m as u64) * (n as u64)),
    );
    let mpan = (m + MR - 1) / MR;
    let npan = (n + NR - 1) / NR;
    let slab = KC.min(k);
    let mut pa = vec![0.0f32; mpan * MR * slab];
    let mut pb = vec![0.0f32; npan * NR * slab];
    let mblk = (m + MC - 1) / MC;
    let nblk = (n + NC - 1) / NC;
    let c_ptr = SendPtr::new(c.as_mut_ptr());

    let mut k0 = 0usize;
    while k0 < k {
        let kc = KC.min(k - k0);
        // ---- pack both operand slabs (parallel over micro-panels) ----
        {
            let pa_ptr = SendPtr::new(pa.as_mut_ptr());
            pool::parallel_for(threads, mpan, 1, |q| {
                // SAFETY: panel q's range is disjoint from every other q.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(pa_ptr.get().add(q * MR * kc), MR * kc)
                };
                pack_panel(dst, MR, a, a_rs, a_cs, m, q, k0, kc, None);
            });
            let pb_ptr = SendPtr::new(pb.as_mut_ptr());
            pool::parallel_for(threads, npan, 1, |q| {
                // SAFETY: panel q's range is disjoint from every other q.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(pb_ptr.get().add(q * NR * kc), NR * kc)
                };
                pack_panel(dst, NR, b, b_rs, b_cs, n, q, k0, kc, b_kscale);
            });
        }
        // ---- 2-D macro-tile sweep over the C plane ----
        let first = k0 == 0;
        let pa_ref = &pa;
        let pb_ref = &pb;
        pool::parallel_for(threads, mblk * nblk, 1, |blk| {
            let bi = blk / nblk;
            let bj = blk % nblk;
            let i_end = (bi * MC + MC).min(m);
            let j_end = (bj * NC + NC).min(n);
            let mut i = bi * MC;
            while i < i_end {
                let panel_a = &pa_ref[(i / MR) * MR * kc..(i / MR + 1) * MR * kc];
                let ih = MR.min(m - i);
                let mut j = bj * NC;
                while j < j_end {
                    let panel_b = &pb_ref[(j / NR) * NR * kc..(j / NR + 1) * NR * kc];
                    let acc = backend.microkernel_8x8(panel_a, panel_b, kc);
                    let jw = NR.min(n - j);
                    for ii in 0..ih {
                        // SAFETY: rows [i, i+ih) x cols [j, j+jw) of C
                        // belong to macro-tile (bi, bj), owned by exactly
                        // this task for this slab.
                        let crow = unsafe {
                            std::slice::from_raw_parts_mut(
                                c_ptr.get().add((i + ii) * ldc + j),
                                jw,
                            )
                        };
                        let arow = &acc[ii * NR..ii * NR + jw];
                        if first {
                            crow.copy_from_slice(arow);
                        } else {
                            for (cv, av) in crow.iter_mut().zip(arow) {
                                *cv += av;
                            }
                        }
                    }
                    j += NR;
                }
                i += MR;
            }
        });
        k0 += kc;
    }
}

/// `out = M v` over a row-major `rows x cols` view (lane-accumulated f32
/// dots, threaded over row chunks). The slice-level form of
/// [`crate::linalg::gemv`] for callers that hold a tile as `&[f32]`.
pub fn gemv_blocked(
    threads: usize,
    rows: usize,
    cols: usize,
    a: &[f32],
    lda: usize,
    v: &[f32],
    out: &mut [f32],
) {
    assert_eq!(v.len(), cols);
    assert_eq!(out.len(), rows);
    assert!(lda >= cols);
    crate::trace::count(crate::trace::Counter::GemmFlops, 2 * (rows as u64) * (cols as u64));
    crate::trace::count(
        crate::trace::Counter::GemmBytes,
        4 * ((rows as u64) * (cols as u64) + (cols as u64) + (rows as u64)),
    );
    let backend = simd::active();
    let rows_per = ((rows + 63) / 64).max(1);
    pool::parallel_chunks_mut(threads, out, rows_per, |c, slice| {
        for (off, slot) in slice.iter_mut().enumerate() {
            let r = c * rows_per + off;
            *slot = backend.dot(&a[r * lda..r * lda + cols], v);
        }
    });
}

/// `K[t x b] = exp(-gamma · max(0, ‖xᵢ‖² + ‖xbⱼ‖² - 2·xᵢ·xbⱼ))` — the
/// canonical norms + GEMM + fused-exp RBF block, shared by
/// `Engine::rbf_block` and `kernel::kernel_block` so the bit-exactness
/// contract lives in one place. Norms use [`sum_sq`] (the GEMM's own
/// accumulation order), so an identical pair of points cancels to a
/// distance of exactly 0 — the diagonal of a symmetric block is exactly
/// 1.0 — and the clamp keeps every value in (0, 1]. Deterministic for
/// every thread count.
#[allow(clippy::too_many_arguments)]
pub fn rbf_blocked(
    threads: usize,
    x: &[f32],
    t: usize,
    xb: &[f32],
    b: usize,
    d: usize,
    gamma: f32,
    out: &mut [f32],
) {
    rbf_blocked_with(simd::active(), threads, x, t, xb, b, d, gamma, out);
}

/// [`rbf_blocked`] pinned to an explicit backend (norms and GEMM run
/// the same flavor, so the exact-diagonal contract holds per backend).
#[allow(clippy::too_many_arguments)]
pub fn rbf_blocked_with(
    backend: Backend,
    threads: usize,
    x: &[f32],
    t: usize,
    xb: &[f32],
    b: usize,
    d: usize,
    gamma: f32,
    out: &mut [f32],
) {
    assert_eq!(xb.len(), b * d);
    if b == 0 {
        assert_eq!(out.len(), t * b);
        return;
    }
    let bsq: Vec<f32> = (0..b).map(|j| backend.sum_sq(&xb[j * d..(j + 1) * d])).collect();
    rbf_blocked_pre_with(backend, threads, x, t, xb, b, d, gamma, &bsq, out);
}

/// [`rbf_blocked`] with the b-side squared norms supplied by the caller.
/// The serve-time entry point: a model registry computes `bsq` once at
/// registration (`serve::registry`), so the per-batch cost drops to one
/// GEMM + a-side norms + the fused exp pass. `bsq[j]` must be
/// `sum_sq(&xb[j*d..(j+1)*d])` — the GEMM's own accumulation order — for
/// the exact-diagonal contract to survive; any other norms silently
/// shift every distance. Deterministic for every thread count.
#[allow(clippy::too_many_arguments)]
pub fn rbf_blocked_pre(
    threads: usize,
    x: &[f32],
    t: usize,
    xb: &[f32],
    b: usize,
    d: usize,
    gamma: f32,
    bsq: &[f32],
    out: &mut [f32],
) {
    rbf_blocked_pre_with(simd::active(), threads, x, t, xb, b, d, gamma, bsq, out);
}

/// [`rbf_blocked_pre`] pinned to an explicit backend. `bsq` must have
/// been computed with the same backend's `sum_sq` for the
/// exact-diagonal contract to survive.
#[allow(clippy::too_many_arguments)]
pub fn rbf_blocked_pre_with(
    backend: Backend,
    threads: usize,
    x: &[f32],
    t: usize,
    xb: &[f32],
    b: usize,
    d: usize,
    gamma: f32,
    bsq: &[f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * d);
    assert_eq!(xb.len(), b * d);
    assert_eq!(out.len(), t * b);
    assert_eq!(bsq.len(), b);
    if b == 0 {
        return;
    }
    gemm_nt_strided_with(backend, threads, t, b, d, x, d, 1, xb, d, 1, None, out, b);
    pool::parallel_chunks_mut(threads, out, b, |i, row| {
        let xsq = backend.sum_sq(&x[i * d..(i + 1) * d]);
        for (j, slot) in row.iter_mut().enumerate() {
            let d2 = (xsq + bsq[j] - 2.0 * *slot).max(0.0);
            *slot = (-gamma * d2).exp();
        }
    });
}

/// `out = Mᵀ v` over a row-major `rows x cols` view: column blocks run in
/// parallel, rows stream through in 8-row panels so each `out` element is
/// updated once per panel instead of once per row. Row order is fixed, so
/// the result is thread-count independent.
pub fn gemv_t_blocked(
    threads: usize,
    rows: usize,
    cols: usize,
    a: &[f32],
    lda: usize,
    v: &[f32],
    out: &mut [f32],
) {
    assert_eq!(v.len(), rows);
    assert_eq!(out.len(), cols);
    assert!(lda >= cols);
    const CB: usize = 256;
    pool::parallel_chunks_mut(threads, out, CB, |bidx, o| {
        let c0 = bidx * CB;
        let c1 = c0 + o.len();
        let w = o.len();
        o.iter_mut().for_each(|x| *x = 0.0);
        let mut r = 0usize;
        while r + 8 <= rows {
            let vv = &v[r..r + 8];
            if vv.iter().all(|&x| x == 0.0) {
                r += 8;
                continue;
            }
            let base = r * lda + c0;
            let r0 = &a[base..base + w];
            let r1 = &a[base + lda..base + lda + w];
            let r2 = &a[base + 2 * lda..base + 2 * lda + w];
            let r3 = &a[base + 3 * lda..base + 3 * lda + w];
            let r4 = &a[base + 4 * lda..base + 4 * lda + w];
            let r5 = &a[base + 5 * lda..base + 5 * lda + w];
            let r6 = &a[base + 6 * lda..base + 6 * lda + w];
            let r7 = &a[base + 7 * lda..base + 7 * lda + w];
            for j in 0..w {
                o[j] += ((vv[0] * r0[j] + vv[1] * r1[j])
                    + (vv[2] * r2[j] + vv[3] * r3[j]))
                    + ((vv[4] * r4[j] + vv[5] * r5[j])
                        + (vv[6] * r6[j] + vv[7] * r7[j]));
            }
            r += 8;
        }
        while r < rows {
            let vr = v[r];
            if vr != 0.0 {
                let row = &a[r * lda + c0..r * lda + c1];
                for (oj, aj) in o.iter_mut().zip(row) {
                    *oj += vr * aj;
                }
            }
            r += 1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, Matrix};
    use crate::rng::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.gaussian_f32()).collect())
    }

    fn blocked(threads: usize, a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.rows);
        gemm_nt_strided(
            threads, a.rows, b.rows, a.cols, &a.data, a.cols, 1, &b.data, b.cols, 1, None,
            &mut c.data, b.rows,
        );
        c
    }

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                c.set(i, j, dot(a.row(i), b.row(j)));
            }
        }
        c
    }

    #[test]
    fn matches_naive_on_odd_shapes() {
        // non-bucket shapes: 1x1, prime dims, k < MR, k spanning slabs
        let cases = [
            (1usize, 1usize, 1usize),
            (1, 1, 7),
            (31, 29, 23),
            (7, 13, 3),
            (17, 5, 300), // k crosses the KC slab boundary
            (9, 64, 1),
            (64, 9, 257),
            (130, 70, 40),
        ];
        let mut rng = Rng::new(100);
        for &(m, n, k) in &cases {
            let a = randmat(&mut rng, m, k);
            let b = randmat(&mut rng, n, k);
            let c = blocked(4, &a, &b);
            let e = naive(&a, &b);
            let dmax = c.max_abs_diff(&e);
            let scale = (k as f32).sqrt();
            assert!(dmax < 1e-4 * scale.max(1.0), "({m},{n},{k}): diff {dmax}");
        }
    }

    #[test]
    fn empty_dims_are_fine() {
        let mut rng = Rng::new(101);
        // m == 0 / n == 0: nothing to write
        let a = Matrix::zeros(0, 5);
        let b = randmat(&mut rng, 4, 5);
        let mut c = Matrix::zeros(0, 4);
        gemm_nt_strided(4, 0, 4, 5, &a.data, 5, 1, &b.data, 5, 1, None, &mut c.data, 4);
        let mut c2 = Matrix::zeros(4, 0);
        gemm_nt_strided(4, 4, 0, 5, &b.data, 5, 1, &a.data, 5, 1, None, &mut c2.data, 0);
        // k == 0: C must be zeroed (empty sum), even if it held garbage
        let a0 = Matrix::zeros(3, 0);
        let b0 = Matrix::zeros(2, 0);
        let mut c0 = Matrix::from_vec(3, 2, vec![9.0; 6]);
        gemm_nt_strided(4, 3, 2, 0, &a0.data, 0, 1, &b0.data, 0, 1, None, &mut c0.data, 2);
        assert!(c0.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(102);
        for &(m, n, k) in &[(257usize, 129usize, 300usize), (40, 40, 17), (1024, 64, 64)] {
            let a = randmat(&mut rng, m, k);
            let b = randmat(&mut rng, n, k);
            let c1 = blocked(1, &a, &b);
            for &threads in &[2usize, 8] {
                let ck = blocked(threads, &a, &b);
                assert_eq!(c1.data, ck.data, "({m},{n},{k}) threads={threads}");
            }
        }
    }

    #[test]
    fn strided_operands_express_transpose() {
        // C = Aᵀ·A via strides must equal gemm(Aᵀ as a materialized matrix)
        let mut rng = Rng::new(103);
        let a = randmat(&mut rng, 37, 11); // t x b
        let at = a.transpose();
        let expect = naive(&at, &at);
        let mut c = Matrix::zeros(11, 11);
        gemm_nt_strided(
            3, 11, 11, 37, &a.data, 1, 11, &a.data, 1, 11, None, &mut c.data, 11,
        );
        assert!(c.max_abs_diff(&expect) < 1e-3, "diff {}", c.max_abs_diff(&expect));
    }

    #[test]
    fn kscale_weights_the_depth_dimension() {
        let mut rng = Rng::new(104);
        let a = randmat(&mut rng, 5, 50);
        let b = randmat(&mut rng, 6, 50);
        let w: Vec<f32> = (0..50).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let mut c = Matrix::zeros(5, 6);
        gemm_nt_strided(2, 5, 6, 50, &a.data, 50, 1, &b.data, 50, 1, Some(&w), &mut c.data, 6);
        for i in 0..5 {
            for j in 0..6 {
                let mut e = 0.0f64;
                for p in 0..50 {
                    e += (w[p] * a.at(i, p) * b.at(j, p)) as f64;
                }
                assert!((c.at(i, j) - e as f32).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn ldc_larger_than_n_leaves_padding_untouched() {
        let mut rng = Rng::new(105);
        let a = randmat(&mut rng, 9, 12);
        let b = randmat(&mut rng, 5, 12);
        let ldc = 8;
        let mut c = vec![7.0f32; 9 * ldc];
        gemm_nt_strided(4, 9, 5, 12, &a.data, 12, 1, &b.data, 12, 1, None, &mut c, ldc);
        let e = naive(&a, &b);
        for i in 0..9 {
            for j in 0..5 {
                assert!((c[i * ldc + j] - e.at(i, j)).abs() < 1e-4);
            }
            for j in 5..ldc {
                assert_eq!(c[i * ldc + j], 7.0, "padding clobbered at ({i},{j})");
            }
        }
    }

    #[test]
    fn dot_lanes_matches_f64_dot() {
        let mut rng = Rng::new(106);
        for len in [0usize, 1, 7, 8, 9, 64, 257] {
            let x: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let got = dot_lanes(&x, &y);
            let want = dot(&x, &y);
            assert!((got - want).abs() < 1e-3, "len {len}: {got} vs {want}");
            assert_eq!(dist2_lanes(&x, &x), 0.0, "len {len}");
        }
    }

    #[test]
    fn sum_sq_cancels_with_gemm_diagonal() {
        // the RBF-diagonal contract: ‖x‖² from sum_sq must equal the
        // GEMM's x·x bit-for-bit, including across slab boundaries
        let mut rng = Rng::new(107);
        for d in [3usize, 8, 255, 256, 257, 700] {
            let x = randmat(&mut rng, 1, d);
            let c = blocked(1, &x, &x);
            assert_eq!(c.data[0].to_bits(), sum_sq(x.row(0)).to_bits(), "d={d}");
        }
    }

    #[test]
    fn rbf_blocked_pre_is_bit_identical_to_recomputed() {
        // the serve path supplies registration-time norms; with norms from
        // sum_sq (the contract) the output must match rbf_blocked bit for
        // bit, for every thread count
        let mut rng = Rng::new(109);
        for &(t, b, d) in &[(7usize, 5usize, 3usize), (33, 16, 257), (64, 8, 64)] {
            let x: Vec<f32> = (0..t * d).map(|_| rng.gaussian_f32()).collect();
            let xb: Vec<f32> = (0..b * d).map(|_| rng.gaussian_f32()).collect();
            let bsq: Vec<f32> = (0..b).map(|j| sum_sq(&xb[j * d..(j + 1) * d])).collect();
            let mut base = vec![0.0f32; t * b];
            rbf_blocked(1, &x, t, &xb, b, d, 0.7, &mut base);
            for &threads in &[1usize, 4] {
                let mut pre = vec![0.0f32; t * b];
                rbf_blocked_pre(threads, &x, t, &xb, b, d, 0.7, &bsq, &mut pre);
                for (a, e) in pre.iter().zip(&base) {
                    assert_eq!(a.to_bits(), e.to_bits(), "({t},{b},{d}) threads={threads}");
                }
            }
            // diagonal contract survives the precomputed-norms path
            let mut sym = vec![0.0f32; b * b];
            rbf_blocked_pre(2, &xb, b, &xb, b, d, 0.7, &bsq, &mut sym);
            for i in 0..b {
                assert_eq!(sym[i * b + i], 1.0, "diag {i}");
            }
        }
    }

    #[test]
    fn gemv_t_blocked_matches_naive() {
        let mut rng = Rng::new(108);
        for &(rows, cols) in &[(1usize, 1usize), (9, 300), (67, 301), (300, 5), (8, 8)] {
            let m = randmat(&mut rng, rows, cols);
            let v: Vec<f32> = (0..rows).map(|_| rng.gaussian_f32()).collect();
            let mut out = vec![0.0f32; cols];
            gemv_t_blocked(4, rows, cols, &m.data, cols, &v, &mut out);
            for j in 0..cols {
                let mut e = 0.0f64;
                for r in 0..rows {
                    e += (v[r] * m.at(r, j)) as f64;
                }
                assert!(
                    (out[j] - e as f32).abs() < 1e-3,
                    "({rows},{cols}) col {j}: {} vs {e}",
                    out[j]
                );
            }
            // thread-count determinism
            let mut o1 = vec![0.0f32; cols];
            gemv_t_blocked(1, rows, cols, &m.data, cols, &v, &mut o1);
            assert_eq!(out, o1);
        }
    }
}
