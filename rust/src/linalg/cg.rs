//! Conjugate-gradient SPD solver (CPU counterpart of the `cg_solve`
//! artifact; used by the CpuSeq/CpuPar engines and the primal baseline).
//!
//! One loop body serves every caller: [`run`] is parameterized by an
//! apply closure, and the masked matrix solve ([`solve_masked`]) and
//! the kernel-operator solve ([`solve_operator`], the LS-SVM normal
//! equations) are thin shells around it — identical update arithmetic,
//! so the refactor changes no bits.

use super::{dot, gemv, Matrix};
use crate::kernel::operator::KernelOperator;

/// Result of a CG solve.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub x: Vec<f32>,
    pub iters: usize,
    pub residual: f32,
}

/// The CG loop over an abstract SPD apply. `tol` bounds the *squared*
/// residual norm, matching the historical convention of this module.
pub fn run(
    apply: &mut dyn FnMut(&[f32], &mut Vec<f32>),
    b: &[f32],
    max_iters: usize,
    tol: f32,
) -> CgResult {
    let n = b.len();
    let mut x = vec![0.0f32; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut rs = dot(&r, &r);
    let mut ap = vec![0.0f32; n];
    let mut iters = 0;
    for _ in 0..max_iters {
        if rs <= tol {
            break;
        }
        iters += 1;
        apply(&p, &mut ap);
        let denom = dot(&p, &ap).max(1e-30);
        let alpha = rs / denom;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs.max(1e-30);
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    CgResult { x, iters, residual: rs.sqrt() }
}

/// Solve (M (H + reg I) M + (I-M)) x = M g by conjugate gradient, where
/// M = diag(mask). Mirrors the masked-system convention of the XLA
/// `cg_solve` artifact exactly (model.py) so engines are interchangeable.
pub fn solve_masked(
    threads: usize,
    h: &Matrix,
    g: &[f32],
    mask: &[f32],
    reg: f32,
    max_iters: usize,
    tol: f32,
) -> CgResult {
    let n = h.rows;
    assert_eq!(h.cols, n);
    assert_eq!(g.len(), n);
    assert_eq!(mask.len(), n);

    let mut apply = |v: &[f32], out: &mut Vec<f32>| {
        // out = (M (H + reg I) M + (I-M)) v
        let mv: Vec<f32> = v.iter().zip(mask).map(|(a, m)| a * m).collect();
        gemv(threads, h, &mv, out);
        for i in 0..n {
            out[i] = mask[i] * (out[i] + reg * mv[i]) + (1.0 - mask[i]) * v[i];
        }
    };

    let b: Vec<f32> = g.iter().zip(mask).map(|(a, m)| a * m).collect();
    let mut res = run(&mut apply, &b, max_iters, tol);
    for i in 0..n {
        res.x[i] *= mask[i];
    }
    res
}

/// Solve (K + reg I) x = g against any [`KernelOperator`] — with a
/// low-rank operator this is the O(n·r)-per-iteration regularized
/// normal-equations solve LS-SVM runs on.
pub fn solve_operator(
    op: &dyn KernelOperator,
    g: &[f32],
    reg: f32,
    max_iters: usize,
    tol: f32,
) -> CgResult {
    let n = op.n();
    assert_eq!(g.len(), n);
    let mut apply = |v: &[f32], out: &mut Vec<f32>| {
        op.matvec(v, out);
        for i in 0..n {
            out[i] += reg * v[i];
        }
    };
    run(&mut apply, g, max_iters, tol)
}

/// Plain SPD solve (mask of ones).
pub fn solve(
    threads: usize,
    h: &Matrix,
    g: &[f32],
    reg: f32,
    max_iters: usize,
    tol: f32,
) -> CgResult {
    let mask = vec![1.0f32; g.len()];
    solve_masked(threads, h, g, &mask, reg, max_iters, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm_nt;
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let a = Matrix::from_vec(n, n, (0..n * n).map(|_| rng.gaussian_f32()).collect());
        let mut c = Matrix::zeros(n, n);
        gemm_nt(1, &a, &a, &mut c);
        for i in 0..n {
            c.set(i, i, c.at(i, i) + n as f32);
        }
        c
    }

    #[test]
    fn solves_spd_system() {
        let mut rng = Rng::new(10);
        let h = spd(&mut rng, 40);
        let x_true: Vec<f32> = (0..40).map(|_| rng.gaussian_f32()).collect();
        let mut g = vec![0.0; 40];
        gemv(1, &h, &x_true, &mut g);
        let r = solve(1, &h, &g, 0.0, 400, 1e-12);
        for (a, b) in r.x.iter().zip(&x_true) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn masked_slots_stay_zero() {
        let mut rng = Rng::new(11);
        let h = spd(&mut rng, 20);
        let g: Vec<f32> = (0..20).map(|_| rng.gaussian_f32()).collect();
        let mut mask = vec![1.0f32; 20];
        for i in 12..20 {
            mask[i] = 0.0;
        }
        let r = solve_masked(1, &h, &g, &mask, 1e-3, 200, 1e-12);
        for i in 12..20 {
            assert_eq!(r.x[i], 0.0);
        }
        // the occupied sub-system is actually solved
        for i in 0..12 {
            let mut resid = -g[i];
            for j in 0..12 {
                resid += (h.at(i, j) + if i == j { 1e-3 } else { 0.0 }) * r.x[j];
            }
            assert!(resid.abs() < 1e-2, "row {i} resid {resid}");
        }
    }

    #[test]
    fn matches_cholesky() {
        let mut rng = Rng::new(12);
        let h = spd(&mut rng, 25);
        let g: Vec<f32> = (0..25).map(|_| rng.gaussian_f32()).collect();
        let xc = crate::linalg::chol::solve_ridge(&h, &g, 0.0).unwrap();
        let r = solve(1, &h, &g, 0.0, 300, 1e-14);
        for (a, b) in r.x.iter().zip(&xc) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn identity_is_one_iteration() {
        let h = Matrix::eye(8);
        let g = vec![1.0f32; 8];
        let r = solve(1, &h, &g, 0.0, 50, 1e-20);
        assert!(r.iters <= 2);
        for v in &r.x {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn operator_solve_matches_matrix_solve() {
        let mut rng = Rng::new(14);
        let h = spd(&mut rng, 32);
        let g: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let op = crate::kernel::operator::ExactDense::from_matrix(h.clone(), 1);
        let a = solve(1, &h, &g, 1e-3, 200, 1e-12);
        let b = solve_operator(&op, &g, 1e-3, 200, 1e-12);
        assert_eq!(a.iters, b.iters);
        for (x, y) in a.x.iter().zip(&b.x) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn threaded_matches_sequential() {
        let mut rng = Rng::new(13);
        let h = spd(&mut rng, 64);
        let g: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        let r1 = solve(1, &h, &g, 1e-3, 100, 1e-12);
        let r8 = solve(8, &h, &g, 1e-3, 100, 1e-12);
        for (a, b) in r1.x.iter().zip(&r8.x) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
