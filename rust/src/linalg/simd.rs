//! Explicit SIMD backend layer with runtime CPU-feature dispatch
//! (DESIGN.md §SIMD).
//!
//! The paper's implicit methods win because their work collapses into a
//! few large dense ops executed by *highly optimized* kernels. Until
//! now the hot inner loops (the 8x8 GEMM micro-kernel, the lane dot /
//! distance reductions, the SpMM axpy) relied on LLVM auto-vectorizing
//! fixed-shape scalar code. This module makes that half of the thesis
//! explicit: hand-written AVX2+FMA (x86-64) and NEON (aarch64)
//! flavors of every hot primitive, selected **once per process** by
//! runtime feature detection and overridable with
//! `WU_SVM_FORCE_SCALAR=1`. The original scalar code remains the
//! portable fallback and the reference the property tests compare
//! against.
//!
//! **Determinism contract.** Within one backend, every primitive
//! accumulates each output element in a fixed per-element order — the
//! SIMD flavors vectorize *across* independent accumulators (the NR=8
//! columns of a micro-kernel row, the 8 lanes of a dot product, the b
//! columns of an SpMM panel), never across the sequential depth chain.
//! So the bit-identical-across-thread-counts contract of the scalar
//! substrate holds per backend, and every `sum_sq`-vs-GEMM-diagonal
//! cancellation contract survives (the FMA flavor of `sum_sq` is the
//! same fused chain the FMA micro-kernel applies to a diagonal
//! element).
//!
//! **Across backends** results agree only to rounding: FMA fuses
//! multiply and add into one rounding step, so scalar-vs-SIMD is a
//! tolerance (≤1e-5 relative) contract, not a bit contract. That is why
//! the backend is resolved once per process: mixing flavors within one
//! run would silently break the exact-diagonal contracts (e.g. CSR
//! norms computed under one flavor against cross products from
//! another).

use super::gemm::{KC, LANES, MR, NR};
use std::sync::OnceLock;

/// Which compute flavor the process runs on. All variants exist on all
/// architectures (so tests and benches can name them portably); only
/// the native ones are ever returned by [`Backend::detect`], and
/// dispatching a non-native variant falls back to scalar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The portable scalar/auto-vectorized code paths (the pre-SIMD
    /// substrate, bit-for-bit).
    Scalar,
    /// x86-64 AVX2 + FMA: 8-wide f32 fused multiply-add lanes.
    Avx2Fma,
    /// aarch64 NEON: 4-wide f32 fused multiply-add lanes (two per
    /// 8-wide logical lane group).
    Neon,
}

impl Backend {
    /// Probe the CPU and pick the fastest supported backend.
    /// `force_scalar` short-circuits to [`Backend::Scalar`] — the pure
    /// form of the `WU_SVM_FORCE_SCALAR` override, kept separate so it
    /// is testable without touching the process environment.
    pub fn detect(force_scalar: bool) -> Backend {
        if force_scalar {
            return Backend::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return Backend::Avx2Fma;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Backend::Neon;
            }
        }
        Backend::Scalar
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2Fma => "avx2+fma",
            Backend::Neon => "neon",
        }
    }

    /// Accumulate an `MR x NR` C tile from two packed depth-major
    /// panels over `kc` depth steps — the inner kernel of
    /// [`super::gemm::gemm_nt_strided`]. Row-major `out[i*NR + j]`.
    /// Per-element accumulation order is the sequential depth chain in
    /// every flavor; the SIMD flavors vectorize across the NR columns.
    #[inline]
    pub fn microkernel_8x8(self, pa: &[f32], pb: &[f32], kc: usize) -> [f32; MR * NR] {
        debug_assert!(pa.len() >= kc * MR && pb.len() >= kc * NR);
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2Fma is only constructed after a successful
            // runtime probe for avx2+fma (Backend::detect).
            Backend::Avx2Fma => unsafe { microkernel_avx2(pa, pb, kc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: Neon is only constructed after a runtime probe.
            Backend::Neon => unsafe { microkernel_neon(pa, pb, kc) },
            _ => microkernel_scalar(pa, pb, kc),
        }
    }

    /// Lane-accumulated f32 dot product (LANES independent chains
    /// combined by the fixed pairwise tree). Deterministic per backend.
    #[inline]
    pub fn dot(self, x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { dot_avx2(x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Neon => unsafe { dot_neon(x, y) },
            _ => dot_scalar(x, y),
        }
    }

    /// Squared euclidean distance with the same lane scheme as
    /// [`Backend::dot`]. Exact 0 on identical inputs in every flavor
    /// (each lane subtracts before squaring — no cancellation).
    #[inline]
    pub fn dist2(self, x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { dist2_avx2(x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Neon => unsafe { dist2_neon(x, y) },
            _ => dist2_scalar(x, y),
        }
    }

    /// Σ xᵢ² in KC-chunked sequential order — exactly the chain this
    /// backend's micro-kernel applies to a diagonal element
    /// `cᵢᵢ = Σ xₚ·xₚ`. The FMA flavors are deliberately *scalar*
    /// sequential fused chains: vectorizing the depth dimension would
    /// change the diagonal accumulation order and break the RBF
    /// exact-diagonal contract.
    #[inline]
    pub fn sum_sq(self, x: &[f32]) -> f32 {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { sum_sq_fma_x86(x) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => sum_sq_fma_body(x),
            _ => sum_sq_scalar(x),
        }
    }

    /// Σ v² over one sorted sparse row in the same KC-chunk order as
    /// [`Backend::sum_sq`] (zero columns are identity adds under FMA
    /// too: `fma(0, b, acc) == acc`), so the sparse norm equals the
    /// dense one bit for bit within a backend.
    #[inline]
    pub fn sparse_sum_sq(self, cols: &[u32], vals: &[f32]) -> f32 {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { sparse_sum_sq_fma_x86(cols, vals) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => sparse_sum_sq_fma_body(cols, vals),
            _ => sparse_sum_sq_scalar(cols, vals),
        }
    }

    /// Dot of one sorted sparse row with a dense vector, in the same
    /// KC-chunk order as [`Backend::sparse_sum_sq`] — so a row dotted
    /// with its own densified copy reproduces the stored norm bitwise.
    #[inline]
    pub fn sparse_dot_dense(self, cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { sparse_dot_dense_fma_x86(cols, vals, x) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => sparse_dot_dense_fma_body(cols, vals, x),
            _ => sparse_dot_dense_scalar(cols, vals, x),
        }
    }

    /// `y[j] += a * x[j]` — the SpMM inner loop
    /// ([`super::spmm::csr_gemm_nt_packed`] calls this once per stored
    /// entry). Each `y[j]` is an independent accumulator, so
    /// vectorizing across j preserves the per-element order.
    #[inline]
    pub fn axpy(self, a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Avx2Fma => unsafe { axpy_avx2(a, x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: variant implies a successful runtime probe.
            Backend::Neon => unsafe { axpy_neon(a, x, y) },
            _ => axpy_scalar(a, x, y),
        }
    }
}

/// `WU_SVM_FORCE_SCALAR` values that mean "yes".
pub fn parse_force_scalar(v: &str) -> bool {
    matches!(v.trim(), "1" | "true" | "yes" | "on")
}

fn force_scalar_env() -> bool {
    std::env::var("WU_SVM_FORCE_SCALAR").is_ok_and(|v| parse_force_scalar(&v))
}

/// The process-wide backend: detected once on first use (respecting
/// `WU_SVM_FORCE_SCALAR`), then immutable. One flavor per process is
/// what keeps the cross-primitive bit contracts (CSR norms vs GEMM
/// diagonals, registry norms vs serve-time blocks) intact.
pub fn active() -> Backend {
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| Backend::detect(force_scalar_env()))
}

/// Human-readable summary of what the CPU offers (independent of what
/// [`active`] picked — `wu-svm info` prints both).
pub fn detected_features() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        let probes = [
            ("sse2", is_x86_feature_detected!("sse2")),
            ("sse4.2", is_x86_feature_detected!("sse4.2")),
            ("avx", is_x86_feature_detected!("avx")),
            ("avx2", is_x86_feature_detected!("avx2")),
            ("fma", is_x86_feature_detected!("fma")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
        ];
        let have: Vec<&str> =
            probes.iter().filter(|(_, h)| *h).map(|(n, _)| *n).collect();
        format!("x86_64: {}", if have.is_empty() { "none".into() } else { have.join(" ") })
    }
    #[cfg(target_arch = "aarch64")]
    {
        let neon = std::arch::is_aarch64_feature_detected!("neon");
        format!("aarch64: {}", if neon { "neon" } else { "none" })
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        format!("{}: no explicit SIMD probe", std::env::consts::ARCH)
    }
}

/// Log the detected features and chosen backend to stderr, once per
/// process — called from pool/engine init so every run is attributable
/// to the hardware path that produced it.
pub fn log_once() {
    static LOGGED: OnceLock<()> = OnceLock::new();
    LOGGED.get_or_init(|| {
        eprintln!("wu-svm simd: backend={} [{}]", active().name(), detected_features());
    });
}

// ---------------------------------------------------------------------
// scalar flavors — the pre-SIMD substrate, verbatim. These stay the
// portable fallback and the reference every property test compares the
// SIMD flavors against.
// ---------------------------------------------------------------------

/// Combine the lane accumulators in a fixed pairwise tree — derived
/// from `LANES` (retuning the constant cannot silently drop lanes) and
/// order-deterministic. Shared by every backend flavor so the lane
/// layout, not the combine, is the only thing that varies.
#[inline]
pub fn combine_lanes(acc: [f32; LANES]) -> f32 {
    let mut tmp = acc;
    let mut width = LANES / 2;
    while width > 0 {
        for l in 0..width {
            tmp[l] += tmp[l + width];
        }
        width /= 2;
    }
    tmp[0]
}

#[inline]
fn microkernel_scalar(pa: &[f32], pb: &[f32], kc: usize) -> [f32; MR * NR] {
    let mut acc = [0.0f32; MR * NR];
    for p in 0..kc {
        let a = &pa[p * MR..(p + 1) * MR];
        let b = &pb[p * NR..(p + 1) * NR];
        for i in 0..MR {
            let ai = a[i];
            let row = &mut acc[i * NR..(i + 1) * NR];
            for j in 0..NR {
                row[j] += ai * b[j];
            }
        }
    }
    acc
}

#[inline]
fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let xb = &x[c * LANES..(c + 1) * LANES];
        let yb = &y[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] += xb[l] * yb[l];
        }
    }
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        s += x[i] * y[i];
    }
    s
}

#[inline]
fn dist2_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let xb = &x[c * LANES..(c + 1) * LANES];
        let yb = &y[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            let d = xb[l] - yb[l];
            acc[l] += d * d;
        }
    }
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

#[inline]
fn sum_sq_scalar(x: &[f32]) -> f32 {
    let mut total = 0.0f32;
    for chunk in x.chunks(KC) {
        let mut s = 0.0f32;
        for &v in chunk {
            s += v * v;
        }
        total += s;
    }
    total
}

#[inline]
fn sparse_sum_sq_scalar(cols: &[u32], vals: &[f32]) -> f32 {
    let mut total = 0.0f32;
    let mut partial = 0.0f32;
    let mut boundary = KC as u32;
    for (&c, &v) in cols.iter().zip(vals) {
        if c >= boundary {
            total += partial;
            partial = 0.0;
            boundary = (c / KC as u32 + 1) * KC as u32;
        }
        partial += v * v;
    }
    total + partial
}

#[inline]
fn sparse_dot_dense_scalar(cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    let mut total = 0.0f32;
    let mut partial = 0.0f32;
    let mut boundary = KC as u32;
    for (&c, &v) in cols.iter().zip(vals) {
        if c >= boundary {
            total += partial;
            partial = 0.0;
            boundary = (c / KC as u32 + 1) * KC as u32;
        }
        partial += v * x[c as usize];
    }
    total + partial
}

#[inline]
fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

// ---------------------------------------------------------------------
// fused sequential chains shared by the FMA backends. `mul_add` is the
// IEEE fused operation whatever the codegen (hardware fma inside the
// `target_feature(fma)` wrappers, libm elsewhere), so the *values* are
// backend-portable even when the speed is not. These must stay scalar
// sequential: they mirror the per-element depth chain of the FMA
// micro-kernels, which is what the exact-diagonal contracts consume.
// ---------------------------------------------------------------------

#[inline(always)]
fn sum_sq_fma_body(x: &[f32]) -> f32 {
    let mut total = 0.0f32;
    for chunk in x.chunks(KC) {
        let mut s = 0.0f32;
        for &v in chunk {
            s = v.mul_add(v, s);
        }
        total += s;
    }
    total
}

#[inline(always)]
fn sparse_sum_sq_fma_body(cols: &[u32], vals: &[f32]) -> f32 {
    let mut total = 0.0f32;
    let mut partial = 0.0f32;
    let mut boundary = KC as u32;
    for (&c, &v) in cols.iter().zip(vals) {
        if c >= boundary {
            total += partial;
            partial = 0.0;
            boundary = (c / KC as u32 + 1) * KC as u32;
        }
        partial = v.mul_add(v, partial);
    }
    total + partial
}

#[inline(always)]
fn sparse_dot_dense_fma_body(cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    let mut total = 0.0f32;
    let mut partial = 0.0f32;
    let mut boundary = KC as u32;
    for (&c, &v) in cols.iter().zip(vals) {
        if c >= boundary {
            total += partial;
            partial = 0.0;
            boundary = (c / KC as u32 + 1) * KC as u32;
        }
        partial = v.mul_add(x[c as usize], partial);
    }
    total + partial
}

// x86 wrappers: compiling the fused chains inside a
// `target_feature(fma)` function lets `mul_add` lower to vfmadd
// instead of a per-element libm call.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn sum_sq_fma_x86(x: &[f32]) -> f32 {
    sum_sq_fma_body(x)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn sparse_sum_sq_fma_x86(cols: &[u32], vals: &[f32]) -> f32 {
    sparse_sum_sq_fma_body(cols, vals)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "fma")]
unsafe fn sparse_dot_dense_fma_x86(cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    sparse_dot_dense_fma_body(cols, vals, x)
}

// ---------------------------------------------------------------------
// AVX2 + FMA flavors (x86-64). One f32x8 register per logical lane
// group: a micro-kernel accumulator row is one register, the dot/dist2
// lane array is one register, an SpMM panel streams in 8-wide strips.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_avx2(pa: &[f32], pb: &[f32], kc: usize) -> [f32; MR * NR] {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR];
    let pa_ptr = pa.as_ptr();
    let pb_ptr = pb.as_ptr();
    for p in 0..kc {
        // one NR=8 column strip of B, reused by all MR rows
        let b = _mm256_loadu_ps(pb_ptr.add(p * NR));
        let ap = pa_ptr.add(p * MR);
        for (i, accv) in acc.iter_mut().enumerate() {
            let a = _mm256_broadcast_ss(&*ap.add(i));
            *accv = _mm256_fmadd_ps(a, b, *accv);
        }
    }
    let mut out = [0.0f32; MR * NR];
    for (i, accv) in acc.iter().enumerate() {
        _mm256_storeu_ps(out.as_mut_ptr().add(i * NR), *accv);
    }
    out
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let chunks = n / LANES;
    let mut accv = _mm256_setzero_ps();
    for c in 0..chunks {
        let xv = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
        let yv = _mm256_loadu_ps(y.as_ptr().add(c * LANES));
        accv = _mm256_fmadd_ps(xv, yv, accv);
    }
    let mut acc = [0.0f32; LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        s = x[i].mul_add(y[i], s);
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dist2_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let chunks = n / LANES;
    let mut accv = _mm256_setzero_ps();
    for c in 0..chunks {
        let xv = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
        let yv = _mm256_loadu_ps(y.as_ptr().add(c * LANES));
        let d = _mm256_sub_ps(xv, yv);
        accv = _mm256_fmadd_ps(d, d, accv);
    }
    let mut acc = [0.0f32; LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        let d = x[i] - y[i];
        s = d.mul_add(d, s);
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let av = _mm256_set1_ps(a);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(av, xv, yv));
        i += 8;
    }
    while i < n {
        y[i] = a.mul_add(x[i], y[i]);
        i += 1;
    }
}

// ---------------------------------------------------------------------
// NEON flavors (aarch64). f32x4 registers — two per 8-wide logical
// lane group, combined through the same pairwise tree.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_neon(pa: &[f32], pb: &[f32], kc: usize) -> [f32; MR * NR] {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    let pa_ptr = pa.as_ptr();
    let pb_ptr = pb.as_ptr();
    for p in 0..kc {
        let b0 = vld1q_f32(pb_ptr.add(p * NR));
        let b1 = vld1q_f32(pb_ptr.add(p * NR + 4));
        let ap = pa_ptr.add(p * MR);
        for i in 0..MR {
            let a = vdupq_n_f32(*ap.add(i));
            lo[i] = vfmaq_f32(lo[i], a, b0);
            hi[i] = vfmaq_f32(hi[i], a, b1);
        }
    }
    let mut out = [0.0f32; MR * NR];
    for i in 0..MR {
        vst1q_f32(out.as_mut_ptr().add(i * NR), lo[i]);
        vst1q_f32(out.as_mut_ptr().add(i * NR + 4), hi[i]);
    }
    out
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = x.len();
    let chunks = n / LANES;
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let base = c * LANES;
        acc0 = vfmaq_f32(acc0, vld1q_f32(x.as_ptr().add(base)), vld1q_f32(y.as_ptr().add(base)));
        acc1 = vfmaq_f32(
            acc1,
            vld1q_f32(x.as_ptr().add(base + 4)),
            vld1q_f32(y.as_ptr().add(base + 4)),
        );
    }
    let mut acc = [0.0f32; LANES];
    vst1q_f32(acc.as_mut_ptr(), acc0);
    vst1q_f32(acc.as_mut_ptr().add(4), acc1);
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        s = x[i].mul_add(y[i], s);
    }
    s
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dist2_neon(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = x.len();
    let chunks = n / LANES;
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let base = c * LANES;
        let d0 = vsubq_f32(vld1q_f32(x.as_ptr().add(base)), vld1q_f32(y.as_ptr().add(base)));
        let d1 = vsubq_f32(
            vld1q_f32(x.as_ptr().add(base + 4)),
            vld1q_f32(y.as_ptr().add(base + 4)),
        );
        acc0 = vfmaq_f32(acc0, d0, d0);
        acc1 = vfmaq_f32(acc1, d1, d1);
    }
    let mut acc = [0.0f32; LANES];
    vst1q_f32(acc.as_mut_ptr(), acc0);
    vst1q_f32(acc.as_mut_ptr().add(4), acc1);
    let mut s = combine_lanes(acc);
    for i in chunks * LANES..n {
        let d = x[i] - y[i];
        s = d.mul_add(d, s);
    }
    s
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(a: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = x.len();
    let av = vdupq_n_f32(a);
    let mut i = 0usize;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let yv = vld1q_f32(y.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vfmaq_f32(yv, av, xv));
        i += 4;
    }
    while i < n {
        y[i] = a.mul_add(x[i], y[i]);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn native() -> Backend {
        Backend::detect(false)
    }

    #[test]
    fn force_scalar_wins_over_any_cpu() {
        assert_eq!(Backend::detect(true), Backend::Scalar);
    }

    #[test]
    fn force_scalar_env_values_parse() {
        for v in ["1", "true", "yes", "on", " 1 "] {
            assert!(parse_force_scalar(v), "{v:?}");
        }
        for v in ["0", "false", "", "no", "2"] {
            assert!(!parse_force_scalar(v), "{v:?}");
        }
    }

    #[test]
    fn active_is_stable_and_named() {
        let a = active();
        assert_eq!(a, active());
        assert!(!a.name().is_empty());
        assert!(!detected_features().is_empty());
        log_once();
        log_once(); // second call must be a no-op
    }

    #[test]
    fn simd_dot_agrees_with_scalar() {
        let mut rng = Rng::new(11);
        let be = native();
        for len in [0usize, 1, 7, 8, 9, 64, 257, 1000] {
            let x: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let want = Backend::Scalar.dot(&x, &y);
            let got = be.dot(&x, &y);
            let tol = 1e-5 * (len as f32).sqrt().max(1.0);
            assert!((got - want).abs() <= tol, "len {len}: {got} vs {want}");
            assert_eq!(be.dist2(&x, &x), 0.0, "self-dist2 must be exact 0");
            let d_want = Backend::Scalar.dist2(&x, &y);
            let d_got = be.dist2(&x, &y);
            assert!((d_got - d_want).abs() <= 4.0 * tol, "len {len}: {d_got} vs {d_want}");
        }
    }

    #[test]
    fn simd_sum_sq_agrees_and_spans_chunks() {
        let mut rng = Rng::new(12);
        let be = native();
        for len in [3usize, 255, 256, 257, 700] {
            let x: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let want = Backend::Scalar.sum_sq(&x);
            let got = be.sum_sq(&x);
            assert!((got - want).abs() <= 1e-5 * want.max(1.0), "len {len}");
        }
    }

    #[test]
    fn sparse_flavors_match_dense_flavors_bitwise() {
        // within ONE backend: sparse norms/dots on a densified row must
        // reproduce the dense chain bit for bit (zero entries are
        // identity adds under both `+ a*b` and `fma`)
        let mut rng = Rng::new(13);
        for be in [Backend::Scalar, native()] {
            for cols in [5usize, 256, 300, 700] {
                let dense: Vec<f32> = (0..cols)
                    .map(|_| if rng.bernoulli(0.3) { rng.gaussian_f32() } else { 0.0 })
                    .collect();
                let (ci, vs): (Vec<u32>, Vec<f32>) = dense
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| (c as u32, v))
                    .unzip();
                let want = be.sum_sq(&dense);
                assert_eq!(
                    be.sparse_sum_sq(&ci, &vs).to_bits(),
                    want.to_bits(),
                    "{} cols={cols}",
                    be.name()
                );
                assert_eq!(
                    be.sparse_dot_dense(&ci, &vs, &dense).to_bits(),
                    want.to_bits(),
                    "{} cols={cols}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn simd_microkernel_agrees_with_scalar() {
        let mut rng = Rng::new(14);
        let be = native();
        for kc in [1usize, 3, 17, 256] {
            let pa: Vec<f32> = (0..kc * MR).map(|_| rng.gaussian_f32()).collect();
            let pb: Vec<f32> = (0..kc * NR).map(|_| rng.gaussian_f32()).collect();
            let want = Backend::Scalar.microkernel_8x8(&pa, &pb, kc);
            let got = be.microkernel_8x8(&pa, &pb, kc);
            let tol = 1e-5 * (kc as f32).sqrt().max(1.0);
            for (e, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!((w - g).abs() <= tol, "kc={kc} elem {e}: {w} vs {g}");
            }
        }
    }

    #[test]
    fn simd_axpy_agrees_with_scalar() {
        let mut rng = Rng::new(15);
        let be = native();
        for len in [0usize, 1, 3, 8, 9, 31, 256] {
            let x: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let mut ys: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
            let mut yv = ys.clone();
            Backend::Scalar.axpy(0.37, &x, &mut ys);
            be.axpy(0.37, &x, &mut yv);
            for (a, b) in ys.iter().zip(&yv) {
                assert!((a - b).abs() <= 1e-6, "len {len}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn microkernel_diagonal_matches_sum_sq_per_backend() {
        // the RBF exact-diagonal contract at the primitive level: pack x
        // on both sides, the (i,i) element must equal this backend's
        // sum_sq of x, bit for bit (kc <= KC here; the cross-slab case
        // is covered by the gemm-level tests)
        let mut rng = Rng::new(16);
        for be in [Backend::Scalar, native()] {
            for kc in [1usize, 7, 64, 256] {
                let x: Vec<f32> = (0..kc).map(|_| rng.gaussian_f32()).collect();
                // depth-major panels holding x in row/col 0
                let mut pa = vec![0.0f32; kc * MR];
                let mut pb = vec![0.0f32; kc * NR];
                for p in 0..kc {
                    pa[p * MR] = x[p];
                    pb[p * NR] = x[p];
                }
                let acc = be.microkernel_8x8(&pa, &pb, kc);
                assert_eq!(
                    acc[0].to_bits(),
                    be.sum_sq(&x).to_bits(),
                    "{} kc={kc}",
                    be.name()
                );
            }
        }
    }
}
