//! Low-rank PSD factorizations: K ≈ G Gᵀ with G ∈ ℝ^{n×r}, r ≪ n.
//!
//! Two constructions back the `LowRank` kernel operator
//! (`kernel::operator`):
//!
//! * **Pivoted incomplete Cholesky (ICF)** — the PSVM construction: at
//!   step k pick the row with the largest residual diagonal, append the
//!   corresponding (projected, scaled) kernel column to G, and stop when
//!   the residual trace falls below `tol` × the initial trace or the
//!   rank budget is spent. Approximation error is exactly the residual
//!   trace: `trace(K - G Gᵀ) = Σ_i d_i ≥ 0`.
//! * **Nyström landmarks** — G = C · L⁻ᵀ for C = K[:, L], W = K[L, L]
//!   = L Lᵀ, so G Gᵀ = C W⁻¹ Cᵀ. W is regularized through the shared
//!   escalating-ridge policy ([`chol::factor_ridge`]).
//!
//! Both are data-agnostic: kernel entries arrive through caller-supplied
//! inputs (the operator layer owns dataset plumbing), keeping `linalg`
//! free of data-layer dependencies. Both honor the substrate determinism
//! contract (DESIGN.md §LOWRANK): pivots are chosen by a sequential
//! first-max scan, and every parallel loop partitions elements without
//! changing any element's accumulation order, so factors are
//! bit-identical across thread counts.

use super::{chol, Matrix};
use crate::pool;

/// A rank-`r` factor of an n × n PSD matrix.
#[derive(Debug, Clone)]
pub struct LowRankFactor {
    /// n × r row-major factor; `r` is the rank actually built (ICF may
    /// stop early on the trace test).
    pub g: Matrix,
    /// Residual diagonal trace at stop, as a fraction of the initial
    /// trace — the relative approximation error in the trace norm.
    pub residual_frac: f64,
    /// ICF pivot rows / Nyström landmark rows, in selection order.
    pub pivots: Vec<usize>,
}

impl LowRankFactor {
    pub fn rank(&self) -> usize {
        self.g.cols
    }
}

/// out[i] = Σ_j w[j] · cols[j][i]. The j-loop is innermost and always
/// ascending, so each element's accumulation order is fixed no matter
/// how the i-range is partitioned across threads.
fn project(threads: usize, cols: &[Vec<f32>], w: &[f32], out: &mut [f32]) {
    if cols.is_empty() {
        out.fill(0.0);
        return;
    }
    const CHUNK: usize = 2048;
    pool::parallel_chunks_mut(threads, out, CHUNK, |c, slice| {
        let base = c * CHUNK;
        slice.fill(0.0);
        for (col, &wj) in cols.iter().zip(w) {
            let src = &col[base..base + slice.len()];
            for (o, &s) in slice.iter_mut().zip(src) {
                *o += wj * s;
            }
        }
    });
}

/// Pivoted incomplete Cholesky with diagonal-trace stopping.
///
/// `diag` holds the exact diagonal K_ii; `column(p, buf)` must fill
/// `buf` with kernel column p (length n, deterministically). Builds at
/// most `rank` columns, stopping early once the residual trace drops to
/// `tol` × the initial trace.
pub fn icf(
    threads: usize,
    diag: &[f32],
    rank: usize,
    tol: f64,
    mut column: impl FnMut(usize, &mut [f32]),
) -> LowRankFactor {
    let _sp = crate::trace::span("operator/icf");
    let n = diag.len();
    let rank = rank.min(n).max(1);
    let mut d: Vec<f64> = diag.iter().map(|&v| v as f64).collect();
    let trace0: f64 = d.iter().sum::<f64>();
    let trace0 = trace0.max(f64::MIN_POSITIVE);
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(rank);
    let mut pivots: Vec<usize> = Vec::with_capacity(rank);
    let mut kcol = vec![0.0f32; n];
    let mut proj = vec![0.0f32; n];
    for _ in 0..rank {
        // deterministic pivot: first index attaining the max residual
        let mut p = 0;
        for i in 1..n {
            if d[i] > d[p] {
                p = i;
            }
        }
        let dp = d[p];
        if dp <= tol * trace0 {
            break;
        }
        column(p, &mut kcol);
        let w: Vec<f32> = cols.iter().map(|c| c[p]).collect();
        project(threads, &cols, &w, &mut proj);
        let root = dp.sqrt();
        let inv = (1.0 / root) as f32;
        let mut g = vec![0.0f32; n];
        for i in 0..n {
            g[i] = (kcol[i] - proj[i]) * inv;
        }
        g[p] = root as f32;
        for i in 0..n {
            d[i] -= g[i] as f64 * g[i] as f64;
        }
        d[p] = 0.0;
        pivots.push(p);
        cols.push(g);
    }
    // pack the column list into the row-major n × r factor
    let r = cols.len();
    let mut gm = Matrix::zeros(n, r);
    for (j, c) in cols.iter().enumerate() {
        for i in 0..n {
            gm.data[i * r + j] = c[i];
        }
    }
    let resid: f64 = d.iter().map(|v| v.max(0.0)).sum();
    LowRankFactor { g: gm, residual_frac: resid / trace0, pivots }
}

/// Nyström factor from the landmark cross block C = K[:, L] (n × m) and
/// landmark Gram W = K[L, L] (m × m). Rows of G solve independently
/// (sequential forward substitution per row, f64 accumulation like
/// [`chol::solve_with_factor`]), so the factor is bit-identical across
/// thread counts. `diag` (exact K_ii) is only used to report the
/// residual trace fraction.
pub fn nystrom(
    threads: usize,
    diag: &[f32],
    c: &Matrix,
    w: &Matrix,
    jitter: f32,
    pivots: Vec<usize>,
) -> Result<LowRankFactor, chol::CholError> {
    let _sp = crate::trace::span("operator/nystrom");
    let n = c.rows;
    let m = c.cols;
    assert_eq!(w.rows, m);
    assert_eq!(w.cols, m);
    assert_eq!(diag.len(), n);
    let (l, _reg) = chol::factor_ridge(w, jitter, 8)?;
    let mut g = Matrix::zeros(n, m);
    let lref = &l;
    pool::parallel_chunks_mut(threads, &mut g.data, m, |i, row| {
        let crow = c.row(i);
        let mut y = vec![0.0f64; m];
        for a in 0..m {
            let mut v = crow[a] as f64;
            for k in 0..a {
                v -= lref.at(a, k) as f64 * y[k];
            }
            y[a] = v / lref.at(a, a) as f64;
        }
        for (dst, v) in row.iter_mut().zip(&y) {
            *dst = *v as f32;
        }
    });
    let trace0: f64 = diag.iter().map(|&v| v as f64).sum();
    let trace0 = trace0.max(f64::MIN_POSITIVE);
    let mut resid = 0.0f64;
    for i in 0..n {
        let row = g.row(i);
        let mut s = 0.0f64;
        for &v in row {
            s += v as f64 * v as f64;
        }
        resid += (diag[i] as f64 - s).max(0.0);
    }
    Ok(LowRankFactor { g, residual_frac: resid / trace0, pivots })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm_nt;
    use crate::rng::Rng;

    /// Random PSD matrix B Bᵀ with a mild diagonal boost.
    fn psd(rng: &mut Rng, n: usize, inner: usize) -> Matrix {
        let b = Matrix::from_vec(
            n,
            inner,
            (0..n * inner).map(|_| rng.gaussian_f32()).collect(),
        );
        let mut a = Matrix::zeros(n, n);
        gemm_nt(1, &b, &b, &mut a);
        for i in 0..n {
            a.set(i, i, a.at(i, i) + 0.1);
        }
        a
    }

    fn reconstruction_err(a: &Matrix, g: &Matrix) -> f32 {
        let n = a.rows;
        let mut worst = 0.0f32;
        for i in 0..n {
            for j in 0..n {
                let mut e = 0.0f64;
                for k in 0..g.cols {
                    e += g.at(i, k) as f64 * g.at(j, k) as f64;
                }
                worst = worst.max((a.at(i, j) - e as f32).abs());
            }
        }
        worst
    }

    fn diag_of(a: &Matrix) -> Vec<f32> {
        (0..a.rows).map(|i| a.at(i, i)).collect()
    }

    fn col_closure(a: &Matrix) -> impl FnMut(usize, &mut [f32]) + '_ {
        move |p: usize, buf: &mut [f32]| {
            for i in 0..a.rows {
                buf[i] = a.at(i, p);
            }
        }
    }

    #[test]
    fn icf_full_rank_reconstructs() {
        let mut rng = Rng::new(21);
        let a = psd(&mut rng, 24, 24);
        let f = icf(1, &diag_of(&a), 24, 0.0, col_closure(&a));
        assert!(
            reconstruction_err(&a, &f.g) < 1e-3,
            "err {}",
            reconstruction_err(&a, &f.g)
        );
        assert!(f.residual_frac < 1e-6);
    }

    #[test]
    fn icf_truncates_on_trace_and_improves_with_rank() {
        let mut rng = Rng::new(22);
        // numerically rank-8 matrix: ICF should stop well short of n
        let a = psd(&mut rng, 40, 8);
        let f = icf(1, &diag_of(&a), 40, 1e-8, col_closure(&a));
        assert!(f.rank() < 40, "rank {}", f.rank());
        let f4 = icf(1, &diag_of(&a), 4, 0.0, col_closure(&a));
        let f8 = icf(1, &diag_of(&a), 8, 0.0, col_closure(&a));
        assert!(f8.residual_frac <= f4.residual_frac + 1e-12);
    }

    #[test]
    fn icf_bits_stable_across_threads() {
        let mut rng = Rng::new(23);
        let a = psd(&mut rng, 64, 16);
        let d = diag_of(&a);
        let f1 = icf(1, &d, 16, 0.0, col_closure(&a));
        let f8 = icf(8, &d, 16, 0.0, col_closure(&a));
        assert_eq!(f1.pivots, f8.pivots);
        assert_eq!(f1.g.data, f8.g.data);
    }

    #[test]
    fn nystrom_all_landmarks_reconstructs() {
        let mut rng = Rng::new(24);
        let a = psd(&mut rng, 20, 20);
        let pivots: Vec<usize> = (0..20).collect();
        let w = a.clone();
        let f = nystrom(1, &diag_of(&a), &a, &w, 0.0, pivots).unwrap();
        assert!(
            reconstruction_err(&a, &f.g) < 1e-2,
            "err {}",
            reconstruction_err(&a, &f.g)
        );
    }

    #[test]
    fn nystrom_bits_stable_across_threads() {
        let mut rng = Rng::new(25);
        let a = psd(&mut rng, 48, 12);
        let d = diag_of(&a);
        let lm: Vec<usize> = (0..12).map(|j| j * 4).collect();
        let mut c = Matrix::zeros(48, 12);
        let mut w = Matrix::zeros(12, 12);
        for i in 0..48 {
            for (jj, &j) in lm.iter().enumerate() {
                c.set(i, jj, a.at(i, j));
            }
        }
        for (ii, &i) in lm.iter().enumerate() {
            for (jj, &j) in lm.iter().enumerate() {
                w.set(ii, jj, a.at(i, j));
            }
        }
        let f1 = nystrom(1, &d, &c, &w, 1e-6, lm.clone()).unwrap();
        let f8 = nystrom(8, &d, &c, &w, 1e-6, lm).unwrap();
        assert_eq!(f1.g.data, f8.g.data);
    }
}
