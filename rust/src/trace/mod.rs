//! Unified tracing: scoped spans, phase laps, and runtime counters.
//!
//! The paper's explicit-vs-implicit argument is an argument about
//! *where wall-time goes* (WSS scans vs gradient updates vs big GEMMs),
//! so every layer of this crate reports into one process-wide trace:
//! solvers emit phase laps ([`phases`]), operators/serve emit RAII
//! spans ([`span`]), and the pool/cache/GEMM/SpMM feed the relaxed
//! counter registry ([`counters`]). A [`Session`] brackets one traced
//! workload and drains everything into a [`TraceReport`] — the human
//! `--profile` table, the Chrome-trace `--trace-json` export
//! ([`chrome`]), and the `counters` section of BENCH_*.json records all
//! render from it.
//!
//! Contracts (property-tested in `rust/tests/trace_props.rs`):
//!
//! * **Disabled = one branch.** Every instrumentation site guards on
//!   [`enabled`] — a single relaxed `AtomicBool` load. No session, no
//!   atomics, no clock reads, no allocation.
//! * **Observation doesn't perturb.** Recording only appends to
//!   per-thread buffers and bumps counters; no traced code path makes a
//!   different decision because tracing is on. Traced runs are
//!   bit-identical to untraced runs.
//! * **Sessions serialize.** The registries are process-global, so
//!   [`Session::start`] holds a process-wide lock until `finish()`;
//!   concurrent would-be sessions queue instead of mixing events.
//!   `WU_SVM_TRACE=0` is the kill switch: sessions become inert and
//!   the process stays on the disabled path.

pub mod chrome;
pub mod counters;
pub mod report;

pub use counters::{count, Counter, COUNTER_NAMES, NUM_COUNTERS};
pub use report::{PhaseRow, Span, ThreadTrace, TraceReport};

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// The one global switch every instrumentation site branches on.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is a trace session recording right now?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Per-thread buffers stop growing past this many events; overflow is
/// tallied in [`Counter::EventsDropped`] instead of reallocating forever.
const MAX_EVENTS_PER_THREAD: usize = 1 << 20;

/// One raw begin/end record. Per-thread *push order* is always balanced
/// (span guards push B before E, laps push adjacent B/E pairs), which is
/// what [`report`] pairs on — timestamps only order the nesting forest.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub name: &'static str,
    pub begin: bool,
    pub ts_ns: u64,
}

/// A thread's event buffer. Only the owning thread locks it on the hot
/// path (uncontended); the session drains it at start/finish.
struct ThreadBuf {
    tid: u32,
    events: Mutex<Vec<Event>>,
}

static NEXT_TID: AtomicU32 = AtomicU32::new(0);
static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: Arc<ThreadBuf> = register_thread();
}

fn register_thread() -> Arc<ThreadBuf> {
    let buf = Arc::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        events: Mutex::new(Vec::new()),
    });
    REGISTRY
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .push(buf.clone());
    buf
}

/// Monotonic nanoseconds since the process's first trace timestamp.
fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

#[inline]
fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Append one event to this thread's buffer (tracing already checked).
fn push(ev: Event) {
    LOCAL.with(|buf| {
        let mut events = buf.events.lock().unwrap_or_else(|p| p.into_inner());
        if events.len() >= MAX_EVENTS_PER_THREAD {
            counters::count(Counter::EventsDropped, 1);
            return;
        }
        events.push(ev);
    });
}

/// Append a retroactive begin/end pair in one lock acquisition, so the
/// pair stays adjacent in push order.
fn push_pair(name: &'static str, t0_ns: u64, t1_ns: u64) {
    LOCAL.with(|buf| {
        let mut events = buf.events.lock().unwrap_or_else(|p| p.into_inner());
        if events.len() + 2 > MAX_EVENTS_PER_THREAD {
            counters::count(Counter::EventsDropped, 2);
            return;
        }
        events.push(Event { name, begin: true, ts_ns: t0_ns });
        events.push(Event { name, begin: false, ts_ns: t1_ns });
    });
}

/// Open a named RAII span on the current thread; the span closes when
/// the guard drops. Free when tracing is off.
#[must_use = "the span ends when the guard drops"]
pub fn span(name: &'static str) -> SpanGuard {
    let armed = enabled();
    if armed {
        push(Event { name, begin: true, ts_ns: now_ns() });
    }
    SpanGuard { name, armed }
}

/// Guard returned by [`span`]. Records the matching end event on drop.
pub struct SpanGuard {
    name: &'static str,
    armed: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // both checks: never emit an E without its B (armed), and never
        // write into a buffer after the session disabled recording
        if self.armed && enabled() {
            push(Event { name: self.name, begin: false, ts_ns: now_ns() });
        }
    }
}

/// Sequential phase timing, drop-in for the old `Stopwatch::lap` style:
/// each [`PhaseGuard::lap`] closes the interval since the previous
/// boundary under the given name (retroactive begin/end pair).
pub fn phases() -> PhaseGuard {
    PhaseGuard { last_ns: if enabled() { now_ns() } else { 0 } }
}

/// Guard returned by [`phases`].
pub struct PhaseGuard {
    last_ns: u64,
}

impl PhaseGuard {
    /// Close the phase that just ran as `name`; the next phase starts now.
    #[inline]
    pub fn lap(&mut self, name: &'static str) {
        if enabled() {
            let now = now_ns();
            push_pair(name, self.last_ns.min(now), now);
            self.last_ns = now;
        }
    }
}

/// Process-wide serialization of sessions (the buffers and counters are
/// global). Held from [`Session::start`] until `finish()`/drop.
static SESSION_LOCK: Mutex<()> = Mutex::new(());

/// One traced workload: `start()` → run the code under test →
/// `finish()` → [`TraceReport`]. Inert (records nothing, holds no lock)
/// when `WU_SVM_TRACE=0`.
pub struct Session {
    active: bool,
    started: Option<Instant>,
    _guard: Option<MutexGuard<'static, ()>>,
}

impl Session {
    /// Begin recording: zero the counters, clear every thread buffer,
    /// flip the global switch. Blocks until any other session finishes.
    pub fn start() -> Session {
        let killed = std::env::var("WU_SVM_TRACE").map(|v| v == "0").unwrap_or(false);
        if killed {
            return Session { active: false, started: None, _guard: None };
        }
        let guard = SESSION_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        counters::reset();
        for buf in REGISTRY.lock().unwrap_or_else(|p| p.into_inner()).iter() {
            buf.events.lock().unwrap_or_else(|p| p.into_inner()).clear();
        }
        ENABLED.store(true, Ordering::SeqCst);
        Session { active: true, started: Some(Instant::now()), _guard: Some(guard) }
    }

    /// Did this session actually record (false under `WU_SVM_TRACE=0`)?
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Stop recording and drain everything into a [`TraceReport`].
    pub fn finish(mut self) -> TraceReport {
        if !self.active {
            return TraceReport::empty();
        }
        ENABLED.store(false, Ordering::SeqCst);
        self.active = false;
        let wall = self.started.take().map(|t| t.elapsed()).unwrap_or_default();
        let counters = counters::snapshot();
        let mut raw: Vec<(u32, Vec<Event>)> = Vec::new();
        for buf in REGISTRY.lock().unwrap_or_else(|p| p.into_inner()).iter() {
            let mut events = buf.events.lock().unwrap_or_else(|p| p.into_inner());
            if !events.is_empty() {
                raw.push((buf.tid, std::mem::take(&mut *events)));
            }
        }
        raw.sort_by_key(|(tid, _)| *tid);
        TraceReport::build(wall, counters, raw)
        // the session lock releases when `_guard` drops here
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // safety net: a session abandoned without finish() (e.g. a panic
        // in the traced workload) must not leave recording enabled
        if self.active {
            ENABLED.store(false, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sessions serialize on SESSION_LOCK, so these tests are safe under
    // the parallel test harness; the kill-switch test lives in
    // rust/tests/trace_props.rs (env vars are process-global).

    #[test]
    fn disabled_records_nothing() {
        // hold the session lock so no concurrently running test can have
        // tracing enabled while this one asserts the disabled path
        let _bar = SESSION_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!enabled());
        {
            let _s = span("never");
            let mut ph = phases();
            ph.lap("never");
        }
        let snapshot = LOCAL.with(|b| b.events.lock().unwrap().len());
        assert_eq!(snapshot, 0);
    }

    #[test]
    fn session_captures_spans_and_laps() {
        let session = Session::start();
        if !session.is_active() {
            return; // WU_SVM_TRACE=0 in the environment
        }
        {
            let _root = span("root");
            let _inner = span("inner");
        }
        let mut ph = phases();
        std::hint::black_box(0u64);
        ph.lap("phase-a");
        count(Counter::CacheHits, 3);
        let report = session.finish();
        assert!(!enabled());
        assert_eq!(report.counter(Counter::CacheHits), 3);
        let names: Vec<&str> = report.phase_rows().iter().map(|r| r.name).collect();
        assert!(names.contains(&"root"), "{names:?}");
        assert!(names.contains(&"phase-a"), "{names:?}");
        // `inner` nests under `root` in the forest
        let this_thread: Vec<&ThreadTrace> = report
            .threads
            .iter()
            .filter(|t| t.roots.iter().any(|s| s.name == "root"))
            .collect();
        assert_eq!(this_thread.len(), 1);
        let root = this_thread[0].roots.iter().find(|s| s.name == "root").unwrap();
        assert_eq!(root.children.len(), 1);
        assert_eq!(root.children[0].name, "inner");
        assert!(root.t0_ns <= root.children[0].t0_ns);
        assert!(root.children[0].t1_ns <= root.t1_ns);
    }

    #[test]
    fn sessions_reset_counters_and_buffers() {
        let s1 = Session::start();
        if !s1.is_active() {
            return;
        }
        count(Counter::PoolJobs, 7);
        let _ = span("left-over");
        let r1 = s1.finish();
        assert_eq!(r1.counter(Counter::PoolJobs), 7);
        let s2 = Session::start();
        let r2 = s2.finish();
        assert_eq!(r2.counter(Counter::PoolJobs), 0);
        assert!(r2.threads.is_empty(), "second session must start clean");
    }
}
