//! Process-wide runtime counters: one relaxed atomic per [`Counter`].
//!
//! Counting is gated on [`crate::trace::enabled`], so a disabled process
//! pays one predictable branch per site and no atomic traffic. Relaxed
//! ordering is deliberate: each counter is an independent monotone tally
//! (no cross-counter ordering is ever read back mid-run), and a
//! [`crate::trace::Session`] reads them only after `finish()` has
//! disabled recording and every worker has left the traced region — the
//! session's own synchronization (pool joins, the drained buffers)
//! orders the final loads after all increments. Within a traced run the
//! *deterministic* counters (cache, kernel rows, flop/byte tallies) are
//! exact and thread-count invariant; the pool counters describe
//! scheduling and legitimately vary with the worker count.

use std::sync::atomic::{AtomicU64, Ordering};

/// Everything the runtime tallies. Discriminants index [`COUNTERS`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// `SharedRowCache` lookups (hits + misses, cross-checked in CI).
    CacheLookups = 0,
    /// Lookups served from a cached row.
    CacheHits = 1,
    /// Lookups that had to compute the row.
    CacheMisses = 2,
    /// Bytes evicted to stay inside the cache byte budget.
    CacheEvictedBytes = 3,
    /// Kernel rows computed by the explicit solvers (cache misses that
    /// reached the row builder, including batch fills).
    KernelRowsComputed = 4,
    /// Jobs submitted to the worker pool.
    PoolJobs = 5,
    /// Times an idle pool worker joined a running job as a helper.
    PoolHelperJoins = 6,
    /// Floating-point operations issued through the blocked GEMM/GEMV
    /// entry points (2·m·n·k per call).
    GemmFlops = 7,
    /// Bytes the GEMM/GEMV entry points logically touch (A + B + C).
    GemmBytes = 8,
    /// Floating-point operations through the CSR SpMM (2·b per stored
    /// nonzero).
    SpmmFlops = 9,
    /// Bytes the SpMM logically touches (CSR range + packed B + C).
    SpmmBytes = 10,
    /// Engine degradations: an implicit solver or the serve path fell
    /// back from the requested engine to the cpu route.
    EngineFallbacks = 11,
    /// Trace events discarded because a thread buffer hit its cap.
    EventsDropped = 12,
    /// Shard sub-problems trained by the cascade driver (all layers,
    /// including warm-started merge retrains).
    CascadeShardsTrained = 13,
    /// Support vectors surviving cascade merge steps (after the
    /// cross-shard shrinking filter).
    CascadeSvsMerged = 14,
    /// KKT violations found by the cascade's global sweeps and fed back
    /// into the next outer round.
    CascadeKktViolations = 15,
    /// Cache-aware WSS picks (`--cache-slack`): times a near-equal,
    /// already-cached candidate was preferred over the argmax violator.
    CachePreferredPicks = 16,
    /// SMO/WSS pair updates taken inside the polishing phase
    /// (`--polish`).
    PolishSteps = 17,
}

/// Number of [`Counter`] variants.
pub const NUM_COUNTERS: usize = 18;

/// Snapshot/report key for each counter, by discriminant.
pub const COUNTER_NAMES: [&str; NUM_COUNTERS] = [
    "cache_lookups",
    "cache_hits",
    "cache_misses",
    "cache_evicted_bytes",
    "kernel_rows_computed",
    "pool_jobs",
    "pool_helper_joins",
    "gemm_flops",
    "gemm_bytes",
    "spmm_flops",
    "spmm_bytes",
    "engine_fallbacks",
    "events_dropped",
    "cascade_shards_trained",
    "cascade_svs_merged",
    "cascade_kkt_violations",
    "cache_preferred_picks",
    "polish_steps",
];

// `static [AtomicU64; N]` needs a const repeat seed; the interior
// mutability is the point (same idiom as serve/metrics.rs).
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; NUM_COUNTERS] = [ZERO; NUM_COUNTERS];

/// Add `n` to `c` if tracing is enabled. The disabled path is a single
/// relaxed load + branch — cheap enough for GEMM-entry call sites.
#[inline]
pub fn count(c: Counter, n: u64) {
    if crate::trace::enabled() {
        COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Current value of one counter (test/report helper).
pub fn value(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Zero every counter (session start).
pub(crate) fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
}

/// Read every counter, by discriminant (session finish).
pub(crate) fn snapshot() -> [u64; NUM_COUNTERS] {
    std::array::from_fn(|i| COUNTERS[i].load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_cover_every_variant() {
        assert_eq!(COUNTER_NAMES.len(), NUM_COUNTERS);
        // discriminants must be dense and in name order
        for (i, c) in [
            Counter::CacheLookups,
            Counter::CacheHits,
            Counter::CacheMisses,
            Counter::CacheEvictedBytes,
            Counter::KernelRowsComputed,
            Counter::PoolJobs,
            Counter::PoolHelperJoins,
            Counter::GemmFlops,
            Counter::GemmBytes,
            Counter::SpmmFlops,
            Counter::SpmmBytes,
            Counter::EngineFallbacks,
            Counter::EventsDropped,
            Counter::CascadeShardsTrained,
            Counter::CascadeSvsMerged,
            Counter::CascadeKktViolations,
            Counter::CachePreferredPicks,
            Counter::PolishSteps,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(c as usize, i);
        }
    }

    #[test]
    fn disabled_count_is_a_no_op() {
        // unit tests never hold a Session here, so tracing is off and
        // count() must not touch the atomics
        let before = value(Counter::GemmFlops);
        count(Counter::GemmFlops, 1_000);
        assert_eq!(value(Counter::GemmFlops), before);
    }
}
