//! Drained trace data: the span forest per thread, the counter
//! snapshot, and the renderers (`--profile` table, counters JSON).

use std::time::Duration;

use super::counters::{Counter, COUNTER_NAMES, NUM_COUNTERS};
use super::Event;

/// One completed span, nested by time containment.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    pub t0_ns: u64,
    pub t1_ns: u64,
    pub children: Vec<Span>,
}

impl Span {
    pub fn duration_ns(&self) -> u64 {
        self.t1_ns.saturating_sub(self.t0_ns)
    }

    /// Duration minus the time spent inside child spans.
    pub fn self_ns(&self) -> u64 {
        let inner: u64 = self.children.iter().map(Span::duration_ns).sum();
        self.duration_ns().saturating_sub(inner)
    }
}

/// Every root span recorded by one thread.
#[derive(Debug, Clone)]
pub struct ThreadTrace {
    pub tid: u32,
    pub roots: Vec<Span>,
}

/// Aggregated wall time for one span name across the whole trace.
#[derive(Debug, Clone)]
pub struct PhaseRow {
    pub name: &'static str,
    /// Summed span durations (children included).
    pub total_ns: u64,
    /// Summed self time (children excluded) — what the phase itself cost.
    pub self_ns: u64,
    pub count: u64,
}

/// Everything a finished [`super::Session`] observed.
#[derive(Debug, Clone)]
pub struct TraceReport {
    /// Wall clock of the session, start() to finish().
    pub wall: Duration,
    counters: [u64; NUM_COUNTERS],
    pub threads: Vec<ThreadTrace>,
}

impl TraceReport {
    pub(super) fn empty() -> TraceReport {
        TraceReport { wall: Duration::ZERO, counters: [0; NUM_COUNTERS], threads: Vec::new() }
    }

    /// Pair each thread's raw events and nest them into a forest.
    pub(super) fn build(
        wall: Duration,
        counters: [u64; NUM_COUNTERS],
        raw: Vec<(u32, Vec<Event>)>,
    ) -> TraceReport {
        let threads = raw
            .into_iter()
            .map(|(tid, events)| ThreadTrace { tid, roots: nest(pair(&events)) })
            .collect();
        TraceReport { wall, counters, threads }
    }

    /// Final value of one runtime counter.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// The raw counter snapshot, indexed like [`COUNTER_NAMES`].
    pub fn counters(&self) -> &[u64; NUM_COUNTERS] {
        &self.counters
    }

    /// Cache hit rate over the session, if any lookups happened.
    pub fn cache_hit_rate(&self) -> Option<f64> {
        let lookups = self.counter(Counter::CacheLookups);
        (lookups > 0).then(|| self.counter(Counter::CacheHits) as f64 / lookups as f64)
    }

    /// Effective GFLOP/s over the session wall (GEMM + SpMM tallies).
    pub fn gflops(&self) -> f64 {
        let flops = self.counter(Counter::GemmFlops) + self.counter(Counter::SpmmFlops);
        flops as f64 / self.wall.as_secs_f64().max(1e-12) / 1e9
    }

    /// Per-name aggregation over every span in the trace, widest self
    /// time first.
    pub fn phase_rows(&self) -> Vec<PhaseRow> {
        let mut rows: Vec<PhaseRow> = Vec::new();
        fn walk(spans: &[Span], rows: &mut Vec<PhaseRow>) {
            for s in spans {
                match rows.iter_mut().find(|r| r.name == s.name) {
                    Some(r) => {
                        r.total_ns += s.duration_ns();
                        r.self_ns += s.self_ns();
                        r.count += 1;
                    }
                    None => rows.push(PhaseRow {
                        name: s.name,
                        total_ns: s.duration_ns(),
                        self_ns: s.self_ns(),
                        count: 1,
                    }),
                }
                walk(&s.children, rows);
            }
        }
        for t in &self.threads {
            walk(&t.roots, &mut rows);
        }
        rows.sort_by(|a, b| b.self_ns.cmp(&a.self_ns).then(a.name.cmp(b.name)));
        rows
    }

    /// Fraction of the session wall covered by root spans (max over
    /// threads — the primary thread's top-level phases should tile the
    /// traced workload).
    pub fn coverage(&self) -> f64 {
        let wall_ns = self.wall.as_nanos().max(1) as u64;
        self.threads
            .iter()
            .map(|t| {
                let ns: u64 = t.roots.iter().map(Span::duration_ns).sum();
                ns as f64 / wall_ns as f64
            })
            .fold(0.0, f64::max)
            .min(1.0)
    }

    /// The human `--profile` table: per-phase wall breakdown, then the
    /// counter digest (cache hit rate, flop throughput, pool activity).
    pub fn render_profile(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let wall_s = self.wall.as_secs_f64();
        let _ = writeln!(
            out,
            "-- profile: wall {:.3}s, {} thread(s) recorded, coverage {:.0}%",
            wall_s,
            self.threads.len(),
            self.coverage() * 100.0
        );
        let rows = self.phase_rows();
        if rows.is_empty() {
            let _ = writeln!(out, "   (no spans recorded)");
        } else {
            let _ = writeln!(
                out,
                "   {:<26} {:>10} {:>10} {:>7} {:>7}",
                "phase", "self", "total", "self%", "calls"
            );
            let wall_ns = self.wall.as_nanos().max(1) as f64;
            for r in &rows {
                let _ = writeln!(
                    out,
                    "   {:<26} {:>10} {:>10} {:>6.1}% {:>7}",
                    r.name,
                    fmt_ns(r.self_ns),
                    fmt_ns(r.total_ns),
                    r.self_ns as f64 / wall_ns * 100.0,
                    r.count
                );
            }
        }
        match self.cache_hit_rate() {
            Some(rate) => {
                let _ = writeln!(
                    out,
                    "   cache: {:.1}% hit rate ({} lookups, {} rows computed, {} evicted)",
                    rate * 100.0,
                    self.counter(Counter::CacheLookups),
                    self.counter(Counter::KernelRowsComputed),
                    fmt_bytes(self.counter(Counter::CacheEvictedBytes)),
                );
            }
            None => {
                let _ = writeln!(out, "   cache: no lookups (implicit path or no shared cache)");
            }
        }
        let _ = writeln!(
            out,
            "   compute: {:.2} GFLOP/s effective ({} gemm + {} spmm flops, {} backend)",
            self.gflops(),
            self.counter(Counter::GemmFlops),
            self.counter(Counter::SpmmFlops),
            crate::linalg::simd::active().name(),
        );
        let _ = writeln!(
            out,
            "   pool: {} jobs, {} helper joins; engine fallbacks: {}; events dropped: {}",
            self.counter(Counter::PoolJobs),
            self.counter(Counter::PoolHelperJoins),
            self.counter(Counter::EngineFallbacks),
            self.counter(Counter::EventsDropped),
        );
        out
    }

    /// The `counters` object embedded in BENCH_*.json records (validated
    /// by `ci/check_bench_json.py`: hits + misses must equal lookups).
    pub fn counters_json(&self) -> String {
        let fields: Vec<String> = COUNTER_NAMES
            .iter()
            .zip(self.counters.iter())
            .map(|(name, v)| format!("\"{name}\": {v}"))
            .collect();
        format!("{{{}}}", fields.join(", "))
    }
}

/// Pair raw events into flat `(name, t0, t1)` spans by *push order*: a
/// begin opens, the next end closes the innermost open span. Push order
/// is balanced by construction (guards, adjacent lap pairs); leftovers
/// from a workload that outlived the session are closed at the last
/// timestamp seen so the report stays well-formed.
fn pair(events: &[Event]) -> Vec<Span> {
    let mut open: Vec<(&'static str, u64)> = Vec::new();
    let mut spans: Vec<Span> = Vec::new();
    let mut last_ts = 0u64;
    for ev in events {
        last_ts = last_ts.max(ev.ts_ns);
        if ev.begin {
            open.push((ev.name, ev.ts_ns));
        } else if let Some((name, t0)) = open.pop() {
            spans.push(Span { name, t0_ns: t0, t1_ns: ev.ts_ns.max(t0), children: Vec::new() });
        }
        // an end without a begin means the begin was dropped at the
        // buffer cap — skip it rather than inventing a span
    }
    for (name, t0) in open.into_iter().rev() {
        spans.push(Span { name, t0_ns: t0, t1_ns: last_ts.max(t0), children: Vec::new() });
    }
    spans
}

/// Nest flat spans into a containment forest. Sorting by (start asc,
/// end desc) visits every parent before its children, so a simple stack
/// walk rebuilds the hierarchy; Chrome B/E export then emits it
/// depth-first with non-decreasing timestamps.
fn nest(mut flat: Vec<Span>) -> Vec<Span> {
    flat.sort_by(|a, b| a.t0_ns.cmp(&b.t0_ns).then(b.t1_ns.cmp(&a.t1_ns)));
    let mut roots: Vec<Span> = Vec::new();
    // stack of open ancestors; the top owns whatever comes next inside it
    let mut stack: Vec<Span> = Vec::new();
    for mut s in flat {
        while let Some(top) = stack.last() {
            if s.t0_ns >= top.t1_ns {
                let done = stack.pop().unwrap();
                attach(&mut stack, &mut roots, done);
            } else {
                // retroactive lap pairs can graze an open RAII span;
                // clamp so the forest stays strictly nested
                if s.t1_ns > top.t1_ns {
                    s.t1_ns = top.t1_ns;
                }
                break;
            }
        }
        stack.push(s);
    }
    while let Some(done) = stack.pop() {
        attach(&mut stack, &mut roots, done);
    }
    roots
}

fn attach(stack: &mut [Span], roots: &mut Vec<Span>, done: Span) {
    match stack.last_mut() {
        Some(parent) => parent.children.push(done),
        None => roots.push(done),
    }
}

fn fmt_ns(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

fn fmt_bytes(b: u64) -> String {
    if b < 1024 {
        format!("{b}B")
    } else if b < 1024 * 1024 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else {
        format!("{:.1}MiB", b as f64 / (1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, begin: bool, ts: u64) -> Event {
        Event { name, begin, ts_ns: ts }
    }

    #[test]
    fn pairing_follows_push_order() {
        // span(a){ span(b){} } then a lap pair (c) — push order a,b,b,a,c,c
        let events = vec![
            ev("a", true, 0),
            ev("b", true, 10),
            ev("b", false, 20),
            ev("a", false, 30),
            ev("c", true, 30),
            ev("c", false, 40),
        ];
        let roots = nest(pair(&events));
        assert_eq!(roots.len(), 2);
        assert_eq!(roots[0].name, "a");
        assert_eq!(roots[0].children.len(), 1);
        assert_eq!(roots[0].children[0].name, "b");
        assert_eq!(roots[1].name, "c");
    }

    #[test]
    fn retroactive_pairs_nest_under_covering_interval() {
        // an operator span pushed first, then the phase lap that covers
        // it temporally: the forest must put the span inside the phase
        let events = vec![
            ev("operator/icf", true, 10),
            ev("operator/icf", false, 40),
            ev("solver/setup", true, 0),
            ev("solver/setup", false, 50),
        ];
        let roots = nest(pair(&events));
        assert_eq!(roots.len(), 1);
        assert_eq!(roots[0].name, "solver/setup");
        assert_eq!(roots[0].children.len(), 1);
        assert_eq!(roots[0].children[0].name, "operator/icf");
        assert_eq!(roots[0].self_ns(), 20);
    }

    #[test]
    fn unmatched_begin_is_closed_at_last_ts() {
        let events = vec![ev("a", true, 5), ev("b", true, 10), ev("b", false, 20)];
        let spans = pair(&events);
        assert_eq!(spans.len(), 2);
        let a = spans.iter().find(|s| s.name == "a").unwrap();
        assert_eq!((a.t0_ns, a.t1_ns), (5, 20));
    }

    #[test]
    fn phase_rows_aggregate_by_name() {
        let events = vec![
            ev("k", true, 0),
            ev("k", false, 10),
            ev("k", true, 10),
            ev("k", false, 30),
            ev("u", true, 30),
            ev("u", false, 35),
        ];
        let report =
            TraceReport::build(Duration::from_nanos(35), [0; NUM_COUNTERS], vec![(0, events)]);
        let rows = report.phase_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "k");
        assert_eq!(rows[0].total_ns, 30);
        assert_eq!(rows[0].count, 2);
        assert_eq!(rows[1].name, "u");
        assert!(report.coverage() > 0.99);
        let json = report.counters_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"cache_lookups\": 0"));
    }
}
