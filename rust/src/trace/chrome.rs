//! Chrome trace-event export: load the file in `chrome://tracing` or
//! <https://ui.perfetto.dev> to see the span forest on a timeline.
//!
//! The emitter walks each thread's nesting forest depth-first, writing
//! duration events (`ph:"B"`/`ph:"E"`) per tid with microsecond
//! timestamps. Because the forest is strictly nested and visited in
//! start order, every thread's B/E stream is balanced and its
//! timestamps are non-decreasing — the exact property
//! `ci/check_trace_json.py` validates in CI.

use std::fmt::Write as _;
use std::path::Path;

use super::report::{Span, TraceReport};

/// Serialize `report` as a Chrome trace-event JSON array.
pub fn render(report: &TraceReport) -> String {
    let mut out = String::from("[\n");
    let mut first = true;
    push_event(
        &mut out,
        &mut first,
        "{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"wu-svm\"}}",
    );
    for t in &report.threads {
        push_event(
            &mut out,
            &mut first,
            &format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"trace-thread-{}\"}}}}",
                t.tid, t.tid
            ),
        );
        for root in &t.roots {
            emit_span(&mut out, &mut first, t.tid, root);
        }
    }
    out.push_str("\n]\n");
    out
}

/// Render and write `report` to `path`.
pub fn write_chrome_json(report: &TraceReport, path: &Path) -> std::io::Result<()> {
    std::fs::write(path, render(report))
}

fn emit_span(out: &mut String, first: &mut bool, tid: u32, span: &Span) {
    let mut b = String::new();
    let _ = write!(
        b,
        "{{\"ph\":\"B\",\"pid\":1,\"tid\":{},\"ts\":{:.3},\"name\":\"{}\"}}",
        tid,
        span.t0_ns as f64 / 1e3,
        escape(span.name)
    );
    push_event(out, first, &b);
    for child in &span.children {
        emit_span(out, first, tid, child);
    }
    let mut e = String::new();
    let _ = write!(
        e,
        "{{\"ph\":\"E\",\"pid\":1,\"tid\":{},\"ts\":{:.3},\"name\":\"{}\"}}",
        tid,
        span.t1_ns as f64 / 1e3,
        escape(span.name)
    );
    push_event(out, first, &e);
}

fn push_event(out: &mut String, first: &mut bool, event: &str) {
    if !*first {
        out.push_str(",\n");
    }
    *first = false;
    out.push_str(event);
}

/// Span names are static identifiers, but escape the JSON specials
/// anyway so a future name can't corrupt the file.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Event, NUM_COUNTERS};
    use std::time::Duration;

    #[test]
    fn render_is_balanced_and_ordered() {
        let events = vec![
            Event { name: "outer", begin: true, ts_ns: 1_000 },
            Event { name: "inner", begin: true, ts_ns: 2_000 },
            Event { name: "inner", begin: false, ts_ns: 3_000 },
            Event { name: "outer", begin: false, ts_ns: 4_000 },
        ];
        let report = TraceReport::build(
            Duration::from_micros(4),
            [0; NUM_COUNTERS],
            vec![(3, events)],
        );
        let json = render(&report);
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert_eq!(json.matches("\"ph\":\"B\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"E\"").count(), 2);
        // depth-first order: B outer, B inner, E inner, E outer
        let b_outer = json.find("\"ts\":1.000,\"name\":\"outer\"").unwrap();
        let b_inner = json.find("\"ts\":2.000,\"name\":\"inner\"").unwrap();
        let e_inner = json.find("\"ts\":3.000,\"name\":\"inner\"").unwrap();
        let e_outer = json.find("\"ts\":4.000,\"name\":\"outer\"").unwrap();
        assert!(b_outer < b_inner && b_inner < e_inner && e_inner < e_outer);
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("plain/name"), "plain/name");
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("x\ny"), "x\\u000ay");
    }
}
