//! Experiment drivers: Table 1 and the prose-claim ablation figures
//! (DESIGN.md §6). Shared by the CLI (`wu-svm bench ...`) and the
//! `cargo bench` targets.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{build_engine, load_data, run, EngineChoice, RunRecord, Solver, TrainJob};
use crate::data::{paper, Format};
use crate::pool;
use crate::report::{fill_speedups, render_sweep, render_table, Row};
use crate::solvers::TraceObserver;

/// Default bench scale per dataset: sized so the single-core SMO baseline
/// finishes in minutes, not hours (the *relative* ordering is the paper's
/// claim; see EXPERIMENTS.md for the scale used in the recorded run).
pub fn default_scale(key: &str) -> f64 {
    match key {
        "adult" => 0.16,     // ~5.0k train
        "covertype" => 0.05, // ~5.0k
        "kdd99" => 0.02,     // ~3.0k (C = 1e3 -> many bounded SVs)
        "mitfaces" => 0.04,  // ~3.2k
        "fd" => 0.06,        // ~3.0k (d = 900)
        "epsilon" => 0.075,  // ~3.0k (d = 2000)
        "mnist8m" => 0.05,   // ~3.0k over 45 pairs
        _ => 0.05,
    }
}

/// The six Table-1 method configurations (paper row order).
pub fn table1_methods(
    mc_threads: usize,
) -> Vec<(&'static str, &'static str, Solver, EngineChoice)> {
    vec![
        ("SC", "LibSVM", Solver::Smo, EngineChoice::CpuSeq),
        ("MC", "LibSVM", Solver::Smo, EngineChoice::CpuPar(mc_threads)),
        ("MC", "SP-SVM", Solver::SpSvm, EngineChoice::CpuPar(mc_threads)),
        ("XLA", "GPU-SVM", Solver::Smo, EngineChoice::Xla),
        ("XLA", "GTSVM", Solver::Wss, EngineChoice::Xla),
        ("XLA", "SP-SVM", Solver::SpSvm, EngineChoice::Xla),
    ]
}

fn record_to_row(arch: &str, method: &str, rec: &RunRecord) -> Row {
    Row {
        dataset: rec.job.dataset.clone(),
        arch: arch.to_string(),
        method: method.to_string(),
        metric_name: rec.metric_name.clone(),
        test_metric: rec.test_metric,
        train_time: rec.train_time,
        speedup: 1.0,
        notes: format!(
            "n={} m={}",
            rec.n_train, rec.expansion_size
        ),
    }
}

/// Run one Table-1 dataset row across methods. `methods_filter` limits to
/// matching method names (empty = all). Failures become "—" rows, like the
/// paper's dashes.
pub fn run_table1_dataset(
    key: &str,
    scale: f64,
    max_basis: usize,
    methods_filter: &[String],
) -> Result<Vec<Row>> {
    let threads = pool::default_threads();
    let mut rows = Vec::new();
    for (arch, method, solver, engine) in table1_methods(threads) {
        if !methods_filter.is_empty()
            && !methods_filter.iter().any(|m| m.eq_ignore_ascii_case(method))
        {
            continue;
        }
        // mnist8m (45 pair models) is too slow for the accelerator SMO
        // family at any useful scale on this box; the paper's Table 1
        // likewise has "—" for every GPU method on MNIST8M. Keep SC/MC
        // LibSVM (the baseline) and SP-SVM.
        if key == "mnist8m"
            && (matches!(solver, Solver::Wss)
                || (matches!(solver, Solver::Smo) && engine == EngineChoice::Xla))
        {
            rows.push(dash_row(key, arch, method, "skipped: 45 OvO pairs on accel (paper: —)"));
            continue;
        }
        let job = TrainJob {
            dataset: key.to_string(),
            scale,
            solver,
            engine,
            max_basis,
            ..Default::default()
        };
        eprintln!("[table1] {key} {arch}/{method} ...");
        match run(&job) {
            Ok(rec) => rows.push(record_to_row(arch, method, &rec)),
            Err(e) => {
                eprintln!("[table1] {key} {arch}/{method} failed: {e:#}");
                rows.push(dash_row(key, arch, method, &format!("{e}")));
            }
        }
    }
    fill_speedups(&mut rows, "LibSVM", "SC");
    Ok(rows)
}

fn dash_row(ds: &str, arch: &str, method: &str, note: &str) -> Row {
    Row {
        dataset: ds.into(),
        arch: arch.into(),
        method: method.into(),
        metric_name: "-".into(),
        test_metric: f64::NAN,
        train_time: Duration::ZERO,
        speedup: f64::NAN,
        notes: note.chars().take(60).collect(),
    }
}

/// F.scaling — speedup vs thread count for SMO and SP-SVM (paper §5:
/// "5-8x on twelve cores"; SP-SVM speedup grows with library occupancy).
pub fn run_scaling(dataset: &str, scale: f64, threads_list: &[usize]) -> Result<String> {
    let mut points = Vec::new();
    let mut base = (0.0f64, 0.0f64);
    for (i, &t) in threads_list.iter().enumerate() {
        let smo_job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::Smo,
            engine: if t == 1 { EngineChoice::CpuSeq } else { EngineChoice::CpuPar(t) },
            ..Default::default()
        };
        let sp_job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::SpSvm,
            engine: if t == 1 { EngineChoice::CpuSeq } else { EngineChoice::CpuPar(t) },
            max_basis: 255,
            ..Default::default()
        };
        let rs = run(&smo_job)?;
        let rp = run(&sp_job)?;
        let ts = rs.train_time.as_secs_f64();
        let tp = rp.train_time.as_secs_f64();
        if i == 0 {
            base = (ts, tp);
        }
        points.push((t as f64, vec![ts, base.0 / ts, tp, base.1 / tp]));
    }
    Ok(render_sweep(
        &format!("F.scaling on {dataset} (scale {scale})"),
        "threads",
        &["smo_time_s", "smo_speedup", "spsvm_time_s", "spsvm_speedup"],
        &points,
    ))
}

/// F.basis — error/time vs basis capacity (SP-SVM's accuracy trade-off).
pub fn run_basis_sweep(dataset: &str, scale: f64, sizes: &[usize]) -> Result<String> {
    let mut points = Vec::new();
    for &b in sizes {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::SpSvm,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            max_basis: b,
            ..Default::default()
        };
        let rec = run(&job)?;
        points.push((
            b as f64,
            vec![rec.test_metric, rec.train_time.as_secs_f64(), rec.expansion_size as f64],
        ));
    }
    Ok(render_sweep(
        &format!("F.basis on {dataset} (scale {scale})"),
        "max_basis",
        &["test_metric", "time_s", "used"],
        &points,
    ))
}

/// F.wss — working-set-size sweep (GTSVM's S = 16 vs SMO's S = 2).
pub fn run_wss_sweep(dataset: &str, scale: f64, sizes: &[usize]) -> Result<String> {
    let mut points = Vec::new();
    for &s in sizes {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::Wss,
            engine: EngineChoice::Xla,
            wss_size: s,
            ..Default::default()
        };
        let rec = run(&job)?;
        points.push((s as f64, vec![rec.test_metric, rec.train_time.as_secs_f64()]));
    }
    Ok(render_sweep(
        &format!("F.wss on {dataset} (scale {scale}, xla engine)"),
        "wss_size",
        &["test_metric", "time_s"],
        &points,
    ))
}

/// F.epsstop — the paper's epsilon = 5e-6 stopping-rule sweep.
pub fn run_eps_sweep(dataset: &str, scale: f64, epss: &[f64]) -> Result<String> {
    let mut points = Vec::new();
    for &e in epss {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::SpSvm,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            eps: Some(e),
            max_basis: 511,
            ..Default::default()
        };
        let rec = run(&job)?;
        points.push((
            e,
            vec![rec.test_metric, rec.train_time.as_secs_f64(), rec.expansion_size as f64],
        ));
    }
    Ok(render_sweep(
        &format!("F.epsstop on {dataset} (scale {scale})"),
        "eps",
        &["test_metric", "time_s", "basis"],
        &points,
    ))
}

/// F.convergence — per-iteration `(iter, objective, active, elapsed)`
/// traces via the [`TraceObserver`], one TSV block per solver: the raw
/// material of the time-vs-accuracy convergence curves the paper's
/// Table 1 (end-state numbers only) cannot show. `every` decimates the
/// trace (1 = keep every iteration).
pub fn run_convergence(
    dataset: &str,
    scale: f64,
    solvers: &[Solver],
    every: usize,
) -> Result<String> {
    let mut out = String::new();
    for &solver in solvers {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            ..Default::default()
        };
        let (tr, _, spec) = load_data(&job)?;
        anyhow::ensure!(
            !tr.is_multiclass(),
            "convergence traces are binary-only (dataset '{dataset}' is multiclass)"
        );
        let engine = build_engine(job.engine)?;
        let obs = Arc::new(TraceObserver::every(every));
        let trainer = job.trainer(&spec, &engine).observer(obs.clone());
        let name = trainer.solver_name().to_string();
        let r = trainer.train(&tr)?;
        out.push_str(&format!(
            "# F.convergence {name} on {dataset} (scale {scale}): {} iters, \
             final objective {:.6}\n",
            r.iterations, r.objective
        ));
        out.push_str(&obs.to_tsv());
        out.push('\n');
    }
    Ok(out)
}

/// F.sparse — the CSR substrate against the densified path on one
/// workload (EXPERIMENTS.md §SPARSE): the same solver trains the same
/// rows stored dense and CSR; the table reports both wall times, the
/// storage footprints, and the maximum absolute test-margin difference
/// (the ≤ 1e-6 agreement contract of the SpMM-backed kernel paths).
pub fn run_sparse_compare(dataset: &str, scale: f64, solver: Solver) -> Result<String> {
    let threads = pool::default_threads();
    let job = TrainJob {
        dataset: dataset.into(),
        scale,
        solver,
        engine: EngineChoice::CpuPar(threads),
        ..Default::default()
    };
    let (tr_dense, te, spec) = load_data(&job)?;
    anyhow::ensure!(
        !tr_dense.is_multiclass(),
        "sparse compare is binary-only (dataset '{dataset}' is multiclass)"
    );
    let engine = build_engine(job.engine)?;
    let trainer = job.trainer(&spec, &engine);
    let tr_csr = tr_dense.clone().with_format(Format::Csr);

    let t0 = std::time::Instant::now();
    let rd = trainer.train(&tr_dense)?;
    let t_dense = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let rc = trainer.train(&tr_csr)?;
    let t_csr = t0.elapsed().as_secs_f64();

    let md = rd.model.decision_batch(&te, threads);
    let mc = rc.model.decision_batch(&te, threads);
    let dmax = md
        .iter()
        .zip(&mc)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    let mut out = format!(
        "F.sparse {} on {dataset} (scale {scale}, n = {}, sparsity {:.1}%)\n",
        trainer.solver_name(),
        tr_dense.n,
        tr_dense.sparsity() * 100.0
    );
    out.push_str(&format!(
        "  dense: {t_dense:.3}s ({} bytes)   csr: {t_csr:.3}s ({} bytes)   \
         speedup {:.2}x   bytes ratio {:.2}x\n",
        tr_dense.bytes(),
        tr_csr.bytes(),
        t_dense / t_csr.max(1e-9),
        tr_dense.bytes() as f64 / tr_csr.bytes().max(1) as f64
    ));
    out.push_str(&format!("  max |margin_dense - margin_csr| = {dmax:.2e}\n"));
    // tile/full-kernel solvers (spsvm, mu, primal) are bit-identical
    // across storage formats (DESIGN.md §SPARSE); the row-fed explicit
    // solvers agree to kernel-evaluation rounding, so the hard gate sits
    // at the decomposition solvers' stopping tolerance.
    anyhow::ensure!(
        dmax <= 1e-3,
        "csr and dense models diverged (max margin diff {dmax:.2e})"
    );
    Ok(out)
}

/// F.rank — LS-SVM accuracy and operator memory vs ICF rank
/// (EXPERIMENTS.md §LOWRANK). Row 0 is the exact-kernel baseline
/// (`--rank 0`); each sweep row trains the same data on a rank-r pivoted
/// incomplete Cholesky operator and reports the test metric, wall time,
/// the operator's own `memory_bytes` in MB, and that footprint as a
/// fraction of the n^2 exact kernel.
pub fn run_rank_curve(dataset: &str, scale: f64, ranks: &[usize]) -> Result<String> {
    let mut points = Vec::new();
    let mut n_train = 0usize;
    for &r in std::iter::once(&0usize).chain(ranks) {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::LsSvm,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            rank: Some(r),
            ..Default::default()
        };
        let rec = run(&job)?;
        n_train = rec.n_train;
        let exact_bytes = (rec.n_train * rec.n_train * 4) as f64;
        let op_bytes: f64 = rec
            .notes
            .iter()
            .find(|(k, _)| k == "operator_bytes")
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(exact_bytes);
        points.push((
            r as f64,
            vec![
                rec.test_metric,
                rec.train_time.as_secs_f64(),
                op_bytes / 1e6,
                op_bytes / exact_bytes,
            ],
        ));
    }
    Ok(render_sweep(
        &format!("F.rank lssvm on {dataset} (scale {scale}, n = {n_train}; rank 0 = exact)"),
        "rank",
        &["test_metric", "time_s", "op_mb", "vs_exact"],
        &points,
    ))
}

/// F.cascade — cascade sharded training vs direct: wall time, accuracy,
/// final SV count and KKT feedback volume per shard count. Shard count 1
/// is the direct (uncascaded) baseline the speedup column divides by.
pub fn run_cascade_scaling(dataset: &str, scale: f64, shards: &[usize]) -> Result<String> {
    let mut points = Vec::new();
    let mut base = 0.0f64;
    let mut n_train = 0usize;
    for (i, &s) in shards.iter().enumerate() {
        let job = TrainJob {
            dataset: dataset.into(),
            scale,
            solver: Solver::Smo,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            cascade_shards: s,
            ..Default::default()
        };
        let rec = run(&job)?;
        n_train = rec.n_train;
        let t = rec.train_time.as_secs_f64();
        if i == 0 {
            base = t;
        }
        let note_num = |key: &str| -> f64 {
            rec.notes
                .iter()
                .find(|(k, _)| k == key)
                .and_then(|(_, v)| v.parse().ok())
                .unwrap_or(0.0)
        };
        points.push((
            s as f64,
            vec![
                t,
                base / t,
                rec.test_metric,
                note_num("n_sv"),
                note_num("cascade_kkt_violations"),
            ],
        ));
    }
    Ok(render_sweep(
        &format!("F.cascade smo on {dataset} (scale {scale}, n = {n_train}; shards 1 = direct)"),
        "shards",
        &["time_s", "speedup", "test_metric", "n_sv", "kkt_fb"],
        &points,
    ))
}

/// F.memory — the memory wall for exact implicit methods: bytes required
/// vs n for MU (2 n^2), full primal (n^2) and SP-SVM (|J| n), plus
/// whether each method runs under a 2 GB cap.
pub fn run_memory_table(ns: &[usize], basis: usize) -> String {
    let cap: usize = 2 << 30;
    let mut points = Vec::new();
    for &n in ns {
        let mu = 2 * n * n * 4;
        let primal = n * n * 4;
        let spsvm = n * (basis + 1) * 4;
        points.push((
            n as f64,
            vec![
                mu as f64 / 1e9,
                if mu <= cap { 1.0 } else { 0.0 },
                primal as f64 / 1e9,
                if primal <= cap { 1.0 } else { 0.0 },
                spsvm as f64 / 1e9,
                if spsvm <= cap { 1.0 } else { 0.0 },
            ],
        ));
    }
    render_sweep(
        &format!("F.memory (2 GB cap, |J| = {basis})"),
        "n",
        &["mu_gb", "mu_ok", "primal_gb", "primal_ok", "spsvm_gb", "spsvm_ok"],
        &points,
    )
}

/// Render Table-1 rows with the paper's reference numbers alongside.
pub fn render_with_reference(rows: &[Row]) -> String {
    let mut out = render_table(rows);
    out.push_str("\npaper reference (Table 1):\n");
    for spec in paper::specs() {
        out.push_str(&format!(
            "  {:<10} paper LibSVM err {:.1}%  (C = {}, gamma = {}, paper n = {})\n",
            spec.key,
            spec.paper_error * 100.0,
            spec.c,
            spec.gamma,
            spec.paper_n
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scales_are_sane() {
        for s in paper::specs() {
            let sc = default_scale(s.key);
            assert!(sc > 0.0 && sc <= 1.0);
            let n = (s.n_train as f64 * sc) as usize;
            assert!(n >= 500 && n <= 10_000, "{}: n = {n}", s.key);
        }
    }

    #[test]
    fn methods_cover_table1() {
        let m = table1_methods(4);
        assert_eq!(m.len(), 6);
        assert_eq!(m[0].0, "SC");
        assert!(m.iter().filter(|x| x.1 == "SP-SVM").count() == 2);
    }

    #[test]
    fn memory_table_shows_the_wall() {
        let t = run_memory_table(&[10_000, 100_000, 1_000_000], 511);
        assert!(t.contains("mu_gb"));
        // at n = 1M, MU needs 8 TB -> not ok; SP-SVM a few GB -> ok
        let last = t.lines().last().unwrap();
        assert!(last.contains("0.00000")); // some method fails the cap
    }

    #[test]
    fn convergence_trace_produces_points() {
        let t = run_convergence("adult", 0.01, &[Solver::SpSvm], 1).unwrap();
        assert!(t.contains("F.convergence spsvm"), "{t}");
        assert!(t.contains("iter\tobjective\tactive\telapsed_ms"), "{t}");
        // at least one data row under the header
        assert!(t.lines().any(|l| l.starts_with("1\t")), "{t}");
        // multiclass datasets are rejected, not mis-traced
        assert!(run_convergence("mnist8m", 0.004, &[Solver::SpSvm], 1).is_err());
    }

    #[test]
    fn sparse_compare_runs_and_agrees() {
        // kdd99 analog is ~90% sparse; the default (spsvm) path is
        // bit-identical across storage formats, so the 1e-3 gate inside
        // run_sparse_compare must hold with room to spare
        let t = run_sparse_compare("kdd99", 0.004, Solver::SpSvm).unwrap();
        assert!(t.contains("F.sparse spsvm"), "{t}");
        assert!(t.contains("max |margin_dense - margin_csr|"), "{t}");
        // multiclass datasets are rejected, not mis-compared
        assert!(run_sparse_compare("mnist8m", 0.004, Solver::SpSvm).is_err());
    }

    #[test]
    fn rank_curve_runs_exact_and_lowrank() {
        let t = run_rank_curve("adult", 0.01, &[16]).unwrap();
        assert!(t.contains("F.rank lssvm"), "{t}");
        assert!(t.contains("op_mb"), "{t}");
        // one exact row (rank 0) + one sweep row
        assert!(t.lines().any(|l| l.starts_with("0")), "{t}");
        assert!(t.lines().any(|l| l.starts_with("16")), "{t}");
    }

    #[test]
    fn cascade_scaling_runs_direct_and_sharded() {
        let t = run_cascade_scaling("adult", 0.01, &[1, 2]).unwrap();
        assert!(t.contains("F.cascade smo"), "{t}");
        assert!(t.contains("speedup"), "{t}");
        assert!(t.lines().any(|l| l.starts_with("1")), "{t}");
        assert!(t.lines().any(|l| l.starts_with("2")), "{t}");
    }

    #[test]
    fn table1_single_method_small() {
        let rows =
            run_table1_dataset("adult", 0.01, 63, &["SP-SVM".to_string()]).unwrap();
        assert_eq!(rows.len(), 2); // MC + XLA-or-dash
        assert!(rows.iter().all(|r| r.method == "SP-SVM"));
    }
}
