//! wu-svm CLI: train / predict / datagen / bench / serve / info.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use wu_svm::config::Config;
use wu_svm::coordinator::{self, TrainJob};
use wu_svm::serve;
use wu_svm::data::{libsvm, paper};
use wu_svm::experiments;
use wu_svm::metrics::fmt_duration;
use wu_svm::model::SvmModel;
use wu_svm::pool;
use wu_svm::report;

const USAGE: &str = "\
wu-svm — Parallel Support Vector Machines in Practice (Tyree et al. 2014)

USAGE: wu-svm <command> [--flags]

COMMANDS
  train     train one model
            --dataset adult|covertype|kdd99|mitfaces|fd|epsilon|mnist8m
            --input data.libsvm [--test-input t.libsvm]  (real files; else
              a generated analog of --dataset; default test = 80/20 split)
            --format dense|csr|auto  (design-matrix storage; auto picks
              CSR at <= 25% density; files default auto, analogs dense)
            --solver smo|wss|mu|primal|spsvm|lssvm  --engine cpu-seq|cpu-par|xla
            --scale 0.05  --c --gamma --eps --max-basis --seed
            --rank R       (implicit solvers: pivoted-ICF kernel rank;
              0 = exact; lssvm defaults to 256)
            --landmarks M  (Nystrom landmarks instead of ICF)
            --time-budget-secs T --max-iters N  (training budget)
            --cascade-shards S  (cascade sharded training, smo|wss only:
              partition rows, train shards concurrently, merge SV unions
              warm-started, verify global KKT; 0/1 = off)
            --cascade-layers auto|L  (merge-layer cap; reaching it
              collapses the remaining fits in one final merge)
            --cascade-kkt-tol T  (global KKT sweep tolerance, default 1e-3)
            --cache-mb N|auto  (kernel row cache budget; auto sizes to
              half of available RAM from /proc/meminfo)
            --cache-slack F  (smo|wss: among rows within F*eps of the
              max violation, prefer one already in the cache; 0 = off,
              bit-identical; F in [0, 1))
            --polish  (smo|wss: after converging with shrinking,
              re-optimize the full unshrunk problem until KKT-clean;
              report notes polish = clean|capped|stalled)
            --save model.txt  (unknown --keys are rejected)
            --profile  (per-phase wall breakdown + runtime counters)
            --trace-json trace.json  (Chrome trace-event export; open
              in chrome://tracing or ui.perfetto.dev)
  pack      --input data.libsvm --out data.wusvm [--format dense|csr|auto]
            [--d N]  (one-shot convert to the packed mmap layout; train
            then streams rows off disk: --input data.wusvm is sniffed
            by magic and memory-mapped instead of parsed)
  predict   --model model.txt --input data.libsvm [--threads N]
            [--format dense|csr|auto]
  datagen   --dataset KEY --scale S --out file.libsvm [--test-out f]
  bench     table1|scaling|basis|wss|epsstop|memory|convergence|sparse|
            rank-curve|cascade
            table1: --dataset KEY|all --scale S --methods a,b --max-basis N
            convergence: --dataset KEY --scale S --solvers smo,spsvm --every K
            sparse: --dataset kdd99 --scale S --solver spsvm  (csr vs dense)
            rank-curve: --dataset KEY --scale S --ranks 16,32,64,128,256
              (lssvm accuracy/memory vs ICF rank, exact baseline at rank 0)
            cascade: --dataset KEY --scale S --shards 1,2,4,8
              (cascade wall/accuracy vs direct training per shard count)
            bench also honors --profile and --trace-json (see train)
  serve     --dataset KEY --scale S [--engine E] [--requests N] [--batch N]
            [--shards K] [--queue-cap N]  (multiclass datasets serve OvO)
  info      artifact manifest + runtime info
  help      this text

All heavy math is AOT-compiled XLA (run `make artifacts` first for the
xla engine); cpu engines work without artifacts.";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let cfg = Config::from_args(&args[1..])?;
    match cmd.as_str() {
        "train" => run_traced(&cfg, || cmd_train(&cfg)),
        "predict" => cmd_predict(&cfg),
        "pack" => cmd_pack(&cfg),
        "datagen" => cmd_datagen(&cfg),
        "bench" => run_traced(&cfg, || cmd_bench(&cfg)),
        "serve" => cmd_serve(&cfg),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Run `f` under a trace session when `--profile`/`--trace-json` ask
/// for one; otherwise stay on the permanently-disabled fast path.
fn run_traced(cfg: &Config, f: impl FnOnce() -> Result<()>) -> Result<()> {
    let profile = cfg.bool_or("profile", false)?;
    let trace_json = cfg.get("trace-json").map(PathBuf::from);
    if !profile && trace_json.is_none() {
        return f();
    }
    let session = wu_svm::trace::Session::start();
    if !session.is_active() {
        println!("note: WU_SVM_TRACE=0 set, tracing disabled");
    }
    let out = f();
    let report = session.finish();
    if out.is_ok() {
        if profile {
            print!("{}", report.render_profile());
        }
        if let Some(path) = &trace_json {
            wu_svm::trace::chrome::write_chrome_json(&report, path)?;
            println!("wrote chrome trace to {}", path.display());
        }
    }
    out
}

fn cmd_train(cfg: &Config) -> Result<()> {
    cfg.check_known(coordinator::TRAIN_KEYS)?;
    let job = TrainJob::from_config(cfg)?;
    let source = job.input.clone().unwrap_or_else(|| job.dataset.clone());
    println!(
        "training {} with {:?} on {:?} (scale {}, format {})",
        source,
        job.solver,
        job.engine,
        job.scale,
        job.format.name()
    );
    let rec = coordinator::run(&job)?;
    println!(
        "n_train={} n_test={} expansion={}",
        rec.n_train, rec.n_test, rec.expansion_size
    );
    println!(
        "{} = {:.2}%  train time = {}",
        rec.metric_name,
        rec.test_metric * 100.0,
        fmt_duration(rec.train_time)
    );
    for (k, v) in &rec.notes {
        println!("  {k} = {v}");
    }
    if let Some(path) = cfg.get("save") {
        // run() reports metrics but discards the model; retrain through
        // the same Trainer the run used (works for every solver now).
        let (tr, _, spec) = coordinator::load_data(&job)?;
        if tr.is_multiclass() {
            bail!("--save supports binary datasets");
        }
        let engine = coordinator::build_engine(job.engine)?;
        let trainer = job.trainer(&spec, &engine);
        let r = trainer.train(&tr)?;
        r.model.save(Path::new(path))?;
        println!("saved {} model to {path}", trainer.solver_name());
    }
    Ok(())
}

fn cmd_predict(cfg: &Config) -> Result<()> {
    let model_path = cfg.get("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    let input = cfg.get("input").ok_or_else(|| anyhow::anyhow!("--input required"))?;
    let threads = cfg.usize_or("threads", pool::default_threads())?;
    let format = wu_svm::data::Format::parse(&cfg.str_or("format", "auto"))?;
    let model = SvmModel::load(Path::new(model_path))?;
    let ds = libsvm::read_file_with(Path::new(input), model.d, format)?;
    let t0 = std::time::Instant::now();
    let margins = model.decision_batch(&ds, threads);
    let dt = t0.elapsed();
    let err = wu_svm::metrics::error_rate(&margins, &ds.y);
    println!(
        "predicted {} rows in {} ({:.0} rows/s), error = {:.2}%",
        ds.n,
        fmt_duration(dt),
        ds.n as f64 / dt.as_secs_f64(),
        err * 100.0
    );
    Ok(())
}

fn cmd_pack(cfg: &Config) -> Result<()> {
    cfg.check_known(&["input", "out", "format", "d"])?;
    let input = cfg.get("input").ok_or_else(|| anyhow::anyhow!("--input required"))?;
    let out = cfg
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(input).with_extension("wusvm"));
    let d_hint = cfg.usize_or("d", 0)?;
    let format = wu_svm::data::Format::parse(&cfg.str_or("format", "auto"))?;
    let t0 = std::time::Instant::now();
    let (n, d, kind) = wu_svm::data::pack::pack_file(Path::new(input), &out, d_hint, format)?;
    println!(
        "packed {n} rows (d = {d}, {kind}) to {} in {}",
        out.display(),
        fmt_duration(t0.elapsed())
    );
    Ok(())
}

fn cmd_datagen(cfg: &Config) -> Result<()> {
    let key = cfg.str_or("dataset", "adult");
    let scale = cfg.f64_or("scale", 0.05)?;
    let seed = cfg.u64_or("seed", 1)?;
    let out = PathBuf::from(cfg.str_or("out", &format!("{key}.libsvm")));
    let spec = paper::spec(&key).ok_or_else(|| anyhow::anyhow!("unknown dataset {key}"))?;
    let (tr, te) = spec.generate(scale, seed);
    libsvm::write_file(&tr, &out)?;
    println!("wrote {} train rows (d = {}) to {}", tr.n, tr.d, out.display());
    if let Some(tpath) = cfg.get("test-out") {
        libsvm::write_file(&te, Path::new(tpath))?;
        println!("wrote {} test rows to {tpath}", te.n);
    }
    Ok(())
}

fn cmd_bench(cfg: &Config) -> Result<()> {
    let which = cfg
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("table1");
    match which {
        "table1" => {
            let key = cfg.str_or("dataset", "all");
            let methods: Vec<String> = cfg
                .get("methods")
                .map(|m| m.split(',').map(|s| s.trim().to_string()).collect())
                .unwrap_or_default();
            let max_basis = cfg.usize_or("max-basis", 255)?;
            let keys: Vec<String> = if key == "all" {
                paper::specs().iter().map(|s| s.key.to_string()).collect()
            } else {
                vec![key]
            };
            let mut all_rows = Vec::new();
            for k in keys {
                let scale = cfg.f64_or("scale", experiments::default_scale(&k))?;
                let rows = experiments::run_table1_dataset(&k, scale, max_basis, &methods)?;
                println!("{}", report::render_table(&rows));
                all_rows.extend(rows);
            }
            println!("{}", experiments::render_with_reference(&all_rows));
        }
        "scaling" => {
            let ds = cfg.str_or("dataset", "covertype");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            let max_t = pool::default_threads();
            let mut ts = vec![1usize, 2, 4];
            if max_t >= 8 {
                ts.push(8);
            }
            if max_t > 8 {
                ts.push(max_t);
            }
            println!("{}", experiments::run_scaling(&ds, scale, &ts)?);
        }
        "basis" => {
            let ds = cfg.str_or("dataset", "covertype");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            println!(
                "{}",
                experiments::run_basis_sweep(&ds, scale, &[15, 31, 63, 127, 255, 511])?
            );
        }
        "wss" => {
            let ds = cfg.str_or("dataset", "adult");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            println!("{}", experiments::run_wss_sweep(&ds, scale, &[2, 4, 8, 16, 32])?);
        }
        "epsstop" => {
            let ds = cfg.str_or("dataset", "adult");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            println!(
                "{}",
                experiments::run_eps_sweep(&ds, scale, &[1e-3, 1e-4, 1e-5, 5e-6, 1e-6])?
            );
        }
        "memory" => {
            println!(
                "{}",
                experiments::run_memory_table(
                    &[1_000, 10_000, 31_562, 100_000, 489_410, 4_898_431],
                    511
                )
            );
        }
        "convergence" => {
            let ds = cfg.str_or("dataset", "adult");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            let every = cfg.usize_or("every", 25)?;
            let solvers: Vec<wu_svm::coordinator::Solver> = cfg
                .str_or("solvers", "smo,spsvm")
                .split(',')
                .map(|s| wu_svm::coordinator::Solver::parse(s.trim()))
                .collect::<Result<_>>()?;
            println!("{}", experiments::run_convergence(&ds, scale, &solvers, every)?);
        }
        "sparse" => {
            let ds = cfg.str_or("dataset", "kdd99");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            let solver = wu_svm::coordinator::Solver::parse(&cfg.str_or("solver", "spsvm"))?;
            println!("{}", experiments::run_sparse_compare(&ds, scale, solver)?);
        }
        "rank-curve" => {
            let ds = cfg.str_or("dataset", "adult");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            let ranks: Vec<usize> = cfg
                .str_or("ranks", "16,32,64,128,256")
                .split(',')
                .map(|s| s.trim().parse::<usize>())
                .collect::<std::result::Result<_, _>>()?;
            println!("{}", experiments::run_rank_curve(&ds, scale, &ranks)?);
        }
        "cascade" => {
            let ds = cfg.str_or("dataset", "adult");
            let scale = cfg.f64_or("scale", experiments::default_scale(&ds))?;
            let shards: Vec<usize> = cfg
                .str_or("shards", "1,2,4,8")
                .split(',')
                .map(|s| s.trim().parse::<usize>())
                .collect::<std::result::Result<_, _>>()?;
            println!("{}", experiments::run_cascade_scaling(&ds, scale, &shards)?);
        }
        other => bail!(
            "unknown bench '{other}' (table1|scaling|basis|wss|epsstop|memory|\
             convergence|sparse|rank-curve|cascade)"
        ),
    }
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    let key = cfg.str_or("dataset", "adult");
    let scale = cfg.f64_or("scale", 0.02)?;
    let n_req = cfg.usize_or("requests", 2000)?;
    let batch = cfg.usize_or("batch", 256)?;
    let shards = cfg.usize_or("shards", 2)?.max(1);
    let queue_cap = cfg.usize_or("queue-cap", 4096)?;
    let engine_choice = coordinator::EngineChoice::parse(
        &cfg.str_or("engine", "cpu-par"),
        cfg.usize_or("threads", pool::default_threads())?,
    )?;
    let job = TrainJob {
        dataset: key.clone(),
        scale,
        solver: coordinator::Solver::SpSvm,
        engine: coordinator::EngineChoice::CpuPar(pool::default_threads()),
        max_basis: 127,
        ..Default::default()
    };
    println!("training a quick SP-SVM model on {key} (scale {scale})...");
    let (tr, te, spec) = coordinator::load_data(&job)?;
    let engine = coordinator::build_engine(job.engine)?;
    let trainer = job.trainer(&spec, &engine);
    // binary datasets register an SvmModel, multiclass an OvO ensemble —
    // both serve through the same registry + sharded batchers
    let registry = if tr.is_multiclass() {
        let ovo = wu_svm::multiclass::OvoModel::train_with(&tr, &trainer, job.cache_mb)?;
        println!(
            "model: {} OvO pairs, {} expansion vectors",
            ovo.pairs.len(),
            ovo.total_vectors()
        );
        std::sync::Arc::new(serve::ModelRegistry::new(&ovo))
    } else {
        let r = trainer.train(&tr)?;
        println!("model: {} basis vectors", r.model.num_vectors());
        std::sync::Arc::new(serve::ModelRegistry::new(&r.model))
    };
    println!("compiled: {}", registry.current().describe());

    let serve_engine = coordinator::build_engine(engine_choice)?;
    let server = serve::Server::with_registry(
        registry,
        serve_engine,
        serve::ServeConfig { batch, shards, queue_cap, ..Default::default() },
    );
    let client = server.client();
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        let row = te.row(i % te.n).to_vec();
        let _ = client.predict(row)?;
    }
    let total = t0.elapsed();
    let stats = server.stop();
    println!(
        "served {} requests in {} ({:.0} req/s)",
        n_req,
        fmt_duration(total),
        n_req as f64 / total.as_secs_f64()
    );
    println!("{stats}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "wu-svm {} ({} threads available)",
        env!("CARGO_PKG_VERSION"),
        pool::default_threads()
    );
    println!("simd backend: {}", wu_svm::linalg::simd::active().name());
    println!("cpu features: {}", wu_svm::linalg::simd::detected_features());
    match coordinator::shared_runtime() {
        Ok(rt) => {
            println!("artifacts: tile_t = {}, s_cand = {}", rt.tile_t(), rt.s_cand());
            println!("d buckets: {:?}", rt.manifest().d_buckets());
            println!("b buckets: {:?}", rt.manifest().b_buckets());
            let total: usize = rt.manifest().by_op.values().map(|v| v.len()).sum();
            println!("{total} artifacts across {} ops", rt.manifest().by_op.len());
        }
        Err(e) => println!("xla runtime unavailable: {e} (cpu engines still work)"),
    }
    println!("datasets:");
    for s in paper::specs() {
        println!(
            "  {:<10} n = {:>7} d = {:>4} classes = {:>2} C = {:<8} gamma = {}",
            s.key, s.n_train, s.d, s.classes, s.c, s.gamma
        );
    }
    Ok(())
}
