//! Configuration: key=value files and CLI flags (the offline registry has
//! no clap/serde, so this is a small hand-rolled layer).
//!
//! Precedence: defaults < config file (`--config path`) < CLI flags
//! (`--key value` or `--key=value`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Ordered key -> value map with typed getters.
#[derive(Debug, Default, Clone)]
pub struct Config {
    map: BTreeMap<String, String>,
    /// positional (non-flag) arguments, in order
    pub positional: Vec<String>,
}

impl Config {
    /// Parse a config file: `key = value` lines, '#' comments.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let mut cfg = Config::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(cfg)
    }

    /// Parse CLI args (after the subcommand). `--key value`, `--key=value`
    /// and bare `--flag` (-> "true") forms. `--config FILE` merges the
    /// file first (CLI wins).
    pub fn from_args(args: &[String]) -> Result<Config> {
        let mut cli = Config::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    cli.map.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    cli.map.insert(stripped.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    cli.map.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                cli.positional.push(a.clone());
            }
            i += 1;
        }
        if let Some(path) = cli.map.get("config").cloned() {
            let mut merged = Config::from_file(Path::new(&path))?;
            merged.map.extend(cli.map);
            merged.positional = cli.positional;
            return Ok(merged);
        }
        Ok(cli)
    }

    pub fn set(&mut self, k: &str, v: &str) {
        self.map.insert(k.to_string(), v.to_string());
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.map.get(k).map(|s| s.as_str())
    }

    pub fn str_or(&self, k: &str, default: &str) -> String {
        self.get(k).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, k: &str, default: f64) -> Result<f64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{k} = '{v}' is not a number")),
        }
    }

    pub fn f32_or(&self, k: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(k, default as f64)? as f32)
    }

    pub fn usize_or(&self, k: &str, default: usize) -> Result<usize> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{k} = '{v}' is not an integer")),
        }
    }

    pub fn u64_or(&self, k: &str, default: u64) -> Result<u64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{k} = '{v}' is not an integer")),
        }
    }

    pub fn bool_or(&self, k: &str, default: bool) -> Result<bool> {
        match self.get(k) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{k} = '{v}' is not a bool"),
        }
    }

    /// Reject unknown keys (catch typos in experiment scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.map.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}; known: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parses_flag_forms() {
        // note: a bare `--flag` eats a following non--- token as its
        // value, so positionals go before flags (like the CLI subcommand).
        let c = Config::from_args(&args(&["pos", "--a", "1", "--b=x", "--flag"])).unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("x"));
        assert_eq!(c.bool_or("flag", false).unwrap(), true);
        assert_eq!(c.positional, vec!["pos"]);
    }

    #[test]
    fn typed_getters() {
        let c = Config::from_args(&args(&["--x", "2.5", "--n", "7"])).unwrap();
        assert_eq!(c.f64_or("x", 0.0).unwrap(), 2.5);
        assert_eq!(c.usize_or("n", 0).unwrap(), 7);
        assert_eq!(c.usize_or("missing", 9).unwrap(), 9);
        assert!(c.f64_or("n", 0.0).is_ok());
        assert!(Config::from_args(&args(&["--x", "abc"])).unwrap().f64_or("x", 0.0).is_err());
    }

    #[test]
    fn config_file_and_cli_precedence() {
        let dir = std::env::temp_dir().join("wu_svm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.cfg");
        std::fs::write(&p, "a = 1\nb = 2 # comment\n# whole line\n").unwrap();
        let c = Config::from_args(&args(&["--config", p.to_str().unwrap(), "--b", "3"])).unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("3"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn unknown_key_detection() {
        let c = Config::from_args(&args(&["--oops", "1"])).unwrap();
        assert!(c.check_known(&["fine"]).is_err());
        assert!(c.check_known(&["oops"]).is_ok());
    }

    #[test]
    fn malformed_file_rejected() {
        let dir = std::env::temp_dir().join("wu_svm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.cfg");
        std::fs::write(&p, "no equals sign\n").unwrap();
        assert!(Config::from_file(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
