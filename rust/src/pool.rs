//! Scoped thread pool — the *explicit* parallelism substrate.
//!
//! This is our stand-in for the paper's hand-written OpenMP/pthreads
//! parallelism: work is decomposed by hand into index ranges and dispatched
//! onto OS threads. The `CpuPar` compute engine (engine.rs) and the
//! threaded linalg routines build on it. Contrast with the `Xla` engine,
//! where the parallel schedule is owned by the library (the paper's
//! "implicit" approach).
//!
//! Built on `std::thread::scope` — the offline registry has no rayon.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Shared raw pointer for disjoint parallel writes. Callers must
/// guarantee each element is written by at most one task (as
/// `parallel_for` guarantees for per-index writes).
pub struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer. Method (not field) access so closures capture
    /// the whole `SendPtr` (which is Sync) rather than the raw pointer.
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Number of worker threads to use by default (live cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Run `f(i)` for every `i in 0..n`, dynamically load-balanced over
/// `threads` workers in chunks of `chunk`. `f` must be `Sync` (called
/// concurrently from many threads).
pub fn parallel_for<F>(threads: usize, n: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    let chunk = chunk.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(threads, n, 1, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

/// Split `0..n` into `parts` near-equal contiguous ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` on each contiguous sub-slice of `data`, one task per range,
/// in parallel. Used for disjoint in-place tile updates.
pub fn parallel_chunks_mut<T, F>(threads: usize, data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    let chunks: Vec<(usize, &mut [T])> =
        data.chunks_mut(chunk).enumerate().collect();
    let counter = AtomicUsize::new(0);
    let n = chunks.len();
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.max(1).min(n) {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let (idx, slice) = slots[i].lock().unwrap().take().unwrap();
                f(idx, slice);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, 1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_matches() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 100, 10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(4, 257, |i| i * i);
        assert_eq!(out, (0..257).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn split_ranges_cover_exactly() {
        for &(n, p) in &[(10usize, 3usize), (0, 4), (7, 7), (100, 1), (5, 9)] {
            let rs = split_ranges(n, p);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
        }
    }

    #[test]
    fn parallel_chunks_mut_writes_disjoint() {
        let mut data = vec![0usize; 1000];
        parallel_chunks_mut(8, &mut data, 13, |idx, slice| {
            for (k, v) in slice.iter_mut().enumerate() {
                *v = idx * 13 + k;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn zero_work_is_fine() {
        parallel_for(4, 0, 1, |_| panic!("should not run"));
        let out: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(out.is_empty());
    }
}
