//! Persistent scoped thread pool — the *explicit* parallelism substrate.
//!
//! This is our stand-in for the paper's hand-written OpenMP/pthreads
//! parallelism: work is decomposed by hand into index ranges and dispatched
//! onto OS threads. The `CpuPar` compute engine (engine.rs) and the
//! threaded linalg routines build on it. Contrast with the `Xla` engine,
//! where the parallel schedule is owned by the library (the paper's
//! "implicit" approach).
//!
//! The pool is a lazily started set of long-lived workers (the offline
//! registry has no rayon). Earlier revisions spawned scoped threads per
//! call; that is fine for coarse work (kernel tiles) but the SMO hot loop
//! issues two O(n) scans *per iteration*, where a ~100µs spawn dwarfs the
//! scan itself. Submissions are erased closures drained cooperatively: the
//! submitter always participates (so nested submissions from inside pool
//! workers can never deadlock — every job can finish on its submitter
//! alone), idle workers join up to the submitter's thread budget, and the
//! submitter blocks until every joined worker has left the closure.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Shared raw pointer for disjoint parallel writes. Callers must
/// guarantee each element is written by at most one task (as
/// `parallel_for` guarantees for per-index writes).
pub struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer. Method (not field) access so closures capture
    /// the whole `SendPtr` (which is Sync) rather than the raw pointer.
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Number of worker threads to use by default: the `POOL_THREADS` env
/// var when set (how CI pins both extremes of the thread axis to
/// exercise the bit-identical-across-thread-counts contracts), else the
/// live core count, capped. Read once — the pool sizes itself off the
/// first call.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("POOL_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n.min(32);
                }
            }
            eprintln!("warning: ignoring invalid POOL_THREADS='{v}'");
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(32)
    })
}

/// Erased borrow of a submitter's drain closure. Only dereferenced while
/// the owning [`Pool::run`] call keeps the closure alive (see the
/// completion protocol in `run`).
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn() + Sync));
unsafe impl Send for JobPtr {}
unsafe impl Sync for JobPtr {}

/// One in-flight submission.
struct JobEntry {
    job: JobPtr,
    /// Helpers allowed to join (the submitter drains unconditionally).
    max_helpers: usize,
    /// Helpers currently inside the closure (guarded by `Pool::state`).
    helpers_in: usize,
    /// Set once the chunk source is drained; no new helper joins after.
    exhausted: Arc<AtomicBool>,
    /// A helper's drain panicked; rethrown by the submitter.
    panicked: bool,
    id: u64,
}

#[derive(Default)]
struct PoolState {
    jobs: Vec<JobEntry>,
    next_id: u64,
}

/// Long-lived worker pool; one global instance, started on first use.
struct Pool {
    state: Mutex<PoolState>,
    /// Wakes workers when a job is pushed.
    work_cv: Condvar,
    /// Wakes submitters when a helper leaves a job.
    done_cv: Condvar,
}

impl Pool {
    fn global() -> &'static Pool {
        static POOL: OnceLock<&'static Pool> = OnceLock::new();
        *POOL.get_or_init(|| {
            crate::linalg::simd::log_once();
            let pool: &'static Pool = Box::leak(Box::new(Pool {
                state: Mutex::new(PoolState::default()),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            }));
            let workers = default_threads().saturating_sub(1).max(1);
            for _ in 0..workers {
                std::thread::Builder::new()
                    .name("wu-svm-pool".into())
                    .spawn(move || pool.worker_loop())
                    .expect("spawn pool worker");
            }
            pool
        })
    }

    fn worker_loop(&self) {
        let mut guard = self.state.lock().unwrap();
        loop {
            let pick = guard.jobs.iter_mut().find(|j| {
                !j.exhausted.load(Ordering::Relaxed) && j.helpers_in < j.max_helpers
            });
            if let Some(entry) = pick {
                entry.helpers_in += 1;
                crate::trace::count(crate::trace::Counter::PoolHelperJoins, 1);
                let id = entry.id;
                let job = entry.job;
                drop(guard);
                // SAFETY: the submitter of `id` blocks in `run` until
                // `helpers_in` returns to 0, so the closure outlives this
                // call (we registered under the lock before releasing it).
                let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*job.0)() }));
                guard = self.state.lock().unwrap();
                if let Some(entry) = guard.jobs.iter_mut().find(|j| j.id == id) {
                    entry.helpers_in -= 1;
                    if result.is_err() {
                        entry.panicked = true;
                    }
                }
                self.done_cv.notify_all();
            } else {
                guard = self.work_cv.wait(guard).unwrap();
            }
        }
    }

    /// Run `job` to completion: the calling thread drains it, up to
    /// `max_helpers` idle workers join, and the call returns only after
    /// every participant has left the closure. `exhausted` must be set by
    /// the closure once its work source is empty (participants that enter
    /// afterwards return immediately). Panics from helpers are rethrown.
    fn run(&self, job: &(dyn Fn() + Sync), max_helpers: usize, exhausted: &Arc<AtomicBool>) {
        let id = {
            let mut guard = self.state.lock().unwrap();
            let id = guard.next_id;
            guard.next_id += 1;
            guard.jobs.push(JobEntry {
                job: JobPtr(job as *const _),
                max_helpers,
                helpers_in: 0,
                exhausted: exhausted.clone(),
                panicked: false,
                id,
            });
            id
        };
        crate::trace::count(crate::trace::Counter::PoolJobs, 1);
        self.work_cv.notify_all();
        // The completion guard runs even if the submitter's own drain
        // panics: it bars new helpers, waits out the ones inside the
        // closure (which must stay borrowable until they leave), and
        // unregisters the job.
        struct Completion<'a> {
            pool: &'a Pool,
            exhausted: &'a AtomicBool,
            id: u64,
        }
        impl Drop for Completion<'_> {
            fn drop(&mut self) {
                self.exhausted.store(true, Ordering::Relaxed);
                let mut guard = self.pool.state.lock().unwrap();
                loop {
                    let pos = guard
                        .jobs
                        .iter()
                        .position(|j| j.id == self.id)
                        .expect("job registered");
                    if guard.jobs[pos].helpers_in == 0 {
                        guard.jobs.remove(pos);
                        break;
                    }
                    guard = self.pool.done_cv.wait(guard).unwrap();
                }
            }
        }
        let completion = Completion { pool: self, exhausted: exhausted.as_ref(), id };
        job();
        // Wait for helpers now so the panic flag is final, then rethrow.
        let panicked = {
            let mut guard = self.state.lock().unwrap();
            loop {
                let pos = guard
                    .jobs
                    .iter()
                    .position(|j| j.id == completion.id)
                    .expect("job registered");
                if guard.jobs[pos].helpers_in == 0 {
                    break guard.jobs[pos].panicked;
                }
                guard = self.done_cv.wait(guard).unwrap();
            }
        };
        if panicked {
            // completion's Drop unregisters before the unwind leaves `run`
            panic!("wu-svm pool helper panicked");
        }
        drop(completion);
    }
}

/// Run `f(i)` for every `i in 0..n`, dynamically load-balanced over
/// `threads` participants in chunks of `chunk`. `f` must be `Sync`
/// (called concurrently from many threads). `threads == 1` runs inline
/// with no synchronization at all.
pub fn parallel_for<F>(threads: usize, n: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = chunk.max(1);
    let counter = AtomicUsize::new(0);
    let exhausted = Arc::new(AtomicBool::new(false));
    let drain = {
        let exhausted = exhausted.clone();
        move || {
            loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            }
            exhausted.store(true, Ordering::Relaxed);
        }
    };
    Pool::global().run(&drain, threads - 1, &exhausted);
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
/// Each result is written directly into its (uninitialized) output slot —
/// the same disjoint-write guarantee `parallel_for` documents — so `T`
/// needs neither `Default` nor `Clone` and no per-element lock is taken.
pub fn parallel_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<T> = Vec::with_capacity(n);
    let done: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    // If `f` panics, the output Vec unwinds with len 0; this guard drops
    // the elements that were already written so they are not leaked.
    // `Pool::run` only propagates a panic after every participant has
    // left the closure, so the flags are final when the guard runs.
    struct DropInitialized<'a, T> {
        ptr: *mut T,
        done: &'a [AtomicBool],
        armed: bool,
    }
    impl<T> Drop for DropInitialized<'_, T> {
        fn drop(&mut self) {
            if !self.armed {
                return;
            }
            for (i, d) in self.done.iter().enumerate() {
                if d.load(Ordering::Acquire) {
                    // SAFETY: slot i was fully written and is not owned by
                    // the Vec (its len is still 0).
                    unsafe { std::ptr::drop_in_place(self.ptr.add(i)) };
                }
            }
        }
    }
    let mut guard = DropInitialized { ptr: out.as_mut_ptr(), done: &done, armed: true };
    {
        let out_ptr = SendPtr::new(out.as_mut_ptr());
        let done_ref = &done;
        parallel_for(threads, n, 1, |i| {
            // SAFETY: slot i of the reserved capacity is written by exactly
            // one task (parallel_for visits each index once).
            unsafe { out_ptr.get().add(i).write(f(i)) };
            done_ref[i].store(true, Ordering::Release);
        });
    }
    guard.armed = false;
    // SAFETY: all n slots were initialized above (parallel_for covers
    // every index; a panic in `f` propagates before reaching here).
    unsafe { out.set_len(n) };
    out
}

/// Deterministic chunked parallel reduction over `0..n`: `map` folds each
/// contiguous chunk `[k*chunk, (k+1)*chunk)` into a partial accumulator,
/// and partials are combined **in chunk order** with `reduce`. The result
/// is therefore identical for every thread count (including 1), which is
/// what lets `cpu-par(k)` SMO reproduce `cpu-seq` working-set choices
/// bit for bit. Returns `None` when `n == 0`.
pub fn parallel_reduce<A, M, R>(
    threads: usize,
    n: usize,
    chunk: usize,
    map: M,
    reduce: R,
) -> Option<A>
where
    A: Send,
    M: Fn(std::ops::Range<usize>) -> A + Sync,
    R: Fn(A, A) -> A,
{
    if n == 0 {
        return None;
    }
    let chunk = chunk.max(1);
    let n_chunks = (n + chunk - 1) / chunk;
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        let mut acc = map(0..chunk.min(n));
        let mut start = chunk;
        while start < n {
            let end = (start + chunk).min(n);
            acc = reduce(acc, map(start..end));
            start = end;
        }
        return Some(acc);
    }
    let mut partials: Vec<Option<A>> = (0..n_chunks).map(|_| None).collect();
    {
        let out_ptr = SendPtr::new(partials.as_mut_ptr());
        parallel_for(threads, n_chunks, 1, |c| {
            let start = c * chunk;
            let end = (start + chunk).min(n);
            // SAFETY: partial slot c is written by exactly one task, and
            // overwriting the prefilled `None` drops nothing.
            unsafe { out_ptr.get().add(c).write(Some(map(start..end))) };
        });
    }
    partials
        .into_iter()
        .map(|p| p.expect("every chunk produced a partial"))
        .reduce(reduce)
}

/// Split `0..n` into `parts` near-equal contiguous ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        if len == 0 {
            continue;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` on each contiguous sub-slice of `data`, one task per range,
/// in parallel. Used for disjoint in-place tile updates.
pub fn parallel_chunks_mut<T, F>(threads: usize, data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    let n = data.len();
    let n_chunks = (n + chunk - 1) / chunk;
    let base = SendPtr::new(data.as_mut_ptr());
    parallel_for(threads, n_chunks, 1, |idx| {
        let start = idx * chunk;
        let len = chunk.min(n - start);
        // SAFETY: chunk idx covers [start, start+len), disjoint from every
        // other chunk, and each idx is visited exactly once.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.get().add(start), len) };
        f(idx, slice);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, 1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_single_thread_matches() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 100, 10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(4, 257, |i| i * i);
        assert_eq!(out, (0..257).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_handles_non_default_non_clone_types() {
        // neither Default nor Clone: a boxed string built per index
        struct Opaque(Box<str>, usize);
        let out = parallel_map(8, 100, |i| Opaque(format!("v{i}").into(), i));
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.1, i);
            assert_eq!(&*v.0, format!("v{i}").as_str());
        }
    }

    #[test]
    fn parallel_reduce_sums_like_sequential() {
        let expect: u64 = (0..10_000u64).sum();
        for &threads in &[1usize, 2, 7] {
            let got = parallel_reduce(
                threads,
                10_000,
                333,
                |r| r.map(|i| i as u64).sum::<u64>(),
                |a, b| a + b,
            )
            .unwrap();
            assert_eq!(got, expect, "threads {threads}");
        }
    }

    #[test]
    fn parallel_reduce_deterministic_argmax_across_thread_counts() {
        // values with deliberate ties: the winner must not depend on the
        // thread count, only on the (chunk-ordered) reduction
        let vals: Vec<i64> = (0..5000).map(|i| (i * 37) % 101).collect();
        let argmax = |threads: usize| {
            parallel_reduce(
                threads,
                vals.len(),
                256,
                |r| {
                    let mut best = (i64::MIN, usize::MAX);
                    for i in r {
                        if vals[i] >= best.0 {
                            best = (vals[i], i);
                        }
                    }
                    best
                },
                |a, b| if b.0 >= a.0 { b } else { a },
            )
            .unwrap()
        };
        let base = argmax(1);
        for threads in [2usize, 4, 16] {
            assert_eq!(argmax(threads), base, "threads {threads}");
        }
    }

    #[test]
    fn parallel_reduce_empty_is_none() {
        assert!(parallel_reduce(4, 0, 8, |_| 0u32, |a, b| a + b).is_none());
    }

    #[test]
    fn nested_submissions_do_not_deadlock() {
        // outer parallel_map items each submit their own inner reductions,
        // mirroring OvO pair workers running parallel SMO scans
        let sums = parallel_map(4, 8, |outer| {
            parallel_reduce(
                4,
                1000,
                64,
                |r| r.map(|i| (i + outer) as u64).sum::<u64>(),
                |a, b| a + b,
            )
            .unwrap()
        });
        for (outer, s) in sums.iter().enumerate() {
            let expect: u64 = (0..1000).map(|i| (i + outer) as u64).sum();
            assert_eq!(*s, expect);
        }
    }

    #[test]
    fn many_small_jobs_reuse_the_pool() {
        // regression guard for per-call spawn overhead: thousands of tiny
        // submissions must complete promptly
        let t0 = std::time::Instant::now();
        let total = AtomicU64::new(0);
        for _ in 0..2000 {
            parallel_for(4, 64, 8, |i| {
                total.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 2000 * (0..64u64).sum::<u64>());
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(20),
            "pool submissions far too slow: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn split_ranges_cover_exactly() {
        for &(n, p) in &[(10usize, 3usize), (0, 4), (7, 7), (100, 1), (5, 9)] {
            let rs = split_ranges(n, p);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
        }
    }

    #[test]
    fn parallel_chunks_mut_writes_disjoint() {
        let mut data = vec![0usize; 1000];
        parallel_chunks_mut(8, &mut data, 13, |idx, slice| {
            for (k, v) in slice.iter_mut().enumerate() {
                *v = idx * 13 + k;
            }
        });
        assert_eq!(data, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn zero_work_is_fine() {
        parallel_for(4, 0, 1, |_| panic!("should not run"));
        let out: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(out.is_empty());
    }
}
