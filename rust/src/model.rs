//! Trained SVM models: prediction and (de)serialization.
//!
//! Both solver families produce the same functional form
//! `f(x) = sum_j coef_j k(x, v_j) + bias`; only how the expansion vectors
//! were chosen differs (support vectors for the dual solvers, basis
//! vectors for SP-SVM).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::kernel::KernelKind;
use crate::pool;

/// A trained binary SVM.
#[derive(Debug, Clone)]
pub struct SvmModel {
    pub kernel: KernelKind,
    /// Expansion vectors, row-major [m x d].
    pub vectors: Vec<f32>,
    pub d: usize,
    /// Expansion coefficients (alpha_j y_j for dual solvers, beta_j for
    /// SP-SVM), length m.
    pub coef: Vec<f32>,
    pub bias: f32,
    /// Which solver produced this model (report metadata).
    pub solver: String,
}

impl SvmModel {
    pub fn num_vectors(&self) -> usize {
        self.coef.len()
    }

    /// Margin for a single input.
    pub fn decision(&self, x: &[f32]) -> f32 {
        assert_eq!(x.len(), self.d);
        let mut f = self.bias as f64;
        for (j, &c) in self.coef.iter().enumerate() {
            if c != 0.0 {
                f += (c * self.kernel.eval(x, &self.vectors[j * self.d..(j + 1) * self.d])) as f64;
            }
        }
        f as f32
    }

    /// Margins for every row of a dataset (threaded). Sparse designs
    /// densify row chunks into a per-task buffer; row order is fixed, so
    /// the output is identical for every thread count either way.
    pub fn decision_batch(&self, ds: &Dataset, threads: usize) -> Vec<f32> {
        assert_eq!(ds.d, self.d);
        if ds.is_sparse() {
            const CHUNK: usize = 64;
            let mut out = vec![0.0f32; ds.n];
            pool::parallel_chunks_mut(threads, &mut out, CHUNK, |c, slice| {
                let mut buf = vec![0.0f32; self.d];
                for (off, slot) in slice.iter_mut().enumerate() {
                    ds.row_into(c * CHUNK + off, &mut buf);
                    *slot = self.decision(&buf);
                }
            });
            return out;
        }
        pool::parallel_map(threads, ds.n, |i| self.decision(ds.row(i)))
    }

    /// {-1,+1} predictions.
    pub fn predict_batch(&self, ds: &Dataset, threads: usize) -> Vec<f32> {
        self.decision_batch(ds, threads)
            .into_iter()
            .map(|f| if f > 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Save in a simple self-describing text format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        self.write_to(&mut w)
    }

    /// Write the v1 text format to any writer. `save` wraps this; the OvO
    /// container format ([`crate::multiclass::OvoModel::save`]) embeds one
    /// block per pair model.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        writeln!(w, "wu-svm-model v1")?;
        writeln!(w, "solver {}", self.solver)?;
        match self.kernel {
            KernelKind::Rbf { gamma } => writeln!(w, "kernel rbf {gamma}")?,
            KernelKind::Linear => writeln!(w, "kernel linear")?,
            KernelKind::Poly { degree, gamma, coef0 } => {
                writeln!(w, "kernel poly {degree} {gamma} {coef0}")?
            }
        }
        writeln!(w, "bias {}", self.bias)?;
        writeln!(w, "dims {} {}", self.num_vectors(), self.d)?;
        for j in 0..self.num_vectors() {
            write!(w, "{}", self.coef[j])?;
            for v in &self.vectors[j * self.d..(j + 1) * self.d] {
                write!(w, " {v}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Load a model saved by [`SvmModel::save`].
    pub fn load(path: &Path) -> Result<SvmModel> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut lines = std::io::BufReader::new(f).lines();
        SvmModel::read_from(&mut lines)
    }

    /// Read one v1 model block from a line iterator, leaving the iterator
    /// positioned just past the model's last vector line (so container
    /// formats can read several blocks back to back).
    pub fn read_from<I>(lines: &mut I) -> Result<SvmModel>
    where
        I: Iterator<Item = std::io::Result<String>>,
    {
        let magic = next_line(lines)?;
        if magic.trim() != "wu-svm-model v1" {
            bail!("not a wu-svm model file");
        }
        let solver = next_line(lines)?
            .strip_prefix("solver ")
            .context("solver line")?
            .to_string();
        let kline = next_line(lines)?;
        let ktok: Vec<&str> = kline.split_ascii_whitespace().collect();
        let kernel = match ktok.as_slice() {
            ["kernel", "rbf", g] => KernelKind::Rbf { gamma: g.parse()? },
            ["kernel", "linear"] => KernelKind::Linear,
            ["kernel", "poly", d, g, c0] => KernelKind::Poly {
                degree: d.parse()?,
                gamma: g.parse()?,
                coef0: c0.parse()?,
            },
            _ => bail!("bad kernel line '{kline}'"),
        };
        let bias: f32 = next_line(lines)?
            .strip_prefix("bias ")
            .context("bias line")?
            .parse()?;
        let dline = next_line(lines)?;
        let dtok: Vec<&str> = dline.split_ascii_whitespace().collect();
        let (m, d): (usize, usize) = match dtok.as_slice() {
            ["dims", m, d] => (m.parse()?, d.parse()?),
            _ => bail!("bad dims line"),
        };
        let mut coef = Vec::with_capacity(m);
        let mut vectors = Vec::with_capacity(m * d);
        for _ in 0..m {
            let line = next_line(lines)?;
            let mut it = line.split_ascii_whitespace();
            coef.push(it.next().context("coef")?.parse()?);
            let mut cnt = 0;
            for tok in it {
                vectors.push(tok.parse()?);
                cnt += 1;
            }
            if cnt != d {
                bail!("expected {d} features, got {cnt}");
            }
        }
        Ok(SvmModel { kernel, vectors, d, coef, bias, solver })
    }
}

/// Pull the next line out of a model-file iterator or fail with a
/// uniform truncation error (shared by [`SvmModel::read_from`] and the
/// OvO container loader).
pub(crate) fn next_line<I>(lines: &mut I) -> Result<String>
where
    I: Iterator<Item = std::io::Result<String>>,
{
    lines
        .next()
        .transpose()?
        .context("unexpected end of model file")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SvmModel {
        SvmModel {
            kernel: KernelKind::Rbf { gamma: 0.5 },
            vectors: vec![0.0, 0.0, 1.0, 1.0],
            d: 2,
            coef: vec![1.0, -1.0],
            bias: 0.25,
            solver: "test".into(),
        }
    }

    #[test]
    fn decision_matches_manual() {
        let m = model();
        let x = [0.0f32, 0.0];
        let k2 = (-0.5f32 * 2.0).exp();
        let expect = 1.0 - k2 + 0.25;
        assert!((m.decision(&x) - expect).abs() < 1e-6);
    }

    #[test]
    fn batch_matches_single(){
        let m = model();
        let ds = Dataset::new_binary(
            "t",
            2,
            vec![0.1, 0.2, 0.9, 0.8, 0.5, 0.5],
            vec![1.0, -1.0, 1.0],
        );
        let batch = m.decision_batch(&ds, 3);
        for i in 0..3 {
            assert!((batch[i] - m.decision(ds.row(i))).abs() < 1e-6);
        }
        let preds = m.predict_batch(&ds, 2);
        for (p, f) in preds.iter().zip(&batch) {
            assert_eq!(*p, if *f > 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("wu_svm_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.model");
        let m = model();
        m.save(&path).unwrap();
        let back = SvmModel::load(&path).unwrap();
        assert_eq!(back.coef, m.coef);
        assert_eq!(back.vectors, m.vectors);
        assert_eq!(back.bias, m.bias);
        assert_eq!(back.solver, "test");
        assert_eq!(back.kernel, m.kernel);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_round_trip_leaves_iterator_past_block() {
        // two models written back to back into one buffer must read back
        // as two blocks (the OvO container relies on this positioning)
        let mut a = model();
        a.solver = "first".into();
        let mut b = model();
        b.solver = "second".into();
        b.bias = -0.5;
        let mut buf: Vec<u8> = Vec::new();
        a.write_to(&mut buf).unwrap();
        b.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text.lines().map(|l| Ok(l.to_string()));
        let ra = SvmModel::read_from(&mut lines).unwrap();
        let rb = SvmModel::read_from(&mut lines).unwrap();
        assert_eq!(ra.solver, "first");
        assert_eq!(rb.solver, "second");
        assert_eq!(rb.bias, -0.5);
        assert!(lines.next().is_none());
        assert!(SvmModel::read_from(&mut std::iter::empty()).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("wu_svm_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.model");
        std::fs::write(&path, "not a model\n").unwrap();
        assert!(SvmModel::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn zero_coef_vectors_skipped_consistently() {
        let mut m = model();
        m.coef[1] = 0.0;
        let x = [0.3f32, 0.7];
        let k1 = m.kernel.eval(&x, &[0.0, 0.0]);
        assert!((m.decision(&x) - (k1 + 0.25)).abs() < 1e-6);
    }
}
