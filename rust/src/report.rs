//! Paper-style result tables (Table 1 rendering).

use std::time::Duration;

use crate::metrics::fmt_duration;

/// One Table-1 style row: a (dataset, architecture, method) measurement.
#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    pub arch: String,   // SC / MC / GPU-analog
    pub method: String, // LibSVM / SP-SVM / ...
    pub metric_name: String,
    /// Test error or (1-AUC), as a fraction.
    pub test_metric: f64,
    pub train_time: Duration,
    /// Speedup vs the dataset's single-core baseline (1.0 for baseline).
    pub speedup: f64,
    pub notes: String,
}

/// Render rows grouped by dataset in the paper's layout.
pub fn render_table(rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:<4} {:<18} {:>10} {:>14} {:>9}  {}\n",
        "dataset", "arch", "method", "metric", "train time", "speedup", "notes"
    ));
    out.push_str(&"-".repeat(96));
    out.push('\n');
    let mut last_ds = String::new();
    for r in rows {
        let ds = if r.dataset == last_ds { String::new() } else { r.dataset.clone() };
        last_ds = r.dataset.clone();
        out.push_str(&format!(
            "{:<12} {:<4} {:<18} {:>9.2}% {:>14} {:>8.1}x  {}\n",
            ds,
            r.arch,
            r.method,
            r.test_metric * 100.0,
            fmt_duration(r.train_time),
            r.speedup,
            r.notes
        ));
    }
    out
}

/// Compute speedups within each dataset against the named baseline method.
pub fn fill_speedups(rows: &mut [Row], baseline_method: &str, baseline_arch: &str) {
    let baselines: Vec<(String, f64)> = rows
        .iter()
        .filter(|r| r.method == baseline_method && r.arch == baseline_arch)
        .map(|r| (r.dataset.clone(), r.train_time.as_secs_f64()))
        .collect();
    for r in rows.iter_mut() {
        if let Some((_, base)) = baselines.iter().find(|(d, _)| *d == r.dataset) {
            let t = r.train_time.as_secs_f64();
            r.speedup = if t > 0.0 { base / t } else { 0.0 };
        }
    }
}

/// Render a simple two-column sweep (ablation figures).
pub fn render_sweep(
    title: &str,
    xlabel: &str,
    ylabels: &[&str],
    points: &[(f64, Vec<f64>)],
) -> String {
    let mut out = format!("== {title} ==\n{:<12}", xlabel);
    for y in ylabels {
        out.push_str(&format!(" {:>14}", y));
    }
    out.push('\n');
    for (x, ys) in points {
        out.push_str(&format!("{:<12.4}", x));
        for y in ys {
            out.push_str(&format!(" {:>14.5}", y));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(ds: &str, arch: &str, method: &str, secs: f64) -> Row {
        Row {
            dataset: ds.into(),
            arch: arch.into(),
            method: method.into(),
            metric_name: "err".into(),
            test_metric: 0.149,
            train_time: Duration::from_secs_f64(secs),
            speedup: 1.0,
            notes: String::new(),
        }
    }

    #[test]
    fn speedups_relative_to_baseline() {
        let mut rows = vec![
            row("adult", "SC", "libsvm", 60.0),
            row("adult", "MC", "libsvm", 10.0),
            row("adult", "GPU", "spsvm", 5.0),
        ];
        fill_speedups(&mut rows, "libsvm", "SC");
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        assert!((rows[1].speedup - 6.0).abs() < 1e-9);
        assert!((rows[2].speedup - 12.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = vec![row("adult", "SC", "libsvm", 60.0), row("adult", "MC", "libsvm", 10.0)];
        let t = render_table(&rows);
        assert!(t.contains("libsvm"));
        assert!(t.contains("14.90%"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn sweep_renders() {
        let s = render_sweep("basis", "|J|", &["err", "time"], &[(8.0, vec![0.2, 1.0])]);
        assert!(s.contains("|J|") && s.contains("0.2"));
    }
}
