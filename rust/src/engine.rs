//! ComputeEngine — the paper's independent variable, as a type.
//!
//! Every solver expresses its heavy math as the five tile ops below. Who
//! executes them is the *explicit vs implicit* axis of the study
//! (DESIGN.md §2):
//!
//! * [`EngineKind::CpuSeq`] — the blocked-GEMM substrate
//!   (`linalg::gemm`, DESIGN.md §GEMM) on one thread. The paper's
//!   single-core LibSVM baseline substrate.
//! * [`EngineKind::CpuPar`] — the same substrate hand-decomposed over
//!   our scoped thread pool (bit-identical to `cpu-seq` by the
//!   substrate's determinism contract). The paper's *explicit*
//!   parallelization (LibSVM+OpenMP, hand-tuned CUDA) — except the tile
//!   ops now behave like the optimized BLAS the implicit methods lean
//!   on, which is the comparison the paper actually ran.
//! * [`EngineKind::Xla`] — one call per op into an AOT-compiled XLA
//!   executable (from the JAX/Pallas build path). The paper's *implicit*
//!   parallelization: the algorithm is a few large dense ops and the
//!   library owns the schedule (MKL / CUBLAS / Jacket).
//!
//! All three produce the same numbers (tested below), so Table-1 style
//! comparisons measure the parallelization strategy, not the algorithm.

use std::sync::Arc;

use anyhow::Result;

use crate::linalg::{self, Matrix};
use crate::pool;
use crate::pool::SendPtr;
use crate::runtime::XlaRuntime;

/// Engine flavor (see module docs).
#[derive(Clone)]
pub enum EngineKind {
    CpuSeq,
    CpuPar { threads: usize },
    Xla { runtime: Arc<XlaRuntime> },
}

/// Output of `tile_stats`.
#[derive(Debug, Clone)]
pub struct TileStats {
    pub grad: Vec<f32>,
    pub hess: Vec<f32>, // b x b row-major
    pub loss: f32,
    pub nerr: f32,
}

/// A compute engine bound to fixed tile/bucket shapes.
#[derive(Clone)]
pub struct Engine {
    pub kind: EngineKind,
}

impl Engine {
    pub fn cpu_seq() -> Engine {
        crate::linalg::simd::log_once();
        Engine { kind: EngineKind::CpuSeq }
    }

    pub fn cpu_par(threads: usize) -> Engine {
        crate::linalg::simd::log_once();
        Engine { kind: EngineKind::CpuPar { threads: threads.max(1) } }
    }

    pub fn xla(runtime: Arc<XlaRuntime>) -> Engine {
        Engine { kind: EngineKind::Xla { runtime } }
    }

    pub fn name(&self) -> String {
        match &self.kind {
            EngineKind::CpuSeq => "cpu-seq".into(),
            EngineKind::CpuPar { threads } => format!("cpu-par({threads})"),
            EngineKind::Xla { .. } => "xla".into(),
        }
    }

    pub fn is_xla(&self) -> bool {
        matches!(self.kind, EngineKind::Xla { .. })
    }

    /// Worker threads this engine hand-parallelizes over (1 for `cpu-seq`
    /// and `xla` — the xla library owns its own parallel schedule). The
    /// solvers use this to size their explicit WSS/gradient parallelism.
    pub fn threads(&self) -> usize {
        match &self.kind {
            EngineKind::CpuSeq => 1,
            EngineKind::CpuPar { threads } => *threads,
            EngineKind::Xla { .. } => 1,
        }
    }

    /// K[t, b] = exp(-gamma ||x_i - xb_j||^2).
    pub fn rbf_block(
        &self,
        x: &[f32],
        t: usize,
        d: usize,
        xb: &[f32],
        b: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        assert_eq!(x.len(), t * d);
        assert_eq!(xb.len(), b * d);
        if let EngineKind::Xla { runtime } = &self.kind {
            let entry = runtime.lookup("kernel_block", t, d, b, 0)?;
            assert_eq!((entry.t, entry.d, entry.b), (t, d, b),
                "xla engine requires exact bucket shapes (got t={t} d={d} b={b})");
            let out = runtime.execute(
                &entry,
                &[
                    (&[t as i64, d as i64], x),
                    (&[b as i64, d as i64], xb),
                    (&[1], &[gamma]),
                ],
            )?;
            return Ok(out.into_iter().next().unwrap());
        }
        // CPU path — the same expansion as the Pallas kernel, in the
        // paper's optimized-BLAS formulation: norms + one blocked GEMM +
        // fused exp row pass (`gemm::rbf_blocked`, shared with
        // `kernel::kernel_block`).
        let mut k = vec![0.0f32; t * b];
        linalg::gemm::rbf_blocked(self.threads(), x, t, xb, b, d, gamma, &mut k);
        Ok(k)
    }

    /// [`Engine::rbf_block`] over a CSR row range: `K[t x b]` for rows
    /// `[row0, row0 + t)` of a sparse design against a dense `b x d`
    /// block (rows past `a.rows` are all-zero tile padding). CPU engines
    /// route through the row-blocked SpMM (`linalg::spmm`, deterministic
    /// for every thread count, exact RBF diagonals — DESIGN.md §SPARSE).
    /// The xla engine has no sparse artifact: it densifies the row range
    /// and runs the standard kernel (same numbers, dense memory cost).
    pub fn rbf_block_csr(
        &self,
        a: &crate::data::CsrMatrix,
        row0: usize,
        t: usize,
        xb: &[f32],
        b: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let d = a.cols;
        assert_eq!(xb.len(), b * d);
        if self.is_xla() {
            let mut dense = vec![0.0f32; t * d];
            for r in 0..t {
                if row0 + r < a.rows {
                    a.densify_row_into(row0 + r, &mut dense[r * d..(r + 1) * d]);
                }
            }
            return self.rbf_block(&dense, t, d, xb, b, gamma);
        }
        let mut k = vec![0.0f32; t * b];
        linalg::spmm::rbf_csr_blocked(self.threads(), a, row0, t, xb, b, gamma, &mut k);
        Ok(k)
    }

    /// [`Engine::rbf_block`] with the b-side squared norms supplied by the
    /// caller — the serve-time entry point. A model registry computes
    /// `bnorms` once at registration (`gemm::sum_sq` order, so the
    /// exact-diagonal contract holds), and every batch then skips
    /// re-deriving them. The xla engine has no norms-supplied artifact and
    /// routes to the standard kernel (same numbers, norms recomputed on
    /// device).
    #[allow(clippy::too_many_arguments)]
    pub fn rbf_block_pre(
        &self,
        x: &[f32],
        t: usize,
        d: usize,
        xb: &[f32],
        b: usize,
        gamma: f32,
        bnorms: &[f32],
    ) -> Result<Vec<f32>> {
        assert_eq!(x.len(), t * d);
        assert_eq!(xb.len(), b * d);
        assert_eq!(bnorms.len(), b);
        if self.is_xla() {
            return self.rbf_block(x, t, d, xb, b, gamma);
        }
        let mut k = vec![0.0f32; t * b];
        linalg::gemm::rbf_blocked_pre(self.threads(), x, t, xb, b, d, gamma, bnorms, &mut k);
        Ok(k)
    }

    /// Fused squared-hinge statistics for one tile (see kernels/hinge.py).
    pub fn tile_stats(
        &self,
        k: &[f32],
        t: usize,
        b: usize,
        y: &[f32],
        m: &[f32],
        beta: &[f32],
        c: f32,
    ) -> Result<TileStats> {
        assert_eq!(k.len(), t * b);
        assert_eq!(y.len(), t);
        assert_eq!(m.len(), t);
        assert_eq!(beta.len(), b);
        if let EngineKind::Xla { runtime } = &self.kind {
            let entry = runtime.lookup("tile_stats", t, 0, b, 0)?;
            assert_eq!((entry.t, entry.b), (t, b));
            let out = runtime.execute(
                &entry,
                &[
                    (&[t as i64, b as i64], k),
                    (&[t as i64], y),
                    (&[t as i64], m),
                    (&[b as i64], beta),
                    (&[1], &[c]),
                ],
            )?;
            let mut it = out.into_iter();
            let grad = it.next().unwrap();
            let hess = it.next().unwrap();
            let loss = it.next().unwrap()[0];
            let nerr = it.next().unwrap()[0];
            return Ok(TileStats { grad, hess, loss, nerr });
        }
        // The tile stays a borrowed slice end to end: margins, gradient
        // and Gauss-Newton block all run on the slice-level substrate
        // entry points (no t x b copy into a Matrix).
        let threads = self.threads();
        let mut f = vec![0.0f32; t];
        linalg::gemm::gemv_blocked(threads, t, b, k, b, beta, &mut f);
        let mut w = vec![0.0f32; t]; // a_i y_i h_i
        let mut active = vec![0.0f32; t];
        let mut loss = 0.0f64;
        let mut nerr = 0.0f64;
        for i in 0..t {
            let hinge = (1.0 - y[i] * f[i]).max(0.0);
            let a = if hinge > 0.0 { m[i] } else { 0.0 };
            active[i] = a;
            w[i] = a * y[i] * hinge;
            loss += (c * a * hinge * hinge) as f64;
            if y[i] * f[i] <= 0.0 {
                nerr += m[i] as f64;
            }
        }
        let mut grad = vec![0.0f32; b];
        linalg::gemm::gemv_t_blocked(threads, t, b, k, b, &w, &mut grad);
        for g in grad.iter_mut() {
            *g *= -2.0 * c;
        }
        // hess = 2C · Kᵀ diag(active) K — the masked SYRK as one strided
        // packed-GEMM call (both operands are Kᵀ via strides).
        let mut hess = vec![0.0f32; b * b];
        linalg::gemm::gemm_nt_strided(
            threads, b, b, t, k, 1, b, k, 1, b, Some(&active), &mut hess, b,
        );
        for h in hess.iter_mut() {
            *h *= 2.0 * c;
        }
        Ok(TileStats { grad, hess, loss: loss as f32, nerr: nerr as f32 })
    }

    /// Masked damped CG solve (see model.py cg_solve for the convention).
    pub fn cg_solve(
        &self,
        h: &[f32],
        b: usize,
        g: &[f32],
        bmask: &[f32],
        reg: f32,
    ) -> Result<Vec<f32>> {
        assert_eq!(h.len(), b * b);
        assert_eq!(g.len(), b);
        assert_eq!(bmask.len(), b);
        if let EngineKind::Xla { runtime } = &self.kind {
            let entry = runtime.lookup("cg_solve", 0, 0, b, 0)?;
            assert_eq!(entry.b, b);
            let out = runtime.execute(
                &entry,
                &[
                    (&[b as i64, b as i64], h),
                    (&[b as i64], g),
                    (&[b as i64], bmask),
                    (&[1], &[reg]),
                ],
            )?;
            return Ok(out.into_iter().next().unwrap());
        }
        let hm = Matrix { rows: b, cols: b, data: h.to_vec() };
        // mirror the artifact: fixed cap 96, residual tolerance 1e-10
        let r = linalg::cg::solve_masked(self.threads(), &hm, g, bmask, reg, 96, 1e-10);
        Ok(r.x)
    }

    /// Candidate-scoring accumulators for one tile.
    pub fn score_tile(
        &self,
        kc: &[f32],
        t: usize,
        s: usize,
        r: &[f32],
        a: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(kc.len(), t * s);
        assert_eq!(r.len(), t);
        assert_eq!(a.len(), t);
        if let EngineKind::Xla { runtime } = &self.kind {
            let entry = runtime.lookup("score_tile", t, 0, 0, s)?;
            assert_eq!((entry.t, entry.s), (t, s));
            let out = runtime.execute(
                &entry,
                &[
                    (&[t as i64, s as i64], kc),
                    (&[t as i64], r),
                    (&[t as i64], a),
                ],
            )?;
            let mut it = out.into_iter();
            return Ok((it.next().unwrap(), it.next().unwrap()));
        }
        // One fused sweep over Kc: gc = Kᵀr and hc = (K ∘ K)ᵀa together —
        // no copied t x s squared matrix, one pass of memory traffic.
        // Column blocks run in parallel; row order is fixed, so every
        // thread count produces identical sums.
        let threads = self.threads();
        let mut gc = vec![0.0f32; s];
        let mut hc = vec![0.0f32; s];
        const CB: usize = 256;
        let nblk = (s + CB - 1) / CB;
        let gc_ptr = SendPtr::new(gc.as_mut_ptr());
        let hc_ptr = SendPtr::new(hc.as_mut_ptr());
        pool::parallel_for(threads, nblk, 1, |bidx| {
            let c0 = bidx * CB;
            let c1 = (c0 + CB).min(s);
            let w = c1 - c0;
            // SAFETY: column blocks are disjoint across iterations.
            let g = unsafe { std::slice::from_raw_parts_mut(gc_ptr.get().add(c0), w) };
            let h = unsafe { std::slice::from_raw_parts_mut(hc_ptr.get().add(c0), w) };
            for i in 0..t {
                let (ri, ai) = (r[i], a[i]);
                if ri == 0.0 && ai == 0.0 {
                    continue;
                }
                let row = &kc[i * s + c0..i * s + c1];
                for j in 0..w {
                    let v = row[j];
                    g[j] += ri * v;
                    h[j] += ai * v * v;
                }
            }
        });
        Ok((gc, hc))
    }

    /// Margins f[t] = K beta.
    pub fn predict_block(&self, k: &[f32], t: usize, b: usize, beta: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(k.len(), t * b);
        assert_eq!(beta.len(), b);
        if let EngineKind::Xla { runtime } = &self.kind {
            let entry = runtime.lookup("predict_block", t, 0, b, 0)?;
            assert_eq!((entry.t, entry.b), (t, b));
            let out = runtime.execute(
                &entry,
                &[(&[t as i64, b as i64], k), (&[b as i64], beta)],
            )?;
            return Ok(out.into_iter().next().unwrap());
        }
        let mut f = vec![0.0f32; t];
        linalg::gemm::gemv_blocked(self.threads(), t, b, k, b, beta, &mut f);
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform_f32()).collect()
    }

    fn engines() -> Vec<Engine> {
        let mut v = vec![Engine::cpu_seq(), Engine::cpu_par(4)];
        if let Ok(rt) = XlaRuntime::load(&crate::runtime::default_artifacts_dir()) {
            v.push(Engine::xla(Arc::new(rt)));
        } else {
            eprintln!("note: xla engine skipped (no artifacts)");
        }
        v
    }

    #[test]
    fn rbf_block_agrees_across_engines() {
        let mut rng = Rng::new(1);
        let (t, d, b) = (1024, 64, 64); // a real bucket so xla can join
        let x = rand_vec(&mut rng, t * d);
        let xb = rand_vec(&mut rng, b * d);
        let base = Engine::cpu_seq().rbf_block(&x, t, d, &xb, b, 0.4).unwrap();
        for e in engines() {
            let k = e.rbf_block(&x, t, d, &xb, b, 0.4).unwrap();
            let max: f32 = k
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(max < 1e-4, "{} differs by {max}", e.name());
        }
    }

    #[test]
    fn rbf_block_pre_matches_rbf_block() {
        let mut rng = Rng::new(11);
        let (t, d, b) = (37, 19, 23); // deliberately non-bucket shapes
        let x = rand_vec(&mut rng, t * d);
        let xb = rand_vec(&mut rng, b * d);
        let bnorms: Vec<f32> =
            (0..b).map(|j| crate::linalg::gemm::sum_sq(&xb[j * d..(j + 1) * d])).collect();
        for e in [Engine::cpu_seq(), Engine::cpu_par(4)] {
            let base = e.rbf_block(&x, t, d, &xb, b, 0.8).unwrap();
            let pre = e.rbf_block_pre(&x, t, d, &xb, b, 0.8, &bnorms).unwrap();
            assert_eq!(base.len(), pre.len());
            for (a, w) in pre.iter().zip(&base) {
                assert_eq!(a.to_bits(), w.to_bits(), "{}", e.name());
            }
        }
    }

    #[test]
    fn tile_stats_agree_across_engines() {
        let mut rng = Rng::new(2);
        let (t, b) = (1024, 64);
        let k = rand_vec(&mut rng, t * b);
        let y: Vec<f32> = (0..t).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m: Vec<f32> = (0..t).map(|_| if rng.bernoulli(0.8) { 1.0 } else { 0.0 }).collect();
        let beta: Vec<f32> = (0..b).map(|_| rng.gaussian_f32() * 0.1).collect();
        let base = Engine::cpu_seq().tile_stats(&k, t, b, &y, &m, &beta, 2.0).unwrap();
        for e in engines() {
            let s = e.tile_stats(&k, t, b, &y, &m, &beta, 2.0).unwrap();
            assert!((s.loss - base.loss).abs() / base.loss.max(1.0) < 1e-3,
                "{} loss {} vs {}", e.name(), s.loss, base.loss);
            assert_eq!(s.nerr, base.nerr, "{}", e.name());
            let gmax: f32 =
                s.grad.iter().zip(&base.grad).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(gmax < 2e-2, "{} grad diff {gmax}", e.name());
            let hmax: f32 =
                s.hess.iter().zip(&base.hess).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(hmax < 0.5, "{} hess diff {hmax}", e.name());
        }
    }

    #[test]
    fn cg_solve_agrees_across_engines() {
        let mut rng = Rng::new(3);
        let b = 64;
        // SPD: A A^T / b + I
        let a = rand_vec(&mut rng, b * b);
        let am = Matrix { rows: b, cols: b, data: a };
        let mut h = Matrix::zeros(b, b);
        linalg::gemm_nt(1, &am, &am, &mut h);
        for i in 0..b {
            h.set(i, i, h.at(i, i) + b as f32);
        }
        let g: Vec<f32> = (0..b).map(|_| rng.gaussian_f32()).collect();
        let mut bmask = vec![1.0f32; b];
        for i in 50..b {
            bmask[i] = 0.0;
        }
        let base = Engine::cpu_seq().cg_solve(&h.data, b, &g, &bmask, 1e-3).unwrap();
        for e in engines() {
            let x = e.cg_solve(&h.data, b, &g, &bmask, 1e-3).unwrap();
            for i in 0..b {
                assert!((x[i] - base[i]).abs() < 1e-3,
                    "{} x[{i}] = {} vs {}", e.name(), x[i], base[i]);
            }
        }
    }

    #[test]
    fn score_and_predict_agree_across_engines() {
        let mut rng = Rng::new(4);
        let (t, s, b) = (1024, 64, 128);
        let kc = rand_vec(&mut rng, t * s);
        let r: Vec<f32> = (0..t).map(|_| rng.gaussian_f32()).collect();
        let a: Vec<f32> = (0..t).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let k = rand_vec(&mut rng, t * b);
        let beta: Vec<f32> = (0..b).map(|_| rng.gaussian_f32()).collect();
        let (gc0, hc0) = Engine::cpu_seq().score_tile(&kc, t, s, &r, &a).unwrap();
        let f0 = Engine::cpu_seq().predict_block(&k, t, b, &beta).unwrap();
        for e in engines() {
            let (gc, hc) = e.score_tile(&kc, t, s, &r, &a).unwrap();
            let (dg, dh): (f32, f32) = (
                gc.iter().zip(&gc0).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max),
                hc.iter().zip(&hc0).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max),
            );
            assert!(dg < 1e-2 && dh < 1e-2, "{}: {dg} {dh}", e.name());
            let f = e.predict_block(&k, t, b, &beta).unwrap();
            let df: f32 = f.iter().zip(&f0).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(df < 1e-2, "{}: {df}", e.name());
        }
    }

    #[test]
    fn rbf_block_csr_matches_dense_bitwise() {
        use crate::data::CsrMatrix;
        let mut rng = Rng::new(12);
        let (t, d, b) = (40, 300, 6);
        let x: Vec<f32> = (0..t * d)
            .map(|_| if rng.bernoulli(0.1) { rng.uniform_f32() } else { 0.0 })
            .collect();
        let xb = rand_vec(&mut rng, b * d);
        let csr = CsrMatrix::from_dense(t, d, &x);
        for e in [Engine::cpu_seq(), Engine::cpu_par(4)] {
            let dense = e.rbf_block(&x, t, d, &xb, b, 0.6).unwrap();
            let sparse = e.rbf_block_csr(&csr, 0, t, &xb, b, 0.6).unwrap();
            for (a, w) in sparse.iter().zip(&dense) {
                assert_eq!(a.to_bits(), w.to_bits(), "{}", e.name());
            }
        }
        // padded row range past a.rows scores like zero rows
        let pad = Engine::cpu_seq().rbf_block_csr(&csr, t - 2, 4, &xb, b, 0.6).unwrap();
        let mut zrows = x[(t - 2) * d..].to_vec();
        zrows.resize(4 * d, 0.0);
        let want = Engine::cpu_seq().rbf_block(&zrows, 4, d, &xb, b, 0.6).unwrap();
        assert_eq!(pad, want);
    }

    #[test]
    fn engine_names() {
        assert_eq!(Engine::cpu_seq().name(), "cpu-seq");
        assert_eq!(Engine::cpu_par(12).name(), "cpu-par(12)");
    }
}
