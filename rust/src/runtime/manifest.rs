//! Artifact manifest parsing and shape-bucket lookup.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per
//! AOT-lowered executable:
//!
//! ```text
//! <op> <t> <d> <b> <s> <file>
//! ```
//!
//! (0 in a dimension means the op ignores it.) The store picks the
//! *smallest bucket that fits* a request and the caller pads/masks up to
//! the bucket shape (DESIGN.md §5).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub op: String,
    pub t: usize,
    pub d: usize,
    pub b: usize,
    pub s: usize,
    pub path: PathBuf,
}

/// Parsed manifest: entries grouped by op.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    pub by_op: HashMap<String, Vec<Entry>>,
    pub tile_t: usize,
    pub s_cand: usize,
}

impl Manifest {
    /// Parse `manifest.txt` in `dir`; entry paths are resolved into `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('#') {
                // header: "# ... tile_t=1024 s_cand=64"
                for tok in line.split_ascii_whitespace() {
                    if let Some(v) = tok.strip_prefix("tile_t=") {
                        m.tile_t = v.parse().unwrap_or(0);
                    } else if let Some(v) = tok.strip_prefix("s_cand=") {
                        m.s_cand = v.parse().unwrap_or(0);
                    }
                }
                continue;
            }
            let f: Vec<&str> = line.split_ascii_whitespace().collect();
            if f.len() != 6 {
                bail!("manifest line {} malformed: '{line}'", lineno + 1);
            }
            let e = Entry {
                op: f[0].to_string(),
                t: f[1].parse().context("t")?,
                d: f[2].parse().context("d")?,
                b: f[3].parse().context("b")?,
                s: f[4].parse().context("s")?,
                path: dir.join(f[5]),
            };
            m.by_op.entry(e.op.clone()).or_default().push(e);
        }
        if m.by_op.is_empty() {
            bail!("manifest has no entries");
        }
        for v in m.by_op.values_mut() {
            v.sort_by_key(|e| (e.d, e.b, e.s, e.t));
        }
        Ok(m)
    }

    /// Smallest bucket of `op` with t >= min_t, d >= min_d, b >= min_b,
    /// s >= min_s (0 requirements match anything).
    pub fn lookup(
        &self,
        op: &str,
        min_t: usize,
        min_d: usize,
        min_b: usize,
        min_s: usize,
    ) -> Option<&Entry> {
        self.by_op.get(op)?.iter().find(|e| {
            (min_t == 0 || e.t >= min_t)
                && (min_d == 0 || e.d >= min_d)
                && (min_b == 0 || e.b >= min_b)
                && (min_s == 0 || e.s >= min_s)
        })
    }

    /// Distinct d buckets available for `kernel_block`.
    pub fn d_buckets(&self) -> Vec<usize> {
        let mut ds: Vec<usize> = self
            .by_op
            .get("kernel_block")
            .map(|v| v.iter().map(|e| e.d).collect())
            .unwrap_or_default();
        ds.sort_unstable();
        ds.dedup();
        ds
    }

    /// Distinct b buckets available for `tile_stats`.
    pub fn b_buckets(&self) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .by_op
            .get("tile_stats")
            .map(|v| v.iter().map(|e| e.b).collect())
            .unwrap_or_default();
        bs.sort_unstable();
        bs.dedup();
        bs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# wu-svm artifact manifest; tile_t=1024 s_cand=64
kernel_block 1024 64 64 0 kb_64_64.hlo.txt
kernel_block 1024 128 64 0 kb_128_64.hlo.txt
kernel_block 1024 64 128 0 kb_64_128.hlo.txt
tile_stats 1024 0 64 0 ts_64.hlo.txt
tile_stats 1024 0 128 0 ts_128.hlo.txt
cg_solve 0 0 64 0 cg_64.hlo.txt
score_tile 1024 0 0 64 sc_64.hlo.txt
";

    #[test]
    fn parses_header_and_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.tile_t, 1024);
        assert_eq!(m.s_cand, 64);
        assert_eq!(m.by_op["kernel_block"].len(), 3);
        assert_eq!(m.by_op["tile_stats"][0].path, Path::new("/a/ts_64.hlo.txt"));
    }

    #[test]
    fn lookup_picks_smallest_fitting_bucket() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        let e = m.lookup("kernel_block", 1024, 100, 10, 0).unwrap();
        assert_eq!((e.d, e.b), (128, 64));
        let e2 = m.lookup("kernel_block", 0, 64, 65, 0).unwrap();
        assert_eq!((e2.d, e2.b), (64, 128));
    }

    #[test]
    fn lookup_none_when_too_big() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert!(m.lookup("kernel_block", 0, 4096, 0, 0).is_none());
        assert!(m.lookup("nope", 0, 0, 0, 0).is_none());
    }

    #[test]
    fn buckets_listed() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.d_buckets(), vec![64, 128]);
        assert_eq!(m.b_buckets(), vec![64, 128]);
    }

    #[test]
    fn malformed_line_rejected() {
        assert!(Manifest::parse("kernel_block 1 2 3\n", Path::new("/")).is_err());
        assert!(Manifest::parse("", Path::new("/")).is_err());
    }
}
