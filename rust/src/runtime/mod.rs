//! PJRT runtime: load + compile AOT artifacts, execute from the hot path.
//!
//! This is the "optimized opaque library" of the implicit approach: the
//! Rust coordinator hands it large padded tiles and the XLA CPU backend
//! owns the parallel schedule (the role MKL/CUBLAS/Jacket play in the
//! paper). One `XlaRuntime` per process; executables are compiled lazily
//! per (op, bucket) and cached.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

pub use manifest::{Entry, Manifest};

/// Per-op execution statistics (perf pass, EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct OpStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

/// Everything that touches the non-atomically-refcounted xla wrappers
/// lives behind one mutex: the `xla` crate uses `Rc` internally (so its
/// types are !Send/!Sync) even though the underlying PJRT CPU client is
/// thread-safe. Serializing every compile/execute/drop through `inner`
/// means no `Rc` refcount is ever mutated concurrently.
struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

/// Loaded PJRT runtime with lazy executable cache.
pub struct XlaRuntime {
    inner: Mutex<Inner>,
    manifest: Manifest,
    stats: Mutex<HashMap<String, OpStats>>,
}

// SAFETY: all access to the Rc-bearing `Inner` is serialized by the
// mutex (see `Inner` docs); the wrapped PJRT C API itself is thread-safe.
// One dispatch at a time also matches the single-accelerator model of the
// paper's implicit library (the device owns intra-op parallelism).
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Load from an artifacts directory (`make artifacts` output).
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(XlaRuntime {
            inner: Mutex::new(Inner { client, executables: HashMap::new() }),
            manifest,
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Row-tile size every artifact expects.
    pub fn tile_t(&self) -> usize {
        self.manifest.tile_t
    }

    /// Candidate batch size of the score_tile artifact.
    pub fn s_cand(&self) -> usize {
        self.manifest.s_cand
    }

    /// Execute `entry` with f32 inputs of the given shapes; returns every
    /// tuple element flattened to f32. Compiles lazily on first use.
    ///
    /// Inputs go through `buffer_from_host_buffer` + `execute_b` (explicit
    /// PjRtBuffers we drop ourselves) rather than `execute` with Literals:
    /// the C shim behind `execute` leaks one device copy of every input
    /// per call (~4 MB/call at the d=2048 bucket — found via the OOM in
    /// the first full Table-1 run; see EXPERIMENTS.md §Perf).
    pub fn execute(&self, entry: &Entry, inputs: &[(&[i64], &[f32])]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|(shape, data)| {
                let dims: Vec<usize> = shape.iter().map(|&v| v as usize).collect();
                inner
                    .client
                    .buffer_from_host_buffer(data, &dims, None)
                    .map_err(|e| anyhow!("host buffer {:?} for {}: {e:?}", shape, entry.op))
            })
            .collect::<Result<_>>()?;
        if !inner.executables.contains_key(&entry.path) {
            let tc = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .map_err(|e| anyhow!("load {}: {e:?}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.path.display()))?;
            inner.executables.insert(entry.path.clone(), exe);
            self.stats
                .lock()
                .unwrap()
                .entry(entry.op.clone())
                .or_default()
                .compile_time += tc.elapsed();
        }
        let exe = inner.executables.get(&entry.path).expect("compiled above");
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("execute {}: {e:?}", entry.op))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        drop(result);
        drop(bufs);
        drop(inner);
        let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let out: Vec<Vec<f32>> = parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect::<Result<_>>()?;
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(entry.op.clone()).or_default();
        s.calls += 1;
        s.total += t0.elapsed();
        Ok(out)
    }

    /// Look up the smallest fitting bucket (see `Manifest::lookup`).
    pub fn lookup(
        &self,
        op: &str,
        min_t: usize,
        min_d: usize,
        min_b: usize,
        min_s: usize,
    ) -> Result<Entry> {
        self.manifest
            .lookup(op, min_t, min_d, min_b, min_s)
            .cloned()
            .with_context(|| {
                format!(
                    "no artifact for {op} (t>={min_t}, d>={min_d}, b>={min_b}, \
                     s>={min_s}); re-run `make artifacts`"
                )
            })
    }

    /// Snapshot of per-op stats.
    pub fn stats(&self) -> HashMap<String, OpStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Human-readable stats summary.
    pub fn stats_report(&self) -> String {
        let stats = self.stats();
        let mut keys: Vec<_> = stats.keys().cloned().collect();
        keys.sort();
        let mut out = String::from("op                calls   exec_total   compile\n");
        for k in keys {
            let s = &stats[&k];
            out.push_str(&format!(
                "{:<16} {:>6} {:>12.3}s {:>8.3}s\n",
                k,
                s.calls,
                s.total.as_secs_f64(),
                s.compile_time.as_secs_f64()
            ));
        }
        out
    }
}

/// Default artifacts directory: $WU_SVM_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("WU_SVM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<XlaRuntime> {
        let dir = default_artifacts_dir();
        XlaRuntime::load(&dir).ok()
    }

    #[test]
    fn loads_manifest_and_buckets() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        assert_eq!(rt.tile_t(), 1024);
        assert!(!rt.manifest().d_buckets().is_empty());
        assert!(!rt.manifest().b_buckets().is_empty());
    }

    #[test]
    fn kernel_block_executes_and_matches_cpu() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let entry = rt.lookup("kernel_block", 1024, 64, 64, 0).unwrap();
        let (t, d, b) = (entry.t, entry.d, entry.b);
        let mut rng = crate::rng::Rng::new(1);
        let x: Vec<f32> = (0..t * d).map(|_| rng.uniform_f32()).collect();
        let xb: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
        let gamma = [0.35f32];
        let out = rt
            .execute(
                &entry,
                &[
                    (&[t as i64, d as i64], &x),
                    (&[b as i64, d as i64], &xb),
                    (&[1], &gamma),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let k = &out[0];
        assert_eq!(k.len(), t * b);
        // spot-check against scalar CPU eval
        let kind = crate::kernel::KernelKind::Rbf { gamma: gamma[0] };
        for &(i, j) in &[(0usize, 0usize), (5, 3), (1023, 63), (512, 17)] {
            let e = kind.eval(&x[i * d..(i + 1) * d], &xb[j * d..(j + 1) * d]);
            assert!(
                (k[i * b + j] - e).abs() < 1e-4,
                "K[{i},{j}] = {} vs {e}",
                k[i * b + j]
            );
        }
        let stats = rt.stats();
        assert_eq!(stats["kernel_block"].calls, 1);
    }

    #[test]
    fn lookup_error_is_actionable() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let err = rt.lookup("kernel_block", 0, 1 << 20, 0, 0).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
