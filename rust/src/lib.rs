//! # wu-svm — Parallel Support Vector Machines in Practice
//!
//! A from-scratch reproduction of Tyree et al. (2014): kernel-SVM training
//! parallelized *explicitly* (hand-threaded SMO-family solvers) and
//! *implicitly* (the optimization reformulated as a few large dense
//! linear-algebra calls, AOT-compiled from JAX/Pallas to XLA and executed
//! through PJRT from this Rust coordinator).
//!
//! See `rust/DESIGN.md` for the system inventory (engine layering, the
//! shared kernel-row cache, the SMO shrinking heuristic) and
//! `rust/EXPERIMENTS.md` for how to regenerate the Table-1 numbers.
//!
//! Layering (Python never runs at train/serve time):
//! * L1 — Pallas kernels (`python/compile/kernels/`): RBF block, fused
//!   squared-hinge statistics.
//! * L2 — JAX graphs (`python/compile/model.py`): the five tile ops,
//!   lowered to HLO text artifacts by `make artifacts`.
//! * L3 — this crate: datasets, solvers, engines, coordinator, the
//!   serving subsystem (`serve/`), CLI.

pub mod bench_util;
pub mod cascade;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod multiclass;
pub mod pool;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod trace;
