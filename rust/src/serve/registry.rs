//! Versioned model registry: compile once at registration, serve from an
//! immutable packed representation, hot-swap behind an `Arc`.
//!
//! Registration is where serve-time work that would otherwise repeat per
//! batch happens exactly once:
//!
//! * zero-coefficient expansion vectors are dropped and bit-identical
//!   vectors merged (their coefficients sum — for an OvO ensemble the
//!   merge runs *across pairs*, so the shared RBF block is computed
//!   against the deduplicated union of every pair's support vectors);
//! * surviving vectors are packed into a contiguous matrix padded to the
//!   GEMM's B-panel width ([`crate::linalg::gemm::NR`]) with zero rows
//!   and zero coefficients, so serve tiles have no partial micro-panels;
//! * squared norms are precomputed in [`crate::linalg::gemm::sum_sq`]
//!   order, feeding the norms-supplied [`crate::engine::Engine::rbf_block_pre`]
//!   entry point — per batch only the a-side norms are derived.
//!
//! Models whose kernels can't share one RBF block (non-RBF, or OvO pairs
//! with mixed kernels) compile to a scalar representation instead; that
//! is a *compile-time* property of the model, distinct from the counted
//! engine-error fallback in the batcher.
//!
//! The packed store is a [`Design`]: expansion vectors whose post-dedup
//! density is at or below [`AUTO_SPARSE_THRESHOLD`] compile to CSR and
//! serve through the dense-queries x sparse-vectors SpMM path
//! (`spmm::rbf_dense_csr_pre`, norms precomputed in registration order);
//! denser stores keep the NR-padded packed-GEMM route. Models trained on
//! rcv1-class sparse data keep their memory and bandwidth wins at serve
//! time.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::data::{CsrMatrix, Design, AUTO_SPARSE_THRESHOLD};
use crate::engine::Engine;
use crate::kernel::KernelKind;
use crate::linalg::{gemm, spmm, Matrix};
use crate::model::SvmModel;
use crate::multiclass::{vote_argmax, OvoModel};
use crate::serve::Output;

/// Anything the registry can compile into a serve-time model.
pub trait Servable {
    /// Feature dimension this model scores (fixed per registry).
    fn input_dim(&self) -> usize;
    /// Pack/compact into an immutable serve-time representation stamped
    /// with `version`.
    fn compile(&self, version: u64) -> CompiledModel;
}

impl Servable for SvmModel {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn compile(&self, version: u64) -> CompiledModel {
        compile_binary(self, version)
    }
}

impl Servable for OvoModel {
    fn input_dim(&self) -> usize {
        self.models.first().map_or(0, |m| m.d)
    }

    fn compile(&self, version: u64) -> CompiledModel {
        compile_ovo(self, version)
    }
}

/// Versioned registry of one serving lineage: all versions score the same
/// feature dimension. Reads are an `Arc` clone; publishes compile outside
/// the lock and swap atomically, so in-flight batches finish on the
/// version they started with.
pub struct ModelRegistry {
    current: RwLock<Arc<CompiledModel>>,
    next_version: AtomicU64,
    d: usize,
}

impl ModelRegistry {
    /// Create a registry serving `model` as version 1.
    pub fn new(model: &dyn Servable) -> ModelRegistry {
        ModelRegistry {
            current: RwLock::new(Arc::new(model.compile(1))),
            next_version: AtomicU64::new(2),
            d: model.input_dim(),
        }
    }

    /// Compile `model` and hot-swap it in as the new current version.
    /// Returns the version id. Fails if the feature dimension differs
    /// from the registry's lineage. The expensive compile runs outside
    /// the lock; the version is allocated *inside* the write lock and
    /// stamped just before the swap, so concurrent publishes always
    /// leave the highest version live (swap order == version order).
    pub fn publish(&self, model: &dyn Servable) -> Result<u64> {
        let _sp = crate::trace::span("serve/publish");
        if model.input_dim() != self.d {
            bail!(
                "model input dim {} != registry dim {}",
                model.input_dim(),
                self.d
            );
        }
        let mut compiled = model.compile(0);
        let mut guard = self.current.write().unwrap();
        let v = self.next_version.fetch_add(1, Ordering::Relaxed);
        compiled.version = v;
        *guard = Arc::new(compiled);
        Ok(v)
    }

    /// The model currently serving (an `Arc` snapshot: callers score a
    /// whole batch off one coherent version even across a swap).
    pub fn current(&self) -> Arc<CompiledModel> {
        self.current.read().unwrap().clone()
    }

    /// Version id of the model currently serving.
    pub fn version(&self) -> u64 {
        self.current().version
    }

    /// Feature dimension of this lineage.
    pub fn input_dim(&self) -> usize {
        self.d
    }
}

/// An immutable, serve-ready model (see module docs for what compiling
/// does). Shared by every batcher shard via `Arc`.
pub struct CompiledModel {
    pub version: u64,
    /// Feature dimension.
    pub d: usize,
    kind: CompiledKind,
}

enum CompiledKind {
    Binary(PackedBinary),
    Ovo(PackedOvo),
    ScalarBinary(SvmModel),
    ScalarOvo(OvoModel),
}

struct PackedBinary {
    gamma: f32,
    /// Store row count (padded to `gemm::NR` for dense stores; exact for
    /// CSR stores — the SpMM has no panel-width requirement).
    b: usize,
    /// Compacted rows before padding.
    packed: usize,
    /// `[b x d]` packed expansion vectors, dense or CSR (module docs).
    store: Design,
    /// Registration-time squared norms for the *dense* store path
    /// (`sum_sq` order); empty for CSR stores, which carry their norms
    /// internally (`CsrMatrix::sum_sq`).
    norms: Vec<f32>,
    coef: Vec<f32>,
    bias: f32,
}

struct PackedOvo {
    gamma: f32,
    classes: usize,
    pairs: Vec<(usize, usize)>,
    /// Union store row count (padded to `gemm::NR` for dense stores).
    u: usize,
    /// Deduplicated union rows before padding.
    packed: usize,
    /// Nonzero-coefficient vectors across all pairs before dedup.
    raw: usize,
    /// `[u x d]` deduplicated union of all pairs' support vectors,
    /// dense or CSR (module docs).
    store: Design,
    /// Dense-store squared norms (`sum_sq` order); empty for CSR.
    norms: Vec<f32>,
    /// Row-major `[pairs x u]`: pair `p`'s coefficients scattered over
    /// the union (the B operand of the one shared scoring GEMM).
    coef_t: Vec<f32>,
    bias: Vec<f32>,
}

/// Pack a compacted `packed x d` row block into the serve-time store:
/// CSR when its density is at or below [`AUTO_SPARSE_THRESHOLD`]
/// (b = packed, norms empty — they live in the CSR), else the NR-padded
/// dense block (b = padded, norms in `sum_sq` order). Returns
/// `(store, b, norms)`.
fn pack_store(mut vectors: Vec<f32>, packed: usize, d: usize) -> (Design, usize, Vec<f32>) {
    let nonzero = vectors.iter().filter(|&&v| v != 0.0).count();
    let dense_cells = (packed * d).max(1);
    if packed > 0 && (nonzero as f64 / dense_cells as f64) <= AUTO_SPARSE_THRESHOLD {
        // norms live inside the CSR (`sum_sq`); no separate copy to drift
        let csr = CsrMatrix::from_dense(packed, d, &vectors);
        return (Design::Sparse(csr), packed, Vec::new());
    }
    let b = pad_rows(packed);
    vectors.resize(b * d, 0.0);
    let norms = store_norms(&vectors, b, d);
    (Design::Dense(Matrix::from_vec(b, d, vectors)), b, norms)
}

/// One `K[t x b]` RBF block of a dense query batch against the packed
/// store, with registration-time b-side norms — dense stores take the
/// norms-supplied packed-GEMM entry point, CSR stores the SpMM one.
#[allow(clippy::too_many_arguments)]
fn store_rbf_block(
    engine: &Engine,
    store: &Design,
    norms: &[f32],
    x: &[f32],
    t: usize,
    d: usize,
    b: usize,
    gamma: f32,
) -> Result<Vec<f32>> {
    match store {
        Design::Dense(m) => engine.rbf_block_pre(x, t, d, &m.data, b, gamma, norms),
        Design::Sparse(csr) => {
            // the xla engine has no sparse artifact; run the SpMM on the
            // cpu pool at full width rather than engine.threads() (which
            // is 1 for xla) — output is thread-count independent anyway
            let threads = if engine.is_xla() {
                crate::pool::default_threads()
            } else {
                engine.threads()
            };
            let mut k = vec![0.0f32; t * b];
            spmm::rbf_dense_csr_pre(threads, x, t, csr, gamma, &mut k);
            Ok(k)
        }
        Design::MmapDense(_) | Design::MmapCsr(_) => {
            unreachable!("serve stores are packed in-memory by the compiler")
        }
    }
}

/// Scalar (engine-free) RBF distance of a dense query to store row `j`.
fn store_dist2(store: &Design, d: usize, j: usize, x: &[f32], xsq: f32) -> f32 {
    match store {
        Design::Dense(m) => gemm::dist2_lanes(x, &m.data[j * d..(j + 1) * d]),
        Design::Sparse(csr) => {
            (xsq + csr.sum_sq[j] - 2.0 * csr.row_dot_dense(j, x)).max(0.0)
        }
        Design::MmapDense(_) | Design::MmapCsr(_) => {
            unreachable!("serve stores are packed in-memory by the compiler")
        }
    }
}

/// Pad a packed row count up to a multiple of the GEMM's B-panel width
/// so serve tiles have no partial micro-panels. Padded rows are all-zero
/// features with zero coefficients: their kernel values are multiplied
/// by 0 and contribute to no margin.
fn pad_rows(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        (n + gemm::NR - 1) / gemm::NR * gemm::NR
    }
}

/// Fold one model's expansion into the shared dedup store: skip
/// zero-coefficient rows, merge bit-identical rows, and return each
/// surviving coefficient's `(store slot, value)`. One definition shared
/// by the binary and OvO compilers so the dedup rule cannot diverge.
fn dedup_rows(
    dedup: &mut HashMap<Vec<u32>, usize>,
    store: &mut Vec<f32>,
    d: usize,
    vectors: &[f32],
    coef: &[f32],
) -> Vec<(usize, f32)> {
    let mut out = Vec::with_capacity(coef.len());
    for (j, &c) in coef.iter().enumerate() {
        if c == 0.0 {
            continue;
        }
        let row = &vectors[j * d..(j + 1) * d];
        let key: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
        let next_slot = store.len() / d;
        let slot = *dedup.entry(key).or_insert_with(|| {
            store.extend_from_slice(row);
            next_slot
        });
        out.push((slot, c));
    }
    out
}

/// Registration-time squared norms for a packed `[rows x d]` store, in
/// the GEMM's own accumulation order.
fn store_norms(store: &[f32], rows: usize, d: usize) -> Vec<f32> {
    (0..rows).map(|j| gemm::sum_sq(&store[j * d..(j + 1) * d])).collect()
}

fn compile_binary(m: &SvmModel, version: u64) -> CompiledModel {
    let _sp = crate::trace::span("serve/compile");
    let kind = match m.kernel {
        KernelKind::Rbf { gamma } if m.num_vectors() > 0 && m.d > 0 => {
            let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
            let mut vectors: Vec<f32> = Vec::new();
            let list = dedup_rows(&mut dedup, &mut vectors, m.d, &m.vectors, &m.coef);
            let packed = vectors.len() / m.d;
            let (store, b, norms) = pack_store(vectors, packed, m.d);
            let mut coef = vec![0.0f32; b];
            for &(slot, c) in &list {
                coef[slot] += c;
            }
            CompiledKind::Binary(PackedBinary {
                gamma,
                b,
                packed,
                store,
                norms,
                coef,
                bias: m.bias,
            })
        }
        _ => CompiledKind::ScalarBinary(m.clone()),
    };
    CompiledModel { version, d: m.d, kind }
}

fn compile_ovo(m: &OvoModel, version: u64) -> CompiledModel {
    let _sp = crate::trace::span("serve/compile");
    let d = m.models.first().map_or(0, |sm| sm.d);
    // the shared-block fast path needs every pair on one RBF kernel
    let mut uniform = m.models.first().and_then(|sm| match sm.kernel {
        KernelKind::Rbf { gamma } => Some(gamma),
        _ => None,
    });
    if let Some(g) = uniform {
        let same = m
            .models
            .iter()
            .all(|sm| sm.d == d && sm.kernel == (KernelKind::Rbf { gamma: g }));
        if !same || d == 0 {
            uniform = None;
        }
    }
    let kind = match uniform {
        Some(gamma) => {
            let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
            let mut union: Vec<f32> = Vec::new();
            // per-pair (union slot, coefficient) scatter lists
            let scatter: Vec<Vec<(usize, f32)>> = m
                .models
                .iter()
                .map(|sm| dedup_rows(&mut dedup, &mut union, d, &sm.vectors, &sm.coef))
                .collect();
            let raw = scatter.iter().map(|l| l.len()).sum::<usize>();
            let packed = union.len() / d;
            let (store, u, norms) = pack_store(union, packed, d);
            let mut coef_t = vec![0.0f32; m.models.len() * u];
            for (pi, list) in scatter.iter().enumerate() {
                for &(slot, c) in list {
                    coef_t[pi * u + slot] += c;
                }
            }
            CompiledKind::Ovo(PackedOvo {
                gamma,
                classes: m.classes,
                pairs: m.pairs.clone(),
                u,
                packed,
                raw,
                store,
                norms,
                coef_t,
                bias: m.models.iter().map(|sm| sm.bias).collect(),
            })
        }
        None => CompiledKind::ScalarOvo(m.clone()),
    };
    CompiledModel { version, d, kind }
}

impl CompiledModel {
    /// Compacted expansion rows actually carried (post-dedup, pre-padding);
    /// 0 for scalar-compiled models.
    pub fn packed_vectors(&self) -> usize {
        match &self.kind {
            CompiledKind::Binary(pb) => pb.packed,
            CompiledKind::Ovo(po) => po.packed,
            _ => 0,
        }
    }

    /// Whether this model serves on the packed shared-GEMM fast path.
    pub fn is_packed(&self) -> bool {
        matches!(self.kind, CompiledKind::Binary(_) | CompiledKind::Ovo(_))
    }

    /// Whether the packed store compiled to CSR (sparse serve path).
    pub fn is_sparse_store(&self) -> bool {
        match &self.kind {
            CompiledKind::Binary(pb) => pb.store.is_sparse(),
            CompiledKind::Ovo(po) => po.store.is_sparse(),
            _ => false,
        }
    }

    /// One-line description for logs and examples.
    pub fn describe(&self) -> String {
        match &self.kind {
            CompiledKind::Binary(pb) => format!(
                "v{} binary packed[{}]: {} rows (store {}), d={}",
                self.version,
                if pb.store.is_sparse() { "csr" } else { "dense" },
                pb.packed,
                pb.b,
                self.d
            ),
            CompiledKind::Ovo(po) => format!(
                "v{} ovo packed[{}]: {} pairs share a {}-row union (from {} raw, store {}), d={}",
                self.version,
                if po.store.is_sparse() { "csr" } else { "dense" },
                po.pairs.len(),
                po.packed,
                po.raw,
                po.u,
                self.d
            ),
            CompiledKind::ScalarBinary(m) => {
                format!("v{} binary scalar ({} kernel)", self.version, m.kernel.name())
            }
            CompiledKind::ScalarOvo(m) => {
                format!("v{} ovo scalar ({} pairs)", self.version, m.pairs.len())
            }
        }
    }

    /// Score `t` packed feature rows through the engine: one shared
    /// kernel block per batch (for OvO, one block against the union and
    /// one GEMM scoring every pair off it). An `Err` means the engine
    /// failed; the batcher then uses [`CompiledModel::score_scalar`] and
    /// counts the fallback.
    pub fn score_batch(&self, engine: &Engine, x: &[f32], t: usize) -> Result<Vec<Output>> {
        assert_eq!(x.len(), t * self.d);
        match &self.kind {
            CompiledKind::Binary(pb) => {
                let k =
                    store_rbf_block(engine, &pb.store, &pb.norms, x, t, self.d, pb.b, pb.gamma)?;
                let mut f = engine.predict_block(&k, t, pb.b, &pb.coef)?;
                for v in f.iter_mut() {
                    *v += pb.bias;
                }
                Ok(f.into_iter().map(Output::Margin).collect())
            }
            CompiledKind::Ovo(po) => {
                let k =
                    store_rbf_block(engine, &po.store, &po.norms, x, t, self.d, po.u, po.gamma)?;
                let p = po.pairs.len();
                let mut fm = vec![0.0f32; t * p];
                gemm::gemm_nt_strided(
                    engine.threads(),
                    t,
                    p,
                    po.u,
                    &k,
                    po.u,
                    1,
                    &po.coef_t,
                    po.u,
                    1,
                    None,
                    &mut fm,
                    p,
                );
                Ok((0..t)
                    .map(|i| {
                        let mut votes = vec![0u32; po.classes];
                        for (pi, &(a, b)) in po.pairs.iter().enumerate() {
                            if fm[i * p + pi] + po.bias[pi] > 0.0 {
                                votes[a] += 1;
                            } else {
                                votes[b] += 1;
                            }
                        }
                        let c = vote_argmax(&votes);
                        Output::Class { class: c, votes: votes[c] }
                    })
                    .collect())
            }
            CompiledKind::ScalarBinary(m) => Ok((0..t)
                .map(|i| Output::Margin(m.decision(&x[i * self.d..(i + 1) * self.d])))
                .collect()),
            CompiledKind::ScalarOvo(m) => Ok((0..t)
                .map(|i| {
                    let (c, v) = m.vote_one(&x[i * self.d..(i + 1) * self.d]);
                    Output::Class { class: c, votes: v }
                })
                .collect()),
        }
    }

    /// Engine-free scalar scoring: the batcher's counted fallback on
    /// engine error and the drain path for worker-less shutdown. Same
    /// compacted expansion, f64-accumulated like `SvmModel::decision`.
    pub fn score_scalar(&self, x: &[f32]) -> Output {
        assert_eq!(x.len(), self.d);
        match &self.kind {
            CompiledKind::Binary(pb) => {
                let xsq = gemm::sum_sq(x);
                let mut f = pb.bias as f64;
                for j in 0..pb.b {
                    let c = pb.coef[j];
                    if c != 0.0 {
                        let d2 = store_dist2(&pb.store, self.d, j, x, xsq);
                        f += (c * (-pb.gamma * d2).exp()) as f64;
                    }
                }
                Output::Margin(f as f32)
            }
            CompiledKind::Ovo(po) => {
                let xsq = gemm::sum_sq(x);
                let mut votes = vec![0u32; po.classes];
                for (pi, &(a, b)) in po.pairs.iter().enumerate() {
                    let mut f = po.bias[pi] as f64;
                    for j in 0..po.u {
                        let c = po.coef_t[pi * po.u + j];
                        if c != 0.0 {
                            let d2 = store_dist2(&po.store, self.d, j, x, xsq);
                            f += (c * (-po.gamma * d2).exp()) as f64;
                        }
                    }
                    if f > 0.0 {
                        votes[a] += 1;
                    } else {
                        votes[b] += 1;
                    }
                }
                let c = vote_argmax(&votes);
                Output::Class { class: c, votes: votes[c] }
            }
            CompiledKind::ScalarBinary(m) => Output::Margin(m.decision(x)),
            CompiledKind::ScalarOvo(m) => {
                let (c, v) = m.vote_one(x);
                Output::Class { class: c, votes: v }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_model(rng: &mut Rng, b: usize, d: usize) -> SvmModel {
        SvmModel {
            kernel: KernelKind::Rbf { gamma: 0.6 },
            vectors: (0..b * d).map(|_| rng.uniform_f32()).collect(),
            d,
            coef: (0..b).map(|_| rng.gaussian_f32()).collect(),
            bias: 0.2,
            solver: "t".into(),
        }
    }

    #[test]
    fn compile_compacts_zero_coefs_and_duplicates() {
        let mut rng = Rng::new(1);
        let mut m = rand_model(&mut rng, 10, 3);
        m.coef[3] = 0.0; // dropped
        m.coef[7] = 0.0; // dropped
        // make row 5 a bit-exact duplicate of row 1: coefficients merge
        let r1: Vec<f32> = m.vectors[3..6].to_vec();
        m.vectors[15..18].copy_from_slice(&r1);
        let c = m.compile(1);
        assert!(c.is_packed());
        assert_eq!(c.packed_vectors(), 7); // 10 - 2 zeros - 1 duplicate
        assert!(c.describe().contains("packed"));
    }

    #[test]
    fn packed_binary_margins_match_decision() {
        let mut rng = Rng::new(2);
        let m = rand_model(&mut rng, 37, 6);
        let c = m.compile(4);
        assert_eq!(c.version, 4);
        let t = 11;
        let x: Vec<f32> = (0..t * 6).map(|_| rng.uniform_f32()).collect();
        let outs = c.score_batch(&Engine::cpu_par(3), &x, t).unwrap();
        for (i, o) in outs.iter().enumerate() {
            let want = m.decision(&x[i * 6..(i + 1) * 6]);
            let got = o.margin().unwrap();
            assert!((got - want).abs() < 1e-5, "row {i}: {got} vs {want}");
            // scalar fallback path agrees too
            let sc = c.score_scalar(&x[i * 6..(i + 1) * 6]).margin().unwrap();
            assert!((sc - want).abs() < 1e-5, "row {i} scalar: {sc} vs {want}");
        }
    }

    #[test]
    fn sparse_vectors_compile_to_csr_store_and_match_decision() {
        let mut rng = Rng::new(9);
        let (b, d) = (20usize, 120usize);
        let m = SvmModel {
            kernel: KernelKind::Rbf { gamma: 0.5 },
            vectors: (0..b * d)
                .map(|_| if rng.bernoulli(0.1) { rng.uniform_f32() } else { 0.0 })
                .collect(),
            d,
            coef: (0..b).map(|_| rng.gaussian_f32()).collect(),
            bias: -0.15,
            solver: "t".into(),
        };
        let c = m.compile(3);
        assert!(c.is_packed());
        assert!(c.is_sparse_store(), "10%-dense vectors must pack to csr");
        assert!(c.describe().contains("csr"), "{}", c.describe());
        let t = 9;
        let x: Vec<f32> = (0..t * d).map(|_| rng.uniform_f32()).collect();
        for e in [Engine::cpu_seq(), Engine::cpu_par(3)] {
            let outs = c.score_batch(&e, &x, t).unwrap();
            for (i, o) in outs.iter().enumerate() {
                let want = m.decision(&x[i * d..(i + 1) * d]);
                let got = o.margin().unwrap();
                assert!((got - want).abs() < 1e-5, "row {i}: {got} vs {want}");
            }
        }
        for i in 0..t {
            let q = &x[i * d..(i + 1) * d];
            let sc = c.score_scalar(q).margin().unwrap();
            assert!((sc - m.decision(q)).abs() < 1e-5, "scalar row {i}");
        }
        // a dense model still packs dense
        let dense = rand_model(&mut rng, 8, 4).compile(1);
        assert!(!dense.is_sparse_store());
    }

    #[test]
    fn non_rbf_compiles_to_scalar_and_still_scores() {
        let m = SvmModel {
            kernel: KernelKind::Linear,
            vectors: vec![1.0, 0.0, 0.0, 1.0],
            d: 2,
            coef: vec![0.5, -0.25],
            bias: 0.1,
            solver: "t".into(),
        };
        let c = m.compile(1);
        assert!(!c.is_packed());
        let x = [0.4f32, 0.8];
        let got = c.score_batch(&Engine::cpu_seq(), &x, 1).unwrap()[0];
        assert!((got.margin().unwrap() - m.decision(&x)).abs() < 1e-6);
    }

    #[test]
    fn ovo_union_dedups_across_pairs() {
        // three pairs sharing one pool of 4 distinct vectors: the union
        // must carry each distinct vector once
        let pool: Vec<Vec<f32>> = vec![
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
        ];
        let mk = |ids: &[usize], coefs: &[f32], bias: f32| SvmModel {
            kernel: KernelKind::Rbf { gamma: 1.0 },
            vectors: ids.iter().flat_map(|&i| pool[i].clone()).collect(),
            d: 2,
            coef: coefs.to_vec(),
            bias,
            solver: "t".into(),
        };
        let ovo = OvoModel {
            classes: 3,
            pairs: vec![(0, 1), (0, 2), (1, 2)],
            models: vec![
                mk(&[0, 1, 2], &[1.0, -0.5, 0.25], 0.1),
                mk(&[1, 2, 3], &[0.7, -0.7, 0.3], -0.2),
                mk(&[0, 3], &[0.9, -0.9], 0.05),
            ],
            train_secs: 0.0,
        };
        let c = ovo.compile(1);
        assert!(c.is_packed());
        assert_eq!(c.packed_vectors(), 4, "union must dedup 8 raw rows to 4");

        // packed voting matches the scalar ensemble on a grid of queries
        let queries: Vec<[f32; 2]> = vec![
            [0.1, 0.1],
            [0.9, 0.1],
            [0.1, 0.9],
            [0.9, 0.9],
            [0.5, 0.2],
        ];
        let mut x = Vec::new();
        for q in &queries {
            x.extend_from_slice(q);
        }
        let outs = c.score_batch(&Engine::cpu_par(2), &x, queries.len()).unwrap();
        for (q, o) in queries.iter().zip(&outs) {
            let (want, _) = ovo.vote_one(q);
            assert_eq!(o.class().unwrap(), want, "query {q:?}");
            assert_eq!(c.score_scalar(q).class().unwrap(), want, "scalar {q:?}");
        }
    }

    #[test]
    fn mixed_kernel_ovo_compiles_to_scalar() {
        let rbf = SvmModel {
            kernel: KernelKind::Rbf { gamma: 1.0 },
            vectors: vec![0.0, 0.0],
            d: 2,
            coef: vec![1.0],
            bias: 0.0,
            solver: "t".into(),
        };
        let mut lin = rbf.clone();
        lin.kernel = KernelKind::Linear;
        let ovo = OvoModel {
            classes: 3,
            pairs: vec![(0, 1), (0, 2)],
            models: vec![rbf, lin],
            train_secs: 0.0,
        };
        let c = ovo.compile(1);
        assert!(!c.is_packed());
        let got = c.score_batch(&Engine::cpu_seq(), &[0.3, 0.4], 1).unwrap()[0];
        assert_eq!(got.class(), Some(ovo.vote_one(&[0.3, 0.4]).0));
    }

    #[test]
    fn registry_swaps_versions_and_rejects_dim_mismatch() {
        let mut rng = Rng::new(3);
        let a = rand_model(&mut rng, 8, 4);
        let reg = ModelRegistry::new(&a);
        assert_eq!(reg.version(), 1);
        assert_eq!(reg.input_dim(), 4);
        // an Arc snapshot taken before a swap keeps its version
        let old = reg.current();
        let b = rand_model(&mut rng, 12, 4);
        let v = reg.publish(&b).unwrap();
        assert_eq!(v, 2);
        assert_eq!(reg.version(), 2);
        assert_eq!(old.version, 1, "pre-swap snapshot must stay coherent");
        let wrong = rand_model(&mut rng, 8, 5);
        assert!(reg.publish(&wrong).is_err());
        assert_eq!(reg.version(), 2, "failed publish must not swap");
    }
}
