//! Production serving subsystem — the implicit-parallel credo applied to
//! inference, grown from the single-threaded demo loop that used to live
//! in `coordinator::serve` (that deprecated re-export has been removed;
//! import `wu_svm::serve` directly).
//!
//! Four pillars (DESIGN.md §SERVE):
//!
//! * **Versioned model registry** ([`registry`]) — models are *compiled*
//!   at registration into an immutable serve-time representation
//!   (zero-coefficient vectors dropped, duplicate expansion vectors
//!   merged, rows packed into padded tiles, squared norms precomputed for
//!   the norms-supplied `Engine::rbf_block_pre` entry point) and
//!   hot-swapped behind an `Arc`. Both binary [`crate::model::SvmModel`]s
//!   and multiclass [`crate::multiclass::OvoModel`]s are [`Servable`]; an
//!   OvO ensemble is served off **one** shared RBF block against the
//!   deduplicated union of all pairs' support vectors, then every pair is
//!   scored from that single GEMM.
//! * **Sharded batching** ([`batcher`]) — N batcher workers drain a
//!   *bounded* queue, so multiple engine calls pipeline concurrently and
//!   a full queue rejects with [`SubmitError::Overloaded`] instead of
//!   queueing without bound (admission control bounds tail latency).
//! * **Compacted serve-time models** — see registry above; the per-batch
//!   kernel cost drops to one GEMM + a-side norms + the fused exp pass.
//! * **Serve metrics** ([`metrics`]) — throughput / batch-occupancy /
//!   queue-depth counters, engine-fallback counts (never silent), and a
//!   log-bucketed latency histogram, exposed as a [`Snapshot`].
//!
//! **Determinism.** Every per-request output is independent of batch
//! composition and shard count: the blocked GEMM gives each K row a fixed
//! accumulation order regardless of how many rows share the tile, so the
//! same features produce bit-identical margins whether they ride a batch
//! of 1 or 256, on 1 shard or 8 (property-tested in
//! `rust/tests/serve_props.rs`).

pub mod batcher;
pub mod metrics;
pub mod registry;

pub use batcher::{Client, Pending, Server, SubmitError};
pub use metrics::{ServeMetrics, Snapshot};
pub use registry::{CompiledModel, ModelRegistry, Servable};

use std::time::Duration;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests per batch (and engine tile rows).
    pub batch: usize,
    /// How long a batcher waits to fill a batch after its first request.
    pub max_wait: Duration,
    /// Batcher worker shards draining the queue. `0` spawns no workers:
    /// requests queue up (to `queue_cap`) until [`Server::stop`] drains
    /// them — deterministic harness for admission-control tests.
    pub shards: usize,
    /// Bounded queue capacity; submissions beyond it are rejected with
    /// [`SubmitError::Overloaded`] rather than queued without bound.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch: 256,
            max_wait: Duration::from_millis(2),
            shards: 2,
            queue_cap: 4096,
        }
    }
}

/// One scored prediction: binary models produce margins, OvO ensembles a
/// voted class id (with its vote count, LibSVM tie-break toward the
/// smaller class id).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Output {
    Margin(f32),
    Class { class: usize, votes: u32 },
}

impl Output {
    /// Binary margin, if this is a binary prediction.
    pub fn margin(&self) -> Option<f32> {
        match self {
            Output::Margin(m) => Some(*m),
            Output::Class { .. } => None,
        }
    }

    /// Voted class id, if this is a multiclass prediction.
    pub fn class(&self) -> Option<usize> {
        match self {
            Output::Class { class, .. } => Some(*class),
            Output::Margin(_) => None,
        }
    }
}

/// A prediction response.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Registry version of the model that scored this request. Every
    /// request in a batch is scored by the same version — a hot-swap
    /// mid-batch never mixes versions within a batch.
    pub version: u64,
    pub output: Output,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_accessors() {
        let m = Output::Margin(1.5);
        assert_eq!(m.margin(), Some(1.5));
        assert_eq!(m.class(), None);
        let c = Output::Class { class: 3, votes: 7 };
        assert_eq!(c.class(), Some(3));
        assert_eq!(c.margin(), None);
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.batch > 0 && cfg.shards > 0 && cfg.queue_cap >= cfg.batch);
    }
}
