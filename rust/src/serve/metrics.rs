//! Serve-time metrics: lock-free counters plus a log-bucketed latency
//! histogram, exposed as an immutable [`Snapshot`].
//!
//! Everything is a relaxed atomic — recording sits on the batcher hot
//! path and must cost a handful of nanoseconds, not a lock. The
//! histogram buckets latency at power-of-two microsecond boundaries
//! (bucket `i` covers `[2^i, 2^{i+1})` µs). Quantiles interpolate the
//! target rank linearly *within* its bucket and clamp against the exact
//! maximum observed latency, so p50/p99/p999 are estimates with at most
//! one-bucket (2x) error instead of the old hard upper bounds.
//!
//! Answers and fallbacks are additionally attributed to the model
//! version that served them, in a small fixed table of CAS-claimed
//! slots (registry versions start at 1, so 0 is the free sentinel);
//! versions beyond the table spill into an overflow counter rather
//! than being dropped silently.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket count: bucket `i` covers `[2^i, 2^{i+1})` µs, the
/// last bucket absorbs the tail (2^31 µs ≈ 36 minutes).
const BUCKETS: usize = 32;

/// Per-version attribution slots. A rollout touches a handful of
/// versions; 16 covers any sane serve lifetime, and the overflow
/// counter keeps the accounting honest past that.
const VERSION_SLOTS: usize = 16;

/// One CAS-claimed per-model-version counter row. `version == 0` marks
/// a free slot (registry versions start at 1).
struct VersionSlot {
    version: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// Shared, thread-safe serve counters. One instance per [`super::Server`];
/// clients record submissions/rejections, batcher shards record batches,
/// fallbacks and per-request latency.
pub struct ServeMetrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    answered: AtomicU64,
    batches: AtomicU64,
    fallbacks: AtomicU64,
    panics: AtomicU64,
    max_batch: AtomicU64,
    depth_peak: AtomicU64,
    /// Exact maximum latency observed (µs) — clamps the interpolated
    /// quantile estimates so no estimate exceeds a real observation.
    max_us: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    versions: [VersionSlot; VERSION_SLOTS],
    version_overflow: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        #[allow(clippy::declare_interior_mutable_const)]
        const FREE: VersionSlot = VersionSlot {
            version: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        };
        ServeMetrics {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            answered: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            depth_peak: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
            latency: [ZERO; BUCKETS],
            versions: [FREE; VERSION_SLOTS],
            version_overflow: AtomicU64::new(0),
        }
    }

    /// A request was admitted; `depth` is the queue depth it observed.
    pub(crate) fn on_submit(&self, depth: usize) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.depth_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// A request was rejected with `Overloaded`.
    pub(crate) fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch of `size` requests left the queue for the engine.
    pub(crate) fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.max_batch.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// `n` requests fell back to scalar scoring after an engine error,
    /// attributed to the model `version` that failed.
    pub(crate) fn on_fallback(&self, n: usize, version: u64) {
        self.fallbacks.fetch_add(n as u64, Ordering::Relaxed);
        crate::trace::count(crate::trace::Counter::EngineFallbacks, n as u64);
        match self.version_slot(version) {
            Some(s) => {
                s.errors.fetch_add(n as u64, Ordering::Relaxed);
            }
            None => {
                self.version_overflow.fetch_add(n as u64, Ordering::Relaxed);
            }
        }
    }

    /// A batch panicked while scoring (its waiters were notified by the
    /// dropped reply senders; the shard survived).
    pub(crate) fn on_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A response was sent `latency` after its request was enqueued, by
    /// model `version`.
    pub(crate) fn on_answer(&self, latency: Duration, version: u64) {
        self.answered.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        self.latency[bucket(us)].fetch_add(1, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        match self.version_slot(version) {
            Some(s) => {
                s.requests.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.version_overflow.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Find or CAS-claim the slot for `version`; `None` when the table
    /// is full (or `version` is the free sentinel 0).
    fn version_slot(&self, version: u64) -> Option<&VersionSlot> {
        if version == 0 {
            return None;
        }
        for s in &self.versions {
            let v = s.version.load(Ordering::Relaxed);
            if v == version {
                return Some(s);
            }
            if v == 0 {
                if s.version
                    .compare_exchange(0, version, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
                {
                    return Some(s);
                }
                // lost the race: the winner may have claimed our version
                if s.version.load(Ordering::Relaxed) == version {
                    return Some(s);
                }
            }
        }
        None
    }

    /// Engine-error fallback count so far (asserted zero by happy-path
    /// tests — an engine failure must never be silent).
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of every counter. `queue_depth` and
    /// `model_version` are gauges owned by the server, passed through.
    pub fn snapshot(&self, queue_depth: usize, model_version: u64) -> Snapshot {
        let mut counts = [0u64; BUCKETS];
        for (c, a) in counts.iter_mut().zip(self.latency.iter()) {
            *c = a.load(Ordering::Relaxed);
        }
        let total: u64 = counts.iter().sum();
        let answered = self.answered.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let max_us = self.max_us.load(Ordering::Relaxed);
        let q = |q: f64| {
            let est = quantile_est_us(&counts, total, q).min(max_us as f64);
            Duration::from_nanos((est * 1e3).round() as u64)
        };
        let mut per_version: Vec<VersionCounts> = self
            .versions
            .iter()
            .filter(|s| s.version.load(Ordering::Relaxed) != 0)
            .map(|s| VersionCounts {
                version: s.version.load(Ordering::Relaxed),
                requests: s.requests.load(Ordering::Relaxed),
                errors: s.errors.load(Ordering::Relaxed),
            })
            .collect();
        per_version.sort_by_key(|v| v.version);
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            requests: answered,
            batches,
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed) as usize,
            mean_batch: if batches == 0 { 0.0 } else { answered as f64 / batches as f64 },
            queue_depth,
            queue_depth_peak: self.depth_peak.load(Ordering::Relaxed) as usize,
            model_version,
            p50: q(0.50),
            p99: q(0.99),
            p999: q(0.999),
            max_latency: Duration::from_micros(max_us),
            per_version,
            version_overflow: self.version_overflow.load(Ordering::Relaxed),
        }
    }
}

/// Histogram bucket for a latency of `us` microseconds.
fn bucket(us: u64) -> usize {
    let b = 63 - us.max(1).leading_zeros() as usize;
    b.min(BUCKETS - 1)
}

/// Estimated µs of the `q`-quantile observation: find the bucket holding
/// the target rank and interpolate linearly between its bounds by the
/// rank's position among the bucket's observations. Monotone in `q` by
/// construction (cumulative rank, monotone bucket bounds).
fn quantile_est_us(counts: &[u64; BUCKETS], total: u64, q: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let target = ((total as f64) * q).ceil().max(1.0);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let before = cum as f64;
        cum += c;
        if cum as f64 >= target {
            let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
            let hi = (1u64 << (i + 1)) as f64;
            let frac = (target - before) / c as f64;
            return lo + frac * (hi - lo);
        }
    }
    (1u64 << (BUCKETS - 1)) as f64 * 2.0
}

/// Per-model-version request/error attribution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VersionCounts {
    pub version: u64,
    /// Requests answered by this version.
    pub requests: u64,
    /// Requests this version fell back to scalar scoring on.
    pub errors: u64,
}

/// Immutable copy of the serve counters at one instant.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Requests rejected with `Overloaded`.
    pub rejected: u64,
    /// Requests answered (equals `submitted` once the queue is drained).
    pub requests: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Requests scored by the counted scalar fallback after an engine
    /// error (0 on any healthy run).
    pub fallbacks: u64,
    /// Batches whose scoring panicked (waiters notified by the dropped
    /// reply senders; the shard survived — 0 on any healthy run).
    pub panics: u64,
    /// Largest batch observed.
    pub max_batch: usize,
    /// Mean batch occupancy (`requests / batches`).
    pub mean_batch: f64,
    /// Queue depth when the snapshot was taken.
    pub queue_depth: usize,
    /// Peak queue depth observed at submission time.
    pub queue_depth_peak: usize,
    /// Registry version serving when the snapshot was taken.
    pub model_version: u64,
    /// Latency quantiles: within-bucket linear interpolation over the
    /// log₂ histogram, clamped to the exact observed maximum.
    pub p50: Duration,
    pub p99: Duration,
    pub p999: Duration,
    /// Exact maximum latency observed.
    pub max_latency: Duration,
    /// Per-model-version answer/error counts, ascending by version.
    pub per_version: Vec<VersionCounts>,
    /// Events whose version missed the fixed slot table (0 normally).
    pub version_overflow: u64,
}

impl fmt::Display for Snapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serve: {} answered / {} submitted ({} rejected), {} batches \
             (mean {:.1}, max {}), {} fallbacks, {} panics, p50 ~{:?}, \
             p99 ~{:?}, p999 ~{:?}, max {:?}, queue {} (peak {}), model v{}",
            self.requests,
            self.submitted,
            self.rejected,
            self.batches,
            self.mean_batch,
            self.max_batch,
            self.fallbacks,
            self.panics,
            self.p50,
            self.p99,
            self.p999,
            self.max_latency,
            self.queue_depth,
            self.queue_depth_peak,
            self.model_version
        )?;
        for v in &self.per_version {
            write!(f, ", v{}: {} req {} err", v.version, v.requests, v.errors)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(1023), 9);
        assert_eq!(bucket(1024), 10);
        assert_eq!(bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let m = ServeMetrics::new();
        // 99 fast answers (1µs, bucket 0 = [0,2)) and 1 slow (1000µs)
        for _ in 0..99 {
            m.on_answer(Duration::from_micros(1), 1);
        }
        m.on_answer(Duration::from_micros(1000), 1);
        let s = m.snapshot(0, 1);
        assert_eq!(s.requests, 100);
        // p50 = rank 50 of 99 in [0,2): ~1.0µs, far below the old 2µs
        // bucket upper bound
        assert!(s.p50 > Duration::from_nanos(500) && s.p50 < Duration::from_micros(2), "{:?}", s.p50);
        // p999 hits the slow observation's bucket [512,1024) but clamps
        // at the exact max 1000µs
        assert!(s.p999 <= Duration::from_micros(1000), "{:?}", s.p999);
        assert!(s.p999 >= Duration::from_micros(512), "{:?}", s.p999);
        assert_eq!(s.max_latency, Duration::from_micros(1000));
    }

    #[test]
    fn quantiles_are_monotone_and_capped_by_max() {
        let m = ServeMetrics::new();
        let mut state = 0x243f_6a88_85a3_08d3u64;
        for _ in 0..5000 {
            // deterministic xorshift latencies spanning several buckets
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            m.on_answer(Duration::from_micros(1 + state % 8192), 1);
        }
        let s = m.snapshot(0, 1);
        assert!(s.p50 <= s.p99, "p50 {:?} p99 {:?}", s.p50, s.p99);
        assert!(s.p99 <= s.p999, "p99 {:?} p999 {:?}", s.p99, s.p999);
        assert!(s.p999 <= s.max_latency, "p999 {:?} max {:?}", s.p999, s.max_latency);
        assert!(s.max_latency <= Duration::from_micros(8192));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = ServeMetrics::new();
        let s = m.snapshot(3, 7);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50, Duration::ZERO);
        assert_eq!(s.p999, Duration::ZERO);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.model_version, 7);
        assert!(s.per_version.is_empty());
    }

    #[test]
    fn counters_accumulate() {
        let m = ServeMetrics::new();
        m.on_submit(5);
        m.on_submit(2);
        m.on_reject();
        m.on_batch(4);
        m.on_batch(9);
        m.on_fallback(3, 1);
        m.on_panic();
        let s = m.snapshot(0, 1);
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_batch, 9);
        assert_eq!(s.fallbacks, 3);
        assert_eq!(s.panics, 1);
        assert_eq!(s.queue_depth_peak, 5);
        let line = s.to_string();
        assert!(line.contains("rejected") && line.contains("fallbacks"));
    }

    #[test]
    fn per_version_attribution_and_overflow() {
        let m = ServeMetrics::new();
        m.on_answer(Duration::from_micros(5), 1);
        m.on_answer(Duration::from_micros(5), 2);
        m.on_answer(Duration::from_micros(5), 2);
        m.on_fallback(4, 2);
        let s = m.snapshot(0, 2);
        assert_eq!(
            s.per_version,
            vec![
                VersionCounts { version: 1, requests: 1, errors: 0 },
                VersionCounts { version: 2, requests: 2, errors: 4 },
            ]
        );
        assert_eq!(s.version_overflow, 0);
        // exhaust the slot table: the spill lands in the overflow counter
        for v in 3..=(VERSION_SLOTS as u64 + 2) {
            m.on_answer(Duration::from_micros(5), v);
        }
        assert_eq!(m.snapshot(0, 2).version_overflow, 2);
        let line = m.snapshot(0, 2).to_string();
        assert!(line.contains("v2: 2 req 4 err"), "{line}");
    }
}
