//! Serve-time metrics: lock-free counters plus a log-bucketed latency
//! histogram, exposed as an immutable [`Snapshot`].
//!
//! Everything is a relaxed atomic — recording sits on the batcher hot
//! path and must cost a handful of nanoseconds, not a lock. The
//! histogram buckets latency at power-of-two microsecond boundaries
//! (bucket `i` covers `[2^i, 2^{i+1})` µs), so quantiles read from it
//! are *upper bounds* that overestimate by at most 2x — the honest
//! trade for a fixed-size, allocation-free histogram.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket count: bucket `i` covers `[2^i, 2^{i+1})` µs, the
/// last bucket absorbs the tail (2^31 µs ≈ 36 minutes).
const BUCKETS: usize = 32;

/// Shared, thread-safe serve counters. One instance per [`super::Server`];
/// clients record submissions/rejections, batcher shards record batches,
/// fallbacks and per-request latency.
pub struct ServeMetrics {
    submitted: AtomicU64,
    rejected: AtomicU64,
    answered: AtomicU64,
    batches: AtomicU64,
    fallbacks: AtomicU64,
    panics: AtomicU64,
    max_batch: AtomicU64,
    depth_peak: AtomicU64,
    latency: [AtomicU64; BUCKETS],
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        ServeMetrics {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            answered: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            depth_peak: AtomicU64::new(0),
            latency: [ZERO; BUCKETS],
        }
    }

    /// A request was admitted; `depth` is the queue depth it observed.
    pub(crate) fn on_submit(&self, depth: usize) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.depth_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// A request was rejected with `Overloaded`.
    pub(crate) fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch of `size` requests left the queue for the engine.
    pub(crate) fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.max_batch.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// `n` requests fell back to scalar scoring after an engine error.
    pub(crate) fn on_fallback(&self, n: usize) {
        self.fallbacks.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// A batch panicked while scoring (its waiters were notified by the
    /// dropped reply senders; the shard survived).
    pub(crate) fn on_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A response was sent `latency` after its request was enqueued.
    pub(crate) fn on_answer(&self, latency: Duration) {
        self.answered.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        self.latency[bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Engine-error fallback count so far (asserted zero by happy-path
    /// tests — an engine failure must never be silent).
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of every counter. `queue_depth` and
    /// `model_version` are gauges owned by the server, passed through.
    pub fn snapshot(&self, queue_depth: usize, model_version: u64) -> Snapshot {
        let mut counts = [0u64; BUCKETS];
        for (c, a) in counts.iter_mut().zip(self.latency.iter()) {
            *c = a.load(Ordering::Relaxed);
        }
        let total: u64 = counts.iter().sum();
        let answered = self.answered.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            requests: answered,
            batches,
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed) as usize,
            mean_batch: if batches == 0 { 0.0 } else { answered as f64 / batches as f64 },
            queue_depth,
            queue_depth_peak: self.depth_peak.load(Ordering::Relaxed) as usize,
            model_version,
            p50: Duration::from_micros(quantile_us(&counts, total, 0.50)),
            p99: Duration::from_micros(quantile_us(&counts, total, 0.99)),
        }
    }
}

/// Histogram bucket for a latency of `us` microseconds.
fn bucket(us: u64) -> usize {
    let b = 63 - us.max(1).leading_zeros() as usize;
    b.min(BUCKETS - 1)
}

/// Upper bound (µs) of the bucket holding the `q`-quantile observation.
fn quantile_us(counts: &[u64; BUCKETS], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * q).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return 1u64 << (i + 1);
        }
    }
    1u64 << BUCKETS
}

/// Immutable copy of the serve counters at one instant.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Requests rejected with `Overloaded`.
    pub rejected: u64,
    /// Requests answered (equals `submitted` once the queue is drained).
    pub requests: u64,
    /// Engine batches executed.
    pub batches: u64,
    /// Requests scored by the counted scalar fallback after an engine
    /// error (0 on any healthy run).
    pub fallbacks: u64,
    /// Batches whose scoring panicked (waiters notified by the dropped
    /// reply senders; the shard survived — 0 on any healthy run).
    pub panics: u64,
    /// Largest batch observed.
    pub max_batch: usize,
    /// Mean batch occupancy (`requests / batches`).
    pub mean_batch: f64,
    /// Queue depth when the snapshot was taken.
    pub queue_depth: usize,
    /// Peak queue depth observed at submission time.
    pub queue_depth_peak: usize,
    /// Registry version serving when the snapshot was taken.
    pub model_version: u64,
    /// Latency quantiles from the log-bucketed histogram — bucket upper
    /// bounds, i.e. overestimates by at most 2x.
    pub p50: Duration,
    pub p99: Duration,
}

impl fmt::Display for Snapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serve: {} answered / {} submitted ({} rejected), {} batches \
             (mean {:.1}, max {}), {} fallbacks, {} panics, p50 <= {:?}, \
             p99 <= {:?}, queue {} (peak {}), model v{}",
            self.requests,
            self.submitted,
            self.rejected,
            self.batches,
            self.mean_batch,
            self.max_batch,
            self.fallbacks,
            self.panics,
            self.p50,
            self.p99,
            self.queue_depth,
            self.queue_depth_peak,
            self.model_version
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(1023), 9);
        assert_eq!(bucket(1024), 10);
        assert_eq!(bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_read_bucket_upper_bounds() {
        let m = ServeMetrics::new();
        // 99 fast answers (1µs bucket 0) and 1 slow (1000µs bucket 9)
        for _ in 0..99 {
            m.on_answer(Duration::from_micros(1));
        }
        m.on_answer(Duration::from_micros(1000));
        let s = m.snapshot(0, 1);
        assert_eq!(s.requests, 100);
        assert_eq!(s.p50, Duration::from_micros(2));
        // p99 target is the 99th observation — still in the fast bucket;
        // the slow one is the 100th
        assert_eq!(s.p99, Duration::from_micros(2));
        m.on_answer(Duration::from_micros(1000));
        let s = m.snapshot(0, 1);
        assert_eq!(s.p99, Duration::from_micros(1024));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = ServeMetrics::new();
        let s = m.snapshot(3, 7);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50, Duration::ZERO);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.model_version, 7);
    }

    #[test]
    fn counters_accumulate() {
        let m = ServeMetrics::new();
        m.on_submit(5);
        m.on_submit(2);
        m.on_reject();
        m.on_batch(4);
        m.on_batch(9);
        m.on_fallback(3);
        m.on_panic();
        let s = m.snapshot(0, 1);
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_batch, 9);
        assert_eq!(s.fallbacks, 3);
        assert_eq!(s.panics, 1);
        assert_eq!(s.queue_depth_peak, 5);
        let line = s.to_string();
        assert!(line.contains("rejected") && line.contains("fallbacks"));
    }
}
