//! Sharded batching over a bounded queue with admission control.
//!
//! N batcher workers drain one bounded MPMC queue. Each worker blocks
//! for a first request, fills its batch for at most `max_wait`, snapshots
//! the registry's current model `Arc` (one coherent version per batch),
//! and runs one engine call for the whole batch — so with k shards, k
//! engine calls pipeline concurrently over the pool instead of
//! serializing behind a single batcher thread.
//!
//! Invariants (property-tested in `rust/tests/serve_props.rs`):
//!
//! * **Admission control** — a full queue rejects with
//!   [`SubmitError::Overloaded`] immediately; admitted requests are never
//!   silently dropped.
//! * **Exactly once** — every admitted request is answered exactly once,
//!   including across shutdown: `stop()` closes the queue to new
//!   submissions, workers drain what was already admitted (the seed's
//!   batcher broke on its shutdown sentinel and dropped everything queued
//!   behind it), and any stragglers are answered on the stopping thread.
//! * **Counted fallback** — an engine error never silently degrades:
//!   affected requests are scored by the scalar path and counted in
//!   [`ServeMetrics`] (happy-path tests assert the count is zero).

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::Engine;
use crate::serve::metrics::{ServeMetrics, Snapshot};
use crate::serve::registry::{CompiledModel, ModelRegistry, Servable};
use crate::serve::{Output, Response, ServeConfig};

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full — shed load now rather than letting the
    /// backlog (and tail latency) grow without bound.
    Overloaded,
    /// The server has been stopped.
    Closed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded => f.write_str("serve queue full (overloaded)"),
            SubmitError::Closed => f.write_str("server stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A prediction request in flight.
struct Request {
    id: u64,
    features: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Response>,
}

struct QueueInner {
    q: VecDeque<Request>,
    shutdown: bool,
}

/// Bounded MPMC request queue (mutex + condvar; contention is one push
/// or one batch-pop at a time, far below engine-call cost).
struct Queue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            inner: Mutex::new(QueueInner { q: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Admit a request, or reject immediately (never blocks).
    /// Returns the queue depth observed after the push.
    fn push(&self, req: Request) -> Result<usize, SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return Err(SubmitError::Closed);
        }
        if g.q.len() >= self.cap {
            return Err(SubmitError::Overloaded);
        }
        g.q.push_back(req);
        let depth = g.q.len();
        drop(g);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Pop one batch: block for a first request, then fill up to `max`
    /// for at most `max_wait`. Returns `None` only when the queue is
    /// shut down **and** empty — after `close()`, callers keep getting
    /// batches until everything admitted has been drained. During
    /// shutdown the fill wait is skipped so draining is prompt.
    fn pop_batch(&self, max: usize, max_wait: Duration) -> Option<Vec<Request>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.q.is_empty() {
                break;
            }
            if g.shutdown {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        let mut batch = Vec::with_capacity(max.min(g.q.len()));
        while batch.len() < max {
            match g.q.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        if batch.len() < max && !g.shutdown {
            let deadline = Instant::now() + max_wait;
            loop {
                while batch.len() < max {
                    match g.q.pop_front() {
                        Some(r) => batch.push(r),
                        None => break,
                    }
                }
                if batch.len() >= max || g.shutdown {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g2, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
                g = g2;
            }
        }
        let leftover = !g.q.is_empty();
        drop(g);
        if leftover {
            // a notify may have been consumed by this (now full) batch;
            // hand the remainder to another shard promptly
            self.not_empty.notify_one();
        }
        Some(batch)
    }

    /// Refuse new submissions and wake every waiter.
    fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shutdown = true;
        drop(g);
        self.not_empty.notify_all();
    }

    /// Pop one straggler (stop-time drain, after workers exited).
    fn drain_one(&self) -> Option<Request> {
        self.inner.lock().unwrap().q.pop_front()
    }

    fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }
}

/// Handle for submitting requests; cheap to clone, usable from any thread.
#[derive(Clone)]
pub struct Client {
    queue: Arc<Queue>,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServeMetrics>,
    next_id: Arc<AtomicU64>,
}

/// An admitted request's in-flight response handle.
pub struct Pending {
    pub id: u64,
    rx: Receiver<Response>,
}

impl Pending {
    /// Block for the response.
    pub fn wait(&self) -> Result<Response> {
        Ok(self.rx.recv()?)
    }

    /// Non-blocking poll (the exactly-once tests use this to assert no
    /// second response ever arrives).
    pub fn try_take(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }
}

impl Client {
    /// Submit one request. Rejection (`Overloaded`/`Closed`) is
    /// immediate — admission control never blocks the caller.
    pub fn submit(&self, features: Vec<f32>) -> Result<Pending, SubmitError> {
        assert_eq!(
            features.len(),
            self.registry.input_dim(),
            "feature dim mismatch"
        );
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, features, enqueued: Instant::now(), reply: tx };
        match self.queue.push(req) {
            Ok(depth) => {
                self.metrics.on_submit(depth);
                Ok(Pending { id, rx })
            }
            Err(e) => {
                if e == SubmitError::Overloaded {
                    self.metrics.on_reject();
                }
                Err(e)
            }
        }
    }

    /// Submit and block for the output (error if rejected or stopped).
    pub fn predict(&self, features: Vec<f32>) -> Result<Output> {
        let p = self.submit(features)?;
        Ok(p.wait()?.output)
    }
}

/// Running server: a registry, a bounded queue and its batcher shards.
pub struct Server {
    queue: Arc<Queue>,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServeMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: Arc<AtomicU64>,
}

impl Server {
    /// Compile `model` into a fresh single-version registry and serve it.
    pub fn start(model: &dyn Servable, engine: Engine, cfg: ServeConfig) -> Server {
        Server::with_registry(Arc::new(ModelRegistry::new(model)), engine, cfg)
    }

    /// Serve an existing (possibly shared) registry.
    pub fn with_registry(
        registry: Arc<ModelRegistry>,
        engine: Engine,
        cfg: ServeConfig,
    ) -> Server {
        let queue = Arc::new(Queue::new(cfg.queue_cap));
        let metrics = Arc::new(ServeMetrics::new());
        let workers = (0..cfg.shards)
            .map(|s| {
                let q = queue.clone();
                let r = registry.clone();
                let m = metrics.clone();
                let e = engine.clone();
                let c = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("wu-svm-serve-{s}"))
                    .spawn(move || worker_loop(&q, &r, &e, &c, &m))
                    .expect("spawn serve shard")
            })
            .collect();
        Server {
            queue,
            registry,
            metrics,
            workers,
            next_id: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn client(&self) -> Client {
        Client {
            queue: self.queue.clone(),
            registry: self.registry.clone(),
            metrics: self.metrics.clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// The registry backing this server (for out-of-band hot swaps).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Compile and hot-swap a new model version; in-flight batches finish
    /// on the version they started with. Returns the new version id.
    pub fn publish(&self, model: &dyn Servable) -> Result<u64> {
        self.registry.publish(model)
    }

    /// Live metrics handle.
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        self.metrics.clone()
    }

    /// Point-in-time counters.
    pub fn snapshot(&self) -> Snapshot {
        self.metrics.snapshot(self.queue.depth(), self.registry.version())
    }

    /// Stop serving: refuse new submissions, let the shards drain every
    /// admitted request, then answer any stragglers on this thread (only
    /// possible with `shards == 0`). Every admitted request is answered
    /// exactly once. Returns the final counters.
    pub fn stop(mut self) -> Snapshot {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let model = self.registry.current();
        while let Some(req) = self.queue.drain_one() {
            let out = model.score_scalar(&req.features);
            let lat = req.enqueued.elapsed();
            let _ = req
                .reply
                .send(Response { id: req.id, version: model.version, output: out });
            self.metrics.on_answer(lat, model.version);
        }
        self.snapshot()
    }
}

fn worker_loop(
    queue: &Queue,
    registry: &ModelRegistry,
    engine: &Engine,
    cfg: &ServeConfig,
    metrics: &ServeMetrics,
) {
    while let Some(batch) = queue.pop_batch(cfg.batch, cfg.max_wait) {
        // one model snapshot per batch: a hot swap mid-batch never mixes
        // versions inside a batch, and the load happens strictly after
        // every request in the batch was admitted
        let model = registry.current();
        metrics.on_batch(batch.len());
        // a panic while scoring (e.g. a malformed model) must not kill
        // the shard: the poisoned batch's reply senders drop (waiters see
        // an error, not a hang), the panic is counted, and the shard
        // lives on to serve the next batch
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&model, engine, batch, metrics);
        }))
        .is_err();
        if poisoned {
            metrics.on_panic();
        }
    }
}

fn process_batch(
    model: &CompiledModel,
    engine: &Engine,
    batch: Vec<Request>,
    metrics: &ServeMetrics,
) {
    let t = batch.len();
    let d = model.d;
    let mut x = vec![0.0f32; t * d];
    for (i, r) in batch.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(&r.features);
    }
    let outputs = match model.score_batch(engine, &x, t) {
        Ok(o) => o,
        Err(_) => {
            // engine failed (e.g. an xla runtime went away): degrade to
            // scalar scoring, but never silently — the counter is
            // asserted zero by every happy-path test
            metrics.on_fallback(t, model.version);
            batch.iter().map(|r| model.score_scalar(&r.features)).collect()
        }
    };
    for (r, out) in batch.into_iter().zip(outputs) {
        let lat = r.enqueued.elapsed();
        let _ = r
            .reply
            .send(Response { id: r.id, version: model.version, output: out });
        metrics.on_answer(lat, model.version);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelKind;
    use crate::model::SvmModel;

    fn model() -> SvmModel {
        SvmModel {
            kernel: KernelKind::Rbf { gamma: 0.5 },
            vectors: vec![0.0, 0.0, 1.0, 1.0],
            d: 2,
            coef: vec![1.0, -1.0],
            bias: 0.1,
            solver: "t".into(),
        }
    }

    #[test]
    fn serves_correct_margins() {
        let m = model();
        let expect = m.decision(&[0.25, 0.75]);
        let server = Server::start(&m, Engine::cpu_seq(), ServeConfig::default());
        let client = server.client();
        let got = client.predict(vec![0.25, 0.75]).unwrap().margin().unwrap();
        assert!((got - expect).abs() < 1e-5, "{got} vs {expect}");
        let stats = server.stop();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let m = model();
        let server = Server::start(
            &m,
            Engine::cpu_par(2),
            ServeConfig {
                batch: 16,
                max_wait: Duration::from_millis(5),
                shards: 2,
                queue_cap: 4096,
            },
        );
        let client = server.client();
        let pending: Vec<(Pending, Vec<f32>)> = (0..200)
            .map(|i| {
                let f = vec![(i as f32) / 200.0, 0.5];
                (client.submit(f.clone()).unwrap(), f)
            })
            .collect();
        for (p, f) in pending {
            let resp = p.wait().unwrap();
            assert_eq!(resp.id, p.id);
            assert!((resp.output.margin().unwrap() - m.decision(&f)).abs() < 1e-4);
            assert!(p.try_take().is_none(), "second response for one request");
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 200);
        assert_eq!(stats.submitted, 200);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.fallbacks, 0);
        assert_eq!(stats.panics, 0);
        assert!(stats.max_batch <= 16);
        assert!(stats.batches >= 200 / 16);
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::start(&model(), Engine::cpu_seq(), ServeConfig::default());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = server.client();
                std::thread::spawn(move || {
                    let m = model();
                    for i in 0..50 {
                        let f = vec![(t as f32) / 8.0, (i as f32) / 50.0];
                        let got = c.predict(f.clone()).unwrap().margin().unwrap();
                        assert!((got - m.decision(&f)).abs() < 1e-4);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 400);
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn shutdown_drains_already_enqueued_requests() {
        // regression: the seed's batcher broke on its shutdown sentinel
        // and dropped every request queued behind it without a response
        for &shards in &[0usize, 1, 4] {
            let m = model();
            let server = Server::start(
                &m,
                Engine::cpu_seq(),
                ServeConfig {
                    batch: 8,
                    max_wait: Duration::from_millis(1),
                    shards,
                    queue_cap: 4096,
                },
            );
            let client = server.client();
            let pending: Vec<(Pending, Vec<f32>)> = (0..120)
                .map(|i| {
                    let f = vec![(i as f32) / 120.0, 0.25];
                    (client.submit(f.clone()).unwrap(), f)
                })
                .collect();
            // stop immediately: everything admitted must still be answered
            let stats = server.stop();
            assert_eq!(stats.requests, 120, "shards={shards}");
            for (p, f) in pending {
                let resp = p.wait().expect("admitted request dropped at shutdown");
                assert!(
                    (resp.output.margin().unwrap() - m.decision(&f)).abs() < 1e-4,
                    "shards={shards}"
                );
                assert!(p.try_take().is_none());
            }
            // the queue is closed: new submissions fail fast
            assert_eq!(
                client.submit(vec![0.0, 0.0]).err(),
                Some(SubmitError::Closed),
                "shards={shards}"
            );
        }
    }

    #[test]
    fn overload_rejects_immediately_instead_of_hanging() {
        // no workers: the queue fills deterministically to its cap
        let m = model();
        let server = Server::start(
            &m,
            Engine::cpu_seq(),
            ServeConfig {
                batch: 4,
                max_wait: Duration::from_millis(1),
                shards: 0,
                queue_cap: 4,
            },
        );
        let client = server.client();
        let admitted: Vec<Pending> =
            (0..4).map(|_| client.submit(vec![0.5, 0.5]).unwrap()).collect();
        for _ in 0..3 {
            assert_eq!(
                client.submit(vec![0.5, 0.5]).err(),
                Some(SubmitError::Overloaded)
            );
        }
        let stats = server.stop();
        assert_eq!(stats.rejected, 3);
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.requests, 4, "admitted requests answered at stop");
        for p in admitted {
            assert!(p.wait().is_ok());
        }
    }

    #[test]
    fn predict_surfaces_rejection_as_error() {
        let server = Server::start(
            &model(),
            Engine::cpu_seq(),
            ServeConfig { shards: 0, queue_cap: 1, ..Default::default() },
        );
        let client = server.client();
        let _held = client.submit(vec![0.1, 0.2]).unwrap();
        let err = client.predict(vec![0.3, 0.4]).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "{err}");
        server.stop();
    }
}
