//! Training-job coordination: one place that wires datasets, solvers and
//! engines together (used by the CLI, the examples and the bench
//! harness). A [`TrainJob`] compiles to a [`Trainer`]
//! ([`TrainJob::trainer`]); the only per-solver dispatch left here is
//! hyperparameter construction in [`TrainJob::solver_spec`] — caches,
//! thread counts, iteration caps and observers all travel through the
//! unified API. Serving lives in [`crate::serve`].

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::data::{libsvm, pack, paper, Dataset, Format};
use crate::engine::Engine;
use crate::kernel::cache::CacheBudget;
use crate::kernel::KernelKind;
use crate::metrics::{auc, error_rate, multiclass_error};
use crate::multiclass::OvoModel;
use crate::pool;
use crate::runtime::{default_artifacts_dir, XlaRuntime};
use crate::kernel::operator::LowRankConfig;
use crate::solvers::api::{Budget, SolverSpec, Trainer};
use crate::solvers::{lssvm, mu, primal, smo, spsvm, wss};

/// Which solver to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Solver {
    Smo,
    Wss,
    Mu,
    Primal,
    SpSvm,
    LsSvm,
}

impl Solver {
    pub fn parse(s: &str) -> Result<Solver> {
        Ok(match s {
            "smo" | "libsvm" => Solver::Smo,
            "wss" | "gtsvm" => Solver::Wss,
            "mu" => Solver::Mu,
            "primal" => Solver::Primal,
            "spsvm" | "wusvm" => Solver::SpSvm,
            "lssvm" | "plssvm" => Solver::LsSvm,
            _ => bail!("unknown solver '{s}' (smo|wss|mu|primal|spsvm|lssvm)"),
        })
    }
}

/// Which engine executes the heavy ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    CpuSeq,
    CpuPar(usize),
    Xla,
}

impl EngineChoice {
    pub fn parse(s: &str, threads: usize) -> Result<EngineChoice> {
        Ok(match s {
            "cpu-seq" | "sc" => EngineChoice::CpuSeq,
            "cpu-par" | "mc" => EngineChoice::CpuPar(threads),
            "xla" | "gpu" => EngineChoice::Xla,
            _ => bail!("unknown engine '{s}' (cpu-seq|cpu-par|xla)"),
        })
    }

    /// Table-1 architecture label.
    pub fn arch(&self) -> &'static str {
        match self {
            EngineChoice::CpuSeq => "SC",
            EngineChoice::CpuPar(_) => "MC",
            EngineChoice::Xla => "XLA",
        }
    }
}

/// A fully specified training job.
#[derive(Debug, Clone)]
pub struct TrainJob {
    pub dataset: String,
    pub scale: f64,
    pub solver: Solver,
    pub engine: EngineChoice,
    pub c: Option<f32>,
    pub gamma: Option<f32>,
    pub eps: Option<f64>,
    pub max_basis: usize,
    pub wss_size: usize,
    /// Pivoted-ICF rank for implicit solvers (`--rank`; 0 = exact).
    pub rank: Option<usize>,
    /// Nyström landmark count (`--landmarks`; excludes `--rank`).
    pub landmarks: Option<usize>,
    /// Resolved kernel-row cache size in MB (from `--cache-mb N|auto`).
    pub cache_mb: usize,
    /// What the user asked for (`auto` resolves via available RAM at
    /// [`TrainJob::from_config`] time; kept for reporting).
    pub cache_budget: CacheBudget,
    /// Cache-aware WSS slack (`--cache-slack`, 0 = off). Explicit dual
    /// solvers only.
    pub cache_slack: f64,
    /// Polishing phase after convergence (`--polish`). Explicit dual
    /// solvers only.
    pub polish: bool,
    pub seed: u64,
    /// Cap on training rows (0 = spec size * scale).
    pub max_train: usize,
    /// Wall-clock training budget in seconds (`--time-budget-secs`).
    pub time_budget_secs: Option<f64>,
    /// Iteration budget in the solver's own unit (`--max-iters`).
    pub max_iters: Option<usize>,
    /// Train from this libsvm file instead of a generated analog
    /// (`--input`; `dataset` is ignored when set).
    pub input: Option<String>,
    /// Evaluation libsvm file (`--test-input`; defaults to an 80/20
    /// split of `input`).
    pub test_input: Option<String>,
    /// Design-matrix storage (`--format dense|csr|auto`; auto = CSR at
    /// or below the density threshold). Applies to files *and* to
    /// generated analogs, so `--dataset kdd99 --format csr` exercises
    /// the sparse path too.
    pub format: Format,
    /// Cascade sharded training (`--cascade-shards S`): 0/1 = off, S > 1
    /// wraps the (dual) solver in [`crate::cascade::CascadeParams`].
    pub cascade_shards: usize,
    /// Merge-layer cap (`--cascade-layers auto|L`; `None` = auto).
    pub cascade_layers: Option<usize>,
    /// Global KKT sweep tolerance (`--cascade-kkt-tol`).
    pub cascade_kkt_tol: f64,
}

impl Default for TrainJob {
    fn default() -> Self {
        TrainJob {
            dataset: "adult".into(),
            scale: 0.05,
            solver: Solver::SpSvm,
            engine: EngineChoice::CpuPar(pool::default_threads()),
            c: None,
            gamma: None,
            eps: None,
            max_basis: 255,
            wss_size: 16,
            rank: None,
            landmarks: None,
            cache_mb: 512,
            cache_budget: CacheBudget::Mb(512),
            cache_slack: 0.0,
            polish: false,
            seed: 1,
            max_train: 0,
            time_budget_secs: None,
            max_iters: None,
            input: None,
            test_input: None,
            format: Format::Dense,
            cascade_shards: 0,
            cascade_layers: None,
            cascade_kkt_tol: 1e-3,
        }
    }
}

/// CLI keys [`TrainJob::from_config`] understands (plus the generic
/// `config`/`save` keys and the `profile`/`trace-json` trace exporters
/// handled in `main`) — the `check_known` allowlist for `wu-svm train`.
pub const TRAIN_KEYS: &[&str] = &[
    "dataset",
    "scale",
    "solver",
    "engine",
    "threads",
    "c",
    "gamma",
    "eps",
    "max-basis",
    "wss-size",
    "rank",
    "landmarks",
    "cache-mb",
    "cache-slack",
    "polish",
    "seed",
    "max-train",
    "time-budget-secs",
    "max-iters",
    "input",
    "test-input",
    "format",
    "cascade-shards",
    "cascade-layers",
    "cascade-kkt-tol",
    "config",
    "save",
    "profile",
    "trace-json",
];

impl TrainJob {
    /// Build from parsed CLI config.
    pub fn from_config(cfg: &Config) -> Result<TrainJob> {
        let threads = cfg.usize_or("threads", pool::default_threads())?;
        let mut job = TrainJob::default();
        job.dataset = cfg.str_or("dataset", &job.dataset);
        job.scale = cfg.f64_or("scale", job.scale)?;
        job.solver = Solver::parse(&cfg.str_or("solver", "spsvm"))?;
        job.engine = EngineChoice::parse(&cfg.str_or("engine", "cpu-par"), threads)?;
        job.c = cfg.get("c").map(|v| v.parse()).transpose()?;
        job.gamma = cfg.get("gamma").map(|v| v.parse()).transpose()?;
        job.eps = cfg.get("eps").map(|v| v.parse()).transpose()?;
        job.max_basis = cfg.usize_or("max-basis", job.max_basis)?;
        job.wss_size = cfg.usize_or("wss-size", job.wss_size)?;
        job.rank = cfg.get("rank").map(|v| v.parse()).transpose()?;
        job.landmarks = cfg.get("landmarks").map(|v| v.parse()).transpose()?;
        if job.rank.is_some() && job.landmarks.is_some() {
            bail!(
                "--rank and --landmarks are mutually exclusive \
                 (--rank = pivoted-ICF width, --landmarks = Nystrom landmark count)"
            );
        }
        if matches!(job.solver, Solver::Smo | Solver::Wss)
            && (job.rank.is_some() || job.landmarks.is_some())
        {
            bail!(
                "--rank/--landmarks only apply to the implicit family — {:?} computes \
                 exact kernel rows; drop the flag or pick --solver mu|primal|spsvm|lssvm",
                job.solver
            );
        }
        job.cache_budget = CacheBudget::parse(&cfg.str_or("cache-mb", "512"))?;
        job.cache_mb = job.cache_budget.resolve_mb();
        job.cache_slack = cfg.f64_or("cache-slack", 0.0)?;
        job.polish = cfg.bool_or("polish", false)?;
        if (job.polish || job.cache_slack != 0.0)
            && !matches!(job.solver, Solver::Smo | Solver::Wss)
        {
            bail!(
                "--polish/--cache-slack apply to the explicit dual solvers \
                 (--solver smo|wss), got {:?}",
                job.solver
            );
        }
        if !(0.0..1.0).contains(&job.cache_slack) {
            bail!("--cache-slack must be in [0, 1), got {}", job.cache_slack);
        }
        job.seed = cfg.u64_or("seed", job.seed)?;
        job.max_train = cfg.usize_or("max-train", 0)?;
        job.time_budget_secs = cfg.get("time-budget-secs").map(|v| v.parse()).transpose()?;
        job.max_iters = cfg.get("max-iters").map(|v| v.parse()).transpose()?;
        job.input = cfg.get("input").map(|v| v.to_string());
        job.test_input = cfg.get("test-input").map(|v| v.to_string());
        // files default to auto (sparse sources stay sparse); generated
        // analogs default to the seed's dense representation
        let fmt_default = if job.input.is_some() { "auto" } else { "dense" };
        job.format = Format::parse(&cfg.str_or("format", fmt_default))?;
        job.cascade_shards = cfg.usize_or("cascade-shards", job.cascade_shards)?;
        job.cascade_layers = match cfg.get("cascade-layers") {
            None | Some("auto") => None,
            Some(v) => Some(v.parse()?),
        };
        job.cascade_kkt_tol = cfg.f64_or("cascade-kkt-tol", job.cascade_kkt_tol)?;
        if job.cascade_shards > 1 && !matches!(job.solver, Solver::Smo | Solver::Wss) {
            bail!(
                "--cascade-shards requires a dual solver whose alphas can be merged \
                 (--solver smo|wss), got {:?}",
                job.solver
            );
        }
        Ok(job)
    }

    /// Low-rank operator request from the CLI flags: `--landmarks M`
    /// picks Nyström, `--rank R` picks pivoted ICF, `--rank 0` forces
    /// the exact path, neither flag leaves the solver's default.
    fn lowrank(&self) -> Option<LowRankConfig> {
        match (self.rank, self.landmarks) {
            (_, Some(m)) => Some(LowRankConfig::nystrom(m)),
            (Some(0), _) => None,
            (Some(r), _) => Some(LowRankConfig::icf(r)),
            (None, None) => None,
        }
    }

    /// The job's stopping policy: CLI budget keys, or solver defaults.
    pub fn budget(&self) -> Budget {
        Budget {
            max_iters: self.max_iters,
            wall: self.time_budget_secs.map(Duration::from_secs_f64),
            target_objective: None,
        }
    }

    /// Solver hyperparameters for this job — the one remaining
    /// per-solver dispatch in the coordinator. Everything environmental
    /// (engine, kernel, cache, budget) rides on the [`Trainer`] instead.
    pub fn solver_spec(&self, spec: &paper::PaperSpec) -> SolverSpec {
        let c = self.c.unwrap_or(spec.c);
        let base = match self.solver {
            Solver::Smo => SolverSpec::Smo(smo::SmoParams {
                c,
                eps: self.eps.unwrap_or(1e-3),
                cache_mb: self.cache_mb,
                cache_slack: self.cache_slack,
                polish: self.polish,
                ..Default::default()
            }),
            Solver::Wss => SolverSpec::Wss(wss::WssParams {
                c,
                s: self.wss_size,
                eps: self.eps.unwrap_or(1e-3),
                cache_mb: self.cache_mb,
                cache_slack: self.cache_slack,
                polish: self.polish,
                ..Default::default()
            }),
            Solver::Mu => SolverSpec::Mu(mu::MuParams {
                c,
                lowrank: self.lowrank(),
                ..Default::default()
            }),
            Solver::Primal => SolverSpec::Primal(primal::PrimalParams {
                c,
                lowrank: self.lowrank(),
                ..Default::default()
            }),
            Solver::SpSvm => SolverSpec::SpSvm(spsvm::SpSvmParams {
                c,
                gamma: self.gamma.unwrap_or(spec.gamma),
                max_basis: self.max_basis,
                eps: self.eps.unwrap_or(5e-6),
                seed: self.seed,
                lowrank: self.lowrank(),
                ..Default::default()
            }),
            // lssvm defaults to rank-256 ICF; `--rank 0` opts into the
            // exact memory-capped path.
            Solver::LsSvm => SolverSpec::LsSvm(lssvm::LsSvmParams {
                c,
                lowrank: match (self.rank, self.landmarks) {
                    (Some(0), _) => None,
                    (None, None) => Some(LowRankConfig::icf(256)),
                    _ => self.lowrank(),
                },
                ..Default::default()
            }),
        };
        if self.cascade_shards > 1 {
            return SolverSpec::Cascade(crate::cascade::CascadeParams {
                shards: self.cascade_shards,
                layers: self.cascade_layers,
                kkt_tol: self.cascade_kkt_tol,
                seed: self.seed,
                cache_mb: self.cache_mb,
                inner: Box::new(base),
                ..Default::default()
            });
        }
        base
    }

    /// Compile the job into a ready-to-run [`Trainer`] on `engine`.
    pub fn trainer(&self, spec: &paper::PaperSpec, engine: &Engine) -> Trainer {
        Trainer::new(self.solver_spec(spec))
            .kernel(KernelKind::Rbf { gamma: self.gamma.unwrap_or(spec.gamma) })
            .engine(engine.clone())
            .budget(self.budget())
    }
}

/// Outcome of a run, ready for reporting.
#[derive(Debug)]
pub struct RunRecord {
    pub job: TrainJob,
    pub metric_name: String,
    /// Test error or (1-AUC), fraction.
    pub test_metric: f64,
    pub train_time: Duration,
    pub n_train: usize,
    pub n_test: usize,
    pub expansion_size: usize,
    pub notes: Vec<(String, String)>,
}

/// Shared, lazily created XLA runtime (compiling artifacts once per
/// process regardless of how many jobs run).
static XLA_RT: once_cell::sync::OnceCell<Arc<XlaRuntime>> = once_cell::sync::OnceCell::new();

pub fn shared_runtime() -> Result<Arc<XlaRuntime>> {
    if let Some(rt) = XLA_RT.get() {
        return Ok(rt.clone());
    }
    let rt = Arc::new(XlaRuntime::load(&default_artifacts_dir())?);
    let _ = XLA_RT.set(rt.clone());
    Ok(rt)
}

pub fn build_engine(choice: EngineChoice) -> Result<Engine> {
    Ok(match choice {
        EngineChoice::CpuSeq => Engine::cpu_seq(),
        EngineChoice::CpuPar(t) => Engine::cpu_par(t),
        EngineChoice::Xla => Engine::xla(shared_runtime()?),
    })
}

/// Load the job's dataset pair: a libsvm or `wu-svm pack`ed file when
/// `input` is set (sniffed by magic, no flag needed; test from
/// `test_input`, else an 80/20 split), a generated paper analog
/// otherwise. Either source lands in the job's requested storage
/// [`Format`] before any solver sees it — except packed inputs under
/// `--format auto`, which stay mmap-backed (the out-of-core path; note
/// that splitting or subsampling a packed input materializes the
/// selection in memory, so pass `--test-input` to keep the whole
/// training design on disk).
pub fn load_data(job: &TrainJob) -> Result<(Dataset, Dataset, paper::PaperSpec)> {
    let read_any = |path: &str, d_hint: usize| -> Result<Dataset> {
        let p = std::path::Path::new(path);
        if pack::is_packed_file(p) {
            // Auto keeps the design mmap-backed; an explicit dense/csr
            // request materializes it in memory
            Ok(pack::load_packed(p)?.with_format(job.format))
        } else {
            libsvm::read_file_with(p, d_hint, job.format)
        }
    };
    if let Some(path) = &job.input {
        let full = read_any(path, 0)?;
        let (mut tr, te) = match &job.test_input {
            Some(tp) => {
                let te = read_any(tp, full.d)?;
                (full, te)
            }
            None => full.split(0.8, job.seed),
        };
        if job.max_train > 0 && tr.n > job.max_train {
            tr = tr.subsample(job.max_train, job.seed ^ 0xfeed);
        }
        let spec = paper::PaperSpec::external(tr.d, tr.num_classes());
        return Ok((tr, te, spec));
    }
    let spec = paper::spec(&job.dataset)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown dataset '{}' (one of: {})",
            job.dataset,
            paper::specs().iter().map(|s| s.key).collect::<Vec<_>>().join(", ")
        ))?;
    let (mut tr, te) = spec.generate(job.scale, job.seed);
    if job.max_train > 0 && tr.n > job.max_train {
        tr = tr.subsample(job.max_train, job.seed ^ 0xfeed);
    }
    Ok((tr.with_format(job.format), te.with_format(job.format), spec))
}

/// Run a training job end to end (train + evaluate).
pub fn run(job: &TrainJob) -> Result<RunRecord> {
    let (train_ds, test_ds, spec) = load_data(job)?;
    let engine = build_engine(job.engine)?;
    let eval_threads = pool::default_threads();
    let trainer = job.trainer(&spec, &engine);

    let t0 = std::time::Instant::now();
    if train_ds.is_multiclass() {
        // OvO: report the *accumulated* per-pair training time (Table-1
        // convention) so sequential and concurrent runs stay comparable;
        // the wall clock of the concurrent run goes in the notes.
        let ovo = OvoModel::train_with(&train_ds, &trainer, job.cache_mb)?;
        let wall = t0.elapsed();
        let train_time = Duration::from_secs_f64(ovo.train_secs);
        let pred = ovo.predict(&test_ds, eval_threads);
        let err = multiclass_error(&pred, &test_ds.class_ids);
        return Ok(RunRecord {
            job: job.clone(),
            metric_name: "error".into(),
            test_metric: err,
            train_time,
            n_train: train_ds.n,
            n_test: test_ds.n,
            expansion_size: ovo.total_vectors(),
            notes: vec![
                ("pairs".into(), ovo.pairs.len().to_string()),
                ("wall_secs".into(), format!("{:.3}", wall.as_secs_f64())),
                ("storage".into(), train_ds.design.storage().into()),
                ("cache_budget_mb".into(), job.cache_mb.to_string()),
            ],
        });
    }

    let r = trainer.train(&train_ds)?;
    let (model, mut notes) = (r.model, r.notes);
    notes.push(("storage".into(), train_ds.design.storage().into()));
    notes.push(("cache_budget_mb".into(), job.cache_mb.to_string()));
    let train_time = t0.elapsed();
    let margins = model.decision_batch(&test_ds, eval_threads);
    let (metric_name, metric) = match spec.metric {
        paper::Metric::Error => ("error".to_string(), error_rate(&margins, &test_ds.y)),
        paper::Metric::OneMinusAuc => {
            ("1-auc".to_string(), 1.0 - auc(&margins, &test_ds.y))
        }
    };
    Ok(RunRecord {
        job: job.clone(),
        metric_name,
        test_metric: metric,
        train_time,
        n_train: train_ds.n,
        n_test: test_ds.n,
        expansion_size: model.num_vectors(),
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_and_engine_parsing() {
        assert_eq!(Solver::parse("libsvm").unwrap(), Solver::Smo);
        assert_eq!(Solver::parse("wusvm").unwrap(), Solver::SpSvm);
        assert_eq!(Solver::parse("lssvm").unwrap(), Solver::LsSvm);
        assert_eq!(Solver::parse("plssvm").unwrap(), Solver::LsSvm);
        assert!(Solver::parse("nope").is_err());
        assert_eq!(EngineChoice::parse("mc", 4).unwrap(), EngineChoice::CpuPar(4));
        assert_eq!(EngineChoice::parse("xla", 4).unwrap(), EngineChoice::Xla);
        assert!(EngineChoice::parse("quantum", 1).is_err());
    }

    #[test]
    fn job_from_config() {
        let cfg = Config::from_args(&[
            "--dataset".into(),
            "covertype".into(),
            "--solver".into(),
            "smo".into(),
            "--engine".into(),
            "cpu-seq".into(),
            "--scale".into(),
            "0.01".into(),
            "--c".into(),
            "2.5".into(),
        ])
        .unwrap();
        let job = TrainJob::from_config(&cfg).unwrap();
        assert_eq!(job.dataset, "covertype");
        assert_eq!(job.solver, Solver::Smo);
        assert_eq!(job.engine, EngineChoice::CpuSeq);
        assert_eq!(job.c, Some(2.5));
    }

    #[test]
    fn lowrank_flags_from_config() {
        let cfg = |args: &[&str]| {
            Config::from_args(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        // --rank on an implicit solver -> ICF of that width
        let job =
            TrainJob::from_config(&cfg(&["--solver", "primal", "--rank", "64"])).unwrap();
        assert_eq!(job.lowrank(), Some(LowRankConfig::icf(64)));
        // --landmarks -> Nystrom
        let job =
            TrainJob::from_config(&cfg(&["--solver", "lssvm", "--landmarks", "32"])).unwrap();
        assert_eq!(job.lowrank(), Some(LowRankConfig::nystrom(32)));
        // --rank 0 -> exact, even on lssvm (which defaults to ICF 256)
        let job = TrainJob::from_config(&cfg(&["--solver", "lssvm", "--rank", "0"])).unwrap();
        assert_eq!(job.lowrank(), None);
        match job.solver_spec(&paper::spec("adult").unwrap()) {
            SolverSpec::LsSvm(p) => assert!(p.lowrank.is_none()),
            other => panic!("expected lssvm spec, got {}", other.driver().name()),
        }
        // both flags at once is a contradiction
        let err = TrainJob::from_config(&cfg(&[
            "--solver", "mu", "--rank", "8", "--landmarks", "8",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        // explicit-family solvers compute exact rows; the flag is an error
        let err =
            TrainJob::from_config(&cfg(&["--solver", "smo", "--rank", "64"])).unwrap_err();
        assert!(err.to_string().contains("implicit family"), "{err}");
    }

    #[test]
    fn budget_keys_from_config() {
        let cfg = Config::from_args(&[
            "--time-budget-secs".into(),
            "1.5".into(),
            "--max-iters".into(),
            "42".into(),
        ])
        .unwrap();
        let job = TrainJob::from_config(&cfg).unwrap();
        assert_eq!(job.max_iters, Some(42));
        assert_eq!(job.time_budget_secs, Some(1.5));
        let b = job.budget();
        assert_eq!(b.max_iters, Some(42));
        assert_eq!(b.wall, Some(Duration::from_secs_f64(1.5)));
        assert_eq!(b.target_objective, None);
    }

    #[test]
    fn train_keys_cover_from_config() {
        // every key from_config reads must be in the check_known allowlist
        for k in [
            "dataset", "scale", "solver", "engine", "threads", "c", "gamma", "eps",
            "max-basis", "wss-size", "rank", "landmarks", "cache-mb", "cache-slack",
            "polish", "seed", "max-train",
            "time-budget-secs", "max-iters", "cascade-shards", "cascade-layers",
            "cascade-kkt-tol",
        ] {
            assert!(TRAIN_KEYS.contains(&k), "{k} missing from TRAIN_KEYS");
        }
        let cfg = Config::from_args(&["--oops".into(), "1".into()]).unwrap();
        assert!(cfg.check_known(TRAIN_KEYS).is_err());
    }

    #[test]
    fn cascade_keys_from_config() {
        let cfg = |args: &[&str]| {
            Config::from_args(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
        };
        let job = TrainJob::from_config(&cfg(&[
            "--solver",
            "smo",
            "--cascade-shards",
            "4",
            "--cascade-layers",
            "auto",
            "--cascade-kkt-tol",
            "0.01",
        ]))
        .unwrap();
        assert_eq!(job.cascade_shards, 4);
        assert_eq!(job.cascade_layers, None);
        assert_eq!(job.cascade_kkt_tol, 0.01);
        match job.solver_spec(&paper::spec("adult").unwrap()) {
            SolverSpec::Cascade(p) => {
                assert_eq!(p.shards, 4);
                assert_eq!(p.kkt_tol, 0.01);
                assert!(matches!(*p.inner, SolverSpec::Smo(_)));
            }
            other => panic!("expected cascade spec, got {}", other.name()),
        }
        // explicit layer cap parses as a number
        let job =
            TrainJob::from_config(&cfg(&["--solver", "wss", "--cascade-layers", "3"])).unwrap();
        assert_eq!(job.cascade_layers, Some(3));
        // a non-dual inner solver is rejected up front
        let err = TrainJob::from_config(&cfg(&["--solver", "mu", "--cascade-shards", "2"]))
            .unwrap_err();
        assert!(err.to_string().contains("dual solver"), "{err}");
        // shards <= 1 leaves the spec unwrapped
        let job = TrainJob::from_config(&cfg(&["--solver", "smo"])).unwrap();
        assert!(matches!(
            job.solver_spec(&paper::spec("adult").unwrap()),
            SolverSpec::Smo(_)
        ));
    }

    #[test]
    fn budgeted_run_is_capped() {
        let job = TrainJob {
            dataset: "covertype".into(),
            scale: 0.003,
            solver: Solver::Smo,
            engine: EngineChoice::CpuSeq,
            max_iters: Some(3),
            ..Default::default()
        };
        let rec = run(&job).unwrap();
        assert!(
            rec.notes.iter().any(|(k, v)| k == "capped" && v == "iters"),
            "notes: {:?}",
            rec.notes
        );
    }

    #[test]
    fn run_spsvm_small_end_to_end() {
        let job = TrainJob {
            dataset: "adult".into(),
            scale: 0.02,
            solver: Solver::SpSvm,
            engine: EngineChoice::CpuPar(4),
            max_basis: 63,
            ..Default::default()
        };
        let rec = run(&job).unwrap();
        assert!(rec.test_metric < 0.45, "metric {}", rec.test_metric);
        assert!(rec.expansion_size > 0 && rec.expansion_size <= 63);
        assert!(rec.n_train > 500);
    }

    #[test]
    fn run_smo_small_end_to_end() {
        let job = TrainJob {
            dataset: "covertype".into(),
            scale: 0.003,
            solver: Solver::Smo,
            engine: EngineChoice::CpuSeq,
            ..Default::default()
        };
        let rec = run(&job).unwrap();
        assert!(rec.test_metric < 0.5);
        assert_eq!(rec.metric_name, "error");
    }

    #[test]
    fn unknown_dataset_rejected() {
        let job = TrainJob { dataset: "nope".into(), ..Default::default() };
        assert!(run(&job).is_err());
    }
}
