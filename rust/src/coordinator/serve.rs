//! Batched prediction service.
//!
//! The implicit-parallel credo applied to inference: individual prediction
//! requests are routed into a queue, a batcher thread groups them into
//! padded tiles, and one engine call per tile computes every margin
//! (kernel block against the model's expansion vectors + predict). Under
//! the cpu engines those two calls — `rbf_block` + `predict_block` — run
//! on the blocked-GEMM substrate (DESIGN.md §GEMM), so batching buys the
//! same dense-library throughput at serve time that the implicit solvers
//! get at train time. This mirrors how a deployed WU-SVM would serve
//! traffic, and exercises the coordinator invariants the property tests
//! check: every request is answered exactly once, responses match their
//! requests, batches never exceed the tile size.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::engine::Engine;
use crate::kernel::KernelKind;
use crate::model::SvmModel;

/// A prediction request: features + reply channel.
struct Request {
    id: u64,
    features: Vec<f32>,
    reply: Sender<Response>,
}

/// A prediction response.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub margin: f32,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests per batch (and engine tile rows).
    pub batch: usize,
    /// How long the batcher waits to fill a batch.
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch: 256, max_wait: Duration::from_millis(2) }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
}

impl Client {
    /// Submit one request; returns a receiver for its response.
    pub fn submit(&self, features: Vec<f32>) -> (u64, Receiver<Response>) {
        let (rtx, rrx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Ignore send errors after shutdown; the receiver will see
        // disconnection.
        let _ = self.tx.send(Request { id, features, reply: rtx });
        (id, rrx)
    }

    /// Submit and block for the margin.
    pub fn predict(&self, features: Vec<f32>) -> Result<f32> {
        let (_, rx) = self.submit(features);
        Ok(rx.recv()?.margin)
    }
}

/// Running server with its worker thread.
pub struct Server {
    client: Client,
    handle: Option<JoinHandle<ServeStats>>,
    shutdown_tx: Sender<Request>,
}

/// Counters reported at shutdown.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch: usize,
}

impl Server {
    /// Spawn the batcher thread for `model` on `engine`.
    pub fn start(model: SvmModel, engine: Engine, cfg: ServeConfig) -> Server {
        let (tx, rx) = channel::<Request>();
        let shutdown_tx = tx.clone();
        let handle = std::thread::spawn(move || batcher_loop(model, engine, cfg, rx));
        Server {
            client: Client { tx, next_id: Arc::new(std::sync::atomic::AtomicU64::new(0)) },
            handle: Some(handle),
            shutdown_tx,
        }
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Stop the server and return its stats. Safe even while client
    /// clones are still alive: a sentinel request tells the batcher to
    /// drain and exit.
    pub fn stop(mut self) -> ServeStats {
        let (rtx, _rrx) = channel();
        let _ = self
            .shutdown_tx
            .send(Request { id: SHUTDOWN_ID, features: Vec::new(), reply: rtx });
        self.handle.take().map(|h| h.join().unwrap()).unwrap_or_default()
    }
}

/// Reserved request id that tells the batcher to shut down.
const SHUTDOWN_ID: u64 = u64::MAX;

fn batcher_loop(model: SvmModel, engine: Engine, cfg: ServeConfig, rx: Receiver<Request>) -> ServeStats {
    let mut stats = ServeStats::default();
    let gamma = match model.kernel {
        KernelKind::Rbf { gamma } => gamma,
        _ => f32::NAN, // non-RBF served via scalar fallback below
    };
    let b = model.num_vectors();
    let d = model.d;
    loop {
        // Block for the first request; then drain up to batch-1 more
        // within max_wait.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders gone
        };
        let mut shutdown = false;
        if first.id == SHUTDOWN_ID {
            break;
        }
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + cfg.max_wait;
        while batch.len() < cfg.batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) if r.id == SHUTDOWN_ID => {
                    shutdown = true;
                    break;
                }
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        stats.requests += batch.len() as u64;
        stats.batches += 1;
        stats.max_batch = stats.max_batch.max(batch.len());

        // one engine call for the whole batch (padded to batch rows)
        let margins: Vec<f32> = if gamma.is_nan() || b == 0 {
            batch.iter().map(|r| model.decision(&r.features)).collect()
        } else {
            let t = batch.len();
            let mut x = vec![0.0f32; t * d];
            for (i, r) in batch.iter().enumerate() {
                x[i * d..(i + 1) * d].copy_from_slice(&r.features);
            }
            match engine
                .rbf_block(&x, t, d, &model.vectors, b, gamma)
                .and_then(|k| engine.predict_block(&k, t, b, &model.coef))
            {
                Ok(mut f) => {
                    for v in f.iter_mut() {
                        *v += model.bias;
                    }
                    f
                }
                Err(_) => batch.iter().map(|r| model.decision(&r.features)).collect(),
            }
        };
        for (r, m) in batch.into_iter().zip(margins) {
            let _ = r.reply.send(Response { id: r.id, margin: m });
        }
        if shutdown {
            break;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SvmModel {
        SvmModel {
            kernel: KernelKind::Rbf { gamma: 0.5 },
            vectors: vec![0.0, 0.0, 1.0, 1.0],
            d: 2,
            coef: vec![1.0, -1.0],
            bias: 0.1,
            solver: "t".into(),
        }
    }

    #[test]
    fn serves_correct_margins() {
        let m = model();
        let expect = m.decision(&[0.25, 0.75]);
        let server = Server::start(m, Engine::cpu_seq(), ServeConfig::default());
        let client = server.client();
        let got = client.predict(vec![0.25, 0.75]).unwrap();
        assert!((got - expect).abs() < 1e-5, "{got} vs {expect}");
        let stats = server.stop();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let server = Server::start(model(), Engine::cpu_par(2), ServeConfig { batch: 16, max_wait: Duration::from_millis(5) });
        let client = server.client();
        let pending: Vec<(u64, Receiver<Response>, Vec<f32>)> = (0..200)
            .map(|i| {
                let f = vec![(i as f32) / 200.0, 0.5];
                let (id, rx) = client.submit(f.clone());
                (id, rx, f)
            })
            .collect();
        let m = model();
        for (id, rx, f) in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id);
            assert!((resp.margin - m.decision(&f)).abs() < 1e-4);
            // exactly once: channel now empty & disconnected or empty
            assert!(rx.try_recv().is_err());
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 200);
        assert!(stats.max_batch <= 16);
        assert!(stats.batches >= (200 / 16) as u64);
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::start(model(), Engine::cpu_seq(), ServeConfig::default());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = server.client();
                std::thread::spawn(move || {
                    let m = model();
                    for i in 0..50 {
                        let f = vec![(t as f32) / 8.0, (i as f32) / 50.0];
                        let got = c.predict(f.clone()).unwrap();
                        assert!((got - m.decision(&f)).abs() < 1e-4);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 400);
    }
}
