//! Deprecated location of the serving subsystem.
//!
//! The single-threaded demo batcher that lived here grew into the real
//! serving stack at [`crate::serve`] (versioned model registry, sharded
//! batchers over a bounded queue, compacted serve-time models, metrics —
//! DESIGN.md §SERVE). This re-export keeps `coordinator::serve::*` paths
//! compiling for one release; new code should import `wu_svm::serve`
//! directly.

pub use crate::serve::*;
