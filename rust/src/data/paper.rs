//! The seven Table-1 dataset analogs (DESIGN.md §4, §6).
//!
//! Each spec records the paper's original size alongside our generated
//! size: solver *cost* scales with (n, d, #SV), so scaled-down n with the
//! paper's d and published (C, gamma) preserves who-beats-whom; absolute
//! times are reported against our own single-core baseline.

use super::synth::{generate, sigma_for, SynthSpec};
use super::Dataset;

/// Which Table-1 metric the dataset reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Test error % (most datasets).
    Error,
    /// (1 - AUC)% — MITFaces, extreme class imbalance.
    OneMinusAuc,
}

/// Full description of one Table-1 row's workload.
#[derive(Debug, Clone)]
pub struct PaperSpec {
    pub key: &'static str,
    /// Paper's n (train), for the record.
    pub paper_n: usize,
    /// Our generated train size at scale = 1.0.
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    pub classes: usize,
    pub c: f32,
    pub gamma: f32,
    pub metric: Metric,
    /// Paper's reported LibSVM test error (fraction), the calibration
    /// target for the generator's noise floor.
    pub paper_error: f64,
    flip: f64,
    sparsity: f64,
    pos_frac: f64,
    clusters: usize,
}

/// All seven Table-1 workloads.
pub fn specs() -> Vec<PaperSpec> {
    vec![
        PaperSpec {
            key: "adult",
            paper_n: 31_562,
            n_train: 31_562,
            n_test: 16_281,
            d: 123,
            classes: 2,
            c: 1.0,
            gamma: 0.05,
            metric: Metric::Error,
            paper_error: 0.149,
            flip: 0.135,
            sparsity: 0.7,
            pos_frac: 0.25,
            clusters: 12,
        },
        PaperSpec {
            key: "covertype",
            paper_n: 522_911,
            n_train: 100_000,
            n_test: 40_000,
            d: 54,
            classes: 2,
            c: 3.0,
            gamma: 1.0,
            metric: Metric::Error,
            paper_error: 0.139,
            flip: 0.125,
            sparsity: 0.0,
            pos_frac: 0.45,
            clusters: 24,
        },
        PaperSpec {
            key: "kdd99",
            paper_n: 4_898_431,
            n_train: 150_000,
            n_test: 60_000,
            d: 127,
            classes: 2,
            // paper uses C = 1e6; with squared hinge on f32 that is
            // numerically extreme, we scale to 1e3 (DESIGN.md §4).
            c: 1.0e3,
            gamma: 0.137,
            metric: Metric::Error,
            paper_error: 0.074,
            flip: 0.065,
            sparsity: 0.9,
            pos_frac: 0.4,
            clusters: 10,
        },
        PaperSpec {
            key: "mitfaces",
            paper_n: 489_410,
            n_train: 80_000,
            n_test: 40_000,
            d: 361,
            classes: 2,
            c: 20.0,
            gamma: 0.02,
            metric: Metric::OneMinusAuc,
            paper_error: 0.056,
            flip: 0.03,
            sparsity: 0.0,
            pos_frac: 0.02,
            clusters: 10,
        },
        PaperSpec {
            key: "fd",
            paper_n: 200_000,
            n_train: 50_000,
            n_test: 20_000,
            d: 900,
            classes: 2,
            c: 10.0,
            gamma: 1.0,
            metric: Metric::Error,
            paper_error: 0.014,
            flip: 0.012,
            sparsity: 0.0,
            pos_frac: 0.3,
            clusters: 10,
        },
        PaperSpec {
            key: "epsilon",
            paper_n: 160_000,
            n_train: 40_000,
            n_test: 16_000,
            d: 2000,
            classes: 2,
            c: 1.0,
            gamma: 0.125,
            metric: Metric::Error,
            paper_error: 0.109,
            flip: 0.10,
            sparsity: 0.0,
            pos_frac: 0.5,
            clusters: 16,
        },
        PaperSpec {
            key: "mnist8m",
            paper_n: 8_100_000,
            n_train: 60_000,
            n_test: 10_000,
            d: 784,
            classes: 10,
            c: 1000.0,
            gamma: 0.006,
            metric: Metric::Error,
            paper_error: 0.010,
            flip: 0.008,
            sparsity: 0.75,
            pos_frac: 0.5,
            clusters: 4,
        },
    ]
}

/// Look up a spec by key.
pub fn spec(key: &str) -> Option<PaperSpec> {
    specs().into_iter().find(|s| s.key == key)
}

impl PaperSpec {
    /// A spec for an external libsvm file (CLI `--input`): carries the
    /// hyperparameter defaults (`C = 1`, `gamma = 1/d` — the libsvm
    /// convention) and the error metric; [`PaperSpec::generate`] is never
    /// called for these.
    pub fn external(d: usize, classes: usize) -> PaperSpec {
        PaperSpec {
            key: "file",
            paper_n: 0,
            n_train: 0,
            n_test: 0,
            d,
            classes,
            c: 1.0,
            gamma: 1.0 / d.max(1) as f32,
            metric: Metric::Error,
            paper_error: f64::NAN,
            flip: 0.0,
            sparsity: 0.0,
            pos_frac: 0.5,
            clusters: 1,
        }
    }

    fn synth_spec(&self) -> SynthSpec {
        SynthSpec {
            d: self.d,
            classes: self.classes,
            clusters: self.clusters,
            sigma: sigma_for(self.gamma as f64, self.d, self.sparsity, 0.5),
            flip: self.flip,
            sparsity: self.sparsity,
            pos_frac: self.pos_frac,
        }
    }

    /// Generate (train, test) at the given scale factor in (0, 1].
    /// Test points come from the same distribution, disjoint stream.
    pub fn generate(&self, scale: f64, seed: u64) -> (Dataset, Dataset) {
        assert!(scale > 0.0 && scale <= 1.0);
        let ntr = ((self.n_train as f64 * scale) as usize).max(64);
        let nte = ((self.n_test as f64 * scale) as usize).max(64);
        let spec = self.synth_spec();
        // One stream, split: train and test share centers (same seed into
        // generate), disjoint samples via distinct row-index streams.
        let all = generate(&spec, ntr + nte, seed ^ 0xda7a_5e7, self.key);
        let train_idx: Vec<usize> = (0..ntr).collect();
        let test_idx: Vec<usize> = (ntr..ntr + nte).collect();
        (all.select(&train_idx), all.select(&test_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_specs_with_unique_keys() {
        let s = specs();
        assert_eq!(s.len(), 7);
        let keys: std::collections::HashSet<_> = s.iter().map(|x| x.key).collect();
        assert_eq!(keys.len(), 7);
    }

    #[test]
    fn spec_lookup() {
        assert!(spec("adult").is_some());
        assert!(spec("nope").is_none());
    }

    #[test]
    fn paper_dims_preserved() {
        let a = spec("adult").unwrap();
        assert_eq!((a.d, a.paper_n), (123, 31_562));
        assert_eq!(spec("epsilon").unwrap().d, 2000);
        assert_eq!(spec("mnist8m").unwrap().classes, 10);
    }

    #[test]
    fn generate_small_scale_shapes() {
        let s = spec("adult").unwrap();
        let (tr, te) = s.generate(0.02, 1);
        assert_eq!(tr.d, 123);
        assert!(tr.n >= 600 && te.n >= 300);
        assert!(!tr.is_multiclass());
    }

    #[test]
    fn kdd_is_sparse() {
        let s = spec("kdd99").unwrap();
        let (tr, _) = s.generate(0.01, 2);
        assert!(tr.sparsity() > 0.8, "sparsity {}", tr.sparsity());
    }

    #[test]
    fn mitfaces_is_imbalanced() {
        let s = spec("mitfaces").unwrap();
        let (tr, _) = s.generate(0.05, 3);
        let pf = tr.positive_fraction();
        assert!(pf < 0.06, "pos frac {pf}");
    }

    #[test]
    fn mnist_is_multiclass() {
        let s = spec("mnist8m").unwrap();
        let (tr, te) = s.generate(0.02, 4);
        assert!(tr.is_multiclass());
        assert_eq!(tr.num_classes(), 10);
        assert_eq!(te.d, 784);
    }

    #[test]
    fn train_test_disjoint_streams_share_distribution() {
        let s = spec("covertype").unwrap();
        let (tr, te) = s.generate(0.01, 5);
        // quick sanity: means within a tolerance of each other
        let mean = |ds: &Dataset| {
            ds.dense_x().iter().map(|&v| v as f64).sum::<f64>() / ds.dense_x().len() as f64
        };
        assert!((mean(&tr) - mean(&te)).abs() < 0.05);
    }
}
