//! Memory-mapped design storage — the out-of-core half of the
//! [`Design`](super::Design) substrate (DESIGN.md §OOC).
//!
//! A packed file (written by [`super::pack`]) holds the design matrix in
//! exactly the byte layout the in-memory types use: row-major f32 for
//! dense, row-ptr / `u32` col-idx / f32 values / stored KC-chunk-order
//! norms for CSR. [`MmapMatrix`] and [`MmapCsr`] expose those sections
//! as borrowed slices straight out of the mapping, so `Dataset::row_into`
//! / `gather_rows` / `kernel_block` stream rows off disk through the OS
//! page cache without ever materializing the design — the file can be
//! 10x larger than RAM and training still runs (rust/EXPERIMENTS.md
//! §OOC).
//!
//! **Bit contract.** A mapped read returns the same bytes the packer
//! wrote from the in-memory design, and every kernel path consumes those
//! bytes through the same SIMD primitives and accumulation orders as the
//! in-memory variants — so an mmap-backed dataset trains bit-identically
//! to its dense/CSR equivalent (`rust/tests/ooc_props.rs`). CSR norms
//! are *stored*, not recomputed at load, so they carry the packing
//! process's backend flavor (pack and train under the same
//! `WU_SVM_FORCE_SCALAR` setting for cross-flavor runs).
//!
//! The mapping itself uses `mmap(2)` through a local `extern "C"`
//! declaration on unix (std already links libc; no new dependency); on
//! other targets a read-into-memory fallback presents the same
//! interface, keeping the types portable at the cost of residency.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

#[cfg(unix)]
mod sys {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// A read-only private file mapping. The pointer is page-aligned by
    /// the kernel, which is what makes the typed slice views in
    /// [`super::MmapFile`] sound.
    pub struct Map {
        ptr: *mut u8,
        len: usize,
    }

    // The mapping is read-only shared memory; the raw pointer is only a
    // capability to read immutable bytes.
    unsafe impl Send for Map {}
    unsafe impl Sync for Map {}

    impl Map {
        pub fn of_file(f: &File, len: usize) -> io::Result<Map> {
            if len == 0 {
                return Ok(Map { ptr: std::ptr::null_mut(), len: 0 });
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, f.as_raw_fd(), 0)
            };
            if ptr.is_null() || ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(Map { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            if self.len == 0 {
                &[]
            } else {
                unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
            }
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            if !self.ptr.is_null() && self.len > 0 {
                unsafe {
                    munmap(self.ptr, self.len);
                }
            }
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use std::fs::File;
    use std::io::{self, Read};

    /// Portable fallback: the whole file read into an 8-byte-aligned
    /// buffer (`Vec<u64>` backing). Same interface, full residency.
    pub struct Map {
        buf: Vec<u64>,
        len: usize,
    }

    impl Map {
        pub fn of_file(f: &File, len: usize) -> io::Result<Map> {
            let mut buf = vec![0u64; len.div_ceil(8)];
            if len > 0 {
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len)
                };
                let mut r = io::BufReader::new(f);
                r.read_exact(dst)?;
            }
            Ok(Map { buf, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.buf.as_ptr() as *const u8, self.len) }
        }
    }
}

/// A read-only mapped file with typed section views. Sections are laid
/// out 8-byte-aligned by the packer, and the mapping base is at least
/// 8-byte-aligned (page-aligned on unix, `u64`-backed in the fallback),
/// so reinterpreting an aligned byte range as `[f32]`/`[u32]`/`[u64]`
/// is well-defined.
pub struct MmapFile {
    map: sys::Map,
    len: usize,
}

impl MmapFile {
    pub fn open(path: &Path) -> Result<MmapFile> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open packed file {}", path.display()))?;
        let len = f
            .metadata()
            .with_context(|| format!("stat packed file {}", path.display()))?
            .len() as usize;
        let map = sys::Map::of_file(&f, len)
            .with_context(|| format!("map packed file {}", path.display()))?;
        Ok(MmapFile { map, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        self.map.bytes()
    }

    fn typed<T>(&self, off: usize, len: usize) -> &[T] {
        let size = std::mem::size_of::<T>();
        assert!(off % size == 0, "section offset {off} unaligned for {size}-byte elements");
        assert!(
            off + len * size <= self.len,
            "section [{off}, +{len}x{size}] outside {}-byte mapping",
            self.len
        );
        if len == 0 {
            return &[];
        }
        unsafe {
            std::slice::from_raw_parts(self.bytes().as_ptr().add(off) as *const T, len)
        }
    }

    /// `len` f32 values starting at byte offset `off`.
    pub fn f32s(&self, off: usize, len: usize) -> &[f32] {
        self.typed::<f32>(off, len)
    }

    /// `len` u32 values starting at byte offset `off`.
    pub fn u32s(&self, off: usize, len: usize) -> &[u32] {
        self.typed::<u32>(off, len)
    }

    /// `len` u64 values starting at byte offset `off`.
    pub fn u64s(&self, off: usize, len: usize) -> &[u64] {
        self.typed::<u64>(off, len)
    }
}

impl std::fmt::Debug for MmapFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MmapFile({} bytes)", self.len)
    }
}

/// A dense row-major `rows x cols` f32 matrix served from a mapping.
/// Byte-for-byte the same layout as [`crate::linalg::Matrix::data`], so
/// the dense kernel paths consume it unchanged.
#[derive(Debug, Clone)]
pub struct MmapMatrix {
    map: Arc<MmapFile>,
    pub rows: usize,
    pub cols: usize,
    x_off: usize,
}

impl MmapMatrix {
    pub fn new(map: Arc<MmapFile>, rows: usize, cols: usize, x_off: usize) -> MmapMatrix {
        // bounds + alignment checked once here; row views are then plain
        // subslices of this section
        let _ = map.f32s(x_off, rows * cols);
        MmapMatrix { map, rows, cols, x_off }
    }

    /// The full row-major feature block (a borrowed view of the file).
    #[inline]
    pub fn data(&self) -> &[f32] {
        self.map.f32s(self.x_off, self.rows * self.cols)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data()[i * self.cols..(i + 1) * self.cols]
    }
}

impl PartialEq for MmapMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.data() == other.data()
    }
}

/// A CSR `rows x cols` matrix served from a mapping: stored norms,
/// `u64` row pointers, `u32` column indices, f32 values — the same
/// triplet-plus-norms shape as [`CsrMatrix`], with identical per-row
/// semantics (`row`, `densify_row_into`, `row_dot_dense` all mirror the
/// in-memory methods and dispatch through the same SIMD primitives).
#[derive(Debug, Clone)]
pub struct MmapCsr {
    map: Arc<MmapFile>,
    pub rows: usize,
    pub cols: usize,
    nnz: usize,
    sum_sq_off: usize,
    row_ptr_off: usize,
    col_idx_off: usize,
    vals_off: usize,
}

use super::sparse::CsrMatrix;

impl MmapCsr {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        map: Arc<MmapFile>,
        rows: usize,
        cols: usize,
        nnz: usize,
        sum_sq_off: usize,
        row_ptr_off: usize,
        col_idx_off: usize,
        vals_off: usize,
    ) -> Result<MmapCsr> {
        let mc = MmapCsr { map, rows, cols, nnz, sum_sq_off, row_ptr_off, col_idx_off, vals_off };
        // validate bounds/alignment once, plus the row-pointer monotone
        // invariant every row view depends on
        let _ = mc.sum_sq();
        let _ = mc.map.u32s(mc.col_idx_off, mc.nnz);
        let _ = mc.map.f32s(mc.vals_off, mc.nnz);
        let rp = mc.row_ptrs();
        anyhow::ensure!(rp.len() == rows + 1, "row_ptr section has {} entries", rp.len());
        anyhow::ensure!(
            rp[0] == 0 && rp[rows] == nnz as u64 && rp.windows(2).all(|w| w[0] <= w[1]),
            "packed CSR row pointers are not monotone over [0, nnz]"
        );
        Ok(mc)
    }

    #[inline]
    fn row_ptrs(&self) -> &[u64] {
        self.map.u64s(self.row_ptr_off, self.rows + 1)
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Per-row Σ v², stored at pack time in the KC-chunk order of
    /// [`crate::linalg::gemm::sum_sq`] (module docs: the exact-diagonal
    /// contract travels with the file).
    #[inline]
    pub fn sum_sq(&self) -> &[f32] {
        self.map.f32s(self.sum_sq_off, self.rows)
    }

    /// Row i's `(columns, values)` slices — mirrors [`CsrMatrix::row`].
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let rp = self.row_ptrs();
        let (lo, hi) = (rp[i] as usize, rp[i + 1] as usize);
        let cols = &self.map.u32s(self.col_idx_off, self.nnz)[lo..hi];
        let vals = &self.map.f32s(self.vals_off, self.nnz)[lo..hi];
        (cols, vals)
    }

    /// Scatter row i into a dense buffer — mirrors
    /// [`CsrMatrix::densify_row_into`].
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        assert!(out.len() >= self.cols);
        for v in out.iter_mut() {
            *v = 0.0;
        }
        let (cols, vals) = self.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            out[c as usize] = v;
        }
    }

    /// Dot of row i with a dense vector in the shared KC-chunk order —
    /// mirrors [`CsrMatrix::row_dot_dense`].
    pub fn row_dot_dense(&self, i: usize, x: &[f32]) -> f32 {
        assert!(x.len() >= self.cols);
        let (cols, vals) = self.row(i);
        crate::linalg::simd::active().sparse_dot_dense(cols, vals, x)
    }

    /// Materialize the whole matrix in memory. Norms are copied, not
    /// recomputed, so the result equals the CSR that was packed bit for
    /// bit.
    pub fn to_csr(&self) -> CsrMatrix {
        let rp = self.row_ptrs();
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            row_ptr: rp.iter().map(|&p| p as usize).collect(),
            col_idx: self.map.u32s(self.col_idx_off, self.nnz).to_vec(),
            vals: self.map.f32s(self.vals_off, self.nnz).to_vec(),
            sum_sq: self.sum_sq().to_vec(),
        }
    }

    /// Gather the given rows into an in-memory CSR (row order = `idx`
    /// order, norms copied) — mirrors [`CsrMatrix::select`].
    pub fn select_csr(&self, idx: &[usize]) -> CsrMatrix {
        let nnz: usize = idx.iter().map(|&i| self.row(i).1.len()).sum();
        let mut row_ptr = Vec::with_capacity(idx.len() + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut sum_sq = Vec::with_capacity(idx.len());
        row_ptr.push(0);
        for &i in idx {
            let (c, v) = self.row(i);
            col_idx.extend_from_slice(c);
            vals.extend_from_slice(v);
            row_ptr.push(col_idx.len());
            sum_sq.push(self.sum_sq()[i]);
        }
        CsrMatrix { rows: idx.len(), cols: self.cols, row_ptr, col_idx, vals, sum_sq }
    }
}

impl PartialEq for MmapCsr {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.row_ptrs() == other.row_ptrs()
            && self.map.u32s(self.col_idx_off, self.nnz)
                == other.map.u32s(other.col_idx_off, other.nnz)
            && self.map.f32s(self.vals_off, self.nnz)
                == other.map.f32s(other.vals_off, other.nnz)
    }
}
