//! LibSVM sparse text format reader/writer.
//!
//! `label idx:val idx:val ...` per line, 1-based indices. This is the
//! format every dataset in the paper ships in; our synthetic analogs can
//! round-trip through it so real downloads drop in unchanged.
//!
//! Parsing is **streaming and chunk-parallel**: lines are read in
//! batches, each batch is tokenized in parallel on the pool, and the
//! parsed rows are appended to a [`CsrBuilder`] in input order — the
//! design matrix is built in CSR directly, so a 90%-sparse source never
//! materializes its dense form unless [`Format::Dense`] asks for it.
//!
//! Real downloads are messy; the parser normalizes or rejects the common
//! defects instead of silently mis-reading them: CRLF endings and
//! trailing `# comment` text are stripped, ranking `qid:` qualifiers are
//! skipped, descending indices are sorted, and duplicate indices are an
//! error (two conflicting values for one feature have no right answer).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::sparse::CsrBuilder;
use super::{Dataset, Design, Format};
use crate::pool;

/// Lines tokenized per parallel batch.
const CHUNK_LINES: usize = 4096;

/// One successfully parsed data line.
struct ParsedLine {
    label: f64,
    /// 0-based `(col, value)` pairs, strictly ascending columns.
    feats: Vec<(u32, f32)>,
    /// Highest 1-based index seen, including explicit zeros (zeros are
    /// dropped from `feats` but still pin the dimensionality).
    max_idx: usize,
}

/// Tokenize one line. `Ok(None)` = blank or comment-only line.
fn parse_line(line: &str, lineno: usize) -> Result<Option<ParsedLine>> {
    // trailing "# comment" (and whole-line comments) are not data
    let line = line.split('#').next().unwrap_or("");
    let line = line.trim(); // also strips the \r of CRLF endings
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label: f64 = parts
        .next()
        .context("missing label")?
        .parse()
        .with_context(|| format!("bad label on line {lineno}"))?;
    let mut feats: Vec<(u32, f32)> = Vec::new();
    let mut max_idx = 0usize;
    let mut sorted = true;
    for tok in parts {
        if tok.starts_with("qid:") {
            // ranking-task qualifier (svmlight extension): not a feature
            continue;
        }
        let (i, v) = tok
            .split_once(':')
            .with_context(|| format!("bad feature '{tok}' line {lineno}"))?;
        let i: usize = i
            .parse()
            .with_context(|| format!("bad feature index '{i}' line {lineno}"))?;
        if i == 0 {
            bail!("libsvm indices are 1-based (line {lineno})");
        }
        if i > u32::MAX as usize {
            bail!("feature index {i} exceeds the u32 index space (line {lineno})");
        }
        // f32 parsing covers scientific notation ("1.5e-3") natively
        let v: f32 = v
            .parse()
            .with_context(|| format!("bad feature value '{v}' line {lineno}"))?;
        max_idx = max_idx.max(i);
        let col = (i - 1) as u32;
        if let Some(&(prev, _)) = feats.last() {
            if col <= prev {
                sorted = false;
            }
        }
        // explicit zeros ride along so duplicate detection sees them
        // (CsrBuilder drops them at append time)
        feats.push((col, v));
    }
    if !sorted {
        // descending/unordered indices: normalize to CSR's sorted order
        feats.sort_unstable_by_key(|&(c, _)| c);
    }
    for w in feats.windows(2) {
        if w[0].0 == w[1].0 {
            bail!(
                "duplicate feature index {} on line {lineno}",
                w[0].0 as usize + 1
            );
        }
    }
    Ok(Some(ParsedLine { label, feats, max_idx }))
}

/// Parse libsvm text into the requested storage [`Format`]. Labels may be
/// real classes (multiclass) or +/-1. `d_hint` pads/validates
/// dimensionality (0 = infer from max index).
pub fn parse_with<R: BufRead>(
    reader: R,
    name: &str,
    d_hint: usize,
    format: Format,
) -> Result<Dataset> {
    let threads = pool::default_threads();
    let mut builder = CsrBuilder::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_idx = 0usize;
    let mut batch: Vec<(usize, String)> = Vec::with_capacity(CHUNK_LINES);
    let mut lines = reader.lines();
    let mut lineno = 0usize;
    let mut done = false;
    while !done {
        batch.clear();
        while batch.len() < CHUNK_LINES {
            match lines.next() {
                Some(line) => {
                    lineno += 1;
                    batch.push((lineno, line?));
                }
                None => {
                    done = true;
                    break;
                }
            }
        }
        if batch.is_empty() {
            break;
        }
        // tokenize the batch in parallel, append in input order
        let batch_ref = &batch;
        let parsed = pool::parallel_map(threads, batch.len(), |k| {
            let (no, line) = &batch_ref[k];
            parse_line(line, *no)
        });
        for row in parsed {
            if let Some(p) = row? {
                max_idx = max_idx.max(p.max_idx);
                builder.push_row(&p.feats);
                labels.push(p.label);
            }
        }
    }
    if labels.is_empty() {
        bail!("empty libsvm file");
    }
    let d = if d_hint > 0 {
        if max_idx > d_hint {
            bail!("feature index {max_idx} exceeds d_hint {d_hint}");
        }
        d_hint
    } else {
        max_idx
    };
    let csr = builder.finish(d);
    let design = match format {
        Format::Dense => Design::Dense(csr.to_dense()),
        Format::Csr => Design::Sparse(csr),
        Format::Auto => {
            if csr.density() <= super::AUTO_SPARSE_THRESHOLD {
                Design::Sparse(csr)
            } else {
                Design::Dense(csr.to_dense())
            }
        }
    };

    // Binary iff labels take exactly the values {-1, +1} (or {0, 1}).
    let mut uniq: Vec<f64> = labels.clone();
    uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
    uniq.dedup();
    let binary = uniq.len() <= 2
        && uniq.iter().all(|&v| v == -1.0 || v == 1.0 || v == 0.0);
    if binary {
        let y = labels
            .into_iter()
            .map(|v| if v > 0.0 { 1.0 } else { -1.0 })
            .collect();
        Ok(Dataset::binary_with_design(name, design, y))
    } else {
        // map sorted unique labels to 0..k
        let ids = labels
            .into_iter()
            .map(|v| uniq.binary_search_by(|u| u.partial_cmp(&v).unwrap()).unwrap())
            .collect();
        Ok(Dataset::multiclass_with_design(name, design, ids))
    }
}

/// [`parse_with`] densifying on load (the seed behavior, kept for the
/// existing call sites; sparse-aware callers pass a [`Format`]).
pub fn parse<R: BufRead>(reader: R, name: &str, d_hint: usize) -> Result<Dataset> {
    parse_with(reader, name, d_hint, Format::Dense)
}

/// Read a libsvm file from disk into the requested storage format.
pub fn read_file_with(path: &Path, d_hint: usize, format: Format) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    parse_with(std::io::BufReader::new(f), &name, d_hint, format)
}

/// Read a libsvm file from disk, densified (the seed behavior).
pub fn read_file(path: &Path, d_hint: usize) -> Result<Dataset> {
    read_file_with(path, d_hint, Format::Dense)
}

/// Write a dataset in libsvm format (zeros omitted; CSR designs stream
/// their stored entries directly).
pub fn write_file(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n {
        if ds.is_multiclass() {
            write!(w, "{}", ds.class_ids[i])?;
        } else {
            write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        }
        match ds.sparse_row(i) {
            Some((cols, vals)) => {
                for (&j, &v) in cols.iter().zip(vals) {
                    write!(w, " {}:{}", j as usize + 1, v)?;
                }
            }
            None => {
                for (j, &v) in ds.row(i).iter().enumerate() {
                    if v != 0.0 {
                        write!(w, " {}:{}", j + 1, v)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_binary() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let ds = parse(Cursor::new(text), "t", 0).unwrap();
        assert_eq!((ds.n, ds.d), (2, 3));
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert!(!ds.is_multiclass());
    }

    #[test]
    fn parses_multiclass() {
        let text = "3 1:1\n7 1:2\n3 2:1\n";
        let ds = parse(Cursor::new(text), "t", 0).unwrap();
        assert!(ds.is_multiclass());
        assert_eq!(ds.class_ids, vec![0, 1, 0]);
    }

    #[test]
    fn zero_one_labels_map_to_pm1() {
        let ds = parse(Cursor::new("0 1:1\n1 1:2\n"), "t", 0).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn d_hint_pads() {
        let ds = parse(Cursor::new("+1 1:1\n"), "t", 5).unwrap();
        assert_eq!(ds.d, 5);
    }

    #[test]
    fn d_hint_too_small_errors() {
        assert!(parse(Cursor::new("+1 4:1\n"), "t", 2).is_err());
    }

    #[test]
    fn zero_index_rejected() {
        assert!(parse(Cursor::new("+1 0:1\n"), "t", 0).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse(Cursor::new("# c\n\n+1 1:1\n"), "t", 0).unwrap();
        assert_eq!(ds.n, 1);
    }

    #[test]
    fn trailing_comment_stripped() {
        let ds = parse(Cursor::new("+1 1:0.5 2:1.0 # row from fold 3\n-1 1:1\n"), "t", 0)
            .unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert_eq!(ds.row(0), &[0.5, 1.0]);
    }

    #[test]
    fn qid_tokens_skipped() {
        let ds = parse(Cursor::new("+1 qid:3 1:0.5 2:1.0\n-1 qid:4 1:1\n"), "t", 0).unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert_eq!(ds.row(0), &[0.5, 1.0]);
        assert_eq!(ds.row(1), &[1.0, 0.0]);
    }

    #[test]
    fn duplicate_index_rejected() {
        let err = parse(Cursor::new("+1 2:1 2:3\n"), "t", 0).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
        // duplicates hidden behind descending order are caught too
        assert!(parse(Cursor::new("+1 3:1 1:2 3:4\n"), "t", 0).is_err());
        // ...and so are duplicates where one value is an explicit zero
        assert!(parse(Cursor::new("+1 2:0 2:3\n"), "t", 0).is_err());
        assert!(parse(Cursor::new("+1 2:3 2:0\n"), "t", 0).is_err());
    }

    #[test]
    fn descending_indices_normalized() {
        let ds = parse(Cursor::new("+1 3:3.0 1:1.0 2:2.0\n"), "t", 0).unwrap();
        assert_eq!(ds.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn scientific_notation_values() {
        let ds = parse(Cursor::new("+1 1:1.5e-3 2:-2E2 3:1e0\n-1 1:1\n"), "t", 0).unwrap();
        assert_eq!(ds.row(0), &[1.5e-3, -200.0, 1.0]);
    }

    #[test]
    fn crlf_line_endings() {
        let ds = parse(Cursor::new("+1 1:0.5 2:1.5\r\n-1 1:1\r\n"), "t", 0).unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert_eq!(ds.row(0), &[0.5, 1.5]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn explicit_zero_pins_dimensionality() {
        let ds = parse(Cursor::new("+1 1:1 5:0\n-1 1:2\n"), "t", 0).unwrap();
        assert_eq!(ds.d, 5);
    }

    #[test]
    fn csr_format_matches_dense_parse() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1 4:0.25\n";
        let dense = parse_with(Cursor::new(text), "t", 0, Format::Dense).unwrap();
        let csr = parse_with(Cursor::new(text), "t", 0, Format::Csr).unwrap();
        assert!(csr.is_sparse() && !dense.is_sparse());
        assert_eq!(csr.csr().unwrap().to_dense().data, dense.dense_x());
        assert_eq!(csr.y, dense.y);
        // this sample is 5/12 dense (41.7% > the 25% threshold): auto
        // keeps it dense...
        let auto_dense = parse_with(Cursor::new(text), "t", 0, Format::Auto).unwrap();
        assert!(!auto_dense.is_sparse());
        // ...while a 2/16-dense sample (12.5%) goes csr
        let auto = parse_with(Cursor::new("+1 1:1\n-1 8:1\n"), "t", 0, Format::Auto).unwrap();
        assert!(auto.is_sparse());
        // ...and dense for a fully dense source
        let auto2 = parse_with(Cursor::new("+1 1:1 2:2\n-1 1:3 2:4\n"), "t", 0, Format::Auto)
            .unwrap();
        assert!(!auto2.is_sparse());
    }

    #[test]
    fn chunked_parse_spans_batches() {
        // more lines than one parallel batch, parsed in order
        let mut text = String::new();
        for i in 0..(super::CHUNK_LINES + 100) {
            text.push_str(&format!("{} 1:{}\n", if i % 2 == 0 { "+1" } else { "-1" }, i + 1));
        }
        let ds = parse_with(Cursor::new(text), "t", 0, Format::Csr).unwrap();
        assert_eq!(ds.n, super::CHUNK_LINES + 100);
        let mut buf = [0.0f32; 1];
        ds.row_into(super::CHUNK_LINES + 50, &mut buf);
        assert_eq!(buf[0], (super::CHUNK_LINES + 51) as f32);
        assert_eq!(ds.y[0], 1.0);
        assert_eq!(ds.y[1], -1.0);
    }

    #[test]
    fn round_trip_through_file() {
        let dir = std::env::temp_dir().join("wu_svm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        let ds = Dataset::new_binary(
            "rt",
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.5, 0.0],
            vec![1.0, -1.0],
        );
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, 3).unwrap();
        assert_eq!(back.dense_x(), ds.dense_x());
        assert_eq!(back.y, ds.y);
        // CSR write/read round-trips through the same file format
        let sp = ds.clone().with_format(Format::Csr);
        write_file(&sp, &path).unwrap();
        let back2 = read_file_with(&path, 3, Format::Csr).unwrap();
        assert_eq!(back2.csr().unwrap(), sp.csr().unwrap());
        std::fs::remove_file(path).ok();
    }
}
