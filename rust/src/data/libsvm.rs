//! LibSVM sparse text format reader/writer.
//!
//! `label idx:val idx:val ...` per line, 1-based indices. This is the
//! format every dataset in the paper ships in; our synthetic analogs can
//! round-trip through it so real downloads drop in unchanged.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

/// Parse libsvm text. Labels may be real classes (multiclass) or +/-1.
/// `d_hint` pads/validates dimensionality (0 = infer from max index).
pub fn parse<R: BufRead>(reader: R, name: &str, d_hint: usize) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let lab: f64 = parts
            .next()
            .context("missing label")?
            .parse()
            .with_context(|| format!("bad label on line {}", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("bad feature '{tok}' line {}", lineno + 1))?;
            let i: usize = i.parse()?;
            if i == 0 {
                bail!("libsvm indices are 1-based (line {})", lineno + 1);
            }
            let v: f32 = v.parse()?;
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        rows.push(feats);
        labels.push(lab);
    }
    if rows.is_empty() {
        bail!("empty libsvm file");
    }
    let d = if d_hint > 0 {
        if max_idx > d_hint {
            bail!("feature index {max_idx} exceeds d_hint {d_hint}");
        }
        d_hint
    } else {
        max_idx
    };

    let n = rows.len();
    let mut x = vec![0.0f32; n * d];
    for (r, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x[r * d + j] = v;
        }
    }

    // Binary iff labels take exactly the values {-1, +1} (or {0, 1}).
    let mut uniq: Vec<f64> = labels.clone();
    uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
    uniq.dedup();
    let binary = uniq.len() <= 2
        && uniq.iter().all(|&v| v == -1.0 || v == 1.0 || v == 0.0);
    if binary {
        let y = labels
            .into_iter()
            .map(|v| if v > 0.0 { 1.0 } else { -1.0 })
            .collect();
        Ok(Dataset::new_binary(name, d, x, y))
    } else {
        // map sorted unique labels to 0..k
        let ids = labels
            .into_iter()
            .map(|v| uniq.binary_search_by(|u| u.partial_cmp(&v).unwrap()).unwrap())
            .collect();
        Ok(Dataset::new_multiclass(name, d, x, ids))
    }
}

/// Read a libsvm file from disk.
pub fn read_file(path: &Path, d_hint: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    parse(std::io::BufReader::new(f), &name, d_hint)
}

/// Write a dataset in libsvm format (zeros omitted).
pub fn write_file(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n {
        if ds.is_multiclass() {
            write!(w, "{}", ds.class_ids[i])?;
        } else {
            write!(w, "{}", if ds.y[i] > 0.0 { "+1" } else { "-1" })?;
        }
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_binary() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let ds = parse(Cursor::new(text), "t", 0).unwrap();
        assert_eq!((ds.n, ds.d), (2, 3));
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert!(!ds.is_multiclass());
    }

    #[test]
    fn parses_multiclass() {
        let text = "3 1:1\n7 1:2\n3 2:1\n";
        let ds = parse(Cursor::new(text), "t", 0).unwrap();
        assert!(ds.is_multiclass());
        assert_eq!(ds.class_ids, vec![0, 1, 0]);
    }

    #[test]
    fn zero_one_labels_map_to_pm1() {
        let ds = parse(Cursor::new("0 1:1\n1 1:2\n"), "t", 0).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn d_hint_pads() {
        let ds = parse(Cursor::new("+1 1:1\n"), "t", 5).unwrap();
        assert_eq!(ds.d, 5);
    }

    #[test]
    fn d_hint_too_small_errors() {
        assert!(parse(Cursor::new("+1 4:1\n"), "t", 2).is_err());
    }

    #[test]
    fn zero_index_rejected() {
        assert!(parse(Cursor::new("+1 0:1\n"), "t", 0).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse(Cursor::new("# c\n\n+1 1:1\n"), "t", 0).unwrap();
        assert_eq!(ds.n, 1);
    }

    #[test]
    fn round_trip_through_file() {
        let dir = std::env::temp_dir().join("wu_svm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        let ds = Dataset::new_binary(
            "rt",
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.5, 0.0],
            vec![1.0, -1.0],
        );
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, 3).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(path).ok();
    }
}
