//! Synthetic dataset generators.
//!
//! The paper's seven benchmark datasets are multi-gigabyte downloads we do
//! not have (DESIGN.md §4). These generators produce RBF-SVM-learnable
//! surrogates with the *same cost-determining shape*: n, d, class count,
//! class imbalance, sparsity, and an adjustable Bayes-error floor (label
//! flip noise) calibrated to the paper's reported test errors.
//!
//! Structure: each class owns `clusters` Gaussian clusters whose centers
//! are interleaved in [0,1]^d (so the decision surface is nonlinear and a
//! kernel method is actually required); label noise sets the error floor.
//! Sparse datasets put clusters on sparse supports so the 90%-zeros
//! property of kdd99-like data survives.
//!
//! Generation is deterministic per (spec, seed) regardless of thread
//! count: each row derives its own RNG stream from the row index.

use crate::pool;
use crate::rng::Rng;

use super::Dataset;

/// Generator parameters (see module docs).
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub d: usize,
    /// Number of classes (2 = binary with labels in {-1,+1}).
    pub classes: usize,
    /// Gaussian clusters per class.
    pub clusters: usize,
    /// Within-cluster standard deviation.
    pub sigma: f32,
    /// Label-flip probability (Bayes-error floor).
    pub flip: f64,
    /// Fraction of zero entries per cluster support (0 = dense).
    pub sparsity: f64,
    /// Positive-class fraction (binary only; 0.5 = balanced).
    pub pos_frac: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            d: 16,
            classes: 2,
            clusters: 8,
            sigma: 0.15,
            flip: 0.0,
            sparsity: 0.0,
            pos_frac: 0.5,
        }
    }
}

/// A cluster center: dense values with an explicit support.
struct Center {
    values: Vec<f32>, // length d, zeros off-support
    class: usize,
}

fn make_centers(spec: &SynthSpec, rng: &mut Rng) -> Vec<Center> {
    let mut centers = Vec::with_capacity(spec.classes * spec.clusters);
    let nz = ((spec.d as f64) * (1.0 - spec.sparsity)).ceil().max(1.0) as usize;
    for class in 0..spec.classes {
        for _ in 0..spec.clusters {
            let mut values = vec![0.0f32; spec.d];
            if spec.sparsity > 0.0 {
                for j in rng.sample_indices(spec.d, nz) {
                    values[j] = 0.3 + 0.7 * rng.uniform_f32();
                }
            } else {
                for v in values.iter_mut() {
                    *v = rng.uniform_f32();
                }
            }
            centers.push(Center { values, class });
        }
    }
    centers
}

/// Generate `n` samples. Binary specs return {-1,+1} labels; multiclass
/// specs return class ids.
pub fn generate(spec: &SynthSpec, n: usize, seed: u64, name: &str) -> Dataset {
    assert!(spec.classes >= 2);
    let mut rng = Rng::new(seed);
    let centers = make_centers(spec, &mut rng);
    let base = rng.next_u64();

    let d = spec.d;
    let mut x = vec![0.0f32; n * d];
    let mut labels = vec![0usize; n];
    {
        let labels_ptr = crate::pool::SendPtr::new(labels.as_mut_ptr());
        let centers_ref = &centers;
        pool::parallel_chunks_mut(
            pool::default_threads(),
            &mut x,
            d, // one row per chunk
            |i, row| {
                let mut r = Rng::new(base ^ (i as u64).wrapping_mul(0x9E37_79B9));
                // class choice: imbalance for binary, uniform otherwise
                let class = if spec.classes == 2 {
                    usize::from(r.bernoulli(spec.pos_frac))
                } else {
                    r.below(spec.classes)
                };
                let k = r.below(spec.clusters);
                let c = &centers_ref[class * spec.clusters + k];
                for (j, v) in row.iter_mut().enumerate() {
                    let cv = c.values[j];
                    if cv == 0.0 && spec.sparsity > 0.0 {
                        *v = 0.0; // stay on the sparse support
                    } else {
                        *v = (cv + spec.sigma * r.gaussian_f32()).clamp(0.0, 1.0);
                    }
                }
                let mut lab = c.class;
                if r.bernoulli(spec.flip) {
                    // flip to a uniformly random *other* class
                    lab = (lab + 1 + r.below(spec.classes - 1)) % spec.classes;
                }
                // SAFETY: row i written exactly once.
                unsafe { *labels_ptr.get().add(i) = lab };
            },
        );
    }

    if spec.classes == 2 {
        let y = labels
            .into_iter()
            .map(|c| if c == 1 { 1.0 } else { -1.0 })
            .collect();
        Dataset::new_binary(name, d, x, y)
    } else {
        Dataset::new_multiclass(name, d, x, labels)
    }
}

/// Pick sigma so that gamma * E[within-cluster distance^2] ~ target,
/// keeping the paper's published (C, gamma) in a regime where the RBF
/// kernel resolves the cluster structure (DESIGN.md §4).
pub fn sigma_for(gamma: f64, d: usize, sparsity: f64, target: f64) -> f32 {
    let d_eff = (d as f64) * (1.0 - sparsity);
    let s2 = target / (2.0 * gamma * d_eff.max(1.0));
    (s2.sqrt() as f32).clamp(0.01, 0.25)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let spec = SynthSpec::default();
        let a = generate(&spec, 200, 7, "a");
        let b = generate(&spec, 200, 7, "b");
        assert_eq!(a.dense_x(), b.dense_x());
        assert_eq!(a.y, b.y);
        let c = generate(&spec, 200, 8, "c");
        assert_ne!(a.dense_x(), c.dense_x());
    }

    #[test]
    fn features_in_unit_cube() {
        let ds = generate(&SynthSpec::default(), 500, 1, "u");
        assert!(ds.dense_x().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn imbalance_respected() {
        let spec = SynthSpec { pos_frac: 0.05, ..Default::default() };
        let ds = generate(&spec, 20_000, 2, "i");
        let pf = ds.positive_fraction();
        assert!((pf - 0.05).abs() < 0.01, "pos frac {pf}");
    }

    #[test]
    fn sparsity_respected() {
        let spec = SynthSpec { d: 100, sparsity: 0.9, ..Default::default() };
        let ds = generate(&spec, 2_000, 3, "s");
        let sp = ds.sparsity();
        assert!(sp > 0.85 && sp < 0.95, "sparsity {sp}");
    }

    #[test]
    fn multiclass_labels_cover_classes() {
        let spec = SynthSpec { classes: 10, ..Default::default() };
        let ds = generate(&spec, 5_000, 4, "m");
        assert!(ds.is_multiclass());
        assert_eq!(ds.num_classes(), 10);
        let mut seen = vec![false; 10];
        for &c in &ds.class_ids {
            seen[c] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn flip_sets_error_floor() {
        // a 1-NN-on-centers classifier cannot beat the flip rate
        let spec = SynthSpec { flip: 0.25, sigma: 0.02, clusters: 2, ..Default::default() };
        let ds = generate(&spec, 10_000, 5, "f");
        // measure: nearest center class vs observed label disagreement
        let mut rng = Rng::new(5);
        let centers = make_centers(&spec, &mut rng);
        let mut dis = 0usize;
        for i in 0..ds.n {
            let row = ds.row(i);
            let best = centers
                .iter()
                .min_by(|a, b| {
                    crate::linalg::dist2(&a.values, row)
                        .partial_cmp(&crate::linalg::dist2(&b.values, row))
                        .unwrap()
                })
                .unwrap();
            let lab = if best.class == 1 { 1.0 } else { -1.0 };
            if lab != ds.y[i] {
                dis += 1;
            }
        }
        let rate = dis as f64 / ds.n as f64;
        assert!((rate - 0.25).abs() < 0.03, "disagreement {rate}");
    }

    #[test]
    fn sigma_for_reasonable() {
        let s = sigma_for(0.05, 123, 0.0, 0.5);
        assert!(s > 0.1 && s <= 0.25, "{s}");
        let s2 = sigma_for(1.0, 900, 0.0, 0.5);
        assert!(s2 < 0.05, "{s2}");
    }
}
