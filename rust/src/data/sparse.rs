//! Compressed-sparse-row storage — the sparse half of the [`Design`]
//! substrate (DESIGN.md §SPARSE).
//!
//! The paper's benchmark suite is dominated by sparse sources (adult,
//! web, kdd99, rcv1-class data at d ≈ 47k); a dense `n x d` design
//! matrix cannot hold them at full n. [`CsrMatrix`] keeps the classic
//! row-ptr / col-idx / value triplet plus one derived array the kernel
//! paths rely on: per-row squared norms accumulated in **the same
//! KC-chunked order as [`crate::linalg::gemm::sum_sq`]** (zeros contribute identity
//! adds, so the chunked sparse sum is bit-identical to the dense one).
//! That is what lets the SpMM-backed RBF path (`linalg::spmm`) keep the
//! exact-diagonal contract the dense path has.
//!
//! Column indices are `u32` (rcv1's d ≈ 47k fits with room to spare) and
//! stored strictly ascending per row; explicit zeros are dropped at
//! construction — they change no dot product, no norm, and no chunk
//! boundary semantics.

/// Density at or below which `Format::Auto` (and the serve registry)
/// choose CSR over dense storage. At 25% stored entries the CSR triplet
/// (8 bytes/nnz + row pointers) already beats the dense 4 bytes/element,
/// and the SpMM wins grow from there.
pub const AUTO_SPARSE_THRESHOLD: f64 = 0.25;

/// How a design matrix is stored: the axis [`super::Dataset`], the
/// solvers' tile views and the serve registry all dispatch on.
#[derive(Debug, Clone, PartialEq)]
pub enum Design {
    /// Row-major dense `n x d` (the seed's only representation).
    Dense(crate::linalg::Matrix),
    /// CSR, for sparse sources that must never densify on load.
    Sparse(CsrMatrix),
    /// Dense rows memory-mapped from a packed file — the out-of-core
    /// path (DESIGN.md §OOC); byte-identical to `Dense` row data.
    MmapDense(crate::data::mmap::MmapMatrix),
    /// CSR memory-mapped from a packed file (stored norms included).
    MmapCsr(crate::data::mmap::MmapCsr),
}

impl Design {
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows,
            Design::Sparse(c) => c.rows,
            Design::MmapDense(m) => m.rows,
            Design::MmapCsr(c) => c.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols,
            Design::Sparse(c) => c.cols,
            Design::MmapDense(m) => m.cols,
            Design::MmapCsr(c) => c.cols,
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Design::Sparse(_) | Design::MmapCsr(_))
    }

    /// Whether the design is served from a mapped file (out of core).
    pub fn is_mmap(&self) -> bool {
        matches!(self, Design::MmapDense(_) | Design::MmapCsr(_))
    }

    /// Stable storage-kind name for reports (`storage = ...` note).
    pub fn storage(&self) -> &'static str {
        match self {
            Design::Dense(_) => "dense",
            Design::Sparse(_) => "csr",
            Design::MmapDense(_) => "mmap-dense",
            Design::MmapCsr(_) => "mmap-csr",
        }
    }

    /// Approximate in-memory footprint in bytes. Mapped designs report
    /// 0 — their pages live in the OS page cache, not the heap.
    pub fn bytes(&self) -> usize {
        match self {
            Design::Dense(m) => m.data.len() * 4,
            Design::Sparse(c) => c.bytes(),
            Design::MmapDense(_) | Design::MmapCsr(_) => 0,
        }
    }
}

/// Requested storage for a parsed/generated dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Format {
    /// Densify (the seed behavior).
    Dense,
    /// Build CSR, never densify.
    Csr,
    /// CSR iff density <= [`AUTO_SPARSE_THRESHOLD`].
    #[default]
    Auto,
}

impl Format {
    pub fn parse(s: &str) -> anyhow::Result<Format> {
        Ok(match s {
            "dense" => Format::Dense,
            "csr" | "sparse" => Format::Csr,
            "auto" => Format::Auto,
            _ => anyhow::bail!("unknown format '{s}' (dense|csr|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::Dense => "dense",
            Format::Csr => "csr",
            Format::Auto => "auto",
        }
    }
}

/// A compressed-sparse-row `rows x cols` f32 matrix (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes row i's entries (len rows + 1).
    pub row_ptr: Vec<usize>,
    /// Column of each stored value, strictly ascending per row.
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    /// Per-row Σ v², accumulated in [`crate::linalg::gemm::sum_sq`]'s
    /// KC-chunk order — the RBF paths' exact-diagonal contract depends
    /// on this.
    pub sum_sq: Vec<f32>,
}

/// Σ v² over one sorted sparse row in `gemm::sum_sq`'s accumulation
/// order: partials reset at every KC column boundary, partials added to
/// the total in column order (zero columns are identity adds — under
/// FMA too, since `fma(0, b, acc) == acc` — so this equals the dense
/// chunked sum bit for bit). Dispatched to the active SIMD backend so
/// the stored norms always match the flavor the kernel paths run.
fn chunked_sum_sq(cols: &[u32], vals: &[f32]) -> f32 {
    crate::linalg::simd::active().sparse_sum_sq(cols, vals)
}

/// Incremental CSR assembly (the streaming libsvm parser appends one
/// parsed row at a time; `finish` seals the column count and norms).
pub struct CsrBuilder {
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

impl CsrBuilder {
    pub fn new() -> CsrBuilder {
        CsrBuilder { cols: 0, row_ptr: vec![0], col_idx: Vec::new(), vals: Vec::new() }
    }

    /// Append one row given `(col, value)` pairs with strictly ascending
    /// columns (the parser guarantees this). Zero values are dropped.
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        debug_assert!(entries.windows(2).all(|w| w[0].0 < w[1].0));
        for &(c, v) in entries {
            if v != 0.0 {
                self.col_idx.push(c);
                self.vals.push(v);
                self.cols = self.cols.max(c as usize + 1);
            }
        }
        self.row_ptr.push(self.col_idx.len());
    }

    pub fn rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Seal into a [`CsrMatrix`]. `cols` must cover every stored index
    /// (0 = infer from the data).
    pub fn finish(self, cols: usize) -> CsrMatrix {
        let cols = if cols == 0 { self.cols } else { cols };
        assert!(cols >= self.cols, "cols {cols} < max stored index {}", self.cols);
        let rows = self.row_ptr.len() - 1;
        let sum_sq = (0..rows)
            .map(|i| {
                let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
                chunked_sum_sq(&self.col_idx[lo..hi], &self.vals[lo..hi])
            })
            .collect();
        CsrMatrix {
            rows,
            cols,
            row_ptr: self.row_ptr,
            col_idx: self.col_idx,
            vals: self.vals,
            sum_sq,
        }
    }
}

impl Default for CsrBuilder {
    fn default() -> Self {
        CsrBuilder::new()
    }
}

impl CsrMatrix {
    /// Compress a row-major dense `rows x cols` slice (zeros dropped).
    pub fn from_dense(rows: usize, cols: usize, x: &[f32]) -> CsrMatrix {
        assert_eq!(x.len(), rows * cols);
        assert!(cols <= u32::MAX as usize, "column count exceeds u32 index space");
        let mut b = CsrBuilder::new();
        let mut entries: Vec<(u32, f32)> = Vec::new();
        for r in 0..rows {
            entries.clear();
            for (c, &v) in x[r * cols..(r + 1) * cols].iter().enumerate() {
                if v != 0.0 {
                    entries.push((c as u32, v));
                }
            }
            b.push_row(&entries);
        }
        b.finish(cols)
    }

    /// An empty matrix with `rows` all-empty rows.
    pub fn empty(rows: usize, cols: usize) -> CsrMatrix {
        CsrMatrix {
            rows,
            cols,
            row_ptr: vec![0; rows + 1],
            col_idx: Vec::new(),
            vals: Vec::new(),
            sum_sq: vec![0.0; rows],
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Stored-entry fraction (1.0 = fully dense).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Approximate in-memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 8
            + self.col_idx.len() * 4
            + self.vals.len() * 4
            + self.sum_sq.len() * 4
    }

    /// Row i's `(columns, values)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Scatter row i into a dense buffer (`out.len() >= cols`; the tail
    /// past `cols` is zeroed too, so padded tile rows come out clean).
    pub fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        assert!(out.len() >= self.cols);
        for v in out.iter_mut() {
            *v = 0.0;
        }
        let (cols, vals) = self.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            out[c as usize] = v;
        }
    }

    /// Decompress to a row-major dense matrix.
    pub fn to_dense(&self) -> crate::linalg::Matrix {
        let mut m = crate::linalg::Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let row = m.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                row[c as usize] = v;
            }
        }
        m
    }

    /// Gather the given rows into a new matrix (row order = `idx` order).
    pub fn select(&self, idx: &[usize]) -> CsrMatrix {
        let nnz: usize = idx.iter().map(|&i| self.row_ptr[i + 1] - self.row_ptr[i]).sum();
        let mut row_ptr = Vec::with_capacity(idx.len() + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        let mut sum_sq = Vec::with_capacity(idx.len());
        row_ptr.push(0);
        for &i in idx {
            let (c, v) = self.row(i);
            col_idx.extend_from_slice(c);
            vals.extend_from_slice(v);
            row_ptr.push(col_idx.len());
            sum_sq.push(self.sum_sq[i]);
        }
        CsrMatrix { rows: idx.len(), cols: self.cols, row_ptr, col_idx, vals, sum_sq }
    }

    /// Same matrix with `rows` extended by trailing all-zero rows (tile
    /// padding: empty rows cost one pointer each, no values).
    pub fn pad_rows(&self, rows: usize) -> CsrMatrix {
        assert!(rows >= self.rows);
        let mut out = self.clone();
        out.row_ptr.resize(rows + 1, *self.row_ptr.last().unwrap());
        out.sum_sq.resize(rows, 0.0);
        out.rows = rows;
        out
    }

    /// Dot of row i with a dense vector, accumulated in the same
    /// KC-chunk order as [`CsrMatrix::sum_sq`] / the SpMM — so
    /// `dot(i, densified row i)` equals `sum_sq[i]` bit for bit.
    pub fn row_dot_dense(&self, i: usize, x: &[f32]) -> f32 {
        assert!(x.len() >= self.cols);
        let (cols, vals) = self.row(i);
        crate::linalg::simd::active().sparse_dot_dense(cols, vals, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::rng::Rng;

    fn rand_sparse_dense(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
        (0..rows * cols)
            .map(|_| if rng.bernoulli(density) { rng.gaussian_f32() } else { 0.0 })
            .collect()
    }

    #[test]
    fn dense_round_trip() {
        let mut rng = Rng::new(1);
        for &(r, c) in &[(1usize, 1usize), (7, 13), (40, 300), (5, 0)] {
            let x = rand_sparse_dense(&mut rng, r, c, 0.2);
            let csr = CsrMatrix::from_dense(r, c, &x);
            assert_eq!(csr.to_dense().data, x, "({r},{c})");
        }
    }

    #[test]
    fn sum_sq_matches_gemm_sum_sq_bitwise() {
        // including rows that span KC chunk boundaries
        let mut rng = Rng::new(2);
        for &cols in &[3usize, 255, 256, 257, 700] {
            let x = rand_sparse_dense(&mut rng, 4, cols, 0.3);
            let csr = CsrMatrix::from_dense(4, cols, &x);
            for i in 0..4 {
                let want = gemm::sum_sq(&x[i * cols..(i + 1) * cols]);
                assert_eq!(csr.sum_sq[i].to_bits(), want.to_bits(), "cols={cols} row {i}");
            }
        }
    }

    #[test]
    fn row_dot_dense_matches_sum_sq_on_self() {
        let mut rng = Rng::new(3);
        let cols = 600;
        let x = rand_sparse_dense(&mut rng, 6, cols, 0.15);
        let csr = CsrMatrix::from_dense(6, cols, &x);
        let mut buf = vec![0.0f32; cols];
        for i in 0..6 {
            csr.densify_row_into(i, &mut buf);
            assert_eq!(csr.row_dot_dense(i, &buf).to_bits(), csr.sum_sq[i].to_bits());
        }
    }

    #[test]
    fn select_gathers_rows_and_norms() {
        let mut rng = Rng::new(4);
        let x = rand_sparse_dense(&mut rng, 10, 20, 0.4);
        let csr = CsrMatrix::from_dense(10, 20, &x);
        let sel = csr.select(&[7, 0, 7]);
        assert_eq!(sel.rows, 3);
        let d = sel.to_dense();
        assert_eq!(d.row(0), &x[7 * 20..8 * 20]);
        assert_eq!(d.row(1), &x[..20]);
        assert_eq!(d.row(2), d.row(0));
        assert_eq!(sel.sum_sq[0].to_bits(), csr.sum_sq[7].to_bits());
    }

    #[test]
    fn pad_rows_appends_empty_rows() {
        let csr = CsrMatrix::from_dense(2, 3, &[1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let p = csr.pad_rows(5);
        assert_eq!(p.rows, 5);
        assert_eq!(p.nnz(), csr.nnz());
        let (c, v) = p.row(4);
        assert!(c.is_empty() && v.is_empty());
        assert_eq!(p.sum_sq[4], 0.0);
        let mut buf = [9.0f32; 3];
        p.densify_row_into(3, &mut buf);
        assert_eq!(buf, [0.0; 3]);
    }

    #[test]
    fn builder_drops_explicit_zeros() {
        let mut b = CsrBuilder::new();
        b.push_row(&[(0, 1.0), (2, 0.0), (5, -2.0)]);
        b.push_row(&[]);
        let m = b.finish(0);
        assert_eq!((m.rows, m.cols, m.nnz()), (2, 6, 2));
        assert_eq!(m.row(0), (&[0u32, 5][..], &[1.0f32, -2.0][..]));
        assert!((m.density() - 2.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn format_parses() {
        assert_eq!(Format::parse("csr").unwrap(), Format::Csr);
        assert_eq!(Format::parse("dense").unwrap(), Format::Dense);
        assert_eq!(Format::parse("auto").unwrap(), Format::Auto);
        assert!(Format::parse("nope").is_err());
        assert_eq!(Format::Csr.name(), "csr");
    }

    #[test]
    fn design_reports_shape_and_kind() {
        let d = Design::Sparse(CsrMatrix::from_dense(2, 3, &[0.0; 6]));
        assert!(d.is_sparse());
        assert_eq!((d.rows(), d.cols()), (2, 3));
        let m = Design::Dense(crate::linalg::Matrix::zeros(4, 5));
        assert!(!m.is_sparse());
        assert_eq!(m.bytes(), 80);
    }
}
