//! Dataset substrate: design matrices (dense row-major **or** CSR) with
//! labels.
//!
//! The paper's accelerated solvers store inputs dense, but its benchmark
//! *sources* are dominated by sparse libsvm files (adult, web, rcv1 at
//! d ≈ 47k) that cannot densify at full n. A [`Dataset`] therefore
//! carries a [`Design`]: `Dense(Matrix)` (the seed representation, the
//! packed-GEMM fast path), `Sparse(CsrMatrix)` (never densified; the
//! SpMM fast path — see `rust/DESIGN.md` §SPARSE), or the mmap-backed
//! variants `MmapDense`/`MmapCsr` served straight from a packed file
//! written by `wu-svm pack` (`rust/DESIGN.md` §OOC) — the out-of-core
//! path for sources bigger than RAM. Kernel evaluation, tiling,
//! prediction and serving all dispatch on the design; solvers are
//! unaware of the distinction.

pub mod libsvm;
pub mod mmap;
pub mod pack;
pub mod paper;
pub mod sparse;
pub mod synth;

use crate::linalg::Matrix;
use crate::rng::Rng;

pub use mmap::{MmapCsr, MmapMatrix};
pub use sparse::{CsrMatrix, Design, Format, AUTO_SPARSE_THRESHOLD};

/// A labeled dataset. `labels` are {-1,+1} for binary tasks; multiclass
/// tasks keep class ids in `class_ids` and derive pairwise binary views.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    /// The design matrix (dense or CSR — see module docs).
    pub design: Design,
    /// Binary labels in {-1.0, +1.0} (for multiclass: -1 placeholder).
    pub y: Vec<f32>,
    /// Multiclass ids (empty for binary tasks).
    pub class_ids: Vec<usize>,
    pub name: String,
}

impl Dataset {
    pub fn new_binary(name: &str, d: usize, x: Vec<f32>, y: Vec<f32>) -> Self {
        assert_eq!(x.len() % d, 0);
        let n = x.len() / d;
        Dataset::binary_with_design(name, Design::Dense(Matrix::from_vec(n, d, x)), y)
    }

    pub fn new_multiclass(name: &str, d: usize, x: Vec<f32>, class_ids: Vec<usize>) -> Self {
        assert_eq!(x.len() % d, 0);
        let n = x.len() / d;
        Dataset::multiclass_with_design(
            name,
            Design::Dense(Matrix::from_vec(n, d, x)),
            class_ids,
        )
    }

    /// Binary dataset over an explicit design (the CSR ingestion path).
    pub fn binary_with_design(name: &str, design: Design, y: Vec<f32>) -> Self {
        let (n, d) = (design.rows(), design.cols());
        assert_eq!(y.len(), n);
        debug_assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        Dataset { n, d, design, y, class_ids: Vec::new(), name: name.to_string() }
    }

    /// Multiclass dataset over an explicit design.
    pub fn multiclass_with_design(name: &str, design: Design, class_ids: Vec<usize>) -> Self {
        let (n, d) = (design.rows(), design.cols());
        assert_eq!(class_ids.len(), n);
        Dataset { n, d, design, y: vec![-1.0; n], class_ids, name: name.to_string() }
    }

    pub fn is_sparse(&self) -> bool {
        self.design.is_sparse()
    }

    /// The in-memory CSR design, if there is one. An mmap CSR design
    /// returns `None` — its callers dispatch on the design directly
    /// (or use [`Dataset::sparse_row`]).
    pub fn csr(&self) -> Option<&CsrMatrix> {
        match &self.design {
            Design::Sparse(c) => Some(c),
            Design::Dense(_) | Design::MmapDense(_) | Design::MmapCsr(_) => None,
        }
    }

    /// Row i's `(columns, values)` slices for either sparse storage
    /// (in-memory CSR or mapped CSR); `None` on dense designs.
    pub fn sparse_row(&self, i: usize) -> Option<(&[u32], &[f32])> {
        match &self.design {
            Design::Sparse(c) => Some(c.row(i)),
            Design::MmapCsr(mc) => Some(mc.row(i)),
            Design::Dense(_) | Design::MmapDense(_) => None,
        }
    }

    /// The dense row-major feature block (in-memory or mapped). Panics
    /// on sparse datasets — callers that must handle both use
    /// [`Dataset::row_into`] / [`Dataset::gather_rows`] or dispatch on
    /// [`Dataset::csr`].
    #[inline]
    pub fn dense_x(&self) -> &[f32] {
        match &self.design {
            Design::Dense(m) => &m.data,
            Design::MmapDense(m) => m.data(),
            Design::Sparse(_) | Design::MmapCsr(_) => {
                panic!("dense feature access on sparse dataset '{}'", self.name)
            }
        }
    }

    /// Row i of a dense dataset (panics on sparse — see
    /// [`Dataset::dense_x`]).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.d;
        &self.dense_x()[i * d..(i + 1) * d]
    }

    /// Copy row i (densified if needed) into `out` (`out.len() >= d`;
    /// any tail past `d` is zeroed).
    pub fn row_into(&self, i: usize, out: &mut [f32]) {
        assert!(out.len() >= self.d);
        match &self.design {
            Design::Dense(m) => {
                out[..self.d].copy_from_slice(m.row(i));
                for v in out[self.d..].iter_mut() {
                    *v = 0.0;
                }
            }
            Design::MmapDense(m) => {
                out[..self.d].copy_from_slice(m.row(i));
                for v in out[self.d..].iter_mut() {
                    *v = 0.0;
                }
            }
            Design::Sparse(c) => c.densify_row_into(i, out),
            Design::MmapCsr(mc) => mc.densify_row_into(i, out),
        }
    }

    /// Densified copies of the given rows, row-major `idx.len() x d`
    /// (model extraction: support/basis vectors are stored dense).
    pub fn gather_rows(&self, idx: &[usize]) -> Vec<f32> {
        let d = self.d;
        let mut out = vec![0.0f32; idx.len() * d];
        match &self.design {
            Design::Dense(m) => {
                for (q, &i) in idx.iter().enumerate() {
                    out[q * d..(q + 1) * d].copy_from_slice(m.row(i));
                }
            }
            Design::MmapDense(m) => {
                for (q, &i) in idx.iter().enumerate() {
                    out[q * d..(q + 1) * d].copy_from_slice(m.row(i));
                }
            }
            Design::Sparse(c) => {
                for (q, &i) in idx.iter().enumerate() {
                    c.densify_row_into(i, &mut out[q * d..(q + 1) * d]);
                }
            }
            Design::MmapCsr(mc) => {
                for (q, &i) in idx.iter().enumerate() {
                    mc.densify_row_into(i, &mut out[q * d..(q + 1) * d]);
                }
            }
        }
        out
    }

    /// Convert to the requested [`Format`] (no-op when already there;
    /// `Auto` applies the [`AUTO_SPARSE_THRESHOLD`] density rule, and
    /// leaves mmap-backed designs mapped). An explicit `Dense`/`Csr`
    /// request on an mmap design materializes it in memory.
    pub fn with_format(mut self, format: Format) -> Dataset {
        if self.design.is_mmap() && format == Format::Auto {
            return self;
        }
        let sparse = self.is_sparse();
        match format {
            Format::Dense if sparse => {
                let m = match &self.design {
                    Design::Sparse(c) => c.to_dense(),
                    Design::MmapCsr(mc) => mc.to_csr().to_dense(),
                    Design::Dense(_) | Design::MmapDense(_) => unreachable!(),
                };
                self.design = Design::Dense(m);
            }
            Format::Dense if self.design.is_mmap() => {
                let m = Matrix::from_vec(self.n, self.d, self.dense_x().to_vec());
                self.design = Design::Dense(m);
            }
            Format::Csr if !sparse => {
                let csr = CsrMatrix::from_dense(self.n, self.d, self.dense_x());
                self.design = Design::Sparse(csr);
            }
            Format::Csr if matches!(self.design, Design::MmapCsr(_)) => {
                let Design::MmapCsr(mc) = &self.design else { unreachable!() };
                self.design = Design::Sparse(mc.to_csr());
            }
            Format::Auto if !sparse && self.sparsity() >= 1.0 - AUTO_SPARSE_THRESHOLD => {
                let csr = CsrMatrix::from_dense(self.n, self.d, self.dense_x());
                self.design = Design::Sparse(csr);
            }
            _ => {}
        }
        self
    }

    pub fn is_multiclass(&self) -> bool {
        !self.class_ids.is_empty()
    }

    pub fn num_classes(&self) -> usize {
        self.class_ids.iter().copied().max().map_or(2, |m| m + 1)
    }

    /// Scale every feature to [0, 1] (paper §5 "Datasets"). Returns the
    /// per-feature (min, max) used, so test sets can reuse train scaling.
    /// Dense-only: min-max shifting would densify a sparse design (real
    /// libsvm sources ship pre-scaled).
    pub fn scale_unit(&mut self) -> Vec<(f32, f32)> {
        let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); self.d];
        for i in 0..self.n {
            for (j, &v) in self.row(i).iter().enumerate() {
                ranges[j].0 = ranges[j].0.min(v);
                ranges[j].1 = ranges[j].1.max(v);
            }
        }
        self.apply_scaling(&ranges);
        ranges
    }

    /// Apply previously computed per-feature (min, max) scaling
    /// (dense-only, like [`Dataset::scale_unit`]).
    pub fn apply_scaling(&mut self, ranges: &[(f32, f32)]) {
        assert_eq!(ranges.len(), self.d);
        let d = self.d;
        let Design::Dense(m) = &mut self.design else {
            panic!("scaling would densify sparse dataset '{}'", self.name);
        };
        for i in 0..self.n {
            let row = &mut m.data[i * d..(i + 1) * d];
            for (v, &(lo, hi)) in row.iter_mut().zip(ranges) {
                let span = hi - lo;
                *v = if span > 0.0 { (*v - lo) / span } else { 0.0 };
            }
        }
    }

    /// Uniform random subsample without replacement (paper §5 subsamples
    /// Epsilon and FD the same way).
    pub fn subsample(&self, n_keep: usize, seed: u64) -> Dataset {
        let n_keep = n_keep.min(self.n);
        let mut rng = Rng::new(seed);
        let mut idx = rng.sample_indices(self.n, n_keep);
        idx.sort_unstable();
        self.select(&idx)
    }

    /// Row-index selection (format-preserving for in-memory designs;
    /// a selection from an mmap design materializes in memory — the
    /// subset is expected to be small relative to the mapped file).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let design = match &self.design {
            Design::Dense(m) => {
                let mut x = Vec::with_capacity(idx.len() * self.d);
                for &i in idx {
                    x.extend_from_slice(m.row(i));
                }
                Design::Dense(Matrix::from_vec(idx.len(), self.d, x))
            }
            Design::MmapDense(m) => {
                let mut x = Vec::with_capacity(idx.len() * self.d);
                for &i in idx {
                    x.extend_from_slice(m.row(i));
                }
                Design::Dense(Matrix::from_vec(idx.len(), self.d, x))
            }
            Design::Sparse(c) => Design::Sparse(c.select(idx)),
            Design::MmapCsr(mc) => Design::Sparse(mc.select_csr(idx)),
        };
        let mut y = Vec::with_capacity(idx.len());
        let mut cls = Vec::new();
        for &i in idx {
            y.push(self.y[i]);
            if self.is_multiclass() {
                cls.push(self.class_ids[i]);
            }
        }
        Dataset {
            n: idx.len(),
            d: self.d,
            design,
            y,
            class_ids: cls,
            name: self.name.clone(),
        }
    }

    /// Shuffled train/test split.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.n).collect();
        Rng::new(seed).shuffle(&mut idx);
        let ntr = ((self.n as f64) * train_frac).round() as usize;
        let ntr = ntr.clamp(1, self.n.saturating_sub(1).max(1));
        (self.select(&idx[..ntr]), self.select(&idx[ntr..]))
    }

    /// Fraction of exactly-zero entries (sparsity, kdd99-like is ~90%).
    pub fn sparsity(&self) -> f64 {
        if self.n == 0 || self.d == 0 {
            return 0.0;
        }
        let total = self.n * self.d;
        let nonzero = match &self.design {
            Design::Dense(m) => m.data.iter().filter(|&&v| v != 0.0).count(),
            Design::MmapDense(m) => m.data().iter().filter(|&&v| v != 0.0).count(),
            // stored values are nonzero by construction
            Design::Sparse(c) => c.nnz(),
            Design::MmapCsr(mc) => mc.nnz(),
        };
        (total - nonzero) as f64 / total as f64
    }

    /// Positive-class fraction (class-imbalance check, mitfaces-like).
    pub fn positive_fraction(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.n as f64
    }

    /// Binary one-vs-one view of a multiclass dataset: class `a` -> +1,
    /// class `b` -> -1, others dropped.
    pub fn ovo_view(&self, a: usize, b: usize) -> Dataset {
        assert!(self.is_multiclass());
        let idx: Vec<usize> = (0..self.n)
            .filter(|&i| self.class_ids[i] == a || self.class_ids[i] == b)
            .collect();
        let mut ds = self.select(&idx);
        for (yi, &i) in ds.y.iter_mut().zip(&idx) {
            *yi = if self.class_ids[i] == a { 1.0 } else { -1.0 };
        }
        ds.class_ids.clear();
        ds.name = format!("{}-{}v{}", self.name, a, b);
        ds
    }

    /// Approximate in-memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.design.bytes() + self.y.len() * 4 + self.class_ids.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new_binary(
            "t",
            2,
            vec![0.0, 10.0, 1.0, 20.0, 2.0, 30.0, 3.0, 40.0],
            vec![1.0, -1.0, 1.0, -1.0],
        )
    }

    #[test]
    fn scale_unit_maps_to_unit_interval() {
        let mut ds = tiny();
        let ranges = ds.scale_unit();
        assert_eq!(ranges, vec![(0.0, 3.0), (10.0, 40.0)]);
        for i in 0..ds.n {
            for &v in ds.row(i) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
        assert_eq!(ds.row(0), &[0.0, 0.0]);
        assert_eq!(ds.row(3), &[1.0, 1.0]);
    }

    #[test]
    fn apply_scaling_reuses_train_ranges() {
        let mut train = tiny();
        let ranges = train.scale_unit();
        let mut test = Dataset::new_binary("t2", 2, vec![1.5, 25.0], vec![1.0]);
        test.apply_scaling(&ranges);
        assert_eq!(test.row(0), &[0.5, 0.5]);
    }

    #[test]
    fn constant_feature_scales_to_zero() {
        let mut ds = Dataset::new_binary("c", 1, vec![5.0, 5.0], vec![1.0, -1.0]);
        ds.scale_unit();
        assert_eq!(ds.dense_x(), &[0.0, 0.0]);
    }

    #[test]
    fn subsample_preserves_rows() {
        let ds = tiny();
        let sub = ds.subsample(2, 1);
        assert_eq!(sub.n, 2);
        for i in 0..sub.n {
            let found = (0..ds.n).any(|j| ds.row(j) == sub.row(i) && ds.y[j] == sub.y[i]);
            assert!(found);
        }
    }

    #[test]
    fn split_partitions() {
        let ds = tiny();
        let (tr, te) = ds.split(0.5, 3);
        assert_eq!(tr.n + te.n, ds.n);
        assert_eq!(tr.n, 2);
    }

    #[test]
    fn ovo_view_filters_and_relabels() {
        let ds = Dataset::new_multiclass(
            "m",
            1,
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            vec![0, 1, 2, 0, 1, 2],
        );
        let v = ds.ovo_view(0, 2);
        assert_eq!(v.n, 4);
        assert_eq!(v.y, vec![1.0, -1.0, 1.0, -1.0]);
        assert!(!v.is_multiclass());
    }

    #[test]
    fn sparsity_and_imbalance() {
        let ds = Dataset::new_binary("s", 2, vec![0.0, 1.0, 0.0, 0.0], vec![1.0, -1.0]);
        assert!((ds.sparsity() - 0.75).abs() < 1e-12);
        assert!((ds.positive_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn num_classes_counts() {
        let ds = Dataset::new_multiclass("m", 1, vec![0.0; 3], vec![0, 4, 2]);
        assert_eq!(ds.num_classes(), 5);
    }

    #[test]
    fn format_round_trip_preserves_values() {
        let ds = Dataset::new_binary(
            "f",
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.0],
            vec![1.0, -1.0, 1.0],
        );
        let sp = ds.clone().with_format(Format::Csr);
        assert!(sp.is_sparse());
        assert_eq!(sp.csr().unwrap().nnz(), 3);
        assert!((sp.sparsity() - ds.sparsity()).abs() < 1e-12);
        let back = sp.clone().with_format(Format::Dense);
        assert!(!back.is_sparse());
        assert_eq!(back.dense_x(), ds.dense_x());
        // auto picks csr at ~67% zeros (threshold 75% sparsity)... this
        // one is 6/9 = 66.7% zeros < 75%: stays dense
        assert!(!ds.clone().with_format(Format::Auto).is_sparse());
    }

    #[test]
    fn sparse_select_and_row_into() {
        let ds = Dataset::new_binary(
            "f",
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.0],
            vec![1.0, -1.0, 1.0],
        )
        .with_format(Format::Csr);
        let sel = ds.select(&[2, 0]);
        assert!(sel.is_sparse());
        assert_eq!(sel.y, vec![1.0, 1.0]);
        let mut buf = [9.0f32; 4];
        sel.row_into(0, &mut buf);
        assert_eq!(buf, [0.0, 0.5, 0.0, 0.0]);
        assert_eq!(ds.gather_rows(&[0, 2]), vec![1.0, 0.0, 2.0, 0.0, 0.5, 0.0]);
    }

    #[test]
    fn sparse_ovo_view_stays_sparse() {
        let ds = Dataset::new_multiclass(
            "m",
            2,
            vec![1.0, 0.0, 0.0, 2.0, 3.0, 0.0, 0.0, 4.0],
            vec![0, 1, 0, 2],
        )
        .with_format(Format::Csr);
        let v = ds.ovo_view(0, 2);
        assert!(v.is_sparse());
        assert_eq!(v.n, 3);
        assert_eq!(v.y, vec![1.0, 1.0, -1.0]);
    }
}
