//! The packed on-disk dataset format behind the out-of-core path
//! (DESIGN.md §OOC).
//!
//! `wu-svm pack` converts a libsvm text file once into this binary
//! layout; [`load_packed`] then memory-maps it and hands back a
//! [`Dataset`] whose design is [`Design::MmapDense`] or
//! [`Design::MmapCsr`] — labels are small and copied, the design matrix
//! stays on disk.
//!
//! Layout (all integers and floats native-endian, each section padded
//! to an 8-byte boundary):
//!
//! ```text
//! header (64 bytes):
//!   magic    b"WUSVPACK"          8 bytes
//!   version  u32 = 1
//!   endian   u32 = 0x01020304     (reads back swapped on the wrong arch)
//!   kind     u32                  0 = dense, 1 = csr
//!   flags    u32                  bit 0 = multiclass
//!   n        u64                  rows
//!   d        u64                  features
//!   nnz      u64                  stored values (0 for dense)
//!   reserved 16 zero bytes
//! sections:
//!   y          f32 x n            {-1,+1} labels (multiclass: -1 fill)
//!   class_ids  u32 x n            only when the multiclass flag is set
//!   dense kind: x          f32 x (n*d)     row-major design
//!   csr   kind: sum_sq     f32 x n         stored KC-chunk-order norms
//!               row_ptr    u64 x (n+1)
//!               col_idx    u32 x nnz
//!               vals       f32 x nnz
//! ```
//!
//! The sections are byte-for-byte the in-memory representations (norms
//! included — stored at pack time, never recomputed at load), which is
//! the whole bit-identity argument: mapping the file recovers exactly
//! the arrays the packing process trained from.

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::mmap::{MmapCsr, MmapFile, MmapMatrix};
use super::sparse::Format;
use super::{Dataset, Design};

pub const MAGIC: &[u8; 8] = b"WUSVPACK";
pub const VERSION: u32 = 1;
/// Written native-endian; a cross-endian reader sees the bytes swapped.
pub const ENDIAN_TAG: u32 = 0x0102_0304;
const KIND_DENSE: u32 = 0;
const KIND_CSR: u32 = 1;
const FLAG_MULTICLASS: u32 = 1;
const HEADER_BYTES: usize = 64;

fn align8(off: usize) -> usize {
    (off + 7) & !7
}

/// Byte offsets of every section for a given header, shared by the
/// writer and the loader so the two can never disagree.
struct Layout {
    y_off: usize,
    cls_off: usize,
    x_off: usize,
    sum_sq_off: usize,
    row_ptr_off: usize,
    col_idx_off: usize,
    vals_off: usize,
    total: usize,
}

fn layout(kind: u32, multiclass: bool, n: usize, d: usize, nnz: usize) -> Layout {
    let y_off = HEADER_BYTES;
    let cls_off = align8(y_off + 4 * n);
    let mut off = if multiclass { align8(cls_off + 4 * n) } else { cls_off };
    let (x_off, sum_sq_off);
    let (mut row_ptr_off, mut col_idx_off, mut vals_off) = (off, off, off);
    if kind == KIND_DENSE {
        x_off = off;
        sum_sq_off = off;
        off = align8(off + 4 * n * d);
    } else {
        x_off = off;
        sum_sq_off = off;
        off = align8(off + 4 * n);
        row_ptr_off = off;
        off = align8(off + 8 * (n + 1));
        col_idx_off = off;
        off = align8(off + 4 * nnz);
        vals_off = off;
        off = align8(off + 4 * nnz);
    }
    Layout { y_off, cls_off, x_off, sum_sq_off, row_ptr_off, col_idx_off, vals_off, total: off }
}

/// View any plain scalar slice as native-endian bytes.
fn raw_bytes<T: Copy>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
    }
}

/// Advance the writer to `off` with zero padding, then emit `bytes`.
fn put<W: Write>(w: &mut W, pos: &mut usize, off: usize, bytes: &[u8]) -> Result<()> {
    assert!(off >= *pos, "section write out of order");
    const ZEROS: [u8; 8] = [0; 8];
    w.write_all(&ZEROS[..off - *pos])?;
    w.write_all(bytes)?;
    *pos = off + bytes.len();
    Ok(())
}

/// Whether `path` starts with the packed-file magic (the coordinator
/// sniffs this so `--input file.wup` needs no format flag).
pub fn is_packed_file(path: &Path) -> bool {
    let mut head = [0u8; 8];
    match std::fs::File::open(path) {
        Ok(mut f) => f.read_exact(&mut head).is_ok() && &head == MAGIC,
        Err(_) => false,
    }
}

/// Write a dataset's in-memory design to the packed layout. Refuses
/// mmap-backed designs — they already live in a packed file.
pub fn write_packed(ds: &Dataset, path: &Path) -> Result<()> {
    let (kind, nnz) = match &ds.design {
        Design::Dense(_) => (KIND_DENSE, 0),
        Design::Sparse(c) => (KIND_CSR, c.nnz()),
        Design::MmapDense(_) | Design::MmapCsr(_) => {
            bail!("dataset '{}' is already mmap-backed; copy the packed file instead", ds.name)
        }
    };
    let multiclass = ds.is_multiclass();
    let lay = layout(kind, multiclass, ds.n, ds.d, nnz);

    let mut header = [0u8; HEADER_BYTES];
    header[..8].copy_from_slice(MAGIC);
    header[8..12].copy_from_slice(&VERSION.to_ne_bytes());
    header[12..16].copy_from_slice(&ENDIAN_TAG.to_ne_bytes());
    header[16..20].copy_from_slice(&kind.to_ne_bytes());
    let flags: u32 = if multiclass { FLAG_MULTICLASS } else { 0 };
    header[20..24].copy_from_slice(&flags.to_ne_bytes());
    header[24..32].copy_from_slice(&(ds.n as u64).to_ne_bytes());
    header[32..40].copy_from_slice(&(ds.d as u64).to_ne_bytes());
    header[40..48].copy_from_slice(&(nnz as u64).to_ne_bytes());

    let f = std::fs::File::create(path)
        .with_context(|| format!("create packed file {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    let mut pos = 0usize;
    put(&mut w, &mut pos, 0, &header)?;
    put(&mut w, &mut pos, lay.y_off, raw_bytes(&ds.y))?;
    if multiclass {
        let cls: Vec<u32> = ds.class_ids.iter().map(|&c| c as u32).collect();
        put(&mut w, &mut pos, lay.cls_off, raw_bytes(&cls))?;
    }
    match &ds.design {
        Design::Dense(m) => put(&mut w, &mut pos, lay.x_off, raw_bytes(&m.data))?,
        Design::Sparse(c) => {
            put(&mut w, &mut pos, lay.sum_sq_off, raw_bytes(&c.sum_sq))?;
            let rp: Vec<u64> = c.row_ptr.iter().map(|&p| p as u64).collect();
            put(&mut w, &mut pos, lay.row_ptr_off, raw_bytes(&rp))?;
            put(&mut w, &mut pos, lay.col_idx_off, raw_bytes(&c.col_idx))?;
            put(&mut w, &mut pos, lay.vals_off, raw_bytes(&c.vals))?;
        }
        Design::MmapDense(_) | Design::MmapCsr(_) => unreachable!(),
    }
    put(&mut w, &mut pos, lay.total, &[])?;
    w.flush().with_context(|| format!("write packed file {}", path.display()))?;
    Ok(())
}

fn header_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_ne_bytes(bytes[off..off + 4].try_into().unwrap())
}

fn header_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_ne_bytes(bytes[off..off + 8].try_into().unwrap())
}

/// Memory-map a packed file into a [`Dataset`]: labels copied (small),
/// design served from the mapping.
pub fn load_packed(path: &Path) -> Result<Dataset> {
    let map = Arc::new(MmapFile::open(path)?);
    let bytes = map.bytes();
    if bytes.len() < HEADER_BYTES || &bytes[..8] != MAGIC {
        bail!("{} is not a wu-svm packed file (bad magic)", path.display());
    }
    let version = header_u32(bytes, 8);
    if version != VERSION {
        bail!("{}: packed format v{version}, this build reads v{VERSION}", path.display());
    }
    let endian = header_u32(bytes, 12);
    if endian != ENDIAN_TAG {
        if endian == ENDIAN_TAG.swap_bytes() {
            bail!(
                "{} was packed on a machine with the opposite endianness; repack it here",
                path.display()
            );
        }
        bail!("{}: corrupt endianness tag {endian:#010x}", path.display());
    }
    let kind = header_u32(bytes, 16);
    let flags = header_u32(bytes, 20);
    let n = header_u64(bytes, 24) as usize;
    let d = header_u64(bytes, 32) as usize;
    let nnz = header_u64(bytes, 40) as usize;
    let multiclass = flags & FLAG_MULTICLASS != 0;
    if kind != KIND_DENSE && kind != KIND_CSR {
        bail!("{}: unknown design kind {kind}", path.display());
    }
    let lay = layout(kind, multiclass, n, d, nnz);
    if lay.total != bytes.len() {
        bail!(
            "{}: header promises {} bytes, file has {} (truncated or corrupt)",
            path.display(),
            lay.total,
            bytes.len()
        );
    }
    let y = map.f32s(lay.y_off, n).to_vec();
    let class_ids: Vec<usize> = if multiclass {
        map.u32s(lay.cls_off, n).iter().map(|&c| c as usize).collect()
    } else {
        Vec::new()
    };
    let design = if kind == KIND_DENSE {
        Design::MmapDense(MmapMatrix::new(map, n, d, lay.x_off))
    } else {
        Design::MmapCsr(MmapCsr::new(
            map,
            n,
            d,
            nnz,
            lay.sum_sq_off,
            lay.row_ptr_off,
            lay.col_idx_off,
            lay.vals_off,
        )?)
    };
    let name = path.file_stem().map_or_else(|| "packed".into(), |s| s.to_string_lossy());
    Ok(Dataset { n, d, design, y, class_ids, name: name.into_owned() })
}

/// The one-shot converter behind `wu-svm pack`: parse a libsvm text
/// file (honoring the usual `--format` choice, `Auto` applies the
/// density rule) and write the packed layout. Returns `(rows, features,
/// storage-kind-name)` for the report.
pub fn pack_file(
    input: &Path,
    output: &Path,
    d_hint: usize,
    format: Format,
) -> Result<(usize, usize, &'static str)> {
    let ds = super::libsvm::read_file_with(input, d_hint, format)?;
    write_packed(&ds, output)?;
    let kind = if ds.is_sparse() { "csr" } else { "dense" };
    Ok((ds.n, ds.d, kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("wu_svm_pack_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn dense_ds() -> Dataset {
        Dataset::new_binary(
            "t",
            3,
            vec![1.0, 0.0, 2.5, -1.0, 0.5, 0.0, 0.0, 0.0, 4.0],
            vec![1.0, -1.0, 1.0],
        )
    }

    #[test]
    fn dense_round_trip_is_bit_exact() {
        let ds = dense_ds();
        let path = tmp("dense.wup");
        write_packed(&ds, &path).unwrap();
        assert!(is_packed_file(&path));
        let back = load_packed(&path).unwrap();
        assert_eq!(back.n, ds.n);
        assert_eq!(back.d, ds.d);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.dense_x(), ds.dense_x());
        assert!(matches!(back.design, Design::MmapDense(_)));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csr_round_trip_preserves_triplet_and_norms() {
        let ds = dense_ds().with_format(Format::Csr);
        let path = tmp("csr.wup");
        write_packed(&ds, &path).unwrap();
        let back = load_packed(&path).unwrap();
        let want = ds.csr().unwrap();
        let Design::MmapCsr(mc) = &back.design else { panic!("expected mmap csr") };
        assert_eq!(mc.to_csr(), *want);
        for i in 0..ds.n {
            let (wc, wv) = want.row(i);
            assert_eq!(mc.row(i), (wc, wv));
            assert_eq!(mc.sum_sq()[i].to_bits(), want.sum_sq[i].to_bits());
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn multiclass_labels_survive() {
        let ds = Dataset::new_multiclass("m", 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], vec![0, 2, 1]);
        let path = tmp("multi.wup");
        write_packed(&ds, &path).unwrap();
        let back = load_packed(&path).unwrap();
        assert_eq!(back.class_ids, ds.class_ids);
        assert!(back.is_multiclass());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loader_rejects_corruption() {
        let ds = dense_ds();
        let path = tmp("corrupt.wup");
        write_packed(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flipped endianness tag must be diagnosed, not misread
        bytes[12..16].copy_from_slice(&ENDIAN_TAG.swap_bytes().to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_packed(&path).unwrap_err().to_string();
        assert!(err.contains("endian"), "{err}");
        // truncation must be diagnosed too
        bytes[12..16].copy_from_slice(&ENDIAN_TAG.to_ne_bytes());
        bytes.truncate(bytes.len() - 4);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_packed(&path).is_err());
        std::fs::write(&path, b"short").unwrap();
        assert!(load_packed(&path).is_err());
        assert!(!is_packed_file(&path));
        std::fs::remove_file(path).ok();
    }
}
