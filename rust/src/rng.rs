//! Deterministic, dependency-free random number generation.
//!
//! The offline crate registry has no `rand`, so we ship a SplitMix64
//! generator: tiny, fast, and statistically solid for the purposes of this
//! crate (synthetic dataset generation, candidate sampling in SP-SVM,
//! property-test case generation). Every consumer takes an explicit seed so
//! all experiments are reproducible.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free bounded sample is overkill here;
        // modulo bias is negligible for the n values we use (< 2^32).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled uniformly from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's algorithm for sparse sampling.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }

    /// Derive an independent stream (for per-thread / per-run seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let m: f64 = (0..50_000).map(|_| r.uniform()).sum::<f64>() / 50_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(13);
        for &(n, k) in &[(10usize, 10usize), (100, 7), (1000, 64), (5, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(1);
        let mut f = a.fork();
        let x = a.next_u64();
        let y = f.next_u64();
        assert_ne!(x, y);
    }
}
