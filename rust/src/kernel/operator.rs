//! Kernel access behind an object-safe operator (DESIGN.md §LOWRANK).
//!
//! The implicit solvers (`mu`, `primal`, `spsvm`, `lssvm`) never touch
//! kernel tiles directly any more: they consume a `&dyn KernelOperator`
//! and see only `matvec` / `block` / `diag`. Four implementations:
//!
//! * [`ExactDense`] — the full n × n matrix, materialized once
//!   (memory-capped, the pre-refactor MU/Primal behavior).
//! * [`ExactTiled`] — streaming exact kernel over the dense GEMM path;
//!   only a `row_tile × n` staging buffer is resident.
//! * [`ExactCsr`] — the same streaming operator for sparse designs
//!   (CSR SpMM path under [`kernel_block`]).
//! * [`LowRank`] — K ≈ G Gᵀ via pivoted ICF or Nyström landmarks
//!   ([`crate::linalg::lowrank`]); `matvec` is two skinny GEMVs at
//!   O(n·r) memory and per-iteration cost — the paper's approximate
//!   implicit regime.
//!
//! Every implementation inherits the substrate determinism contract:
//! outputs are bit-identical across thread counts, and the exact
//! operators agree bit-for-bit with each other because [`kernel_block`]
//! values are independent of tile shape (per-element accumulation
//! order is fixed — DESIGN.md §GEMM).

use anyhow::{anyhow, ensure, Result};

use crate::data::{Dataset, Design};
use crate::linalg::{gemm, gemm_nt, gemv, gemv_t, lowrank, Matrix};

use super::{full_kernel, kernel_block, KernelKind};

/// Object-safe view of an n × n SPD kernel matrix.
pub trait KernelOperator: Send + Sync {
    /// Number of training points (the operator is n × n).
    fn n(&self) -> usize;
    /// out = K v. Bit-identical for every thread count.
    fn matvec(&self, v: &[f32], out: &mut [f32]);
    /// Row-major `|ri| × |ci|` block of K.
    fn block(&self, ri: &[usize], ci: &[usize], out: &mut [f32]);
    /// The operator's own diagonal — exact K_ii for the exact
    /// operators, `||g_i||²` for [`LowRank`].
    fn diag(&self, out: &mut [f32]);
    /// Bytes held resident (materialized matrix / factor / staging).
    fn memory_bytes(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// Exact kernel diagonal K_ii for any design.
pub fn kernel_diag(kind: &KernelKind, ds: &Dataset, out: &mut [f32]) {
    assert_eq!(out.len(), ds.n);
    match &ds.design {
        Design::Dense(_) | Design::MmapDense(_) => {
            for i in 0..ds.n {
                out[i] = kind.self_eval(ds.row(i));
            }
        }
        Design::Sparse(_) | Design::MmapCsr(_) => {
            let sum_sq: &[f32] = match &ds.design {
                Design::Sparse(csr) => &csr.sum_sq,
                Design::MmapCsr(mc) => mc.sum_sq(),
                _ => unreachable!(),
            };
            match *kind {
                KernelKind::Rbf { .. } => out.fill(1.0),
                KernelKind::Linear => out.copy_from_slice(sum_sq),
                KernelKind::Poly { degree, gamma, coef0 } => {
                    for i in 0..ds.n {
                        out[i] = (gamma * sum_sq[i] + coef0).powi(degree);
                    }
                }
            }
        }
    }
}

/// Staging-tile height targeting ~32 MB of `row_tile × n` buffer.
fn default_row_tile(n: usize) -> usize {
    ((32 << 20) / (4 * n.max(1))).max(8).min(n.max(1))
}

// ---------------------------------------------------------------- exact

/// The fully materialized kernel matrix (memory-capped).
pub struct ExactDense {
    k: Matrix,
    threads: usize,
}

impl ExactDense {
    /// Materialize the full kernel; refuses above `max_bytes` with the
    /// same "memory wall" diagnostic as [`full_kernel`].
    pub fn build(
        kind: &KernelKind,
        ds: &Dataset,
        threads: usize,
        max_bytes: usize,
    ) -> Result<Self> {
        let _sp = crate::trace::span("operator/exact-dense");
        let k = full_kernel(kind, ds, threads, max_bytes).map_err(|e| anyhow!(e))?;
        Ok(ExactDense { k, threads })
    }

    /// Wrap an already-built n × n matrix.
    pub fn from_matrix(k: Matrix, threads: usize) -> Self {
        assert_eq!(k.rows, k.cols);
        ExactDense { k, threads }
    }

    /// The materialized matrix (MU's Q± split streams its rows).
    pub fn matrix(&self) -> &Matrix {
        &self.k
    }
}

impl KernelOperator for ExactDense {
    fn n(&self) -> usize {
        self.k.rows
    }

    fn matvec(&self, v: &[f32], out: &mut [f32]) {
        gemv(self.threads, &self.k, v, out);
    }

    fn block(&self, ri: &[usize], ci: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), ri.len() * ci.len());
        let s = ci.len();
        for (q, &i) in ri.iter().enumerate() {
            let row = self.k.row(i);
            for (slot, &j) in out[q * s..(q + 1) * s].iter_mut().zip(ci) {
                *slot = row[j];
            }
        }
    }

    fn diag(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.k.rows);
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.k.at(i, i);
        }
    }

    fn memory_bytes(&self) -> usize {
        self.k.rows * self.k.cols * 4
    }

    fn name(&self) -> &'static str {
        "exact-dense"
    }
}

/// Streaming exact operator over the dense/sparse tile producer
/// [`kernel_block`]: nothing n × n is ever resident.
pub struct ExactTiled<'a> {
    ds: &'a Dataset,
    kind: KernelKind,
    threads: usize,
    row_tile: usize,
}

impl<'a> ExactTiled<'a> {
    pub fn new(kind: KernelKind, ds: &'a Dataset, threads: usize) -> Self {
        let row_tile = default_row_tile(ds.n);
        ExactTiled { ds, kind, threads, row_tile }
    }
}

impl KernelOperator for ExactTiled<'_> {
    fn n(&self) -> usize {
        self.ds.n
    }

    fn matvec(&self, v: &[f32], out: &mut [f32]) {
        let n = self.ds.n;
        assert_eq!(v.len(), n);
        assert_eq!(out.len(), n);
        let all: Vec<usize> = (0..n).collect();
        let mut buf = vec![0.0f32; self.row_tile.min(n) * n];
        let mut start = 0;
        // sequential tile loop; each tile's values and the GEMV over it
        // are tile-shape-independent per element, so out matches the
        // materialized path bit-for-bit.
        while start < n {
            let m = self.row_tile.min(n - start);
            let ri = &all[start..start + m];
            kernel_block(&self.kind, self.ds, ri, &all, self.threads, &mut buf[..m * n]);
            gemm::gemv_blocked(
                self.threads,
                m,
                n,
                &buf[..m * n],
                n,
                v,
                &mut out[start..start + m],
            );
            start += m;
        }
    }

    fn block(&self, ri: &[usize], ci: &[usize], out: &mut [f32]) {
        kernel_block(&self.kind, self.ds, ri, ci, self.threads, out);
    }

    fn diag(&self, out: &mut [f32]) {
        kernel_diag(&self.kind, self.ds, out);
    }

    fn memory_bytes(&self) -> usize {
        self.row_tile.min(self.ds.n) * self.ds.n * 4
    }

    fn name(&self) -> &'static str {
        "exact-tiled"
    }
}

/// [`ExactTiled`] restricted to sparse designs — the tile producer
/// routes through the CSR SpMM path, whose output is bit-identical to
/// the dense path by the substrate contract.
pub struct ExactCsr<'a>(ExactTiled<'a>);

impl<'a> ExactCsr<'a> {
    pub fn new(kind: KernelKind, ds: &'a Dataset, threads: usize) -> Result<Self> {
        ensure!(
            ds.is_sparse(),
            "exact-csr operator needs a sparse design (dataset '{}' is dense)",
            ds.name
        );
        Ok(ExactCsr(ExactTiled::new(kind, ds, threads)))
    }
}

impl KernelOperator for ExactCsr<'_> {
    fn n(&self) -> usize {
        self.0.n()
    }

    fn matvec(&self, v: &[f32], out: &mut [f32]) {
        self.0.matvec(v, out)
    }

    fn block(&self, ri: &[usize], ci: &[usize], out: &mut [f32]) {
        self.0.block(ri, ci, out)
    }

    fn diag(&self, out: &mut [f32]) {
        self.0.diag(out)
    }

    fn memory_bytes(&self) -> usize {
        self.0.memory_bytes()
    }

    fn name(&self) -> &'static str {
        "exact-csr"
    }
}

// -------------------------------------------------------------- lowrank

/// K ≈ G Gᵀ: the paper's approximate implicit regime. `matvec` is
/// `G (Gᵀ v)` — two skinny GEMVs, O(n·r) flops and bytes.
pub struct LowRank {
    g: Matrix,
    diag: Vec<f32>,
    residual_frac: f64,
    threads: usize,
    method: &'static str,
}

impl LowRank {
    /// Pivoted incomplete Cholesky factor of the kernel
    /// ([`lowrank::icf`]); kernel columns stream through
    /// [`kernel_block`] on demand.
    pub fn icf(kind: &KernelKind, ds: &Dataset, threads: usize, rank: usize, tol: f64) -> Self {
        let n = ds.n;
        let mut dg = vec![0.0f32; n];
        kernel_diag(kind, ds, &mut dg);
        let all: Vec<usize> = (0..n).collect();
        let f = lowrank::icf(threads, &dg, rank, tol, |p, col| {
            kernel_block(kind, ds, &all, &[p], threads, col)
        });
        LowRank::from_factor(f, threads, "icf")
    }

    /// Nyström factor over evenly spread landmark rows
    /// ([`lowrank::nystrom`]); deterministic landmark choice, shared
    /// escalating-ridge regularization of the landmark Gram.
    pub fn nystrom(
        kind: &KernelKind,
        ds: &Dataset,
        threads: usize,
        landmarks: usize,
    ) -> Result<Self> {
        let n = ds.n;
        let m = landmarks.min(n).max(1);
        let lm: Vec<usize> = (0..m).map(|j| j * n / m).collect();
        let all: Vec<usize> = (0..n).collect();
        let mut c = Matrix::zeros(n, m);
        kernel_block(kind, ds, &all, &lm, threads, &mut c.data);
        let mut w = Matrix::zeros(m, m);
        kernel_block(kind, ds, &lm, &lm, threads, &mut w.data);
        let mut dg = vec![0.0f32; n];
        kernel_diag(kind, ds, &mut dg);
        let f = lowrank::nystrom(threads, &dg, &c, &w, 1e-6, lm)
            .map_err(|e| anyhow!("nystrom landmark factorization failed: {e}"))?;
        Ok(LowRank::from_factor(f, threads, "nystrom"))
    }

    pub fn from_factor(f: lowrank::LowRankFactor, threads: usize, method: &'static str) -> Self {
        let n = f.g.rows;
        let mut diag = vec![0.0f32; n];
        for (i, slot) in diag.iter_mut().enumerate() {
            *slot = gemm::sum_sq(f.g.row(i));
        }
        LowRank { g: f.g, diag, residual_frac: f.residual_frac, threads, method }
    }

    pub fn rank(&self) -> usize {
        self.g.cols
    }

    /// `trace(K - G Gᵀ) / trace(K)` at factorization stop.
    pub fn residual_frac(&self) -> f64 {
        self.residual_frac
    }
}

impl KernelOperator for LowRank {
    fn n(&self) -> usize {
        self.g.rows
    }

    fn matvec(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.g.rows);
        assert_eq!(out.len(), self.g.rows);
        if self.g.cols == 0 {
            out.fill(0.0);
            return;
        }
        let mut t = vec![0.0f32; self.g.cols];
        gemv_t(self.threads, &self.g, v, &mut t);
        gemv(self.threads, &self.g, &t, out);
    }

    fn block(&self, ri: &[usize], ci: &[usize], out: &mut [f32]) {
        let (m, s, r) = (ri.len(), ci.len(), self.g.cols);
        assert_eq!(out.len(), m * s);
        if r == 0 {
            out.fill(0.0);
            return;
        }
        let mut a = Matrix::zeros(m, r);
        for (q, &i) in ri.iter().enumerate() {
            a.data[q * r..(q + 1) * r].copy_from_slice(self.g.row(i));
        }
        let mut b = Matrix::zeros(s, r);
        for (q, &j) in ci.iter().enumerate() {
            b.data[q * r..(q + 1) * r].copy_from_slice(self.g.row(j));
        }
        let mut c = Matrix::zeros(m, s);
        gemm_nt(self.threads, &a, &b, &mut c);
        out.copy_from_slice(&c.data);
    }

    fn diag(&self, out: &mut [f32]) {
        out.copy_from_slice(&self.diag);
    }

    fn memory_bytes(&self) -> usize {
        (self.g.data.len() + self.diag.len()) * 4
    }

    fn name(&self) -> &'static str {
        self.method
    }
}

// ---------------------------------------------------------------- build

/// Low-rank request carried by the implicit solvers' params.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LowRankConfig {
    /// Factor width r: ICF pivot budget or Nyström landmark count.
    pub rank: usize,
    /// Nyström landmark sampling instead of pivoted ICF.
    pub nystrom: bool,
    /// ICF stop: residual trace ≤ `tol` × initial trace.
    pub tol: f64,
}

impl LowRankConfig {
    pub fn icf(rank: usize) -> Self {
        LowRankConfig { rank, nystrom: false, tol: 1e-6 }
    }

    pub fn nystrom(rank: usize) -> Self {
        LowRankConfig { rank, nystrom: true, tol: 1e-6 }
    }
}

/// Build the operator a solver asked for: `Some(cfg)` → [`LowRank`],
/// `None` → the exact streaming operator matching the design
/// ([`ExactCsr`] for sparse, [`ExactTiled`] for dense).
pub fn build<'a>(
    kind: &KernelKind,
    ds: &'a Dataset,
    threads: usize,
    cfg: Option<LowRankConfig>,
) -> Result<Box<dyn KernelOperator + 'a>> {
    match cfg {
        Some(c) if c.nystrom => Ok(Box::new(LowRank::nystrom(kind, ds, threads, c.rank)?)),
        Some(c) => Ok(Box::new(LowRank::icf(kind, ds, threads, c.rank, c.tol))),
        None if ds.is_sparse() => Ok(Box::new(ExactCsr::new(*kind, ds, threads)?)),
        None => Ok(Box::new(ExactTiled::new(*kind, ds, threads))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn blobs(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let pos = rng.bernoulli(0.5);
            let c = if pos { 0.7 } else { 0.3 };
            for _ in 0..d {
                x.push(c + 0.1 * rng.gaussian_f32());
            }
            y.push(if pos { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("blobs", d, x, y)
    }

    #[test]
    fn dense_and_tiled_matvec_bitwise_equal() {
        let ds = blobs(97, 5, 31);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let dense = ExactDense::build(&kind, &ds, 4, usize::MAX).unwrap();
        let tiled = ExactTiled { row_tile: 16, ..ExactTiled::new(kind, &ds, 4) };
        let mut rng = Rng::new(32);
        let v: Vec<f32> = (0..ds.n).map(|_| rng.gaussian_f32()).collect();
        let mut a = vec![0.0f32; ds.n];
        let mut b = vec![0.0f32; ds.n];
        dense.matvec(&v, &mut a);
        tiled.matvec(&v, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn lowrank_block_consistent_with_matvec() {
        let ds = blobs(60, 3, 33);
        let kind = KernelKind::Rbf { gamma: 1.5 };
        let op = LowRank::icf(&kind, &ds, 2, 60, 0.0);
        // K e_j column via block must match matvec against e_j
        let all: Vec<usize> = (0..ds.n).collect();
        let j = 17;
        let mut col = vec![0.0f32; ds.n];
        op.block(&all, &[j], &mut col);
        let mut e = vec![0.0f32; ds.n];
        e[j] = 1.0;
        let mut mv = vec![0.0f32; ds.n];
        op.matvec(&e, &mut mv);
        for (a, b) in col.iter().zip(&mv) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn build_dispatches_on_design_and_config() {
        let ds = blobs(40, 3, 34);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        assert_eq!(build(&kind, &ds, 1, None).unwrap().name(), "exact-tiled");
        let lr = build(&kind, &ds, 1, Some(LowRankConfig::icf(8))).unwrap();
        assert_eq!(lr.name(), "icf");
        let ny = build(&kind, &ds, 1, Some(LowRankConfig::nystrom(8))).unwrap();
        assert_eq!(ny.name(), "nystrom");
        let sp = blobs(40, 3, 34).with_format(crate::data::Format::Csr);
        assert_eq!(build(&kind, &sp, 1, None).unwrap().name(), "exact-csr");
    }

    #[test]
    fn lowrank_memory_is_fraction_of_exact() {
        let ds = blobs(2000, 4, 35);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let op = LowRank::icf(&kind, &ds, 4, 64, 0.0);
        let exact = ds.n * ds.n * 4;
        assert!(op.memory_bytes() * 10 < exact, "{} vs {}", op.memory_bytes(), exact);
    }
}
