//! LRU kernel-row cache (LibSVM-style).
//!
//! Dual-decomposition solvers touch a skewed subset of kernel rows over
//! and over (working-set variables recur); LibSVM's cache is the reason it
//! is usable at all at medium scale. Bounded by bytes, evicts least
//! recently used whole rows.

use std::collections::HashMap;

/// Byte-bounded LRU cache of f32 kernel rows.
pub struct RowCache {
    capacity_rows: usize,
    row_len: usize,
    map: HashMap<usize, usize>, // row index -> slot
    slots: Vec<Vec<f32>>,
    slot_owner: Vec<Option<usize>>,
    // LRU via monotone ticks (simple and fast enough; slot count is small)
    ticks: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    /// `max_bytes` of row storage for rows of `row_len` f32s.
    pub fn new(max_bytes: usize, row_len: usize) -> Self {
        let capacity_rows = (max_bytes / (row_len.max(1) * 4)).max(2);
        RowCache {
            capacity_rows,
            row_len,
            map: HashMap::new(),
            slots: Vec::new(),
            slot_owner: Vec::new(),
            ticks: Vec::new(),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    /// Fetch row `i`, computing it with `fill` on a miss.
    pub fn get_or_compute<F>(&mut self, i: usize, fill: F) -> &[f32]
    where
        F: FnOnce(&mut [f32]),
    {
        self.clock += 1;
        if let Some(&slot) = self.map.get(&i) {
            self.hits += 1;
            self.ticks[slot] = self.clock;
            return &self.slots[slot];
        }
        self.misses += 1;
        let slot = if self.slots.len() < self.capacity_rows {
            self.slots.push(vec![0.0; self.row_len]);
            self.slot_owner.push(None);
            self.ticks.push(0);
            self.slots.len() - 1
        } else {
            // evict LRU
            let (slot, _) = self
                .ticks
                .iter()
                .enumerate()
                .min_by_key(|(_, &t)| t)
                .unwrap();
            if let Some(old) = self.slot_owner[slot] {
                self.map.remove(&old);
            }
            slot
        };
        fill(&mut self.slots[slot]);
        self.map.insert(i, slot);
        self.slot_owner[slot] = Some(i);
        self.ticks[slot] = self.clock;
        &self.slots[slot]
    }

    pub fn contains(&self, i: usize) -> bool {
        self.map.contains_key(&i)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_const(v: f32) -> impl FnOnce(&mut [f32]) {
        move |row| row.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn computes_on_miss_and_caches() {
        let mut c = RowCache::new(1024, 4);
        let r = c.get_or_compute(5, fill_const(5.0)).to_vec();
        assert_eq!(r, vec![5.0; 4]);
        // second access must not recompute
        let r2 = c.get_or_compute(5, |_| panic!("recomputed")).to_vec();
        assert_eq!(r2, r);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = RowCache::new(2 * 4 * 4, 4); // 2 rows
        c.get_or_compute(1, fill_const(1.0));
        c.get_or_compute(2, fill_const(2.0));
        c.get_or_compute(1, |_| panic!()); // touch 1 -> 2 is LRU
        c.get_or_compute(3, fill_const(3.0)); // evicts 2
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
        let r = c.get_or_compute(2, fill_const(2.5)).to_vec();
        assert_eq!(r, vec![2.5; 4]);
    }

    #[test]
    fn capacity_at_least_two_rows() {
        let c = RowCache::new(1, 1000);
        assert!(c.capacity_rows() >= 2);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = RowCache::new(4096, 8);
        for _ in 0..4 {
            c.get_or_compute(0, fill_const(0.0));
        }
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn many_rows_stress() {
        let mut c = RowCache::new(16 * 4 * 10, 10); // 16 rows
        for round in 0..3 {
            for i in 0..100 {
                let v = i as f32;
                let row = c.get_or_compute(i, fill_const(v)).to_vec();
                assert_eq!(row[0], v, "round {round} row {i}");
            }
        }
        assert!(c.misses >= 100);
    }
}
