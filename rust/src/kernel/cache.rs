//! LRU kernel-row caches (LibSVM-style).
//!
//! Dual-decomposition solvers touch a skewed subset of kernel rows over
//! and over (working-set variables recur); LibSVM's cache is the reason it
//! is usable at all at medium scale. Bounded by bytes, evicts least
//! recently used whole rows.
//!
//! Two variants (see `rust/DESIGN.md` §Cache):
//! * [`RowCache`] — the original single-owner cache (`&mut self`, rows
//!   borrowed out, fixed row length). Kept for callers that own their
//!   cache exclusively.
//! * [`SharedRowCache`] — sharded, `Mutex`-per-shard, `Arc`-handed rows of
//!   per-call length. Many solver instances (e.g. concurrent OvO
//!   subproblems with different training-set sizes) share one byte
//!   budget; rows are keyed by `(group, row)` so each subproblem sees its
//!   own kernel. A failed fill commits nothing — the next fetch
//!   recomputes instead of silently hitting a poisoned slot.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

/// How many megabytes of kernel rows the shared cache may hold: a fixed
/// figure, or `Auto` — sized from the machine's available RAM at train
/// time (the out-of-core recipe: give the cache most of what the mapped
/// design is *not* using, see DESIGN.md §OOC). `--cache-mb auto` on the
/// CLI parses to `Auto`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheBudget {
    Mb(usize),
    Auto,
}

/// Fraction of detected available RAM handed to the row cache under
/// `Auto`. Leaves headroom for solver state, staging buffers, and the
/// page cache holding the mapped design itself.
const AUTO_RAM_FRACTION: f64 = 0.5;

/// Fallback budget when available RAM cannot be detected (non-Linux, or
/// an unreadable `/proc/meminfo`).
const AUTO_FALLBACK_MB: usize = 1024;

impl CacheBudget {
    /// Parse a `--cache-mb` value: `"auto"` or a megabyte count.
    pub fn parse(s: &str) -> Result<CacheBudget> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(CacheBudget::Auto);
        }
        match s.parse::<usize>() {
            Ok(mb) => Ok(CacheBudget::Mb(mb)),
            Err(_) => bail!("cache-mb must be a megabyte count or 'auto', got '{s}'"),
        }
    }

    /// Resolve to a concrete megabyte figure. `Auto` takes
    /// [`AUTO_RAM_FRACTION`] of `MemAvailable` from `/proc/meminfo`
    /// (the kernel's own estimate of reclaimable memory), falling back
    /// to [`AUTO_FALLBACK_MB`] when that is unreadable.
    pub fn resolve_mb(self) -> usize {
        match self {
            CacheBudget::Mb(mb) => mb,
            CacheBudget::Auto => match available_ram_mb() {
                Some(avail) => ((avail as f64 * AUTO_RAM_FRACTION) as usize).max(1),
                None => AUTO_FALLBACK_MB,
            },
        }
    }
}

impl std::fmt::Display for CacheBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheBudget::Mb(mb) => write!(f, "{mb}"),
            CacheBudget::Auto => write!(f, "auto"),
        }
    }
}

/// `MemAvailable` from `/proc/meminfo` in megabytes, `None` off-Linux
/// or on any parse surprise.
fn available_ram_mb() -> Option<usize> {
    let text = std::fs::read_to_string("/proc/meminfo").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("MemAvailable:") {
            let kb: usize = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb / 1024);
        }
    }
    None
}

/// Byte-bounded LRU cache of f32 kernel rows.
pub struct RowCache {
    capacity_rows: usize,
    row_len: usize,
    map: HashMap<usize, usize>, // row index -> slot
    slots: Vec<Vec<f32>>,
    slot_owner: Vec<Option<usize>>,
    // LRU via monotone ticks (simple and fast enough; slot count is small)
    ticks: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    /// `max_bytes` of row storage for rows of `row_len` f32s.
    pub fn new(max_bytes: usize, row_len: usize) -> Self {
        let capacity_rows = (max_bytes / (row_len.max(1) * 4)).max(2);
        RowCache {
            capacity_rows,
            row_len,
            map: HashMap::new(),
            slots: Vec::new(),
            slot_owner: Vec::new(),
            ticks: Vec::new(),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    /// Fetch row `i`, computing it with `fill` on a miss.
    pub fn get_or_compute<F>(&mut self, i: usize, fill: F) -> &[f32]
    where
        F: FnOnce(&mut [f32]),
    {
        self.clock += 1;
        if let Some(&slot) = self.map.get(&i) {
            self.hits += 1;
            self.ticks[slot] = self.clock;
            return &self.slots[slot];
        }
        self.misses += 1;
        let slot = if self.slots.len() < self.capacity_rows {
            self.slots.push(vec![0.0; self.row_len]);
            self.slot_owner.push(None);
            self.ticks.push(0);
            self.slots.len() - 1
        } else {
            // evict LRU
            let (slot, _) = self
                .ticks
                .iter()
                .enumerate()
                .min_by_key(|(_, &t)| t)
                .unwrap();
            if let Some(old) = self.slot_owner[slot] {
                self.map.remove(&old);
            }
            slot
        };
        fill(&mut self.slots[slot]);
        self.map.insert(i, slot);
        self.slot_owner[slot] = Some(i);
        self.ticks[slot] = self.clock;
        &self.slots[slot]
    }

    pub fn contains(&self, i: usize) -> bool {
        self.map.contains_key(&i)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One cached row inside a [`Shard`].
struct Entry {
    key: (u64, usize),
    row: Arc<Vec<f32>>,
    tick: u64,
}

/// One shard of a [`SharedRowCache`]: an independently locked LRU pool
/// with its own byte budget.
struct Shard {
    map: HashMap<(u64, usize), usize>, // key -> index into entries
    entries: Vec<Entry>,
    bytes: usize,
    clock: u64,
}

impl Shard {
    fn new() -> Shard {
        Shard { map: HashMap::new(), entries: Vec::new(), bytes: 0, clock: 0 }
    }

    fn lookup(&mut self, key: (u64, usize)) -> Option<Arc<Vec<f32>>> {
        let idx = *self.map.get(&key)?;
        self.clock += 1;
        self.entries[idx].tick = self.clock;
        Some(self.entries[idx].row.clone())
    }

    /// Drop the entry at `idx`; returns the bytes it freed.
    fn remove_at(&mut self, idx: usize) -> usize {
        let e = self.entries.swap_remove(idx);
        self.map.remove(&e.key);
        let freed = e.row.len() * 4;
        self.bytes -= freed;
        if idx < self.entries.len() {
            let moved = self.entries[idx].key;
            self.map.insert(moved, idx);
        }
        freed
    }

    /// Insert `row`, evicting LRU entries to stay inside `budget`.
    /// Returns the total bytes evicted (0 on a raced duplicate key).
    fn insert(&mut self, key: (u64, usize), row: Arc<Vec<f32>>, budget: usize) -> usize {
        if self.map.contains_key(&key) {
            // another thread raced the same miss; keep its row
            return 0;
        }
        let sz = row.len() * 4;
        let mut evicted = 0usize;
        // Evict LRU rows until the new one fits. An oversized row still
        // lands after the shard empties (progress over strictness).
        while self.bytes + sz > budget && !self.entries.is_empty() {
            let (victim, _) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.tick)
                .expect("entries nonempty");
            evicted += self.remove_at(victim);
        }
        self.clock += 1;
        self.map.insert(key, self.entries.len());
        self.bytes += sz;
        self.entries.push(Entry { key, row, tick: self.clock });
        evicted
    }
}

/// Byte-bounded, sharded LRU cache of f32 kernel rows with interior
/// mutability: `&self` everywhere, one `Mutex` per shard, rows handed out
/// as `Arc` clones so eviction never invalidates a row in use. Rows are
/// keyed by `(group, row-index)` and may have different lengths per group;
/// concurrent solver instances use distinct groups and share the single
/// byte budget.
pub struct SharedRowCache {
    shards: Vec<Mutex<Shard>>,
    bytes_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evicted_bytes: AtomicU64,
}

impl SharedRowCache {
    /// `max_bytes` of row storage split over `shards` independently locked
    /// LRU pools.
    pub fn new(max_bytes: usize, shards: usize) -> SharedRowCache {
        let shards = shards.max(1);
        SharedRowCache {
            bytes_per_shard: (max_bytes / shards).max(64),
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evicted_bytes: AtomicU64::new(0),
        }
    }

    /// Total byte budget across shards.
    pub fn budget_bytes(&self) -> usize {
        self.bytes_per_shard * self.shards.len()
    }

    /// Bytes currently held.
    pub fn used_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    #[inline]
    fn shard_of(&self, key: (u64, usize)) -> &Mutex<Shard> {
        let h = key
            .0
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(key.1 as u64)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9);
        &self.shards[(h >> 32) as usize % self.shards.len()]
    }

    /// Fetch row `(group, i)` of `row_len` f32s, computing it with `fill`
    /// on a miss. The fill runs **outside** the shard lock (concurrent
    /// misses on different rows compute in parallel; a duplicate miss on
    /// the same row wastes one computation, never correctness). If `fill`
    /// errors, nothing is committed: the next fetch recomputes.
    pub fn get_or_try_compute<F>(
        &self,
        group: u64,
        i: usize,
        row_len: usize,
        fill: F,
    ) -> Result<Arc<Vec<f32>>>
    where
        F: FnOnce(&mut [f32]) -> Result<()>,
    {
        let key = (group, i);
        let shard = self.shard_of(key);
        if let Some(row) = shard.lock().unwrap().lookup(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            crate::trace::count(crate::trace::Counter::CacheLookups, 1);
            crate::trace::count(crate::trace::Counter::CacheHits, 1);
            return Ok(row);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        crate::trace::count(crate::trace::Counter::CacheLookups, 1);
        crate::trace::count(crate::trace::Counter::CacheMisses, 1);
        let mut buf = vec![0.0f32; row_len];
        fill(&mut buf)?;
        let row = Arc::new(buf);
        let evicted = shard
            .lock()
            .unwrap()
            .insert(key, row.clone(), self.bytes_per_shard);
        if evicted > 0 {
            self.evicted_bytes.fetch_add(evicted as u64, Ordering::Relaxed);
            crate::trace::count(crate::trace::Counter::CacheEvictedBytes, evicted as u64);
        }
        Ok(row)
    }

    /// Whether `(group, i)` is currently cached.
    pub fn contains(&self, group: u64, i: usize) -> bool {
        let key = (group, i);
        self.shard_of(key).lock().unwrap().map.contains_key(&key)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Total bytes evicted to stay inside the budget — the capacity-
    /// pressure signal (0 means the working set fit).
    pub fn evicted_bytes(&self) -> u64 {
        self.evicted_bytes.load(Ordering::Relaxed)
    }

    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits() as f64, self.misses() as f64);
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_budget_parses_fixed_and_auto() {
        assert_eq!(CacheBudget::parse("256").unwrap(), CacheBudget::Mb(256));
        assert_eq!(CacheBudget::parse("auto").unwrap(), CacheBudget::Auto);
        assert_eq!(CacheBudget::parse("AUTO").unwrap(), CacheBudget::Auto);
        assert!(CacheBudget::parse("lots").is_err());
        assert!(CacheBudget::parse("-3").is_err());
    }

    #[test]
    fn cache_budget_resolves_to_positive_mb() {
        assert_eq!(CacheBudget::Mb(64).resolve_mb(), 64);
        // Auto must yield something usable whether or not /proc/meminfo
        // exists on the test machine.
        assert!(CacheBudget::Auto.resolve_mb() >= 1);
    }

    #[test]
    fn cache_budget_displays_cli_form() {
        assert_eq!(CacheBudget::Mb(128).to_string(), "128");
        assert_eq!(CacheBudget::Auto.to_string(), "auto");
    }

    fn fill_const(v: f32) -> impl FnOnce(&mut [f32]) {
        move |row| row.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn computes_on_miss_and_caches() {
        let mut c = RowCache::new(1024, 4);
        let r = c.get_or_compute(5, fill_const(5.0)).to_vec();
        assert_eq!(r, vec![5.0; 4]);
        // second access must not recompute
        let r2 = c.get_or_compute(5, |_| panic!("recomputed")).to_vec();
        assert_eq!(r2, r);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = RowCache::new(2 * 4 * 4, 4); // 2 rows
        c.get_or_compute(1, fill_const(1.0));
        c.get_or_compute(2, fill_const(2.0));
        c.get_or_compute(1, |_| panic!()); // touch 1 -> 2 is LRU
        c.get_or_compute(3, fill_const(3.0)); // evicts 2
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
        let r = c.get_or_compute(2, fill_const(2.5)).to_vec();
        assert_eq!(r, vec![2.5; 4]);
    }

    #[test]
    fn capacity_at_least_two_rows() {
        let c = RowCache::new(1, 1000);
        assert!(c.capacity_rows() >= 2);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = RowCache::new(4096, 8);
        for _ in 0..4 {
            c.get_or_compute(0, fill_const(0.0));
        }
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn many_rows_stress() {
        let mut c = RowCache::new(16 * 4 * 10, 10); // 16 rows
        for round in 0..3 {
            for i in 0..100 {
                let v = i as f32;
                let row = c.get_or_compute(i, fill_const(v)).to_vec();
                assert_eq!(row[0], v, "round {round} row {i}");
            }
        }
        assert!(c.misses >= 100);
    }

    fn ok_fill(v: f32) -> impl FnOnce(&mut [f32]) -> Result<()> {
        move |row| {
            row.iter_mut().for_each(|x| *x = v);
            Ok(())
        }
    }

    #[test]
    fn shared_computes_on_miss_and_caches() {
        let c = SharedRowCache::new(1 << 16, 4);
        let r = c.get_or_try_compute(0, 5, 4, ok_fill(5.0)).unwrap();
        assert_eq!(r.to_vec(), vec![5.0; 4]);
        let r2 = c
            .get_or_try_compute(0, 5, 4, |_| panic!("recomputed"))
            .unwrap();
        assert_eq!(r2.to_vec(), r.to_vec());
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn shared_failed_fill_commits_nothing() {
        // Regression: a fill error must not leave a zero-filled (or
        // half-filled) slot behind as a future silent hit.
        let c = SharedRowCache::new(1 << 16, 2);
        let err = c
            .get_or_try_compute(3, 7, 8, |row| {
                row[0] = 123.0; // partial garbage written before the error
                Err(anyhow::anyhow!("simulated engine failure"))
            })
            .unwrap_err();
        assert!(err.to_string().contains("simulated"));
        assert!(!c.contains(3, 7), "failed fill left a cache entry");
        // the next fetch recomputes and sees clean data
        let r = c.get_or_try_compute(3, 7, 8, ok_fill(2.5)).unwrap();
        assert_eq!(r.to_vec(), vec![2.5; 8]);
        assert_eq!(c.misses(), 2, "second fetch must be a recompute");
        assert_eq!(c.hits(), 0);
    }

    #[test]
    fn shared_groups_are_distinct_and_budget_is_shared() {
        let c = SharedRowCache::new(8 * 4 * 4, 2); // 8 rows of 4 floats
        let a = c.get_or_try_compute(1, 0, 4, ok_fill(1.0)).unwrap();
        let b = c.get_or_try_compute(2, 0, 4, ok_fill(2.0)).unwrap();
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 2.0, "groups must not alias the same row index");
        // overflow the shared budget from a third group; bytes stay bounded
        for i in 0..100 {
            let _ = c.get_or_try_compute(9, i, 4, ok_fill(i as f32)).unwrap();
        }
        assert!(
            c.used_bytes() <= c.budget_bytes(),
            "used {} > budget {}",
            c.used_bytes(),
            c.budget_bytes()
        );
    }

    #[test]
    fn shared_variable_row_lengths_coexist() {
        let c = SharedRowCache::new(1 << 16, 2);
        let short = c.get_or_try_compute(0, 1, 3, ok_fill(1.0)).unwrap();
        let long = c.get_or_try_compute(1, 1, 9, ok_fill(2.0)).unwrap();
        assert_eq!(short.len(), 3);
        assert_eq!(long.len(), 9);
    }

    #[test]
    fn shared_rows_survive_eviction_while_held() {
        let c = SharedRowCache::new(2 * 4 * 4, 1); // 2 rows of 4 floats
        let held = c.get_or_try_compute(0, 0, 4, ok_fill(7.0)).unwrap();
        for i in 1..10 {
            let _ = c.get_or_try_compute(0, i, 4, ok_fill(i as f32)).unwrap();
        }
        assert_eq!(held.to_vec(), vec![7.0; 4], "Arc row mutated by eviction");
        assert!(c.used_bytes() <= c.budget_bytes().max(64));
        // 10 rows of 16 bytes pushed through a 2-row budget: at least 8
        // rows' worth of evictions must have been tallied
        assert!(c.evicted_bytes() >= 8 * 16, "evicted {} bytes", c.evicted_bytes());
    }

    #[test]
    fn shared_concurrent_stress_never_returns_wrong_row() {
        let c = SharedRowCache::new(32 * 4 * 8, 4);
        crate::pool::parallel_for(8, 2000, 1, |k| {
            let group = (k % 3) as u64;
            let i = (k * 17) % 50;
            let want = group as f32 * 1000.0 + i as f32;
            let row = c.get_or_try_compute(group, i, 8, ok_fill(want)).unwrap();
            assert!(
                row.iter().all(|&v| v == want),
                "stale row for ({group},{i})"
            );
        });
        assert_eq!(c.hits() + c.misses(), 2000);
    }
}
