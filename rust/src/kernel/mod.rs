//! Kernel functions and CPU kernel-matrix computation.
//!
//! The explicit engines compute kernel rows/blocks here (the paper's
//! LibSVM / LibSVM+OpenMP path); the implicit engine computes the same
//! blocks inside XLA artifacts. Per-pair evaluation runs on the
//! lane-unrolled primitives of `linalg::gemm` (the row fills SMO/WSS
//! issue every iteration), and whole blocks route through the packed
//! GEMM itself: gather, one `A·Bᵀ` cross-product call, then a fused
//! per-kind transform — the same formulation `Engine::rbf_block` uses.
//!
//! Storage dispatch happens here: datasets with a CSR design route the
//! same row/block shapes through the SpMM substrate (`linalg::spmm`,
//! DESIGN.md §SPARSE) — the row side stays sparse, only the small
//! column-index side (working set / basis / candidates) densifies — so
//! every solver inherits the sparse fast path with no API change.

pub mod cache;
pub mod operator;

use crate::data::{CsrMatrix, Dataset, Design, MmapCsr};
use crate::linalg::{gemm, spmm};
use crate::pool;
use crate::pool::SendPtr;

/// Either sparse storage (in-memory CSR or mapped CSR from a packed
/// file) behind one row interface, so the sparse kernel paths are
/// written once. Both variants dispatch to the same SIMD primitives on
/// the same bytes, which is what keeps mmap-backed training
/// bit-identical to in-memory CSR (DESIGN.md §OOC).
enum SparseSrc<'a> {
    Mem(&'a CsrMatrix),
    Map(&'a MmapCsr),
}

impl SparseSrc<'_> {
    fn densify_row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            SparseSrc::Mem(c) => c.densify_row_into(i, out),
            SparseSrc::Map(c) => c.densify_row_into(i, out),
        }
    }

    fn sum_sq(&self, i: usize) -> f32 {
        match self {
            SparseSrc::Mem(c) => c.sum_sq[i],
            SparseSrc::Map(c) => c.sum_sq()[i],
        }
    }

    fn row_dot_dense(&self, i: usize, x: &[f32]) -> f32 {
        match self {
            SparseSrc::Mem(c) => c.row_dot_dense(i, x),
            SparseSrc::Map(c) => c.row_dot_dense(i, x),
        }
    }
}

fn sparse_src(ds: &Dataset) -> Option<SparseSrc<'_>> {
    match &ds.design {
        Design::Sparse(c) => Some(SparseSrc::Mem(c)),
        Design::MmapCsr(c) => Some(SparseSrc::Map(c)),
        Design::Dense(_) | Design::MmapDense(_) => None,
    }
}

/// Kernel function family. The paper evaluates RBF throughout; linear and
/// polynomial are provided for completeness of the public API.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    Rbf { gamma: f32 },
    Linear,
    Poly { degree: i32, gamma: f32, coef0: f32 },
}

impl KernelKind {
    /// k(x, z). Lane-unrolled f32 reductions (`linalg::gemm`) — the
    /// vectorizable form of the seed's f64-converted scalar loops; the
    /// RBF distance still cancels to exactly 0 on identical inputs.
    #[inline]
    pub fn eval(&self, x: &[f32], z: &[f32]) -> f32 {
        match *self {
            KernelKind::Rbf { gamma } => (-gamma * gemm::dist2_lanes(x, z)).exp(),
            KernelKind::Linear => gemm::dot_lanes(x, z),
            KernelKind::Poly { degree, gamma, coef0 } => {
                (gamma * gemm::dot_lanes(x, z) + coef0).powi(degree)
            }
        }
    }

    /// k(x, x) without computing a distance (1 for RBF).
    #[inline]
    pub fn self_eval(&self, x: &[f32]) -> f32 {
        match *self {
            KernelKind::Rbf { .. } => 1.0,
            _ => self.eval(x, x),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Rbf { .. } => "rbf",
            KernelKind::Linear => "linear",
            KernelKind::Poly { .. } => "poly",
        }
    }
}

/// Compute one kernel row k(x_i, .) against every row of `ds` into `out`.
/// `threads = 1` is the LibSVM single-core path; more threads is the
/// LibSVM+OpenMP path (the paper's most basic speedup). Sparse designs
/// evaluate each pair in O(nnz_j) via the chunk-ordered CSR dot — the
/// diagonal entry still cancels to an exact RBF 1.0 — and are
/// deterministic for every thread count like the dense path.
pub fn kernel_row(kind: &KernelKind, ds: &Dataset, i: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(out.len(), ds.n);
    if let Some(src) = sparse_src(ds) {
        let mut xi = vec![0.0f32; ds.d];
        src.densify_row_into(i, &mut xi);
        let xi_sq = src.sum_sq(i);
        let src = &src;
        pool::parallel_chunks_mut(threads, out, 256, |c, slice| {
            for (off, slot) in slice.iter_mut().enumerate() {
                let j = c * 256 + off;
                let dot = src.row_dot_dense(j, &xi);
                *slot = match *kind {
                    KernelKind::Rbf { gamma } => {
                        let d2 = (xi_sq + src.sum_sq(j) - 2.0 * dot).max(0.0);
                        (-gamma * d2).exp()
                    }
                    KernelKind::Linear => dot,
                    KernelKind::Poly { degree, gamma, coef0 } => {
                        (gamma * dot + coef0).powi(degree)
                    }
                };
            }
        });
        return;
    }
    let xi: Vec<f32> = ds.row(i).to_vec();
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    pool::parallel_for(threads, ds.n, 256, |j| {
        // SAFETY: each j written once.
        unsafe { *out_ptr.get().add(j) = kind.eval(&xi, ds.row(j)) };
    });
}

/// Dense kernel block K[rows x cols] for row indices `ri` against column
/// indices `ci` (row-major into `out`). Dense designs route through the
/// packed GEMM: gather the index sets into contiguous staging blocks
/// (skipped when an index set is the identity prefix — the `full_kernel`
/// case), compute the cross-product block with one blocked `A·Bᵀ`, then
/// apply the kernel's scalar transform in a fused parallel row pass.
/// Sparse designs keep the row side in CSR and route through the
/// row-blocked SpMM (`linalg::spmm`); only the `ci` side (working set /
/// basis — small by construction) densifies. Either way RBF norms use
/// the substrate's own accumulation order, so diagonal entries of a
/// symmetric block come out as exactly 1.0, and output is bit-identical
/// for every thread count.
pub fn kernel_block(
    kind: &KernelKind,
    ds: &Dataset,
    ri: &[usize],
    ci: &[usize],
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), ri.len() * ci.len());
    let (m, n, d) = (ri.len(), ci.len(), ds.d);
    if m == 0 || n == 0 {
        return;
    }
    let is_prefix = |idx: &[usize]| idx.iter().enumerate().all(|(q, &i)| q == i);
    if let Some(src) = sparse_src(ds) {
        let sub_store;
        let acsr: &CsrMatrix = match &ds.design {
            Design::Sparse(csr) if is_prefix(ri) => csr,
            Design::Sparse(csr) => {
                sub_store = csr.select(ri);
                &sub_store
            }
            // The SpMM row side needs an in-memory CSR, so a mapped
            // design materializes just the `ri` rows — bounded by the
            // caller's tile height (operators stream ~32 MB tiles), not
            // by n. Row data and stored norms copy bit-for-bit, so the
            // block equals the in-memory result exactly.
            Design::MmapCsr(mc) => {
                sub_store = mc.select_csr(ri);
                &sub_store
            }
            Design::Dense(_) | Design::MmapDense(_) => unreachable!(),
        };
        // Densify the ci side in column blocks: with ci = all rows of a
        // wide sparse dataset (the `full_kernel` case, rcv1-class d), a
        // one-shot gather would materialize the whole n x d dense matrix
        // (plus the SpMM's d x n transpose) that CSR storage exists to
        // avoid. Staging is capped at ~32 MB per buffer; column blocks
        // change no per-element accumulation, so values stay
        // bit-identical to the unblocked call.
        let bw = n.min(((32 << 20) / (4 * d.max(1))).max(16));
        kernel_block_csr(kind, acsr, m, &src, ci, threads, bw, out);
        return;
    }
    let gather = |idx: &[usize]| -> Vec<f32> {
        let mut g = vec![0.0f32; idx.len() * d];
        for (q, &i) in idx.iter().enumerate() {
            g[q * d..(q + 1) * d].copy_from_slice(ds.row(i));
        }
        g
    };
    let a_store;
    let am: &[f32] = if is_prefix(ri) {
        &ds.dense_x()[..m * d]
    } else {
        a_store = gather(ri);
        &a_store
    };
    let b_store;
    let bm: &[f32] = if is_prefix(ci) {
        &ds.dense_x()[..n * d]
    } else {
        b_store = gather(ci);
        &b_store
    };
    match *kind {
        KernelKind::Rbf { gamma } => gemm::rbf_blocked(threads, am, m, bm, n, d, gamma, out),
        KernelKind::Linear => {
            gemm::gemm_nt_strided(threads, m, n, d, am, d, 1, bm, d, 1, None, out, n);
        }
        KernelKind::Poly { degree, gamma, coef0 } => {
            gemm::gemm_nt_strided(threads, m, n, d, am, d, 1, bm, d, 1, None, out, n);
            pool::parallel_chunks_mut(threads, out, n, |_r, row| {
                for slot in row.iter_mut() {
                    *slot = (gamma * *slot + coef0).powi(degree);
                }
            });
        }
    }
}

/// The sparse arm of [`kernel_block`]: rows `[0, m)` of `acsr` against
/// the `ci` rows of `src`, densified `bw` columns at a time (see the
/// call site for why). Split out so tests can force small `bw` values.
#[allow(clippy::too_many_arguments)]
fn kernel_block_csr(
    kind: &KernelKind,
    acsr: &CsrMatrix,
    m: usize,
    src: &SparseSrc,
    ci: &[usize],
    threads: usize,
    bw: usize,
    out: &mut [f32],
) {
    let (n, d) = (ci.len(), acsr.cols);
    let bw = bw.clamp(1, n.max(1));
    let mut bm = vec![0.0f32; bw * d];
    let mut tmp = vec![0.0f32; m * bw];
    let mut c0 = 0usize;
    while c0 < n {
        let cw = bw.min(n - c0);
        for (q, &j) in ci[c0..c0 + cw].iter().enumerate() {
            src.densify_row_into(j, &mut bm[q * d..(q + 1) * d]);
        }
        let bm_blk = &bm[..cw * d];
        let tmp_blk = &mut tmp[..m * cw];
        match *kind {
            KernelKind::Rbf { gamma } => {
                spmm::rbf_csr_blocked(threads, acsr, 0, m, bm_blk, cw, gamma, tmp_blk);
            }
            KernelKind::Linear => spmm::csr_gemm_nt(threads, acsr, 0, m, bm_blk, cw, tmp_blk),
            KernelKind::Poly { degree, gamma, coef0 } => {
                spmm::csr_gemm_nt(threads, acsr, 0, m, bm_blk, cw, tmp_blk);
                pool::parallel_chunks_mut(threads, tmp_blk, cw, |_r, row| {
                    for slot in row.iter_mut() {
                        *slot = (gamma * *slot + coef0).powi(degree);
                    }
                });
            }
        }
        for r in 0..m {
            out[r * n + c0..r * n + c0 + cw].copy_from_slice(&tmp_blk[r * cw..(r + 1) * cw]);
        }
        c0 += cw;
    }
}

/// Full n x n kernel matrix (full-kernel baselines only; refuses above a
/// byte cap — the paper's point about MU/primal memory infeasibility).
pub fn full_kernel(
    kind: &KernelKind,
    ds: &Dataset,
    threads: usize,
    max_bytes: usize,
) -> Result<crate::linalg::Matrix, String> {
    let need = ds.n * ds.n * 4;
    if need > max_bytes {
        return Err(format!(
            "full kernel needs {:.1} GB > cap {:.1} GB (n = {}); \
             this is the memory wall the paper describes for the exact \
             implicit methods",
            need as f64 / 1e9,
            max_bytes as f64 / 1e9,
            ds.n
        ));
    }
    let mut k = crate::linalg::Matrix::zeros(ds.n, ds.n);
    let idx: Vec<usize> = (0..ds.n).collect();
    kernel_block(kind, ds, &idx, &idx, threads, &mut k.data);
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        Dataset::new_binary("t", d, x, y)
    }

    #[test]
    fn rbf_self_is_one() {
        let k = KernelKind::Rbf { gamma: 0.7 };
        let x = [0.3f32, 0.9, 0.1];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-6);
        assert_eq!(k.self_eval(&x), 1.0);
    }

    #[test]
    fn rbf_decreases_with_distance() {
        let k = KernelKind::Rbf { gamma: 1.0 };
        let a = [0.0f32, 0.0];
        assert!(k.eval(&a, &[0.1, 0.0]) > k.eval(&a, &[0.5, 0.0]));
    }

    #[test]
    fn linear_matches_dot() {
        let k = KernelKind::Linear;
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-6);
    }

    #[test]
    fn poly_matches_formula() {
        let k = KernelKind::Poly { degree: 2, gamma: 1.0, coef0: 1.0 };
        // (1*2 + 1)^2 = 9
        assert!((k.eval(&[1.0], &[2.0]) - 9.0).abs() < 1e-6);
    }

    #[test]
    fn kernel_row_matches_eval() {
        let ds = dataset(50, 7, 1);
        let kind = KernelKind::Rbf { gamma: 0.5 };
        let mut row = vec![0.0; 50];
        kernel_row(&kind, &ds, 3, 4, &mut row);
        for j in 0..50 {
            assert!((row[j] - kind.eval(ds.row(3), ds.row(j))).abs() < 1e-6);
        }
    }

    #[test]
    fn kernel_block_matches_eval() {
        // 1e-4 (not the seed's 1e-6): the block path computes the cross
        // products with the f32 blocked GEMM, while eval accumulates the
        // distance directly — equal formulations, different rounding.
        let ds = dataset(30, 5, 2);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let ri = [0, 5, 7];
        let ci = [1, 2, 3, 4];
        let mut out = vec![0.0; 12];
        kernel_block(&kind, &ds, &ri, &ci, 2, &mut out);
        for (r, &i) in ri.iter().enumerate() {
            for (c, &j) in ci.iter().enumerate() {
                assert!((out[r * 4 + c] - kind.eval(ds.row(i), ds.row(j))).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn kernel_block_all_kinds_match_eval() {
        let ds = dataset(40, 9, 7);
        let ri: Vec<usize> = (0..40).collect(); // identity prefix fast path
        let ci = [3usize, 0, 39, 17, 17];
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Linear,
            KernelKind::Poly { degree: 3, gamma: 0.5, coef0: 1.0 },
        ] {
            let mut out = vec![0.0; ri.len() * ci.len()];
            kernel_block(&kind, &ds, &ri, &ci, 4, &mut out);
            for (r, &i) in ri.iter().enumerate() {
                for (c, &j) in ci.iter().enumerate() {
                    let e = kind.eval(ds.row(i), ds.row(j));
                    let got = out[r * ci.len() + c];
                    assert!(
                        (got - e).abs() < 1e-4 * e.abs().max(1.0),
                        "{} ({i},{j}): {got} vs {e}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_block_thread_count_deterministic() {
        let ds = dataset(70, 11, 8);
        let kind = KernelKind::Rbf { gamma: 1.3 };
        let ri: Vec<usize> = (0..70).collect();
        let ci: Vec<usize> = (0..70).collect();
        let mut k1 = vec![0.0; 70 * 70];
        kernel_block(&kind, &ds, &ri, &ci, 1, &mut k1);
        for threads in [2usize, 8] {
            let mut kt = vec![0.0; 70 * 70];
            kernel_block(&kind, &ds, &ri, &ci, threads, &mut kt);
            assert_eq!(k1, kt, "threads {threads}");
        }
        // symmetric block: exact diagonal and bit-exact symmetry
        for i in 0..70 {
            assert_eq!(k1[i * 70 + i], 1.0, "diag {i}");
            for j in 0..70 {
                assert_eq!(k1[i * 70 + j].to_bits(), k1[j * 70 + i].to_bits());
            }
        }
    }

    fn sparse_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d)
            .map(|_| if rng.bernoulli(0.1) { rng.uniform_f32() } else { 0.0 })
            .collect();
        let y: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        Dataset::new_binary("t", d, x, y)
    }

    #[test]
    fn sparse_kernel_block_bit_identical_to_dense() {
        // the SpMM path's KC-chunked accumulation matches the packed
        // GEMM's per-element order, and zeros are identity adds — so CSR
        // storage changes no bit of any kernel block (DESIGN.md §SPARSE)
        let dense = sparse_dataset(60, 300, 11); // spans a KC boundary
        let sparse = dense.clone().with_format(crate::data::Format::Csr);
        let ri: Vec<usize> = (0..60).collect(); // identity prefix
        let ci = [3usize, 0, 59, 17, 17, 8];
        let gathered = [5usize, 1, 44]; // non-prefix row gather
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Linear,
            KernelKind::Poly { degree: 3, gamma: 0.5, coef0: 1.0 },
        ] {
            let mut kd = vec![0.0; ri.len() * ci.len()];
            let mut ks = vec![0.0; ri.len() * ci.len()];
            kernel_block(&kind, &dense, &ri, &ci, 4, &mut kd);
            kernel_block(&kind, &sparse, &ri, &ci, 4, &mut ks);
            for (a, b) in ks.iter().zip(&kd) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", kind.name());
            }
            let mut gd = vec![0.0; gathered.len() * ci.len()];
            let mut gs = vec![0.0; gathered.len() * ci.len()];
            kernel_block(&kind, &dense, &gathered, &ci, 2, &mut gd);
            kernel_block(&kind, &sparse, &gathered, &ci, 2, &mut gs);
            assert_eq!(gd, gs, "{} gather", kind.name());
        }
    }

    #[test]
    fn sparse_kernel_block_thread_count_deterministic() {
        let ds = sparse_dataset(70, 40, 12).with_format(crate::data::Format::Csr);
        let kind = KernelKind::Rbf { gamma: 1.3 };
        let idx: Vec<usize> = (0..70).collect();
        let mut k1 = vec![0.0; 70 * 70];
        kernel_block(&kind, &ds, &idx, &idx, 1, &mut k1);
        for threads in [2usize, 8] {
            let mut kt = vec![0.0; 70 * 70];
            kernel_block(&kind, &ds, &idx, &idx, threads, &mut kt);
            assert_eq!(k1, kt, "threads {threads}");
        }
        for i in 0..70 {
            assert_eq!(k1[i * 70 + i], 1.0, "diag {i}");
        }
    }

    #[test]
    fn sparse_column_blocking_changes_no_bit() {
        // small forced block widths must reproduce the one-shot call
        // exactly — the memory-bounded full_kernel path depends on it
        let ds = sparse_dataset(40, 90, 14).with_format(crate::data::Format::Csr);
        let csr = ds.csr().unwrap();
        let ci = [7usize, 0, 33, 12, 25, 25, 39, 2, 18];
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Linear,
            KernelKind::Poly { degree: 2, gamma: 0.4, coef0: 0.5 },
        ] {
            let mut whole = vec![0.0; 40 * ci.len()];
            kernel_block_csr(&kind, csr, 40, &SparseSrc::Mem(csr), &ci, 4, ci.len(), &mut whole);
            for bw in [1usize, 2, 4] {
                let mut blocked = vec![0.0; 40 * ci.len()];
                kernel_block_csr(&kind, csr, 40, &SparseSrc::Mem(csr), &ci, 4, bw, &mut blocked);
                assert_eq!(whole, blocked, "{} bw={bw}", kind.name());
            }
        }
    }

    #[test]
    fn sparse_kernel_row_close_to_eval_with_exact_diag() {
        let dense = sparse_dataset(80, 33, 13);
        let sparse = dense.clone().with_format(crate::data::Format::Csr);
        for kind in [KernelKind::Rbf { gamma: 0.9 }, KernelKind::Linear] {
            let mut rs = vec![0.0; 80];
            kernel_row(&kind, &sparse, 17, 4, &mut rs);
            for j in 0..80 {
                let e = kind.eval(dense.row(17), dense.row(j));
                assert!((rs[j] - e).abs() < 1e-5, "{} j={j}: {} vs {e}", kind.name(), rs[j]);
            }
            // thread-count invariance
            let mut r1 = vec![0.0; 80];
            kernel_row(&kind, &sparse, 17, 1, &mut r1);
            assert_eq!(rs, r1);
        }
        let mut row = vec![0.0; 80];
        kernel_row(&KernelKind::Rbf { gamma: 0.9 }, &sparse, 17, 2, &mut row);
        assert_eq!(row[17], 1.0, "sparse RBF self-similarity must be exactly 1");
    }

    #[test]
    fn full_kernel_symmetric_psd_diag() {
        let ds = dataset(40, 4, 3);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let k = full_kernel(&kind, &ds, 2, usize::MAX).unwrap();
        for i in 0..40 {
            assert!((k.at(i, i) - 1.0).abs() < 1e-6);
            for j in 0..40 {
                assert!((k.at(i, j) - k.at(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn full_kernel_memory_cap_enforced() {
        let ds = dataset(100, 2, 4);
        let err = full_kernel(&KernelKind::Linear, &ds, 1, 1000).unwrap_err();
        assert!(err.contains("memory wall"));
    }

    #[test]
    fn threaded_row_matches_sequential() {
        let ds = dataset(300, 6, 5);
        let kind = KernelKind::Rbf { gamma: 0.3 };
        let mut r1 = vec![0.0; 300];
        let mut r8 = vec![0.0; 300];
        kernel_row(&kind, &ds, 17, 1, &mut r1);
        kernel_row(&kind, &ds, 17, 8, &mut r8);
        assert_eq!(r1, r8);
    }
}
