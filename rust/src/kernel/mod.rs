//! Kernel functions and CPU kernel-matrix computation.
//!
//! The explicit engines compute kernel rows/blocks here (scalar loops,
//! optionally hand-threaded — the paper's LibSVM / LibSVM+OpenMP path);
//! the implicit engine computes the same blocks inside XLA artifacts.

pub mod cache;

use crate::data::Dataset;
use crate::linalg::{dist2, dot};
use crate::pool;
use crate::pool::SendPtr;

/// Kernel function family. The paper evaluates RBF throughout; linear and
/// polynomial are provided for completeness of the public API.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    Rbf { gamma: f32 },
    Linear,
    Poly { degree: i32, gamma: f32, coef0: f32 },
}

impl KernelKind {
    /// k(x, z).
    #[inline]
    pub fn eval(&self, x: &[f32], z: &[f32]) -> f32 {
        match *self {
            KernelKind::Rbf { gamma } => (-gamma * dist2(x, z)).exp(),
            KernelKind::Linear => dot(x, z),
            KernelKind::Poly { degree, gamma, coef0 } => {
                (gamma * dot(x, z) + coef0).powi(degree)
            }
        }
    }

    /// k(x, x) without computing a distance (1 for RBF).
    #[inline]
    pub fn self_eval(&self, x: &[f32]) -> f32 {
        match *self {
            KernelKind::Rbf { .. } => 1.0,
            _ => self.eval(x, x),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Rbf { .. } => "rbf",
            KernelKind::Linear => "linear",
            KernelKind::Poly { .. } => "poly",
        }
    }
}

/// Compute one kernel row k(x_i, .) against every row of `ds` into `out`.
/// `threads = 1` is the LibSVM single-core path; more threads is the
/// LibSVM+OpenMP path (the paper's most basic speedup).
pub fn kernel_row(kind: &KernelKind, ds: &Dataset, i: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(out.len(), ds.n);
    let xi: Vec<f32> = ds.row(i).to_vec();
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    pool::parallel_for(threads, ds.n, 256, |j| {
        // SAFETY: each j written once.
        unsafe { *out_ptr.get().add(j) = kind.eval(&xi, ds.row(j)) };
    });
}

/// Dense kernel block K[rows x cols] for row indices `ri` against column
/// indices `ci` (row-major into `out`).
pub fn kernel_block(
    kind: &KernelKind,
    ds: &Dataset,
    ri: &[usize],
    ci: &[usize],
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), ri.len() * ci.len());
    let w = ci.len();
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    pool::parallel_for(threads, ri.len(), 4, |r| {
        let xi = ds.row(ri[r]);
        // SAFETY: row r written by exactly one task.
        let row = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r * w), w) };
        for (slot, &c) in row.iter_mut().zip(ci) {
            *slot = kind.eval(xi, ds.row(c));
        }
    });
}

/// Full n x n kernel matrix (full-kernel baselines only; refuses above a
/// byte cap — the paper's point about MU/primal memory infeasibility).
pub fn full_kernel(
    kind: &KernelKind,
    ds: &Dataset,
    threads: usize,
    max_bytes: usize,
) -> Result<crate::linalg::Matrix, String> {
    let need = ds.n * ds.n * 4;
    if need > max_bytes {
        return Err(format!(
            "full kernel needs {:.1} GB > cap {:.1} GB (n = {}); \
             this is the memory wall the paper describes for the exact \
             implicit methods",
            need as f64 / 1e9,
            max_bytes as f64 / 1e9,
            ds.n
        ));
    }
    let mut k = crate::linalg::Matrix::zeros(ds.n, ds.n);
    let idx: Vec<usize> = (0..ds.n).collect();
    kernel_block(kind, ds, &idx, &idx, threads, &mut k.data);
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        Dataset::new_binary("t", d, x, y)
    }

    #[test]
    fn rbf_self_is_one() {
        let k = KernelKind::Rbf { gamma: 0.7 };
        let x = [0.3f32, 0.9, 0.1];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-6);
        assert_eq!(k.self_eval(&x), 1.0);
    }

    #[test]
    fn rbf_decreases_with_distance() {
        let k = KernelKind::Rbf { gamma: 1.0 };
        let a = [0.0f32, 0.0];
        assert!(k.eval(&a, &[0.1, 0.0]) > k.eval(&a, &[0.5, 0.0]));
    }

    #[test]
    fn linear_matches_dot() {
        let k = KernelKind::Linear;
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-6);
    }

    #[test]
    fn poly_matches_formula() {
        let k = KernelKind::Poly { degree: 2, gamma: 1.0, coef0: 1.0 };
        // (1*2 + 1)^2 = 9
        assert!((k.eval(&[1.0], &[2.0]) - 9.0).abs() < 1e-6);
    }

    #[test]
    fn kernel_row_matches_eval() {
        let ds = dataset(50, 7, 1);
        let kind = KernelKind::Rbf { gamma: 0.5 };
        let mut row = vec![0.0; 50];
        kernel_row(&kind, &ds, 3, 4, &mut row);
        for j in 0..50 {
            assert!((row[j] - kind.eval(ds.row(3), ds.row(j))).abs() < 1e-6);
        }
    }

    #[test]
    fn kernel_block_matches_eval() {
        let ds = dataset(30, 5, 2);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let ri = [0, 5, 7];
        let ci = [1, 2, 3, 4];
        let mut out = vec![0.0; 12];
        kernel_block(&kind, &ds, &ri, &ci, 2, &mut out);
        for (r, &i) in ri.iter().enumerate() {
            for (c, &j) in ci.iter().enumerate() {
                assert!((out[r * 4 + c] - kind.eval(ds.row(i), ds.row(j))).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn full_kernel_symmetric_psd_diag() {
        let ds = dataset(40, 4, 3);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let k = full_kernel(&kind, &ds, 2, usize::MAX).unwrap();
        for i in 0..40 {
            assert!((k.at(i, i) - 1.0).abs() < 1e-6);
            for j in 0..40 {
                assert!((k.at(i, j) - k.at(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn full_kernel_memory_cap_enforced() {
        let ds = dataset(100, 2, 4);
        let err = full_kernel(&KernelKind::Linear, &ds, 1, 1000).unwrap_err();
        assert!(err.contains("memory wall"));
    }

    #[test]
    fn threaded_row_matches_sequential() {
        let ds = dataset(300, 6, 5);
        let kind = KernelKind::Rbf { gamma: 0.3 };
        let mut r1 = vec![0.0; 300];
        let mut r8 = vec![0.0; 300];
        kernel_row(&kind, &ds, 17, 1, &mut r1);
        kernel_row(&kind, &ds, 17, 8, &mut r8);
        assert_eq!(r1, r8);
    }
}
