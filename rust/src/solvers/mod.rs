//! SVM training algorithms.
//!
//! Explicit family (dual decomposition, §3 of the paper):
//! * [`smo`] — SMO with 2nd-order working-set selection (LibSVM analog;
//!   `cpu-seq` = LibSVM, `cpu-par` = LibSVM+OpenMP, `xla` = GPU SVM).
//! * [`wss`] — working-set-S dual decomposition (GTSVM analog, S = 16).
//!
//! Implicit family (linear-algebra reformulations, §4):
//! * [`mu`] — multiplicative updates (Sha et al.), full kernel.
//! * [`primal`] — primal Newton (Chapelle), full kernel.
//! * [`spsvm`] — sparse primal SVM (Keerthi et al.), the paper's headline
//!   method (WU-SVM).
//! * [`lssvm`] — least-squares SVM (PLSSVM style): one CG solve on the
//!   low-rank normal equations over a `KernelOperator`.
//!
//! All six implement the object-safe [`SolverDriver`] contract and are
//! normally driven through the [`Trainer`] builder ([`api`] module);
//! the per-solver free functions remain as thin shims for one release.
//! The implicit family reaches the kernel only through
//! [`crate::kernel::operator::KernelOperator`] — exact or low-rank.

pub mod api;
pub mod common;
pub mod lssvm;
// note: the cascade meta-solver lives in `crate::cascade`, not here — it
// is a driver *over* these solvers, not a seventh dual/primal algorithm.
pub mod mu;
pub mod primal;
pub mod smo;
pub mod spsvm;
pub mod wss;

pub use api::{
    Budget, BudgetMeter, Family, IterEvent, NullObserver, SolverDriver, SolverSpec, StopReason,
    TraceObserver, TrainCtx, TrainObserver, Trainer,
};

use crate::model::SvmModel;

/// Common training outcome. Phase timings live in the process-wide
/// trace layer ([`crate::trace`]) — wrap the call in a
/// [`crate::trace::Session`] to collect them.
#[derive(Debug)]
pub struct TrainResult {
    pub model: SvmModel,
    /// Total optimization iterations (solver-specific unit).
    pub iterations: usize,
    /// Final objective value (solver-specific convention).
    pub objective: f64,
    /// Full-length dual variables (one per training row, `0` for
    /// non-SVs), exposed by the dual decomposition solvers (SMO/WSS) so
    /// cascade layers can warm-start merged subproblems
    /// ([`api::TrainCtx::initial_alpha`]). `None` for solvers whose
    /// expansion coefficients are not box-constrained duals.
    pub alpha: Option<Vec<f32>>,
    /// Solver-specific notes for reports (cache hit rate etc.).
    pub notes: Vec<(String, String)>,
}

impl TrainResult {
    pub fn note(&mut self, k: &str, v: String) {
        self.notes.push((k.to_string(), v));
    }
}
