//! Working-set-S dual decomposition — the GTSVM analog (Cotter, Srebro &
//! Keshet 2011).
//!
//! GTSVM's key idea: enlarge SMO's working set from 2 to 16 so each
//! outer iteration does enough work to amortize the accelerator's
//! per-call overhead. We reproduce that structure: each outer iteration
//! (1) picks the S most KKT-violating variables (balanced between I_up
//! and I_low so a feasible direction exists), (2) fetches their kernel
//! rows in one batched engine sweep (`KernelRows::get_batch` — one
//! `kernel_block` artifact call per row tile covers all S rows), (3)
//! solves the S-variable subproblem exactly with inner SMO on the cached
//! S x S block, and (4) applies the aggregate gradient update.

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::cache::SharedRowCache;
use crate::kernel::KernelKind;
use crate::model::SvmModel;
use crate::pool::{self, SendPtr};

use super::api::{Budget, Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::common::{dual_objective, KernelRows};
use super::TrainResult;

const TAU: f64 = 1e-12;
/// Chunk size of the threaded KKT scan / gradient sweep (fixed so results
/// are identical across thread counts).
const SCAN_CHUNK: usize = 512;

/// Working-set solver hyperparameters. Outer-round/wall caps come from
/// the ctx [`Budget`] (default [`Budget::wss_default_iters`]).
#[derive(Debug, Clone)]
pub struct WssParams {
    pub c: f32,
    /// Working-set size (GTSVM uses 16).
    pub s: usize,
    /// Outer KKT tolerance.
    pub eps: f64,
    /// Inner subproblem sweeps.
    pub max_inner: usize,
    /// Private kernel-row cache size when the ctx supplies none.
    pub cache_mb: usize,
    /// Cache-aware candidate ordering (`--cache-slack`, DESIGN.md §OOC):
    /// within the band of violations no more than `cache_slack * eps`
    /// below the maximum, already-cached rows are picked into the
    /// working set first. `0.0` (the default) skips the probe and is
    /// bit-identical to plain selection.
    pub cache_slack: f64,
    /// Polishing phase (`--polish`): if the cache-aware ordering stalls
    /// the outer loop early, finish with strict (reorder-free) rounds
    /// until the true KKT gap closes; always report a final verdict.
    /// Off (the default) is bit-identical to the phase not existing.
    pub polish: bool,
}

impl Default for WssParams {
    fn default() -> Self {
        WssParams {
            c: 1.0,
            s: 16,
            eps: 1e-3,
            max_inner: 300,
            cache_mb: 512,
            cache_slack: 0.0,
            polish: false,
        }
    }
}

impl SolverDriver for WssParams {
    fn name(&self) -> &str {
        "wss"
    }

    fn family(&self) -> Family {
        Family::Explicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// Legacy entry point — thin shim over the [`SolverDriver`] path (kept
/// for one release; prefer [`Trainer`]).
pub fn train(
    ds: &Dataset,
    kind: KernelKind,
    params: &WssParams,
    engine: &Engine,
) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Wss(params.clone()))
        .kernel(kind)
        .engine(engine.clone())
        .train(ds)
}

/// Legacy shared-cache entry point — thin shim over [`Trainer`] with
/// [`Trainer::shared_cache`] (kept for one release).
pub fn train_cached(
    ds: &Dataset,
    kind: KernelKind,
    params: &WssParams,
    engine: &Engine,
    cache: Arc<SharedRowCache>,
    cache_group: u64,
) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Wss(params.clone()))
        .kernel(kind)
        .engine(engine.clone())
        .shared_cache(cache, cache_group)
        .train(ds)
}

/// Cache-aware candidate reorder (`--cache-slack`): `cands` is sorted by
/// violation descending; within the band no more than `slack_abs` below
/// the top, stably move rows whose kernel row is already resident ahead
/// of uncached ones. Sequential, deterministic, and purely an ordering
/// change — the violation values (and so every convergence check) are
/// untouched.
fn reorder_cached(cands: &mut [(f64, usize)], slack_abs: f64, rows: &KernelRows) {
    let Some(&(top, _)) = cands.first() else { return };
    let band = cands.partition_point(|&(v, _)| v >= top - slack_abs);
    let cached = cands[..band].iter().filter(|&&(_, t)| rows.is_cached(t)).count();
    if cached > 0 && cached < band {
        // stable: cached candidates keep their relative violation order
        cands[..band].sort_by_key(|&(_, t)| !rows.is_cached(t));
        crate::trace::count(crate::trace::Counter::CachePreferredPicks, cached as u64);
    }
}

/// Train a binary SVM by S-variable dual decomposition; kernel, engine,
/// cache, budget and observer all come from the ctx.
fn train_ctx(ctx: &TrainCtx<'_>, params: &WssParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let kind = ctx.kind;
    let engine = ctx.engine;
    assert!(params.s >= 2);
    let mut ph = crate::trace::phases();
    let n = ds.n;
    let c = params.c as f64;
    let s_max = params.s.min(n);
    // wall clock starts before setup so budgets cover the whole call
    let mut meter = ctx.meter("wss", Budget::wss_default_iters(n));
    let mut rows = ctx.kernel_rows(params.cache_mb)?;
    let scan_threads = engine.threads();
    ph.lap("wss/setup");

    let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
    let diag: Vec<f64> = rows.diag.iter().map(|&v| v as f64).collect();
    let mut alpha = vec![0.0f64; n];
    let mut grad = vec![-1.0f64; n];
    // Warm start (cascade layers): clip to the box and rebuild the
    // gradient from scratch, G_t = -1 + y_t sum_j a_j y_j K(j, t),
    // streaming one cached kernel row per nonzero alpha. A zero vector
    // skips the rebuild and reproduces the cold start bit-for-bit.
    let mut warm = false;
    if let Some(a0) = ctx.initial_alpha {
        for (t, &a) in a0.iter().enumerate() {
            alpha[t] = (a as f64).clamp(0.0, c);
        }
        warm = alpha.iter().any(|&a| a != 0.0);
        if warm {
            for j in 0..n {
                if alpha[j] == 0.0 {
                    continue;
                }
                let kj = rows.get(ds, j)?;
                let coef = alpha[j] * y[j];
                let grad_ptr = SendPtr::new(grad.as_mut_ptr());
                let kj_ref = &kj;
                let y_ref = &y;
                pool::parallel_for(scan_threads, n, SCAN_CHUNK, |t| {
                    // SAFETY: each index t is written by exactly one task.
                    unsafe { *grad_ptr.get().add(t) += coef * y_ref[t] * kj_ref[t] as f64 };
                });
            }
            ph.lap("wss/warmstart");
        }
    }

    let cache_slack = params.cache_slack.clamp(0.0, 0.95);
    // polishing = strict tail rounds (`--polish`): cache-aware reorder
    // off, run until the true KKT gap closes
    let mut polishing = false;
    let mut polish_steps = 0u64;
    let mut polish_verdict: Option<&'static str> = None;
    loop {
        // --- KKT violation scan (chunk-ordered parallel reduction, so the
        // candidate order matches the sequential scan exactly) ---
        let (mut ups, mut lows) = pool::parallel_reduce(
            scan_threads,
            n,
            SCAN_CHUNK,
            |r| {
                let mut ups: Vec<(f64, usize)> = Vec::new();
                let mut lows: Vec<(f64, usize)> = Vec::new();
                for t in r {
                    if (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0) {
                        ups.push((-y[t] * grad[t], t));
                    }
                    if (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c) {
                        lows.push((y[t] * grad[t], t));
                    }
                }
                (ups, lows)
            },
            |mut a, b| {
                a.0.extend(b.0);
                a.1.extend(b.1);
                a
            },
        )
        .unwrap_or_default();
        ups.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        lows.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let gmax = ups.first().map_or(f64::NEG_INFINITY, |v| v.0);
        let gmax2 = lows.first().map_or(f64::NEG_INFINITY, |v| v.0);
        if gmax + gmax2 < params.eps {
            // WSS keeps every gradient entry fresh, so this is the true
            // KKT gap — a clean verdict needs no extra work
            if params.polish {
                polish_verdict = Some("clean");
            }
            break;
        }
        // cache-aware scheduling: within slack of the top violation,
        // pick resident rows into the working set first (never while
        // polishing — the tail rounds are strict)
        if cache_slack > 0.0 && !polishing {
            let slack_abs = cache_slack * params.eps;
            reorder_cached(&mut ups, slack_abs, &rows);
            reorder_cached(&mut lows, slack_abs, &rows);
        }
        // balanced working set: top violators from each side, dedup
        let mut ws: Vec<usize> = Vec::with_capacity(s_max);
        let half = s_max / 2;
        for &(_, t) in ups.iter().take(half) {
            ws.push(t);
        }
        for &(_, t) in lows.iter() {
            if ws.len() >= s_max {
                break;
            }
            if !ws.contains(&t) {
                ws.push(t);
            }
        }
        for &(_, t) in ups.iter().skip(half) {
            if ws.len() >= s_max {
                break;
            }
            if !ws.contains(&t) {
                ws.push(t);
            }
        }
        ph.lap("wss/select");

        // --- batched kernel rows for the working set ---
        let krows = rows.get_batch(ds, &ws)?;
        ph.lap("wss/kernel");

        // --- inner solver on the S-variable subproblem ---
        // local gradient over ws, Q_ws_ws from the fetched rows
        let s = ws.len();
        let mut a_loc: Vec<f64> = ws.iter().map(|&t| alpha[t]).collect();
        let a0 = a_loc.clone();
        let mut g_loc: Vec<f64> = ws.iter().map(|&t| grad[t]).collect();
        let q = |p: usize, r: usize| -> f64 {
            y[ws[p]] * y[ws[r]] * krows[p][ws[r]] as f64
        };
        for _ in 0..params.max_inner {
            // WSS2 inside the subproblem
            let mut gm = f64::NEG_INFINITY;
            let mut isel = usize::MAX;
            for p in 0..s {
                let t = ws[p];
                if (y[t] > 0.0 && a_loc[p] < c) || (y[t] < 0.0 && a_loc[p] > 0.0) {
                    let v = -y[t] * g_loc[p];
                    if v >= gm {
                        gm = v;
                        isel = p;
                    }
                }
            }
            if isel == usize::MAX {
                break;
            }
            let mut gm2 = f64::NEG_INFINITY;
            let mut jsel = usize::MAX;
            let mut obj_min = f64::INFINITY;
            for p in 0..s {
                let t = ws[p];
                if (y[t] > 0.0 && a_loc[p] > 0.0) || (y[t] < 0.0 && a_loc[p] < c) {
                    let v = y[t] * g_loc[p];
                    if v > gm2 {
                        gm2 = v;
                    }
                    let gd = gm + v;
                    if gd > 0.0 {
                        let quad = (diag[ws[isel]] + diag[t] - 2.0 * q(isel, p)).max(TAU);
                        let obj = -(gd * gd) / quad;
                        if obj <= obj_min {
                            obj_min = obj;
                            jsel = p;
                        }
                    }
                }
            }
            // tighter inner tolerance so outer progress is real
            if jsel == usize::MAX || gm + gm2 < params.eps * 0.1 {
                break;
            }
            let (i, j) = (isel, jsel);
            let (yi, yj) = (y[ws[i]], y[ws[j]]);
            let old_ai = a_loc[i];
            let old_aj = a_loc[j];
            if yi != yj {
                let quad = (diag[ws[i]] + diag[ws[j]] + 2.0 * q(i, j)).max(TAU);
                let delta = (-g_loc[i] - g_loc[j]) / quad;
                let diff = a_loc[i] - a_loc[j];
                a_loc[i] += delta;
                a_loc[j] += delta;
                if diff > 0.0 {
                    if a_loc[j] < 0.0 {
                        a_loc[j] = 0.0;
                        a_loc[i] = diff;
                    }
                } else if a_loc[i] < 0.0 {
                    a_loc[i] = 0.0;
                    a_loc[j] = -diff;
                }
                if diff > 0.0 {
                    if a_loc[i] > c {
                        a_loc[i] = c;
                        a_loc[j] = c - diff;
                    }
                } else if a_loc[j] > c {
                    a_loc[j] = c;
                    a_loc[i] = c + diff;
                }
            } else {
                let quad = (diag[ws[i]] + diag[ws[j]] - 2.0 * q(i, j)).max(TAU);
                let delta = (g_loc[i] - g_loc[j]) / quad;
                let sum = a_loc[i] + a_loc[j];
                a_loc[i] -= delta;
                a_loc[j] += delta;
                if sum > c {
                    if a_loc[i] > c {
                        a_loc[i] = c;
                        a_loc[j] = sum - c;
                    }
                } else if a_loc[j] < 0.0 {
                    a_loc[j] = 0.0;
                    a_loc[i] = sum;
                }
                if sum > c {
                    if a_loc[j] > c {
                        a_loc[j] = c;
                        a_loc[i] = sum - c;
                    }
                } else if a_loc[i] < 0.0 {
                    a_loc[i] = 0.0;
                    a_loc[j] = sum;
                }
            }
            let dai = a_loc[i] - old_ai;
            let daj = a_loc[j] - old_aj;
            // local gradient update on the S x S block
            for p in 0..s {
                g_loc[p] += q(p, i) * dai + q(p, j) * daj;
            }
        }
        ph.lap("wss/inner");

        // --- apply aggregate update to global state: one threaded sweep
        // over t accumulates every changed row's contribution ---
        let mut deltas: Vec<(f64, f64, Arc<Vec<f32>>)> = Vec::new(); // (y_p, da, K row)
        for p in 0..s {
            let da = a_loc[p] - a0[p];
            if da.abs() > 1e-15 {
                alpha[ws[p]] = a_loc[p];
                deltas.push((y[ws[p]], da, krows[p].clone()));
            }
        }
        let changed = !deltas.is_empty();
        if changed {
            let grad_ptr = SendPtr::new(grad.as_mut_ptr());
            let deltas_ref = &deltas;
            let y_ref = &y;
            pool::parallel_for(scan_threads, n, SCAN_CHUNK, |t| {
                let mut acc = 0.0f64;
                for (yp, da, kp) in deltas_ref {
                    acc += yp * kp[t] as f64 * da;
                }
                // SAFETY: each index t is written by exactly one task.
                unsafe { *grad_ptr.get().add(t) += y_ref[t] * acc };
            });
        }
        ph.lap("wss/update");
        if polishing {
            polish_steps += 1;
            crate::trace::count(crate::trace::Counter::PolishSteps, 1);
        }
        let cont = meter.tick(|| {
            let nsv = alpha.iter().filter(|&&a| a > 0.0).count();
            (dual_objective(&alpha, &grad), nsv)
        });
        if !cont {
            if params.polish {
                polish_verdict = Some("capped");
            }
            break;
        }
        if !changed {
            // the inner solver made no progress on this working set
            if params.polish && !polishing && cache_slack > 0.0 {
                // the cache-preferring order may have starved the true
                // violators; switch to strict rounds and keep going
                polishing = true;
                continue;
            }
            if params.polish {
                polish_verdict = Some("stalled");
            }
            break;
        }
    }

    // bias (same as SMO)
    let mut nfree = 0usize;
    let mut sum_free = 0.0f64;
    let mut ub = f64::INFINITY;
    let mut lb = f64::NEG_INFINITY;
    for t in 0..n {
        let ygt = y[t] * grad[t];
        if alpha[t] > 0.0 && alpha[t] < c {
            nfree += 1;
            sum_free += ygt;
        } else if (alpha[t] == 0.0 && y[t] > 0.0) || (alpha[t] == c && y[t] < 0.0) {
            ub = ub.min(ygt);
        } else {
            lb = lb.max(ygt);
        }
    }
    let rho = if nfree > 0 { sum_free / nfree as f64 } else { (ub + lb) / 2.0 };

    let objective: f64 = 0.5
        * alpha
            .iter()
            .zip(&grad)
            .map(|(a, g)| a * (g - 1.0))
            .sum::<f64>();

    let sv_idx: Vec<usize> = (0..n).filter(|&t| alpha[t] > 0.0).collect();
    let vectors = ds.gather_rows(&sv_idx);
    let coef: Vec<f32> = sv_idx.iter().map(|&t| (alpha[t] * y[t]) as f32).collect();
    ph.lap("wss/finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias: -rho as f32,
        solver: format!("wss{}[{}]", params.s, engine.name()),
    };
    let mut res = TrainResult {
        model,
        iterations: meter.iterations(),
        objective,
        alpha: Some(alpha.iter().map(|&a| a as f32).collect()),
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", if warm { "accepted" } else { "zero (cold)" }.to_string());
    }
    res.note("n_sv", sv_idx.len().to_string());
    res.note("cache_hit_rate", format!("{:.3}", rows.hit_rate()));
    res.note("cache_evicted_bytes", rows.cache_evicted_bytes().to_string());
    res.note(
        "cache_fill",
        format!("{:.3}", rows.cache_used_bytes() as f64 / rows.cache_budget_bytes().max(1) as f64),
    );
    res.note("rows_computed", rows.rows_computed.to_string());
    if let Some(v) = polish_verdict {
        res.note("polish", v.to_string());
        res.note("polish_steps", polish_steps.to_string());
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::error_rate;
    use crate::solvers::smo;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    #[test]
    fn solves_xor() {
        let ds = xor_dataset(300, 11);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 8.0 },
            &WssParams { c: 10.0, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.05);
    }

    #[test]
    fn matches_smo_objective() {
        let ds = xor_dataset(200, 13);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let sp = smo::SmoParams { c: 5.0, ..Default::default() };
        let a = smo::train(&ds, kind, &sp, &Engine::cpu_seq()).unwrap();
        let wp = WssParams { c: 5.0, ..Default::default() };
        let b = train(&ds, kind, &wp, &Engine::cpu_seq()).unwrap();
        // both solve the same strictly convex-ish dual to eps: objectives close
        let rel = (a.objective - b.objective).abs() / a.objective.abs().max(1.0);
        assert!(rel < 5e-3, "smo {} vs wss {}", a.objective, b.objective);
    }

    #[test]
    fn fewer_outer_iterations_than_smo() {
        let ds = xor_dataset(400, 17);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let sp = smo::SmoParams { c: 10.0, ..Default::default() };
        let a = smo::train(&ds, kind, &sp, &Engine::cpu_seq()).unwrap();
        let wp = WssParams { c: 10.0, s: 16, ..Default::default() };
        let b = train(&ds, kind, &wp, &Engine::cpu_seq()).unwrap();
        assert!(
            b.iterations * 4 < a.iterations,
            "wss {} vs smo {} iterations",
            b.iterations,
            a.iterations
        );
    }

    #[test]
    fn polish_and_slack_report_verdict_and_match_objective() {
        let ds = xor_dataset(250, 23);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let base =
            train(&ds, kind, &WssParams { c: 5.0, ..Default::default() }, &Engine::cpu_seq())
                .unwrap();
        let p = WssParams { c: 5.0, cache_slack: 0.5, polish: true, ..Default::default() };
        let r = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        let rel = (r.objective - base.objective).abs() / base.objective.abs().max(1.0);
        assert!(rel < 5e-3, "slack+polish {} vs plain {}", r.objective, base.objective);
        let verdict = r.notes.iter().find(|(k, _)| k == "polish").map(|(_, v)| v.as_str());
        assert!(
            matches!(verdict, Some("clean" | "capped" | "stalled")),
            "verdict {verdict:?}"
        );
    }

    #[test]
    fn working_set_size_two_behaves_like_smo() {
        let ds = xor_dataset(150, 19);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let wp = WssParams { c: 2.0, s: 2, ..Default::default() };
        let r = train(&ds, kind, &wp, &Engine::cpu_seq()).unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.08);
    }
}
