//! SP-SVM — sparse primal SVM (Keerthi, Chapelle & DeCoste 2006), the
//! paper's headline implicitly-parallel method (released as WU-SVM).
//!
//! Optimizes the basis-restricted primal (paper eq. 4)
//!   min_b  1/2 b^T K_JJ b + C sum_i max(0, 1 - y_i (b^T k_Ji))^2
//! by alternating two stages:
//!
//! * **Basis selection** — sample S candidates, score each by the
//!   approximate one-dimensional loss decrease g_j^2 / (k_jj + h_j)
//!   (accumulated tile-by-tile with `score_tile`), greedily add the top
//!   scorers to J.
//! * **Re-optimization** — Newton on the restricted primal: per-tile
//!   gradient/Gauss-Newton statistics (`tile_stats`, a fused Pallas
//!   kernel), masked CG solve (`cg_solve`, a single artifact call), line
//!   search on cached margins.
//!
//! Every heavy operation is one large dense engine op over padded tiles,
//! so the same code is the paper's multicore-MKL SP-SVM under `cpu-par`
//! and the GPU SP-SVM under `xla`. Since the cpu engines route those ops
//! through the blocked-GEMM substrate (DESIGN.md §GEMM), `cpu-par` now
//! carries the paper's actual performance mechanism — an optimized dense
//! library under an implicitly-parallel algorithm — not just its
//! algorithmic shape. Stopping follows the paper: after
//! re-optimization, stop when (change in training error) / (basis vectors
//! added) < epsilon (default 5e-6), or at the basis capacity.
//!
//! Memory: O(|J| n) for the cached kernel tiles — the compromise that
//! lets SP-SVM scale where MU/full-primal cannot (paper §4).

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::operator::{build as build_operator, KernelOperator, LowRankConfig};
use crate::kernel::KernelKind;
use crate::model::SvmModel;
use crate::rng::Rng;

use super::api::{Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::common::TiledData;
use super::TrainResult;

/// SP-SVM hyperparameters.
#[derive(Debug, Clone)]
pub struct SpSvmParams {
    pub c: f32,
    /// RBF width used by the legacy [`train`] shim only; the
    /// [`SolverDriver`] path takes gamma from the ctx kernel.
    pub gamma: f32,
    /// Basis capacity, excluding the bias slot. The engine bucket is the
    /// next b bucket above (max_basis + 1).
    pub max_basis: usize,
    /// Candidates sampled per selection round (Keerthi's kappa = 59; we
    /// use the artifact bucket 64).
    pub candidates: usize,
    /// Basis vectors added per selection round before re-optimizing.
    pub add_per_round: usize,
    /// Paper's stopping threshold epsilon.
    pub eps: f64,
    /// Newton iterations per re-optimization.
    pub max_newton: usize,
    pub seed: u64,
    /// `Some` sources candidate-scoring tiles and K_JJ from a low-rank
    /// G·Gᵀ factor (cpu engines only; the accelerator tile path is
    /// exact and sits below the operator layer).
    pub lowrank: Option<LowRankConfig>,
}

impl Default for SpSvmParams {
    fn default() -> Self {
        SpSvmParams {
            c: 1.0,
            gamma: 1.0,
            max_basis: 511,
            candidates: 64,
            add_per_round: 8,
            eps: 5e-6,
            max_newton: 8,
            seed: 0x5b5b,
            lowrank: None,
        }
    }
}

/// Internal training state over padded tiles.
struct SpState {
    tiled: TiledData,
    /// Engine bucket for the basis dimension (includes bias slot 0).
    /// Starts at the smallest bucket and grows as the basis fills —
    /// tile_stats/cg cost scales with the bucket, so early rounds run at
    /// a fraction of the final cost (EXPERIMENTS.md §Perf).
    b: usize,
    /// Available bucket ladder (ascending).
    buckets: Vec<usize>,
    /// Cached kernel tiles K[t x b]; column 0 = bias ones; columns filled
    /// up to n_basis+1.
    ktiles: Vec<Vec<f32>>,
    /// Cached margins per tile.
    margins: Vec<Vec<f32>>,
    /// Basis vector rows (padded d), slot 0 unused (bias).
    xb: Vec<f32>,
    /// Training-set indices of basis vectors (slot order, bias skipped).
    basis_idx: Vec<usize>,
    /// K_JJ regularizer (b x b, bias row/col zero).
    kjj: Vec<f32>,
    beta: Vec<f32>,
    bmask: Vec<f32>,
}

impl SpState {
    fn n_basis(&self) -> usize {
        self.basis_idx.len()
    }

    /// Occupied slots including bias.
    fn occ(&self) -> usize {
        self.n_basis() + 1
    }
}

fn build_state(ds: &Dataset, engine: &Engine, params: &SpSvmParams) -> Result<SpState> {
    // pick buckets: xla engines must land exactly on manifest buckets;
    // cpu engines use the same sizes for comparability.
    let (t, d_pad, buckets) = match &engine.kind {
        crate::engine::EngineKind::Xla { runtime } => {
            let t = runtime.tile_t();
            let d_pad = *runtime
                .manifest()
                .d_buckets()
                .iter()
                .find(|&&x| x >= ds.d)
                .ok_or_else(|| anyhow::anyhow!("no d bucket >= {} (make artifacts)", ds.d))?;
            let buckets: Vec<usize> = runtime
                .manifest()
                .b_buckets()
                .into_iter()
                .filter(|&x| {
                    // the d bucket must exist for kernel_block at this b
                    runtime.manifest().lookup("kernel_block", t, d_pad, x, 0).is_some()
                })
                .collect();
            // a short ladder is fine — training caps max_basis to its top
            // (`max_basis.min(buckets.last() - 1)` below) — but an empty
            // one means kernel_block has no artifact at all
            anyhow::ensure!(!buckets.is_empty(), "no usable b bucket (make artifacts)");
            (t, d_pad, buckets)
        }
        _ => {
            let t = 1024;
            let max_b = (params.max_basis + 1).next_power_of_two().max(64);
            let mut buckets = vec![];
            let mut b = 64;
            while b <= max_b {
                buckets.push(b);
                b *= 2;
            }
            (t, ds.d, buckets)
        }
    };
    let b = buckets[0];
    // xla artifacts need dense bucket-shaped tiles; cpu engines keep a
    // sparse design in CSR and score candidates through the SpMM path
    let tiled = if engine.is_xla() {
        TiledData::densified(ds, t, d_pad)
    } else {
        TiledData::new(ds, t, d_pad)
    };
    let n_tiles = tiled.n_tiles;
    let mut ktiles = Vec::with_capacity(n_tiles);
    let mut margins = Vec::with_capacity(n_tiles);
    for _ in 0..n_tiles {
        let mut kt = vec![0.0f32; t * b];
        for r in 0..t {
            kt[r * b] = 1.0; // bias column
        }
        ktiles.push(kt);
        margins.push(vec![0.0f32; t]);
    }
    let mut bmask = vec![0.0f32; b];
    bmask[0] = 1.0; // bias active from the start
    Ok(SpState {
        tiled,
        b,
        buckets,
        ktiles,
        margins,
        xb: vec![0.0f32; b * d_pad],
        basis_idx: Vec::new(),
        kjj: vec![0.0f32; b * b],
        beta: vec![0.0f32; b],
        bmask,
    })
}

/// Migrate the state to the next bucket size (copy-stride reallocation of
/// the kernel tiles and B-indexed arrays). Returns false at the ladder top.
fn grow_bucket(st: &mut SpState) -> bool {
    let old_b = st.b;
    let Some(&new_b) = st.buckets.iter().find(|&&x| x > old_b) else {
        return false;
    };
    let t = st.tiled.t;
    let d_pad = st.tiled.d_pad;
    for kt in st.ktiles.iter_mut() {
        let mut nk = vec![0.0f32; t * new_b];
        for r in 0..t {
            nk[r * new_b..r * new_b + old_b].copy_from_slice(&kt[r * old_b..(r + 1) * old_b]);
        }
        *kt = nk;
    }
    let mut nkjj = vec![0.0f32; new_b * new_b];
    for r in 0..old_b {
        nkjj[r * new_b..r * new_b + old_b].copy_from_slice(&st.kjj[r * old_b..(r + 1) * old_b]);
    }
    st.kjj = nkjj;
    let mut nxb = vec![0.0f32; new_b * d_pad];
    nxb[..old_b * d_pad].copy_from_slice(&st.xb);
    st.xb = nxb;
    st.beta.resize(new_b, 0.0);
    st.bmask.resize(new_b, 0.0);
    st.b = new_b;
    true
}

/// Loss over all tiles from cached margins: 1/2 b K_JJ b + C sum h^2,
/// plus the training error count.
fn loss_and_err(st: &SpState, c: f32) -> (f64, usize) {
    let b = st.b;
    // reg term
    let mut reg = 0.0f64;
    for i in 0..b {
        if st.bmask[i] == 0.0 {
            continue;
        }
        let bi = st.beta[i] as f64;
        if bi == 0.0 {
            continue;
        }
        let mut acc = 0.0f64;
        for j in 0..b {
            acc += st.kjj[i * b + j] as f64 * st.beta[j] as f64;
        }
        reg += bi * acc;
    }
    let mut loss = 0.5 * reg;
    let mut nerr = 0usize;
    for tile in 0..st.tiled.n_tiles {
        let y = &st.tiled.y[tile];
        let m = &st.tiled.m[tile];
        let f = &st.margins[tile];
        for r in 0..st.tiled.t {
            if m[r] == 0.0 {
                continue;
            }
            let h = (1.0 - y[r] * f[r]).max(0.0);
            loss += (c * h * h) as f64;
            if y[r] * f[r] <= 0.0 {
                nerr += 1;
            }
        }
    }
    (loss, nerr)
}

/// Candidate-scoring tile `K[t × s]` of one padded tile against the
/// candidate rows, through the kernel operator. Real rows come from
/// `op.block` (tiles are contiguous row ranges); padded tail rows and
/// unused candidate columns stay zero — every downstream consumer
/// (score_tile, tile_stats, loss_and_err) masks them out via the tile
/// validity mask / `a_t = r_t = 0`, so a zero fill is exact.
fn cross_tile(
    op: &dyn KernelOperator,
    tiled: &TiledData,
    tile: usize,
    cand: &[usize],
    s: usize,
) -> Vec<f32> {
    let t = tiled.t;
    let start = tile * t;
    let m_real = t.min(op.n() - start);
    let ri: Vec<usize> = (start..start + m_real).collect();
    let nc = cand.len();
    let mut tmp = vec![0.0f32; m_real * nc];
    op.block(&ri, cand, &mut tmp);
    let mut kc = vec![0.0f32; t * s];
    for r in 0..m_real {
        kc[r * s..r * s + nc].copy_from_slice(&tmp[r * nc..(r + 1) * nc]);
    }
    kc
}

/// Refresh cached margins from the kernel tiles (one predict per tile).
fn refresh_margins(st: &mut SpState, engine: &Engine) -> Result<()> {
    for tile in 0..st.tiled.n_tiles {
        st.margins[tile] =
            engine.predict_block(&st.ktiles[tile], st.tiled.t, st.b, &st.beta)?;
    }
    Ok(())
}

/// One full re-optimization (Newton with line search). Returns #iters.
fn reoptimize(
    st: &mut SpState,
    engine: &Engine,
    params: &SpSvmParams,
    ph: &mut crate::trace::PhaseGuard,
) -> Result<usize> {
    let b = st.b;
    let t = st.tiled.t;
    let c = params.c;
    let (mut cur_loss, _) = loss_and_err(st, c);
    let mut iters = 0;
    for _ in 0..params.max_newton {
        iters += 1;
        // accumulate data-term gradient and Gauss-Newton across tiles
        let mut grad = vec![0.0f32; b];
        let mut hess = vec![0.0f32; b * b];
        for tile in 0..st.tiled.n_tiles {
            let stats = engine.tile_stats(
                &st.ktiles[tile],
                t,
                b,
                &st.tiled.y[tile],
                &st.tiled.m[tile],
                &st.beta,
                c,
            )?;
            crate::linalg::axpy(1.0, &stats.grad, &mut grad);
            crate::linalg::axpy(1.0, &stats.hess, &mut hess);
        }
        ph.lap("spsvm/reopt/stats");
        // regularizer: g += K_JJ beta, H += K_JJ
        for i in 0..b {
            if st.bmask[i] == 0.0 {
                continue;
            }
            let mut acc = 0.0f64;
            for j in 0..b {
                acc += st.kjj[i * b + j] as f64 * st.beta[j] as f64;
            }
            grad[i] += acc as f32;
        }
        for i in 0..b * b {
            hess[i] += st.kjj[i];
        }
        // Levenberg damping relative to the Gauss-Newton diagonal scale
        let mut diag_mean = 0.0f64;
        let occ = st.occ().max(1);
        for i in 0..b {
            if st.bmask[i] != 0.0 {
                diag_mean += hess[i * b + i] as f64;
            }
        }
        diag_mean /= occ as f64;
        let reg = (1e-4 * diag_mean).max(1e-6) as f32;

        let neg_grad: Vec<f32> = grad.iter().map(|v| -v).collect();
        let delta = engine.cg_solve(&hess, b, &neg_grad, &st.bmask, reg)?;
        ph.lap("spsvm/reopt/solve");

        // line search on cached margin updates: f_new = f + step * K delta
        let mut fdelta: Vec<Vec<f32>> = Vec::with_capacity(st.tiled.n_tiles);
        for tile in 0..st.tiled.n_tiles {
            fdelta.push(engine.predict_block(&st.ktiles[tile], t, b, &delta)?);
        }
        let mut step = 1.0f32;
        let mut accepted = false;
        for _ in 0..6 {
            // trial margins
            let trial_beta: Vec<f32> = st
                .beta
                .iter()
                .zip(&delta)
                .map(|(bv, dv)| bv + step * dv)
                .collect();
            let saved_margins = std::mem::take(&mut st.margins);
            let mut trial_margins = saved_margins.clone();
            for tile in 0..st.tiled.n_tiles {
                for r in 0..t {
                    trial_margins[tile][r] += step * fdelta[tile][r];
                }
            }
            st.margins = trial_margins;
            let saved_beta = std::mem::replace(&mut st.beta, trial_beta);
            let (trial_loss, _) = loss_and_err(st, c);
            if trial_loss <= cur_loss {
                cur_loss = trial_loss;
                accepted = true;
                break;
            }
            // revert
            st.beta = saved_beta;
            st.margins = saved_margins;
            step *= 0.5;
        }
        ph.lap("spsvm/reopt/linesearch");
        if !accepted {
            break;
        }
        // stop when the Newton step stops mattering
        let gn: f64 = grad
            .iter()
            .zip(&st.bmask)
            .map(|(g, m)| (g * m) as f64)
            .map(|v| v * v)
            .sum::<f64>()
            .sqrt();
        if gn < 1e-4 * (1.0 + cur_loss.abs()) {
            break;
        }
    }
    Ok(iters)
}

impl SolverDriver for SpSvmParams {
    fn name(&self) -> &str {
        "spsvm"
    }

    fn family(&self) -> Family {
        Family::Implicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// Legacy entry point — thin shim over the [`SolverDriver`] path (kept
/// for one release; prefer [`Trainer`]). The kernel is
/// `Rbf { gamma: params.gamma }`, the historical convention.
pub fn train(ds: &Dataset, params: &SpSvmParams, engine: &Engine) -> Result<TrainResult> {
    Trainer::new(SolverSpec::SpSvm(params.clone()))
        .kernel(KernelKind::Rbf { gamma: params.gamma })
        .engine(engine.clone())
        .train(ds)
}

/// Train SP-SVM. RBF-only: the ctx kernel supplies gamma.
fn train_ctx(ctx: &TrainCtx<'_>, params: &SpSvmParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let engine = ctx.engine;
    let gamma = match ctx.kind {
        KernelKind::Rbf { gamma } => gamma,
        other => anyhow::bail!("spsvm supports the RBF kernel only (got {})", other.name()),
    };
    let mut ph = crate::trace::phases();
    // budget unit = selection+reopt rounds, counted by the meter; every
    // round grows the basis by at least one vector, so max_basis + 1
    // bounds the natural round count (the +1 keeps an uncapped run that
    // exactly fills its basis from being flagged `capped`). The wall
    // clock starts before tile/state setup.
    let mut meter = ctx.meter("spsvm", params.max_basis.max(1) + 1);
    let mut st = build_state(ds, engine, params)?;
    let mut rng = Rng::new(params.seed);
    let kind = KernelKind::Rbf { gamma };
    // Kernel access for candidate scoring and K_JJ: cpu engines go
    // through the operator layer (exact streaming by default, low-rank
    // G·Gᵀ when params ask); the xla engine keeps its bucket-shaped
    // artifact tile path, which lives below the operator abstraction
    // (ROADMAP item 3 slots the accelerator under it).
    let op: Option<Box<dyn KernelOperator + '_>> = if engine.is_xla() {
        anyhow::ensure!(
            params.lowrank.is_none(),
            "spsvm low-rank (--rank/--landmarks) runs on the cpu engines only \
             (the accelerator tile path is exact)"
        );
        None
    } else {
        Some(build_operator(&kind, ds, engine.threads(), params.lowrank)?)
    };
    let lowrank_on = params.lowrank.is_some();
    let s = params.candidates.min(64);
    let t = st.tiled.t;
    let d_pad = st.tiled.d_pad;
    let n = ds.n;
    ph.lap("spsvm/setup");

    refresh_margins(&mut st, engine)?; // beta = 0 -> margins 0
    let (_, mut last_err) = loss_and_err(&st, params.c);
    let mut newton_total = 0usize;
    let mut rounds = 0usize;
    let max_basis = params.max_basis.min(st.buckets.last().unwrap() - 1);

    'outer: while st.n_basis() < max_basis {
        rounds += 1;
        let mut added_this_phase = 0usize;
        // ---- selection stage: add up to add_per_round basis vectors ----
        while added_this_phase < params.add_per_round && st.n_basis() < max_basis {
            // sample S candidates, biased toward active (hinge > 0) rows
            let mut cand: Vec<usize> = Vec::with_capacity(s);
            let mut guard = 0;
            while cand.len() < s && guard < 50 * s {
                guard += 1;
                let i = rng.below(n);
                let (tile, r) = st.tiled.locate(i);
                let active = {
                    let y = st.tiled.y[tile][r];
                    let f = st.margins[tile][r];
                    1.0 - y * f > 0.0
                };
                // keep actives; accept inactives with low probability
                if (active || rng.bernoulli(0.1))
                    && !st.basis_idx.contains(&i)
                    && !cand.contains(&i)
                {
                    cand.push(i);
                }
            }
            if cand.is_empty() {
                break 'outer; // nothing violates: done
            }
            // pack candidate rows into the S-bucket
            let mut xc = vec![0.0f32; s * d_pad];
            for (q, &i) in cand.iter().enumerate() {
                st.tiled.copy_row(i, &mut xc[q * d_pad..(q + 1) * d_pad]);
            }
            // accumulate scoring stats over tiles; stash Kc columns so the
            // winners' kernel columns are free
            let mut gc = vec![0.0f64; s];
            let mut hc = vec![0.0f64; s];
            let mut kc_tiles: Vec<Vec<f32>> = Vec::with_capacity(st.tiled.n_tiles);
            for tile in 0..st.tiled.n_tiles {
                let kc = match &op {
                    Some(op) => cross_tile(op.as_ref(), &st.tiled, tile, &cand, s),
                    None => st.tiled.rbf_block(engine, tile, &xc, s, gamma)?,
                };
                let y = &st.tiled.y[tile];
                let m = &st.tiled.m[tile];
                let f = &st.margins[tile];
                let mut r_t = vec![0.0f32; t];
                let mut a_t = vec![0.0f32; t];
                for r in 0..t {
                    let h = (1.0 - y[r] * f[r]).max(0.0);
                    if h > 0.0 && m[r] != 0.0 {
                        a_t[r] = 1.0;
                        r_t[r] = y[r] * h;
                    }
                }
                let (gct, hct) = engine.score_tile(&kc, t, s, &r_t, &a_t)?;
                for q in 0..s.min(cand.len()) {
                    gc[q] += gct[q] as f64;
                    hc[q] += hct[q] as f64;
                }
                kc_tiles.push(kc);
            }
            ph.lap("spsvm/select/score");
            // Keerthi score: one-dim Newton decrease (2C g)^2 / (k_jj + 2C h)
            let c2 = 2.0 * params.c as f64;
            let mut scored: Vec<(f64, usize)> = (0..cand.len())
                .map(|q| {
                    let g = c2 * gc[q];
                    let h = 1.0 + c2 * hc[q]; // k_jj = 1 for RBF
                    (g * g / h, q)
                })
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            // add the best candidate from this sample (Keerthi adds 1 per
            // 59-sample; we add 1 per 64-sample)
            let &(best_score, q) = &scored[0];
            if best_score <= 0.0 {
                break 'outer;
            }
            let i = cand[q];
            if st.occ() == st.b && !grow_bucket(&mut st) {
                break 'outer; // bucket ladder exhausted
            }
            let slot = st.occ(); // next free slot (0 is bias)
            // basis row
            st.tiled
                .copy_row(i, &mut st.xb[slot * d_pad..(slot + 1) * d_pad]);
            // kernel column: reuse the scoring block
            for tile in 0..st.tiled.n_tiles {
                let kc = &kc_tiles[tile];
                let kt = &mut st.ktiles[tile];
                for r in 0..t {
                    kt[r * st.b + slot] = kc[r * s + q];
                }
            }
            // K_JJ extension (tiny: |J| kernel entries). The low-rank
            // path sources them from the operator so the restricted
            // primal optimizes one consistent G·Gᵀ surrogate; exact
            // paths keep the direct per-pair evaluation.
            let xi = &st.xb[slot * d_pad..(slot + 1) * d_pad];
            for (other_pos, &other_idx) in st.basis_idx.clone().iter().enumerate() {
                let oslot = other_pos + 1;
                let v = match (&op, lowrank_on) {
                    (Some(op), true) => {
                        let mut buf = [0.0f32; 1];
                        op.block(&[i], &[other_idx], &mut buf);
                        buf[0]
                    }
                    _ => {
                        let xo = &st.xb[oslot * d_pad..(oslot + 1) * d_pad];
                        kind.eval(xi, xo)
                    }
                };
                st.kjj[slot * st.b + oslot] = v;
                st.kjj[oslot * st.b + slot] = v;
            }
            st.kjj[slot * st.b + slot] = match (&op, lowrank_on) {
                (Some(op), true) => {
                    let mut buf = [0.0f32; 1];
                    op.block(&[i], &[i], &mut buf);
                    buf[0]
                }
                _ => 1.0,
            };
            st.bmask[slot] = 1.0;
            st.basis_idx.push(i);
            added_this_phase += 1;
            ph.lap("spsvm/select/add");
        }
        if added_this_phase == 0 {
            break;
        }
        // ---- re-optimization stage ----
        newton_total += reoptimize(&mut st, engine, params, &mut ph)?;
        refresh_margins(&mut st, engine)?;
        ph.lap("spsvm/reopt/margins");
        let (loss, err) = loss_and_err(&st, params.c);
        if !meter.tick(|| (loss, st.n_basis())) {
            break;
        }
        // paper's stopping rule
        let delta_err = (last_err as f64 - err as f64) / n as f64;
        last_err = err;
        if st.n_basis() >= 16 && delta_err / (added_this_phase as f64) < params.eps {
            break;
        }
    }

    // ---- extract the model (unpadded vectors, bias from slot 0) ----
    let nb = st.n_basis();
    let mut vectors = Vec::with_capacity(nb * ds.d);
    let mut coef = Vec::with_capacity(nb);
    for pos in 0..nb {
        let slot = pos + 1;
        vectors.extend_from_slice(&st.xb[slot * d_pad..slot * d_pad + ds.d]);
        coef.push(st.beta[slot]);
    }
    ph.lap("spsvm/finalize");
    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias: st.beta[0],
        solver: format!("spsvm[{}]", engine.name()),
    };
    let (final_loss, final_err) = loss_and_err(&st, params.c);
    // iterations = budget/observer rounds (matching IterEvent.iter and
    // Budget::max_iters units); the Newton-step total rides in the notes
    let mut res = TrainResult {
        model,
        iterations: meter.iterations(),
        objective: final_loss,
        alpha: None,
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", "rejected (spsvm betas are not box-constrained duals)".into());
    }
    res.note("n_basis", nb.to_string());
    res.note("newton_iters", newton_total.to_string());
    res.note("rounds", rounds.to_string());
    res.note("train_err", format!("{:.4}", final_err as f64 / n as f64));
    res.note("kernel_cache_bytes", (st.tiled.n_tiles * t * st.b * 4).to_string());
    if let Some(op) = &op {
        res.note("operator", op.name().to_string());
        res.note("operator_bytes", op.memory_bytes().to_string());
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::error_rate;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    fn params(gamma: f32, c: f32, max_basis: usize) -> SpSvmParams {
        SpSvmParams { c, gamma, max_basis, ..Default::default() }
    }

    #[test]
    fn solves_xor() {
        let ds = xor_dataset(1500, 21);
        let r = train(&ds, &params(8.0, 10.0, 63), &Engine::cpu_seq()).unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        let err = error_rate(&margins, &ds.y);
        assert!(err < 0.06, "train error {err}");
        assert!(r.model.num_vectors() <= 63);
        assert!(r.model.num_vectors() >= 8);
    }

    #[test]
    fn basis_capacity_respected() {
        let ds = xor_dataset(800, 23);
        let r = train(&ds, &params(8.0, 10.0, 20), &Engine::cpu_seq()).unwrap();
        assert!(r.model.num_vectors() <= 20);
    }

    #[test]
    fn cpu_engines_agree() {
        let ds = xor_dataset(600, 25);
        let p = params(8.0, 5.0, 31);
        let a = train(&ds, &p, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, &p, &Engine::cpu_par(4)).unwrap();
        // same seed, same candidate stream -> same basis, near-same loss
        assert_eq!(a.model.num_vectors(), b.model.num_vectors());
        let rel = (a.objective - b.objective).abs() / a.objective.abs().max(1.0);
        assert!(rel < 1e-2, "{} vs {}", a.objective, b.objective);
    }

    #[test]
    fn more_basis_lowers_training_error() {
        let ds = xor_dataset(1200, 27);
        let small = train(&ds, &params(8.0, 10.0, 8), &Engine::cpu_seq()).unwrap();
        let large = train(&ds, &params(8.0, 10.0, 63), &Engine::cpu_seq()).unwrap();
        let es = error_rate(&small.model.decision_batch(&ds, 2), &ds.y);
        let el = error_rate(&large.model.decision_batch(&ds, 2), &ds.y);
        assert!(el <= es + 0.01, "small {es} vs large {el}");
    }

    #[test]
    fn lowrank_operator_close_to_exact() {
        let ds = xor_dataset(900, 37);
        let exact = train(&ds, &params(8.0, 10.0, 31), &Engine::cpu_seq()).unwrap();
        let p = SpSvmParams {
            lowrank: Some(LowRankConfig::icf(96)),
            ..params(8.0, 10.0, 31)
        };
        let lr = train(&ds, &p, &Engine::cpu_seq()).unwrap();
        let e0 = error_rate(&exact.model.decision_batch(&ds, 2), &ds.y);
        let e1 = error_rate(&lr.model.decision_batch(&ds, 2), &ds.y);
        assert!(e1 < e0 + 0.05, "exact {e0} lowrank {e1}");
        assert!(lr.notes.iter().any(|(k, v)| k == "operator" && v == "icf"));
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = xor_dataset(500, 29);
        let p = params(8.0, 5.0, 24);
        let a = train(&ds, &p, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, &p, &Engine::cpu_seq()).unwrap();
        assert_eq!(a.model.coef, b.model.coef);
    }

    #[test]
    fn xla_engine_close_to_cpu() {
        let artifacts = crate::runtime::default_artifacts_dir();
        let Ok(rt) = crate::runtime::XlaRuntime::load(&artifacts) else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let ds = xor_dataset(1500, 31);
        let p = params(8.0, 10.0, 63);
        let cpu = train(&ds, &p, &Engine::cpu_seq()).unwrap();
        let xla = train(&ds, &p, &Engine::xla(std::sync::Arc::new(rt))).unwrap();
        let ec = error_rate(&cpu.model.decision_batch(&ds, 2), &ds.y);
        let ex = error_rate(&xla.model.decision_batch(&ds, 2), &ds.y);
        assert!((ec - ex).abs() < 0.03, "cpu {ec} vs xla {ex}");
    }
}
