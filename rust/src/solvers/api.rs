//! The unified training API: one object-safe contract over all five
//! solvers, plus the [`Trainer`] builder everything routes through.
//!
//! The paper's contribution is a *controlled comparison* of explicit
//! (SMO/WSS) and implicit (MU, Primal, SP-SVM) solvers, and Glasmachers'
//! "recipe" paper argues such comparisons are only meaningful under
//! shared budgets. This module is that discipline as a type system:
//!
//! * [`SolverDriver`] — the object-safe trait every solver implements.
//!   A driver reads everything environmental (dataset view, kernel,
//!   engine, shared cache, budget, observer) from a [`TrainCtx`]; its
//!   params struct holds only algorithm hyperparameters.
//! * [`Budget`] — one enforced stopping policy (iteration cap,
//!   wall-clock, target objective) replacing the per-solver magic caps
//!   that used to live in the coordinator's dispatch arms. Budgets are
//!   enforced by a [`BudgetMeter`] the solver ticks once per iteration;
//!   a budget-terminated run is flagged `capped` in the result notes.
//! * [`TrainObserver`] — per-iteration `(iter, objective, active,
//!   elapsed)` events, the raw material of time-vs-accuracy convergence
//!   curves. The default [`NullObserver`] disables per-iteration
//!   objective computation entirely, so an unobserved run costs exactly
//!   what it did before this API existed.
//! * [`Trainer`] — the builder:
//!
//! ```no_run
//! use std::time::Duration;
//! use wu_svm::data::Dataset;
//! use wu_svm::engine::Engine;
//! use wu_svm::kernel::KernelKind;
//! use wu_svm::solvers::spsvm::SpSvmParams;
//! use wu_svm::solvers::{Budget, SolverSpec, Trainer};
//!
//! # fn demo(train: &Dataset) -> anyhow::Result<()> {
//! let result = Trainer::new(SolverSpec::SpSvm(SpSvmParams {
//!         c: 1.0,
//!         max_basis: 255,
//!         ..Default::default()
//!     }))
//!     .kernel(KernelKind::Rbf { gamma: 0.5 })
//!     .engine(Engine::cpu_par(8))
//!     .budget(Budget::wall(Duration::from_secs(30)).max_iters(10_000))
//!     .train(train)?;
//! # let _ = result; Ok(())
//! # }
//! ```
//!
//! The legacy free functions (`smo::train`, `mu::train`, ...) survive
//! for one release as thin shims over this path; a conformance test
//! proves the two are bit-identical per solver.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::cache::SharedRowCache;
use crate::kernel::KernelKind;

use super::common::KernelRows;
use super::{lssvm, mu, primal, smo, spsvm, wss, TrainResult};

/// The paper's methodological axis: who parallelizes the heavy math.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Hand-decomposed dual solvers (SMO, WSS): we parallelize.
    Explicit,
    /// Dense-linear-algebra reformulations (MU, Primal, SP-SVM): the
    /// library (blocked GEMM substrate / XLA) parallelizes.
    Implicit,
}

impl Family {
    pub fn as_str(&self) -> &'static str {
        match self {
            Family::Explicit => "explicit",
            Family::Implicit => "implicit",
        }
    }
}

/// A shared stopping policy. Every field is optional; what a solver does
/// when a field is unset is the solver's documented default (e.g. SMO
/// falls back to [`Budget::smo_default_iters`]). The same `Budget` given
/// to two solvers means the same thing — the precondition for the
/// paper's controlled comparisons.
///
/// Semantics (all enforced by [`BudgetMeter::tick`], once per finished
/// iteration, so at least one iteration always runs):
/// * `max_iters` — hard cap on solver iterations (solver-specific unit:
///   SMO working-set steps, WSS/SP-SVM outer rounds, MU sweeps, Newton
///   steps).
/// * `wall` — wall-clock limit, checked after every iteration.
/// * `target_objective` — stop once the solver's running objective is
///   `<=` this value (objectives here are minimized). Under SMO
///   shrinking the running objective is the active-set approximation.
///
/// A run stopped by any of the three carries a `("capped", reason)`
/// note in its [`TrainResult`], with reason `iters`, `wall` or `target`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Budget {
    pub max_iters: Option<usize>,
    pub wall: Option<Duration>,
    pub target_objective: Option<f64>,
}

impl Budget {
    /// No limits beyond the solver defaults.
    pub fn none() -> Budget {
        Budget::default()
    }

    /// Wall-clock budget.
    pub fn wall(limit: Duration) -> Budget {
        Budget { wall: Some(limit), ..Budget::default() }
    }

    /// Iteration budget.
    pub fn iters(n: usize) -> Budget {
        Budget { max_iters: Some(n), ..Budget::default() }
    }

    /// Builder: set the iteration cap.
    pub fn max_iters(mut self, n: usize) -> Budget {
        self.max_iters = Some(n);
        self
    }

    /// Builder: set the wall-clock limit.
    pub fn wall_clock(mut self, limit: Duration) -> Budget {
        self.wall = Some(limit);
        self
    }

    /// Builder: stop once the running objective reaches `target`.
    pub fn target_objective(mut self, target: f64) -> Budget {
        self.target_objective = Some(target);
        self
    }

    /// Default SMO iteration cap for an `n`-row problem: far past
    /// typical convergence (~2-5n), it only trips on pathological
    /// (huge-C) configurations. Formerly a magic `50 * n` in the
    /// coordinator's SMO arm.
    pub fn smo_default_iters(n: usize) -> usize {
        50 * n
    }

    /// Default WSS outer-round cap (formerly the coordinator's
    /// `10 * n`).
    pub fn wss_default_iters(n: usize) -> usize {
        10 * n
    }
}

/// Why a [`BudgetMeter`] stopped a run early.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    Iters,
    Wall,
    Target,
}

impl StopReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            StopReason::Iters => "iters",
            StopReason::Wall => "wall",
            StopReason::Target => "target",
        }
    }
}

/// One per-iteration observation (the row of a convergence curve).
#[derive(Debug, Clone)]
pub struct IterEvent {
    /// Driver name (`"smo"`, `"spsvm"`, ...).
    pub solver: &'static str,
    /// 1-based iteration count in the solver's own unit.
    pub iter: usize,
    /// Running objective (solver-specific convention; under SMO
    /// shrinking this is the active-set approximation of the dual).
    pub objective: f64,
    /// Size of the solver's working structure: SMO/WSS active or
    /// support set, SP-SVM basis, MU support set, Primal active hinges.
    pub active: usize,
    /// Wall time since training started.
    pub elapsed: Duration,
}

/// Receiver of per-iteration events. Implementations must be cheap and
/// thread-safe — solvers may call from the training thread every
/// iteration.
pub trait TrainObserver: Send + Sync {
    fn on_iter(&self, ev: &IterEvent);

    /// Observers that return `false` (the [`NullObserver`]) let solvers
    /// skip per-iteration objective computation entirely, keeping the
    /// unobserved hot loop at its pre-API cost.
    fn enabled(&self) -> bool {
        true
    }

    /// Whether this observer wants the event for iteration `iter`
    /// (1-based). Decimating observers ([`TraceObserver::every`])
    /// return `false` for dropped iterations so the meter skips both
    /// the event *and* the per-iteration objective computation.
    fn wants(&self, iter: usize) -> bool {
        let _ = iter;
        true
    }
}

/// The default observer: drops every event, reports itself disabled.
pub struct NullObserver;

impl TrainObserver for NullObserver {
    fn on_iter(&self, _ev: &IterEvent) {}

    fn enabled(&self) -> bool {
        false
    }
}

static NULL_OBSERVER: NullObserver = NullObserver;

/// A recording observer: collects (decimated) events for convergence
/// plots. `every = 1` keeps everything; `every = k` keeps iterations
/// 1, k, 2k, ... (the first event is always kept so short runs still
/// produce a curve).
pub struct TraceObserver {
    every: usize,
    points: Mutex<Vec<IterEvent>>,
}

impl TraceObserver {
    pub fn new() -> TraceObserver {
        TraceObserver::every(1)
    }

    pub fn every(every: usize) -> TraceObserver {
        TraceObserver { every: every.max(1), points: Mutex::new(Vec::new()) }
    }

    /// Drain the recorded events.
    pub fn take(&self) -> Vec<IterEvent> {
        std::mem::take(&mut *self.points.lock().unwrap())
    }

    /// Render the trace as `iter\tobjective\tactive\telapsed_ms` lines
    /// (with header) without draining it.
    pub fn to_tsv(&self) -> String {
        let pts = self.points.lock().unwrap();
        let mut out = String::from("iter\tobjective\tactive\telapsed_ms\n");
        for p in pts.iter() {
            out.push_str(&format!(
                "{}\t{:.6}\t{}\t{:.3}\n",
                p.iter,
                p.objective,
                p.active,
                p.elapsed.as_secs_f64() * 1e3
            ));
        }
        out
    }
}

impl Default for TraceObserver {
    fn default() -> Self {
        TraceObserver::new()
    }
}

impl TrainObserver for TraceObserver {
    fn on_iter(&self, ev: &IterEvent) {
        if ev.iter == 1 || ev.iter % self.every == 0 {
            self.points.lock().unwrap().push(ev.clone());
        }
    }

    fn wants(&self, iter: usize) -> bool {
        iter == 1 || iter % self.every == 0
    }
}

/// Per-run budget enforcement + event emission. Created from the ctx
/// ([`TrainCtx::meter`]); the solver calls [`BudgetMeter::tick`] once
/// after each finished iteration and stops when it returns `false`.
pub struct BudgetMeter<'a> {
    solver: &'static str,
    observer: &'a dyn TrainObserver,
    events: bool,
    start: Instant,
    cap: usize,
    wall: Option<Duration>,
    target: Option<f64>,
    iters: usize,
    stop: Option<StopReason>,
}

impl<'a> BudgetMeter<'a> {
    pub fn new(
        solver: &'static str,
        budget: &Budget,
        observer: &'a dyn TrainObserver,
        default_cap: usize,
    ) -> BudgetMeter<'a> {
        BudgetMeter {
            solver,
            observer,
            events: observer.enabled(),
            start: Instant::now(),
            cap: budget.max_iters.unwrap_or(default_cap),
            wall: budget.wall,
            target: budget.target_objective,
            iters: 0,
            stop: None,
        }
    }

    /// Record one finished iteration. `stats` produces the running
    /// `(objective, active)` pair and is only evaluated when someone
    /// needs it (an enabled observer that wants this iteration, or a
    /// target-objective budget) — the unobserved, untargeted path never
    /// pays for it, and a decimating observer only pays on sampled
    /// iterations. Returns `false` when the budget is exhausted and the
    /// solver must stop.
    pub fn tick(&mut self, stats: impl FnOnce() -> (f64, usize)) -> bool {
        self.iters += 1;
        let sampled = self.events && self.observer.wants(self.iters);
        let (objective, active) = if sampled || self.target.is_some() {
            stats()
        } else {
            (f64::NAN, 0)
        };
        let elapsed = if sampled || self.wall.is_some() {
            self.start.elapsed()
        } else {
            Duration::ZERO
        };
        if sampled {
            self.observer.on_iter(&IterEvent {
                solver: self.solver,
                iter: self.iters,
                objective,
                active,
                elapsed,
            });
        }
        if self.iters >= self.cap {
            self.stop = Some(StopReason::Iters);
            return false;
        }
        if self.wall.is_some_and(|w| elapsed >= w) {
            self.stop = Some(StopReason::Wall);
            return false;
        }
        if self.target.is_some_and(|t| objective <= t) {
            self.stop = Some(StopReason::Target);
            return false;
        }
        true
    }

    /// Iterations recorded so far (the value solvers report).
    pub fn iterations(&self) -> usize {
        self.iters
    }

    /// Whether (and why) the budget stopped the run.
    pub fn stopped_by(&self) -> Option<StopReason> {
        self.stop
    }

    /// Append the budget verdict (`capped` note) to a result.
    pub fn annotate(&self, res: &mut TrainResult) {
        if let Some(reason) = self.stop {
            res.note("capped", reason.as_str().to_string());
        }
    }
}

/// Everything environmental a solver needs, in one borrow: the dataset
/// view, the kernel, the engine that executes heavy ops (and sizes
/// explicit scan parallelism via [`Engine::threads`]), an optional
/// shared kernel-row cache (+ group id, for concurrent OvO pair
/// subproblems under one byte budget), the stopping [`Budget`] and the
/// iteration observer.
pub struct TrainCtx<'a> {
    pub ds: &'a Dataset,
    pub kind: KernelKind,
    pub engine: &'a Engine,
    pub cache: Option<(&'a Arc<SharedRowCache>, u64)>,
    pub budget: &'a Budget,
    pub observer: &'a dyn TrainObserver,
    /// Warm-start dual variables, one per dataset row (cascade layers
    /// pass the previous layer's alphas). Dual decomposition solvers
    /// (SMO/WSS) clip them to the box and rebuild the gradient from
    /// scratch; solvers without box-constrained duals ignore the field
    /// and note `warm_start = rejected` in their result. A zero vector
    /// is bit-identical to a cold start.
    pub initial_alpha: Option<&'a [f32]>,
}

impl<'a> TrainCtx<'a> {
    /// A cached kernel-row provider: the ctx's shared cache when one was
    /// supplied, else a private cache of `cache_mb` megabytes.
    pub fn kernel_rows(&self, cache_mb: usize) -> Result<KernelRows> {
        match self.cache {
            Some((cache, group)) => KernelRows::with_shared_cache(
                self.ds,
                self.kind,
                self.engine.clone(),
                cache.clone(),
                group,
            ),
            None => KernelRows::new(self.ds, self.kind, self.engine.clone(), cache_mb),
        }
    }

    /// Budget enforcement for this run; `default_cap` is the solver's
    /// iteration cap when the budget sets none.
    pub fn meter(&self, solver: &'static str, default_cap: usize) -> BudgetMeter<'a> {
        BudgetMeter::new(solver, self.budget, self.observer, default_cap)
    }
}

/// The object-safe training contract all five solvers implement. The
/// implementing type is the solver's hyperparameter struct; everything
/// environmental comes from the [`TrainCtx`].
pub trait SolverDriver: Send + Sync {
    /// Stable short name (`"smo"`, `"wss"`, `"mu"`, `"primal"`,
    /// `"spsvm"`, `"lssvm"`).
    fn name(&self) -> &str;

    /// Which side of the paper's explicit/implicit axis this solver is.
    fn family(&self) -> Family;

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult>;
}

/// A solver choice with its hyperparameters — what [`Trainer::new`]
/// takes, and the one remaining place per-solver dispatch happens.
#[derive(Debug, Clone)]
pub enum SolverSpec {
    Smo(smo::SmoParams),
    Wss(wss::WssParams),
    Mu(mu::MuParams),
    Primal(primal::PrimalParams),
    SpSvm(spsvm::SpSvmParams),
    LsSvm(lssvm::LsSvmParams),
    /// The cascade meta-solver: shard, train the wrapped inner spec per
    /// shard, hierarchically merge SV unions warm-started from the
    /// previous layer, verify global KKT (see [`crate::cascade`]).
    Cascade(crate::cascade::CascadeParams),
}

impl SolverSpec {
    pub fn driver(&self) -> &dyn SolverDriver {
        match self {
            SolverSpec::Smo(p) => p,
            SolverSpec::Wss(p) => p,
            SolverSpec::Mu(p) => p,
            SolverSpec::Primal(p) => p,
            SolverSpec::SpSvm(p) => p,
            SolverSpec::LsSvm(p) => p,
            SolverSpec::Cascade(p) => p,
        }
    }

    pub fn name(&self) -> &str {
        self.driver().name()
    }

    pub fn family(&self) -> Family {
        self.driver().family()
    }
}

/// Builder over the [`SolverDriver`] contract: choose a solver, then an
/// engine, kernel, budget, shared cache and observer, then
/// [`Trainer::train`]. Defaults: `cpu-seq` engine, RBF kernel with
/// `gamma = 1`, empty budget (solver default caps), no shared cache,
/// [`NullObserver`].
///
/// `Trainer` is `Clone`, so one configured instance can fan out across
/// OvO pair subproblems (see `OvoModel::train_with`) with only the
/// cache group differing.
#[derive(Clone)]
pub struct Trainer {
    spec: SolverSpec,
    engine: Engine,
    kind: KernelKind,
    budget: Budget,
    cache: Option<(Arc<SharedRowCache>, u64)>,
    observer: Option<Arc<dyn TrainObserver>>,
    initial_alpha: Option<Arc<Vec<f32>>>,
}

impl Trainer {
    pub fn new(spec: SolverSpec) -> Trainer {
        Trainer {
            spec,
            engine: Engine::cpu_seq(),
            kind: KernelKind::Rbf { gamma: 1.0 },
            budget: Budget::default(),
            cache: None,
            observer: None,
            initial_alpha: None,
        }
    }

    /// Engine that executes the heavy ops (and sizes scan parallelism).
    pub fn engine(mut self, engine: Engine) -> Trainer {
        self.engine = engine;
        self
    }

    /// Kernel function. Solvers that are RBF-only (SP-SVM) reject other
    /// kinds at [`Trainer::train`] time.
    pub fn kernel(mut self, kind: KernelKind) -> Trainer {
        self.kind = kind;
        self
    }

    /// Stopping policy (see [`Budget`]).
    pub fn budget(mut self, budget: Budget) -> Trainer {
        self.budget = budget;
        self
    }

    /// Share a kernel-row cache (and its byte budget) with other
    /// concurrent trainers; `group` keys this trainer's rows so views of
    /// different datasets never alias.
    pub fn shared_cache(mut self, cache: Arc<SharedRowCache>, group: u64) -> Trainer {
        self.cache = Some((cache, group));
        self
    }

    /// Receive per-iteration [`IterEvent`]s (convergence curves).
    pub fn observer(mut self, observer: Arc<dyn TrainObserver>) -> Trainer {
        self.observer = Some(observer);
        self
    }

    /// Warm-start the dual solvers from per-row alphas (length must
    /// equal the training set's row count; see
    /// [`TrainCtx::initial_alpha`] for solver semantics).
    pub fn initial_alpha(mut self, alpha: Vec<f32>) -> Trainer {
        self.initial_alpha = Some(Arc::new(alpha));
        self
    }

    /// Worker threads the configured engine hand-parallelizes over.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// The configured solver's stable name.
    pub fn solver_name(&self) -> &str {
        self.spec.name()
    }

    /// Train a binary problem. Multiclass datasets go through
    /// `OvoModel::train_with`, which fans this trainer out per pair.
    pub fn train(&self, ds: &Dataset) -> Result<TrainResult> {
        anyhow::ensure!(
            !ds.is_multiclass(),
            "Trainer::train solves binary problems; use OvoModel::train_with for one-vs-one"
        );
        let observer: &dyn TrainObserver = match &self.observer {
            Some(o) => o.as_ref(),
            None => &NULL_OBSERVER,
        };
        if let Some(a) = &self.initial_alpha {
            anyhow::ensure!(
                a.len() == ds.n,
                "initial_alpha has {} entries for a {}-row dataset",
                a.len(),
                ds.n
            );
        }
        let ctx = TrainCtx {
            ds,
            kind: self.kind,
            engine: &self.engine,
            cache: self.cache.as_ref().map(|(c, g)| (c, *g)),
            budget: &self.budget,
            observer,
            initial_alpha: self.initial_alpha.as_ref().map(|a| a.as_slice()),
        };
        let driver = self.spec.driver();
        // root span: one "train/<solver>" interval covering the whole
        // call, under which the solver's phase laps nest
        let _sp = crate::trace::span(match &self.spec {
            SolverSpec::Smo(_) => "train/smo",
            SolverSpec::Wss(_) => "train/wss",
            SolverSpec::Mu(_) => "train/mu",
            SolverSpec::Primal(_) => "train/primal",
            SolverSpec::SpSvm(_) => "train/spsvm",
            SolverSpec::LsSvm(_) => "train/lssvm",
            SolverSpec::Cascade(_) => "train/cascade",
        });
        let mut res = driver.train(&ctx)?;
        res.note("family", driver.family().as_str().to_string());
        res.note("simd_backend", crate::linalg::simd::active().name().to_string());
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_builders_compose() {
        let b = Budget::wall(Duration::from_secs(30)).max_iters(100).target_objective(-5.0);
        assert_eq!(b.max_iters, Some(100));
        assert_eq!(b.wall, Some(Duration::from_secs(30)));
        assert_eq!(b.target_objective, Some(-5.0));
        assert_eq!(Budget::none(), Budget::default());
        assert_eq!(Budget::iters(7).max_iters, Some(7));
        assert_eq!(Budget::smo_default_iters(100), 5000);
        assert_eq!(Budget::wss_default_iters(100), 1000);
    }

    #[test]
    fn meter_enforces_iteration_cap() {
        let budget = Budget::iters(3);
        let mut m = BudgetMeter::new("t", &budget, &NULL_OBSERVER, 1000);
        assert!(m.tick(|| (0.0, 0)));
        assert!(m.tick(|| (0.0, 0)));
        assert!(!m.tick(|| (0.0, 0)));
        assert_eq!(m.iterations(), 3);
        assert_eq!(m.stopped_by(), Some(StopReason::Iters));
        let mut res = TrainResult {
            model: crate::model::SvmModel {
                kernel: KernelKind::Linear,
                vectors: vec![],
                d: 0,
                coef: vec![],
                bias: 0.0,
                solver: "t".into(),
            },
            iterations: 3,
            objective: 0.0,
            alpha: None,
            notes: vec![],
        };
        m.annotate(&mut res);
        assert!(res.notes.iter().any(|(k, v)| k == "capped" && v == "iters"));
    }

    #[test]
    fn meter_uses_default_cap_when_budget_is_empty() {
        let budget = Budget::default();
        let mut m = BudgetMeter::new("t", &budget, &NULL_OBSERVER, 2);
        assert!(m.tick(|| (0.0, 0)));
        assert!(!m.tick(|| (0.0, 0)));
        assert_eq!(m.stopped_by(), Some(StopReason::Iters));
    }

    #[test]
    fn meter_stops_on_target_objective() {
        let budget = Budget::default().target_objective(-1.0);
        let mut m = BudgetMeter::new("t", &budget, &NULL_OBSERVER, 1000);
        assert!(m.tick(|| (-0.5, 1)));
        assert!(!m.tick(|| (-1.5, 1)));
        assert_eq!(m.stopped_by(), Some(StopReason::Target));
    }

    #[test]
    fn meter_stops_on_wall_clock() {
        let budget = Budget::wall(Duration::ZERO);
        let mut m = BudgetMeter::new("t", &budget, &NULL_OBSERVER, 1000);
        assert!(!m.tick(|| (0.0, 0)));
        assert_eq!(m.stopped_by(), Some(StopReason::Wall));
    }

    #[test]
    fn meter_skips_stats_without_observer_or_target() {
        let budget = Budget::iters(10);
        let mut m = BudgetMeter::new("t", &budget, &NULL_OBSERVER, 1000);
        // the stats closure must not run on the unobserved path
        assert!(m.tick(|| panic!("stats computed needlessly")));
    }

    #[test]
    fn trace_observer_records_and_decimates() {
        let obs = TraceObserver::every(10);
        let budget = Budget::iters(25);
        let mut m = BudgetMeter::new("t", &budget, &obs, 1000);
        for _ in 0..25 {
            let _ = m.tick(|| (-1.0, 7));
        }
        let pts = obs.take();
        // kept: 1 (always), 10, 20
        assert_eq!(pts.iter().map(|p| p.iter).collect::<Vec<_>>(), vec![1, 10, 20]);
        assert!(pts.iter().all(|p| p.objective == -1.0 && p.active == 7));
        assert_eq!(pts[0].solver, "t");
        assert!(obs.take().is_empty(), "take drains");
    }

    #[test]
    fn trace_observer_tsv_has_header_and_rows() {
        let obs = TraceObserver::new();
        obs.on_iter(&IterEvent {
            solver: "t",
            iter: 1,
            objective: -2.5,
            active: 3,
            elapsed: Duration::from_millis(4),
        });
        let tsv = obs.to_tsv();
        assert!(tsv.starts_with("iter\tobjective\tactive\telapsed_ms\n"));
        assert!(tsv.contains("1\t-2.500000\t3\t4.000"));
    }

    #[test]
    fn solver_spec_names_and_families() {
        let specs = [
            (SolverSpec::Smo(Default::default()), "smo", Family::Explicit),
            (SolverSpec::Wss(Default::default()), "wss", Family::Explicit),
            (SolverSpec::Mu(Default::default()), "mu", Family::Implicit),
            (SolverSpec::Primal(Default::default()), "primal", Family::Implicit),
            (SolverSpec::SpSvm(Default::default()), "spsvm", Family::Implicit),
            (SolverSpec::LsSvm(Default::default()), "lssvm", Family::Implicit),
            // cascade reports the wrapped solver's family (default smo)
            (SolverSpec::Cascade(Default::default()), "cascade", Family::Explicit),
        ];
        for (spec, name, family) in specs {
            assert_eq!(spec.name(), name);
            assert_eq!(spec.family(), family);
        }
    }

    #[test]
    fn trainer_rejects_multiclass_datasets() {
        let ds = Dataset::new_multiclass("t", 1, vec![0.0, 1.0, 2.0], vec![0, 1, 2]);
        let r = Trainer::new(SolverSpec::Smo(Default::default())).train(&ds);
        assert!(r.is_err());
    }
}
