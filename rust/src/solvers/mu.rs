//! Multiplicative-update SVM (Sha, Lin, Saul & Lee 2007) — exact implicit
//! reformulation, full kernel matrix.
//!
//! Solves min_a 1/2 a^T Q a - e^T a over 0 <= a <= C with the
//! nonnegative-QP multiplicative update
//!
//!   a_i <- a_i * (1 + sqrt(1 + 4 (Q+ a)_i (Q- a)_i)) / (2 (Q+ a)_i)
//!
//! (for linear coefficient b_i = -1), clipped to the box. Every iteration
//! is two dense GEMVs — maximally library-friendly, and served by the
//! blocked `linalg` substrate (DESIGN.md §GEMM) like the rest of the
//! implicit family — but the paper finds
//! (and we reproduce) that it is not competitive: it materializes
//! *two* n x n matrices (Q+ and Q-) and converges too slowly. It refuses
//! to run above a memory cap, which is the Table-1 "—" entry.
//!
//! Bias is omitted (the multiplicative update does not handle the
//! equality constraint); the RBF kernel makes that a benign relaxation,
//! matching Sha et al.'s own SVM experiments.

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::operator::{build as build_operator, ExactDense, KernelOperator, LowRankConfig};
use crate::kernel::KernelKind;
use crate::linalg::{gemv, Matrix};
use crate::model::SvmModel;

use super::api::{Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::TrainResult;

/// Multiplicative-update hyperparameters. Parallelism comes from the
/// ctx engine ([`crate::engine::Engine::threads`]), not from here.
#[derive(Debug, Clone)]
pub struct MuParams {
    pub c: f32,
    /// Default sweep cap when the ctx [`super::api::Budget`] sets none.
    pub max_iters: usize,
    /// Stop when the relative objective improvement falls below this.
    pub tol: f64,
    /// Refuse to materialize Q+/Q- beyond this many bytes (both count).
    pub max_kernel_bytes: usize,
    /// `Some` streams Q± off a low-rank kernel factor instead of the
    /// exact kernel. Q± still materialize (the MU memory wall stands —
    /// that is the paper's point about this method); only the kernel
    /// source changes.
    pub lowrank: Option<LowRankConfig>,
}

impl Default for MuParams {
    fn default() -> Self {
        MuParams {
            c: 1.0,
            max_iters: 2000,
            tol: 1e-7,
            max_kernel_bytes: 2 << 30, // 2 GB
            lowrank: None,
        }
    }
}

impl SolverDriver for MuParams {
    fn name(&self) -> &str {
        "mu"
    }

    fn family(&self) -> Family {
        Family::Implicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// Legacy entry point — thin shim over the [`SolverDriver`] path (kept
/// for one release; prefer [`Trainer`]). Runs on the default-threads
/// cpu engine, matching the historical `MuParams::threads` default.
pub fn train(ds: &Dataset, kind: KernelKind, params: &MuParams) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Mu(params.clone()))
        .kernel(kind)
        .engine(Engine::cpu_par(crate::pool::default_threads()))
        .train(ds)
}

/// Train with multiplicative updates; parallelism from the ctx engine.
/// MU has no accelerator path: an xla engine falls back to the cpu
/// substrate, surfaced as an `engine_fallback` note.
fn train_ctx(ctx: &TrainCtx<'_>, params: &MuParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let kind = ctx.kind;
    let threads = ctx.engine.threads();
    let mut ph = crate::trace::phases();
    let n = ds.n;
    // wall clock starts before the O(n^2) kernel build — MU's dominant
    // cost — so wall budgets and IterEvent.elapsed cover all of it
    let mut meter = ctx.meter("mu", params.max_iters);
    // Q+ and Q- both materialize whatever the kernel source: the MU
    // memory wall is 2·n² and the cap applies to it directly.
    let need = 2 * n * n * 4;
    if need > params.max_kernel_bytes {
        return Err(anyhow!(
            "mu needs {need} bytes for Q+/Q- > cap {} — the O(n^2) memory wall (n = {n})",
            params.max_kernel_bytes
        ));
    }
    // Kernel values arrive through the operator abstraction: the exact
    // materialized matrix by default (half the cap each for Q±), or a
    // low-rank G·Gᵀ factor when params ask for one.
    let op: Box<dyn KernelOperator + '_> = match params.lowrank {
        None => Box::new(ExactDense::build(&kind, ds, threads, params.max_kernel_bytes / 2)?),
        Some(cfg) => build_operator(&kind, ds, threads, Some(cfg))?,
    };
    let op_name = op.name();
    let op_bytes = op.memory_bytes();
    // Q = y y^T * K, split into positive and negative parts. Rows
    // stream through op.block in chunks; within a chunk the split runs
    // in parallel (rows are independent) like the GEMVs below.
    let mut qp = Matrix::zeros(n, n);
    let mut qm = Matrix::zeros(n, n);
    {
        let all: Vec<usize> = (0..n).collect();
        let chunk = 256.min(n);
        let mut buf = vec![0.0f32; chunk * n];
        let y = &ds.y;
        let mut start = 0;
        while start < n {
            let m = chunk.min(n - start);
            op.block(&all[start..start + m], &all, &mut buf[..m * n]);
            let qp_ptr = crate::pool::SendPtr::new(qp.data.as_mut_ptr());
            let qm_ptr = crate::pool::SendPtr::new(qm.data.as_mut_ptr());
            let bufref = &buf;
            crate::pool::parallel_for(threads, m, 8, |r| {
                let i = start + r;
                let yi = y[i];
                let krow = &bufref[r * n..(r + 1) * n];
                // SAFETY: row i of each matrix written by exactly one task.
                let qpr = unsafe { std::slice::from_raw_parts_mut(qp_ptr.get().add(i * n), n) };
                let qmr = unsafe { std::slice::from_raw_parts_mut(qm_ptr.get().add(i * n), n) };
                for j in 0..n {
                    let q = yi * y[j] * krow[j];
                    if q >= 0.0 {
                        qpr[j] = q;
                    } else {
                        qmr[j] = -q;
                    }
                }
            });
            start += m;
        }
    }
    drop(op);
    ph.lap("mu/kernel");

    let c = params.c;
    let mut a = vec![0.5f32 * c.min(1.0); n];
    let mut qpa = vec![0.0f32; n];
    let mut qma = vec![0.0f32; n];
    let mut last_obj = f64::INFINITY;
    loop {
        gemv(threads, &qp, &a, &mut qpa);
        gemv(threads, &qm, &a, &mut qma);
        // objective 1/2 a^T Q a - e^T a, Qa = qpa - qma
        let obj: f64 = (0..n)
            .map(|i| 0.5 * (a[i] * (qpa[i] - qma[i])) as f64 - a[i] as f64)
            .sum();
        for i in 0..n {
            let denom = (2.0 * qpa[i]).max(1e-12);
            let disc = 1.0 + 4.0 * qpa[i] * qma[i];
            let factor = (1.0 + disc.sqrt()) / denom;
            a[i] = (a[i] * factor).clamp(0.0, c);
        }
        let done = (last_obj - obj).abs() < params.tol * obj.abs().max(1.0);
        last_obj = obj;
        let cont = meter.tick(|| (obj, a.iter().filter(|&&v| v > 1e-8).count()));
        if done || !cont {
            break;
        }
    }
    ph.lap("mu/iterate");

    let sv: Vec<usize> = (0..n).filter(|&i| a[i] > 1e-8).collect();
    let vectors = ds.gather_rows(&sv);
    let coef: Vec<f32> = sv.iter().map(|&i| a[i] * ds.y[i]).collect();
    ph.lap("mu/finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias: 0.0,
        solver: "mu".into(),
    };
    let mut res = TrainResult {
        model,
        iterations: meter.iterations(),
        objective: last_obj,
        alpha: None,
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", "rejected (mu iterates from a strictly interior point)".into());
    }
    if ctx.engine.is_xla() {
        crate::trace::count(crate::trace::Counter::EngineFallbacks, 1);
        res.note("engine_fallback", "cpu (mu has no accelerator path)".to_string());
    }
    res.note("n_sv", sv.len().to_string());
    res.note("kernel_bytes", (2 * n * n * 4).to_string());
    res.note("operator", op_name.to_string());
    res.note("operator_bytes", op_bytes.to_string());
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::metrics::error_rate;
    use crate::solvers::smo;

    fn blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let pos = rng.bernoulli(0.5);
            let (cx, cy) = if pos { (0.7, 0.7) } else { (0.3, 0.3) };
            x.push(cx + 0.08 * rng.gaussian_f32());
            x.push(cy + 0.08 * rng.gaussian_f32());
            y.push(if pos { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("blobs", 2, x, y)
    }

    #[test]
    fn separates_blobs() {
        let ds = blobs(200, 1);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &MuParams { c: 10.0, ..Default::default() },
        )
        .unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.03);
    }

    #[test]
    fn memory_cap_refusal() {
        let ds = blobs(500, 2);
        let err = train(
            &ds,
            KernelKind::Rbf { gamma: 1.0 },
            &MuParams { max_kernel_bytes: 1024, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("memory wall"));
    }

    #[test]
    fn converges_slower_than_smo_per_iteration_count() {
        // the paper's observation: MU needs many more (albeit parallel)
        // iterations than decomposition needs working-set updates to reach
        // a similar objective region.
        let ds = blobs(150, 3);
        let kind = KernelKind::Rbf { gamma: 4.0 };
        let sp = smo::SmoParams { c: 1.0, ..Default::default() };
        let s = smo::train(&ds, kind, &sp, &Engine::cpu_seq()).unwrap();
        let mp = MuParams { c: 1.0, max_iters: 400, ..Default::default() };
        let m = train(&ds, kind, &mp).unwrap();
        // MU drops the equality constraint (no bias), so its optimum can
        // differ from SMO's in either direction — but it must land in the
        // same objective region...
        let rel = (m.objective - s.objective).abs() / s.objective.abs().max(1.0);
        assert!(rel < 0.5, "mu {} smo {}", m.objective, s.objective);
        // ...and it burns through many full-matrix iterations doing so
        assert!(m.iterations > 50);
    }

    #[test]
    fn lowrank_operator_close_to_exact() {
        let ds = blobs(150, 5);
        let kind = KernelKind::Rbf { gamma: 4.0 };
        let base = MuParams { c: 10.0, max_iters: 400, ..Default::default() };
        let exact = train(&ds, kind, &base).unwrap();
        let lr = train(
            &ds,
            kind,
            &MuParams { lowrank: Some(LowRankConfig::icf(40)), ..base },
        )
        .unwrap();
        let m_exact = exact.model.decision_batch(&ds, 2);
        let m_lr = lr.model.decision_batch(&ds, 2);
        let e0 = error_rate(&m_exact, &ds.y);
        let e1 = error_rate(&m_lr, &ds.y);
        assert!(e1 < e0 + 0.03, "exact {e0} lowrank {e1}");
        assert!(lr.notes.iter().any(|(k, v)| k == "operator" && v == "icf"));
    }

    #[test]
    fn alphas_stay_in_box() {
        let ds = blobs(80, 4);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &MuParams { c: 0.5, ..Default::default() },
        )
        .unwrap();
        assert!(r.model.coef.iter().all(|&v| v.abs() <= 0.5 + 1e-5));
    }
}
