//! Sequential Minimal Optimization with second-order working-set
//! selection — the LibSVM algorithm (Chang & Lin 2011; Platt 1998),
//! reimplemented from scratch.
//!
//! Solves the dual (paper eq. 2):
//!   min_a  1/2 a^T Q a - e^T a,   0 <= a_i <= C,  y^T a = 0,
//! with Q_ij = y_i y_j k(x_i, x_j).
//!
//! The engine choice reproduces three Table-1 configurations:
//! * `cpu-seq`  — single-core LibSVM;
//! * `cpu-par`  — LibSVM+OpenMP: kernel rows hand-threaded *and* the
//!   per-iteration O(n) work (WSS i/j scans, gradient maintenance)
//!   decomposed into chunked parallel reductions over the pool — the
//!   paper's "most basic method of speedup", 5-8x on twelve cores. The
//!   reductions combine per-chunk partials in chunk order, so every
//!   thread count (including 1) selects identical working sets and
//!   reaches an identical objective.
//! * `xla`      — GPU SVM (kernel rows offloaded to the accelerator
//!   library one working pair at a time; high per-call overhead, which is
//!   exactly the paper's observation about explicit GPU SMO).
//!
//! On top of either engine sits LibSVM-style active-set **shrinking**
//! (`rust/DESIGN.md` §Shrinking): bounded variables that are strongly
//! KKT-satisfied leave the active set every `min(n, 1000)` iterations, so
//! the per-iteration scans touch only the surviving set; the gradient of
//! shrunk variables is reconstructed from cached kernel rows before any
//! final decision (convergence re-check, bias, objective).

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::cache::SharedRowCache;
use crate::kernel::KernelKind;
use crate::model::SvmModel;
use crate::pool::{self, SendPtr};

use super::api::{Budget, Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::common::{dual_objective, KernelRows};
use super::TrainResult;

const TAU: f64 = 1e-12;
/// Chunk size of the parallel WSS/gradient scans. Fixed (not derived from
/// the thread count) so chunk boundaries — and therefore tie-breaks — are
/// identical for every engine.
const SCAN_CHUNK: usize = 512;

/// SMO hyperparameters. Iteration/wall caps come from the ctx
/// [`Budget`] (default [`Budget::smo_default_iters`]), not from here.
#[derive(Debug, Clone)]
pub struct SmoParams {
    pub c: f32,
    /// KKT violation tolerance (LibSVM default 1e-3).
    pub eps: f64,
    /// Private kernel-row cache size when the ctx supplies none.
    pub cache_mb: usize,
    /// LibSVM-style active-set shrinking with gradient reconstruction.
    pub shrinking: bool,
    /// Threads for the WSS scans and gradient update; 0 derives the count
    /// from the engine. 1 reproduces the pre-shrinking seed behavior
    /// where only kernel-row fills were threaded.
    pub scan_threads: usize,
    /// Cache-aware WSS (`--cache-slack`, DESIGN.md §OOC): among I_up
    /// candidates whose violation is within `cache_slack * eps` of the
    /// maximum, prefer a row already resident in the kernel cache
    /// (counted by the `cache_preferred_picks` counter). `0.0` (the
    /// default) skips the probe entirely and is bit-identical to plain
    /// WSS2; values are clamped below 1 so a re-pick can never mask an
    /// unconverged problem.
    pub cache_slack: f64,
    /// Polishing phase (`--polish`): after convergence with shrinking,
    /// re-optimize the unshrunk problem over (mostly cached) rows until
    /// KKT-clean. Off (the default) is bit-identical to the phase not
    /// existing.
    pub polish: bool,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams {
            c: 1.0,
            eps: 1e-3,
            cache_mb: 512,
            shrinking: true,
            scan_threads: 0,
            cache_slack: 0.0,
            polish: false,
        }
    }
}

impl SolverDriver for SmoParams {
    fn name(&self) -> &str {
        "smo"
    }

    fn family(&self) -> Family {
        Family::Explicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

#[inline]
fn in_i_up(y: f64, a: f64, c: f64) -> bool {
    (y > 0.0 && a < c) || (y < 0.0 && a > 0.0)
}

#[inline]
fn in_i_low(y: f64, a: f64, c: f64) -> bool {
    (y > 0.0 && a > 0.0) || (y < 0.0 && a < c)
}

/// First half of WSS2: argmax over `active ∩ I_up` of `-y_t G_t`.
/// Ties go to the later index, matching the sequential scan.
fn select_i(
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &[f64],
    c: f64,
    threads: usize,
) -> (f64, usize) {
    pool::parallel_reduce(
        threads,
        active.len(),
        SCAN_CHUNK,
        |r| {
            let mut gmax = f64::NEG_INFINITY;
            let mut i_sel = usize::MAX;
            for p in r {
                let t = active[p];
                if in_i_up(y[t], alpha[t], c) {
                    let v = -y[t] * grad[t];
                    if v >= gmax {
                        gmax = v;
                        i_sel = t;
                    }
                }
            }
            (gmax, i_sel)
        },
        |a, b| if b.0 >= a.0 && b.1 != usize::MAX { b } else { a },
    )
    .unwrap_or((f64::NEG_INFINITY, usize::MAX))
}

/// Second half of WSS2: over `active ∩ I_low`, the maximal violation
/// partner `gmax2` and the second-order best `j` for the chosen `i`.
#[allow(clippy::too_many_arguments)]
fn select_j(
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &[f64],
    diag: &[f64],
    c: f64,
    gmax: f64,
    i_sel: usize,
    yi: f64,
    ki: &[f32],
    threads: usize,
) -> (f64, usize) {
    let red = pool::parallel_reduce(
        threads,
        active.len(),
        SCAN_CHUNK,
        |r| {
            let mut gmax2 = f64::NEG_INFINITY;
            let mut obj_min = f64::INFINITY;
            let mut j_sel = usize::MAX;
            for p in r {
                let t = active[p];
                if in_i_low(y[t], alpha[t], c) {
                    let v = y[t] * grad[t];
                    if v > gmax2 {
                        gmax2 = v;
                    }
                    let grad_diff = gmax + v;
                    if grad_diff > 0.0 {
                        // Q_ii + Q_tt - 2 Q_it with Q_it = y_i y_t K_it
                        let quad = (diag[i_sel] + diag[t]
                            - 2.0 * yi * y[t] * ki[t] as f64)
                            .max(TAU);
                        let obj = -(grad_diff * grad_diff) / quad;
                        if obj <= obj_min {
                            obj_min = obj;
                            j_sel = t;
                        }
                    }
                }
            }
            (gmax2, obj_min, j_sel)
        },
        |a, b| {
            let gmax2 = if b.0 > a.0 { b.0 } else { a.0 };
            if b.2 != usize::MAX && (a.2 == usize::MAX || b.1 <= a.1) {
                (gmax2, b.1, b.2)
            } else {
                (gmax2, a.1, a.2)
            }
        },
    )
    .unwrap_or((f64::NEG_INFINITY, f64::INFINITY, usize::MAX));
    (red.0, red.2)
}

/// Fused pass: apply the rank-2 gradient update over the active set and
/// select the next iteration's `i` in the same sweep (each `grad[t]` is
/// final before the `I_up` test reads it).
#[allow(clippy::too_many_arguments)]
fn update_grad_select_i(
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &mut [f64],
    ki: &[f32],
    kj: &[f32],
    yi: f64,
    yj: f64,
    dai: f64,
    daj: f64,
    c: f64,
    threads: usize,
) -> (f64, usize) {
    let grad_ptr = SendPtr::new(grad.as_mut_ptr());
    pool::parallel_reduce(
        threads,
        active.len(),
        SCAN_CHUNK,
        |r| {
            let mut gmax = f64::NEG_INFINITY;
            let mut i_sel = usize::MAX;
            for p in r {
                let t = active[p];
                // SAFETY: active indices are distinct, so each grad slot
                // is touched by exactly one chunk.
                let g = unsafe { &mut *grad_ptr.get().add(t) };
                *g += yi * y[t] * ki[t] as f64 * dai + yj * y[t] * kj[t] as f64 * daj;
                if in_i_up(y[t], alpha[t], c) {
                    let v = -y[t] * *g;
                    if v >= gmax {
                        gmax = v;
                        i_sel = t;
                    }
                }
            }
            (gmax, i_sel)
        },
        |a, b| if b.0 >= a.0 && b.1 != usize::MAX { b } else { a },
    )
    .unwrap_or((f64::NEG_INFINITY, usize::MAX))
}

/// Fresh `max over active ∩ I_low of y_t G_t` (shrinking heuristic input).
fn max_low_violation(
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &[f64],
    c: f64,
    threads: usize,
) -> f64 {
    pool::parallel_reduce(
        threads,
        active.len(),
        SCAN_CHUNK,
        |r| {
            let mut m = f64::NEG_INFINITY;
            for p in r {
                let t = active[p];
                if in_i_low(y[t], alpha[t], c) {
                    m = m.max(y[t] * grad[t]);
                }
            }
            m
        },
        f64::max,
    )
    .unwrap_or(f64::NEG_INFINITY)
}

/// LibSVM's `be_shrunk`: a bounded variable leaves the active set when it
/// is strongly on the right side of both maximal violations.
#[allow(clippy::too_many_arguments)]
fn be_shrunk(
    t: usize,
    y: &[f64],
    alpha: &[f64],
    grad: &[f64],
    c: f64,
    gmax1: f64,
    gmax2: f64,
) -> bool {
    if alpha[t] >= c {
        if y[t] > 0.0 {
            -grad[t] > gmax1
        } else {
            -grad[t] > gmax2
        }
    } else if alpha[t] <= 0.0 {
        if y[t] > 0.0 {
            grad[t] > gmax2
        } else {
            grad[t] > gmax1
        }
    } else {
        false
    }
}

/// Cache-aware re-pick of the first working-set variable
/// (`--cache-slack`): walk the active set in index order and take the
/// first `I_up` candidate whose violation is within `slack_abs` of the
/// maximum *and* whose kernel row is already cached. Sequential and
/// deterministic — the same candidate wins at every thread count. Falls
/// back to the true argmax when it is itself cached or nothing cheaper
/// qualifies. Returns the winner and its own violation value (the
/// second-order formula in [`select_j`] needs the actual `-y_i G_i`).
#[allow(clippy::too_many_arguments)]
fn repick_cached_i(
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &[f64],
    c: f64,
    gmax: f64,
    i_sel: usize,
    slack_abs: f64,
    rows: &KernelRows,
) -> (f64, usize) {
    if rows.is_cached(i_sel) {
        return (gmax, i_sel);
    }
    let thresh = gmax - slack_abs;
    for &t in active {
        if t != i_sel && in_i_up(y[t], alpha[t], c) {
            let v = -y[t] * grad[t];
            if v >= thresh && rows.is_cached(t) {
                crate::trace::count(crate::trace::Counter::CachePreferredPicks, 1);
                return (v, t);
            }
        }
    }
    (gmax, i_sel)
}

/// Analytic two-variable update (LibSVM Solver::Solve): move the pair
/// `(i, j)` along the equality constraint to the unconstrained optimum,
/// then clip to the box. `kij` is `K(i, j)`. Returns the alpha deltas
/// `(dai, daj)` for the gradient maintenance pass.
#[allow(clippy::too_many_arguments)]
fn pair_update(
    alpha: &mut [f64],
    grad: &[f64],
    diag: &[f64],
    i: usize,
    j: usize,
    yi: f64,
    yj: f64,
    kij: f64,
    c: f64,
) -> (f64, f64) {
    let old_ai = alpha[i];
    let old_aj = alpha[j];
    if yi != yj {
        let quad = (diag[i] + diag[j] + 2.0 * kij).max(TAU);
        let delta = (-grad[i] - grad[j]) / quad;
        let diff = alpha[i] - alpha[j];
        alpha[i] += delta;
        alpha[j] += delta;
        if diff > 0.0 {
            if alpha[j] < 0.0 {
                alpha[j] = 0.0;
                alpha[i] = diff;
            }
        } else if alpha[i] < 0.0 {
            alpha[i] = 0.0;
            alpha[j] = -diff;
        }
        if diff > 0.0 {
            if alpha[i] > c {
                alpha[i] = c;
                alpha[j] = c - diff;
            }
        } else if alpha[j] > c {
            alpha[j] = c;
            alpha[i] = c + diff;
        }
    } else {
        let quad = (diag[i] + diag[j] - 2.0 * kij).max(TAU);
        let delta = (grad[i] - grad[j]) / quad;
        let sum = alpha[i] + alpha[j];
        alpha[i] -= delta;
        alpha[j] += delta;
        if sum > c {
            if alpha[i] > c {
                alpha[i] = c;
                alpha[j] = sum - c;
            }
        } else if alpha[j] < 0.0 {
            alpha[j] = 0.0;
            alpha[i] = sum;
        }
        if sum > c {
            if alpha[j] > c {
                alpha[j] = c;
                alpha[i] = sum - c;
            }
        } else if alpha[i] < 0.0 {
            alpha[i] = 0.0;
            alpha[j] = sum;
        }
    }
    (alpha[i] - old_ai, alpha[j] - old_aj)
}

/// Recompute the gradient of every index *not* in `active` from scratch:
/// `G_t = -1 + y_t * sum_j alpha_j y_j K(j, t)`, streaming one (usually
/// cached) kernel row per nonzero alpha — K is symmetric, so row j
/// provides the K(j, t) column entries.
fn reconstruct_gradient(
    rows: &mut KernelRows,
    ds: &Dataset,
    active: &[usize],
    y: &[f64],
    alpha: &[f64],
    grad: &mut [f64],
    threads: usize,
) -> Result<()> {
    let n = ds.n;
    if active.len() == n {
        return Ok(());
    }
    let mut is_active = vec![false; n];
    for &t in active {
        is_active[t] = true;
    }
    let inactive: Vec<usize> = (0..n).filter(|&t| !is_active[t]).collect();
    for &t in &inactive {
        grad[t] = -1.0;
    }
    for j in 0..n {
        if alpha[j] == 0.0 {
            continue;
        }
        let kj = rows.get(ds, j)?;
        let coef = alpha[j] * y[j];
        let grad_ptr = SendPtr::new(grad.as_mut_ptr());
        let inact = &inactive;
        let kj_ref = &kj;
        pool::parallel_for(threads, inact.len(), SCAN_CHUNK, |p| {
            let t = inact[p];
            // SAFETY: inactive indices are distinct.
            unsafe { *grad_ptr.get().add(t) += coef * y[t] * kj_ref[t] as f64 };
        });
    }
    Ok(())
}

/// Legacy entry point — thin shim over the [`SolverDriver`] path (kept
/// for one release; prefer [`Trainer`]).
pub fn train(
    ds: &Dataset,
    kind: KernelKind,
    params: &SmoParams,
    engine: &Engine,
) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Smo(params.clone()))
        .kernel(kind)
        .engine(engine.clone())
        .train(ds)
}

/// Legacy shared-cache entry point — thin shim over [`Trainer`] with
/// [`Trainer::shared_cache`] (kept for one release).
pub fn train_cached(
    ds: &Dataset,
    kind: KernelKind,
    params: &SmoParams,
    engine: &Engine,
    cache: Arc<SharedRowCache>,
    cache_group: u64,
) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Smo(params.clone()))
        .kernel(kind)
        .engine(engine.clone())
        .shared_cache(cache, cache_group)
        .train(ds)
}

/// Train a binary SVM with SMO; kernel, engine, cache, budget and
/// observer all come from the ctx.
fn train_ctx(ctx: &TrainCtx<'_>, params: &SmoParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let kind = ctx.kind;
    let engine = ctx.engine;
    let mut ph = crate::trace::phases();
    let n = ds.n;
    let c = params.c as f64;
    // slack < 1 guarantees a re-picked i still finds a positive-gain j
    // whenever the true violation exceeds eps (see repick_cached_i)
    let cache_slack = params.cache_slack.clamp(0.0, 0.95);
    // the meter's wall clock starts before any setup work so budgets
    // and IterEvent.elapsed cover the whole training call
    let mut meter = ctx.meter("smo", Budget::smo_default_iters(n));
    let mut rows = ctx.kernel_rows(params.cache_mb)?;
    let scan_threads = if params.scan_threads > 0 {
        params.scan_threads
    } else {
        engine.threads()
    };
    ph.lap("smo/setup");

    let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
    let mut alpha = vec![0.0f64; n];
    // G_i = (Q alpha)_i - 1; alpha = 0 -> G = -1.
    let mut grad = vec![-1.0f64; n];
    // Warm start (cascade layers): clip the supplied alphas to the box
    // and rebuild the gradient from scratch — stale g must never leak
    // in, and the shrink state below starts fresh. A zero vector leaves
    // alpha = 0 and skips the rebuild, reproducing the cold start
    // bit-for-bit.
    let mut warm = false;
    if let Some(a0) = ctx.initial_alpha {
        for (t, &a) in a0.iter().enumerate() {
            alpha[t] = (a as f64).clamp(0.0, c);
        }
        warm = alpha.iter().any(|&a| a != 0.0);
        if warm {
            reconstruct_gradient(&mut rows, ds, &[], &y, &alpha, &mut grad, scan_threads)?;
            ph.lap("smo/warmstart");
        }
    }
    let diag: Vec<f64> = rows.diag.iter().map(|&v| v as f64).collect();

    let mut active: Vec<usize> = (0..n).collect();
    let shrink_interval = n.clamp(1, 1000);
    let mut since_shrink = 0usize;
    let mut unshrunk_once = false;
    let mut shrink_events = 0usize;

    // (gmax, i) carried over from the fused update pass of the previous
    // iteration; None forces a standalone i-scan.
    let mut sel: Option<(f64, usize)> = None;
    loop {
        // --- periodic shrinking (LibSVM do_shrinking) ---
        if params.shrinking && since_shrink >= shrink_interval {
            since_shrink = 0;
            let (gmax1, _) = select_i(&active, &y, &alpha, &grad, c, scan_threads);
            let gmax2 = max_low_violation(&active, &y, &alpha, &grad, c, scan_threads);
            if !unshrunk_once && gmax1 + gmax2 <= params.eps * 10.0 {
                // near convergence: restore everything once and re-shrink
                // against the full gradient
                unshrunk_once = true;
                reconstruct_gradient(&mut rows, ds, &active, &y, &alpha, &mut grad, scan_threads)?;
                active = (0..n).collect();
                ph.lap("smo/reconstruct");
            }
            let before = active.len();
            active.retain(|&t| !be_shrunk(t, &y, &alpha, &grad, c, gmax1, gmax2));
            if active.len() < 2 {
                reconstruct_gradient(&mut rows, ds, &active, &y, &alpha, &mut grad, scan_threads)?;
                active = (0..n).collect();
            }
            if active.len() != before {
                shrink_events += 1;
            }
            sel = None;
            ph.lap("smo/shrink");
        }

        // --- working-set selection (WSS2 of Fan, Chen & Lin) ---
        let (gmax, i_sel) = match sel.take() {
            Some(s) => s,
            None => select_i(&active, &y, &alpha, &grad, c, scan_threads),
        };
        if i_sel == usize::MAX {
            if active.len() < n {
                // the active set may hide violators: restore and re-check
                reconstruct_gradient(&mut rows, ds, &active, &y, &alpha, &mut grad, scan_threads)?;
                active = (0..n).collect();
                since_shrink = 0;
                ph.lap("smo/reconstruct");
                continue;
            }
            break;
        }
        // cache-aware scheduling: trade at most `cache_slack * eps` of
        // violation for a row that needs no recompute. The convergence
        // test below still uses the true maximum `gmax`.
        let (vi, i_sel) = if cache_slack > 0.0 {
            repick_cached_i(
                &active, &y, &alpha, &grad, c, gmax, i_sel, cache_slack * params.eps, &rows,
            )
        } else {
            (gmax, i_sel)
        };
        let ki = rows.get(ds, i_sel)?;
        let yi = y[i_sel];
        ph.lap("smo/kernel");

        let (gmax2, j_sel) =
            select_j(&active, &y, &alpha, &grad, &diag, c, vi, i_sel, yi, &ki, scan_threads);
        ph.lap("smo/select");
        if gmax + gmax2 < params.eps || j_sel == usize::MAX {
            if active.len() < n {
                // converged on the shrunk set only: restore and re-check
                reconstruct_gradient(&mut rows, ds, &active, &y, &alpha, &mut grad, scan_threads)?;
                active = (0..n).collect();
                sel = None;
                since_shrink = 0;
                ph.lap("smo/reconstruct");
                continue;
            }
            break;
        }

        let kj = rows.get(ds, j_sel)?;
        ph.lap("smo/kernel");
        let yj = y[j_sel];
        let (i, j) = (i_sel, j_sel);

        // --- analytic two-variable update (LibSVM Solver::Solve) ---
        let (dai, daj) = pair_update(&mut alpha, &grad, &diag, i, j, yi, yj, ki[j] as f64, c);

        // --- fused gradient maintenance + next i-selection:
        // G_t += Q_ti dAi + Q_tj dAj over the active set ---
        sel = Some(update_grad_select_i(
            &active, &y, &alpha, &mut grad, &ki, &kj, yi, yj, dai, daj, c, scan_threads,
        ));
        ph.lap("smo/update");

        since_shrink += 1;
        if !meter.tick(|| (dual_objective(&alpha, &grad), active.len())) {
            break;
        }
    }

    // shrunk gradients are stale; the bias and objective need all of them
    if active.len() < n {
        reconstruct_gradient(&mut rows, ds, &active, &y, &alpha, &mut grad, scan_threads)?;
        ph.lap("smo/reconstruct");
    }

    // --- polishing phase (`--polish`, DESIGN.md §OOC) ---
    // Shrinking's heuristics can leave sub-eps-but-nonzero violations
    // parked outside the final active set. With the hot rows still
    // cached, a strict unshrunk sweep is cheap: run plain WSS2 over all
    // n rows (no shrinking, no cache-aware re-pick) until the true KKT
    // gap closes or the budget stops us. Every SMO step decreases the
    // dual objective, so polish improves-or-equals, never worsens.
    let mut polish_steps = 0u64;
    let mut polish_verdict: Option<&'static str> = None;
    if params.polish {
        active = (0..n).collect();
        let mut psel: Option<(f64, usize)> = None;
        let verdict = loop {
            let (gmax, i_sel) = match psel.take() {
                Some(s) => s,
                None => select_i(&active, &y, &alpha, &grad, c, scan_threads),
            };
            if i_sel == usize::MAX {
                break "clean";
            }
            let ki = rows.get(ds, i_sel)?;
            let yi = y[i_sel];
            let (gmax2, j_sel) =
                select_j(&active, &y, &alpha, &grad, &diag, c, gmax, i_sel, yi, &ki, scan_threads);
            if gmax + gmax2 < params.eps || j_sel == usize::MAX {
                break "clean";
            }
            let kj = rows.get(ds, j_sel)?;
            let yj = y[j_sel];
            let (dai, daj) =
                pair_update(&mut alpha, &grad, &diag, i_sel, j_sel, yi, yj, ki[j_sel] as f64, c);
            psel = Some(update_grad_select_i(
                &active, &y, &alpha, &mut grad, &ki, &kj, yi, yj, dai, daj, c, scan_threads,
            ));
            polish_steps += 1;
            crate::trace::count(crate::trace::Counter::PolishSteps, 1);
            if !meter.tick(|| (dual_objective(&alpha, &grad), active.len())) {
                break "capped";
            }
        };
        polish_verdict = Some(verdict);
        ph.lap("smo/polish");
    }

    // --- bias: average y_i G_i over free vectors (LibSVM calc_rho) ---
    let mut nfree = 0usize;
    let mut sum_free = 0.0f64;
    let mut ub = f64::INFINITY;
    let mut lb = f64::NEG_INFINITY;
    for t in 0..n {
        let ygt = y[t] * grad[t];
        if alpha[t] > 0.0 && alpha[t] < c {
            nfree += 1;
            sum_free += ygt;
        } else if (alpha[t] == 0.0 && y[t] > 0.0) || (alpha[t] == c && y[t] < 0.0) {
            ub = ub.min(ygt);
        } else {
            lb = lb.max(ygt);
        }
    }
    let rho = if nfree > 0 { sum_free / nfree as f64 } else { (ub + lb) / 2.0 };
    let bias = -rho as f32;

    // dual objective: 1/2 a^T Q a - e^T a = 1/2 sum a_i (G_i - 1)
    let objective: f64 = 0.5
        * alpha
            .iter()
            .zip(&grad)
            .map(|(a, g)| a * (g - 1.0))
            .sum::<f64>();

    // --- extract support vectors ---
    let sv_idx: Vec<usize> = (0..n).filter(|&t| alpha[t] > 0.0).collect();
    let vectors = ds.gather_rows(&sv_idx);
    let coef: Vec<f32> = sv_idx.iter().map(|&t| (alpha[t] * y[t]) as f32).collect();
    ph.lap("smo/finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias,
        solver: format!("smo[{}]", engine.name()),
    };
    let mut res = TrainResult {
        model,
        iterations: meter.iterations(),
        objective,
        alpha: Some(alpha.iter().map(|&a| a as f32).collect()),
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", if warm { "accepted" } else { "zero (cold)" }.to_string());
    }
    res.note("n_sv", sv_idx.len().to_string());
    res.note("cache_hit_rate", format!("{:.3}", rows.hit_rate()));
    res.note("cache_evicted_bytes", rows.cache_evicted_bytes().to_string());
    res.note(
        "cache_fill",
        format!("{:.3}", rows.cache_used_bytes() as f64 / rows.cache_budget_bytes().max(1) as f64),
    );
    res.note("rows_computed", rows.rows_computed.to_string());
    res.note("shrink_events", shrink_events.to_string());
    res.note("final_active", active.len().to_string());
    if let Some(v) = polish_verdict {
        res.note("polish", v.to_string());
        res.note("polish_steps", polish_steps.to_string());
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::metrics::error_rate;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        // classic non-linearly-separable workload
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    fn nsv(r: &TrainResult) -> usize {
        r.notes
            .iter()
            .find(|(k, _)| k == "n_sv")
            .unwrap()
            .1
            .parse()
            .unwrap()
    }

    #[test]
    fn solves_xor_with_rbf() {
        let ds = xor_dataset(300, 1);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let params = SmoParams { c: 10.0, ..Default::default() };
        let r = train(&ds, kind, &params, &Engine::cpu_seq()).unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        let err = error_rate(&margins, &ds.y);
        assert!(err < 0.05, "train error {err}");
        assert!(r.iterations > 10);
    }

    #[test]
    fn linearly_separable_few_svs() {
        // two well-separated blobs: most points should not be SVs
        let spec = SynthSpec { d: 4, clusters: 1, sigma: 0.03, ..Default::default() };
        let ds = generate(&spec, 400, 3, "sep");
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 1.0 },
            &SmoParams { c: 10.0, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        assert!(nsv(&r) < ds.n / 2, "nsv {}", nsv(&r));
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.02);
    }

    #[test]
    fn alphas_respect_box_via_objective_sanity() {
        let ds = xor_dataset(120, 5);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &SmoParams { c: 1.0, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        // coef = alpha*y must lie in [-C, C]
        assert!(r.model.coef.iter().all(|&v| v.abs() <= 1.0 + 1e-6));
        // dual objective at a feasible nonzero point is negative
        assert!(r.objective < 0.0);
    }

    #[test]
    fn engines_reach_same_solution() {
        let ds = xor_dataset(200, 7);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let p = SmoParams { c: 5.0, ..Default::default() };
        let a = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, kind, &p, &Engine::cpu_par(4)).unwrap();
        assert!((a.objective - b.objective).abs() < 1e-6 * a.objective.abs().max(1.0));
    }

    #[test]
    fn parallel_scans_match_sequential_exactly() {
        // chunk-ordered reductions: identical working sets, identical
        // objective and SV count at any thread count
        let ds = xor_dataset(500, 8);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        for shrinking in [false, true] {
            let p = SmoParams { c: 10.0, shrinking, ..Default::default() };
            let base = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
            for threads in [2usize, 8] {
                let r = train(&ds, kind, &p, &Engine::cpu_par(threads)).unwrap();
                let rel = (r.objective - base.objective).abs()
                    / base.objective.abs().max(1.0);
                assert!(
                    rel < 1e-12,
                    "shrinking={shrinking} threads={threads}: {} vs {}",
                    r.objective,
                    base.objective
                );
                assert_eq!(
                    r.iterations, base.iterations,
                    "shrinking={shrinking} threads={threads}"
                );
                assert_eq!(nsv(&r), nsv(&base), "shrinking={shrinking} threads={threads}");
            }
        }
    }

    #[test]
    fn shrinking_reaches_the_unshrunk_objective() {
        let ds = xor_dataset(600, 11);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        // tight eps so the run comfortably outlasts the shrink interval
        let on = train(
            &ds,
            kind,
            &SmoParams { c: 10.0, eps: 1e-5, shrinking: true, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        let off = train(
            &ds,
            kind,
            &SmoParams { c: 10.0, eps: 1e-5, shrinking: false, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        let rel = (on.objective - off.objective).abs() / off.objective.abs().max(1.0);
        assert!(rel < 1e-3, "shrunk {} vs unshrunk {}", on.objective, off.objective);
        // shrinking must have actually engaged on a 600-point problem
        let events: usize = on
            .notes
            .iter()
            .find(|(k, _)| k == "shrink_events")
            .unwrap()
            .1
            .parse()
            .unwrap();
        assert!(events > 0, "no shrink events recorded");
    }

    #[test]
    fn shared_cache_across_groups_reaches_same_solution() {
        let ds = xor_dataset(200, 13);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let p = SmoParams { c: 5.0, ..Default::default() };
        let own = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        let cache = Arc::new(SharedRowCache::new(8 * 1024 * 1024, 4));
        let a = train_cached(&ds, kind, &p, &Engine::cpu_seq(), cache.clone(), 1).unwrap();
        let b = train_cached(&ds, kind, &p, &Engine::cpu_seq(), cache.clone(), 2).unwrap();
        assert!((a.objective - own.objective).abs() < 1e-12 * own.objective.abs().max(1.0));
        assert!((b.objective - own.objective).abs() < 1e-12 * own.objective.abs().max(1.0));
        assert!(cache.hits() > 0);
    }

    #[test]
    fn polish_reports_verdict_and_never_worsens() {
        let ds = xor_dataset(300, 21);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let base =
            train(&ds, kind, &SmoParams { c: 10.0, ..Default::default() }, &Engine::cpu_seq())
                .unwrap();
        let p = SmoParams { c: 10.0, polish: true, ..Default::default() };
        let r = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        // the dual objective is minimized; polish steps only decrease it
        assert!(r.objective <= base.objective + 1e-12, "{} vs {}", r.objective, base.objective);
        assert!(r
            .notes
            .iter()
            .any(|(k, v)| k == "polish" && (v == "clean" || v == "capped")));
        assert!(r.notes.iter().any(|(k, _)| k == "polish_steps"));
    }

    #[test]
    fn cache_slack_converges_to_matching_objective() {
        // the re-pick trades at most slack*eps of violation per step, so
        // the final objective agrees with plain WSS2 to solver tolerance
        let ds = xor_dataset(400, 22);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let base =
            train(&ds, kind, &SmoParams { c: 10.0, ..Default::default() }, &Engine::cpu_seq())
                .unwrap();
        let p = SmoParams { c: 10.0, cache_slack: 0.5, ..Default::default() };
        let r = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        let rel = (r.objective - base.objective).abs() / base.objective.abs().max(1.0);
        assert!(rel < 1e-3, "slack {} vs plain {}", r.objective, base.objective);
    }

    #[test]
    fn iteration_budget_caps_work() {
        let ds = xor_dataset(300, 9);
        let p = SmoParams { c: 10.0, ..Default::default() };
        let r = Trainer::new(SolverSpec::Smo(p))
            .kernel(KernelKind::Rbf { gamma: 8.0 })
            .budget(Budget::iters(5))
            .train(&ds)
            .unwrap();
        assert_eq!(r.iterations, 5);
        assert!(r.notes.iter().any(|(k, v)| k == "capped" && v == "iters"));
    }
}
