//! Sequential Minimal Optimization with second-order working-set
//! selection — the LibSVM algorithm (Chang & Lin 2011; Platt 1998),
//! reimplemented from scratch.
//!
//! Solves the dual (paper eq. 2):
//!   min_a  1/2 a^T Q a - e^T a,   0 <= a_i <= C,  y^T a = 0,
//! with Q_ij = y_i y_j k(x_i, x_j).
//!
//! The engine choice reproduces three Table-1 configurations:
//! * `cpu-seq`  — single-core LibSVM;
//! * `cpu-par`  — LibSVM+OpenMP (kernel rows hand-threaded, the paper's
//!   "most basic method of speedup", 5-8x on twelve cores);
//! * `xla`      — GPU SVM (kernel rows offloaded to the accelerator
//!   library one working pair at a time; high per-call overhead, which is
//!   exactly the paper's observation about explicit GPU SMO).

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::KernelKind;
use crate::metrics::Stopwatch;
use crate::model::SvmModel;

use super::common::KernelRows;
use super::TrainResult;

const TAU: f64 = 1e-12;

/// SMO hyperparameters.
#[derive(Debug, Clone)]
pub struct SmoParams {
    pub c: f32,
    /// KKT violation tolerance (LibSVM default 1e-3).
    pub eps: f64,
    pub max_iters: usize,
    pub cache_mb: usize,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams { c: 1.0, eps: 1e-3, max_iters: 2_000_000, cache_mb: 512 }
    }
}

/// Train a binary SVM with SMO.
pub fn train(
    ds: &Dataset,
    kind: KernelKind,
    params: &SmoParams,
    engine: &Engine,
) -> Result<TrainResult> {
    assert!(!ds.is_multiclass(), "use multiclass::train_ovo");
    let mut sw = Stopwatch::new();
    let n = ds.n;
    let c = params.c as f64;
    let mut rows = KernelRows::new(ds, kind, engine.clone(), params.cache_mb)?;
    sw.lap("setup");

    let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
    let mut alpha = vec![0.0f64; n];
    // G_i = (Q alpha)_i - 1; alpha = 0 -> G = -1.
    let mut grad = vec![-1.0f64; n];
    let diag: Vec<f64> = rows.diag.iter().map(|&v| v as f64).collect();

    let mut iters = 0usize;
    loop {
        // --- working-set selection (WSS2 of Fan, Chen & Lin) ---
        let mut gmax = f64::NEG_INFINITY;
        let mut gmax2 = f64::NEG_INFINITY;
        let mut i_sel = usize::MAX;
        for t in 0..n {
            // I_up: y=+1 & a<C, or y=-1 & a>0
            if (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0) {
                let v = -y[t] * grad[t];
                if v >= gmax {
                    gmax = v;
                    i_sel = t;
                }
            }
        }
        if i_sel == usize::MAX {
            break;
        }
        let ki = rows.get(ds, i_sel)?.to_vec();
        let yi = y[i_sel];

        let mut j_sel = usize::MAX;
        let mut obj_min = f64::INFINITY;
        for t in 0..n {
            // I_low: y=+1 & a>0, or y=-1 & a<C
            if (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c) {
                let v = y[t] * grad[t];
                if v > gmax2 {
                    gmax2 = v;
                }
                let grad_diff = gmax + v;
                if grad_diff > 0.0 {
                    // Q_ii + Q_tt - 2 Q_it with Q_it = y_i y_t K_it
                    let quad = (diag[i_sel] + diag[t]
                        - 2.0 * yi * y[t] * ki[t] as f64)
                        .max(TAU);
                    let obj = -(grad_diff * grad_diff) / quad;
                    if obj <= obj_min {
                        obj_min = obj;
                        j_sel = t;
                    }
                }
            }
        }
        if gmax + gmax2 < params.eps || j_sel == usize::MAX {
            break;
        }
        sw.lap("select");

        let kj = rows.get(ds, j_sel)?.to_vec();
        sw.lap("kernel");
        let yj = y[j_sel];
        let (i, j) = (i_sel, j_sel);
        let old_ai = alpha[i];
        let old_aj = alpha[j];

        // --- analytic two-variable update (LibSVM Solver::Solve) ---
        if yi != yj {
            let quad = (diag[i] + diag[j] + 2.0 * ki[j] as f64).max(TAU);
            let delta = (-grad[i] - grad[j]) / quad;
            let diff = alpha[i] - alpha[j];
            alpha[i] += delta;
            alpha[j] += delta;
            if diff > 0.0 {
                if alpha[j] < 0.0 {
                    alpha[j] = 0.0;
                    alpha[i] = diff;
                }
            } else if alpha[i] < 0.0 {
                alpha[i] = 0.0;
                alpha[j] = -diff;
            }
            if diff > 0.0 {
                if alpha[i] > c {
                    alpha[i] = c;
                    alpha[j] = c - diff;
                }
            } else if alpha[j] > c {
                alpha[j] = c;
                alpha[i] = c + diff;
            }
        } else {
            let quad = (diag[i] + diag[j] - 2.0 * ki[j] as f64).max(TAU);
            let delta = (grad[i] - grad[j]) / quad;
            let sum = alpha[i] + alpha[j];
            alpha[i] -= delta;
            alpha[j] += delta;
            if sum > c {
                if alpha[i] > c {
                    alpha[i] = c;
                    alpha[j] = sum - c;
                }
            } else if alpha[j] < 0.0 {
                alpha[j] = 0.0;
                alpha[i] = sum;
            }
            if sum > c {
                if alpha[j] > c {
                    alpha[j] = c;
                    alpha[i] = sum - c;
                }
            } else if alpha[i] < 0.0 {
                alpha[i] = 0.0;
                alpha[j] = sum;
            }
        }

        // --- gradient maintenance: G_t += Q_ti dAi + Q_tj dAj ---
        let dai = alpha[i] - old_ai;
        let daj = alpha[j] - old_aj;
        for t in 0..n {
            grad[t] += yi * y[t] * ki[t] as f64 * dai + yj * y[t] * kj[t] as f64 * daj;
        }
        sw.lap("update");

        iters += 1;
        if iters >= params.max_iters {
            break;
        }
    }

    // --- bias: average y_i G_i over free vectors (LibSVM calc_rho) ---
    let mut nfree = 0usize;
    let mut sum_free = 0.0f64;
    let mut ub = f64::INFINITY;
    let mut lb = f64::NEG_INFINITY;
    for t in 0..n {
        let ygt = y[t] * grad[t];
        if alpha[t] > 0.0 && alpha[t] < c {
            nfree += 1;
            sum_free += ygt;
        } else if (alpha[t] == 0.0 && y[t] > 0.0) || (alpha[t] == c && y[t] < 0.0) {
            ub = ub.min(ygt);
        } else {
            lb = lb.max(ygt);
        }
    }
    let rho = if nfree > 0 { sum_free / nfree as f64 } else { (ub + lb) / 2.0 };
    let bias = -rho as f32;

    // dual objective: 1/2 a^T Q a - e^T a = 1/2 sum a_i (G_i - 1)
    let objective: f64 = 0.5
        * alpha
            .iter()
            .zip(&grad)
            .map(|(a, g)| a * (g - 1.0))
            .sum::<f64>();

    // --- extract support vectors ---
    let sv_idx: Vec<usize> = (0..n).filter(|&t| alpha[t] > 0.0).collect();
    let mut vectors = Vec::with_capacity(sv_idx.len() * ds.d);
    let mut coef = Vec::with_capacity(sv_idx.len());
    for &t in &sv_idx {
        vectors.extend_from_slice(ds.row(t));
        coef.push((alpha[t] * y[t]) as f32);
    }
    sw.lap("finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias,
        solver: format!("smo[{}]", engine.name()),
    };
    let mut res = TrainResult { model, iterations: iters, objective, stopwatch: sw, notes: vec![] };
    res.note("n_sv", sv_idx.len().to_string());
    res.note("cache_hit_rate", format!("{:.3}", rows.hit_rate()));
    res.note("rows_computed", rows.rows_computed.to_string());
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::metrics::error_rate;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        // classic non-linearly-separable workload
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    #[test]
    fn solves_xor_with_rbf() {
        let ds = xor_dataset(300, 1);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let r = train(&ds, kind, &SmoParams { c: 10.0, ..Default::default() }, &Engine::cpu_seq()).unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        let err = error_rate(&margins, &ds.y);
        assert!(err < 0.05, "train error {err}");
        assert!(r.iterations > 10);
    }

    #[test]
    fn linearly_separable_few_svs() {
        // two well-separated blobs: most points should not be SVs
        let spec = SynthSpec { d: 4, clusters: 1, sigma: 0.03, ..Default::default() };
        let ds = generate(&spec, 400, 3, "sep");
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 1.0 },
            &SmoParams { c: 10.0, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        let nsv: usize = r.notes.iter().find(|(k, _)| k == "n_sv").unwrap().1.parse().unwrap();
        assert!(nsv < ds.n / 2, "nsv {nsv}");
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.02);
    }

    #[test]
    fn alphas_respect_box_via_objective_sanity() {
        let ds = xor_dataset(120, 5);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &SmoParams { c: 1.0, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        // coef = alpha*y must lie in [-C, C]
        assert!(r.model.coef.iter().all(|&v| v.abs() <= 1.0 + 1e-6));
        // dual objective at a feasible nonzero point is negative
        assert!(r.objective < 0.0);
    }

    #[test]
    fn engines_reach_same_solution() {
        let ds = xor_dataset(200, 7);
        let kind = KernelKind::Rbf { gamma: 6.0 };
        let p = SmoParams { c: 5.0, ..Default::default() };
        let a = train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, kind, &p, &Engine::cpu_par(4)).unwrap();
        assert!((a.objective - b.objective).abs() < 1e-6 * a.objective.abs().max(1.0));
    }

    #[test]
    fn max_iters_caps_work() {
        let ds = xor_dataset(300, 9);
        let p = SmoParams { c: 10.0, max_iters: 5, ..Default::default() };
        let r = train(&ds, KernelKind::Rbf { gamma: 8.0 }, &p, &Engine::cpu_seq()).unwrap();
        assert_eq!(r.iterations, 5);
    }
}
