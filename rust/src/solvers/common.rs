//! Shared solver infrastructure: cached kernel-row providers and padded
//! tile views of a dataset.

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::{self, cache::SharedRowCache, KernelKind};

/// Running dual objective of the decomposition solvers:
/// `1/2 a^T Q a - e^T a = 1/2 Σ a_i (G_i - 1)`. Exact when every
/// gradient entry is fresh (WSS); under SMO shrinking the entries of
/// shrunk variables are stale, making this the active-set
/// approximation (exact again after gradient reconstruction).
pub fn dual_objective(alpha: &[f64], grad: &[f64]) -> f64 {
    0.5 * alpha.iter().zip(grad).map(|(a, g)| a * (g - 1.0)).sum::<f64>()
}

/// Padded row-tile view of a dataset for engine calls: X tiles of
/// [t x d_pad] with validity masks (`rust/DESIGN.md` §Tiling).
///
/// Sparse designs keep their tiles in CSR (one padded matrix, empty
/// trailing rows) instead of materializing dense `x` tiles; tile kernel
/// blocks then run on the SpMM substrate via [`TiledData::rbf_block`].
/// The xla engine needs dense bucket-shaped operands, so its callers use
/// [`TiledData::densified`].
pub struct TiledData {
    pub t: usize,
    pub d: usize,
    pub d_pad: usize,
    pub n: usize,
    pub n_tiles: usize,
    /// Per tile: t*d_pad features (padded rows zero). Empty when the
    /// tiles live in [`TiledData::sparse`] instead.
    pub x: Vec<Vec<f32>>,
    /// CSR tiles (`n_tiles * t` rows, trailing padding rows empty);
    /// `None` for dense tiles.
    pub sparse: Option<crate::data::CsrMatrix>,
    /// Per tile: labels (padding 1.0, masked out).
    pub y: Vec<Vec<f32>>,
    /// Per tile: validity mask.
    pub m: Vec<Vec<f32>>,
}

impl TiledData {
    /// Design-aware tiling: dense datasets get dense tiles, sparse
    /// datasets stay in CSR (requires `d_pad == ds.d` — the cpu engines'
    /// convention; the xla path uses [`TiledData::densified`]).
    pub fn new(ds: &Dataset, t: usize, d_pad: usize) -> TiledData {
        if ds.is_sparse() {
            assert_eq!(
                d_pad, ds.d,
                "sparse tiles take no feature padding (use TiledData::densified)"
            );
            // Mapped CSR materializes (same triplets, same stored norms)
            // so tile solvers run the identical SpMM substrate and stay
            // bit-identical to the in-memory equivalent.
            let owned;
            let csr = match ds.csr() {
                Some(c) => c,
                None => {
                    let crate::data::Design::MmapCsr(mc) = &ds.design else {
                        unreachable!("sparse design is CSR or mapped CSR")
                    };
                    owned = mc.to_csr();
                    &owned
                }
            };
            let n_tiles = (ds.n + t - 1) / t;
            let (y, m) = Self::label_tiles(ds, t, n_tiles);
            return TiledData {
                t,
                d: ds.d,
                d_pad,
                n: ds.n,
                n_tiles,
                x: Vec::new(),
                sparse: Some(csr.pad_rows(n_tiles * t)),
                y,
                m,
            };
        }
        Self::densified(ds, t, d_pad)
    }

    /// Dense tiles regardless of the design (the xla path: artifacts
    /// take dense bucket-shaped operands only).
    pub fn densified(ds: &Dataset, t: usize, d_pad: usize) -> TiledData {
        assert!(d_pad >= ds.d);
        let n_tiles = (ds.n + t - 1) / t;
        let mut x = Vec::with_capacity(n_tiles);
        for tile in 0..n_tiles {
            let mut xt = vec![0.0f32; t * d_pad];
            for r in 0..t {
                let i = tile * t + r;
                if i >= ds.n {
                    break;
                }
                ds.row_into(i, &mut xt[r * d_pad..(r + 1) * d_pad]);
            }
            x.push(xt);
        }
        let (y, m) = Self::label_tiles(ds, t, n_tiles);
        TiledData { t, d: ds.d, d_pad, n: ds.n, n_tiles, x, sparse: None, y, m }
    }

    fn label_tiles(ds: &Dataset, t: usize, n_tiles: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut y = Vec::with_capacity(n_tiles);
        let mut m = Vec::with_capacity(n_tiles);
        for tile in 0..n_tiles {
            let mut yt = vec![1.0f32; t];
            let mut mt = vec![0.0f32; t];
            for r in 0..t {
                let i = tile * t + r;
                if i >= ds.n {
                    break;
                }
                yt[r] = ds.y[i];
                mt[r] = 1.0;
            }
            y.push(yt);
            m.push(mt);
        }
        (y, m)
    }

    /// Global row index -> (tile, row-in-tile).
    #[inline]
    pub fn locate(&self, i: usize) -> (usize, usize) {
        (i / self.t, i % self.t)
    }

    /// Copy row `i`'s padded features into `out` (length d_pad).
    pub fn copy_row(&self, i: usize, out: &mut [f32]) {
        match &self.sparse {
            Some(csr) => csr.densify_row_into(i, &mut out[..self.d_pad]),
            None => {
                let (tile, r) = self.locate(i);
                out[..self.d_pad]
                    .copy_from_slice(&self.x[tile][r * self.d_pad..(r + 1) * self.d_pad]);
            }
        }
    }

    /// `K[t x b]` of one tile against a dense `b x d_pad` block through
    /// the engine — the storage-dispatch point that gives tile solvers
    /// (SP-SVM) the sparse fast path with no call-site change.
    pub fn rbf_block(
        &self,
        engine: &Engine,
        tile: usize,
        xb: &[f32],
        b: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        match &self.sparse {
            Some(csr) => engine.rbf_block_csr(csr, tile * self.t, self.t, xb, b, gamma),
            None => engine.rbf_block(&self.x[tile], self.t, self.d_pad, xb, b, gamma),
        }
    }
}

/// Cached provider of kernel rows k(x_i, .) over the whole training set.
///
/// The row *source* is the engine: CPU engines compute rows with scalar
/// loops (threaded for CpuPar); the XLA engine computes them through the
/// `kernel_block` artifact over padded tiles — the GPU-offload path of
/// GPU SVM / GTSVM. A byte-bounded sharded LRU cache sits in front either
/// way (LibSVM's design); several `KernelRows` instances may share one
/// cache (and its byte budget) via [`KernelRows::with_shared_cache`], each
/// under its own group id — how concurrent OvO subproblems stay within a
/// single memory bound.
pub struct KernelRows {
    pub kind: KernelKind,
    engine: Engine,
    cache: Arc<SharedRowCache>,
    group: u64,
    row_len: usize,
    tiled: Option<TiledData>, // present iff engine is xla
    /// Diagonal K_ii (constant 1 for RBF).
    pub diag: Vec<f32>,
    /// b bucket used for xla row batches.
    bucket_b: usize,
    pub rows_computed: u64,
}

/// A sensible shard count for a cache serving `threads` workers.
pub fn cache_shards(threads: usize) -> usize {
    threads.clamp(1, 16).next_power_of_two()
}

impl KernelRows {
    /// Provider with a private cache of `cache_mb` megabytes.
    pub fn new(
        ds: &Dataset,
        kind: KernelKind,
        engine: Engine,
        cache_mb: usize,
    ) -> Result<KernelRows> {
        let shards = cache_shards(engine.threads());
        let cache = Arc::new(SharedRowCache::new(cache_mb * 1024 * 1024, shards));
        KernelRows::with_shared_cache(ds, kind, engine, cache, 0)
    }

    /// Provider backed by a shared cache under the given `group` id.
    /// Groups keep row indices from different datasets (e.g. OvO pair
    /// views) from aliasing; the byte budget is shared by all groups.
    pub fn with_shared_cache(
        ds: &Dataset,
        kind: KernelKind,
        engine: Engine,
        cache: Arc<SharedRowCache>,
        group: u64,
    ) -> Result<KernelRows> {
        let diag = match kind {
            // K_ii = 1 for RBF without touching the row (sparse-friendly)
            KernelKind::Rbf { .. } => vec![1.0f32; ds.n],
            _ => {
                let mut buf = vec![0.0f32; ds.d];
                (0..ds.n)
                    .map(|i| {
                        ds.row_into(i, &mut buf);
                        kind.self_eval(&buf)
                    })
                    .collect()
            }
        };
        let (tiled, bucket_b) = if engine.is_xla() {
            let (rt, gamma_ok) = match (&engine.kind, kind) {
                (crate::engine::EngineKind::Xla { runtime }, KernelKind::Rbf { .. }) => {
                    (runtime.clone(), true)
                }
                (crate::engine::EngineKind::Xla { runtime }, _) => (runtime.clone(), false),
                _ => unreachable!(),
            };
            anyhow::ensure!(gamma_ok, "xla kernel rows support the RBF kernel only");
            let t = rt.tile_t();
            let d_pad = *rt
                .manifest()
                .d_buckets()
                .iter()
                .find(|&&b| b >= ds.d)
                .ok_or_else(|| anyhow::anyhow!("no d bucket >= {}", ds.d))?;
            let b = *rt
                .manifest()
                .b_buckets()
                .first()
                .ok_or_else(|| anyhow::anyhow!("no b buckets"))?;
            (Some(TiledData::densified(ds, t, d_pad)), b)
        } else {
            (None, 0)
        };
        Ok(KernelRows {
            kind,
            engine,
            cache,
            group,
            row_len: ds.n,
            tiled,
            diag,
            bucket_b,
            rows_computed: 0,
        })
    }

    /// Fetch row `i` (through the cache). A failed fill commits nothing,
    /// so a later retry recomputes instead of hitting poisoned data.
    pub fn get(&mut self, ds: &Dataset, i: usize) -> Result<Arc<Vec<f32>>> {
        let engine = &self.engine;
        let kind = &self.kind;
        let tiled = &self.tiled;
        let bucket_b = self.bucket_b;
        let mut computed = false;
        let row = self.cache.get_or_try_compute(self.group, i, self.row_len, |out| {
            computed = true;
            if let Some(tiled) = tiled {
                xla_fill_rows(engine, kind, tiled, bucket_b, &[i], &mut [out])?;
            } else {
                kernel::kernel_row(kind, ds, i, engine.threads(), out);
            }
            Ok(())
        })?;
        if computed {
            self.rows_computed += 1;
            crate::trace::count(crate::trace::Counter::KernelRowsComputed, 1);
        }
        Ok(row)
    }

    /// Fetch a batch of rows at once. The XLA path amortizes one tile
    /// sweep over the whole batch — the GTSVM working-set amortization.
    pub fn get_batch(&mut self, ds: &Dataset, idx: &[usize]) -> Result<Vec<Arc<Vec<f32>>>> {
        // serve hits from cache, batch the misses
        let mut out: Vec<Option<Arc<Vec<f32>>>> = vec![None; idx.len()];
        let mut misses = Vec::new();
        for (slot, &i) in idx.iter().enumerate() {
            if self.cache.contains(self.group, i) {
                out[slot] = Some(self.get(ds, i)?);
            } else {
                misses.push((slot, i));
            }
        }
        if !misses.is_empty() {
            if let Some(tiled) = &self.tiled {
                let ids: Vec<usize> = misses.iter().map(|&(_, i)| i).collect();
                let mut bufs: Vec<Vec<f32>> = vec![vec![0.0f32; ds.n]; ids.len()];
                {
                    let mut views: Vec<&mut [f32]> =
                        bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                    let b = self.bucket_b;
                    xla_fill_rows(&self.engine, &self.kind, tiled, b, &ids, &mut views)?;
                }
                for ((slot, i), buf) in misses.into_iter().zip(bufs) {
                    self.rows_computed += 1;
                    crate::trace::count(crate::trace::Counter::KernelRowsComputed, 1);
                    let row = self.cache.get_or_try_compute(self.group, i, self.row_len, |out| {
                        out.copy_from_slice(&buf);
                        Ok(())
                    })?;
                    out[slot] = Some(row);
                }
            } else {
                for (slot, i) in misses {
                    out[slot] = Some(self.get(ds, i)?);
                }
            }
        }
        Ok(out
            .into_iter()
            .map(|r| r.expect("every slot filled"))
            .collect())
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Whether row `i` is resident in the backing cache right now — the
    /// cache-aware scheduling probe (`--cache-slack`). Pure peek: no
    /// fill, no LRU touch, so probing never perturbs eviction order.
    pub fn is_cached(&self, i: usize) -> bool {
        self.cache.contains(self.group, i)
    }

    /// Bytes the backing cache evicted so far — nonzero means the
    /// working set did not fit the byte budget (capacity pressure).
    pub fn cache_evicted_bytes(&self) -> u64 {
        self.cache.evicted_bytes()
    }

    /// Bytes currently resident in the backing cache.
    pub fn cache_used_bytes(&self) -> usize {
        self.cache.used_bytes()
    }

    /// The backing cache's total byte budget.
    pub fn cache_budget_bytes(&self) -> usize {
        self.cache.budget_bytes()
    }
}

/// Compute full kernel rows for `ids` through the `kernel_block` artifact:
/// one sweep over the row tiles with the query points packed into the
/// basis-side bucket.
fn xla_fill_rows(
    engine: &Engine,
    kind: &KernelKind,
    tiled: &TiledData,
    bucket_b: usize,
    ids: &[usize],
    outs: &mut [&mut [f32]],
) -> Result<()> {
    assert!(ids.len() <= bucket_b, "batch {} > bucket {bucket_b}", ids.len());
    assert_eq!(ids.len(), outs.len());
    let gamma = match kind {
        KernelKind::Rbf { gamma } => *gamma,
        _ => anyhow::bail!("xla rows are RBF-only"),
    };
    let d_pad = tiled.d_pad;
    let mut xb = vec![0.0f32; bucket_b * d_pad];
    for (q, &i) in ids.iter().enumerate() {
        let (tile, r) = tiled.locate(i);
        xb[q * d_pad..(q + 1) * d_pad]
            .copy_from_slice(&tiled.x[tile][r * d_pad..(r + 1) * d_pad]);
    }
    for tile in 0..tiled.n_tiles {
        let k = engine.rbf_block(&tiled.x[tile], tiled.t, d_pad, &xb, bucket_b, gamma)?;
        let base = tile * tiled.t;
        let rows_here = tiled.t.min(tiled.n - base);
        for (q, out) in outs.iter_mut().enumerate() {
            for r in 0..rows_here {
                out[base + r] = k[r * bucket_b + q];
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * d).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        Dataset::new_binary("t", d, x, y)
    }

    #[test]
    fn tiled_data_pads_and_masks() {
        let ds = dataset(100, 5, 1);
        let td = TiledData::new(&ds, 64, 8);
        assert_eq!(td.n_tiles, 2);
        assert_eq!(td.m[0].iter().sum::<f32>(), 64.0);
        assert_eq!(td.m[1].iter().sum::<f32>(), 36.0);
        // row 70 lives in tile 1, row 6
        let (tile, r) = td.locate(70);
        assert_eq!((tile, r), (1, 6));
        assert_eq!(&td.x[tile][r * 8..r * 8 + 5], ds.row(70));
        assert_eq!(&td.x[tile][r * 8 + 5..r * 8 + 8], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn kernel_rows_cpu_match_direct() {
        let ds = dataset(200, 7, 2);
        let kind = KernelKind::Rbf { gamma: 0.8 };
        let mut kr = KernelRows::new(&ds, kind, Engine::cpu_seq(), 16).unwrap();
        let row = kr.get(&ds, 13).unwrap().to_vec();
        for j in 0..ds.n {
            assert!((row[j] - kind.eval(ds.row(13), ds.row(j))).abs() < 1e-5);
        }
        // cache hit on second fetch
        let _ = kr.get(&ds, 13).unwrap();
        assert!(kr.hit_rate() > 0.0);
        assert_eq!(kr.rows_computed, 1);
    }

    #[test]
    fn batch_matches_single_rows() {
        let ds = dataset(150, 6, 3);
        let kind = KernelKind::Rbf { gamma: 0.5 };
        let mut kr = KernelRows::new(&ds, kind, Engine::cpu_par(2), 16).unwrap();
        let batch = kr.get_batch(&ds, &[3, 77, 3, 149]).unwrap();
        for (slot, &i) in [3usize, 77, 3, 149].iter().enumerate() {
            for j in 0..ds.n {
                assert!((batch[slot][j] - kind.eval(ds.row(i), ds.row(j))).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn xla_rows_match_cpu() {
        let artifacts = crate::runtime::default_artifacts_dir();
        let Ok(rt) = crate::runtime::XlaRuntime::load(&artifacts) else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let ds = dataset(2500, 10, 4); // spans 3 tiles
        let kind = KernelKind::Rbf { gamma: 0.6 };
        let mut cpu = KernelRows::new(&ds, kind, Engine::cpu_seq(), 16).unwrap();
        let mut xla = KernelRows::new(&ds, kind, Engine::xla(std::sync::Arc::new(rt)), 16).unwrap();
        for &i in &[0usize, 1023, 1024, 2499] {
            let a = cpu.get(&ds, i).unwrap().to_vec();
            let b = xla.get(&ds, i).unwrap().to_vec();
            let dmax: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
            assert!(dmax < 1e-4, "row {i} differs by {dmax}");
        }
        // batch path
        let batch = xla.get_batch(&ds, &[5, 2000]).unwrap();
        let a5 = cpu.get(&ds, 5).unwrap().to_vec();
        let dmax: f32 = a5.iter().zip(&batch[0]).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(dmax < 1e-4);
    }
}
