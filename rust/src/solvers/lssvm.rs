//! LS-SVM — least-squares SVM (Suykens & Vandewalle 1999), solved on
//! the low-rank normal equations in the style of PLSSVM
//! (arXiv:2202.12674).
//!
//! Replaces the hinge loss with a squared loss and the inequality
//! constraints with equalities, so training collapses to one SPD linear
//! system over the kernel operator:
//!
//!   (K + I/C) α + 1 b = y,   1ᵀ α = 0
//!
//! eliminated through two CG solves against A = K + I/C:
//!   η = A⁻¹ 1,  ν = A⁻¹ y,  b = (1ᵀν)/(1ᵀη),  α = ν − b η.
//!
//! With the default low-rank operator (K ≈ G Gᵀ, rank r) every CG
//! iteration is two skinny GEMVs — O(n·r) — which is the most
//! GEMM-bound solver in the repo and the purest expression of the
//! paper's "approximate implicit" thesis: a handful of large dense
//! linalg calls instead of millions of tiny working-set steps.
//! `lowrank: None` solves on the exact materialized kernel
//! (memory-capped, like `mu`/`primal`).
//!
//! LS-SVM is dense in the α sense: nearly every training point gets a
//! nonzero coefficient, so the model keeps all of them — the classic
//! LS-SVM trade (one big solve, no sparsity).

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::operator::{build as build_operator, ExactDense, KernelOperator, LowRankConfig};
use crate::kernel::KernelKind;
use crate::linalg::{cg, dot};
use crate::model::SvmModel;

use super::api::{Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::TrainResult;

/// LS-SVM hyperparameters. Parallelism comes from the ctx engine.
#[derive(Debug, Clone)]
pub struct LsSvmParams {
    pub c: f32,
    /// Kernel operator request: `Some` (the default, rank 256 ICF)
    /// solves on K ≈ G Gᵀ; `None` materializes the exact kernel under
    /// the memory cap.
    pub lowrank: Option<LowRankConfig>,
    /// CG iteration cap per solve (also the default budget cap).
    pub cg_iters: usize,
    /// CG stop on the squared residual norm.
    pub cg_tol: f32,
    /// Exact-path memory cap (ignored by low-rank operators).
    pub max_kernel_bytes: usize,
}

impl Default for LsSvmParams {
    fn default() -> Self {
        LsSvmParams {
            c: 1.0,
            lowrank: Some(LowRankConfig::icf(256)),
            cg_iters: 500,
            cg_tol: 1e-10,
            max_kernel_bytes: 2 << 30,
        }
    }
}

impl SolverDriver for LsSvmParams {
    fn name(&self) -> &str {
        "lssvm"
    }

    fn family(&self) -> Family {
        Family::Implicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// Legacy-style convenience entry point (the other solvers keep one for
/// a release; LS-SVM starts with it for test ergonomics). Runs on the
/// default-threads cpu engine.
pub fn train(ds: &Dataset, kind: KernelKind, params: &LsSvmParams) -> Result<TrainResult> {
    Trainer::new(SolverSpec::LsSvm(params.clone()))
        .kernel(kind)
        .engine(Engine::cpu_par(crate::pool::default_threads()))
        .train(ds)
}

fn train_ctx(ctx: &TrainCtx<'_>, params: &LsSvmParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let kind = ctx.kind;
    let threads = ctx.engine.threads();
    ensure!(params.c > 0.0, "lssvm needs C > 0 (got {})", params.c);
    let mut ph = crate::trace::phases();
    let n = ds.n;
    // budget unit = CG iterations of the main (ν) solve; the wall clock
    // starts before the factorization, which dominates at low rank.
    let mut meter = ctx.meter("lssvm", params.cg_iters);
    let op: Box<dyn KernelOperator + '_> = match params.lowrank {
        None => Box::new(ExactDense::build(&kind, ds, threads, params.max_kernel_bytes)?),
        Some(cfg) => build_operator(&kind, ds, threads, Some(cfg))?,
    };
    let op = op.as_ref();
    ph.lap("lssvm/operator");

    let reg = 1.0 / params.c;
    // η = A⁻¹ 1 — the bias-elimination solve, off the iteration budget
    // (it shares the main solve's conditioning, so cg_iters bounds it).
    let ones = vec![1.0f32; n];
    let eta = cg::solve_operator(op, &ones, reg, params.cg_iters, params.cg_tol);

    // ν = A⁻¹ y — the main solve. Same update arithmetic as cg::run,
    // inlined so the budget meter can tick (and stop) per CG iteration
    // with the quadratic objective f(x) = ½xᵀAx − yᵀx = −½(xᵀb + xᵀr).
    let y = &ds.y;
    let mut apply = |v: &[f32], out: &mut Vec<f32>| {
        op.matvec(v, out);
        for i in 0..n {
            out[i] += reg * v[i];
        }
    };
    let mut x = vec![0.0f32; n];
    let mut r: Vec<f32> = y.clone();
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let mut ap = vec![0.0f32; n];
    let mut iters = 0usize;
    let mut obj = 0.0f64;
    for _ in 0..params.cg_iters {
        if rs <= params.cg_tol {
            break;
        }
        iters += 1;
        apply(&p, &mut ap);
        let denom = dot(&p, &ap).max(1e-30);
        let alpha = rs / denom;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs.max(1e-30);
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
        obj = -0.5 * (dot(&x, y) as f64 + dot(&x, &r) as f64);
        if !meter.tick(|| (obj, n)) {
            break;
        }
    }
    let nu = x;
    ph.lap("lssvm/solve");

    // b = (1ᵀν)/(1ᵀη), α = ν − b η (f64 sums for the ratio)
    let sum_nu: f64 = nu.iter().map(|&v| v as f64).sum();
    let sum_eta: f64 = eta.x.iter().map(|&v| v as f64).sum();
    let bias = if sum_eta.abs() > 1e-12 { (sum_nu / sum_eta) as f32 } else { 0.0 };
    let alpha: Vec<f32> = nu.iter().zip(&eta.x).map(|(v, e)| v - bias * e).collect();

    // LS-SVM is non-sparse; keep every coefficient that moves a margin.
    let sv: Vec<usize> = (0..n).filter(|&i| alpha[i].abs() > 1e-8).collect();
    let vectors = ds.gather_rows(&sv);
    let coef: Vec<f32> = sv.iter().map(|&i| alpha[i]).collect();
    ph.lap("lssvm/finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias,
        solver: "lssvm".into(),
    };
    let mut res = TrainResult {
        model,
        iterations: iters.max(eta.iters),
        objective: obj,
        alpha: None,
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", "rejected (lssvm duals are unconstrained)".into());
    }
    if ctx.engine.is_xla() {
        crate::trace::count(crate::trace::Counter::EngineFallbacks, 1);
        res.note("engine_fallback", "cpu (lssvm has no accelerator path)".to_string());
    }
    res.note("n_sv", sv.len().to_string());
    res.note("operator", op.name().to_string());
    res.note("operator_bytes", op.memory_bytes().to_string());
    res.note("cg_resid", format!("{:.3e}", rs.sqrt()));
    res.note("cg_resid_eta", format!("{:.3e}", eta.residual));
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::error_rate;
    use crate::rng::Rng;
    use crate::solvers::smo;

    fn blobs(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let pos = rng.bernoulli(0.5);
            let (cx, cy) = if pos { (0.7, 0.7) } else { (0.3, 0.3) };
            x.push(cx + 0.08 * rng.gaussian_f32());
            x.push(cy + 0.08 * rng.gaussian_f32());
            y.push(if pos { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("blobs", 2, x, y)
    }

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    #[test]
    fn separates_blobs() {
        let ds = blobs(300, 41);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &LsSvmParams { c: 10.0, ..Default::default() },
        )
        .unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.03);
        assert!(r.notes.iter().any(|(k, _)| k == "operator"));
    }

    #[test]
    fn close_to_smo_on_xor() {
        let ds = xor_dataset(400, 42);
        let te = xor_dataset(400, 43);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let sp = smo::SmoParams { c: 10.0, ..Default::default() };
        let a = smo::train(&ds, kind, &sp, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, kind, &LsSvmParams { c: 10.0, ..Default::default() }).unwrap();
        let ea = error_rate(&a.model.decision_batch(&te, 2), &te.y);
        let eb = error_rate(&b.model.decision_batch(&te, 2), &te.y);
        assert!((ea - eb).abs() < 0.04, "smo {ea} vs lssvm {eb}");
    }

    #[test]
    fn exact_and_full_rank_agree() {
        let ds = blobs(150, 44);
        let kind = KernelKind::Rbf { gamma: 4.0 };
        let exact =
            train(&ds, kind, &LsSvmParams { c: 5.0, lowrank: None, ..Default::default() })
                .unwrap();
        let full = train(
            &ds,
            kind,
            &LsSvmParams {
                c: 5.0,
                lowrank: Some(LowRankConfig { rank: 150, nystrom: false, tol: 0.0 }),
                ..Default::default()
            },
        )
        .unwrap();
        let me = exact.model.decision_batch(&ds, 2);
        let mf = full.model.decision_batch(&ds, 2);
        for (a, b) in me.iter().zip(&mf) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn nystrom_operator_works() {
        let ds = blobs(300, 45);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 4.0 },
            &LsSvmParams {
                c: 10.0,
                lowrank: Some(LowRankConfig::nystrom(64)),
                ..Default::default()
            },
        )
        .unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.03);
        assert!(r.notes.iter().any(|(k, v)| k == "operator" && v == "nystrom"));
    }

    #[test]
    fn memory_cap_refusal_on_exact_path() {
        let ds = blobs(500, 46);
        let err = train(
            &ds,
            KernelKind::Rbf { gamma: 1.0 },
            &LsSvmParams { lowrank: None, max_kernel_bytes: 1024, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("memory wall"));
    }
}
