//! Primal Newton SVM (Chapelle 2007) — exact implicit reformulation,
//! full kernel matrix.
//!
//! Solves (paper eq. 3)
//!   min_b  1/2 b^T K b + C sum_i max(0, 1 - y_i (K b)_i)^2
//! by Newton's method, with the Hessian-vector products
//!   H v = K v + 2C K I_A K v
//! streamed through dense GEMVs (no Hessian materialization) and the
//! Newton system solved by CG. All heavy work is large dense linalg —
//! the implicit credo — but the full kernel matrix limits it to small n
//! (the paper excludes it from Table 1 for exactly this reason; we keep
//! the same memory cap + refusal behaviour as `mu`).
//!
//! The bias is folded in as an extra constant-1 "kernel column", matching
//! the SP-SVM convention.

use anyhow::Result;

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::operator::{build as build_operator, ExactDense, KernelOperator, LowRankConfig};
use crate::kernel::KernelKind;
use crate::linalg::dot;
use crate::model::SvmModel;

use super::api::{Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use super::TrainResult;

/// Primal Newton hyperparameters. Parallelism comes from the ctx engine
/// ([`crate::engine::Engine::threads`]), not from here.
#[derive(Debug, Clone)]
pub struct PrimalParams {
    pub c: f32,
    /// Default Newton-step cap when the ctx [`super::api::Budget`] sets
    /// none.
    pub max_newton: usize,
    pub cg_iters: usize,
    pub tol: f64,
    pub max_kernel_bytes: usize,
    /// `Some` runs every K·v against a low-rank G·Gᵀ factor — O(n·r)
    /// memory, the paper's approximate implicit regime — instead of the
    /// materialized exact kernel.
    pub lowrank: Option<LowRankConfig>,
}

impl Default for PrimalParams {
    fn default() -> Self {
        PrimalParams {
            c: 1.0,
            max_newton: 30,
            cg_iters: 120,
            tol: 1e-6,
            max_kernel_bytes: 2 << 30,
            lowrank: None,
        }
    }
}

impl SolverDriver for PrimalParams {
    fn name(&self) -> &str {
        "primal"
    }

    fn family(&self) -> Family {
        Family::Implicit
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// Legacy entry point — thin shim over the [`SolverDriver`] path (kept
/// for one release; prefer [`Trainer`]). Runs on the default-threads
/// cpu engine, matching the historical `PrimalParams::threads` default.
pub fn train(ds: &Dataset, kind: KernelKind, params: &PrimalParams) -> Result<TrainResult> {
    Trainer::new(SolverSpec::Primal(params.clone()))
        .kernel(kind)
        .engine(Engine::cpu_par(crate::pool::default_threads()))
        .train(ds)
}

struct State {
    /// margins f = K beta + bias
    f: Vec<f32>,
    loss: f64,
    /// active set: hinge > 0
    active: Vec<f32>,
}

fn eval_state(
    op: &dyn KernelOperator,
    y: &[f32],
    beta: &[f32],
    bias: f32,
    c: f32,
    reg: &mut [f32],
) -> State {
    let n = y.len();
    let mut f = vec![0.0f32; n];
    op.matvec(beta, &mut f);
    for v in f.iter_mut() {
        *v += bias;
    }
    // reg term 1/2 beta^T K beta = 1/2 beta . (f - bias)
    op.matvec(beta, reg);
    let mut loss = 0.5 * dot(beta, reg) as f64;
    let mut active = vec![0.0f32; n];
    for i in 0..n {
        let h = 1.0 - y[i] * f[i];
        if h > 0.0 {
            active[i] = 1.0;
            loss += (c * h * h) as f64;
        }
    }
    State { f, loss, active }
}

/// Train with primal Newton-CG on the full kernel; parallelism from the
/// ctx engine. The full-kernel primal has no accelerator path: an xla
/// engine falls back to the cpu substrate, surfaced as an
/// `engine_fallback` note.
fn train_ctx(ctx: &TrainCtx<'_>, params: &PrimalParams) -> Result<TrainResult> {
    let ds = ctx.ds;
    let kind = ctx.kind;
    let threads = ctx.engine.threads();
    let mut ph = crate::trace::phases();
    let n = ds.n;
    let c = params.c;
    // wall clock starts before the O(n^2) kernel build so wall budgets
    // and IterEvent.elapsed cover all of it
    let mut meter = ctx.meter("primal", params.max_newton);
    // Kernel access goes through the operator abstraction: exact
    // materialized (memory-capped) by default, or a low-rank factor.
    let op: Box<dyn KernelOperator + '_> = match params.lowrank {
        None => Box::new(ExactDense::build(&kind, ds, threads, params.max_kernel_bytes)?),
        Some(cfg) => build_operator(&kind, ds, threads, Some(cfg))?,
    };
    let op = op.as_ref();
    ph.lap("primal/kernel");

    let y = &ds.y;
    let mut beta = vec![0.0f32; n];
    let mut bias = 0.0f32;
    let mut scratch = vec![0.0f32; n];
    let mut state = eval_state(op, y, &beta, bias, c, &mut scratch);

    let mut converged = false;
    loop {
        // gradient: g = K beta + 2C K_A^T (f - y)_A ; g_bias = 2C sum_A (f - y)
        let mut resid = vec![0.0f32; n]; // a_i (f_i - y_i)
        for i in 0..n {
            resid[i] = state.active[i] * (state.f[i] - y[i]);
        }
        let mut kres = vec![0.0f32; n];
        op.matvec(&resid, &mut kres); // K is symmetric
        let mut kbeta = vec![0.0f32; n];
        op.matvec(&beta, &mut kbeta);
        let g: Vec<f32> = (0..n).map(|i| kbeta[i] + 2.0 * c * kres[i]).collect();
        let g_bias: f32 = 2.0 * c * resid.iter().sum::<f32>();

        // Newton direction by CG on H v = K v + 2C K (A .* (K v + v_b)) ;
        // bias row handled jointly. Scratch vectors are hoisted out of the
        // apply so the CG loop allocates nothing per iteration (the GEMVs
        // inside dominate and run on the blocked substrate).
        let mut kv = vec![0.0f32; n];
        let mut av = vec![0.0f32; n];
        let mut kav = vec![0.0f32; n];
        let apply = |v: &[f32],
                     vb: f32,
                     out: &mut Vec<f32>,
                     ob: &mut f32,
                     kv: &mut Vec<f32>,
                     av: &mut Vec<f32>,
                     kav: &mut Vec<f32>| {
            op.matvec(v, kv);
            for i in 0..n {
                av[i] = state.active[i] * (kv[i] + vb);
            }
            op.matvec(av, kav);
            for i in 0..n {
                out[i] = kv[i] + 2.0 * c * kav[i] + 1e-6 * v[i];
            }
            *ob = 2.0 * c * av.iter().sum::<f32>() + 1e-6 * vb;
        };
        // CG over (v, vb)
        let mut x = vec![0.0f32; n];
        let mut xb = 0.0f32;
        let mut r: Vec<f32> = g.iter().map(|v| -v).collect();
        let mut rb = -g_bias;
        let mut p = r.clone();
        let mut pb = rb;
        let mut rs = dot(&r, &r) as f64 + (rb * rb) as f64;
        let rs0 = rs;
        let mut ap = vec![0.0f32; n];
        let mut apb = 0.0f32;
        for _ in 0..params.cg_iters {
            if rs < 1e-10 * rs0.max(1.0) {
                break;
            }
            apply(&p, pb, &mut ap, &mut apb, &mut kv, &mut av, &mut kav);
            let denom = (dot(&p, &ap) as f64 + (pb * apb) as f64).max(1e-30);
            let alpha = (rs / denom) as f32;
            for i in 0..n {
                x[i] += alpha * p[i];
                r[i] -= alpha * ap[i];
            }
            xb += alpha * pb;
            rb -= alpha * apb;
            let rs_new = dot(&r, &r) as f64 + (rb * rb) as f64;
            let betac = (rs_new / rs.max(1e-30)) as f32;
            for i in 0..n {
                p[i] = r[i] + betac * p[i];
            }
            pb = rb + betac * pb;
            rs = rs_new;
        }

        // line search (backtracking, Newton step usually accepted)
        let mut step = 1.0f32;
        let mut accepted = false;
        for _ in 0..8 {
            let nb: Vec<f32> = (0..n).map(|i| beta[i] + step * x[i]).collect();
            let nbias = bias + step * xb;
            let ns = eval_state(op, y, &nb, nbias, c, &mut scratch);
            if ns.loss < state.loss {
                beta = nb;
                bias = nbias;
                let improved = (state.loss - ns.loss) / state.loss.abs().max(1.0);
                state = ns;
                accepted = true;
                // converged: the accepted Newton step no longer moves the loss
                converged = improved < params.tol;
                break;
            }
            step *= 0.5;
        }
        let cont = meter.tick(|| {
            let n_active = state.active.iter().filter(|&&a| a != 0.0).count();
            (state.loss, n_active)
        });
        if !accepted || converged || !cont {
            break;
        }
    }
    ph.lap("primal/newton");

    let sv: Vec<usize> = (0..n).filter(|&i| beta[i].abs() > 1e-7).collect();
    let vectors = ds.gather_rows(&sv);
    let coef: Vec<f32> = sv.iter().map(|&i| beta[i]).collect();
    ph.lap("primal/finalize");

    let model = SvmModel {
        kernel: kind,
        vectors,
        d: ds.d,
        coef,
        bias,
        solver: "primal".into(),
    };
    let mut res = TrainResult {
        model,
        iterations: meter.iterations(),
        objective: state.loss,
        alpha: None,
        notes: vec![],
    };
    meter.annotate(&mut res);
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", "rejected (primal betas are not box-constrained duals)".into());
    }
    if ctx.engine.is_xla() {
        crate::trace::count(crate::trace::Counter::EngineFallbacks, 1);
        res.note("engine_fallback", "cpu (full-kernel primal has no accelerator path)".to_string());
    }
    res.note("n_sv", sv.len().to_string());
    res.note("kernel_bytes", op.memory_bytes().to_string());
    res.note("operator", op.name().to_string());
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::metrics::error_rate;
    use crate::solvers::smo;

    fn xor_dataset(n: usize, seed: u64) -> Dataset {
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform_f32();
            let b = rng.uniform_f32();
            x.push(a);
            x.push(b);
            y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
        }
        Dataset::new_binary("xor", 2, x, y)
    }

    #[test]
    fn solves_xor() {
        let ds = xor_dataset(250, 1);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 8.0 },
            &PrimalParams { c: 10.0, ..Default::default() },
        )
        .unwrap();
        let margins = r.model.decision_batch(&ds, 2);
        assert!(error_rate(&margins, &ds.y) < 0.05);
        assert!(r.iterations < 30, "newton should converge fast, got {}", r.iterations);
    }

    #[test]
    fn close_to_smo_accuracy() {
        // squared vs absolute hinge: "almost identical results" (paper §4)
        let ds = xor_dataset(300, 2);
        let te = xor_dataset(300, 3);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let sp = smo::SmoParams { c: 10.0, ..Default::default() };
        let a = smo::train(&ds, kind, &sp, &Engine::cpu_seq()).unwrap();
        let b = train(&ds, kind, &PrimalParams { c: 10.0, ..Default::default() }).unwrap();
        let ea = error_rate(&a.model.decision_batch(&te, 2), &te.y);
        let eb = error_rate(&b.model.decision_batch(&te, 2), &te.y);
        assert!((ea - eb).abs() < 0.04, "smo {ea} vs primal {eb}");
    }

    #[test]
    fn lowrank_operator_close_to_exact() {
        let ds = xor_dataset(250, 6);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let base = PrimalParams { c: 10.0, ..Default::default() };
        let exact = train(&ds, kind, &base).unwrap();
        let lr = train(
            &ds,
            kind,
            &PrimalParams { lowrank: Some(LowRankConfig::icf(64)), ..base },
        )
        .unwrap();
        let e0 = error_rate(&exact.model.decision_batch(&ds, 2), &ds.y);
        let e1 = error_rate(&lr.model.decision_batch(&ds, 2), &ds.y);
        assert!(e1 < e0 + 0.05, "exact {e0} lowrank {e1}");
        assert!(lr.notes.iter().any(|(k, v)| k == "operator" && v == "icf"));
    }

    #[test]
    fn memory_cap_refusal() {
        let ds = xor_dataset(500, 4);
        let err = train(
            &ds,
            KernelKind::Rbf { gamma: 1.0 },
            &PrimalParams { max_kernel_bytes: 1024, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("memory wall"));
    }

    #[test]
    fn loss_decreases_monotonically_enough() {
        let ds = xor_dataset(150, 5);
        let r = train(
            &ds,
            KernelKind::Rbf { gamma: 6.0 },
            &PrimalParams { c: 5.0, max_newton: 3, ..Default::default() },
        )
        .unwrap();
        // 3 Newton steps beat the all-zeros loss C*n
        assert!(r.objective < 5.0 * 150.0);
    }
}
