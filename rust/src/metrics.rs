//! Evaluation metrics and timing utilities (Table 1 columns).

use std::time::{Duration, Instant};

/// Fraction of sign disagreements between margins and labels (paper's
/// "Test Error (%)" divided by 100). Ties (margin == 0) count as errors,
/// matching LibSVM's decision rule for y in {-1,+1}.
pub fn error_rate(margins: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(margins.len(), labels.len());
    assert!(!margins.is_empty());
    let errs = margins
        .iter()
        .zip(labels)
        .filter(|(f, y)| *f * *y <= 0.0)
        .count();
    errs as f64 / margins.len() as f64
}

/// Area under the ROC curve via the rank statistic (ties handled by
/// midranks). The paper reports (1 - AUC)% for MITFaces.
pub fn auc(margins: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(margins.len(), labels.len());
    let mut idx: Vec<usize> = (0..margins.len()).collect();
    idx.sort_by(|&a, &b| margins[a].partial_cmp(&margins[b]).unwrap());
    // midranks
    let n = margins.len();
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && margins[idx[j + 1]] == margins[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let npos = labels.iter().filter(|&&y| y > 0.0).count();
    let nneg = n - npos;
    if npos == 0 || nneg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&k| labels[k] > 0.0).map(|k| ranks[k]).sum();
    (rank_sum - (npos * (npos + 1)) as f64 / 2.0) / (npos as f64 * nneg as f64)
}

/// Multiclass error rate from predicted and true class ids.
pub fn multiclass_error(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let errs = pred.iter().zip(truth).filter(|(a, b)| a != b).count();
    errs as f64 / pred.len() as f64
}

/// Simple stopwatch with named laps. Solver phase breakdowns moved to
/// the process-wide trace layer ([`crate::trace::phases`]); this stays
/// for ad-hoc local timing (e.g. OvO accumulated train seconds).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        if let Some((_, acc)) = self.laps.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.laps.push((name.to_string(), d));
        }
        d
    }

    pub fn total(&self) -> Duration {
        Instant::now() - self.start
    }

    pub fn lap_secs(&self, name: &str) -> f64 {
        self.laps
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_secs_f64())
            .unwrap_or(0.0)
    }
}

/// Render a duration the way the paper's Table 1 does ("1h 5m 46s").
pub fn fmt_duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs < 1.0 {
        return format!("{:.0}ms", secs * 1e3);
    }
    let total = secs.round() as u64;
    let (h, m, s) = (total / 3600, (total % 3600) / 60, total % 60);
    match (h, m) {
        (0, 0) => format!("{:.1}s", secs),
        (0, _) => format!("{m}m {s}s"),
        _ => format!("{h}h {m}m {s}s"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rate_basic() {
        let f = [1.0, -2.0, 0.5, -0.1];
        let y = [1.0, -1.0, -1.0, -1.0];
        assert!((error_rate(&f, &y) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn error_rate_tie_counts_as_error() {
        assert_eq!(error_rate(&[0.0], &[1.0]), 1.0);
    }

    #[test]
    fn auc_perfect_ranking() {
        let f = [0.1, 0.2, 0.8, 0.9];
        let y = [-1.0, -1.0, 1.0, 1.0];
        assert!((auc(&f, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_inverted_ranking() {
        let f = [0.9, 0.8, 0.2, 0.1];
        let y = [-1.0, -1.0, 1.0, 1.0];
        assert!(auc(&f, &y).abs() < 1e-12);
    }

    #[test]
    fn auc_random_is_half() {
        let f = [0.5, 0.5, 0.5, 0.5];
        let y = [1.0, -1.0, 1.0, -1.0];
        assert!((auc(&f, &y) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_classes() {
        assert_eq!(auc(&[0.1, 0.2], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn multiclass_error_counts() {
        assert!((multiclass_error(&[0, 1, 2, 2], &[0, 1, 1, 2]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn fmt_duration_styles() {
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12ms");
        assert_eq!(fmt_duration(Duration::from_secs_f64(9.94)), "9.9s");
        assert_eq!(fmt_duration(Duration::from_secs(66)), "1m 6s");
        assert_eq!(fmt_duration(Duration::from_secs(3 * 3600 + 61)), "3h 1m 1s");
    }

    #[test]
    fn stopwatch_accumulates_laps() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        sw.lap("a");
        assert_eq!(sw.laps.len(), 2);
        assert!(sw.lap_secs("a") >= 0.0);
        assert!(sw.total().as_nanos() > 0);
    }
}
