//! SV-union merging with cross-shard adaptive shrinking and dual
//! feasibility repair.
//!
//! A merge takes a group of sub-fits (trained shard models plus their
//! dual variables, or untrained carriers), forms the union of their
//! support vectors, filters rows every *partner* model already
//! classifies with margin `> 1 + slack` (arXiv 1406.5161: such rows
//! almost never re-enter the solution, so resolving them in the merged
//! problem is wasted work), and repairs the dual equality constraint
//! Σ αᵢyᵢ = 0 that filtering can break. The output is the merged
//! problem's row set and warm-start alphas.
//!
//! Everything here is deterministic: candidates are collected in fit
//! order, the filter verdict per row is a pure function of the models,
//! the union is sorted by global row id, and the feasibility repair
//! walks rows in ascending index order.

use crate::data::Dataset;
use crate::model::SvmModel;
use crate::pool;

/// One sub-problem's outcome flowing through the cascade: the global
/// row ids it owns (ascending), its dual variables (aligned with
/// `rows`; all zero for carriers) and its model (`None` for untrained
/// carriers — single-class shards and KKT-violator feedback sets).
#[derive(Debug, Clone)]
pub struct SubFit {
    pub rows: Vec<usize>,
    pub alpha: Vec<f64>,
    pub model: Option<SvmModel>,
    /// Final objective of the sub-training (0 for carriers).
    pub objective: f64,
}

impl SubFit {
    /// An untrained carrier: rows enter the next merge with zero duals.
    pub fn carrier(rows: Vec<usize>) -> SubFit {
        let n = rows.len();
        SubFit { rows, alpha: vec![0.0; n], model: None, objective: 0.0 }
    }

    /// Number of support vectors (rows with nonzero dual).
    pub fn n_sv(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 0.0).count()
    }
}

/// A merged subproblem ready for a warm-started retrain.
#[derive(Debug, Clone)]
pub struct Merged {
    /// Global row ids, ascending.
    pub rows: Vec<usize>,
    /// Warm-start duals aligned with `rows` (feasible: Σ αᵢyᵢ = 0).
    pub alpha: Vec<f64>,
    /// Rows the adaptive-shrinking filter removed.
    pub dropped: usize,
    /// Rows entering the retrain with nonzero dual.
    pub n_sv: usize,
}

/// Merge a group of sub-fits into one warm-started subproblem.
///
/// Candidate rows are each trained fit's support vectors plus every row
/// of each untrained carrier. A candidate is dropped when **all**
/// partner models (the group's models minus the candidate's own)
/// classify it with margin `y · f > 1 + slack`; rows with no partner
/// models are always kept. If filtering would leave the merged problem
/// single-class (untrainable), it is disabled for this merge. Duplicate
/// rows keep their largest dual.
pub fn merge_group(ds: &Dataset, group: &[SubFit], slack: f64, threads: usize) -> Merged {
    // (row, alpha, owning fit) in fit order — deterministic
    let mut cands: Vec<(usize, f64, usize)> = Vec::new();
    for (k, fit) in group.iter().enumerate() {
        for (&r, &a) in fit.rows.iter().zip(&fit.alpha) {
            if fit.model.is_none() || a > 0.0 {
                cands.push((r, a, k));
            }
        }
    }

    // partner models per owning fit
    let partners: Vec<Vec<&SvmModel>> = (0..group.len())
        .map(|k| {
            group
                .iter()
                .enumerate()
                .filter(|&(j, f)| j != k && f.model.is_some())
                .map(|(_, f)| f.model.as_ref().unwrap())
                .collect()
        })
        .collect();

    let any_partner = partners.iter().any(|p| !p.is_empty());
    let keep: Vec<bool> = if any_partner && slack.is_finite() {
        pool::parallel_map(threads, cands.len(), |i| {
            let (r, _, k) = cands[i];
            let ps = &partners[k];
            if ps.is_empty() {
                return true;
            }
            let mut buf = vec![0.0f32; ds.d];
            ds.row_into(r, &mut buf);
            let y = ds.y[r] as f64;
            // keep unless every partner clears the slack margin
            !ps.iter().all(|m| y * m.decision(&buf) as f64 > 1.0 + slack)
        })
    } else {
        vec![true; cands.len()]
    };

    let mut kept: Vec<(usize, f64)> = cands
        .iter()
        .zip(&keep)
        .filter(|&(_, &k)| k)
        .map(|(&(r, a, _), _)| (r, a))
        .collect();
    // filtering must not produce an untrainable single-class problem
    if !class_balanced(ds, &kept) {
        kept = cands.iter().map(|&(r, a, _)| (r, a)).collect();
    }
    let dropped = cands.len() - kept.len();

    kept.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
    kept.dedup_by_key(|p| p.0); // keeps the first = largest-alpha copy

    let rows: Vec<usize> = kept.iter().map(|p| p.0).collect();
    let mut alpha: Vec<f64> = kept.iter().map(|p| p.1).collect();
    repair_balance(ds, &rows, &mut alpha);
    let n_sv = alpha.iter().filter(|&&a| a > 0.0).count();
    Merged { rows, alpha, dropped, n_sv }
}

fn class_balanced(ds: &Dataset, rows: &[(usize, f64)]) -> bool {
    let pos = rows.iter().any(|&(r, _)| ds.y[r] > 0.0);
    let neg = rows.iter().any(|&(r, _)| ds.y[r] < 0.0);
    pos && neg
}

/// Restore the dual equality constraint Σ αᵢyᵢ = 0 after rows were
/// dropped. The surplus side's alphas are reduced toward zero in
/// ascending row order — a deterministic projection that keeps every
/// alpha inside its box (reduction never leaves `[0, C]`). SMO/WSS
/// preserve the constraint pairwise, so a warm start that violates it
/// could never be repaired by the solver itself.
pub fn repair_balance(ds: &Dataset, rows: &[usize], alpha: &mut [f64]) {
    let mut s = 0.0f64;
    for (&r, &a) in rows.iter().zip(alpha.iter()) {
        s += a * ds.y[r] as f64;
    }
    if s == 0.0 {
        return;
    }
    let surplus_sign = if s > 0.0 { 1.0f32 } else { -1.0f32 };
    let mut excess = s.abs();
    for (&r, a) in rows.iter().zip(alpha.iter_mut()) {
        if excess <= 0.0 {
            break;
        }
        if ds.y[r] == surplus_sign && *a > 0.0 {
            let cut = a.min(excess);
            *a -= cut;
            excess -= cut;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{self, SynthSpec};

    fn blob(n: usize, d: usize, seed: u64) -> Dataset {
        synth::generate(&SynthSpec { d, ..Default::default() }, n, seed, "merge-test")
    }

    fn two_fits(ds: &Dataset) -> (SubFit, SubFit) {
        let n = ds.n;
        let a: Vec<usize> = (0..n / 2).collect();
        let b: Vec<usize> = (n / 2..n).collect();
        let fa = SubFit {
            alpha: a.iter().map(|&r| if r % 3 == 0 { 0.5 } else { 0.0 }).collect(),
            rows: a,
            model: None,
            objective: 0.0,
        };
        let fb = SubFit::carrier(b);
        (fa, fb)
    }

    #[test]
    fn union_is_sorted_and_feasible() {
        let ds = blob(60, 4, 9);
        let (fa, fb) = two_fits(&ds);
        let m = merge_group(&ds, &[fa, fb], 1.0, 2);
        assert!(m.rows.windows(2).all(|w| w[0] < w[1]));
        let s: f64 = m.rows.iter().zip(&m.alpha).map(|(&r, &a)| a * ds.y[r] as f64).sum();
        assert!(s.abs() < 1e-9, "repair left imbalance {s}");
        assert_eq!(m.dropped, 0, "no models in group, nothing may be filtered");
    }

    #[test]
    fn carrier_keeps_all_rows_with_zero_alpha() {
        let ds = blob(40, 3, 3);
        let rows: Vec<usize> = (0..ds.n).collect();
        let f = SubFit::carrier(rows.clone());
        assert_eq!(f.n_sv(), 0);
        let m = merge_group(&ds, &[f], 1.0, 1);
        assert_eq!(m.rows, rows);
        assert!(m.alpha.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn repair_reduces_surplus_side_only() {
        let ds = blob(10, 2, 5);
        // rows 0 and 1 with whatever labels they carry; force imbalance
        let rows = vec![0usize, 1];
        let y0 = ds.y[0];
        // pick alphas so the y0 side carries 1.0 excess
        let mut alpha = if ds.y[1] == y0 { vec![1.0, 0.0] } else { vec![1.5, 0.5] };
        repair_balance(&ds, &rows, &mut alpha);
        let s: f64 = rows.iter().zip(&alpha).map(|(&r, &a)| a * ds.y[r] as f64).sum();
        assert!(s.abs() < 1e-12);
        assert!(alpha.iter().all(|&a| a >= 0.0));
    }

    #[test]
    fn duplicate_rows_keep_largest_alpha() {
        let ds = blob(20, 2, 7);
        let fa = SubFit { rows: vec![0, 1], alpha: vec![0.2, 0.4], model: None, objective: 0.0 };
        let fb = SubFit { rows: vec![1, 2], alpha: vec![0.9, 0.0], model: None, objective: 0.0 };
        let m = merge_group(&ds, &[fa, fb], f64::INFINITY, 1);
        let i = m.rows.iter().position(|&r| r == 1).unwrap();
        // 0.9 survives dedup (before the feasibility repair possibly
        // reduces it, which only ever lowers values)
        assert!(m.alpha[i] <= 0.9 + 1e-12);
        assert_eq!(m.rows, vec![0, 1, 2]);
    }
}
