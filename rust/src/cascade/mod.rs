//! Cascade sharded training: the Graf et al. (NIPS 2004) cascade SVM as
//! a meta-solver over the unified [`crate::solvers`] API.
//!
//! The paper's explicit solvers parallelize *inside* one optimization
//! (threaded working-set scans, threaded kernel-row fills). The cascade
//! parallelizes *across* optimizations: partition the rows into S
//! shards, train each shard independently on the worker pool, then
//! hierarchically merge pairs (or k-way groups) of sub-models by taking
//! the union of their support vectors and retraining — warm-started
//! from the concatenated dual variables — until one model remains.
//! Because non-support rows have zero dual weight, each merge works on
//! a set far smaller than its inputs' row counts, and layer 0 (the only
//! layer that touches all n rows) is embarrassingly parallel.
//!
//! Three refinements over the textbook cascade:
//!
//! * **Cross-shard adaptive shrinking** (arXiv 1406.5161): before a
//!   merged retrain, candidate rows whose margin against every partner
//!   model already clears `1 + slack` are dropped — they are confidently
//!   classified by the other side's model and almost never return as
//!   support vectors. Dropping a row with nonzero alpha would break the
//!   dual equality constraint Σ αᵢyᵢ = 0, so the merge repairs the sum
//!   deterministically (see [`merge`]).
//! * **Warm-started layers** (cf. Glasmachers, arXiv 2207.01016): merged
//!   subproblems start from the clipped concatenation of their inputs'
//!   alphas via [`crate::solvers::api::TrainCtx::initial_alpha`], so
//!   upper layers pay a gradient rebuild instead of a full resolve.
//! * **Global KKT verification**: a cascade pass is a heuristic — a row
//!   discarded at layer 0 can be a support vector of the global
//!   problem. After the last merge the driver sweeps all n rows,
//!   streaming kernel blocks through [`crate::kernel::operator`], and
//!   feeds violators back into another warm-started retrain (Graf's
//!   outer feedback loop), bounded by `max_outer` rounds.
//!
//! Determinism: partitioning is a pure function of `(n, shards,
//! strategy, seed)`; sub-trainings run through the deterministic
//! solvers (chunk-ordered scans); merges and the KKT sweep iterate in
//! ascending row order. With `shards = 1` the driver delegates directly
//! to the inner solver — bit-identical to not using the cascade at all.

pub mod driver;
pub mod merge;
pub mod partition;

pub use driver::CascadeParams;
pub use partition::{partition, PartitionStrategy};
