//! The cascade meta-solver: [`CascadeParams`] implements
//! [`SolverDriver`], so `SolverSpec::Cascade` runs through the same
//! [`Trainer`] front door as every other solver.
//!
//! One training proceeds in three phases (see the module docs on
//! [`crate::cascade`]):
//!
//! 1. **Layer 0** — [`partition`] the rows, fan the shard trainings
//!    across the worker pool. Shard-level workers split the engine's
//!    thread budget exactly like `OvoModel::train_with` splits it over
//!    class pairs, and every sub-training shares one
//!    [`SharedRowCache`] byte budget (unique group id per subproblem,
//!    so views never alias).
//! 2. **Merge layers** — groups of `merge_width` fits are merged
//!    ([`merge::merge_group`]) and retrained warm-started until one
//!    fit remains. A `layers` cap (or an expired wall budget)
//!    collapses all remaining fits into a single final merge.
//! 3. **KKT feedback** — up to `max_outer` global sweeps stream kernel
//!    blocks through the [`KernelOperator`] built over the full
//!    dataset, feed violating rows back into a warm-started retrain,
//!    and stop as soon as a sweep finds none.
//!
//! Budget semantics: `max_iters` applies per sub-training (each
//! subproblem is its own optimization); the wall clock is global — each
//! sub-training receives only the time remaining until the cascade's
//! deadline, and an expired deadline short-circuits the remaining
//! layers (`capped = wall`). `target_objective` is not forwarded
//! (sub-objectives are not comparable to the global one).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::data::Dataset;
use crate::engine::Engine;
use crate::kernel::cache::SharedRowCache;
use crate::kernel::operator::{self, KernelOperator};
use crate::pool;
use crate::solvers::api::{Budget, Family, SolverDriver, SolverSpec, TrainCtx, Trainer};
use crate::solvers::common::cache_shards;
use crate::solvers::smo::SmoParams;
use crate::solvers::TrainResult;
use crate::trace::{self, Counter};

use super::merge::{self, SubFit};
use super::partition::{partition, PartitionStrategy};

/// Hyperparameters of the cascade meta-solver. `inner` is the dual
/// decomposition solver every subproblem runs (SMO or WSS — the
/// cascade needs box-constrained duals to merge; implicit solvers are
/// rejected at train time).
#[derive(Debug, Clone)]
pub struct CascadeParams {
    /// Layer-0 shard count (1 delegates straight to `inner`).
    pub shards: usize,
    /// Merge-layer cap; `None` = auto (merge until one fit remains).
    /// Reaching the cap collapses all remaining fits into one final
    /// merge-all retrain.
    pub layers: Option<usize>,
    /// Fits merged per group per layer (>= 2).
    pub merge_width: usize,
    /// How rows are assigned to layer-0 shards.
    pub partition: PartitionStrategy,
    /// Seed for the seeded-shuffle partition.
    pub seed: u64,
    /// Cross-shard adaptive shrinking: drop a merge candidate when all
    /// partner models give it margin `> 1 + slack`. `f64::INFINITY`
    /// disables the filter.
    pub slack: f64,
    /// Tolerance of the global KKT verification sweep.
    pub kkt_tol: f64,
    /// Maximum KKT feedback rounds after the last merge layer.
    pub max_outer: usize,
    /// Byte budget (MB) of the shared kernel-row cache all concurrent
    /// sub-trainings draw from.
    pub cache_mb: usize,
    /// The solver every subproblem runs.
    pub inner: Box<SolverSpec>,
}

impl Default for CascadeParams {
    fn default() -> Self {
        CascadeParams {
            shards: 4,
            layers: None,
            merge_width: 2,
            partition: PartitionStrategy::SeededShuffle,
            seed: 42,
            slack: 1.0,
            kkt_tol: 1e-3,
            max_outer: 5,
            cache_mb: 512,
            inner: Box::new(SolverSpec::Smo(SmoParams::default())),
        }
    }
}

impl SolverDriver for CascadeParams {
    fn name(&self) -> &str {
        "cascade"
    }

    fn family(&self) -> Family {
        self.inner.family()
    }

    fn train(&self, ctx: &TrainCtx<'_>) -> Result<TrainResult> {
        train_ctx(ctx, self)
    }
}

/// The inner solver's box constraint, doubling as the dual-solver
/// check: only SMO and WSS expose the alphas merging needs.
fn inner_c(spec: &SolverSpec) -> Result<f64> {
    match spec {
        SolverSpec::Smo(p) => Ok(p.c as f64),
        SolverSpec::Wss(p) => Ok(p.c as f64),
        SolverSpec::Cascade(_) => bail!("cascade cannot nest another cascade"),
        other => bail!(
            "cascade requires a dual decomposition inner solver (smo or wss), got '{}'",
            other.name()
        ),
    }
}

fn single_class(ds: &Dataset, rows: &[usize]) -> bool {
    let (mut pos, mut neg) = (false, false);
    for &r in rows {
        if ds.y[r] > 0.0 {
            pos = true;
        } else {
            neg = true;
        }
        if pos && neg {
            return false;
        }
    }
    true
}

/// Everything a sub-training needs besides its row set.
struct SubCfg<'a> {
    ds: &'a Dataset,
    inner: &'a SolverSpec,
    ctx: &'a TrainCtx<'a>,
    cache: &'a Arc<SharedRowCache>,
    deadline: Option<Instant>,
}

impl SubCfg<'_> {
    /// Per-subproblem budget: `max_iters` passes through, the wall is
    /// whatever remains until the cascade's global deadline.
    fn budget(&self) -> Budget {
        Budget {
            max_iters: self.ctx.budget.max_iters,
            wall: self.deadline.map(|d| d.saturating_duration_since(Instant::now())),
            target_objective: None,
        }
    }

    /// Train one subproblem over `rows` (ascending global ids) with
    /// `threads` scan workers, optionally warm-started. Returns the fit
    /// and the iterations it spent.
    fn train(
        &self,
        rows: &[usize],
        warm: Option<Vec<f32>>,
        group: u64,
        threads: usize,
    ) -> Result<(SubFit, usize)> {
        let _sp = trace::span("cascade/shard-train");
        let view = self.ds.select(rows);
        let mut t = Trainer::new(self.inner.clone())
            .kernel(self.ctx.kind)
            .engine(Engine::cpu_par(threads))
            .budget(self.budget())
            .shared_cache(self.cache.clone(), group);
        if let Some(w) = warm {
            t = t.initial_alpha(w);
        }
        let res = t.train(&view)?;
        trace::count(Counter::CascadeShardsTrained, 1);
        let alpha = res
            .alpha
            .ok_or_else(|| anyhow!("inner solver '{}' returned no duals", self.inner.name()))?;
        let fit = SubFit {
            rows: rows.to_vec(),
            alpha: alpha.iter().map(|&a| a as f64).collect(),
            model: Some(res.model),
            objective: res.objective,
        };
        Ok((fit, res.iterations))
    }
}

fn train_ctx(ctx: &TrainCtx<'_>, p: &CascadeParams) -> Result<TrainResult> {
    let c = inner_c(&p.inner)?;
    let ds = ctx.ds;
    let n = ds.n;
    if p.shards <= 1 || n < 2 * p.shards {
        // degenerate cascade: delegate to the inner solver with the
        // caller's ctx untouched — bit-identical to not cascading
        let mut res = p.inner.driver().train(ctx)?;
        res.note("cascade_shards", "1".to_string());
        return Ok(res);
    }

    let start = Instant::now();
    let deadline = ctx.budget.wall.map(|w| start + w);
    let threads = ctx.engine.threads().max(1);
    let cache =
        Arc::new(SharedRowCache::new(p.cache_mb * 1024 * 1024, cache_shards(threads)));
    let cfg = SubCfg { ds, inner: &p.inner, ctx, cache: &cache, deadline };

    // ---- layer 0: independent shard trainings -----------------------
    let shards_idx = partition(n, p.shards, p.partition, p.seed);
    let n_shards = shards_idx.len();
    let workers = threads.min(n_shards).max(1);
    let per = (threads / workers).max(1);
    let results: Vec<Result<(SubFit, usize)>> =
        pool::parallel_map(workers, n_shards, |k| {
            let rows = &shards_idx[k];
            if single_class(ds, rows) {
                // untrainable shard (class-sorted file + contiguous
                // partition): carry its rows into the merge with zero
                // duals instead of failing
                return Ok((SubFit::carrier(rows.clone()), 0));
            }
            cfg.train(rows, None, k as u64, per)
        });
    let mut fits = Vec::with_capacity(n_shards);
    let mut total_iters = 0usize;
    for r in results {
        let (f, it) = r?;
        total_iters += it;
        fits.push(f);
    }

    // ---- merge layers ------------------------------------------------
    let mut layer_no = 0u64;
    let mut layers_run = 0usize;
    let mut capped_wall = false;
    while fits.len() > 1 {
        layer_no += 1;
        let expired = deadline.is_some_and(|d| Instant::now() >= d);
        capped_wall |= expired;
        let width = match p.layers {
            // reached the layer cap: one final merge-all
            Some(cap) if layers_run + 1 >= cap => fits.len(),
            // wall budget spent: collapse now, sub-budgets are ~zero
            _ if expired => fits.len(),
            _ => p.merge_width.max(2),
        };
        let old = std::mem::take(&mut fits);
        let mut groups: Vec<Vec<SubFit>> = Vec::new();
        let mut cur: Vec<SubFit> = Vec::new();
        for f in old {
            cur.push(f);
            if cur.len() == width {
                groups.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        let gw = threads.min(groups.len()).max(1);
        let per = (threads / gw).max(1);
        let results: Vec<Result<(SubFit, usize)>> =
            pool::parallel_map(gw, groups.len(), |g| {
                let group = &groups[g];
                if group.len() == 1 {
                    return Ok((group[0].clone(), 0));
                }
                let _sp = trace::span("cascade/merge");
                let merged = merge::merge_group(ds, group, p.slack, per);
                trace::count(Counter::CascadeSvsMerged, merged.n_sv as u64);
                if merged.rows.is_empty() || single_class(ds, &merged.rows) {
                    return Ok((SubFit::carrier(merged.rows), 0));
                }
                let warm: Vec<f32> = merged.alpha.iter().map(|&a| a as f32).collect();
                cfg.train(&merged.rows, Some(warm), (layer_no << 32) | g as u64, per)
            });
        for r in results {
            let (f, it) = r?;
            total_iters += it;
            fits.push(f);
        }
        layers_run += 1;
    }
    let mut fina = fits.pop().expect("cascade always keeps at least one fit");
    if fina.model.is_none() {
        bail!("cascade: the merged problem never contained both classes");
    }

    // ---- global KKT verification + feedback --------------------------
    let op = operator::build(&ctx.kind, ds, threads, None)?;
    let mut outer_rounds = 0usize;
    let mut total_violations = 0usize;
    let mut converged = false;
    for _round in 0..p.max_outer.max(1) {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            capped_wall = true;
            break;
        }
        let viol = {
            let _sp = trace::span("cascade/kkt-sweep");
            kkt_violators(ds, op.as_ref(), &fina, c, p.kkt_tol)
        };
        outer_rounds += 1;
        if viol.is_empty() {
            converged = true;
            break;
        }
        trace::count(Counter::CascadeKktViolations, viol.len() as u64);
        total_violations += viol.len();
        layer_no += 1;
        let group = [fina, SubFit::carrier(viol)];
        let merged = merge::merge_group(ds, &group, p.slack, threads);
        trace::count(Counter::CascadeSvsMerged, merged.n_sv as u64);
        let warm: Vec<f32> = merged.alpha.iter().map(|&a| a as f32).collect();
        let (nf, it) = cfg.train(&merged.rows, Some(warm), layer_no << 32, threads)?;
        total_iters += it;
        fina = nf;
    }

    // ---- assemble the global result ----------------------------------
    let n_sv = fina.n_sv();
    let mut alpha_full = vec![0.0f32; n];
    for (&r, &a) in fina.rows.iter().zip(&fina.alpha) {
        alpha_full[r] = a as f32;
    }
    let mut model = fina.model.take().expect("checked above");
    model.solver = format!("cascade({})", p.inner.name());
    let mut res = TrainResult {
        model,
        iterations: total_iters,
        objective: fina.objective,
        alpha: Some(alpha_full),
        notes: vec![],
    };
    res.note("n_sv", n_sv.to_string());
    res.note("cascade_shards", n_shards.to_string());
    res.note("cascade_layers", layers_run.to_string());
    res.note("cascade_partition", p.partition.as_str().to_string());
    res.note("cascade_outer_rounds", outer_rounds.to_string());
    res.note("cascade_kkt_violations", total_violations.to_string());
    let kkt_verdict = if converged {
        "converged"
    } else if capped_wall {
        "wall"
    } else {
        "max-outer"
    };
    res.note("cascade_kkt", kkt_verdict.to_string());
    if ctx.initial_alpha.is_some() {
        res.note("warm_start", "rejected (cascade seeds its own layers)".to_string());
    }
    if capped_wall {
        res.note("capped", "wall".to_string());
    }
    Ok(res)
}

/// Rows outside the fit's training set that violate the global KKT
/// conditions at tolerance `tol`, in ascending order. Kernel values
/// stream through [`KernelOperator::block`] in fixed-size row chunks;
/// decision values accumulate in f64 in support-vector order, so the
/// sweep is deterministic for every thread count.
fn kkt_violators(
    ds: &Dataset,
    op: &dyn KernelOperator,
    fit: &SubFit,
    c: f64,
    tol: f64,
) -> Vec<usize> {
    let mut sv = Vec::new();
    let mut coef = Vec::new();
    for (&r, &a) in fit.rows.iter().zip(&fit.alpha) {
        if a > 0.0 {
            sv.push(r);
            coef.push(a * ds.y[r] as f64);
        }
    }
    if sv.is_empty() {
        return Vec::new();
    }
    let bias = fit.model.as_ref().map_or(0.0, |m| m.bias as f64);
    const CHUNK: usize = 256;
    let mut buf = vec![0.0f32; CHUNK.min(ds.n) * sv.len()];
    let mut out = Vec::new();
    let mut startr = 0;
    while startr < ds.n {
        let endr = (startr + CHUNK).min(ds.n);
        let rows_chunk: Vec<usize> = (startr..endr).collect();
        let b = &mut buf[..rows_chunk.len() * sv.len()];
        op.block(&rows_chunk, &sv, b);
        for (q, &r) in rows_chunk.iter().enumerate() {
            let mut f = bias;
            for (j, &cf) in coef.iter().enumerate() {
                f += cf * b[q * sv.len() + j] as f64;
            }
            let margin = ds.y[r] as f64 * f;
            // alpha of r: rows are sorted, so binary search
            let a = match fit.rows.binary_search(&r) {
                Ok(i) => fit.alpha[i],
                Err(_) => 0.0,
            };
            let violates = if a <= 0.0 {
                margin < 1.0 - tol
            } else if a >= c {
                margin > 1.0 + tol
            } else {
                (margin - 1.0).abs() > tol
            };
            // only rows the subproblem has never seen are fed back:
            // in-set rows already satisfy KKT to the inner solver's eps,
            // and excluding them makes the feedback set strictly new,
            // so the outer loop terminates
            if violates && fit.rows.binary_search(&r).is_err() {
                out.push(r);
            }
        }
        startr = endr;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{self, SynthSpec};

    #[test]
    fn inner_c_accepts_dual_solvers_only() {
        assert_eq!(inner_c(&SolverSpec::Smo(Default::default())).unwrap(), 1.0);
        assert_eq!(inner_c(&SolverSpec::Wss(Default::default())).unwrap(), 1.0);
        assert!(inner_c(&SolverSpec::Mu(Default::default())).is_err());
        assert!(inner_c(&SolverSpec::Cascade(Default::default())).is_err());
    }

    #[test]
    fn default_params_are_sane() {
        let p = CascadeParams::default();
        assert_eq!(p.name(), "cascade");
        assert_eq!(p.family(), Family::Explicit);
        assert!(p.shards >= 2 && p.merge_width >= 2 && p.max_outer >= 1);
        assert!(p.kkt_tol > 0.0 && p.slack > 0.0);
    }

    #[test]
    fn single_class_detection() {
        let ds = synth::generate(&SynthSpec { d: 3, ..Default::default() }, 50, 11, "t");
        let pos: Vec<usize> = (0..ds.n).filter(|&i| ds.y[i] > 0.0).collect();
        assert!(single_class(&ds, &pos));
        assert!(single_class(&ds, &[]));
        assert!(!single_class(&ds, &(0..ds.n).collect::<Vec<_>>()));
    }

    #[test]
    fn kkt_violators_empty_without_svs() {
        let ds = synth::generate(&SynthSpec { d: 3, ..Default::default() }, 30, 2, "t");
        let op = operator::build(&crate::kernel::KernelKind::Rbf { gamma: 0.5 }, &ds, 1, None)
            .unwrap();
        let fit = SubFit::carrier((0..ds.n).collect());
        assert!(kkt_violators(&ds, op.as_ref(), &fit, 1.0, 1e-3).is_empty());
    }
}
