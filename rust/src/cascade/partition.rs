//! Row partitioning for cascade layer 0.
//!
//! A partition is a pure, sequential function of `(n, shards, strategy,
//! seed)` — never of thread count or timing — so a cascade run is
//! reproducible across machines and worker counts. Every row index
//! appears in exactly one shard, shard sizes differ by at most one, and
//! each shard's indices are sorted ascending (so shard views preserve
//! the dataset's row order and kernel-row caches see stable keys).

use crate::rng::Rng;

/// How rows are assigned to layer-0 shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Consecutive row ranges. Cheapest and cache-friendliest, but a
    /// class-sorted file yields single-class shards (the driver carries
    /// such shards into the merge untrained rather than failing).
    Contiguous,
    /// Row i goes to shard `i % shards`. Spreads any global ordering
    /// (class-sorted, time-sorted) evenly across shards.
    RoundRobin,
    /// A seeded Fisher–Yates shuffle of `0..n` chunked into shards —
    /// the robust default: statistically class-balanced shards
    /// regardless of file order, still fully deterministic.
    SeededShuffle,
}

impl PartitionStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            PartitionStrategy::Contiguous => "contiguous",
            PartitionStrategy::RoundRobin => "round-robin",
            PartitionStrategy::SeededShuffle => "seeded-shuffle",
        }
    }

    /// Parse a CLI key (`contiguous | round-robin | seeded-shuffle`).
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        match s {
            "contiguous" => Some(PartitionStrategy::Contiguous),
            "round-robin" | "roundrobin" => Some(PartitionStrategy::RoundRobin),
            "seeded-shuffle" | "shuffle" => Some(PartitionStrategy::SeededShuffle),
            _ => None,
        }
    }
}

/// Split `0..n` into `shards` index lists. Deterministic for a given
/// `(n, shards, strategy, seed)`; shards are sorted ascending and sized
/// within one row of each other. `shards` is clamped to `[1, n]` (no
/// empty shards as long as `n > 0`).
pub fn partition(
    n: usize,
    shards: usize,
    strategy: PartitionStrategy,
    seed: u64,
) -> Vec<Vec<usize>> {
    if n == 0 {
        return vec![Vec::new(); shards.max(1)];
    }
    let s = shards.clamp(1, n);
    let mut out: Vec<Vec<usize>> = (0..s).map(|_| Vec::with_capacity(n / s + 1)).collect();
    match strategy {
        PartitionStrategy::Contiguous => {
            // first (n % s) shards take one extra row
            let base = n / s;
            let extra = n % s;
            let mut start = 0;
            for (k, shard) in out.iter_mut().enumerate() {
                let len = base + usize::from(k < extra);
                shard.extend(start..start + len);
                start += len;
            }
        }
        PartitionStrategy::RoundRobin => {
            for i in 0..n {
                out[i % s].push(i);
            }
        }
        PartitionStrategy::SeededShuffle => {
            let mut idx: Vec<usize> = (0..n).collect();
            Rng::new(seed).shuffle(&mut idx);
            for (k, chunk) in out.iter_mut().enumerate() {
                let base = n / s;
                let extra = n % s;
                let start = k * base + k.min(extra);
                let len = base + usize::from(k < extra);
                chunk.extend_from_slice(&idx[start..start + len]);
                chunk.sort_unstable();
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const STRATEGIES: [PartitionStrategy; 3] = [
        PartitionStrategy::Contiguous,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::SeededShuffle,
    ];

    #[test]
    fn every_row_exactly_once_and_balanced() {
        for &strat in &STRATEGIES {
            for &(n, s) in &[(10usize, 3usize), (100, 7), (5, 5), (17, 4), (8, 1)] {
                let parts = partition(n, s, strat, 42);
                assert_eq!(parts.len(), s.min(n));
                let mut seen: Vec<usize> = parts.iter().flatten().copied().collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..n).collect::<Vec<_>>(), "{strat:?} n={n} s={s}");
                let (lo, hi) = (
                    parts.iter().map(Vec::len).min().unwrap(),
                    parts.iter().map(Vec::len).max().unwrap(),
                );
                assert!(hi - lo <= 1, "{strat:?}: unbalanced {lo}..{hi}");
                for p in &parts {
                    assert!(p.windows(2).all(|w| w[0] < w[1]), "{strat:?}: unsorted shard");
                }
            }
        }
    }

    #[test]
    fn deterministic_for_seed_and_seed_sensitive() {
        let a = partition(200, 8, PartitionStrategy::SeededShuffle, 7);
        let b = partition(200, 8, PartitionStrategy::SeededShuffle, 7);
        assert_eq!(a, b);
        let c = partition(200, 8, PartitionStrategy::SeededShuffle, 8);
        assert_ne!(a, c, "different seeds should shuffle differently");
        // seed is irrelevant to the deterministic strategies
        assert_eq!(
            partition(200, 8, PartitionStrategy::Contiguous, 1),
            partition(200, 8, PartitionStrategy::Contiguous, 2),
        );
    }

    #[test]
    fn shard_count_clamps_to_rows() {
        let parts = partition(3, 10, PartitionStrategy::RoundRobin, 0);
        assert_eq!(parts.len(), 3);
        assert!(parts.iter().all(|p| p.len() == 1));
        let empty = partition(0, 4, PartitionStrategy::Contiguous, 0);
        assert!(empty.iter().all(Vec::is_empty));
    }

    #[test]
    fn parse_round_trips() {
        for &s in &STRATEGIES {
            assert_eq!(PartitionStrategy::parse(s.as_str()), Some(s));
        }
        assert_eq!(PartitionStrategy::parse("bogus"), None);
    }
}
