//! Cascade sharded-training benchmarks: direct SMO versus the cascade
//! meta-solver at increasing layer-0 shard counts, on one synthetic
//! workload. The cascade trades a global KKT verification sweep (plus
//! any feedback retrains) for embarrassingly parallel sub-trainings on
//! n/S-row subproblems — the quadratic-solver term shrinks by ~S^2 per
//! shard while the merge layers re-pay part of it on the SV union
//! (rust/EXPERIMENTS.md §CASCADE). Emits `BENCH_cascade.json`.
//!
//! Run: `cargo bench --bench cascade [-- --n 12000 --d 32]`

use wu_svm::bench_util::{bench, header, smoke, smoke_or};
use wu_svm::cascade::CascadeParams;
use wu_svm::config::Config;
use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::pool;
use wu_svm::solvers::smo::SmoParams;
use wu_svm::solvers::{SolverSpec, Trainer};

fn spec_for(shards: usize) -> SolverSpec {
    let inner = SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() });
    if shards <= 1 {
        inner
    } else {
        SolverSpec::Cascade(CascadeParams {
            shards,
            inner: Box::new(inner),
            ..Default::default()
        })
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let n = cfg.usize_or("n", smoke_or(600, 12_000)).unwrap();
    let d = cfg.usize_or("d", 32).unwrap();
    let threads = pool::default_threads();
    let runs = smoke_or(1, 3);
    let shard_counts = [1usize, 2, 4, 8];

    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 8,
        sigma: 0.25,
        flip: 0.02,
        sparsity: 0.0,
        pos_frac: 0.5,
    };
    let train = generate(&spec, n, 42, "cascade-bench-train");
    let test = generate(&spec, (n / 4).max(100), 4242, "cascade-bench-test");
    let kind = KernelKind::Rbf { gamma: 0.5 };
    println!("workload: n={n} d={d} ({threads} threads)");

    let trace_session = wu_svm::trace::Session::start();

    header("smo direct vs cascade (S shards, hierarchical merge + KKT sweep)");
    let mut times_ms = Vec::new();
    let mut errs = Vec::new();
    let mut svs = Vec::new();
    let mut feedback = Vec::new();
    for &s in &shard_counts {
        let summary = bench(&format!("S={s} [{threads}t]"), 1, runs, || {
            Trainer::new(spec_for(s))
                .kernel(kind)
                .engine(Engine::cpu_par(threads))
                .train(&train)
                .unwrap();
        });
        println!("{}", summary.row());
        let r = Trainer::new(spec_for(s))
            .kernel(kind)
            .engine(Engine::cpu_par(threads))
            .train(&train)
            .unwrap();
        let margins = r.model.decision_batch(&test, threads);
        let wrong = margins
            .iter()
            .zip(&test.y)
            .filter(|(m, y)| (**m > 0.0) != (**y > 0.0))
            .count();
        let err = wrong as f64 / test.n as f64;
        let fb: usize = r
            .notes
            .iter()
            .find(|(k, _)| k == "cascade_kkt_violations")
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(0);
        println!(
            "  S={s}: test err {err:.4}  n_sv {}  kkt feedback rows {fb}",
            r.model.coef.len()
        );
        times_ms.push(summary.median.as_secs_f64() * 1e3);
        errs.push(err);
        svs.push(r.model.coef.len());
        feedback.push(fb);
    }
    let speedup_s4 = times_ms[0] / times_ms[2].max(1e-9);
    println!("cascade S=4 vs direct: {speedup_s4:.2}x");

    let counters = trace_session.finish().counters_json();
    if smoke() {
        println!("BENCH_SMOKE=1: skipping BENCH_cascade.json (not a measurement)");
        return;
    }
    let list = |v: &[f64]| {
        v.iter().map(|x| format!("{x:.4}")).collect::<Vec<_>>().join(", ")
    };
    let ilist = |v: &[usize]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
    };
    // the embedded schema is required by ci/check_bench_json.py, which
    // validates the checked-in copy of this file on every CI run
    let schema = "\"schema\": {\n    \
         \"workload\": \"n training rows, d features; test split is n/4 fresh rows\",\n    \
         \"threads\": \"worker threads shared by every configuration\",\n    \
         \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
         \"shards\": \"layer-0 shard counts measured, in order (1 = direct smo, no cascade)\",\n    \
         \"train_ms\": \"median end-to-end train wall time per shard count\",\n    \
         \"test_err\": \"held-out error rate per shard count\",\n    \
         \"n_sv\": \"support vectors in the final model per shard count\",\n    \
         \"kkt_feedback_rows\": \"violators fed back by the global KKT sweep per shard count\",\n    \
         \"speedup_s4\": \"train_ms[S=1] / train_ms[S=4]\",\n    \
         \"counters\": \"trace-layer runtime counter snapshot over the bench (ci cross-checks the cache identity)\"\n  }";
    let json = format!(
        "{{\n  \"workload\": {{\"n\": {n}, \"d\": {d}}},\n  \
         \"threads\": {threads},\n  \
         \"backend\": \"{}\",\n  \
         \"shards\": [{}],\n  \
         \"train_ms\": [{}],\n  \
         \"test_err\": [{}],\n  \
         \"n_sv\": [{}],\n  \
         \"kkt_feedback_rows\": [{}],\n  \
         \"speedup_s4\": {speedup_s4:.3},\n  \
         \"counters\": {counters},\n  {schema}\n}}\n",
        wu_svm::linalg::simd::active().name(),
        ilist(&shard_counts),
        list(&times_ms),
        list(&errs),
        ilist(&svs),
        ilist(&feedback),
    );
    match std::fs::write("BENCH_cascade.json", &json) {
        Ok(()) => println!("wrote BENCH_cascade.json:\n{json}"),
        Err(e) => eprintln!("could not write BENCH_cascade.json: {e}"),
    }
}
