//! F.scaling + F.basis + F.memory — the prose-claim figures (DESIGN.md
//! §6): thread-count speedup for explicit vs implicit, SP-SVM's basis
//! size/accuracy trade-off, and the memory wall that excludes the exact
//! implicit methods from Table 1.
//!
//! Run: `cargo bench --bench scaling [-- --dataset covertype --scale 0.01]`

use wu_svm::bench_util::{smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::experiments;
use wu_svm::pool;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let dataset = cfg.str_or("dataset", "covertype");
    let scale = cfg.f64_or("scale", smoke_or(0.002, 0.01)).unwrap();

    let max_t = pool::default_threads();
    let mut threads = vec![1usize, 2];
    if !smoke() {
        threads.push(4);
        if max_t >= 8 {
            threads.push(8);
        }
        if max_t > 8 {
            threads.push(max_t);
        }
    }

    match experiments::run_scaling(&dataset, scale, &threads) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("scaling failed: {e:#}"),
    }

    let basis: &[usize] = if smoke() { &[15, 31] } else { &[15, 31, 63, 127, 255] };
    match experiments::run_basis_sweep(&dataset, scale, basis) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("basis sweep failed: {e:#}"),
    }

    println!(
        "{}",
        experiments::run_memory_table(
            &[1_000, 10_000, 31_562, 100_000, 489_410, 4_898_431],
            511
        )
    );
}
