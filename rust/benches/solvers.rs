//! Solver-level benchmarks on a fixed mid-size workload: time-to-model
//! for each algorithm/engine pair plus the F.wss and F.epsstop ablations
//! (DESIGN.md §6).
//!
//! Run: `cargo bench --bench solvers [-- --scale 0.02]`

use wu_svm::bench_util::{bench_once, header, smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::coordinator::{run, EngineChoice, Solver, TrainJob};
use wu_svm::experiments;
use wu_svm::pool;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let scale = cfg.f64_or("scale", smoke_or(0.002, 0.01)).unwrap();
    let dataset = cfg.str_or("dataset", "covertype");
    let threads = pool::default_threads();

    header(&format!("solvers on {dataset} (scale {scale})"));
    let cases: Vec<(String, Solver, EngineChoice)> = vec![
        ("smo[cpu-seq]".into(), Solver::Smo, EngineChoice::CpuSeq),
        (format!("smo[cpu-par({threads})]"), Solver::Smo, EngineChoice::CpuPar(threads)),
        ("smo[xla]".into(), Solver::Smo, EngineChoice::Xla),
        ("wss16[xla]".into(), Solver::Wss, EngineChoice::Xla),
        (format!("spsvm[cpu-par({threads})]"), Solver::SpSvm, EngineChoice::CpuPar(threads)),
        ("spsvm[xla]".into(), Solver::SpSvm, EngineChoice::Xla),
        (format!("mu[cpu-par({threads})]"), Solver::Mu, EngineChoice::CpuPar(threads)),
        (format!("primal[cpu-par({threads})]"), Solver::Primal, EngineChoice::CpuPar(threads)),
    ];
    for (name, solver, engine) in cases {
        let job = TrainJob {
            dataset: dataset.clone(),
            scale,
            solver,
            engine,
            max_basis: 255,
            ..Default::default()
        };
        let mut metric = f64::NAN;
        let s = bench_once(&name, || match run(&job) {
            Ok(rec) => metric = rec.test_metric,
            Err(e) => eprintln!("  {name}: {e}"),
        });
        println!("{}   metric={:.4}", s.row(), metric);
    }

    // Tentpole check: explicitly-parallel SMO (threaded WSS+gradient scans
    // and active-set shrinking) against the seed cpu-par behavior (kernel
    // rows threaded, scans sequential, no shrinking) on a synthetic
    // n >= 4000 RBF problem.
    header(&format!(
        "smo hot loop on synthetic rbf n=4000 (cpu-par({threads}))"
    ));
    {
        use wu_svm::data::synth::{generate, SynthSpec};
        use wu_svm::engine::Engine;
        use wu_svm::kernel::KernelKind;
        use wu_svm::solvers::smo::{self, SmoParams};
        let spec = SynthSpec {
            d: 24,
            classes: 2,
            clusters: 8,
            sigma: 0.08,
            flip: 0.02,
            sparsity: 0.0,
            pos_frac: 0.5,
        };
        let ds = generate(&spec, smoke_or(600, 4000), 42, "smo-bench");
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let engine = Engine::cpu_par(threads);
        let seed_params = SmoParams {
            c: 5.0,
            shrinking: false,
            scan_threads: 1,
            ..Default::default()
        };
        let new_params = SmoParams { c: 5.0, ..Default::default() };
        let mut objs = (f64::NAN, f64::NAN);
        let s_old = bench_once("smo seed-style [seq scans, no shrinking]", || {
            objs.0 = smo::train(&ds, kind, &seed_params, &engine).unwrap().objective;
        });
        println!("{}   objective={:.6}", s_old.row(), objs.0);
        let s_new = bench_once("smo parallel scans + shrinking", || {
            objs.1 = smo::train(&ds, kind, &new_params, &engine).unwrap().objective;
        });
        println!("{}   objective={:.6}", s_new.row(), objs.1);
        let speedup = s_old.median.as_secs_f64() / s_new.median.as_secs_f64().max(1e-9);
        println!("parallel WSS+gradient+shrinking speedup vs seed cpu-par: {speedup:.2}x");
    }

    // F.wss ablation (cpu engine so it runs without artifacts)
    header("F.wss: working-set size (GTSVM's 16 vs SMO's 2)");
    let wss_sizes: &[usize] = if smoke() { &[2, 16] } else { &[2, 4, 8, 16, 32] };
    for &s in wss_sizes {
        let job = TrainJob {
            dataset: dataset.clone(),
            scale,
            solver: Solver::Wss,
            engine: EngineChoice::CpuPar(threads),
            wss_size: s,
            ..Default::default()
        };
        let mut metric = f64::NAN;
        let smp = bench_once(&format!("wss s={s}"), || match run(&job) {
            Ok(rec) => metric = rec.test_metric,
            Err(e) => eprintln!("  wss{s}: {e}"),
        });
        println!("{}   metric={:.4}", smp.row(), metric);
    }

    // F.epsstop ablation
    header("F.epsstop: SP-SVM stopping threshold");
    let epss: &[f64] = if smoke() { &[1e-3] } else { &[1e-3, 1e-4, 1e-5, 5e-6] };
    match experiments::run_eps_sweep(&dataset, scale, epss) {
        Ok(t) => println!("{t}"),
        Err(e) => eprintln!("eps sweep failed: {e}"),
    }
}
