//! Out-of-core benchmarks: SMO over an mmap-backed packed design at a
//! sweep of kernel-row cache budgets (hit rate vs wall time), plus a
//! polish on/off comparison at the tightest budget (error delta and
//! objective movement). The mmap path is bit-identical to in-memory
//! training (rust/tests/ooc_props.rs), so what this bench measures is
//! purely the cache economics of streaming rows off disk
//! (rust/EXPERIMENTS.md §OOC). Emits `BENCH_ooc.json`.
//!
//! Run: `cargo bench --bench ooc [-- --n 8000 --d 48]`

use wu_svm::bench_util::{bench, header, smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::data::{pack, Dataset};
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::pool;
use wu_svm::solvers::smo::{self, SmoParams};

fn note_f64(r: &wu_svm::solvers::TrainResult, key: &str) -> f64 {
    r.notes
        .iter()
        .find(|(k, _)| k == key)
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(f64::NAN)
}

fn err_rate(model: &wu_svm::model::SvmModel, test: &Dataset, threads: usize) -> f64 {
    let margins = model.decision_batch(test, threads);
    wu_svm::metrics::error_rate(&margins, &test.y)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let n = cfg.usize_or("n", smoke_or(500, 8_000)).unwrap();
    let d = cfg.usize_or("d", 48).unwrap();
    let threads = pool::default_threads();
    let runs = smoke_or(1, 3);
    let budgets_mb = [1usize, 4, 16, 64];

    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 8,
        sigma: 0.25,
        flip: 0.02,
        sparsity: 0.0,
        pos_frac: 0.5,
    };
    let train_mem = generate(&spec, n, 42, "ooc-bench-train");
    let test = generate(&spec, (n / 4).max(100), 4242, "ooc-bench-test");
    let packed = std::env::temp_dir().join("wu_svm_ooc_bench.wup");
    pack::write_packed(&train_mem, &packed).unwrap();
    let train = pack::load_packed(&packed).unwrap();
    assert!(train.design.is_mmap());
    let kind = KernelKind::Rbf { gamma: 0.5 };
    let engine = Engine::cpu_par(threads);
    println!("workload: n={n} d={d} mmap-backed ({threads} threads)");

    let trace_session = wu_svm::trace::Session::start();

    header("smo over the mmap design: cache budget vs hit rate / wall time");
    let mut times_ms = Vec::new();
    let mut hit_rates = Vec::new();
    for &mb in &budgets_mb {
        let params = SmoParams { c: 10.0, cache_mb: mb, ..Default::default() };
        let summary = bench(&format!("cache {mb:>3} MB [{threads}t]"), 1, runs, || {
            smo::train(&train, kind, &params, &engine).unwrap();
        });
        println!("{}", summary.row());
        let r = smo::train(&train, kind, &params, &engine).unwrap();
        let rate = note_f64(&r, "cache_hit_rate");
        println!("  {mb} MB: hit rate {rate:.3}  n_sv {}", r.model.coef.len());
        times_ms.push(summary.median.as_secs_f64() * 1e3);
        hit_rates.push(rate);
    }

    header("polish on/off at the tightest budget");
    let tight = SmoParams { c: 10.0, cache_mb: budgets_mb[0], ..Default::default() };
    let off = smo::train(&train, kind, &tight, &engine).unwrap();
    let on = smo::train(
        &train,
        kind,
        &SmoParams { polish: true, cache_slack: 0.5, ..tight.clone() },
        &engine,
    )
    .unwrap();
    let err_off = err_rate(&off.model, &test, threads);
    let err_on = err_rate(&on.model, &test, threads);
    let polish_err_delta = err_off - err_on;
    println!(
        "polish off: err {err_off:.4} obj {:.6}   polish on: err {err_on:.4} obj {:.6} \
         (delta {polish_err_delta:+.4}, {} steps)",
        off.objective,
        on.objective,
        note_f64(&on, "polish_steps"),
    );

    let counters = trace_session.finish().counters_json();
    std::fs::remove_file(&packed).ok();
    if smoke() {
        println!("BENCH_SMOKE=1: skipping BENCH_ooc.json (not a measurement)");
        return;
    }
    let list = |v: &[f64]| {
        v.iter().map(|x| format!("{x:.4}")).collect::<Vec<_>>().join(", ")
    };
    let ilist = |v: &[usize]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
    };
    // the embedded schema is required by ci/check_bench_json.py, which
    // also cross-checks the sweep coherence (hit_rate in [0,1], rising
    // with the budget) and the polish_err_delta presence
    let schema = "\"schema\": {\n    \
         \"workload\": \"n training rows, d features; the design is trained from an mmap-backed packed file\",\n    \
         \"threads\": \"worker threads shared by every configuration\",\n    \
         \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
         \"cache_mb\": \"kernel-row cache budgets swept, in MB, strictly increasing\",\n    \
         \"train_ms\": \"median end-to-end train wall time per budget\",\n    \
         \"hit_rate\": \"kernel-row cache hit rate per budget (should rise with the budget)\",\n    \
         \"polish_err_delta\": \"test error (polish off) - test error (polish on) at the tightest budget\",\n    \
         \"counters\": \"trace-layer runtime counter snapshot over the bench (ci cross-checks the cache identity)\"\n  }";
    let json = format!(
        "{{\n  \"workload\": {{\"n\": {n}, \"d\": {d}}},\n  \
         \"threads\": {threads},\n  \
         \"backend\": \"{}\",\n  \
         \"cache_mb\": [{}],\n  \
         \"train_ms\": [{}],\n  \
         \"hit_rate\": [{}],\n  \
         \"polish_err_delta\": {polish_err_delta:.4},\n  \
         \"counters\": {counters},\n  {schema}\n}}\n",
        wu_svm::linalg::simd::active().name(),
        ilist(&budgets_mb),
        list(&times_ms),
        list(&hit_rates),
    );
    match std::fs::write("BENCH_ooc.json", &json) {
        Ok(()) => println!("wrote BENCH_ooc.json:\n{json}"),
        Err(e) => eprintln!("could not write BENCH_ooc.json: {e}"),
    }
}
