//! Low-rank kernel-operator benchmarks: pivoted-ICF factorization cost,
//! operator matvec against the exact tiled route, and the LS-SVM solve
//! it unlocks — the memory/time trade the approximate-implicit path
//! buys (rust/EXPERIMENTS.md §LOWRANK). Emits machine-readable
//! `BENCH_lowrank.json`.
//!
//! Run: `cargo bench --bench lowrank [-- --n 8000 --d 64 --rank 256]`

use wu_svm::bench_util::{bench, header, smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::kernel::operator::{ExactTiled, KernelOperator, LowRank, LowRankConfig};
use wu_svm::kernel::KernelKind;
use wu_svm::pool;
use wu_svm::rng::Rng;
use wu_svm::solvers::lssvm::{self, LsSvmParams};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let n = cfg.usize_or("n", smoke_or(400, 8000)).unwrap();
    let d = cfg.usize_or("d", 64).unwrap();
    let rank = cfg.usize_or("rank", smoke_or(32, 256)).unwrap();
    let threads = pool::default_threads();
    let runs = smoke_or(2, 7);

    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 8,
        sigma: 0.1,
        flip: 0.02,
        sparsity: 0.0,
        pos_frac: 0.5,
    };
    let ds = generate(&spec, n, 42, "lowrank-bench");
    let kind = KernelKind::Rbf { gamma: 0.5 };
    println!("workload: n={n} d={d} rank={rank} ({threads} threads)");

    // trace the whole bench so the json record carries the
    // runtime-counter snapshot (flop/byte tallies, pool activity)
    let trace_session = wu_svm::trace::Session::start();

    // ---- factorization: the one-off cost of the rank-r operator ----
    header(&format!("pivoted ICF build (n={n}, r={rank})"));
    let s_build = bench(&format!("icf build [{threads}t]"), 1, runs, || {
        let op = LowRank::icf(&kind, &ds, threads, rank, 1e-9);
        assert!(op.rank() > 0);
    });
    println!("{}", s_build.row());
    let op = LowRank::icf(&kind, &ds, threads, rank, 1e-9);
    let tiled = ExactTiled::new(kind, &ds, threads);
    let exact_bytes = 4 * n * n;
    let bytes_ratio = op.memory_bytes() as f64 / exact_bytes as f64;
    println!(
        "operator {} bytes vs exact {exact_bytes} ({:.2}% — residual trace {:.2e})",
        op.memory_bytes(),
        bytes_ratio * 100.0,
        op.residual_frac()
    );

    // ---- the per-iteration primitive: K v, O(n r) vs O(n^2 d) ----
    header("operator matvec — rank-r G Gᵀ v vs exact tiled");
    let mut rng = Rng::new(7);
    let v: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let mut out = vec![0.0f32; n];
    let s_low = bench(&format!("lowrank matvec [{threads}t]"), 1, runs, || {
        op.matvec(&v, &mut out);
    });
    println!("{}", s_low.row());
    let s_tiled = bench(&format!("tiled matvec [{threads}t]"), 1, runs, || {
        tiled.matvec(&v, &mut out);
    });
    println!("{}", s_tiled.row());
    let matvec_speedup = s_tiled.median.as_secs_f64() / s_low.median.as_secs_f64().max(1e-12);
    println!("lowrank matvec vs exact tiled: {matvec_speedup:.2}x");

    // ---- the dispatch layer under both matvec routes: the lane dot
    // primitive, forced scalar vs the detected backend (DESIGN.md §SIMD) ----
    use wu_svm::linalg::simd::{self, Backend};
    let be = simd::active();
    header(&format!("lane dot primitive — scalar vs {}", be.name()));
    let dlen = smoke_or(4096, 1 << 16);
    let calls = smoke_or(200, 2_000);
    let mut xv: Vec<f32> = (0..dlen).map(|_| rng.gaussian_f32()).collect();
    let yv: Vec<f32> = (0..dlen).map(|_| rng.gaussian_f32()).collect();
    let mut dot_sink = 0.0f32;
    let s_dot_scalar = bench(&format!("dot len={dlen} [scalar]"), 1, runs, || {
        for it in 0..calls {
            // touch the input so the pure call cannot be hoisted
            xv[0] = it as f32 * 1e-7;
            dot_sink += std::hint::black_box(Backend::Scalar.dot(&xv, &yv));
        }
    });
    println!("{}", s_dot_scalar.row());
    let s_dot_simd = bench(&format!("dot len={dlen} [{}]", be.name()), 1, runs, || {
        for it in 0..calls {
            xv[0] = it as f32 * 1e-7;
            dot_sink += std::hint::black_box(be.dot(&xv, &yv));
        }
    });
    println!("{}", s_dot_simd.row());
    let dot_simd_speedup =
        s_dot_scalar.median.as_secs_f64() / s_dot_simd.median.as_secs_f64().max(1e-12);
    println!("dot {} vs forced scalar: {dot_simd_speedup:.2}x   (sink {dot_sink:.3})", be.name());

    // ---- end to end: the LS-SVM solve the operator exists for ----
    header("lssvm train — rank-r operator vs exact kernel");
    let lp = LsSvmParams {
        c: 1.0,
        lowrank: Some(LowRankConfig::icf(rank)),
        ..Default::default()
    };
    let s_ls_low = bench("lssvm lowrank", 1, runs, || {
        lssvm::train(&ds, kind, &lp).unwrap();
    });
    println!("{}", s_ls_low.row());
    let ep = LsSvmParams { c: 1.0, lowrank: None, ..Default::default() };
    let s_ls_exact = bench("lssvm exact", 1, runs, || {
        lssvm::train(&ds, kind, &ep).unwrap();
    });
    println!("{}", s_ls_exact.row());

    let counters = trace_session.finish().counters_json();
    if smoke() {
        println!("BENCH_SMOKE=1: skipping BENCH_lowrank.json (not a measurement)");
        return;
    }
    // the embedded schema is required by ci/check_bench_json.py, which
    // validates the checked-in copy of this file on every CI run
    let schema = "\"schema\": {\n    \
         \"workload\": \"n training rows, d features, ICF rank r\",\n    \
         \"threads\": \"worker threads used for every path\",\n    \
         \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
         \"icf_build_ms\": \"median wall time of the rank-r pivoted incomplete Cholesky\",\n    \
         \"lowrank_matvec_ms\": \"median K v time through the rank-r operator (2 GEMVs)\",\n    \
         \"tiled_matvec_ms\": \"median K v time through the exact tiled operator\",\n    \
         \"matvec_speedup\": \"tiled_matvec_ms / lowrank_matvec_ms\",\n    \
         \"dot_scalar_ms\": \"median lane-dot batch time with the forced-scalar flavor\",\n    \
         \"dot_simd_ms\": \"median lane-dot batch time on the detected backend\",\n    \
         \"dot_simd_speedup\": \"dot_scalar_ms / dot_simd_ms (1.0 on scalar-only hosts)\",\n    \
         \"op_bytes\": \"rank-r operator footprint (G plus the diagonal)\",\n    \
         \"exact_bytes\": \"4 n^2 — the materialized exact kernel\",\n    \
         \"bytes_ratio\": \"op_bytes / exact_bytes\",\n    \
         \"residual_frac\": \"kernel trace fraction the factorization left unexplained\",\n    \
         \"lssvm_lowrank_ms\": \"median LS-SVM train time on the rank-r operator\",\n    \
         \"lssvm_exact_ms\": \"median LS-SVM train time on the exact kernel\",\n    \
         \"counters\": \"trace-layer runtime counter snapshot over the bench (ci cross-checks the cache identity)\"\n  }";
    let json = format!(
        "{{\n  \"workload\": {{\"n\": {n}, \"d\": {d}, \"rank\": {rank}}},\n  \
         \"threads\": {threads},\n  \
         \"backend\": \"{}\",\n  \
         \"icf_build_ms\": {:.3},\n  \
         \"lowrank_matvec_ms\": {:.3},\n  \"tiled_matvec_ms\": {:.3},\n  \
         \"matvec_speedup\": {:.3},\n  \
         \"dot_scalar_ms\": {:.3},\n  \"dot_simd_ms\": {:.3},\n  \
         \"dot_simd_speedup\": {:.3},\n  \
         \"op_bytes\": {},\n  \"exact_bytes\": {exact_bytes},\n  \
         \"bytes_ratio\": {bytes_ratio:.5},\n  \"residual_frac\": {:e},\n  \
         \"lssvm_lowrank_ms\": {:.3},\n  \"lssvm_exact_ms\": {:.3},\n  \
         \"counters\": {counters},\n  {schema}\n}}\n",
        be.name(),
        s_build.median.as_secs_f64() * 1e3,
        s_low.median.as_secs_f64() * 1e3,
        s_tiled.median.as_secs_f64() * 1e3,
        s_dot_scalar.median.as_secs_f64() * 1e3,
        s_dot_simd.median.as_secs_f64() * 1e3,
        dot_simd_speedup,
        op.memory_bytes(),
        op.residual_frac(),
        s_ls_low.median.as_secs_f64() * 1e3,
        s_ls_exact.median.as_secs_f64() * 1e3,
    );
    match std::fs::write("BENCH_lowrank.json", &json) {
        Ok(()) => println!("wrote BENCH_lowrank.json:\n{json}"),
        Err(e) => eprintln!("could not write BENCH_lowrank.json: {e}"),
    }
}
