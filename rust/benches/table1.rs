//! Table 1 — the paper's only exhibit, regenerated end to end.
//!
//! For every dataset analog: test error / (1-AUC), training time, and
//! speedup vs single-core LibSVM, across the six method configurations
//! (LibSVM SC/MC, SP-SVM MC, GPU-SVM, GTSVM, SP-SVM on the XLA engine).
//!
//! Run: `cargo bench --bench table1 [-- --dataset adult --scale 0.05
//!       --methods SP-SVM,LibSVM --max-basis 255]`
//! Default runs every dataset at `experiments::default_scale`, which is
//! sized so the whole table finishes in tens of minutes. The recorded
//! output lives in EXPERIMENTS.md.

use wu_svm::bench_util::{smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::data::paper;
use wu_svm::experiments;
use wu_svm::report;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let dataset = cfg.str_or("dataset", smoke_or("adult", "all"));
    let max_basis = cfg.usize_or("max-basis", smoke_or(31, 255)).unwrap();
    let methods: Vec<String> = cfg
        .get("methods")
        .map(|m| m.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_default();

    let keys: Vec<String> = if dataset == "all" {
        paper::specs().iter().map(|s| s.key.to_string()).collect()
    } else {
        vec![dataset]
    };

    let mut all = Vec::new();
    for k in keys {
        let scale_default = if smoke() { 0.004 } else { experiments::default_scale(&k) };
        let scale = cfg.f64_or("scale", scale_default).unwrap();
        eprintln!("=== {k} (scale {scale}) ===");
        match experiments::run_table1_dataset(&k, scale, max_basis, &methods) {
            Ok(rows) => {
                println!("{}", report::render_table(&rows));
                all.extend(rows);
            }
            Err(e) => eprintln!("{k} failed: {e:#}"),
        }
    }
    println!("{}", experiments::render_with_reference(&all));
}
