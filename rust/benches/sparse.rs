//! Sparse substrate benchmarks: the SpMM-backed kernel paths against the
//! densified baseline on 90%-zero data — the workload shape of the
//! paper's sparse sources (kdd99, adult, rcv1-class). Emits
//! machine-readable `BENCH_sparse.json` (rust/EXPERIMENTS.md §SPARSE).
//!
//! Run: `cargo bench --bench sparse [-- --n 4000 --d 512 --sparsity 0.9]`

use wu_svm::bench_util::{bench, header, smoke, smoke_or};
use wu_svm::config::Config;
use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::data::{libsvm, Format};
use wu_svm::kernel::{kernel_block, KernelKind};
use wu_svm::pool;
use wu_svm::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let cfg = Config::from_args(&args).unwrap();
    let n = cfg.usize_or("n", smoke_or(256, 4000)).unwrap();
    let d = cfg.usize_or("d", smoke_or(64, 512)).unwrap();
    let b = cfg.usize_or("b", 64).unwrap();
    let sparsity = cfg.f64_or("sparsity", 0.9).unwrap();
    let threads = pool::default_threads();
    let runs = smoke_or(2, 7);

    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 8,
        sigma: 0.1,
        flip: 0.02,
        sparsity,
        pos_frac: 0.5,
    };
    let dense = generate(&spec, n, 42, "sparse-bench");
    let csr = dense.clone().with_format(Format::Csr);
    println!(
        "workload: n={n} d={d} b={b}, measured sparsity {:.1}% ({} threads)",
        dense.sparsity() * 100.0,
        threads
    );
    println!(
        "design bytes: dense {} vs csr {} ({:.2}x smaller)",
        dense.bytes(),
        csr.bytes(),
        dense.bytes() as f64 / csr.bytes().max(1) as f64
    );

    // trace the whole bench so the json record carries the
    // runtime-counter snapshot (spmm flop/byte tallies, pool activity)
    let trace_session = wu_svm::trace::Session::start();

    // ---- the tentpole comparison: one rbf kernel block K[n x b] of the
    // whole training set against a working-set-sized basis, densified
    // packed-GEMM route vs CSR SpMM route ----
    header(&format!("kernel_block rbf K[{n} x {b}] — densified vs SpMM"));
    let mut rng = Rng::new(7);
    let ri: Vec<usize> = (0..n).collect();
    let ci: Vec<usize> = (0..b).map(|_| rng.below(n)).collect();
    let kind = KernelKind::Rbf { gamma: 0.5 };
    let mut out = vec![0.0f32; n * b];
    let s_dense = bench(&format!("dense kernel_block [{threads}t]"), 1, runs, || {
        kernel_block(&kind, &dense, &ri, &ci, threads, &mut out);
    });
    println!("{}", s_dense.row());
    let s_csr = bench(&format!("csr kernel_block [{threads}t]"), 1, runs, || {
        kernel_block(&kind, &csr, &ri, &ci, threads, &mut out);
    });
    println!("{}", s_csr.row());
    let block_speedup = s_dense.median.as_secs_f64() / s_csr.median.as_secs_f64().max(1e-12);
    println!("csr kernel_block vs densified: {block_speedup:.2}x");

    // agreement check rides along so a broken fast path can't post a win
    let mut kd = vec![0.0f32; n * b];
    let mut ks = vec![0.0f32; n * b];
    kernel_block(&kind, &dense, &ri, &ci, threads, &mut kd);
    kernel_block(&kind, &csr, &ri, &ci, threads, &mut ks);
    let dmax = kd.iter().zip(&ks).map(|(a, c)| (a - c).abs()).fold(0.0f32, f32::max);
    assert!(dmax <= 1e-6, "csr block diverged from dense by {dmax}");
    println!("max |dense - csr| = {dmax:.2e}");

    // ---- the dispatch layer on the sparse path, measured directly:
    // the same raw SpMM through the forced-scalar axpy vs the detected
    // backend's (DESIGN.md §SIMD) ----
    use wu_svm::data::sparse::Design;
    use wu_svm::linalg::simd::{self, Backend};
    use wu_svm::linalg::spmm;
    let be = simd::active();
    header(&format!("raw SpMM C[{n} x {b}] — scalar vs {}", be.name()));
    let csr_mat = match &csr.design {
        Design::Sparse(m) => m,
        _ => unreachable!("csr dataset is CSR by construction"),
    };
    let bm: Vec<f32> = {
        let mut v = vec![0.0f32; b * d];
        let mut r2 = Rng::new(11);
        for slot in v.iter_mut() {
            *slot = r2.gaussian_f32();
        }
        v
    };
    let mut sp_out = vec![0.0f32; n * b];
    let s_sp_scalar = bench(&format!("spmm [scalar {threads}t]"), 1, runs, || {
        spmm::csr_gemm_nt_with(Backend::Scalar, threads, csr_mat, 0, n, &bm, b, &mut sp_out);
    });
    println!("{}", s_sp_scalar.row());
    let s_sp_simd = bench(&format!("spmm [{} {threads}t]", be.name()), 1, runs, || {
        spmm::csr_gemm_nt_with(be, threads, csr_mat, 0, n, &bm, b, &mut sp_out);
    });
    println!("{}", s_sp_simd.row());
    let spmm_simd_speedup =
        s_sp_scalar.median.as_secs_f64() / s_sp_simd.median.as_secs_f64().max(1e-12);
    println!("spmm {} vs forced scalar: {spmm_simd_speedup:.2}x", be.name());

    // ---- ingestion: the streaming chunk-parallel parser, CSR vs densify ----
    header("libsvm parse (streaming chunked-parallel)");
    let dir = std::env::temp_dir().join("wu_svm_sparse_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.libsvm");
    libsvm::write_file(&dense, &path).unwrap();
    let s_parse_csr = bench("parse -> csr", 1, runs, || {
        let ds = libsvm::read_file_with(&path, d, Format::Csr).unwrap();
        assert_eq!(ds.n, n);
    });
    println!("{}", s_parse_csr.row());
    let s_parse_dense = bench("parse -> dense", 1, runs, || {
        let ds = libsvm::read_file_with(&path, d, Format::Dense).unwrap();
        assert_eq!(ds.n, n);
    });
    println!("{}", s_parse_dense.row());
    std::fs::remove_file(&path).ok();

    let counters = trace_session.finish().counters_json();
    if smoke() {
        println!("BENCH_SMOKE=1: skipping BENCH_sparse.json (not a measurement)");
        return;
    }
    // the embedded schema is required by ci/check_bench_json.py, which
    // validates the checked-in copy of this file on every CI run
    let schema = "\"schema\": {\n    \
         \"workload\": \"kernel block dims: K[n x b] over d features at the given zero fraction\",\n    \
         \"threads\": \"worker threads used for both paths\",\n    \
         \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
         \"dense_block_ms\": \"median wall time of kernel_block on the densified dataset\",\n    \
         \"csr_block_ms\": \"median wall time of kernel_block on the CSR dataset (SpMM path)\",\n    \
         \"block_speedup\": \"dense_block_ms / csr_block_ms\",\n    \
         \"max_abs_diff\": \"max |dense - csr| over the block\",\n    \
         \"dense_bytes\": \"design-matrix footprint stored dense\",\n    \
         \"csr_bytes\": \"design-matrix footprint stored CSR\",\n    \
         \"spmm_scalar_ms\": \"median raw SpMM time with the forced-scalar axpy\",\n    \
         \"spmm_simd_ms\": \"median raw SpMM time on the detected backend\",\n    \
         \"spmm_simd_speedup\": \"spmm_scalar_ms / spmm_simd_ms (1.0 on scalar-only hosts)\",\n    \
         \"parse_csr_ms\": \"median libsvm parse time building CSR directly\",\n    \
         \"parse_dense_ms\": \"median libsvm parse time densifying on load\",\n    \
         \"counters\": \"trace-layer runtime counter snapshot over the bench (ci cross-checks the cache identity)\"\n  }";
    let json = format!(
        "{{\n  \"workload\": {{\"n\": {n}, \"d\": {d}, \"b\": {b}, \"sparsity\": {:.3}}},\n  \
         \"threads\": {threads},\n  \
         \"backend\": \"{}\",\n  \
         \"dense_block_ms\": {:.3},\n  \"csr_block_ms\": {:.3},\n  \
         \"block_speedup\": {:.3},\n  \"max_abs_diff\": {dmax:e},\n  \
         \"dense_bytes\": {},\n  \"csr_bytes\": {},\n  \
         \"spmm_scalar_ms\": {:.3},\n  \"spmm_simd_ms\": {:.3},\n  \
         \"spmm_simd_speedup\": {:.3},\n  \
         \"parse_csr_ms\": {:.3},\n  \"parse_dense_ms\": {:.3},\n  \
         \"counters\": {counters},\n  {schema}\n}}\n",
        dense.sparsity(),
        be.name(),
        s_dense.median.as_secs_f64() * 1e3,
        s_csr.median.as_secs_f64() * 1e3,
        block_speedup,
        dense.bytes(),
        csr.bytes(),
        s_sp_scalar.median.as_secs_f64() * 1e3,
        s_sp_simd.median.as_secs_f64() * 1e3,
        spmm_simd_speedup,
        s_parse_csr.median.as_secs_f64() * 1e3,
        s_parse_dense.median.as_secs_f64() * 1e3,
    );
    match std::fs::write("BENCH_sparse.json", &json) {
        Ok(()) => println!("wrote BENCH_sparse.json:\n{json}"),
        Err(e) => eprintln!("could not write BENCH_sparse.json: {e}"),
    }
}
