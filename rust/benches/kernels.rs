//! Micro-benchmarks of the five tile ops across engines — the per-op
//! explicit-vs-implicit comparison underlying every Table-1 number.
//!
//! Run: `cargo bench --bench kernels`

use wu_svm::bench_util::{bench, header};
use wu_svm::engine::Engine;
use wu_svm::pool;
use wu_svm::rng::Rng;
use wu_svm::runtime::{default_artifacts_dir, XlaRuntime};

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_f32()).collect()
}

fn main() {
    let mut engines: Vec<Engine> = vec![Engine::cpu_seq(), Engine::cpu_par(pool::default_threads())];
    match XlaRuntime::load(&default_artifacts_dir()) {
        Ok(rt) => engines.push(Engine::xla(std::sync::Arc::new(rt))),
        Err(e) => eprintln!("xla engine unavailable: {e}"),
    }

    let mut rng = Rng::new(1);
    let t = 1024;

    header("rbf_block K[1024 x B] (d features)");
    for &(d, b) in &[(64usize, 64usize), (128, 256), (512, 512), (2048, 512)] {
        let x = rand_vec(&mut rng, t * d);
        let xb = rand_vec(&mut rng, b * d);
        for e in &engines {
            let s = bench(&format!("rbf d={d} b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.rbf_block(&x, t, d, &xb, b, 0.5).unwrap();
            });
            println!("{}", s.row());
        }
    }

    header("tile_stats (fused hinge grad+gram) [1024 x B]");
    for &b in &[64usize, 256, 512] {
        let k = rand_vec(&mut rng, t * b);
        let y: Vec<f32> = (0..t).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m = vec![1.0f32; t];
        let beta = rand_vec(&mut rng, b);
        for e in &engines {
            let s = bench(&format!("tile_stats b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.tile_stats(&k, t, b, &y, &m, &beta, 2.0).unwrap();
            });
            println!("{}", s.row());
        }
    }

    header("cg_solve (masked Newton system) [B x B]");
    for &b in &[64usize, 256, 512] {
        // SPD system
        let a = rand_vec(&mut rng, b * b);
        let mut h = vec![0.0f32; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut acc = 0.0f32;
                for k2 in 0..b {
                    acc += a[i * b + k2] * a[j * b + k2];
                }
                h[i * b + j] = acc / b as f32 + if i == j { 1.0 } else { 0.0 };
            }
        }
        let g = rand_vec(&mut rng, b);
        let bm = vec![1.0f32; b];
        for e in &engines {
            let s = bench(&format!("cg_solve b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.cg_solve(&h, b, &g, &bm, 1e-3).unwrap();
            });
            println!("{}", s.row());
        }
    }

    header("score_tile + predict_block [1024 x {64,256}]");
    {
        let kc = rand_vec(&mut rng, t * 64);
        let r: Vec<f32> = rand_vec(&mut rng, t);
        let a: Vec<f32> = vec![1.0; t];
        let k = rand_vec(&mut rng, t * 256);
        let beta = rand_vec(&mut rng, 256);
        for e in &engines {
            let s = bench(&format!("score_tile s=64 [{}]", e.name()), 1, 5, || {
                let _ = e.score_tile(&kc, t, 64, &r, &a).unwrap();
            });
            println!("{}", s.row());
            let s = bench(&format!("predict_block b=256 [{}]", e.name()), 1, 5, || {
                let _ = e.predict_block(&k, t, 256, &beta).unwrap();
            });
            println!("{}", s.row());
        }
    }
}
