//! Micro-benchmarks of the five tile ops across engines — the per-op
//! explicit-vs-implicit comparison underlying every Table-1 number.
//!
//! Run: `cargo bench --bench kernels`

use wu_svm::bench_util::{bench, header, smoke, smoke_or};
use wu_svm::engine::Engine;
use wu_svm::pool;
use wu_svm::rng::Rng;
use wu_svm::runtime::{default_artifacts_dir, XlaRuntime};

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_f32()).collect()
}

fn main() {
    let mut engines: Vec<Engine> =
        vec![Engine::cpu_seq(), Engine::cpu_par(pool::default_threads())];
    match XlaRuntime::load(&default_artifacts_dir()) {
        Ok(rt) => engines.push(Engine::xla(std::sync::Arc::new(rt))),
        Err(e) => eprintln!("xla engine unavailable: {e}"),
    }

    let mut rng = Rng::new(1);
    let t = smoke_or(128, 1024);
    let shapes: &[(usize, usize)] = if smoke() {
        &[(64, 64)]
    } else {
        &[(64, 64), (128, 256), (512, 512), (2048, 512)]
    };

    header(&format!("rbf_block K[{t} x B] (d features)"));
    for &(d, b) in shapes {
        let x = rand_vec(&mut rng, t * d);
        let xb = rand_vec(&mut rng, b * d);
        for e in &engines {
            let s = bench(&format!("rbf d={d} b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.rbf_block(&x, t, d, &xb, b, 0.5).unwrap();
            });
            println!("{}", s.row());
        }
    }

    let bsizes: &[usize] = if smoke() { &[64] } else { &[64, 256, 512] };
    header(&format!("tile_stats (fused hinge grad+gram) [{t} x B]"));
    for &b in bsizes {
        let k = rand_vec(&mut rng, t * b);
        let y: Vec<f32> = (0..t).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let m = vec![1.0f32; t];
        let beta = rand_vec(&mut rng, b);
        for e in &engines {
            let s = bench(&format!("tile_stats b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.tile_stats(&k, t, b, &y, &m, &beta, 2.0).unwrap();
            });
            println!("{}", s.row());
        }
    }

    header("cg_solve (masked Newton system) [B x B]");
    for &b in bsizes {
        // SPD system
        let a = rand_vec(&mut rng, b * b);
        let mut h = vec![0.0f32; b * b];
        for i in 0..b {
            for j in 0..b {
                let mut acc = 0.0f32;
                for k2 in 0..b {
                    acc += a[i * b + k2] * a[j * b + k2];
                }
                h[i * b + j] = acc / b as f32 + if i == j { 1.0 } else { 0.0 };
            }
        }
        let g = rand_vec(&mut rng, b);
        let bm = vec![1.0f32; b];
        for e in &engines {
            let s = bench(&format!("cg_solve b={b} [{}]", e.name()), 1, 5, || {
                let _ = e.cg_solve(&h, b, &g, &bm, 1e-3).unwrap();
            });
            println!("{}", s.row());
        }
    }

    header(&format!("score_tile + predict_block [{t} x {{64,256}}]"));
    {
        let kc = rand_vec(&mut rng, t * 64);
        let r: Vec<f32> = rand_vec(&mut rng, t);
        let a: Vec<f32> = vec![1.0; t];
        let k = rand_vec(&mut rng, t * 256);
        let beta = rand_vec(&mut rng, 256);
        for e in &engines {
            let s = bench(&format!("score_tile s=64 [{}]", e.name()), 1, 5, || {
                let _ = e.score_tile(&kc, t, 64, &r, &a).unwrap();
            });
            println!("{}", s.row());
            let s = bench(&format!("predict_block b=256 [{}]", e.name()), 1, 5, || {
                let _ = e.predict_block(&k, t, 256, &beta).unwrap();
            });
            println!("{}", s.row());
        }
    }

    // ---- the substrate comparison behind every number above: the seed's
    // dot-loop GEMM vs the blocked/packed path, plus the rbf_block tile it
    // feeds. Emits machine-readable BENCH_gemm.json for the perf
    // trajectory (rust/EXPERIMENTS.md §GEMM).
    header("gemm_nt — seed dot-loop vs blocked");
    {
        use wu_svm::linalg::{gemm_nt, gemm_nt_naive, Matrix};
        let threads = pool::default_threads();
        // trace the measured section so the json record carries the
        // runtime-counter snapshot (flop/byte tallies, pool activity)
        let trace_session = wu_svm::trace::Session::start();
        let (m, k, n) = smoke_or((400usize, 64usize, 64usize), (4000, 64, 512));
        let a = Matrix::from_vec(m, k, rand_vec(&mut rng, m * k));
        let b = Matrix::from_vec(n, k, rand_vec(&mut rng, n * k));
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let gflops = |d: std::time::Duration| flops / d.as_secs_f64().max(1e-12) / 1e9;
        let mut c = Matrix::zeros(m, n);
        let s_naive = bench(&format!("gemm seed dot-loop [{threads}t]"), 1, 7, || {
            gemm_nt_naive(threads, &a, &b, &mut c);
        });
        println!("{}   {:.2} GFLOP/s", s_naive.row(), gflops(s_naive.median));
        let s_b1 = bench("gemm blocked [1t]", 1, 7, || {
            gemm_nt(1, &a, &b, &mut c);
        });
        println!("{}   {:.2} GFLOP/s", s_b1.row(), gflops(s_b1.median));
        let s_blk = bench(&format!("gemm blocked [{threads}t]"), 1, 7, || {
            gemm_nt(threads, &a, &b, &mut c);
        });
        println!("{}   {:.2} GFLOP/s", s_blk.row(), gflops(s_blk.median));
        let speedup = s_naive.median.as_secs_f64() / s_blk.median.as_secs_f64().max(1e-12);
        println!("blocked vs seed dot-loop: {speedup:.2}x");

        // rbf_block on a large tile: the seed's per-pair f64-dot
        // expansion vs the engine's norms + GEMM + fused-exp path.
        let (rt, rd, rb) = smoke_or((400usize, 64usize, 64usize), (4000, 64, 512));
        let x = rand_vec(&mut rng, rt * rd);
        let xb = rand_vec(&mut rng, rb * rd);
        let gamma = 0.5f32;
        let mut sink = 0.0f32;
        let s_rseed = bench(&format!("rbf seed dot-loop t=4000 [{threads}t]"), 1, 5, || {
            use wu_svm::linalg::dot;
            use wu_svm::pool::SendPtr;
            let mut kk = vec![0.0f32; rt * rb];
            let bsq: Vec<f32> = (0..rb)
                .map(|j| dot(&xb[j * rd..(j + 1) * rd], &xb[j * rd..(j + 1) * rd]))
                .collect();
            let kptr = SendPtr::new(kk.as_mut_ptr());
            pool::parallel_for(threads, rt, 8, |i| {
                let xi = &x[i * rd..(i + 1) * rd];
                let xsq = dot(xi, xi);
                let row =
                    unsafe { std::slice::from_raw_parts_mut(kptr.get().add(i * rb), rb) };
                for (j, slot) in row.iter_mut().enumerate() {
                    let cross = dot(xi, &xb[j * rd..(j + 1) * rd]);
                    let d2 = (xsq + bsq[j] - 2.0 * cross).max(0.0);
                    *slot = (-gamma * d2).exp();
                }
            });
            sink += kk[0];
        });
        println!("{}", s_rseed.row());
        let epar = Engine::cpu_par(threads);
        let s_rblk = bench(&format!("rbf blocked t=4000 [{}]", epar.name()), 1, 5, || {
            sink += epar.rbf_block(&x, rt, rd, &xb, rb, gamma).unwrap()[0];
        });
        println!("{}", s_rblk.row());
        let rbf_speedup = s_rseed.median.as_secs_f64() / s_rblk.median.as_secs_f64().max(1e-12);
        println!("rbf_block blocked vs seed: {rbf_speedup:.2}x   (sink {sink:.3})");

        // ---- the dispatch layer measured directly: the same packed
        // panels through the forced-scalar 8x8 micro-kernel vs the
        // detected backend's — the per-tile primitive every GEMM number
        // above is built from (DESIGN.md §SIMD) ----
        use wu_svm::linalg::gemm::{MR, NR};
        use wu_svm::linalg::simd::{self, Backend};
        let be = simd::active();
        header(&format!("simd 8x8 micro-kernel — scalar vs {}", be.name()));
        let kc = 256usize;
        let calls = smoke_or(500, 20_000);
        let mut pa = rand_vec(&mut rng, kc * MR);
        let pb = rand_vec(&mut rng, kc * NR);
        let mut mk_sink = 0.0f32;
        let s_mk_scalar = bench(&format!("microkernel kc={kc} [scalar]"), 1, 7, || {
            for it in 0..calls {
                // touch the panel so the pure call cannot be hoisted
                pa[0] = it as f32 * 1e-7;
                mk_sink += std::hint::black_box(Backend::Scalar.microkernel_8x8(&pa, &pb, kc))[0];
            }
        });
        println!("{}", s_mk_scalar.row());
        let s_mk_simd = bench(&format!("microkernel kc={kc} [{}]", be.name()), 1, 7, || {
            for it in 0..calls {
                pa[0] = it as f32 * 1e-7;
                mk_sink += std::hint::black_box(be.microkernel_8x8(&pa, &pb, kc))[0];
            }
        });
        println!("{}", s_mk_simd.row());
        let mk_speedup =
            s_mk_scalar.median.as_secs_f64() / s_mk_simd.median.as_secs_f64().max(1e-12);
        println!(
            "micro-kernel {} vs forced scalar: {mk_speedup:.2}x   (sink {mk_sink:.3})",
            be.name()
        );

        let counters = trace_session.finish().counters_json();

        // embedded schema required by ci/check_bench_json.py (validates
        // the checked-in copy of this file on every CI run)
        let schema = "\"schema\": {\n    \
             \"workload\": \"matrix dims, C[m x n] = A[m x k] . B[n x k]^T\",\n    \
             \"threads\": \"worker threads used for both paths\",\n    \
             \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
             \"seed_dot_loop_ms\": \"median wall time of gemm_nt_naive\",\n    \
             \"seed_dot_loop_gflops\": \"2*m*n*k / median time\",\n    \
             \"blocked_1t_ms\": \"median wall time of blocked gemm_nt, 1 thread\",\n    \
             \"blocked_ms\": \"median wall time of blocked gemm_nt, all threads\",\n    \
             \"blocked_gflops\": \"2*m*n*k / median time\",\n    \
             \"speedup_vs_seed\": \"seed_dot_loop_ms / blocked_ms\",\n    \
             \"rbf_tile\": \"same comparison for a large rbf_block tile\",\n    \
             \"simd_microkernel\": \"forced-scalar vs detected-backend 8x8 micro-kernel on identical packed panels\",\n    \
             \"counters\": \"trace-layer runtime counter snapshot over the measured section (ci cross-checks the cache identity)\"\n  }";
        let json = format!(
            "{{\n  \"workload\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}}},\n  \
             \"threads\": {threads},\n  \
             \"backend\": \"{}\",\n  \
             \"seed_dot_loop_ms\": {:.3},\n  \"seed_dot_loop_gflops\": {:.3},\n  \
             \"blocked_1t_ms\": {:.3},\n  \"blocked_ms\": {:.3},\n  \
             \"blocked_gflops\": {:.3},\n  \"speedup_vs_seed\": {:.3},\n  \
             \"rbf_tile\": {{\"t\": {rt}, \"d\": {rd}, \"b\": {rb}, \
             \"seed_ms\": {:.3}, \"blocked_ms\": {:.3}, \"speedup\": {:.3}}},\n  \
             \"simd_microkernel\": {{\"kc\": {kc}, \"calls\": {calls}, \
             \"scalar_ms\": {:.3}, \"simd_ms\": {:.3}, \"speedup\": {:.3}}},\n  \
             \"counters\": {counters},\n  {schema}\n}}\n",
            be.name(),
            s_naive.median.as_secs_f64() * 1e3,
            gflops(s_naive.median),
            s_b1.median.as_secs_f64() * 1e3,
            s_blk.median.as_secs_f64() * 1e3,
            gflops(s_blk.median),
            speedup,
            s_rseed.median.as_secs_f64() * 1e3,
            s_rblk.median.as_secs_f64() * 1e3,
            rbf_speedup,
            s_mk_scalar.median.as_secs_f64() * 1e3,
            s_mk_simd.median.as_secs_f64() * 1e3,
            mk_speedup,
        );
        if smoke() {
            println!("BENCH_SMOKE=1: skipping BENCH_gemm.json (not a measurement)");
        } else {
            match std::fs::write("BENCH_gemm.json", &json) {
                Ok(()) => println!("wrote BENCH_gemm.json:\n{json}"),
                Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
            }
        }
    }
}
