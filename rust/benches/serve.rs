//! Serving throughput and latency across batch sizes and shard counts —
//! the serve-side analog of the GEMM substrate comparison. Emits
//! machine-readable `BENCH_serve.json` (rust/EXPERIMENTS.md §SERVE).
//!
//! Run: `cargo bench --bench serve`

use std::time::{Duration, Instant};

use wu_svm::bench_util::{header, smoke, smoke_or};
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::model::SvmModel;
use wu_svm::multiclass::OvoModel;
use wu_svm::pool;
use wu_svm::rng::Rng;
use wu_svm::serve::{Server, ServeConfig, Snapshot};

fn rand_model(rng: &mut Rng, b: usize, d: usize) -> SvmModel {
    SvmModel {
        kernel: KernelKind::Rbf { gamma: 0.5 },
        vectors: (0..b * d).map(|_| rng.uniform_f32()).collect(),
        d,
        coef: (0..b).map(|_| rng.gaussian_f32() * 0.3).collect(),
        bias: 0.1,
        solver: "bench".into(),
    }
}

/// Closed-loop drive: `clients` threads each issue `per_client` blocking
/// predicts. Returns (wall time, server's final snapshot).
fn drive(server: Server, clients: usize, per_client: usize, d: usize) -> (Duration, Snapshot) {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients as u64)
        .map(|t| {
            let c = server.client();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xbe0 + t);
                for _ in 0..per_client {
                    let f: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
                    c.predict(f).expect("predict");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed();
    (wall, server.stop())
}

fn main() {
    let threads = pool::default_threads();
    let mut rng = Rng::new(7);
    let d = 64;
    let model = rand_model(&mut rng, 256, d);
    let clients = smoke_or(2, 8);
    let per_client = smoke_or(60, 1500);
    let total_req = (clients * per_client) as f64;

    // trace the whole bench so the json record carries the
    // runtime-counter snapshot (compile spans, flop tallies, fallbacks)
    let trace_session = wu_svm::trace::Session::start();

    header(&format!(
        "serve throughput — binary b=256 d={d}, {clients} closed-loop clients x {per_client} reqs"
    ));
    println!(
        "{:<34} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "case", "req/s", "p50<=", "p99<=", "mean", "fallback"
    );
    let mut json_cases = String::new();
    let shard_list: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4] };
    let batch_list: &[usize] = if smoke() { &[32] } else { &[32, 256] };
    for &shards in shard_list {
        for &batch in batch_list {
            let server = Server::start(
                &model,
                Engine::cpu_par(threads),
                ServeConfig {
                    batch,
                    shards,
                    queue_cap: 8192,
                    max_wait: Duration::from_micros(500),
                },
            );
            // warm the pool and the packed tiles
            {
                let c = server.client();
                for _ in 0..64 {
                    c.predict(vec![0.5; d]).unwrap();
                }
            }
            let (wall, snap) = drive(server, clients, per_client, d);
            let rps = total_req / wall.as_secs_f64();
            println!(
                "{:<34} {:>12.0} {:>10?} {:>10?} {:>10.1} {:>10}",
                format!("shards={shards} batch={batch}"),
                rps,
                snap.p50,
                snap.p99,
                snap.mean_batch,
                snap.fallbacks
            );
            if !json_cases.is_empty() {
                json_cases.push_str(",\n");
            }
            json_cases.push_str(&format!(
                "    {{\"shards\": {shards}, \"batch\": {batch}, \"req_per_s\": {:.0}, \
                 \"p50_us\": {}, \"p99_us\": {}, \"mean_batch\": {:.2}, \"fallbacks\": {}}}",
                rps,
                snap.p50.as_micros(),
                snap.p99.as_micros(),
                snap.mean_batch,
                snap.fallbacks
            ));
        }
    }

    // OvO: 10 classes, 45 pairs sharing one dedup'd union — one kernel
    // block per batch instead of 45
    header("serve throughput — OvO 10 classes / 45 pairs, shared union block");
    let classes = 10;
    let mut pairs = Vec::new();
    let mut models = Vec::new();
    // pairs share a common pool of vectors so the union dedup bites
    let pool_rows: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..d).map(|_| rng.uniform_f32()).collect())
        .collect();
    for a in 0..classes {
        for b in (a + 1)..classes {
            let ids: Vec<usize> = (0..12).map(|k| (a * 7 + b * 3 + k * 5) % 64).collect();
            let mut vectors = Vec::with_capacity(ids.len() * d);
            for &i in &ids {
                vectors.extend_from_slice(&pool_rows[i]);
            }
            models.push(SvmModel {
                kernel: KernelKind::Rbf { gamma: 0.5 },
                vectors,
                d,
                coef: (0..12).map(|_| rng.gaussian_f32() * 0.3).collect(),
                bias: 0.05,
                solver: "bench".into(),
            });
            pairs.push((a, b));
        }
    }
    let ovo = OvoModel { classes, pairs, models, train_secs: 0.0 };
    let ovo_raw = ovo.total_vectors();
    let server = Server::start(
        &ovo,
        Engine::cpu_par(threads),
        ServeConfig {
            batch: 256,
            shards: 2,
            queue_cap: 8192,
            max_wait: Duration::from_micros(500),
        },
    );
    let compiled = server.registry().current();
    println!("{}", compiled.describe());
    let ovo_union = compiled.packed_vectors();
    drop(compiled);
    let ovo_per_client = smoke_or(30, 400);
    let (wall, snap) = drive(server, clients, ovo_per_client, d);
    let ovo_rps = (clients * ovo_per_client) as f64 / wall.as_secs_f64();
    println!(
        "{:<34} {:>12.0} {:>10?} {:>10?} {:>10.1} {:>10}",
        format!("ovo union={ovo_union}/{ovo_raw}"),
        ovo_rps,
        snap.p50,
        snap.p99,
        snap.mean_batch,
        snap.fallbacks
    );

    let counters = trace_session.finish().counters_json();

    // embedded schema required by ci/check_bench_json.py (validates the
    // checked-in copy of this file on every CI run)
    let schema = "\"schema\": {\n    \
         \"workload\": \"packed binary model size, feature dim, closed-loop client count\",\n    \
         \"threads\": \"pool worker threads\",\n    \
         \"backend\": \"SIMD backend the measured process dispatched to (scalar | avx2+fma | neon)\",\n    \
         \"cases\": \"per (shards, batch): throughput, p50/p99 upper bounds (us), occupancy, fallbacks\",\n    \
         \"ovo\": \"45-pair ensemble served off one deduplicated union block\",\n    \
         \"counters\": \"trace-layer runtime counter snapshot over the bench (ci cross-checks the cache identity)\"\n  }";
    let json = format!(
        "{{\n  \"workload\": {{\"binary_b\": 256, \"d\": {d}, \"clients\": {clients}, \
         \"per_client\": {per_client}}},\n  \"threads\": {threads},\n  \
         \"backend\": \"{}\",\n  \"cases\": [\n{json_cases}\n  ],\n  \
         \"ovo\": {{\"classes\": {classes}, \"pairs\": 45, \"raw_vectors\": {ovo_raw}, \
         \"union_vectors\": {ovo_union}, \"req_per_s\": {ovo_rps:.0}, \
         \"p50_us\": {}, \"p99_us\": {}}},\n  \"counters\": {counters},\n  {schema}\n}}\n",
        wu_svm::linalg::simd::active().name(),
        snap.p50.as_micros(),
        snap.p99.as_micros(),
    );
    if smoke() {
        println!("BENCH_SMOKE=1: skipping BENCH_serve.json (not a measurement)");
        return;
    }
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json:\n{json}"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
