//! Property tests for the sparse substrate (DESIGN.md §SPARSE):
//! dense<->CSR round trips, SpMM against an accumulation-order-exact
//! reference, sparse-vs-dense kernel agreement, thread-count invariance,
//! and the end-to-end storage-format bit-identity of the tile solvers.

use wu_svm::data::sparse::CsrMatrix;
use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::data::{Dataset, Format};
use wu_svm::engine::Engine;
use wu_svm::kernel::{kernel_block, KernelKind};
use wu_svm::linalg::gemm::KC;
use wu_svm::linalg::{gemm, gemm_nt_naive, spmm, Matrix};
use wu_svm::rng::Rng;
use wu_svm::solvers::spsvm::{self, SpSvmParams};

fn rand_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| if rng.bernoulli(density) { rng.gaussian_f32() } else { 0.0 })
        .collect()
}

#[test]
fn prop_dense_csr_round_trip() {
    let mut rng = Rng::new(1);
    for case in 0..60 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(400);
        let density = 0.02 + 0.4 * rng.uniform_f32() as f64;
        let x = rand_sparse(&mut rng, rows, cols, density);
        let csr = CsrMatrix::from_dense(rows, cols, &x);
        assert_eq!(csr.to_dense().data, x, "case {case} ({rows}x{cols})");
        // per-row norms bit-match the dense accumulation order
        for i in 0..rows {
            let want = gemm::sum_sq(&x[i * cols..(i + 1) * cols]);
            assert_eq!(csr.sum_sq[i].to_bits(), want.to_bits(), "case {case} row {i}");
        }
    }
}

/// A scalar reference that replays the SpMM's exact f32 accumulation
/// order (KC-chunked partials over ascending columns, zeros skipped).
/// The SpMM must reproduce it to 0 ulp — and the same order is the
/// packed GEMM's per-element order, which is why CSR storage changes no
/// kernel bit.
fn chunked_reference(x: &[f32], t: usize, bm: &[f32], b: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * b];
    for i in 0..t {
        for j in 0..b {
            let mut total = 0.0f32;
            let mut k0 = 0usize;
            while k0 < d {
                let hi = (k0 + KC).min(d);
                let mut partial = 0.0f32;
                let mut any = false;
                for p in k0..hi {
                    let v = x[i * d + p];
                    if v != 0.0 {
                        partial += v * bm[j * d + p];
                        any = true;
                    }
                }
                if any {
                    total += partial;
                }
                k0 = hi;
            }
            out[i * b + j] = total;
        }
    }
    out
}

#[test]
fn prop_spmm_zero_ulp_vs_ordered_reference_and_close_to_naive() {
    let mut rng = Rng::new(2);
    for case in 0..25 {
        let t = 1 + rng.below(60);
        let b = 1 + rng.below(20);
        let d = 1 + rng.below(600); // spans KC = 256 boundaries
        let x = rand_sparse(&mut rng, t, d, 0.15);
        let bm: Vec<f32> = (0..b * d).map(|_| rng.gaussian_f32()).collect();
        let csr = CsrMatrix::from_dense(t, d, &x);
        let mut out = vec![0.0f32; t * b];
        spmm::csr_gemm_nt(4, &csr, 0, t, &bm, b, &mut out);
        // 0 ulp against the accumulation-order reference
        let want = chunked_reference(&x, t, &bm, b, d);
        for (idx, (g, w)) in out.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "case {case} ({t},{b},{d}) elem {idx}");
        }
        // and within f32 rounding of the f64-accumulated naive GEMM
        let a = Matrix::from_vec(t, d, x.clone());
        let bmat = Matrix::from_vec(b, d, bm.clone());
        let mut e = Matrix::zeros(t, b);
        gemm_nt_naive(1, &a, &bmat, &mut e);
        for (g, w) in out.iter().zip(&e.data) {
            assert!((g - w).abs() < 1e-3 * (d as f32).sqrt().max(1.0), "case {case}");
        }
    }
}

#[test]
fn prop_spmm_thread_count_invariant() {
    let mut rng = Rng::new(3);
    for case in 0..10 {
        let t = 1 + rng.below(200);
        let b = 1 + rng.below(40);
        let d = 1 + rng.below(500);
        let x = rand_sparse(&mut rng, t, d, 0.1);
        let bm: Vec<f32> = (0..b * d).map(|_| rng.gaussian_f32()).collect();
        let csr = CsrMatrix::from_dense(t, d, &x);
        let mut base = vec![0.0f32; t * b];
        spmm::csr_gemm_nt(1, &csr, 0, t, &bm, b, &mut base);
        for threads in [2usize, 8] {
            let mut got = vec![0.0f32; t * b];
            spmm::csr_gemm_nt(threads, &csr, 0, t, &bm, b, &mut got);
            for (g, w) in got.iter().zip(&base) {
                assert_eq!(g.to_bits(), w.to_bits(), "case {case} threads {threads}");
            }
        }
    }
}

fn sparse_binary(n: usize, d: usize, sparsity: f64, seed: u64) -> Dataset {
    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 6,
        sigma: 0.12,
        flip: 0.02,
        sparsity,
        pos_frac: 0.5,
    };
    generate(&spec, n, seed, "sparse-prop")
}

#[test]
fn prop_sparse_vs_dense_rbf_block_within_1e6() {
    // the satellite's stated contract (the implementation is in fact
    // bit-identical; asserting <= 1e-6 keeps the gate honest even if the
    // accumulation orders ever legitimately diverge)
    let dense = sparse_binary(300, 200, 0.9, 5);
    let sparse = dense.clone().with_format(Format::Csr);
    assert!(sparse.is_sparse() && sparse.sparsity() > 0.8);
    let kind = KernelKind::Rbf { gamma: 0.7 };
    let ri: Vec<usize> = (0..300).collect();
    let mut rng = Rng::new(6);
    let ci: Vec<usize> = (0..48).map(|_| rng.below(300)).collect();
    for threads in [1usize, 2, 8] {
        let mut kd = vec![0.0f32; ri.len() * ci.len()];
        let mut ks = vec![0.0f32; ri.len() * ci.len()];
        kernel_block(&kind, &dense, &ri, &ci, threads, &mut kd);
        kernel_block(&kind, &sparse, &ri, &ci, threads, &mut ks);
        let dmax = kd.iter().zip(&ks).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(dmax <= 1e-6, "threads {threads}: diverged by {dmax}");
    }
}

#[test]
fn spsvm_model_bit_identical_across_storage_formats() {
    // the acceptance contract behind `wu-svm train --format csr`: the
    // tile solver walks the identical optimization path on CSR input
    // because every kernel block is bit-identical (DESIGN.md §SPARSE)
    let dense = sparse_binary(900, 96, 0.9, 7);
    let sparse = dense.clone().with_format(Format::Csr);
    let params = SpSvmParams { c: 5.0, gamma: 2.0, max_basis: 31, ..Default::default() };
    let engine = Engine::cpu_par(4);
    let rd = spsvm::train(&dense, &params, &engine).unwrap();
    let rs = spsvm::train(&sparse, &params, &engine).unwrap();
    assert_eq!(rd.model.coef, rs.model.coef, "coefficients must match bit for bit");
    assert_eq!(rd.model.vectors, rs.model.vectors);
    assert_eq!(rd.model.bias, rs.model.bias);
    assert_eq!(rd.iterations, rs.iterations);
    // identical models -> identical margins on any test set
    let te = sparse_binary(200, 96, 0.9, 8);
    let md = rd.model.decision_batch(&te, 4);
    let ms = rs.model.decision_batch(&te, 4);
    assert_eq!(md, ms);
    // ...and scoring the *sparse* test view agrees with the dense view
    let te_sp = te.clone().with_format(Format::Csr);
    let msp = rs.model.decision_batch(&te_sp, 4);
    for (a, b) in msp.iter().zip(&md) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn smo_trains_on_csr_and_agrees_with_dense_margins() {
    // row-fed explicit solvers see kernel rows that differ from the
    // dense ones only by evaluation rounding; both runs must converge to
    // models whose margins agree to the solver's stopping tolerance
    use wu_svm::solvers::smo::{self, SmoParams};
    let dense = sparse_binary(500, 64, 0.9, 9);
    let sparse = dense.clone().with_format(Format::Csr);
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let params = SmoParams { c: 1.0, ..Default::default() };
    let engine = Engine::cpu_par(4);
    let rd = smo::train(&dense, kind, &params, &engine).unwrap();
    let rs = smo::train(&sparse, kind, &params, &engine).unwrap();
    let te = sparse_binary(150, 64, 0.9, 10);
    let md = rd.model.decision_batch(&te, 4);
    let ms = rs.model.decision_batch(&te, 4);
    let dmax = md.iter().zip(&ms).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(dmax < 1e-2, "smo margins diverged by {dmax}");
    let err_d = wu_svm::metrics::error_rate(&md, &te.y);
    let err_s = wu_svm::metrics::error_rate(&ms, &te.y);
    assert!((err_d - err_s).abs() < 0.02, "{err_d} vs {err_s}");
}

#[test]
fn full_kernel_solvers_accept_sparse_designs() {
    // mu/primal go through full_kernel -> kernel_block: bit-identical
    // kernels mean bit-identical training on CSR input
    use wu_svm::solvers::mu::{self, MuParams};
    let dense = sparse_binary(220, 80, 0.9, 11);
    let sparse = dense.clone().with_format(Format::Csr);
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let rd = mu::train(&dense, kind, &MuParams::default()).unwrap();
    let rs = mu::train(&sparse, kind, &MuParams::default()).unwrap();
    assert_eq!(rd.model.coef, rs.model.coef);
    assert_eq!(rd.objective, rs.objective);
}
