//! Property tests for the unified trace layer (`wu_svm::trace`):
//!
//! * **Observation doesn't perturb.** For every solver, a run traced
//!   under a `Session` is bit-identical (model, objective, iterations)
//!   to the same run untraced.
//! * **Counters are consistent.** `cache_hits + cache_misses ==
//!   cache_lookups`, no events are dropped at test scale, and the span
//!   forest is well-nested (every child inside its parent).
//! * **Deterministic counters are thread-count invariant.** The cache /
//!   kernel-row / flop tallies match across cpu-par worker counts; only
//!   the pool scheduling counters may differ.
//! * **`WU_SVM_TRACE=0` is a kill switch.** Sessions become inert and
//!   nothing is recorded.
//!
//! Sessions serialize on a process-global lock, but the kill-switch test
//! mutates the environment, so every test here takes a file-local lock
//! to keep env reads and sessions from interleaving.

use std::sync::Mutex;

use wu_svm::data::Dataset;
use wu_svm::engine::Engine;
use wu_svm::kernel::operator::LowRankConfig;
use wu_svm::kernel::KernelKind;
use wu_svm::solvers::lssvm::LsSvmParams;
use wu_svm::solvers::mu::MuParams;
use wu_svm::solvers::primal::PrimalParams;
use wu_svm::solvers::smo::SmoParams;
use wu_svm::solvers::spsvm::SpSvmParams;
use wu_svm::solvers::wss::WssParams;
use wu_svm::solvers::{SolverSpec, TrainResult, Trainer};
use wu_svm::trace::{self, Counter, Span, TraceReport, COUNTER_NAMES};

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn xor_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = wu_svm::rng::Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.uniform_f32();
        let b = rng.uniform_f32();
        x.push(a);
        x.push(b);
        y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new_binary("xor", 2, x, y)
}

fn solver_cases() -> Vec<(SolverSpec, &'static str)> {
    vec![
        (SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() }), "train/smo"),
        (SolverSpec::Wss(WssParams { c: 10.0, ..Default::default() }), "train/wss"),
        (SolverSpec::Mu(MuParams { c: 1.0, max_iters: 200, ..Default::default() }), "train/mu"),
        (SolverSpec::Primal(PrimalParams { c: 5.0, ..Default::default() }), "train/primal"),
        (
            SolverSpec::SpSvm(SpSvmParams { c: 10.0, max_basis: 31, ..Default::default() }),
            "train/spsvm",
        ),
        (
            SolverSpec::LsSvm(LsSvmParams {
                c: 1.0,
                lowrank: Some(LowRankConfig::icf(32)),
                ..Default::default()
            }),
            "train/lssvm",
        ),
    ]
}

fn train(spec: SolverSpec, threads: usize, ds: &Dataset) -> TrainResult {
    // always cpu-par so only the worker count varies, never the engine path
    Trainer::new(spec)
        .kernel(KernelKind::Rbf { gamma: 8.0 })
        .engine(Engine::cpu_par(threads))
        .train(ds)
        .unwrap()
}

fn assert_bit_identical(a: &TrainResult, b: &TrainResult, who: &str) {
    assert_eq!(a.iterations, b.iterations, "{who}: iteration counts differ");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{who}: objectives differ");
    assert_eq!(a.model.bias.to_bits(), b.model.bias.to_bits(), "{who}: biases differ");
    assert_eq!(a.model.coef.len(), b.model.coef.len(), "{who}: coef counts differ");
    for (i, (x, y)) in a.model.coef.iter().zip(&b.model.coef).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{who}: coef[{i}] differs");
    }
    assert_eq!(a.model.vectors.len(), b.model.vectors.len(), "{who}: vector counts differ");
    for (i, (x, y)) in a.model.vectors.iter().zip(&b.model.vectors).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{who}: vectors[{i}] differs");
    }
}

/// Every span closes after it opens and contains all of its children.
fn assert_well_nested(spans: &[Span], lo: u64, hi: u64) {
    for s in spans {
        assert!(s.t0_ns <= s.t1_ns, "{}: t0 > t1", s.name);
        assert!(lo <= s.t0_ns && s.t1_ns <= hi, "{}: escapes parent [{lo}, {hi}]", s.name);
        assert_well_nested(&s.children, s.t0_ns, s.t1_ns);
    }
}

fn span_names(spans: &[Span], out: &mut Vec<&'static str>) {
    for s in spans {
        out.push(s.name);
        span_names(&s.children, out);
    }
}

fn all_span_names(report: &TraceReport) -> Vec<&'static str> {
    let mut names = Vec::new();
    for t in &report.threads {
        span_names(&t.roots, &mut names);
    }
    names
}

#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ds = xor_dataset(250, 1);
    for (spec, root) in solver_cases() {
        let untraced = train(spec.clone(), 2, &ds);
        let session = trace::Session::start();
        assert!(session.is_active(), "tracing unexpectedly killed via env");
        let traced = train(spec, 2, &ds);
        let report = session.finish();
        assert_bit_identical(&untraced, &traced, root);
        let names = all_span_names(&report);
        assert!(names.contains(&root), "missing root span {root} in {names:?}");
    }
}

#[test]
fn counters_are_consistent_and_report_is_well_nested() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ds = xor_dataset(300, 7);
    let session = trace::Session::start();
    assert!(session.is_active());
    let _ = train(SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() }), 2, &ds);
    let report = session.finish();

    // cache identity the CI gate also cross-checks on BENCH json
    let lookups = report.counter(Counter::CacheLookups);
    let hits = report.counter(Counter::CacheHits);
    let misses = report.counter(Counter::CacheMisses);
    assert!(lookups > 0, "smo never touched the row cache");
    assert_eq!(hits + misses, lookups, "hits + misses != lookups");
    assert!(report.counter(Counter::KernelRowsComputed) > 0);
    assert_eq!(report.counter(Counter::EventsDropped), 0);
    if let Some(rate) = report.cache_hit_rate() {
        assert!((0.0..=1.0).contains(&rate));
    }

    // spans: balanced by construction (pairing never leaves an open
    // begin once the session is drained), strictly nested by containment
    assert!(!report.threads.is_empty(), "no thread recorded any spans");
    for t in &report.threads {
        assert_well_nested(&t.roots, 0, u64::MAX);
    }
    assert!(report.coverage() <= 1.0);
    let names = all_span_names(&report);
    assert!(names.contains(&"smo/kernel"), "missing solver phase laps: {names:?}");
}

#[test]
fn deterministic_counters_are_thread_invariant() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ds = xor_dataset(220, 13);
    // pool scheduling legitimately varies with the worker count; the
    // event-drop tally is a buffer property, everything else is exact
    let scheduling = ["pool_jobs", "pool_helper_joins", "events_dropped"];
    for (spec, root) in solver_cases() {
        let mut baseline: Option<[u64; trace::NUM_COUNTERS]> = None;
        for k in [1usize, 2, 8] {
            let session = trace::Session::start();
            assert!(session.is_active());
            let _ = train(spec.clone(), k, &ds);
            let report = session.finish();
            let counters = *report.counters();
            match &baseline {
                None => baseline = Some(counters),
                Some(base) => {
                    for (i, name) in COUNTER_NAMES.iter().enumerate() {
                        if scheduling.contains(name) {
                            continue;
                        }
                        assert_eq!(
                            base[i], counters[i],
                            "{root}: counter {name} differs between k=1 and k={k}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn wu_svm_trace_0_is_a_kill_switch() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    std::env::set_var("WU_SVM_TRACE", "0");
    let session = trace::Session::start();
    assert!(!session.is_active(), "kill switch ignored");
    assert!(!trace::enabled(), "kill-switch session enabled recording");
    {
        let _sp = trace::span("never");
        trace::count(Counter::CacheHits, 99);
    }
    let report = session.finish();
    std::env::remove_var("WU_SVM_TRACE");
    assert!(report.threads.is_empty(), "inert session recorded spans");
    assert_eq!(report.counter(Counter::CacheHits), 0);
    assert_eq!(report.wall, std::time::Duration::ZERO);

    // and the switch is re-read per session: tracing works again now
    let session = trace::Session::start();
    assert!(session.is_active());
    {
        let _sp = trace::span("alive");
    }
    let report = session.finish();
    assert!(all_span_names(&report).contains(&"alive"));
}
