//! Out-of-core property tests (DESIGN.md §OOC): mmap-backed designs
//! must train bit-identically to their in-memory equivalents across
//! both explicit solvers and thread counts, packed files must round
//! trip the libsvm text path (dense and CSR, with the endianness tag
//! checked on disk), polishing must never worsen the dual objective,
//! and a deliberately starved 1 MB cache must still terminate and
//! report its hit rate.

use std::path::PathBuf;

use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::data::{libsvm, pack, Dataset, Design, Format};
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::solvers::smo::{self, SmoParams};
use wu_svm::solvers::wss::{self, WssParams};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("wu_svm_ooc_props");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn synth_binary(n: usize, d: usize, sparsity: f64, seed: u64) -> Dataset {
    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 5,
        sigma: 0.15,
        flip: 0.02,
        sparsity,
        pos_frac: 0.5,
    };
    generate(&spec, n, seed, "ooc-prop")
}

/// Pack `ds` to a temp file and map it back: the returned dataset holds
/// the same rows, served from disk.
fn packed_view(ds: &Dataset, name: &str) -> Dataset {
    let path = tmp(name);
    pack::write_packed(ds, &path).unwrap();
    pack::load_packed(&path).unwrap()
}

fn note<'a>(r: &'a wu_svm::solvers::TrainResult, key: &str) -> Option<&'a str> {
    r.notes.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

#[test]
fn smo_mmap_dense_bit_identical_across_threads() {
    let dense = synth_binary(320, 32, 0.0, 1);
    let mapped = packed_view(&dense, "smo_dense.wup");
    assert!(matches!(mapped.design, Design::MmapDense(_)));
    let kind = KernelKind::Rbf { gamma: 0.8 };
    let params = SmoParams { c: 2.0, ..Default::default() };
    for threads in [1usize, 2, 8] {
        let engine = Engine::cpu_par(threads);
        let rm = smo::train(&dense, kind, &params, &engine).unwrap();
        let rp = smo::train(&mapped, kind, &params, &engine).unwrap();
        assert_eq!(rm.model.coef, rp.model.coef, "threads {threads}");
        assert_eq!(rm.model.vectors, rp.model.vectors, "threads {threads}");
        assert_eq!(rm.model.bias, rp.model.bias, "threads {threads}");
        assert_eq!(rm.iterations, rp.iterations, "threads {threads}");
        assert_eq!(rm.objective.to_bits(), rp.objective.to_bits(), "threads {threads}");
    }
}

#[test]
fn smo_mmap_csr_bit_identical_across_threads() {
    let sparse = synth_binary(320, 64, 0.9, 2).with_format(Format::Csr);
    assert!(sparse.is_sparse());
    let mapped = packed_view(&sparse, "smo_csr.wup");
    assert!(matches!(mapped.design, Design::MmapCsr(_)));
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let params = SmoParams { c: 1.0, ..Default::default() };
    for threads in [1usize, 2, 8] {
        let engine = Engine::cpu_par(threads);
        let rm = smo::train(&sparse, kind, &params, &engine).unwrap();
        let rp = smo::train(&mapped, kind, &params, &engine).unwrap();
        assert_eq!(rm.model.coef, rp.model.coef, "threads {threads}");
        assert_eq!(rm.model.vectors, rp.model.vectors, "threads {threads}");
        assert_eq!(rm.iterations, rp.iterations, "threads {threads}");
    }
}

#[test]
fn wss_mmap_bit_identical_for_both_storages() {
    let dense = synth_binary(300, 40, 0.0, 3);
    let sparse = synth_binary(300, 60, 0.9, 4).with_format(Format::Csr);
    let kind = KernelKind::Rbf { gamma: 0.6 };
    let params = WssParams { c: 2.0, ..Default::default() };
    for (mem, name) in [(&dense, "wss_dense.wup"), (&sparse, "wss_csr.wup")] {
        let mapped = packed_view(mem, name);
        assert!(mapped.design.is_mmap());
        for threads in [1usize, 2, 8] {
            let engine = Engine::cpu_par(threads);
            let rm = wss::train(mem, kind, &params, &engine).unwrap();
            let rp = wss::train(&mapped, kind, &params, &engine).unwrap();
            assert_eq!(rm.model.coef, rp.model.coef, "{name} threads {threads}");
            assert_eq!(rm.model.vectors, rp.model.vectors, "{name} threads {threads}");
            assert_eq!(rm.iterations, rp.iterations, "{name} threads {threads}");
            assert_eq!(
                rm.objective.to_bits(),
                rp.objective.to_bits(),
                "{name} threads {threads}"
            );
        }
    }
}

#[test]
fn pack_file_round_trips_libsvm_text_dense_and_csr() {
    let ds = synth_binary(60, 24, 0.85, 5);
    let txt = tmp("round.libsvm");
    libsvm::write_file(&ds, &txt).unwrap();
    for fmt in [Format::Dense, Format::Csr] {
        let packed = tmp(&format!("round_{}.wup", fmt.name()));
        let (n, d, _) = pack::pack_file(&txt, &packed, 0, fmt).unwrap();
        let want = libsvm::read_file_with(&txt, 0, fmt).unwrap();
        assert_eq!((n, d), (want.n, want.d));
        assert!(pack::is_packed_file(&packed));
        let back = pack::load_packed(&packed).unwrap();
        assert!(back.design.is_mmap());
        assert_eq!(back.y, want.y);
        let mut wr = vec![0.0f32; want.d];
        let mut br = vec![0.0f32; want.d];
        for i in 0..want.n {
            want.row_into(i, &mut wr);
            back.row_into(i, &mut br);
            assert_eq!(wr, br, "format {} row {i}", fmt.name());
        }
        // the native-endian tag sits at header offset 12; a swapped tag
        // must be diagnosed as an endianness mismatch, never misread
        let mut bytes = std::fs::read(&packed).unwrap();
        let tag = u32::from_ne_bytes(bytes[12..16].try_into().unwrap());
        assert_eq!(tag, pack::ENDIAN_TAG);
        bytes[12..16].copy_from_slice(&pack::ENDIAN_TAG.swap_bytes().to_ne_bytes());
        std::fs::write(&packed, &bytes).unwrap();
        let err = pack::load_packed(&packed).unwrap_err().to_string();
        assert!(err.contains("endian"), "{err}");
        std::fs::remove_file(packed).ok();
    }
    std::fs::remove_file(txt).ok();
}

#[test]
fn polish_never_worsens_objective_and_reports_verdict() {
    let dense = synth_binary(300, 24, 0.0, 6);
    let mapped = packed_view(&dense, "polish.wup");
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let engine = Engine::cpu_par(4);
    let base =
        smo::train(&mapped, kind, &SmoParams { c: 4.0, ..Default::default() }, &engine).unwrap();
    let pol = smo::train(
        &mapped,
        kind,
        &SmoParams { c: 4.0, polish: true, ..Default::default() },
        &engine,
    )
    .unwrap();
    // each polish step strictly decreases the dual objective, so "on"
    // can only match or improve the converged value
    assert!(
        pol.objective <= base.objective + 1e-12,
        "polish worsened the objective: {} vs {}",
        pol.objective,
        base.objective
    );
    let verdict = note(&pol, "polish").expect("polish verdict note");
    assert!(verdict == "clean" || verdict == "capped", "{verdict}");
    assert!(note(&pol, "polish_steps").is_some());
    // the flag off must stay bit-identical to the phase not existing
    assert_eq!(base.objective.to_bits(), {
        let again = smo::train(&mapped, kind, &SmoParams { c: 4.0, ..Default::default() }, &engine)
            .unwrap();
        again.objective.to_bits()
    });
    // wss reports a verdict too and lands on an eps-accurate optimum
    let wb =
        wss::train(&mapped, kind, &WssParams { c: 4.0, ..Default::default() }, &engine).unwrap();
    let wp = wss::train(
        &mapped,
        kind,
        &WssParams { c: 4.0, polish: true, cache_slack: 0.5, ..Default::default() },
        &engine,
    )
    .unwrap();
    let v = note(&wp, "polish").expect("wss polish verdict note");
    assert!(v == "clean" || v == "capped" || v == "stalled", "{v}");
    let rel = (wp.objective - wb.objective).abs() / wb.objective.abs().max(1.0);
    assert!(rel < 5e-3, "wss polish objective diverged: {} vs {}", wp.objective, wb.objective);
}

#[test]
fn tiny_cache_trains_to_completion_and_reports_hit_rate() {
    // 1 MB holds ~170 of the 1200 kernel rows, so the run must evict
    // constantly; it still has to terminate and report its hit rate
    let dense = synth_binary(1200, 48, 0.0, 7);
    let mapped = packed_view(&dense, "tiny.wup");
    let kind = KernelKind::Rbf { gamma: 0.5 };
    let engine = Engine::cpu_par(2);
    let params = SmoParams {
        c: 1.0,
        cache_mb: 1,
        cache_slack: 0.25,
        polish: true,
        ..Default::default()
    };
    let r = smo::train(&mapped, kind, &params, &engine).unwrap();
    assert!(r.model.num_vectors() > 0);
    let rate: f64 = note(&r, "cache_hit_rate").expect("hit-rate note").parse().unwrap();
    assert!((0.0..=1.0).contains(&rate), "hit rate {rate} out of range");
    assert!(note(&r, "polish").is_some());
}
