//! Property-based tests (seeded-RNG case generation; the offline registry
//! has no proptest). Each property runs across a few hundred random cases
//! and shrinks nothing — failures print the case seed for reproduction.

use wu_svm::data::Dataset;
use wu_svm::engine::Engine;
use wu_svm::kernel::{cache::RowCache, KernelKind};
use wu_svm::pool;
use wu_svm::rng::Rng;

fn rand_dataset(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let x: Vec<f32> = (0..n * d).map(|_| rng.uniform_f32()).collect();
    let y: Vec<f32> = (0..n)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    Dataset::new_binary("p", d, x, y)
}

#[test]
fn prop_split_ranges_always_partition() {
    let mut rng = Rng::new(1);
    for case in 0..500 {
        let n = rng.below(10_000);
        let parts = 1 + rng.below(64);
        let rs = pool::split_ranges(n, parts);
        let total: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(total, n, "case {case}: n={n} parts={parts}");
        let mut next = 0;
        for r in &rs {
            assert_eq!(r.start, next, "case {case}: gap/overlap");
            assert!(r.end > r.start, "case {case}: empty range emitted");
            next = r.end;
        }
    }
}

#[test]
fn prop_parallel_for_covers_every_index_once() {
    let mut rng = Rng::new(2);
    for case in 0..60 {
        let n = rng.below(3000);
        let threads = 1 + rng.below(16);
        let chunk = 1 + rng.below(40);
        let hits: Vec<std::sync::atomic::AtomicU8> =
            (0..n).map(|_| std::sync::atomic::AtomicU8::new(0)).collect();
        pool::parallel_for(threads, n, chunk, |i| {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert!(
            hits.iter().all(|h| h.load(std::sync::atomic::Ordering::Relaxed) == 1),
            "case {case}: n={n} threads={threads} chunk={chunk}"
        );
    }
}

#[test]
fn prop_scale_unit_bounds_and_idempotence() {
    let mut rng = Rng::new(3);
    for case in 0..100 {
        let n = 2 + rng.below(100);
        let d = 1 + rng.below(20);
        let mut ds = Dataset::new_binary(
            "s",
            d,
            (0..n * d).map(|_| (rng.gaussian_f32()) * 100.0).collect(),
            (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
        );
        ds.scale_unit();
        assert!(
            ds.dense_x().iter().all(|&v| (0.0..=1.0).contains(&v)),
            "case {case}: out of unit interval"
        );
        let before = ds.dense_x().to_vec();
        ds.scale_unit(); // idempotent on already-scaled data
        for (a, b) in before.iter().zip(ds.dense_x()) {
            assert!((a - b).abs() < 1e-6, "case {case}: not idempotent");
        }
    }
}

#[test]
fn prop_row_cache_never_returns_wrong_row() {
    let mut rng = Rng::new(4);
    for case in 0..50 {
        let rows = 2 + rng.below(30);
        let len = 1 + rng.below(16);
        let cap_bytes = (1 + rng.below(10)) * len * 4;
        let mut cache = RowCache::new(cap_bytes, len);
        for _ in 0..500 {
            let i = rng.below(rows);
            let got = cache.get_or_compute(i, |out| {
                out.iter_mut().for_each(|v| *v = i as f32);
            });
            assert!(
                got.iter().all(|&v| v == i as f32),
                "case {case}: stale row for {i}"
            );
        }
    }
}

#[test]
fn prop_blocked_gemm_matches_naive_and_is_thread_deterministic() {
    use wu_svm::linalg::{gemm_nt, gemm_nt_naive, Matrix};
    let mut rng = Rng::new(21);
    for case in 0..40 {
        let m = 1 + rng.below(80);
        let n = 1 + rng.below(80);
        let k = rng.below(300); // includes 0, k < MR, and slab-crossing
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.gaussian_f32()).collect());
        let b = Matrix::from_vec(n, k, (0..n * k).map(|_| rng.gaussian_f32()).collect());
        let mut c1 = Matrix::zeros(m, n);
        gemm_nt(1, &a, &b, &mut c1);
        // agrees with the seed's f64 dot-loop reference
        let mut e = Matrix::zeros(m, n);
        gemm_nt_naive(2, &a, &b, &mut e);
        let dmax = c1.max_abs_diff(&e);
        let tol = 1e-4 * (k as f32).sqrt().max(1.0);
        assert!(dmax < tol, "case {case} ({m},{n},{k}): diff {dmax} > {tol}");
        // bit-identical C for every thread count
        for threads in [2usize, 8] {
            let mut ck = Matrix::zeros(m, n);
            gemm_nt(threads, &a, &b, &mut ck);
            assert_eq!(
                c1.data, ck.data,
                "case {case} ({m},{n},{k}): threads {threads} not bit-identical"
            );
        }
    }
}

#[test]
fn prop_engines_agree_on_random_shapes() {
    let mut rng = Rng::new(5);
    let seq = Engine::cpu_seq();
    let par = Engine::cpu_par(4);
    for case in 0..40 {
        let t = 1 + rng.below(300);
        let d = 1 + rng.below(50);
        let b = 1 + rng.below(40);
        let x: Vec<f32> = (0..t * d).map(|_| rng.uniform_f32()).collect();
        let xb: Vec<f32> = (0..b * d).map(|_| rng.uniform_f32()).collect();
        let gamma = rng.uniform_f32() * 2.0;
        let k1 = seq.rbf_block(&x, t, d, &xb, b, gamma).unwrap();
        let k2 = par.rbf_block(&x, t, d, &xb, b, gamma).unwrap();
        let dmax: f32 = k1.iter().zip(&k2).map(|(a, c)| (a - c).abs()).fold(0.0, f32::max);
        assert!(dmax < 1e-5, "case {case}: rbf diff {dmax}");
        // kernel values are valid RBF values
        assert!(k1.iter().all(|&v| (0.0..=1.0 + 1e-6).contains(&v)), "case {case}");
    }
}

#[test]
fn prop_shared_cache_never_returns_wrong_row() {
    use wu_svm::kernel::cache::SharedRowCache;
    let mut rng = Rng::new(14);
    for case in 0..30 {
        let rows = 2 + rng.below(30);
        let len = 1 + rng.below(16);
        let cap_bytes = (1 + rng.below(10)) * len * 4;
        let cache = SharedRowCache::new(cap_bytes, 1 + rng.below(4));
        for _ in 0..400 {
            let g = rng.below(3) as u64;
            let i = rng.below(rows);
            let want = (g as f32) * 100.0 + i as f32;
            let got = cache
                .get_or_try_compute(g, i, len, |out| {
                    out.iter_mut().for_each(|v| *v = want);
                    Ok(())
                })
                .unwrap();
            assert!(
                got.iter().all(|&v| v == want),
                "case {case}: stale row for group {g} index {i}"
            );
        }
    }
}

#[test]
fn prop_parallel_smo_matches_sequential_objective_and_svs() {
    // cpu_par(k) must reproduce cpu_seq exactly (chunk-ordered reductions)
    // for k in {1, 2, 8}, with shrinking both on and off.
    use wu_svm::solvers::smo::{self, SmoParams};
    let mut rng = Rng::new(15);
    for case in 0..6 {
        let n = 150 + rng.below(150);
        let ds = rand_dataset(&mut rng, n, 3);
        let c = 0.5 + rng.uniform_f32() * 5.0;
        let kind = KernelKind::Rbf { gamma: 1.0 + rng.uniform_f32() * 4.0 };
        for shrinking in [false, true] {
            let p = SmoParams { c, shrinking, ..Default::default() };
            let base = smo::train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
            for k in [1usize, 2, 8] {
                let r = smo::train(&ds, kind, &p, &Engine::cpu_par(k)).unwrap();
                let rel = (r.objective - base.objective).abs()
                    / base.objective.abs().max(1.0);
                assert!(
                    rel < 1e-6,
                    "case {case} k={k} shrinking={shrinking}: objective {} vs {}",
                    r.objective,
                    base.objective
                );
                assert_eq!(
                    r.model.coef.len(),
                    base.model.coef.len(),
                    "case {case} k={k} shrinking={shrinking}: sv count"
                );
            }
        }
    }
}

#[test]
fn prop_smo_satisfies_kkt_approximately() {
    let mut rng = Rng::new(6);
    for case in 0..12 {
        let n = 40 + rng.below(120);
        let ds = rand_dataset(&mut rng, n, 3);
        let c = 0.5 + rng.uniform_f32() * 5.0;
        let kind = KernelKind::Rbf { gamma: 1.0 + rng.uniform_f32() * 4.0 };
        let r = wu_svm::solvers::smo::train(
            &ds,
            kind,
            &wu_svm::solvers::smo::SmoParams { c, eps: 1e-3, ..Default::default() },
            &Engine::cpu_seq(),
        )
        .unwrap();
        // box constraint: |coef| = |alpha y| <= C
        assert!(
            r.model.coef.iter().all(|&v| v.abs() <= c + 1e-4),
            "case {case}: coef out of box"
        );
        // KKT: free SVs (0 < alpha < C) sit near the margin y f = 1
        let margins = r.model.decision_batch(&ds, 2);
        let mut worst: f32 = 0.0;
        for (j, &co) in r.model.coef.iter().enumerate() {
            let a = co.abs();
            if a > 1e-5 && a < c - 1e-5 {
                // find this SV's row in ds to read its label/margin
                let vrow = &r.model.vectors[j * ds.d..(j + 1) * ds.d];
                if let Some(i) = (0..ds.n).find(|&i| ds.row(i) == vrow) {
                    worst = worst.max((ds.y[i] * margins[i] - 1.0).abs());
                }
            }
        }
        assert!(worst < 0.05, "case {case}: free SV margin violation {worst}");
    }
}

#[test]
fn prop_spsvm_respects_capacity_and_mask() {
    let mut rng = Rng::new(7);
    for case in 0..6 {
        let n = 300 + rng.below(500);
        let ds = rand_dataset(&mut rng, n, 4);
        let cap = 8 + rng.below(40);
        let r = wu_svm::solvers::spsvm::train(
            &ds,
            &wu_svm::solvers::spsvm::SpSvmParams {
                c: 1.0,
                gamma: 2.0,
                max_basis: cap,
                seed: case as u64,
                ..Default::default()
            },
            &Engine::cpu_par(4),
        )
        .unwrap();
        assert!(
            r.model.num_vectors() <= cap,
            "case {case}: {} > cap {cap}",
            r.model.num_vectors()
        );
        // basis vectors must be actual training rows
        for j in 0..r.model.num_vectors().min(5) {
            let v = &r.model.vectors[j * ds.d..(j + 1) * ds.d];
            assert!(
                (0..ds.n).any(|i| ds.row(i) == v),
                "case {case}: basis vector {j} not from the training set"
            );
        }
    }
}

#[test]
fn prop_serve_batcher_answers_all_under_random_load() {
    let mut rng = Rng::new(8);
    for case in 0..4 {
        for &shards in &[1usize, 2, 4] {
            let batch = 1 + rng.below(64);
            let n_req = 1 + rng.below(300);
            let model = wu_svm::model::SvmModel {
                kernel: KernelKind::Rbf { gamma: 0.5 },
                vectors: vec![0.2, 0.8, 0.9, 0.1],
                d: 2,
                coef: vec![1.0, -0.5],
                bias: 0.05,
                solver: "p".into(),
            };
            let server = wu_svm::serve::Server::start(
                &model,
                Engine::cpu_seq(),
                wu_svm::serve::ServeConfig {
                    batch,
                    max_wait: std::time::Duration::from_micros(200),
                    shards,
                    queue_cap: 4096,
                },
            );
            let client = server.client();
            let pending: Vec<_> = (0..n_req)
                .map(|_| {
                    let f = vec![rng.uniform_f32(), rng.uniform_f32()];
                    let p = client.submit(f.clone()).expect("queue must admit");
                    (p, f)
                })
                .collect();
            for (p, f) in pending {
                let resp = p.wait().expect("response must arrive");
                assert_eq!(
                    resp.id, p.id,
                    "case {case}/{shards}: response routed to wrong request"
                );
                let want = model.decision(&f);
                let got = resp.output.margin().unwrap();
                assert!(
                    (got - want).abs() < 1e-4,
                    "case {case}/{shards}: margin {got} want {want}"
                );
                assert!(p.try_take().is_none(), "case {case}/{shards}: answered twice");
            }
            let stats = server.stop();
            assert_eq!(stats.requests, n_req as u64, "case {case}/{shards}");
            assert!(stats.max_batch <= batch, "case {case}/{shards}: batch overflow");
            assert_eq!(stats.fallbacks, 0, "case {case}/{shards}: silent fallback");
        }
    }
}

#[test]
fn prop_manifest_lookup_minimal_fitting_bucket() {
    use wu_svm::runtime::Manifest;
    let mut rng = Rng::new(9);
    // synthetic manifest with random bucket grid
    let mut text = String::from("# tile_t=1024 s_cand=64\n");
    let mut ds: Vec<usize> = (0..4).map(|_| 32 << rng.below(6)).collect();
    ds.sort_unstable();
    ds.dedup();
    let mut bs: Vec<usize> = (0..3).map(|_| 64 << rng.below(4)).collect();
    bs.sort_unstable();
    bs.dedup();
    for &d in &ds {
        for &b in &bs {
            text.push_str(&format!("kernel_block 1024 {d} {b} 0 kb_{d}_{b}.hlo\n"));
        }
    }
    let m = Manifest::parse(&text, std::path::Path::new("/x")).unwrap();
    for _ in 0..300 {
        let want_d = 1 + rng.below(*ds.last().unwrap());
        let want_b = 1 + rng.below(*bs.last().unwrap());
        let e = m.lookup("kernel_block", 0, want_d, want_b, 0).unwrap();
        assert!(e.d >= want_d && e.b >= want_b, "bucket must fit");
        // minimality: no other bucket fits with smaller (d, b) pair order
        let smaller_fits = ds
            .iter()
            .any(|&d| d >= want_d && d < e.d)
            .then(|| true)
            .unwrap_or(false);
        if smaller_fits {
            // lookup sorts by (d, b): a smaller fitting d must not exist
            panic!("non-minimal d bucket chosen: {} for want {}", e.d, want_d);
        }
    }
}
