//! Property tests for the KernelOperator abstraction and the low-rank
//! path (DESIGN.md §LOWRANK): full-rank ICF reproduces the exact kernel,
//! every operator's matvec is bit-identical across thread counts, the
//! implicit solvers train identical models through the operator layer,
//! LS-SVM tracks SMO on paper-set analogs, and the rank-256 operator's
//! footprint stays a small fraction of the exact kernel at n = 20k.

use wu_svm::data::synth::{generate, SynthSpec};
use wu_svm::data::{paper, Dataset, Format};
use wu_svm::engine::Engine;
use wu_svm::kernel::operator::{ExactCsr, ExactDense, ExactTiled, KernelOperator, LowRank};
use wu_svm::kernel::{kernel_block, KernelKind};
use wu_svm::metrics::error_rate;
use wu_svm::rng::Rng;
use wu_svm::solvers::smo::{self, SmoParams};
use wu_svm::solvers::{lssvm, mu, primal, SolverSpec, Trainer};

fn binary(n: usize, d: usize, sparsity: f64, seed: u64) -> Dataset {
    let spec = SynthSpec {
        d,
        classes: 2,
        clusters: 5,
        sigma: 0.15,
        flip: 0.02,
        sparsity,
        pos_frac: 0.5,
    };
    generate(&spec, n, seed, "lowrank-prop")
}

#[test]
fn prop_full_rank_icf_reproduces_exact_kernel_block() {
    // rank = n with tol = 0 runs the pivoted Cholesky to completion, so
    // G Gᵀ must reproduce K to factorization rounding (the satellite's
    // stated 1e-5 gate) on arbitrary row/column subsets
    let ds = binary(160, 16, 0.0, 21);
    let kind = KernelKind::Rbf { gamma: 0.8 };
    let op = LowRank::icf(&kind, &ds, 4, ds.n, 0.0);
    let mut rng = Rng::new(22);
    let ri: Vec<usize> = (0..40).map(|_| rng.below(ds.n)).collect();
    let ci: Vec<usize> = (0..25).map(|_| rng.below(ds.n)).collect();
    let mut approx = vec![0.0f32; ri.len() * ci.len()];
    let mut exact = vec![0.0f32; ri.len() * ci.len()];
    op.block(&ri, &ci, &mut approx);
    kernel_block(&kind, &ds, &ri, &ci, 4, &mut exact);
    for (idx, (a, e)) in approx.iter().zip(&exact).enumerate() {
        assert!((a - e).abs() <= 1e-5, "elem {idx}: {a} vs {e}");
    }
    // RBF diag is exactly 1; the factor's diag must agree to the same gate
    let mut dg = vec![0.0f32; ds.n];
    op.diag(&mut dg);
    for (i, v) in dg.iter().enumerate() {
        assert!((v - 1.0).abs() <= 1e-5, "diag {i} = {v}");
    }
}

#[test]
fn prop_operator_matvec_bit_identical_across_threads() {
    // the repo-wide determinism contract, restated per operator: the
    // thread count partitions work but never reorders any accumulation
    let dense = binary(300, 24, 0.0, 23);
    let sparse = binary(300, 64, 0.9, 24).with_format(Format::Csr);
    let kind = KernelKind::Rbf { gamma: 0.6 };
    let mut rng = Rng::new(25);
    let v: Vec<f32> = (0..300).map(|_| rng.gaussian_f32()).collect();
    let base: Vec<Box<dyn KernelOperator + '_>> = vec![
        Box::new(ExactTiled::new(kind, &dense, 1)),
        Box::new(ExactCsr::new(kind, &sparse, 1).unwrap()),
        Box::new(LowRank::icf(&kind, &dense, 1, 48, 1e-8)),
        Box::new(LowRank::nystrom(&kind, &dense, 1, 48).unwrap()),
    ];
    for threads in [2usize, 8] {
        let ops: Vec<Box<dyn KernelOperator + '_>> = vec![
            Box::new(ExactTiled::new(kind, &dense, threads)),
            Box::new(ExactCsr::new(kind, &sparse, threads).unwrap()),
            Box::new(LowRank::icf(&kind, &dense, threads, 48, 1e-8)),
            Box::new(LowRank::nystrom(&kind, &dense, threads, 48).unwrap()),
        ];
        for (b, o) in base.iter().zip(&ops) {
            let mut want = vec![0.0f32; 300];
            let mut got = vec![0.0f32; 300];
            b.matvec(&v, &mut want);
            o.matvec(&v, &mut got);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.to_bits(), g.to_bits(), "{} at {threads} threads", o.name());
            }
        }
    }
}

#[test]
fn prop_dense_and_tiled_operators_bit_equal() {
    // the substitution argument behind the solver rewiring: ExactDense
    // (the pre-refactor materialized kernel) and ExactTiled (the
    // streaming form) expose bit-identical matvecs and blocks, so
    // swapping one for the other cannot move a single model bit
    let ds = binary(240, 20, 0.0, 26);
    let kind = KernelKind::Rbf { gamma: 1.2 };
    let dense = ExactDense::build(&kind, &ds, 4, usize::MAX).unwrap();
    let tiled = ExactTiled::new(kind, &ds, 4);
    let mut rng = Rng::new(27);
    let v: Vec<f32> = (0..ds.n).map(|_| rng.gaussian_f32()).collect();
    let (mut a, mut b) = (vec![0.0f32; ds.n], vec![0.0f32; ds.n]);
    dense.matvec(&v, &mut a);
    tiled.matvec(&v, &mut b);
    assert_eq!(
        a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
    let ri: Vec<usize> = (0..30).map(|_| rng.below(ds.n)).collect();
    let ci: Vec<usize> = (0..17).map(|_| rng.below(ds.n)).collect();
    let (mut ka, mut kb) = (vec![0.0f32; 30 * 17], vec![0.0f32; 30 * 17]);
    dense.block(&ri, &ci, &mut ka);
    tiled.block(&ri, &ci, &mut kb);
    assert_eq!(
        ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn implicit_solvers_thread_invariant_through_operator_layer() {
    // mu and primal now reach the kernel only through KernelOperator;
    // training must stay bit-identical across engine thread counts
    let ds = binary(200, 16, 0.0, 28);
    let kind = KernelKind::Rbf { gamma: 0.9 };
    for spec in [
        SolverSpec::Mu(mu::MuParams::default()),
        SolverSpec::Primal(primal::PrimalParams::default()),
    ] {
        let r2 = Trainer::new(spec.clone())
            .kernel(kind)
            .engine(Engine::cpu_par(2))
            .train(&ds)
            .unwrap();
        let r8 = Trainer::new(spec)
            .kernel(kind)
            .engine(Engine::cpu_par(8))
            .train(&ds)
            .unwrap();
        assert_eq!(r2.model.coef, r8.model.coef);
        assert_eq!(r2.model.bias, r8.model.bias);
        assert_eq!(r2.iterations, r8.iterations);
    }
}

#[test]
fn lssvm_tracks_smo_on_paper_analogs() {
    // the satellite's accuracy gate: on synthetic paper-set analogs the
    // default (rank-256 ICF) LS-SVM lands within one error point of SMO
    for (key, scale) in [("adult", 0.02), ("covertype", 0.0015)] {
        let spec = paper::spec(key).unwrap();
        let (tr, te) = spec.generate(scale, 1);
        let kind = KernelKind::Rbf { gamma: spec.gamma };
        let engine = Engine::cpu_par(4);
        let sp = SmoParams { c: spec.c, ..Default::default() };
        let rs = smo::train(&tr, kind, &sp, &engine).unwrap();
        let lp = lssvm::LsSvmParams { c: spec.c, ..Default::default() };
        let rl = lssvm::train(&tr, kind, &lp).unwrap();
        let es = error_rate(&rs.model.decision_batch(&te, 4), &te.y);
        let el = error_rate(&rl.model.decision_batch(&te, 4), &te.y);
        assert!(el <= es + 0.01, "{key}: smo {es:.4} vs lssvm {el:.4}");
    }
}

#[test]
fn lowrank_memory_under_ten_percent_at_20k() {
    // the acceptance criterion verbatim: n = 20k synthetic RBF rows,
    // r = 256 → the operator's own footprint stays under 10% of the
    // 4 n² bytes an exact materialized kernel would take (it is ~1.3%)
    let n = 20_000;
    let ds = binary(n, 24, 0.0, 29);
    let kind = KernelKind::Rbf { gamma: 0.5 };
    let op = LowRank::icf(&kind, &ds, 8, 256, 1e-9);
    assert_eq!(op.rank(), 256);
    let exact = 4 * n * n;
    assert!(
        op.memory_bytes() * 10 < exact,
        "operator {} bytes vs exact {exact}",
        op.memory_bytes()
    );
}
