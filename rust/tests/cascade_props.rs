//! Property tests for the cascade sharded-training subsystem
//! (`wu_svm::cascade`) and the warm-start plumbing it rides on:
//!
//! * `shards = 1` delegates to the inner solver **bit-identically** —
//!   the cascade must cost nothing when it isn't used.
//! * Sharded runs (S = 2, 4, 8) agree with direct training: the global
//!   KKT feedback loop drives both to the same stopping criterion, so
//!   test-set margins and error rates must match closely.
//! * The whole pipeline is deterministic for a fixed seed across
//!   worker-thread counts (partitioning is thread-free, the solvers
//!   and merges are chunk-order deterministic).
//! * The KKT feedback loop terminates under wall and iteration budgets.
//! * Warm start: a zero vector is bit-identical to a cold start
//!   (SMO and WSS), converged alphas restart cheaply, and solvers
//!   without box duals reject the field with a note.

use std::time::Duration;

use wu_svm::cascade::{partition, CascadeParams, PartitionStrategy};
use wu_svm::data::Dataset;
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::solvers::mu::MuParams;
use wu_svm::solvers::smo::SmoParams;
use wu_svm::solvers::wss::WssParams;
use wu_svm::solvers::{Budget, SolverSpec, TrainResult, Trainer};

fn xor_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = wu_svm::rng::Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.uniform_f32();
        let b = rng.uniform_f32();
        x.push(a);
        x.push(b);
        y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new_binary("xor", 2, x, y)
}

const KIND: KernelKind = KernelKind::Rbf { gamma: 8.0 };

fn smo_spec() -> SolverSpec {
    SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() })
}

fn cascade_spec(shards: usize, inner: SolverSpec) -> SolverSpec {
    SolverSpec::Cascade(CascadeParams {
        shards,
        inner: Box::new(inner),
        ..Default::default()
    })
}

fn assert_bit_identical(a: &TrainResult, b: &TrainResult) {
    assert_eq!(a.iterations, b.iterations, "iteration counts differ");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "objectives differ");
    assert_eq!(a.model.bias.to_bits(), b.model.bias.to_bits(), "biases differ");
    assert_eq!(a.model.coef.len(), b.model.coef.len(), "coef counts differ");
    for (i, (x, y)) in a.model.coef.iter().zip(&b.model.coef).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "coef[{i}] differs");
    }
    assert_eq!(a.model.vectors.len(), b.model.vectors.len());
    for (i, (x, y)) in a.model.vectors.iter().zip(&b.model.vectors).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "vectors[{i}] differs");
    }
}

fn note<'a>(r: &'a TrainResult, key: &str) -> Option<&'a str> {
    r.notes.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

#[test]
fn cascade_of_one_shard_is_bit_identical_to_direct() {
    let ds = xor_dataset(300, 1);
    let direct = Trainer::new(smo_spec())
        .kernel(KIND)
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    let cascaded = Trainer::new(cascade_spec(1, smo_spec()))
        .kernel(KIND)
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    assert!(direct.iterations > 10, "degenerate run");
    assert_bit_identical(&direct, &cascaded);
    // the dual vectors match too (warm-start plumbing end to end)
    let (da, ca) = (direct.alpha.as_ref().unwrap(), cascaded.alpha.as_ref().unwrap());
    assert_eq!(da.len(), ca.len());
    for (i, (x, y)) in da.iter().zip(ca).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "alpha[{i}] differs");
    }
}

#[test]
fn sharded_cascade_agrees_with_direct_training() {
    let train = xor_dataset(360, 3);
    let test = xor_dataset(400, 103);
    let threads = 4;
    let direct = Trainer::new(smo_spec())
        .kernel(KIND)
        .engine(Engine::cpu_par(threads))
        .train(&train)
        .unwrap();
    let dm = direct.model.decision_batch(&test, threads);
    let derr = err(&dm, &test.y);
    for shards in [2usize, 4, 8] {
        let r = Trainer::new(cascade_spec(shards, smo_spec()))
            .kernel(KIND)
            .engine(Engine::cpu_par(threads))
            .train(&train)
            .unwrap();
        assert_eq!(note(&r, "cascade_shards"), Some(shards.to_string().as_str()));
        let cm = r.model.decision_batch(&test, threads);
        let cerr = err(&cm, &test.y);
        // both models satisfy the same global KKT criterion, so test
        // behavior must agree: within one error point (+ one test-row
        // quantum) and with closely matching margins
        assert!(
            (derr - cerr).abs() <= 0.01 + 1.0 / test.n as f64,
            "S={shards}: direct err {derr:.4} vs cascade err {cerr:.4}"
        );
        let agree = dm
            .iter()
            .zip(&cm)
            .filter(|(a, b)| (**a > 0.0) == (**b > 0.0))
            .count();
        assert!(
            agree as f64 >= 0.98 * test.n as f64,
            "S={shards}: only {agree}/{} prediction agreements",
            test.n
        );
        let mean_diff: f64 = dm
            .iter()
            .zip(&cm)
            .map(|(a, b)| (*a as f64 - *b as f64).abs())
            .sum::<f64>()
            / test.n as f64;
        assert!(mean_diff < 0.05, "S={shards}: mean margin diff {mean_diff:.4}");
        // the dual vector the cascade reports is box-feasible & balanced
        let alpha = r.alpha.as_ref().unwrap();
        assert_eq!(alpha.len(), train.n);
        assert!(alpha.iter().all(|&a| (0.0f32..=10.0 + 1e-4).contains(&a)));
        let s: f64 = alpha
            .iter()
            .zip(&train.y)
            .map(|(&a, &y)| a as f64 * y as f64)
            .sum();
        assert!(s.abs() < 1e-2, "S={shards}: sum alpha_i y_i = {s}");
    }
}

fn err(margins: &[f32], y: &[f32]) -> f64 {
    let wrong = margins
        .iter()
        .zip(y)
        .filter(|(m, y)| (**m > 0.0) != (**y > 0.0))
        .count();
    wrong as f64 / y.len() as f64
}

#[test]
fn cascade_is_deterministic_across_thread_counts() {
    // partitioning is a pure function of (n, shards, strategy, seed)...
    for strat in [
        PartitionStrategy::Contiguous,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::SeededShuffle,
    ] {
        assert_eq!(partition(500, 8, strat, 7), partition(500, 8, strat, 7));
    }
    // ...and the whole training is chunk-order deterministic, so the
    // final model is bit-identical for every worker count
    let ds = xor_dataset(320, 5);
    let mut baseline: Option<TrainResult> = None;
    for threads in [1usize, 2, 8] {
        let r = Trainer::new(cascade_spec(4, smo_spec()))
            .kernel(KIND)
            .engine(Engine::cpu_par(threads))
            .train(&ds)
            .unwrap();
        match &baseline {
            None => baseline = Some(r),
            Some(base) => assert_bit_identical(base, &r),
        }
    }
}

#[test]
fn kkt_feedback_loop_terminates_under_budgets() {
    let ds = xor_dataset(300, 9);
    // zero wall budget: every sub-training stops after one iteration,
    // the outer loop short-circuits, and the run still returns a model
    let r = Trainer::new(cascade_spec(4, smo_spec()))
        .kernel(KIND)
        .budget(Budget::wall(Duration::ZERO))
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    assert_eq!(note(&r, "capped"), Some("wall"), "notes {:?}", r.notes);
    assert!(!r.model.coef.is_empty() || r.model.vectors.is_empty());
    // a tiny iteration budget bounds every subproblem; the outer loop
    // is bounded by max_outer regardless of convergence
    let r = Trainer::new(cascade_spec(4, smo_spec()))
        .kernel(KIND)
        .budget(Budget::iters(3))
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    let rounds: usize = note(&r, "cascade_outer_rounds").unwrap().parse().unwrap();
    assert!(rounds <= CascadeParams::default().max_outer, "rounds {rounds}");
}

#[test]
fn cascade_runs_with_wss_inner() {
    let ds = xor_dataset(240, 11);
    let inner = SolverSpec::Wss(WssParams { c: 10.0, ..Default::default() });
    let r = Trainer::new(cascade_spec(2, inner))
        .kernel(KIND)
        .engine(Engine::cpu_par(2))
        .train(&ds)
        .unwrap();
    assert_eq!(r.model.solver, "cascade(wss)");
    assert!(note(&r, "cascade_kkt").is_some());
}

#[test]
fn cascade_rejects_non_dual_inner_solvers() {
    let ds = xor_dataset(100, 13);
    let inner = SolverSpec::Mu(MuParams::default());
    let e = Trainer::new(cascade_spec(2, inner)).kernel(KIND).train(&ds).unwrap_err();
    assert!(e.to_string().contains("dual decomposition"), "{e}");
    let nested = cascade_spec(2, cascade_spec(2, smo_spec()));
    let e = Trainer::new(nested).kernel(KIND).train(&ds).unwrap_err();
    assert!(e.to_string().contains("nest"), "{e}");
}

// ---- warm-start plumbing (the satellite the cascade rides on) --------

#[test]
fn zero_warm_start_is_bit_identical_to_cold_start() {
    let ds = xor_dataset(250, 21);
    for spec in [
        smo_spec(),
        SolverSpec::Wss(WssParams { c: 10.0, ..Default::default() }),
    ] {
        let name = spec.name().to_string();
        let cold = Trainer::new(spec.clone()).kernel(KIND).train(&ds).unwrap();
        let warm = Trainer::new(spec)
            .kernel(KIND)
            .initial_alpha(vec![0.0; ds.n])
            .train(&ds)
            .unwrap();
        assert_bit_identical(&cold, &warm);
        assert_eq!(note(&warm, "warm_start"), Some("zero (cold)"), "{name}");
        assert_eq!(note(&cold, "warm_start"), None, "{name}");
    }
}

#[test]
fn warm_start_from_converged_alphas_restarts_cheaply() {
    let ds = xor_dataset(300, 23);
    let cold = Trainer::new(smo_spec()).kernel(KIND).train(&ds).unwrap();
    let alpha = cold.alpha.clone().unwrap();
    assert_eq!(alpha.len(), ds.n);
    let warm = Trainer::new(smo_spec())
        .kernel(KIND)
        .initial_alpha(alpha)
        .train(&ds)
        .unwrap();
    assert_eq!(note(&warm, "warm_start"), Some("accepted"));
    assert!(
        warm.iterations < cold.iterations,
        "warm restart took {} iters vs {} cold",
        warm.iterations,
        cold.iterations
    );
    // the restart lands on (essentially) the same solution
    assert!((warm.objective - cold.objective).abs() <= 1e-3 * cold.objective.abs() + 1e-6);
}

#[test]
fn initial_alpha_length_is_validated() {
    let ds = xor_dataset(100, 25);
    let e = Trainer::new(smo_spec())
        .kernel(KIND)
        .initial_alpha(vec![0.0; 7])
        .train(&ds)
        .unwrap_err();
    assert!(e.to_string().contains("initial_alpha"), "{e}");
}

#[test]
fn solvers_without_box_duals_reject_warm_start_with_a_note() {
    let ds = xor_dataset(120, 27);
    let r = Trainer::new(SolverSpec::Mu(MuParams { c: 1.0, ..Default::default() }))
        .kernel(KIND)
        .initial_alpha(vec![0.0; ds.n])
        .train(&ds)
        .unwrap();
    assert!(
        note(&r, "warm_start").is_some_and(|v| v.starts_with("rejected")),
        "notes {:?}",
        r.notes
    );
    assert!(r.alpha.is_none(), "mu has no box-constrained duals to report");
}
