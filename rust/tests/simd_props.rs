//! Property tests for the runtime-dispatched SIMD backend layer
//! (`linalg::simd` — DESIGN.md §SIMD).
//!
//! The contracts, from strongest to weakest:
//! * **within one backend**: bit-identical across thread counts, and
//!   every `sum_sq`-vs-GEMM-diagonal / sparse-vs-dense cancellation is
//!   exact (diagonals exactly 1.0);
//! * **across backends** (forced scalar vs detected SIMD): agreement to
//!   ≤1e-5-grade tolerances only — FMA fuses multiply+add into one
//!   rounding, so scalar-vs-SIMD is a tolerance contract, not a bit
//!   contract;
//! * the `WU_SVM_FORCE_SCALAR` override pins the scalar flavor (the CI
//!   matrix runs this whole suite under both settings).

use wu_svm::data::sparse::CsrMatrix;
use wu_svm::linalg::gemm::{self, rbf_blocked_with, sum_sq};
use wu_svm::linalg::simd::{self, Backend};
use wu_svm::linalg::spmm;
use wu_svm::rng::Rng;

fn native() -> Backend {
    Backend::detect(false)
}

/// The two flavors every cross-backend test compares (identical on
/// scalar-only hosts, where the comparison degenerates harmlessly).
fn both() -> [Backend; 2] {
    [Backend::Scalar, native()]
}

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian_f32()).collect()
}

fn gemm_with(
    be: Backend,
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    gemm::gemm_nt_strided_with(be, threads, m, n, k, a, k, 1, b, k, 1, None, &mut c, n);
    c
}

#[test]
fn force_scalar_flag_always_wins() {
    assert_eq!(Backend::detect(true), Backend::Scalar);
}

#[test]
fn env_override_is_honored_by_active() {
    // the CI matrix runs this suite with WU_SVM_FORCE_SCALAR=0 and =1;
    // when the override is set, the process-wide backend must be scalar
    // (and without it, whatever detect() picked).
    let forced = std::env::var("WU_SVM_FORCE_SCALAR")
        .map(|v| simd::parse_force_scalar(&v))
        .unwrap_or(false);
    if forced {
        assert_eq!(simd::active(), Backend::Scalar);
    } else {
        assert_eq!(simd::active(), native());
    }
}

#[test]
fn scalar_vs_simd_gemm_agrees_to_tolerance() {
    let mut rng = Rng::new(900);
    for &(m, n, k) in &[(1usize, 1usize, 7usize), (31, 29, 23), (64, 40, 300), (130, 70, 257)] {
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, n * k);
        let want = gemm_with(Backend::Scalar, 4, m, n, k, &a, &b);
        let got = gemm_with(native(), 4, m, n, k, &a, &b);
        let tol = 1e-5 * (k as f32).sqrt().max(1.0);
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert!((w - g).abs() <= tol, "({m},{n},{k}) elem {i}: {w} vs {g}");
        }
    }
}

#[test]
fn scalar_vs_simd_rbf_block_agrees_to_tolerance() {
    let mut rng = Rng::new(901);
    let (t, b, d) = (33usize, 16usize, 257usize);
    let x = randvec(&mut rng, t * d);
    let xb = randvec(&mut rng, b * d);
    let mut want = vec![0.0f32; t * b];
    rbf_blocked_with(Backend::Scalar, 4, &x, t, &xb, b, d, 0.7, &mut want);
    let mut got = vec![0.0f32; t * b];
    rbf_blocked_with(native(), 4, &x, t, &xb, b, d, 0.7, &mut got);
    for (w, g) in want.iter().zip(&got) {
        // kernel values live in (0, 1]; exp contracts the GEMM error
        assert!((w - g).abs() <= 1e-5, "{w} vs {g}");
    }
}

#[test]
fn rbf_diagonal_is_exactly_one_per_backend() {
    let mut rng = Rng::new(902);
    for be in both() {
        for &(n, d) in &[(9usize, 64usize), (17, 300), (8, 700)] {
            let x = randvec(&mut rng, n * d);
            let mut k = vec![0.0f32; n * n];
            rbf_blocked_with(be, 3, &x, n, &x, n, d, 0.5, &mut k);
            for i in 0..n {
                assert_eq!(k[i * n + i], 1.0, "{} ({n},{d}) diag {i}", be.name());
            }
        }
    }
}

#[test]
fn sum_sq_matches_gemm_diagonal_bitwise_per_backend() {
    // the exact panel-order contract, including across KC slab
    // boundaries: a 1-row self-GEMM's single element is ‖x‖² in the
    // backend's own accumulation order
    let mut rng = Rng::new(903);
    for be in both() {
        for d in [3usize, 8, 255, 256, 257, 700] {
            let x = randvec(&mut rng, d);
            let c = gemm_with(be, 1, 1, 1, d, &x, &x);
            assert_eq!(
                c[0].to_bits(),
                be.sum_sq(&x).to_bits(),
                "{} d={d}",
                be.name()
            );
        }
    }
    // and the public sum_sq entry point is the active flavor
    let x = randvec(&mut rng, 300);
    assert_eq!(sum_sq(&x).to_bits(), simd::active().sum_sq(&x).to_bits());
}

#[test]
fn gemm_bit_identical_across_thread_counts_per_backend() {
    let mut rng = Rng::new(904);
    for be in both() {
        for &(m, n, k) in &[(257usize, 129usize, 300usize), (40, 40, 17)] {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, n * k);
            let base = gemm_with(be, 1, m, n, k, &a, &b);
            for threads in [2usize, 8] {
                let got = gemm_with(be, threads, m, n, k, &a, &b);
                for (w, g) in base.iter().zip(&got) {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "{} ({m},{n},{k}) threads={threads}",
                        be.name()
                    );
                }
            }
        }
    }
}

#[test]
fn spmm_bit_identical_across_thread_counts_per_backend() {
    let mut rng = Rng::new(905);
    let (t, b, d) = (300usize, 24usize, 520usize);
    let dense: Vec<f32> = (0..t * d)
        .map(|_| if rng.bernoulli(0.1) { rng.gaussian_f32() } else { 0.0 })
        .collect();
    let csr = CsrMatrix::from_dense(t, d, &dense);
    let bm = randvec(&mut rng, b * d);
    for be in both() {
        let mut base = vec![0.0f32; t * b];
        spmm::csr_gemm_nt_with(be, 1, &csr, 0, t, &bm, b, &mut base);
        for threads in [2usize, 8] {
            let mut got = vec![0.0f32; t * b];
            spmm::csr_gemm_nt_with(be, threads, &csr, 0, t, &bm, b, &mut got);
            for (w, g) in base.iter().zip(&got) {
                assert_eq!(w.to_bits(), g.to_bits(), "{} threads={threads}", be.name());
            }
        }
    }
}

#[test]
fn spmm_matches_dense_gemm_bitwise_per_backend() {
    // PR 5's sparse-equals-dense bit contract must survive FMA: stored
    // entries accumulate in the same per-element order, and skipped
    // zeros are identity adds under fma too
    let mut rng = Rng::new(906);
    for be in both() {
        for &(t, b, d) in &[(13usize, 7usize, 300usize), (40, 9, 257)] {
            let dense: Vec<f32> = (0..t * d)
                .map(|_| if rng.bernoulli(0.2) { rng.gaussian_f32() } else { 0.0 })
                .collect();
            let csr = CsrMatrix::from_dense(t, d, &dense);
            let bm = randvec(&mut rng, b * d);
            let mut sp = vec![0.0f32; t * b];
            spmm::csr_gemm_nt_with(be, 4, &csr, 0, t, &bm, b, &mut sp);
            let dn = gemm_with(be, 4, t, b, d, &dense, &bm);
            for (i, (s, w)) in sp.iter().zip(&dn).enumerate() {
                assert_eq!(s.to_bits(), w.to_bits(), "{} ({t},{b},{d}) elem {i}", be.name());
            }
        }
    }
}

#[test]
fn scalar_vs_simd_spmm_agrees_to_tolerance() {
    let mut rng = Rng::new(907);
    let (t, b, d) = (50usize, 8usize, 400usize);
    let dense: Vec<f32> = (0..t * d)
        .map(|_| if rng.bernoulli(0.15) { rng.gaussian_f32() } else { 0.0 })
        .collect();
    let csr = CsrMatrix::from_dense(t, d, &dense);
    let bm = randvec(&mut rng, b * d);
    let mut want = vec![0.0f32; t * b];
    spmm::csr_gemm_nt_with(Backend::Scalar, 2, &csr, 0, t, &bm, b, &mut want);
    let mut got = vec![0.0f32; t * b];
    spmm::csr_gemm_nt_with(native(), 2, &csr, 0, t, &bm, b, &mut got);
    let tol = 1e-5 * (d as f32).sqrt().max(1.0);
    for (w, g) in want.iter().zip(&got) {
        assert!((w - g).abs() <= tol, "{w} vs {g}");
    }
}

#[test]
fn csr_norms_follow_the_active_backend() {
    // CsrMatrix construction computes norms through the active flavor,
    // so row_dot_dense on the densified row reproduces them bitwise —
    // under whichever backend this process runs
    let mut rng = Rng::new(908);
    let d = 700usize;
    let dense: Vec<f32> = (0..4 * d)
        .map(|_| if rng.bernoulli(0.25) { rng.gaussian_f32() } else { 0.0 })
        .collect();
    let csr = CsrMatrix::from_dense(4, d, &dense);
    let mut buf = vec![0.0f32; d];
    for i in 0..4 {
        csr.densify_row_into(i, &mut buf);
        assert_eq!(csr.row_dot_dense(i, &buf).to_bits(), csr.sum_sq[i].to_bits(), "row {i}");
        assert_eq!(
            csr.sum_sq[i].to_bits(),
            simd::active().sum_sq(&dense[i * d..(i + 1) * d]).to_bits(),
            "row {i} vs dense sum_sq"
        );
    }
}
