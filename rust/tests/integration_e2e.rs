//! Integration tests: all layers composed, including the AOT-XLA path
//! when artifacts exist (CI note: run `make artifacts` first; the xla
//! cases skip gracefully without them).

use std::sync::Arc;

use wu_svm::coordinator::{self, run, EngineChoice, Solver, TrainJob};
use wu_svm::serve;
use wu_svm::data::{libsvm, paper};
use wu_svm::engine::Engine;
use wu_svm::metrics::error_rate;
use wu_svm::model::SvmModel;
use wu_svm::runtime::{default_artifacts_dir, XlaRuntime};

fn xla_runtime() -> Option<Arc<XlaRuntime>> {
    coordinator::shared_runtime().ok().or_else(|| {
        XlaRuntime::load(&default_artifacts_dir()).ok().map(Arc::new)
    })
}

#[test]
fn spsvm_beats_noise_floor_on_adult_analog() {
    let spec = paper::spec("adult").unwrap();
    let (tr, te) = spec.generate(0.04, 11);
    let r = wu_svm::solvers::spsvm::train(
        &tr,
        &wu_svm::solvers::spsvm::SpSvmParams {
            c: spec.c,
            gamma: spec.gamma,
            max_basis: 127,
            ..Default::default()
        },
        &Engine::cpu_par(4),
    )
    .unwrap();
    let err = error_rate(&r.model.decision_batch(&te, 4), &te.y);
    // better than predicting the majority class (pos_frac 0.25)
    assert!(err < 0.25, "test error {err}");
}

#[test]
fn solver_family_agrees_on_small_workload() {
    // All five solvers learn the same small problem to similar accuracy —
    // the paper's "remarkably consistent" accuracy observation.
    let spec = paper::spec("covertype").unwrap();
    let (tr, te) = spec.generate(0.004, 13);
    let kind = wu_svm::kernel::KernelKind::Rbf { gamma: spec.gamma };
    let engine = Engine::cpu_par(4);

    let smo = wu_svm::solvers::smo::train(
        &tr,
        kind,
        &wu_svm::solvers::smo::SmoParams { c: spec.c, ..Default::default() },
        &engine,
    )
    .unwrap();
    let wss = wu_svm::solvers::wss::train(
        &tr,
        kind,
        &wu_svm::solvers::wss::WssParams { c: spec.c, ..Default::default() },
        &engine,
    )
    .unwrap();
    let spsvm = wu_svm::solvers::spsvm::train(
        &tr,
        &wu_svm::solvers::spsvm::SpSvmParams {
            c: spec.c,
            gamma: spec.gamma,
            max_basis: 255,
            ..Default::default()
        },
        &engine,
    )
    .unwrap();
    let primal = wu_svm::solvers::primal::train(
        &tr,
        kind,
        &wu_svm::solvers::primal::PrimalParams { c: spec.c, ..Default::default() },
    )
    .unwrap();

    let e_smo = error_rate(&smo.model.decision_batch(&te, 4), &te.y);
    let e_wss = error_rate(&wss.model.decision_batch(&te, 4), &te.y);
    let e_sp = error_rate(&spsvm.model.decision_batch(&te, 4), &te.y);
    let e_pr = error_rate(&primal.model.decision_batch(&te, 4), &te.y);
    eprintln!("smo {e_smo:.3} wss {e_wss:.3} spsvm {e_sp:.3} primal {e_pr:.3}");
    assert!((e_smo - e_wss).abs() < 0.03, "smo {e_smo} vs wss {e_wss}");
    assert!((e_smo - e_pr).abs() < 0.05, "smo {e_smo} vs primal {e_pr}");
    assert!(e_sp < e_smo + 0.06, "spsvm {e_sp} vs smo {e_smo}");
}

#[test]
fn xla_and_cpu_spsvm_match_end_to_end() {
    let Some(rt) = xla_runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let spec = paper::spec("covertype").unwrap();
    let (tr, te) = spec.generate(0.01, 17);
    let p = wu_svm::solvers::spsvm::SpSvmParams {
        c: spec.c,
        gamma: spec.gamma,
        max_basis: 127,
        ..Default::default()
    };
    let cpu = wu_svm::solvers::spsvm::train(&tr, &p, &Engine::cpu_par(4)).unwrap();
    let xla = wu_svm::solvers::spsvm::train(&tr, &p, &Engine::xla(rt)).unwrap();
    let ec = error_rate(&cpu.model.decision_batch(&te, 4), &te.y);
    let ex = error_rate(&xla.model.decision_batch(&te, 4), &te.y);
    eprintln!("cpu {ec:.4} xla {ex:.4}");
    assert!((ec - ex).abs() < 0.03, "cpu {ec} vs xla {ex}");
}

#[test]
fn coordinator_runs_gpusvm_and_gtsvm_analogs() {
    if xla_runtime().is_none() {
        eprintln!("skipping: no artifacts");
        return;
    }
    for solver in [Solver::Smo, Solver::Wss] {
        let job = TrainJob {
            dataset: "adult".into(),
            scale: 0.008,
            solver,
            engine: EngineChoice::Xla,
            ..Default::default()
        };
        let rec = run(&job).unwrap();
        assert!(rec.test_metric < 0.45, "{solver:?}: {}", rec.test_metric);
    }
}

#[test]
fn model_round_trips_through_disk_and_libsvm_data() {
    let dir = std::env::temp_dir().join("wu_svm_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let spec = paper::spec("adult").unwrap();
    let (tr, te) = spec.generate(0.01, 19);

    // write/read the test set in libsvm format
    let data_path = dir.join("adult_test.libsvm");
    libsvm::write_file(&te, &data_path).unwrap();
    let te_back = libsvm::read_file(&data_path, te.d).unwrap();
    assert_eq!(te_back.n, te.n);

    // train, save, reload, compare predictions
    let r = wu_svm::solvers::spsvm::train(
        &tr,
        &wu_svm::solvers::spsvm::SpSvmParams {
            c: spec.c,
            gamma: spec.gamma,
            max_basis: 63,
            ..Default::default()
        },
        &Engine::cpu_par(4),
    )
    .unwrap();
    let model_path = dir.join("adult.model");
    r.model.save(&model_path).unwrap();
    let loaded = SvmModel::load(&model_path).unwrap();
    let a = r.model.decision_batch(&te_back, 2);
    let b = loaded.decision_batch(&te_back, 2);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4);
    }
    std::fs::remove_file(data_path).ok();
    std::fs::remove_file(model_path).ok();
}

#[test]
fn serving_a_trained_model_end_to_end() {
    let spec = paper::spec("adult").unwrap();
    let (tr, te) = spec.generate(0.01, 23);
    let r = wu_svm::solvers::spsvm::train(
        &tr,
        &wu_svm::solvers::spsvm::SpSvmParams {
            c: spec.c,
            gamma: spec.gamma,
            max_basis: 63,
            ..Default::default()
        },
        &Engine::cpu_par(4),
    )
    .unwrap();
    let expect: Vec<f32> = (0..50).map(|i| r.model.decision(te.row(i))).collect();
    let server =
        serve::Server::start(&r.model, Engine::cpu_par(2), serve::ServeConfig::default());
    let client = server.client();
    for i in 0..50 {
        let got = client.predict(te.row(i).to_vec()).unwrap().margin().unwrap();
        assert!((got - expect[i]).abs() < 1e-4, "row {i}: {got} vs {}", expect[i]);
    }
    let stats = server.stop();
    assert_eq!(stats.requests, 50);
    // an engine-error fallback would hide a real failure: happy path
    // must report zero
    assert_eq!(stats.fallbacks, 0);
    assert_eq!(stats.rejected, 0);
}

#[test]
fn mitfaces_analog_reports_auc_metric() {
    let job = TrainJob {
        dataset: "mitfaces".into(),
        scale: 0.004,
        solver: Solver::SpSvm,
        engine: EngineChoice::CpuPar(4),
        max_basis: 63,
        ..Default::default()
    };
    let rec = run(&job).unwrap();
    assert_eq!(rec.metric_name, "1-auc");
    // must beat random ranking (1-auc = 0.5) comfortably
    assert!(rec.test_metric < 0.35, "1-auc {}", rec.test_metric);
}

#[test]
fn mnist_analog_trains_ovo_pairs() {
    let job = TrainJob {
        dataset: "mnist8m".into(),
        scale: 0.004, // 240 rows, 45 tiny pairs
        solver: Solver::SpSvm,
        engine: EngineChoice::CpuPar(4),
        max_basis: 15,
        ..Default::default()
    };
    let rec = run(&job).unwrap();
    assert_eq!(rec.metric_name, "error");
    // 10 classes: random = 0.9; require real learning
    assert!(rec.test_metric < 0.6, "multiclass error {}", rec.test_metric);
    assert!(rec.notes.iter().any(|(k, v)| k == "pairs" && v == "45"));
}
