//! Property tests for the serving subsystem invariants (ISSUE 3):
//! every admitted request answered exactly once under random load for
//! k ∈ {1,2,4} batcher shards; overload rejects instead of hanging;
//! hot-swap mid-traffic never drops or mixes model versions; the
//! packed/precomputed-norms serve path matches `SvmModel::decision`
//! within 1e-5; and outputs are bit-identical across shard counts.

use std::sync::Arc;

use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::model::SvmModel;
use wu_svm::multiclass::OvoModel;
use wu_svm::rng::Rng;
use wu_svm::serve::{ModelRegistry, Server, ServeConfig, SubmitError};

fn rand_model(rng: &mut Rng, b: usize, d: usize, gamma: f32, bias: f32) -> SvmModel {
    SvmModel {
        kernel: KernelKind::Rbf { gamma },
        vectors: (0..b * d).map(|_| rng.uniform_f32()).collect(),
        d,
        coef: (0..b).map(|_| rng.gaussian_f32() * 0.5).collect(),
        bias,
        solver: "prop".into(),
    }
}

#[test]
fn prop_packed_serve_margins_match_decision_within_1e5() {
    let mut rng = Rng::new(41);
    for case in 0..3 {
        // models with duplicate rows and zero coefficients so compaction
        // is actually exercised
        let d = 3 + rng.below(8);
        let b = 5 + rng.below(40);
        let mut model = rand_model(&mut rng, b, d, 0.4 + rng.uniform_f32(), 0.1);
        if b >= 4 {
            let dup: Vec<f32> = model.vectors[..d].to_vec();
            model.vectors[2 * d..3 * d].copy_from_slice(&dup);
            model.coef[3] = 0.0;
        }
        for &shards in &[1usize, 2, 4] {
            let server = Server::start(
                &model,
                Engine::cpu_par(2),
                ServeConfig { shards, ..Default::default() },
            );
            assert!(server.registry().current().is_packed(), "case {case}");
            let client = server.client();
            for i in 0..40 {
                let f: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
                let got = client.predict(f.clone()).unwrap().margin().unwrap();
                let want = model.decision(&f);
                assert!(
                    (got - want).abs() < 1e-5,
                    "case {case} shards {shards} req {i}: {got} vs {want}"
                );
            }
            let stats = server.stop();
            assert_eq!(stats.requests, 40, "case {case} shards {shards}");
            assert_eq!(stats.fallbacks, 0, "case {case} shards {shards}");
        }
    }
}

#[test]
fn prop_outputs_bit_identical_across_shard_counts() {
    // the blocked GEMM gives every K row a fixed accumulation order
    // regardless of batch composition, so the same features must produce
    // bit-identical margins on 1 shard or 4, batch 1 or 256
    let mut rng = Rng::new(42);
    let model = rand_model(&mut rng, 33, 6, 0.8, -0.2);
    let queries: Vec<Vec<f32>> =
        (0..64).map(|_| (0..6).map(|_| rng.uniform_f32()).collect()).collect();
    let cases = [(1usize, 1usize), (1, 256), (4, 16), (4, 256)];
    let mut runs: Vec<Vec<u32>> = Vec::new();
    for &(shards, batch) in &cases {
        let server = Server::start(
            &model,
            Engine::cpu_par(2),
            ServeConfig { shards, batch, ..Default::default() },
        );
        let client = server.client();
        let pending: Vec<_> =
            queries.iter().map(|q| client.submit(q.clone()).unwrap()).collect();
        let bits: Vec<u32> = pending
            .iter()
            .map(|p| p.wait().unwrap().output.margin().unwrap().to_bits())
            .collect();
        server.stop();
        runs.push(bits);
    }
    for (i, bits) in runs.iter().enumerate().skip(1) {
        assert_eq!(
            &runs[0], bits,
            "case {:?} vs {:?}: margins not bit-identical",
            cases[0], cases[i]
        );
    }
}

#[test]
fn prop_ovo_served_through_shared_block_matches_batch_predict() {
    // three well-separated classes, pair models sharing support vectors
    // (bit-identical rows across pairs) so the union dedup matters
    let mut rng = Rng::new(43);
    let centers = [[0.0f32, 0.0], [1.0, 0.0], [0.0, 1.0]];
    let mk_pair = |a: usize, b: usize| -> SvmModel {
        // one SV at each class center: positive weight on a, negative on b
        let mut vectors = Vec::new();
        vectors.extend_from_slice(&centers[a]);
        vectors.extend_from_slice(&centers[b]);
        SvmModel {
            kernel: KernelKind::Rbf { gamma: 4.0 },
            vectors,
            d: 2,
            coef: vec![1.0, -1.0],
            bias: 0.0,
            solver: "prop".into(),
        }
    };
    let ovo = OvoModel {
        classes: 3,
        pairs: vec![(0, 1), (0, 2), (1, 2)],
        models: vec![mk_pair(0, 1), mk_pair(0, 2), mk_pair(1, 2)],
        train_secs: 0.0,
    };
    let compiled = ModelRegistry::new(&ovo).current();
    assert!(compiled.is_packed());
    assert_eq!(
        compiled.packed_vectors(),
        3,
        "6 raw SVs across pairs must dedup to the 3 shared centers"
    );
    for &shards in &[1usize, 2, 4] {
        let server = Server::start(
            &ovo,
            Engine::cpu_par(2),
            ServeConfig { shards, ..Default::default() },
        );
        let client = server.client();
        for _ in 0..60 {
            let c = rng.below(3);
            let f = vec![
                centers[c][0] + (rng.uniform_f32() - 0.5) * 0.2,
                centers[c][1] + (rng.uniform_f32() - 0.5) * 0.2,
            ];
            let out = client.predict(f.clone()).unwrap();
            let (want, _) = ovo.vote_one(&f);
            assert_eq!(out.class().unwrap(), want, "shards {shards} near class {c}");
            assert_eq!(want, c, "query near center {c} must classify as {c}");
        }
        let stats = server.stop();
        assert_eq!(stats.fallbacks, 0);
    }
}

#[test]
fn prop_overload_rejects_never_hangs() {
    let mut rng = Rng::new(44);
    let model = rand_model(&mut rng, 8, 4, 1.0, 0.0);
    // no workers: deterministic fill to cap, every submit returns promptly
    let cap = 1 + rng.below(32);
    let server = Server::start(
        &model,
        Engine::cpu_seq(),
        ServeConfig { shards: 0, queue_cap: cap, ..Default::default() },
    );
    let client = server.client();
    let mut admitted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..cap + 17 {
        match client.submit(vec![0.5; 4]) {
            Ok(p) => admitted.push(p),
            Err(e) => {
                assert_eq!(e, SubmitError::Overloaded);
                rejected += 1;
            }
        }
    }
    assert_eq!(admitted.len(), cap);
    assert_eq!(rejected, 17);
    let stats = server.stop();
    assert_eq!(stats.rejected, 17);
    assert_eq!(stats.requests, cap as u64, "admitted requests drain at stop");
    for p in &admitted {
        assert!(p.try_take().is_some() || p.wait().is_ok());
    }
}

#[test]
fn prop_hot_swap_mid_traffic_never_drops_or_mixes_versions() {
    let mut rng = Rng::new(45);
    let d = 5;
    let v1 = rand_model(&mut rng, 24, d, 0.7, 10.0); // bias +10: unmistakable
    let v2 = rand_model(&mut rng, 16, d, 0.7, -10.0); // bias -10
    let registry = Arc::new(ModelRegistry::new(&v1));
    let server = Server::with_registry(
        registry.clone(),
        Engine::cpu_par(2),
        ServeConfig { shards: 2, batch: 8, ..Default::default() },
    );
    let client = server.client();

    // background traffic across the swap
    let stop_flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let drivers: Vec<_> = (0..3u64)
        .map(|t| {
            let c = server.client();
            let m1 = v1.clone();
            let m2 = v2.clone();
            let flag = stop_flag.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let mut n = 0u64;
                while !flag.load(std::sync::atomic::Ordering::Relaxed) {
                    let f: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
                    let p = c.submit(f.clone()).expect("admitted");
                    let resp = p.wait().expect("never dropped");
                    // the response's claimed version must exactly explain
                    // its value — a mixed batch could satisfy neither
                    let want = match resp.version {
                        1 => m1.decision(&f),
                        2 => m2.decision(&f),
                        v => panic!("unknown version {v}"),
                    };
                    let got = resp.output.margin().unwrap();
                    assert!(
                        (got - want).abs() < 1e-4,
                        "driver {t}: v{} margin {got} vs {want}",
                        resp.version
                    );
                    n += 1;
                }
                n
            })
        })
        .collect();

    // let traffic build, then swap
    std::thread::sleep(std::time::Duration::from_millis(30));
    let v = registry.publish(&v2).unwrap();
    assert_eq!(v, 2);
    // requests submitted after publish() returns must be scored by v2:
    // the worker snapshots the registry after popping the batch
    for _ in 0..50 {
        let f: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
        let p = client.submit(f.clone()).unwrap();
        let resp = p.wait().unwrap();
        assert_eq!(resp.version, 2, "stale model after swap completed");
        assert!((resp.output.margin().unwrap() - v2.decision(&f)).abs() < 1e-4);
    }
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop_flag.store(true, std::sync::atomic::Ordering::Relaxed);
    let driven: u64 = drivers.into_iter().map(|h| h.join().unwrap()).sum();
    let stats = server.stop();
    assert_eq!(stats.requests, stats.submitted, "every admitted request answered");
    assert!(driven > 0);
    assert_eq!(stats.fallbacks, 0);
    assert_eq!(stats.model_version, 2);
}
