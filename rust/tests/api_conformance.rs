//! Conformance tests for the unified training API (`solvers::api`):
//!
//! * Per solver: the new `Trainer` path produces a **bit-identical**
//!   model (coefficients, vectors, bias, objective) and the same
//!   iteration count as the legacy free-function entry point it
//!   replaces. (The legacy functions are now shims over the same
//!   driver, so these tests guard the *wiring* — kernel/gamma
//!   pass-through, engine selection, private-vs-shared cache paths —
//!   not two independent algorithm copies. One deliberate behavior
//!   change rides on the shims: iteration caps moved from params to
//!   `Budget`, so direct `smo::train`/`wss::train` callers now get the
//!   coordinator's 50n/10n default caps instead of the old 2M/200k
//!   params defaults.)
//! * `Budget` property tests: iteration and wall-clock budgets always
//!   terminate, and a budget-terminated run is flagged `capped` in the
//!   result notes; target-objective budgets stop early.
//! * The observer stream is consistent with the reported result.
//! * mu/primal surface their cpu fallback as a note instead of silently
//!   ignoring an accelerator engine.

use std::sync::Arc;
use std::time::Duration;

use wu_svm::data::Dataset;
use wu_svm::engine::Engine;
use wu_svm::kernel::cache::SharedRowCache;
use wu_svm::kernel::KernelKind;
use wu_svm::pool;
use wu_svm::solvers::mu::{self, MuParams};
use wu_svm::solvers::primal::{self, PrimalParams};
use wu_svm::solvers::smo::{self, SmoParams};
use wu_svm::solvers::spsvm::{self, SpSvmParams};
use wu_svm::solvers::wss::{self, WssParams};
use wu_svm::solvers::{Budget, SolverSpec, TraceObserver, TrainResult, Trainer};

fn xor_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = wu_svm::rng::Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.uniform_f32();
        let b = rng.uniform_f32();
        x.push(a);
        x.push(b);
        y.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new_binary("xor", 2, x, y)
}

/// Bit-exact equality of everything a model is made of, plus the
/// iteration count and objective.
fn assert_bit_identical(old: &TrainResult, new: &TrainResult) {
    assert_eq!(old.iterations, new.iterations, "iteration counts differ");
    assert_eq!(old.objective.to_bits(), new.objective.to_bits(), "objectives differ");
    assert_eq!(old.model.bias.to_bits(), new.model.bias.to_bits(), "biases differ");
    assert_eq!(old.model.d, new.model.d);
    assert_eq!(old.model.coef.len(), new.model.coef.len(), "coef counts differ");
    for (i, (a, b)) in old.model.coef.iter().zip(&new.model.coef).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "coef[{i}] differs");
    }
    assert_eq!(old.model.vectors.len(), new.model.vectors.len());
    for (i, (a, b)) in old.model.vectors.iter().zip(&new.model.vectors).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "vectors[{i}] differs");
    }
}

fn capped_as(r: &TrainResult) -> Option<&str> {
    r.notes.iter().find(|(k, _)| k == "capped").map(|(_, v)| v.as_str())
}

#[test]
fn smo_trainer_matches_legacy_entry_point() {
    let ds = xor_dataset(300, 1);
    let kind = KernelKind::Rbf { gamma: 8.0 };
    let p = SmoParams { c: 10.0, ..Default::default() };
    let old = smo::train(&ds, kind, &p, &Engine::cpu_par(4)).unwrap();
    let new = Trainer::new(SolverSpec::Smo(p))
        .kernel(kind)
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    assert!(new.iterations > 10, "degenerate run");
    assert_bit_identical(&old, &new);
}

#[test]
fn wss_trainer_matches_legacy_entry_point() {
    let ds = xor_dataset(250, 3);
    let kind = KernelKind::Rbf { gamma: 6.0 };
    let p = WssParams { c: 5.0, ..Default::default() };
    let old = wss::train(&ds, kind, &p, &Engine::cpu_seq()).unwrap();
    let new = Trainer::new(SolverSpec::Wss(p))
        .kernel(kind)
        .engine(Engine::cpu_seq())
        .train(&ds)
        .unwrap();
    assert_bit_identical(&old, &new);
}

#[test]
fn mu_trainer_matches_legacy_entry_point() {
    let ds = xor_dataset(150, 5);
    let kind = KernelKind::Rbf { gamma: 4.0 };
    let p = MuParams { c: 1.0, max_iters: 300, ..Default::default() };
    // the legacy shim runs on the default-threads cpu engine
    let engine = Engine::cpu_par(pool::default_threads());
    let old = mu::train(&ds, kind, &p).unwrap();
    let new = Trainer::new(SolverSpec::Mu(p)).kernel(kind).engine(engine).train(&ds).unwrap();
    assert_bit_identical(&old, &new);
}

#[test]
fn primal_trainer_matches_legacy_entry_point() {
    let ds = xor_dataset(180, 7);
    let kind = KernelKind::Rbf { gamma: 6.0 };
    let p = PrimalParams { c: 5.0, ..Default::default() };
    let engine = Engine::cpu_par(pool::default_threads());
    let old = primal::train(&ds, kind, &p).unwrap();
    let new = Trainer::new(SolverSpec::Primal(p)).kernel(kind).engine(engine).train(&ds).unwrap();
    assert_bit_identical(&old, &new);
}

#[test]
fn spsvm_trainer_matches_legacy_entry_point() {
    let ds = xor_dataset(600, 9);
    let p = SpSvmParams { c: 10.0, gamma: 8.0, max_basis: 31, ..Default::default() };
    let old = spsvm::train(&ds, &p, &Engine::cpu_par(4)).unwrap();
    // the driver path takes gamma from the ctx kernel, not the params
    let new = Trainer::new(SolverSpec::SpSvm(p))
        .kernel(KernelKind::Rbf { gamma: 8.0 })
        .engine(Engine::cpu_par(4))
        .train(&ds)
        .unwrap();
    assert_bit_identical(&old, &new);
}

#[test]
fn trainer_shared_cache_matches_private_cache() {
    // ctx-supplied cache plumbing: same bits as a private cache, and the
    // cache actually serves hits across two trainers sharing it
    let ds = xor_dataset(200, 11);
    let kind = KernelKind::Rbf { gamma: 6.0 };
    let p = SmoParams { c: 5.0, ..Default::default() };
    let private = Trainer::new(SolverSpec::Smo(p.clone())).kernel(kind).train(&ds).unwrap();
    let cache = Arc::new(SharedRowCache::new(8 * 1024 * 1024, 4));
    let a = Trainer::new(SolverSpec::Smo(p.clone()))
        .kernel(kind)
        .shared_cache(cache.clone(), 1)
        .train(&ds)
        .unwrap();
    let b = Trainer::new(SolverSpec::Smo(p))
        .kernel(kind)
        .shared_cache(cache.clone(), 2)
        .train(&ds)
        .unwrap();
    assert_bit_identical(&private, &a);
    assert_bit_identical(&private, &b);
    assert!(cache.hits() > 0, "shared cache never hit");
}

#[test]
fn iteration_budget_always_terminates_and_flags_capped() {
    // property: across solvers and seeds, a small iteration budget stops
    // the run at exactly the cap and says so in the notes
    for seed in [21u64, 22, 23] {
        let ds = xor_dataset(150 + 30 * (seed as usize - 20), seed);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let cases: Vec<(SolverSpec, usize)> = vec![
            (SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() }), 4),
            (SolverSpec::Wss(WssParams { c: 10.0, ..Default::default() }), 3),
            (SolverSpec::Mu(MuParams { c: 10.0, tol: 0.0, ..Default::default() }), 5),
            (SolverSpec::SpSvm(SpSvmParams { c: 10.0, max_basis: 63, ..Default::default() }), 2),
        ];
        for (spec, cap) in cases {
            let name = spec.name().to_string();
            let r = Trainer::new(spec)
                .kernel(kind)
                .budget(Budget::iters(cap))
                .train(&ds)
                .unwrap();
            assert_eq!(
                capped_as(&r),
                Some("iters"),
                "{name} seed {seed}: notes {:?}",
                r.notes
            );
        }
    }
}

#[test]
fn wall_budget_always_terminates_and_flags_capped() {
    // a zero wall budget stops every solver after its first iteration
    let ds = xor_dataset(300, 31);
    let kind = KernelKind::Rbf { gamma: 8.0 };
    let specs = vec![
        SolverSpec::Smo(SmoParams { c: 10.0, eps: 1e-9, ..Default::default() }),
        SolverSpec::Wss(WssParams { c: 10.0, eps: 1e-9, ..Default::default() }),
        SolverSpec::Mu(MuParams { c: 10.0, tol: 0.0, ..Default::default() }),
        SolverSpec::Primal(PrimalParams { c: 10.0, ..Default::default() }),
        SolverSpec::SpSvm(SpSvmParams { c: 10.0, max_basis: 63, ..Default::default() }),
    ];
    for spec in specs {
        let name = spec.name().to_string();
        let r = Trainer::new(spec)
            .kernel(kind)
            .budget(Budget::wall(Duration::ZERO))
            .train(&ds)
            .unwrap();
        assert_eq!(capped_as(&r), Some("wall"), "{name}: notes {:?}", r.notes);
    }
}

#[test]
fn target_objective_budget_stops_early() {
    let ds = xor_dataset(300, 41);
    let kind = KernelKind::Rbf { gamma: 8.0 };
    let p = SmoParams { c: 10.0, ..Default::default() };
    let full = Trainer::new(SolverSpec::Smo(p.clone())).kernel(kind).train(&ds).unwrap();
    assert!(full.objective < 0.0);
    // stop halfway down the (negative, decreasing) dual objective
    let target = full.objective * 0.5;
    let early = Trainer::new(SolverSpec::Smo(p))
        .kernel(kind)
        .budget(Budget::none().target_objective(target))
        .train(&ds)
        .unwrap();
    assert_eq!(capped_as(&early), Some("target"), "notes {:?}", early.notes);
    assert!(early.iterations < full.iterations);
    // stopped midway: past the target (within the shrinking
    // approximation's small drift), but well short of full convergence
    assert!(early.objective <= target + 0.02 * full.objective.abs());
    assert!(early.objective > full.objective);
}

#[test]
fn observer_trace_is_consistent_with_result() {
    let ds = xor_dataset(300, 51);
    let kind = KernelKind::Rbf { gamma: 8.0 };
    let obs = Arc::new(TraceObserver::new());
    let r = Trainer::new(SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() }))
        .kernel(kind)
        .observer(obs.clone())
        .train(&ds)
        .unwrap();
    let pts = obs.take();
    assert_eq!(pts.len(), r.iterations, "one event per iteration");
    let last = pts.last().unwrap();
    assert_eq!(last.iter, r.iterations);
    assert!(pts.iter().all(|p| p.objective.is_finite() && p.solver == "smo"));
    // iteration numbers strictly increase, elapsed never goes backwards
    for w in pts.windows(2) {
        assert!(w[1].iter == w[0].iter + 1);
        assert!(w[1].elapsed >= w[0].elapsed);
    }
    // the SMO dual objective decreases monotonically step to step
    assert!(last.objective <= pts[0].objective);
    // observing must not change the trajectory
    let unobserved = Trainer::new(SolverSpec::Smo(SmoParams { c: 10.0, ..Default::default() }))
        .kernel(kind)
        .train(&ds)
        .unwrap();
    assert_bit_identical(&unobserved, &r);
}

#[test]
fn spsvm_observer_reports_basis_growth() {
    let ds = xor_dataset(800, 61);
    let obs = Arc::new(TraceObserver::new());
    let r = Trainer::new(SolverSpec::SpSvm(SpSvmParams {
            c: 10.0,
            max_basis: 31,
            ..Default::default()
        }))
        .kernel(KernelKind::Rbf { gamma: 8.0 })
        .observer(obs.clone())
        .train(&ds)
        .unwrap();
    let pts = obs.take();
    assert!(!pts.is_empty());
    // active = basis size: non-decreasing, capped by max_basis
    for w in pts.windows(2) {
        assert!(w[1].active >= w[0].active);
    }
    assert!(pts.last().unwrap().active <= 31);
    assert!(r.model.num_vectors() <= 31);
}

#[test]
fn mu_and_primal_surface_engine_fallback_note() {
    // mu/primal have no accelerator path; with an xla engine they must
    // say they fell back to cpu instead of silently running there.
    let Ok(rt) = wu_svm::runtime::XlaRuntime::load(&wu_svm::runtime::default_artifacts_dir())
    else {
        eprintln!("skipping: no artifacts (offline build has an xla API stub)");
        return;
    };
    let engine = Engine::xla(Arc::new(rt));
    let ds = xor_dataset(120, 71);
    let kind = KernelKind::Rbf { gamma: 4.0 };
    for spec in [
        SolverSpec::Mu(MuParams { c: 1.0, ..Default::default() }),
        SolverSpec::Primal(PrimalParams { c: 1.0, ..Default::default() }),
    ] {
        let r = Trainer::new(spec)
            .kernel(kind)
            .engine(engine.clone())
            .train(&ds)
            .unwrap();
        let note = r.notes.iter().find(|(k, _)| k == "engine_fallback");
        assert!(
            note.is_some_and(|(_, v)| v.starts_with("cpu")),
            "missing engine_fallback note: {:?}",
            r.notes
        );
    }
}

#[test]
fn family_note_records_the_papers_axis() {
    let ds = xor_dataset(150, 81);
    let kind = KernelKind::Rbf { gamma: 6.0 };
    let cases = vec![
        (SolverSpec::Smo(SmoParams { c: 1.0, ..Default::default() }), "explicit"),
        (SolverSpec::Mu(MuParams { c: 1.0, ..Default::default() }), "implicit"),
    ];
    for (spec, family) in cases {
        let r = Trainer::new(spec).kernel(kind).train(&ds).unwrap();
        assert!(
            r.notes.iter().any(|(k, v)| k == "family" && v == family),
            "notes {:?}",
            r.notes
        );
    }
}
