#!/bin/sh
# CI entry point. Usage: ./ci.sh [tier1|benchcheck|benchsmoke|benchmeasure|tracesmoke|cascadesmoke|docs|lint|all]
# tier1 is the repository's canonical verification (see ROADMAP.md).
# benchcheck compiles the bench targets without running them.
# benchsmoke validates the checked-in BENCH_*.json records against their
# embedded schemas and ratio floors, then *runs* every bench target with
# BENCH_SMOKE=1 (seconds-sized workloads, no json overwrite) so bench
# code paths execute in CI instead of only compiling.
# benchmeasure runs the full bench workloads (minutes, release-built),
# which overwrite BENCH_*.json with measured records, then holds those
# records to the ratio floors in ci/check_bench_json.py — the measured
# regression gate (rust/EXPERIMENTS.md §SIMD).
# tracesmoke runs a seconds-sized traced training (--profile
# --trace-json) and validates the emitted Chrome trace with
# ci/check_trace_json.py, so the observability exporters stay honest.
# cascadesmoke runs a seconds-sized 2-shard cascade training through the
# CLI and checks the report carries the cascade notes (shard count and a
# global-KKT verdict), so the sharded path executes end to end in CI.
# docs builds the public API docs with warnings denied, so the rustdoc
# surface (intra-doc links, examples) can't rot either.
# lint (rustfmt + clippy -D warnings) is part of the blocking gate.
set -eu

mode="${1:-all}"
# usage string kept in sync with the case arms below
usage="usage: ./ci.sh [tier1|benchcheck|benchsmoke|benchmeasure|tracesmoke|cascadesmoke|docs|lint|all]"

tier1() {
    cargo build --release
    cargo test -q
}

benchcheck() {
    cargo bench --no-run
}

benchsmoke() {
    python3 ci/check_bench_json.py BENCH_*.json
    BENCH_SMOKE=1 cargo bench
}

benchmeasure() {
    cargo bench
    python3 ci/check_bench_json.py BENCH_*.json
}

tracesmoke() {
    cargo build --release
    trace_out="$(mktemp -t wu_svm_trace.XXXXXX)"
    ./target/release/wu-svm train --dataset adult --scale 0.01 --solver smo \
        --max-iters 500 --profile --trace-json "$trace_out"
    python3 ci/check_trace_json.py "$trace_out"
    rm -f "$trace_out"
}

cascadesmoke() {
    cargo build --release
    out="$(BENCH_SMOKE=1 ./target/release/wu-svm train --dataset adult --scale 0.01 \
        --solver smo --cascade-shards 2 --cascade-kkt-tol 0.01)"
    echo "$out"
    echo "$out" | grep -q "cascade_shards = 2" || {
        echo "cascadesmoke: report is missing 'cascade_shards = 2'" >&2
        exit 1
    }
    echo "$out" | grep -q "cascade_kkt = " || {
        echo "cascadesmoke: report carries no global-KKT verdict" >&2
        exit 1
    }
}

docs() {
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
}

lint() {
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
}

case "$mode" in
    tier1) tier1 ;;
    benchcheck) benchcheck ;;
    benchsmoke) benchsmoke ;;
    benchmeasure) benchmeasure ;;
    tracesmoke) tracesmoke ;;
    cascadesmoke) cascadesmoke ;;
    docs) docs ;;
    lint) lint ;;
    all)
        # benchsmoke builds *and runs* every bench target, subsuming
        # benchcheck (kept as a standalone fast mode); benchmeasure is
        # the separate full-workload gate — minutes, not part of `all`
        tier1
        benchsmoke
        tracesmoke
        cascadesmoke
        docs
        lint
        ;;
    *)
        echo "$usage" >&2
        exit 2
        ;;
esac
