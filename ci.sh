#!/bin/sh
# CI entry point. Usage: ./ci.sh [tier1|lint|all]
# tier1 is the repository's canonical verification (see ROADMAP.md).
set -eu

mode="${1:-all}"

tier1() {
    cargo build --release
    cargo test -q
}

lint() {
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
}

case "$mode" in
    tier1) tier1 ;;
    lint) lint ;;
    all)
        tier1
        lint
        ;;
    *)
        echo "usage: ./ci.sh [tier1|lint|all]" >&2
        exit 2
        ;;
esac
