#!/bin/sh
# CI entry point. Usage: ./ci.sh [tier1|benchcheck|benchsmoke|benchmeasure|tracesmoke|cascadesmoke|oocsmoke|docs|lint|all]
# tier1 is the repository's canonical verification (see ROADMAP.md).
# benchcheck compiles the bench targets without running them.
# benchsmoke validates the checked-in BENCH_*.json records against their
# embedded schemas and ratio floors, then *runs* every bench target with
# BENCH_SMOKE=1 (seconds-sized workloads, no json overwrite) so bench
# code paths execute in CI instead of only compiling.
# benchmeasure runs the full bench workloads (minutes, release-built),
# which overwrite BENCH_*.json with measured records, then holds those
# records to the ratio floors in ci/check_bench_json.py — the measured
# regression gate (rust/EXPERIMENTS.md §SIMD).
# tracesmoke runs a seconds-sized traced training (--profile
# --trace-json) and validates the emitted Chrome trace with
# ci/check_trace_json.py, so the observability exporters stay honest.
# cascadesmoke runs a seconds-sized 2-shard cascade training through the
# CLI and checks the report carries the cascade notes (shard count and a
# global-KKT verdict), so the sharded path executes end to end in CI.
# oocsmoke packs a small libsvm file with `wu-svm pack`, trains from the
# mmap-backed file with a deliberately starved cache (--cache-mb 1) and
# --polish, and checks the report says storage = mmap, carries a
# cache_hit_rate note, and a polish verdict — the out-of-core path end
# to end through the CLI.
# docs builds the public API docs with warnings denied, so the rustdoc
# surface (intra-doc links, examples) can't rot either.
# lint (rustfmt + clippy -D warnings) is part of the blocking gate.
set -eu

mode="${1:-all}"
# usage string kept in sync with the case arms below
usage="usage: ./ci.sh [tier1|benchcheck|benchsmoke|benchmeasure|tracesmoke|cascadesmoke|oocsmoke|docs|lint|all]"

tier1() {
    cargo build --release
    cargo test -q
}

benchcheck() {
    cargo bench --no-run
}

benchsmoke() {
    python3 ci/check_bench_json.py BENCH_*.json
    BENCH_SMOKE=1 cargo bench
}

benchmeasure() {
    cargo bench
    # after a full measurement run, a surviving not-run placeholder or a
    # counters-free record means a bench target silently failed to write
    python3 ci/check_bench_json.py --require-measured BENCH_*.json
}

tracesmoke() {
    cargo build --release
    trace_out="$(mktemp -t wu_svm_trace.XXXXXX)"
    if [ "${WU_SVM_TRACE:-1}" = "0" ]; then
        # kill-switch cell (the CI matrix pins WU_SVM_TRACE=0): the
        # traced invocation must still train fine, but the session is
        # inert — assert it says so instead of validating an empty trace
        out="$(./target/release/wu-svm train --dataset adult --scale 0.01 --solver smo \
            --max-iters 500 --profile --trace-json "$trace_out")"
        echo "$out"
        echo "$out" | grep -q "tracing disabled" || {
            echo "tracesmoke: WU_SVM_TRACE=0 run did not report the kill switch" >&2
            exit 1
        }
    else
        ./target/release/wu-svm train --dataset adult --scale 0.01 --solver smo \
            --max-iters 500 --profile --trace-json "$trace_out"
        python3 ci/check_trace_json.py "$trace_out"
    fi
    rm -f "$trace_out"
}

cascadesmoke() {
    cargo build --release
    out="$(BENCH_SMOKE=1 ./target/release/wu-svm train --dataset adult --scale 0.01 \
        --solver smo --cascade-shards 2 --cascade-kkt-tol 0.01)"
    echo "$out"
    echo "$out" | grep -q "cascade_shards = 2" || {
        echo "cascadesmoke: report is missing 'cascade_shards = 2'" >&2
        exit 1
    }
    echo "$out" | grep -q "cascade_kkt = " || {
        echo "cascadesmoke: report carries no global-KKT verdict" >&2
        exit 1
    }
}

oocsmoke() {
    cargo build --release
    dir="$(mktemp -d -t wu_svm_ooc.XXXXXX)"
    ./target/release/wu-svm datagen --dataset adult --scale 0.01 \
        --out "$dir/train.libsvm" --test-out "$dir/test.libsvm"
    ./target/release/wu-svm pack --input "$dir/train.libsvm" --out "$dir/train.wusvm"
    # --test-input keeps the training design on disk: a --input-only run
    # would split 80/20, and the row subset materializes in memory
    out="$(./target/release/wu-svm train --input "$dir/train.wusvm" \
        --test-input "$dir/test.libsvm" --solver smo \
        --cache-mb 1 --cache-slack 0.25 --polish)"
    echo "$out"
    rm -rf "$dir"
    echo "$out" | grep -q "storage = mmap" || {
        echo "oocsmoke: report is missing 'storage = mmap' (design was materialized?)" >&2
        exit 1
    }
    echo "$out" | grep -q "cache_hit_rate" || {
        echo "oocsmoke: report carries no cache_hit_rate note" >&2
        exit 1
    }
    echo "$out" | grep -q "polish = " || {
        echo "oocsmoke: report carries no polish verdict" >&2
        exit 1
    }
}

docs() {
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
}

lint() {
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
}

case "$mode" in
    tier1) tier1 ;;
    benchcheck) benchcheck ;;
    benchsmoke) benchsmoke ;;
    benchmeasure) benchmeasure ;;
    tracesmoke) tracesmoke ;;
    cascadesmoke) cascadesmoke ;;
    oocsmoke) oocsmoke ;;
    docs) docs ;;
    lint) lint ;;
    all)
        # benchsmoke builds *and runs* every bench target, subsuming
        # benchcheck (kept as a standalone fast mode); benchmeasure is
        # the separate full-workload gate — minutes, not part of `all`
        tier1
        benchsmoke
        tracesmoke
        cascadesmoke
        oocsmoke
        docs
        lint
        ;;
    *)
        echo "$usage" >&2
        exit 2
        ;;
esac
