#!/bin/sh
# CI entry point. Usage: ./ci.sh [tier1|benchcheck|docs|lint|all]
# tier1 is the repository's canonical verification (see ROADMAP.md).
# benchcheck compiles the bench targets without running them, so the
# harness=false benchmarks (which `cargo test` never builds) can't rot.
# docs builds the public API docs with warnings denied, so the rustdoc
# surface (intra-doc links, examples) can't rot either.
set -eu

mode="${1:-all}"

tier1() {
    cargo build --release
    cargo test -q
}

benchcheck() {
    cargo bench --no-run
}

docs() {
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
}

lint() {
    cargo fmt --check
    cargo clippy --all-targets -- -D warnings
}

case "$mode" in
    tier1) tier1 ;;
    benchcheck) benchcheck ;;
    docs) docs ;;
    lint) lint ;;
    all)
        tier1
        benchcheck
        docs
        lint
        ;;
    *)
        echo "usage: ./ci.sh [tier1|benchcheck|docs|lint|all]" >&2
        exit 2
        ;;
esac
