//! End-to-end driver (the repository's E2E validation): regenerate a
//! Table-1 row on a real small workload, exercising every layer —
//! synthetic dataset substrate -> solvers (explicit SMO family + implicit
//! SP-SVM) -> ComputeEngines (cpu-seq / cpu-par / AOT-XLA artifacts) ->
//! metrics -> paper-style report — then serve the trained model through
//! the serving subsystem (versioned registry, sharded batchers over a
//! bounded queue) and report the serve metrics, including a mid-traffic
//! hot swap.
//!
//! Run: `cargo run --release --example end_to_end_table1 -- [dataset] [scale]`
//! The recorded run lives in EXPERIMENTS.md.

use wu_svm::coordinator::{self, EngineChoice, Solver, TrainJob};
use wu_svm::data::paper;
use wu_svm::experiments;
use wu_svm::metrics::fmt_duration;
use wu_svm::pool;
use wu_svm::report;
use wu_svm::serve;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().cloned().unwrap_or_else(|| "adult".into());
    let scale: f64 = args
        .get(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| experiments::default_scale(&dataset));

    println!("=== end-to-end Table-1 row: {dataset} (scale {scale}) ===\n");

    // Phase 1: the full six-method Table-1 row.
    let rows = experiments::run_table1_dataset(&dataset, scale, 255, &[])?;
    println!("{}", report::render_table(&rows));
    let spec = paper::spec(&dataset).unwrap();
    println!(
        "paper reference: LibSVM err {:.1}%, C = {}, gamma = {} (paper n = {})\n",
        spec.paper_error * 100.0,
        spec.c,
        spec.gamma,
        spec.paper_n
    );

    // Phase 2: serve the winning model (SP-SVM) as a prediction service.
    println!("--- serving phase ---");
    let job = TrainJob {
        dataset: dataset.clone(),
        scale,
        solver: Solver::SpSvm,
        engine: EngineChoice::CpuPar(pool::default_threads()),
        max_basis: 255,
        ..Default::default()
    };
    let (train, test, spec) = coordinator::load_data(&job)?;
    if train.is_multiclass() {
        println!("(multiclass dataset: serving phase covered by binary rows)");
        return Ok(());
    }
    let engine = coordinator::build_engine(job.engine)?;
    let trainer = job.trainer(&spec, &engine);
    let model = trainer.train(&train)?.model;
    let server = serve::Server::start(
        &model,
        engine,
        serve::ServeConfig { shards: 2, ..Default::default() },
    );
    println!("registered: {}", server.registry().current().describe());
    let client = server.client();
    let n_req = 2000.min(test.n * 4);
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        client.predict(test.row(i % test.n).to_vec())?;
    }
    let total = t0.elapsed();
    println!(
        "served {n_req} requests in {} — {:.0} req/s",
        fmt_duration(total),
        n_req as f64 / total.as_secs_f64(),
    );
    // hot-swap a retrained (smaller) version mid-service, then keep serving
    let job2 = TrainJob { max_basis: 63, ..job.clone() };
    let engine2 = coordinator::build_engine(job2.engine)?;
    let model2 = job2.trainer(&spec, &engine2).train(&train)?.model;
    let v = server.publish(&model2)?;
    println!("hot-swapped to {} (version {v})", server.registry().current().describe());
    for i in 0..n_req.min(500) {
        client.predict(test.row(i % test.n).to_vec())?;
    }
    let stats = server.stop();
    println!("{stats}");
    assert_eq!(stats.fallbacks, 0, "engine fallbacks must be zero on a healthy run");
    println!("\nE2E OK: all layers composed (data -> solvers -> engines -> report -> serving)");
    Ok(())
}
