//! Quickstart: train SP-SVM (the paper's headline method) on the
//! adult-like workload through the unified `Trainer` API and evaluate it.
//!
//! Run: `cargo run --release --example quickstart`
//! (use `make artifacts` first to enable the xla engine; this example
//! falls back to the hand-threaded cpu engine when artifacts are absent.)

use std::time::Duration;

use wu_svm::coordinator;
use wu_svm::data::paper;
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::metrics::{error_rate, fmt_duration};
use wu_svm::pool;
use wu_svm::solvers::spsvm::SpSvmParams;
use wu_svm::solvers::{Budget, SolverSpec, Trainer};

fn main() -> anyhow::Result<()> {
    // 1. workload: the Table-1 adult analog at a laptop-friendly scale
    let spec = paper::spec("adult").expect("known dataset");
    let (train, test) = spec.generate(0.2, 42);
    println!(
        "adult-like: {} train / {} test rows, d = {} (paper: n = {})",
        train.n, test.n, train.d, spec.paper_n
    );

    // 2. engine: implicit (XLA artifacts) if built, explicit threads if not
    let engine = match coordinator::shared_runtime() {
        Ok(rt) => {
            println!("engine: xla ({} ops AOT-compiled)", rt.manifest().by_op.len());
            Engine::xla(rt)
        }
        Err(_) => {
            let t = pool::default_threads();
            println!("engine: cpu-par({t}) — run `make artifacts` for the xla engine");
            Engine::cpu_par(t)
        }
    };

    // 3. train with the paper's published hyperparameters through the
    //    one API every solver shares: pick a solver spec, an engine, a
    //    kernel, a budget — then train. The wall-clock budget keeps the
    //    run bounded on any machine (a capped run says so in the notes).
    let t0 = std::time::Instant::now();
    let result = Trainer::new(SolverSpec::SpSvm(SpSvmParams {
            c: spec.c,
            max_basis: 255,
            ..Default::default()
        }))
        .kernel(KernelKind::Rbf { gamma: spec.gamma })
        .engine(engine)
        .budget(Budget::wall(Duration::from_secs(120)))
        .train(&train)?;
    let train_time = t0.elapsed();

    // 4. evaluate
    let margins = result.model.decision_batch(&test, pool::default_threads());
    let err = error_rate(&margins, &test.y);
    println!(
        "trained in {} — {} basis vectors, test error {:.2}% (paper LibSVM: {:.1}%)",
        fmt_duration(train_time),
        result.model.num_vectors(),
        err * 100.0,
        spec.paper_error * 100.0
    );
    for (k, v) in &result.notes {
        println!("  {k} = {v}");
    }
    Ok(())
}
