//! One-vs-one multiclass on the MNIST8M-like workload (paper Table 1,
//! last row): 10 classes, 45 pairwise SP-SVM models, voting prediction,
//! accumulated per-pair training time.
//!
//! Run: `cargo run --release --example multiclass_ovo -- [scale]`

use wu_svm::coordinator;
use wu_svm::data::paper;
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::metrics::{fmt_duration, multiclass_error};
use wu_svm::multiclass::OvoModel;
use wu_svm::pool;
use wu_svm::solvers::spsvm::SpSvmParams;
use wu_svm::solvers::{SolverSpec, Trainer};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.02);
    let spec = paper::spec("mnist8m").expect("known dataset");
    let (train, test) = spec.generate(scale, 7);
    println!(
        "mnist8m-like: {} train / {} test rows, d = {}, {} classes",
        train.n,
        test.n,
        train.d,
        train.num_classes()
    );

    let engine = match coordinator::shared_runtime() {
        Ok(rt) => Engine::xla(rt),
        Err(_) => Engine::cpu_par(pool::default_threads()),
    };
    println!("engine: {}", engine.name());

    // one configured Trainer fans out over all 45 pair subproblems,
    // sharing a single kernel-row cache budget
    let trainer = Trainer::new(SolverSpec::SpSvm(SpSvmParams {
            c: spec.c,
            max_basis: 127,
            ..Default::default()
        }))
        .kernel(KernelKind::Rbf { gamma: spec.gamma })
        .engine(engine);
    let t0 = std::time::Instant::now();
    let ovo = OvoModel::train_with(&train, &trainer, 512)?;
    let train_time = t0.elapsed();

    let pred = ovo.predict(&test, pool::default_threads());
    let err = multiclass_error(&pred, &test.class_ids);
    println!(
        "{} pair models ({} total vectors) in {} — test error {:.2}% (paper SP-SVM: 1.4%)",
        ovo.models.len(),
        ovo.total_vectors(),
        fmt_duration(train_time),
        err * 100.0
    );
    Ok(())
}
