//! Explicit vs implicit, head to head on one workload — the paper's §5
//! comparison in miniature, driven entirely through the unified
//! `Trainer` API: LibSVM (SMO, single core), LibSVM+OpenMP (SMO,
//! hand-threaded), GTSVM (WSS-16), the exact implicit baselines (MU,
//! primal Newton) that hit the memory/convergence wall, SP-SVM on
//! both the cpu and (when artifacts exist) the AOT-XLA engine, and
//! LS-SVM on a rank-256 ICF operator (the approximate-implicit row).
//! Every solver runs under the *same* wall-clock budget — the
//! controlled-comparison discipline the API encodes — and the run ends
//! with an observer-driven convergence trace (iter, objective, elapsed),
//! the time-vs-accuracy curve Table-1 end-state numbers can't show.
//!
//! Run: `cargo run --release --example compare_solvers -- [dataset] [scale]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use wu_svm::coordinator;
use wu_svm::data::paper;
use wu_svm::engine::Engine;
use wu_svm::kernel::KernelKind;
use wu_svm::metrics::{auc, error_rate};
use wu_svm::pool;
use wu_svm::report::{fill_speedups, render_table, Row};
use wu_svm::solvers::lssvm::LsSvmParams;
use wu_svm::solvers::mu::MuParams;
use wu_svm::solvers::primal::PrimalParams;
use wu_svm::solvers::smo::SmoParams;
use wu_svm::solvers::spsvm::SpSvmParams;
use wu_svm::solvers::wss::WssParams;
use wu_svm::solvers::{Budget, SolverSpec, TraceObserver, Trainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().cloned().unwrap_or_else(|| "covertype".into());
    let scale: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.02);
    let threads = pool::default_threads();

    let spec = paper::spec(&dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let (train, test) = spec.generate(scale, 1);
    anyhow::ensure!(!train.is_multiclass(), "pick a binary dataset for this example");
    println!(
        "{dataset}: {} train / {} test rows, d = {} (C = {}, gamma = {})",
        train.n, test.n, train.d, spec.c, spec.gamma
    );

    let c = spec.c;
    let kind = KernelKind::Rbf { gamma: spec.gamma };
    // One shared budget for every contender: comparisons are only
    // meaningful when all solvers answer "how far did you get in the
    // same time?" (budget-capped runs carry a `capped` note).
    let budget = Budget::wall(Duration::from_secs(120));

    let cases: Vec<(&str, &str, SolverSpec, Engine)> = vec![
        (
            "SC",
            "LibSVM",
            SolverSpec::Smo(SmoParams { c, ..Default::default() }),
            Engine::cpu_seq(),
        ),
        (
            "MC",
            "LibSVM",
            SolverSpec::Smo(SmoParams { c, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
        (
            "MC",
            "GTSVM",
            SolverSpec::Wss(WssParams { c, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
        (
            "MC",
            "MU",
            SolverSpec::Mu(MuParams { c, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
        (
            "MC",
            "Primal",
            SolverSpec::Primal(PrimalParams { c, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
        (
            "MC",
            "SP-SVM",
            SolverSpec::SpSvm(SpSvmParams { c, max_basis: 255, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
        // the approximate-implicit contender: LS-SVM on a rank-256 ICF
        // operator — the one solver here that never sees the exact kernel
        (
            "MC",
            "LS-SVM",
            SolverSpec::LsSvm(LsSvmParams { c, ..Default::default() }),
            Engine::cpu_par(threads),
        ),
    ];
    // the paper's accelerator row: implicit SP-SVM on the AOT-XLA engine
    // (shows a failed row when artifacts are absent — offline builds)
    let xla_case = coordinator::shared_runtime().map(|rt| {
        (
            "XLA",
            "SP-SVM",
            SolverSpec::SpSvm(SpSvmParams { c, max_basis: 255, ..Default::default() }),
            Engine::xla(rt),
        )
    });

    let metric_of = |margins: &[f32]| match spec.metric {
        paper::Metric::Error => ("error".to_string(), error_rate(margins, &test.y)),
        paper::Metric::OneMinusAuc => ("1-auc".to_string(), 1.0 - auc(margins, &test.y)),
    };

    let mut rows = Vec::new();
    let all_cases = cases.into_iter().map(Ok).chain(std::iter::once(xla_case));
    for case in all_cases {
        let (arch, name, solver_spec, engine) = match case {
            Ok(c) => c,
            Err(e) => {
                eprintln!("XLA/SP-SVM ... unavailable: {e}");
                rows.push(Row {
                    dataset: dataset.clone(),
                    arch: "XLA".into(),
                    method: "SP-SVM".into(),
                    metric_name: "-".into(),
                    test_metric: f64::NAN,
                    train_time: Duration::ZERO,
                    speedup: f64::NAN,
                    notes: format!("{e}").chars().take(48).collect(),
                });
                continue;
            }
        };
        let trainer = Trainer::new(solver_spec)
            .kernel(kind)
            .engine(engine)
            .budget(budget.clone());
        eprint!("{arch}/{name} ... ");
        let t0 = Instant::now();
        match trainer.train(&train) {
            Ok(r) => {
                let train_time = t0.elapsed();
                let margins = r.model.decision_batch(&test, threads);
                let (metric_name, test_metric) = metric_of(&margins);
                eprintln!("{:.2}% in {train_time:?}", test_metric * 100.0);
                let note = |key: &str, tag: &str| {
                    r.notes
                        .iter()
                        .find(|(k, _)| k == key)
                        .map(|(_, v)| format!(" {tag}={v}"))
                        .unwrap_or_default()
                };
                let capped = note("capped", "capped");
                // explicit solvers report shared-row-cache pressure;
                // implicit solvers have no cache and show nothing here
                let cache = format!(
                    "{}{}",
                    note("cache_hit_rate", "hit"),
                    note("cache_evicted_bytes", "evB")
                );
                rows.push(Row {
                    dataset: dataset.clone(),
                    arch: arch.into(),
                    method: name.into(),
                    metric_name,
                    test_metric,
                    train_time,
                    speedup: 1.0,
                    notes: format!("m={}{capped}{cache}", r.model.num_vectors()),
                });
            }
            Err(e) => {
                eprintln!("failed: {e}");
                rows.push(Row {
                    dataset: dataset.clone(),
                    arch: arch.into(),
                    method: name.into(),
                    metric_name: "-".into(),
                    test_metric: f64::NAN,
                    train_time: Duration::ZERO,
                    speedup: f64::NAN,
                    notes: format!("{e}").chars().take(48).collect(),
                });
            }
        }
    }
    fill_speedups(&mut rows, "LibSVM", "SC");
    println!("\n{}", render_table(&rows));
    println!("(speedups are vs single-core LibSVM on the same rows — the paper's convention)");

    // --- convergence trace: the same API, now observed per iteration ---
    println!("\nconvergence (explicit SMO vs implicit SP-SVM, decimated):");
    for (name, solver_spec, every) in [
        ("smo", SolverSpec::Smo(SmoParams { c, ..Default::default() }), 200usize),
        (
            "spsvm",
            SolverSpec::SpSvm(SpSvmParams { c, max_basis: 255, ..Default::default() }),
            1,
        ),
    ] {
        let obs = Arc::new(TraceObserver::every(every));
        let r = Trainer::new(solver_spec)
            .kernel(kind)
            .engine(Engine::cpu_par(threads))
            .budget(budget.clone())
            .observer(obs.clone())
            .train(&train)?;
        println!("-- {name}: {} iters, final objective {:.6}", r.iterations, r.objective);
        print!("{}", obs.to_tsv());
    }
    Ok(())
}
