//! Explicit vs implicit, head to head on one workload — the paper's §5
//! comparison in miniature: LibSVM (SMO, single core), LibSVM+OpenMP
//! (SMO, hand-threaded), GTSVM (WSS-16), SP-SVM (implicit dense-linalg),
//! and the exact implicit baselines (MU, primal Newton) that hit the
//! memory/convergence wall.
//!
//! Run: `cargo run --release --example compare_solvers -- [dataset] [scale]`

use std::time::Duration;

use wu_svm::coordinator::{run, EngineChoice, Solver, TrainJob};
use wu_svm::pool;
use wu_svm::report::{fill_speedups, render_table, Row};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().cloned().unwrap_or_else(|| "covertype".into());
    let scale: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.02);
    let threads = pool::default_threads();

    let cases: Vec<(&str, &str, Solver, EngineChoice)> = vec![
        ("SC", "LibSVM", Solver::Smo, EngineChoice::CpuSeq),
        ("MC", "LibSVM", Solver::Smo, EngineChoice::CpuPar(threads)),
        ("MC", "GTSVM", Solver::Wss, EngineChoice::CpuPar(threads)),
        ("MC", "MU", Solver::Mu, EngineChoice::CpuPar(threads)),
        ("MC", "Primal", Solver::Primal, EngineChoice::CpuPar(threads)),
        ("MC", "SP-SVM", Solver::SpSvm, EngineChoice::CpuPar(threads)),
        ("XLA", "SP-SVM", Solver::SpSvm, EngineChoice::Xla),
    ];

    let mut rows = Vec::new();
    for (arch, name, solver, engine) in cases {
        let job = TrainJob {
            dataset: dataset.clone(),
            scale,
            solver,
            engine,
            max_basis: 255,
            ..Default::default()
        };
        eprint!("{arch}/{name} ... ");
        match run(&job) {
            Ok(rec) => {
                eprintln!("{:.2}% in {:?}", rec.test_metric * 100.0, rec.train_time);
                rows.push(Row {
                    dataset: dataset.clone(),
                    arch: arch.into(),
                    method: name.into(),
                    metric_name: rec.metric_name,
                    test_metric: rec.test_metric,
                    train_time: rec.train_time,
                    speedup: 1.0,
                    notes: format!("m={}", rec.expansion_size),
                });
            }
            Err(e) => {
                eprintln!("failed: {e}");
                rows.push(Row {
                    dataset: dataset.clone(),
                    arch: arch.into(),
                    method: name.into(),
                    metric_name: "-".into(),
                    test_metric: f64::NAN,
                    train_time: Duration::ZERO,
                    speedup: f64::NAN,
                    notes: format!("{e}").chars().take(48).collect(),
                });
            }
        }
    }
    fill_speedups(&mut rows, "LibSVM", "SC");
    println!("\n{}", render_table(&rows));
    println!("(speedups are vs single-core LibSVM on the same rows — the paper's convention)");
    Ok(())
}
