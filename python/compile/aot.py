"""AOT: lower every L2 op x shape bucket to HLO text + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`. Output:
  artifacts/<op>_t<T>_d<D>_b<B>_s<S>.hlo.txt
  artifacts/manifest.txt   lines: "<op> <t> <d> <b> <s> <relative-path>"

The Rust ArtifactStore (rust/src/runtime/manifest.rs) reads the manifest,
picks the smallest bucket that fits a request, and lazily compiles.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Shape buckets (DESIGN.md §5). T is the row-tile size used everywhere in
# the Rust coordinator; d buckets cover the Table-1 datasets; b buckets are
# basis capacities; s is the candidate batch for basis selection.
TILE_T = 1024
D_BUCKETS = (64, 128, 512, 1024, 2048)
B_BUCKETS = (64, 128, 256, 512)
S_CAND = 64

# Reduced set for --quick (python tests, CI smoke).
QUICK_D = (64,)
QUICK_B = (64, 128)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(op_name, t, d, b, s):
    fn, specs = model.op_specs(t, d, b, s)[op_name]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def artifact_name(op, t, d, b, s):
    return f"{op}_t{t}_d{d}_b{b}_s{s}.hlo.txt"


def plan(d_buckets, b_buckets):
    """(op, t, d, b, s) tuples to emit. d/b/s = 0 where the op ignores it."""
    out = []
    for d in d_buckets:
        for b in b_buckets:
            out.append(("kernel_block", TILE_T, d, b, 0))
    for b in b_buckets:
        out.append(("tile_stats", TILE_T, 0, b, 0))
        out.append(("cg_solve", 0, 0, b, 0))
        out.append(("predict_block", TILE_T, 0, b, 0))
    out.append(("score_tile", TILE_T, 0, 0, S_CAND))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="reduced bucket set")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    d_buckets = QUICK_D if args.quick else D_BUCKETS
    b_buckets = QUICK_B if args.quick else B_BUCKETS

    entries = []
    t0 = time.time()
    for op, t, d, b, s in plan(d_buckets, b_buckets):
        # ops take their shapes from whichever of t/d/b/s they use; fill
        # placeholders with the smallest real bucket for lowering.
        name = artifact_name(op, t, d, b, s)
        path = os.path.join(out_dir, name)
        text = lower_op(op, t or TILE_T, d or d_buckets[0], b or b_buckets[0],
                        s or S_CAND)
        with open(path, "w") as f:
            f.write(text)
        entries.append(f"{op} {t} {d} {b} {s} {name}")
        print(f"  {name}: {len(text)} chars", flush=True)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(f"# wu-svm artifact manifest; tile_t={TILE_T} s_cand={S_CAND}\n")
        f.write("\n".join(entries) + "\n")

    print(f"wrote {len(entries)} artifacts to {out_dir} "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
