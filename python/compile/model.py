"""L2: JAX compute graphs for the SP-SVM tile pipeline.

Five ops (DESIGN.md §2), each AOT-lowered by aot.py into one HLO-text
artifact per shape bucket. The Rust coordinator (L3) loads the artifacts
via PJRT and drives the training outer loop; Python never runs at
training/serving time.

Ops:
  kernel_block  — L1 Pallas RBF block (kernels/rbf.py)
  tile_stats    — L1 Pallas fused squared-hinge statistics (kernels/hinge.py)
  cg_solve      — masked damped conjugate-gradient Newton solve
  score_tile    — Keerthi basis-candidate scoring accumulators
  predict_block — margins for a tile

cg_solve is pure jnp with a lax.while_loop so the whole Newton solve is a
single executable call (no host round-trips, no LAPACK custom-calls —
xla_extension 0.5.1 cannot run jax 0.8's LAPACK FFI custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import rbf, hinge

# Fixed CG iteration cap; the loop early-exits on the residual. B<=512 and
# Levenberg damping keep the effective condition number small enough that
# 96 iterations is far past convergence in practice.
CG_MAX_ITERS = 96
CG_TOL = 1e-10


def kernel_block(x, xb, gamma):
    """K[T, B] via the L1 Pallas RBF kernel."""
    return (rbf.rbf_block(x, xb, gamma),)


def tile_stats(k, y, m, beta, c):
    """(g[B], H[B,B], loss[1], nerr[1]) via the L1 Pallas hinge kernel."""
    return tuple(hinge.hinge_stats(k, y, m, beta, c))


def cg_solve(h, g, bmask, reg):
    """delta[B]: (M (H + reg I) M + (I - M)) delta = M g, M = diag(bmask).

    Masking lets one artifact serve any basis occupancy <= B: padded slots
    get an identity row/column and a zero rhs, so they stay exactly zero
    and do not pollute the Krylov space.
    """
    hm = h * (bmask[:, None] * bmask[None, :])
    diag_fix = reg[0] * bmask + (1.0 - bmask)
    hm = hm + jnp.diag(diag_fix)
    b = g * bmask

    def body(state):
        i, x, r, p, rs = state
        ap = hm @ p
        alpha = rs / jnp.maximum(p @ ap, 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return (i + 1, x, r, p, rs_new)

    def cond(state):
        i, _, _, _, rs = state
        return jnp.logical_and(i < CG_MAX_ITERS, rs > CG_TOL)

    x0 = jnp.zeros_like(b)
    state = (jnp.int32(0), x0, b, b, b @ b)
    _, x, _, _, _ = jax.lax.while_loop(cond, body, state)
    return (x * bmask,)


def score_tile(kc, r, a):
    """(gc[S], hc[S]) candidate-scoring accumulators for one tile.

    r_i = a_i y_i hinge_i residuals, a_i = active*valid mask; the Rust
    coordinator turns the accumulated (gc, hc) into Keerthi scores
    g^2 / (lambda + h) and greedily picks the argmax (DESIGN.md §7).
    """
    gc = r @ kc
    hc = a @ (kc * kc)
    return (gc, hc)


def predict_block(k, beta):
    """Margins f[T] = K beta (bias folded into beta[0])."""
    return (k @ beta,)


def op_specs(t, d, b, s):
    """Abstract input specs per op for the given shape bucket."""
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    return {
        "kernel_block": (
            kernel_block,
            (sds((t, d), f32), sds((b, d), f32), sds((1,), f32)),
        ),
        "tile_stats": (
            tile_stats,
            (
                sds((t, b), f32),
                sds((t,), f32),
                sds((t,), f32),
                sds((b,), f32),
                sds((1,), f32),
            ),
        ),
        "cg_solve": (
            cg_solve,
            (sds((b, b), f32), sds((b,), f32), sds((b,), f32), sds((1,), f32)),
        ),
        "score_tile": (
            score_tile,
            (sds((t, s), f32), sds((t,), f32), sds((t,), f32)),
        ),
        "predict_block": (
            predict_block,
            (sds((t, b), f32), sds((b,), f32)),
        ),
    }
