"""L1 Pallas kernel: fused squared-hinge tile statistics.

For the SP-SVM / primal-Newton re-optimization step (paper eq. 4), each
row tile contributes, given its kernel block K[T, B] and the current
coefficients beta[B] (bias folded in as slot 0):

  f_i   = K_i . beta                       (margin)
  h_i   = max(0, 1 - y_i f_i)              (hinge residual)
  a_i   = 1[h_i > 0] * m_i                 (active-row mask, m = validity)
  g    += -2C * sum_i a_i y_i h_i K_i      (data-term gradient w.r.t. beta)
  H    +=  2C * K_A^T K_A                  (Gauss-Newton Gram block)
  loss +=   C * sum_i a_i h_i^2
  nerr += sum_i m_i * 1[y_i f_i <= 0]

Fusing margin + residual + gradient + Gram into one kernel keeps the K tile
resident in VMEM for all four reductions — the paper's "few iterations of
large dense ops" credo applied at tile granularity. The Gram term K_A^T K_A
is the second MXU-shaped matmul of the pipeline.

Grid: row blocks of the tile; outputs are accumulated across grid steps in
the output refs (revisited blocks), which Pallas guarantees for sequential
grids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _hinge_stats_body(k_ref, y_ref, m_ref, beta_ref, c_ref,
                      g_ref, h_ref, loss_ref, nerr_ref):
    step = pl.program_id(0)

    ks = k_ref[...]  # [RB, B]
    ys = y_ref[...]  # [RB]
    ms = m_ref[...]  # [RB]
    beta = beta_ref[...]  # [B]
    c = c_ref[0]

    f = jnp.dot(ks, beta, preferred_element_type=jnp.float32)  # [RB]
    hinge = jnp.maximum(0.0, 1.0 - ys * f)
    active = jnp.where(hinge > 0.0, 1.0, 0.0) * ms

    # gradient: -2C sum_i a_i y_i h_i K_i
    w = active * ys * hinge  # [RB]
    g_blk = -2.0 * c * jnp.dot(w, ks, preferred_element_type=jnp.float32)

    # Gauss-Newton: 2C K_A^T K_A (mask rows, then MXU matmul)
    ka = ks * active[:, None]
    h_blk = 2.0 * c * jnp.dot(ka.T, ka, preferred_element_type=jnp.float32)

    loss_blk = c * jnp.sum(active * hinge * hinge)
    nerr_blk = jnp.sum(ms * jnp.where(ys * f <= 0.0, 1.0, 0.0))

    @pl.when(step == 0)
    def _init():
        g_ref[...] = g_blk
        h_ref[...] = h_blk
        loss_ref[...] = jnp.reshape(loss_blk, (1,))
        nerr_ref[...] = jnp.reshape(nerr_blk, (1,))

    @pl.when(step != 0)
    def _acc():
        g_ref[...] += g_blk
        h_ref[...] += h_blk
        loss_ref[...] += jnp.reshape(loss_blk, (1,))
        nerr_ref[...] += jnp.reshape(nerr_blk, (1,))


def hinge_stats(k, y, m, beta, c):
    """Fused squared-hinge statistics for one row tile.

    Args:
      k: [T, B] kernel block (column 0 is the constant bias column).
      y: [T] labels in {-1, +1}.
      m: [T] row validity mask in {0, 1} (tile padding).
      beta: [B] coefficients (slot 0 = bias).
      c: [1] loss weight C.

    Returns:
      (g[B], H[B, B], loss[1], nerr[1]) — data-term pieces only; the caller
      adds the K_JJ regularizer (DESIGN.md §7).
    """
    t, b = k.shape
    assert t % ROW_BLOCK == 0
    grid = (t // ROW_BLOCK,)
    return pl.pallas_call(
        _hinge_stats_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, b), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, b), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(k, y, m, beta, c)
